"""Shared test setup.

CI installs the real ``hypothesis`` (see requirements-dev.txt).  Minimal
environments (e.g. the bare container this repo is grown in) may not have
it, which previously broke *collection* of five test modules.  When the
import fails we install a small API-compatible fallback into ``sys.modules``
before the test modules import it: strategies become seeded random samplers
and ``@given`` runs a fixed number of examples per test.  No shrinking and
no example database — reduced property coverage, clearly inferior to the
real library, but the properties still execute instead of erroring out.
"""

from __future__ import annotations

import random
import sys
import types
import zlib


def _install_hypothesis_stub() -> None:
    class _Unsatisfied(Exception):
        pass

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rnd: random.Random):
            return self._draw(rnd)

        def map(self, fn):
            return _Strategy(lambda r: fn(self._draw(r)))

        def filter(self, pred):
            def draw(r):
                for _ in range(100):
                    v = self._draw(r)
                    if pred(v):
                        return v
                raise _Unsatisfied

            return _Strategy(draw)

    def integers(min_value=-(2**31), max_value=2**31 - 1):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)

        def draw(r):
            u = r.random()
            if u < 0.05:
                return lo
            if u < 0.10:
                return hi
            if lo > 0.0 and hi / lo > 1e3:
                # wide positive ranges: sample the exponent uniformly so
                # small magnitudes are exercised, like hypothesis would
                import math

                return math.exp(r.uniform(math.log(lo), math.log(hi)))
            return r.uniform(lo, hi)

        return _Strategy(draw)

    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    def sampled_from(seq):
        choices = list(seq)
        return _Strategy(lambda r: r.choice(choices))

    def lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10

        def draw(r):
            return [elements.example_from(r) for _ in range(r.randint(min_size, hi))]

        return _Strategy(draw)

    def tuples(*elements):
        return _Strategy(lambda r: tuple(e.example_from(r) for e in elements))

    class _DataObject:
        def __init__(self, rnd):
            self._rnd = rnd

        def draw(self, strategy, label=None):
            return strategy.example_from(self._rnd)

    def data():
        return _Strategy(lambda r: _DataObject(r))

    def assume(condition):
        if not condition:
            raise _Unsatisfied
        return True

    def given(*args, **strategies):
        if args:
            raise TypeError("stub hypothesis supports keyword strategies only")

        def decorate(fn):
            import functools
            import inspect

            @functools.wraps(fn)
            def wrapper(*wargs, **wkwargs):
                max_examples = getattr(wrapper, "_stub_max_examples", None) or 25
                seed = zlib.crc32(fn.__qualname__.encode())
                rnd = random.Random(seed)
                ran = 0
                attempts = 0
                while ran < max_examples and attempts < max_examples * 20:
                    attempts += 1
                    drawn = {k: s.example_from(rnd) for k, s in strategies.items()}
                    try:
                        fn(*wargs, **wkwargs, **drawn)
                    except _Unsatisfied:
                        continue
                    except BaseException:
                        shown = {
                            k: v for k, v in drawn.items()
                            if not isinstance(v, _DataObject)
                        }
                        print(
                            f"Falsifying example (stub hypothesis, no shrinking): "
                            f"{fn.__qualname__}({shown!r})"
                        )
                        raise
                    ran += 1

            # pytest must not mistake the strategy parameters for fixtures:
            # expose the original signature minus the drawn arguments
            sig = inspect.signature(fn)
            kept = [p for n, p in sig.parameters.items() if n not in strategies]
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=kept)
            return wrapper

        return decorate

    class settings:
        def __init__(self, max_examples=None, deadline=None, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._stub_max_examples = self.max_examples
            return fn

    class HealthCheck:
        too_slow = "too_slow"
        filter_too_much = "filter_too_much"
        data_too_large = "data_too_large"

    st_mod = types.ModuleType("hypothesis.strategies")
    for name, obj in [
        ("integers", integers),
        ("floats", floats),
        ("booleans", booleans),
        ("sampled_from", sampled_from),
        ("lists", lists),
        ("tuples", tuples),
        ("data", data),
    ]:
        setattr(st_mod, name, obj)

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.strategies = st_mod
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    _install_hypothesis_stub()
