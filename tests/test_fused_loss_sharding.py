"""Exactness tests for the fused chunked-vocab loss and sharding utilities."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.sharding import strip_axis


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "pixtral-12b", "whisper-base"])
def test_fused_loss_matches_plain(arch):
    """Fused CE (value AND gradients) must equal the materialized-logits CE."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["image_embed"] = 0.1 * jax.random.normal(
            jax.random.key(2), (2, cfg.num_image_tokens, cfg.d_model)
        )
    if cfg.family == "enc_dec":
        batch["audio_embed"] = 0.1 * jax.random.normal(
            jax.random.key(2), (2, cfg.encoder_seq, cfg.d_model)
        )
    l_plain, g_plain = jax.value_and_grad(
        lambda p: model.train_loss(p, batch, fused_loss=False)
    )(params)
    l_fused, g_fused = jax.value_and_grad(
        lambda p: model.train_loss(p, batch, fused_loss=True)
    )(params)
    assert abs(float(l_plain) - float(l_fused)) < 1e-5
    for a, b in zip(jax.tree.leaves(g_plain), jax.tree.leaves(g_fused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_fused_loss_chunking_is_invariant():
    """Different vocab chunk sizes give identical losses."""
    from repro.models.transformer import fused_next_token_loss

    cfg = dataclasses.replace(
        get_smoke_config("qwen1.5-0.5b"), dtype="float32", vocab_size=512
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    x = 0.3 * jax.random.normal(jax.random.key(3), (2, 8, cfg.d_model))
    toks = jax.random.randint(jax.random.key(4), (2, 8), 0, 512)
    vals = [
        float(fused_next_token_loss(cfg, params, x, toks, chunk=c))
        for c in (64, 128, 512)
    ]
    np.testing.assert_allclose(vals, vals[0], rtol=1e-6)


class TestStripAxis:
    def test_plain(self):
        assert strip_axis(P("data", "model"), "data") == P(None, "model")

    def test_tuple_entries(self):
        assert strip_axis(P(("pod", "data"), "model"), "data") == P("pod", "model")
        assert strip_axis(P(("data",), None), "data") == P(None, None)

    def test_noop_when_absent(self):
        assert strip_axis(P(None, "model"), "data") == P(None, "model")
