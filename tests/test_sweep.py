"""Tests for the batched §7 scenario-sweep engine.

The load-bearing property: the vectorized engine replaying pre-sampled
traces must reproduce the scalar event-driven simulator's completion-time
sequence *exactly* (bit-for-bit) — the batching is a pure reformulation of
the §4.2 event dynamics, not an approximation.
"""

import time

import numpy as np
import pytest

from repro.cluster.simulator import TraceLatencySource
from repro.experiments.grid import (
    HEAVY_BURSTS,
    PAPER_BURSTS,
    default_methods,
    run_sweep,
    scalar_sweep_seconds,
)
from repro.experiments.results import feed_profiler, paper_ordering, write_bench_sweep
from repro.experiments.sweep import (
    replay_batch,
    scalar_reference,
    scalar_sync_reference,
    synchronous_times_batch,
)
from repro.latency.model import make_heterogeneous_cluster, sample_fleet


def make_traces(n_workers=12, n_scenarios=3, horizon=40, burst_rate=None, seed=7):
    cluster = make_heterogeneous_cluster(
        n_workers, seed=seed, burst_rate=0.0, comp_range=(1.1e-3, 2.5e-3)
    )
    return sample_fleet(
        cluster,
        n_scenarios,
        horizon,
        burst_rate=burst_rate,
        burst_factor_mean=3.0,
        burst_duration_mean=5e-3,
        seed=seed + 1,
    )


class TestScalarEquivalence:
    @pytest.mark.parametrize(
        "w,margin,burst_rate",
        [
            (4, 0.0, None),
            (4, 0.02, None),
            (4, 0.02, 3.0),
            (10, 0.0, 3.0),
            (12, 0.0, None),  # w == N: fully synchronous corner
            (1, 0.05, 8.0),  # w == 1: maximal queue feedback
        ],
    )
    def test_batched_matches_scalar_event_loop_exactly(self, w, margin, burst_rate):
        traces = make_traces(burst_rate=burst_rate)
        T = 40
        res = replay_batch(traces, w, T, margin=margin)
        for s in range(traces.num_scenarios):
            ref = scalar_reference(traces, s, w, T, margin=margin)
            np.testing.assert_array_equal(
                ref.iteration_times, res.iteration_times[s],
                err_msg=f"iteration times diverge in scenario {s}",
            )
            np.testing.assert_array_equal(ref.fresh_counts, res.fresh_counts[s])
            np.testing.assert_allclose(ref.participation, res.participation[s])

    def test_heterogeneous_loads_match(self):
        traces = make_traces()
        loads = np.linspace(0.5, 2.0, traces.num_workers)
        res = replay_batch(traces, 5, 30, margin=0.02, loads=loads)
        ref = scalar_reference(traces, 1, 5, 30, margin=0.02, loads=loads)
        np.testing.assert_array_equal(ref.iteration_times, res.iteration_times[1])

    def test_sync_fast_path_equals_replay_at_w_eq_n(self):
        # with w == N every worker is idle at each sync point, so the
        # queue-feedback engine degenerates to the fully-vectorized path
        traces = make_traces(burst_rate=None)
        n = traces.num_workers
        a = replay_batch(traces, n, 40).iteration_times
        b = synchronous_times_batch(traces, n, 40)
        np.testing.assert_array_equal(a, b)

    @pytest.mark.parametrize("burst_rate", [None, 5.0])
    def test_sync_fast_path_matches_scalar_sync_loop_exactly(self, burst_rate):
        traces = make_traces(burst_rate=burst_rate)
        times = synchronous_times_batch(traces, 9, 40, loads=1.5)
        for s in range(traces.num_scenarios):
            ref = scalar_sync_reference(traces, s, 9, 40, loads=1.5)
            np.testing.assert_array_equal(ref, times[s])

    def test_exhausted_trace_draws_raise_instead_of_repeating(self):
        traces = make_traces(horizon=5)
        src = TraceLatencySource(traces, scenario=0)
        for _ in range(5):
            src.task_latency(0, 1.0, 0.0)
        with pytest.raises(ValueError, match="exhausted"):
            src.task_latency(0, 1.0, 0.0)
        with pytest.raises(ValueError, match="draws/worker"):
            replay_batch(traces, 2, 6)
        with pytest.raises(ValueError, match="draws/worker"):
            scalar_reference(traces, 0, 2, 6)

    def test_trace_source_reproduces_sweep_latencies(self):
        """TraceLatencySource consumes the same streams as the engines."""
        traces = make_traces()
        src = TraceLatencySource(traces, scenario=0)
        comp0, comm0 = src.task_latency(3, 1.0, 0.0)
        assert comm0 == traces.comm[0, 3, 0]
        comp1, _ = src.task_latency(3, 2.0, 0.0)
        # per-unit draw advanced and scaled by the doubled load
        assert comp1 == pytest.approx(2.0 * traces.comp_unit[0, 3, 1]
                                      * traces.slowdown[3])


class TestSweepGrid:
    def test_dsag_not_slower_than_sag_under_bursts(self):
        """Smoke sweep: the paper's headline ordering in the burst regime."""
        out = run_sweep(
            n_workers=40, n_seeds=4, num_iterations=60,
            regimes=(PAPER_BURSTS, HEAVY_BURSTS),
        )
        for regime in ("paper_bursts", "heavy_bursts"):
            t_dsag = out.mean_iter_time(regime, "dsag")
            t_sag = out.mean_iter_time(regime, "sag")
            assert t_dsag <= t_sag, (regime, t_dsag, t_sag)
            ordering = paper_ordering(out, regime)
            assert ordering["coded_over_dsag"] > 1.0

    def test_vectorized_engine_much_faster_than_scalar(self):
        """The acceptance grid: 100 workers x 5 methods x 10 seeds."""
        out = run_sweep(
            n_workers=100, n_seeds=10, num_iterations=40,
            regimes=(HEAVY_BURSTS,),
        )
        assert len({(m) for (_, m, _) in out.results}) == 5
        t0 = time.perf_counter()
        scalar_s = scalar_sweep_seconds(out)
        assert time.perf_counter() - t0 >= scalar_s  # sanity on the timer
        speedup = scalar_s / out.engine_seconds
        # ~25x on an idle machine (recorded in BENCH_sweep.json); the CI gate
        # uses half the acceptance bar so scheduler noise on shared runners
        # cannot flake a genuinely-fast engine
        assert speedup >= 5.0, f"only {speedup:.1f}x faster than scalar loop"

    def test_bench_artifact_round_trips(self, tmp_path):
        out = run_sweep(n_workers=16, n_seeds=2, num_iterations=20)
        path = tmp_path / "BENCH_sweep.json"
        payload = write_bench_sweep(out, str(path), scalar_seconds=1.0)
        import json

        on_disk = json.loads(path.read_text())
        assert on_disk == payload
        assert on_disk["grid"]["n_workers"] == 16
        assert on_disk["grid"]["n_cells"] == len(out.results)
        assert "heavy_bursts" in on_disk["ordering"]
        assert on_disk["speedup_vs_scalar"] == pytest.approx(1.0 / out.engine_seconds)

    def test_default_methods_cover_the_five_columns(self):
        names = [m.name for m in default_methods(100)]
        assert names == ["gd", "coded", "sgd", "sag", "dsag"]

    def test_w_values_above_n_dedup_after_clamping(self):
        # 120 and 150 both clamp to N: the cell must run (and be counted) once
        out = run_sweep(
            n_workers=12, n_seeds=2, num_iterations=10,
            w_values=(120, 150), w_fracs=(),
        )
        dsag_rows = [r for r in out.rows if r.method == "dsag" and r.regime == "calm"]
        assert [r.w for r in dsag_rows] == [12, 12]  # one w cell x two seeds

    def test_sync_participation_is_measured_not_fabricated(self):
        # coded (w < N, sync): slow workers land in the first w less often,
        # so per-worker participation must be non-uniform and average w/N
        out = run_sweep(n_workers=20, n_seeds=3, num_iterations=40)
        res = out.results[("calm", "coded", 19)]
        part = res.participation
        assert part.min() < part.max()
        np.testing.assert_allclose(part.mean(axis=1), 19 / 20, rtol=1e-12)

    def test_scalar_baseline_uses_the_swept_method_specs(self):
        from repro.experiments.grid import MethodSpec

        custom = (MethodSpec("dsag_wide_margin", 0, margin=0.10),)
        out = run_sweep(
            n_workers=10, n_seeds=2, num_iterations=10,
            methods=custom, regimes=(HEAVY_BURSTS,),
        )
        assert out.methods == custom
        assert scalar_sweep_seconds(out) > 0.0  # no KeyError on custom names
        assert paper_ordering(out, "heavy_bursts") == {}  # no dsag column

    def test_mismatched_custom_cluster_is_refused(self):
        with pytest.raises(ValueError, match="cluster has 30 workers"):
            run_sweep(
                n_workers=20, n_seeds=2, num_iterations=10,
                cluster=make_heterogeneous_cluster(30, burst_rate=0.0, seed=0),
            )

    def test_ordering_uses_best_w_cell_not_the_average(self):
        # a deliberately bad extra w for dsag must not flip the verdict
        out = run_sweep(
            n_workers=20, n_seeds=3, num_iterations=30,
            w_fracs=(0.8, 1.0), regimes=(HEAVY_BURSTS,),
        )
        o = paper_ordering(out, "heavy_bursts")
        assert o["dsag_w"] == 16  # the fast operating point, not a blend
        assert o["dsag_mean_iter_time"] == out.mean_iter_time(
            "heavy_bursts", "dsag", 16
        )

    def test_burst_regimes_actually_slow_the_synchronous_methods(self):
        # stationary burst start: even runs much shorter than 1/rate must
        # feel the regime (heavy: 60% of workers begin mid-burst at ~4x)
        out = run_sweep(n_workers=40, n_seeds=6, num_iterations=60)
        assert out.mean_iter_time("heavy_bursts", "sag") > 1.5 * out.mean_iter_time(
            "calm", "sag"
        )

    def test_timed_events_refused_with_trace_replay(self):
        from repro.cluster.simulator import MethodConfig, TrainingSimulator
        from repro.core.problems import LogisticRegressionProblem, make_higgs_like

        traces = make_traces(n_workers=4)
        X, y = make_higgs_like(64, seed=0)
        prob = LogisticRegressionProblem(X=X, y=y)
        cluster = make_heterogeneous_cluster(4, seed=1)
        with pytest.raises(ValueError, match="timed_events"):
            TrainingSimulator(
                prob,
                cluster,
                MethodConfig(name="dsag", w=2),
                timed_events=[(1.0, lambda c: None)],
                latency_source=TraceLatencySource(traces, 0),
            )

    def test_trace_replay_through_training_simulator_is_deterministic(self):
        """Two replays of the same scenario produce identical histories."""
        from repro.cluster.simulator import MethodConfig, TrainingSimulator
        from repro.core.problems import LogisticRegressionProblem, make_higgs_like

        traces = make_traces(n_workers=4, horizon=30)
        X, y = make_higgs_like(64, seed=0)
        prob = LogisticRegressionProblem(X=X, y=y)
        runs = []
        for _ in range(2):
            cluster = make_heterogeneous_cluster(4, seed=1)
            sim = TrainingSimulator(
                prob,
                cluster,
                MethodConfig(name="dsag", w=2, subpartitions=2),
                latency_source=TraceLatencySource(traces, 1),
                seed=0,
            )
            runs.append(sim.run(15))
        np.testing.assert_array_equal(runs[0].times, runs[1].times)
        assert runs[0].times[-1] > 0


class TestProfilerFeed:
    def test_batched_trace_feeds_profiler_moments(self):
        traces = make_traces(n_workers=6, n_scenarios=2, horizon=60)
        res = replay_batch(traces, 4, 60, margin=0.02, record_tasks=True)
        prof = feed_profiler(res, scenario=0, load=1.0)
        now = float(res.iteration_times[0, -1])
        stats = prof.all_stats(now)
        assert len(stats) == 6  # every worker produced samples
        for i, s in stats.items():
            # the profiler's compute-latency moments must track the trace's
            # per-worker draws (same data, moving-window mean)
            started = ~np.isnan(res.task_comp[0, :, i])
            np.testing.assert_allclose(
                s.e_comp, res.task_comp[0, started, i].mean(), rtol=1e-9
            )
            assert s.e_comm > 0.0
            assert s.num_samples == int(started.sum())

    def test_accumulating_two_scenarios_keeps_window_eviction_sound(self):
        # scenario clocks both start at 0; the profiler must re-sort so that
        # the moving-window eviction never strands stale samples behind
        # in-window ones
        traces = make_traces(n_workers=4, n_scenarios=2, horizon=40)
        res = replay_batch(traces, 3, 40, record_tasks=True)
        prof = feed_profiler(res, scenario=0, window=1e-2)
        prof = feed_profiler(res, scenario=1, window=1e-2, profiler=prof)
        now = float(res.iteration_times[:, -1].max())
        stats = prof.all_stats(now)
        for i, s in stats.items():
            fin0 = res.task_finish[0, :, i]
            fin1 = res.task_finish[1, :, i]
            in_window = (fin0 >= now - 1e-2).sum() + (fin1 >= now - 1e-2).sum()
            assert s.num_samples == int(in_window)

    def test_record_tasks_arrays_consistent_with_times(self):
        traces = make_traces()
        res = replay_batch(traces, 5, 30, record_tasks=True)
        # iteration times are strictly increasing, and each iteration's w-th
        # fresh arrival equals the iteration time when no margin is set
        fin = res.task_finish
        assert np.all(np.diff(res.iteration_times, axis=1) > 0)
        for s in range(traces.num_scenarios):
            for t in range(30):
                row = fin[s, t]
                kth = np.sort(row[~np.isnan(row)])[4]
                assert kth == pytest.approx(res.iteration_times[s, t])
