"""Cross-engine bit-exactness for §6 load-balanced configs (the tentpole).

The fused ``jax.lax.scan`` engine now runs Algorithm 1 inside the scan
(:mod:`repro.lb.jit_optimizer` + the pre-allocated slot universe).  These
tests pin the load-bearing property: for §6 configs — margin on and off,
repartition-heavy traces, cache and non-cache methods, vector and matrix
iterates — the scan reproduces the batched host engine and the scalar
``TrainingSimulator`` bit for bit, including the repartition schedule and
the cache eviction/rejection telemetry.  They also pin the routing
contract: ``EngineConfig(kind="auto")`` sends §6 configs to the scan; a
slot universe above the budget routes through the tiled active-slot
cache (still bit-exact); and the one genuinely unsupported case (the
active-entry footprint itself exceeds the budget) raises a structured
``EngineCapabilityError`` instead of silently falling back.
"""

import numpy as np
import pytest

import repro.experiments.fused as fused
from repro.cluster.simulator import (
    MethodConfig,
    TraceLatencySource,
    TrainingSimulator,
)
from repro.core.problems import (
    LogisticRegressionProblem,
    PCAProblem,
    make_genomics_like_matrix,
    make_higgs_like,
)
from repro.experiments.convergence import run_convergence_batch
from repro.latency.model import (
    make_heterogeneous_cluster,
    make_paper_artificial_cluster,
    sample_fleet,
)


@pytest.fixture(scope="module")
def logreg_small():
    X, y = make_higgs_like(480, seed=0)
    return LogisticRegressionProblem(X=X, y=y)


@pytest.fixture(scope="module")
def pca_small():
    return PCAProblem(X=make_genomics_like_matrix(240, 48, seed=0), k=3)


def artificial_fleet(problem, n_workers=6, n_scenarios=3, horizon=40, seed=11):
    """Persistent per-worker slowdowns: the §7.2-style LB showcase."""
    sp = 4
    c_task = problem.compute_cost(
        1, max(problem.num_samples // (n_workers * sp), 1)
    )
    cluster = make_paper_artificial_cluster(
        num_workers=n_workers, load_unit=c_task, seed=1
    )
    return cluster, sample_fleet(cluster, n_scenarios, horizon, seed=seed)


def bursty_fleet(n_workers=6, n_scenarios=2, horizon=30, seed=3):
    cluster = make_heterogeneous_cluster(
        n_workers, seed=seed, burst_rate=0.0, comp_range=(1.1e-3, 2.5e-3)
    )
    traces = sample_fleet(
        cluster, n_scenarios, horizon,
        burst_rate=3.0, burst_factor_mean=3.0, burst_duration_mean=5e-3,
        seed=seed + 8,
    )
    return cluster, traces


def lb_config(name="dsag", w=3, sp=4, **kw):
    kw.setdefault("lb_startup_delay", 0.005)
    kw.setdefault("lb_interval", 0.01)
    return MethodConfig(
        name=name, w=w, eta=0.25, subpartitions=sp, load_balance=True, **kw
    )


def assert_results_equal(a, b):
    np.testing.assert_array_equal(a.times, b.times)
    np.testing.assert_array_equal(a.suboptimality, b.suboptimality)
    np.testing.assert_array_equal(a.fresh_counts, b.fresh_counts)
    np.testing.assert_array_equal(a.per_worker_latency, b.per_worker_latency)
    np.testing.assert_array_equal(a.evictions, b.evictions)
    np.testing.assert_array_equal(a.rejected_stale, b.rejected_stale)
    assert a.repartition_events == b.repartition_events


class TestScanVsHostLB:
    """scan == host for §6 configs, and the balancer really balances."""

    def test_dsag_margin_on(self, logreg_small):
        cluster, traces = artificial_fleet(logreg_small)
        cfg = lb_config("dsag", margin=0.02)
        host = run_convergence_batch(
            logreg_small, traces, cfg, 40, eval_every=2, seed=0, engine="host"
        )
        scan = run_convergence_batch(
            logreg_small, traces, cfg, 40, eval_every=2, seed=0, engine="scan"
        )
        assert_results_equal(host, scan)
        # vacuity guard: the balancer must publish on this fleet
        assert any(len(ev) > 0 for ev in host.repartition_events)

    def test_dsag_margin_off(self, logreg_small):
        cluster, traces = artificial_fleet(logreg_small)
        cfg = lb_config("dsag", margin=0.0)
        host = run_convergence_batch(
            logreg_small, traces, cfg, 40, seed=0, engine="host"
        )
        scan = run_convergence_batch(
            logreg_small, traces, cfg, 40, seed=0, engine="scan"
        )
        assert_results_equal(host, scan)

    @pytest.mark.parametrize("name,w", [("sag", 6), ("sgd", 3)])
    def test_other_methods_with_lb(self, logreg_small, name, w):
        cluster, traces = bursty_fleet()
        cfg = lb_config(name, w=w, sp=3, lb_startup_delay=0.002, lb_interval=0.005)
        host = run_convergence_batch(
            logreg_small, traces, cfg, 30, seed=0, engine="host"
        )
        scan = run_convergence_batch(
            logreg_small, traces, cfg, 30, seed=0, engine="scan"
        )
        assert_results_equal(host, scan)

    def test_repartition_heavy_trace(self, logreg_small):
        """An aggressive publication schedule: many repartitions per run, so
        the slot-universe eviction walk and Algorithm-2 alignment are
        exercised hard — and the engines still agree bit for bit."""
        cluster, traces = bursty_fleet()
        cfg = lb_config("dsag", w=2, sp=3, lb_startup_delay=0.002, lb_interval=0.005)
        host = run_convergence_batch(
            logreg_small, traces, cfg, 30, seed=0, engine="host"
        )
        scan = run_convergence_batch(
            logreg_small, traces, cfg, 30, seed=0, engine="scan"
        )
        assert_results_equal(host, scan)
        assert min(len(ev) for ev in host.repartition_events) >= 5
        # repartitions must actually evict overlapping cache entries
        assert (host.evictions > 0).any()

    def test_pca_matrix_iterate(self, pca_small):
        """Matrix-valued cache entries through the LB slot universe."""
        cluster, traces = bursty_fleet()
        cfg = MethodConfig(
            name="dsag", w=2, eta=0.9, subpartitions=3, load_balance=True,
            lb_startup_delay=0.002, lb_interval=0.005,
        )
        host = run_convergence_batch(
            pca_small, traces, cfg, 25, eval_every=2, seed=0, engine="host"
        )
        scan = run_convergence_batch(
            pca_small, traces, cfg, 25, eval_every=2, seed=0, engine="scan"
        )
        assert_results_equal(host, scan)

    def test_scan_matches_scalar_simulator(self, logreg_small):
        """Direct scan-vs-scalar check (not only via the host engine)."""
        cluster, traces = artificial_fleet(logreg_small)
        cfg = lb_config("dsag")
        scan = run_convergence_batch(
            logreg_small, traces, cfg, 40, eval_every=2, seed=0, engine="scan"
        )
        for s in range(traces.num_scenarios):
            sim = TrainingSimulator(
                logreg_small, cluster, cfg, eval_every=2, seed=0,
                latency_source=TraceLatencySource(traces, s),
            )
            h = sim.run(40)
            np.testing.assert_array_equal(h.times, scan.times[s])
            np.testing.assert_array_equal(h.suboptimality, scan.suboptimality[s])
            np.testing.assert_array_equal(
                h.per_worker_latency, scan.per_worker_latency[s]
            )
            assert list(h.repartition_events) == list(scan.repartition_events[s])
            assert h.evictions == scan.evictions[s]
            assert h.rejected_stale == scan.rejected_stale[s]


class TestRouting:
    """engine='auto' contract: scan by default, host only behind the
    documented slot-universe escape hatch, never silently."""

    def test_auto_routes_lb_to_scan(self, logreg_small, monkeypatch):
        cluster, traces = artificial_fleet(logreg_small)
        cfg = lb_config("dsag")
        calls = []
        orig = fused.run_convergence_scan

        def spy(*args, **kw):
            calls.append(1)
            return orig(*args, **kw)

        monkeypatch.setattr(fused, "run_convergence_scan", spy)
        res = run_convergence_batch(logreg_small, traces, cfg, 10, seed=0)
        assert calls, "auto must route §6 configs to the fused scan"
        assert np.isfinite(res.times).all()

    def test_oversized_universe_runs_tiled_bitexact(self, logreg_small):
        """Bugfix pin: a slot universe above the budget no longer raises
        from explicit ``kind="scan"`` — it routes through the tiled
        active-slot cache and stays bit-exact against the host engine."""
        from repro.experiments.engine import CAP_TILED, EngineConfig

        cluster, traces = artificial_fleet(logreg_small)
        cfg = lb_config("dsag")
        cap_dense = fused.scan_capability(logreg_small, cfg, traces.num_workers)
        budget = cap_dense.slots_total - 1  # forces the tiled layout
        cap = fused.scan_capability(
            logreg_small, cfg, traces.num_workers, slot_budget=budget
        )
        assert cap.supported and cap.code == CAP_TILED
        assert cap.slots_resident <= budget < cap.slots_total
        tiled = run_convergence_batch(
            logreg_small, traces, cfg, 20, seed=0,
            engine=EngineConfig(kind="scan", slot_budget=budget),
        )
        host = run_convergence_batch(
            logreg_small, traces, cfg, 20, seed=0, engine=EngineConfig(kind="host")
        )
        assert_results_equal(host, tiled)
        # the §7.2 showcase actually repartitions, so the tiled walk's
        # eviction path is exercised, not just the SAG fast path
        assert sum(len(ev) for ev in tiled.repartition_events) > 0
        assert tiled.evictions.sum() > 0

    def test_unsupported_config_raises_capability_error(self, logreg_small):
        """Explicit ``kind="scan"`` on a genuinely unsupported config (the
        active-entry footprint itself exceeds the budget) must raise a
        structured capability error — not quietly fall back."""
        from repro.experiments.engine import (
            CAP_ACTIVE_SET,
            EngineCapabilityError,
            EngineConfig,
        )

        cluster, traces = artificial_fleet(logreg_small)
        cfg = lb_config("dsag")
        with pytest.raises(EngineCapabilityError) as exc:
            run_convergence_batch(
                logreg_small, traces, cfg, 10, seed=0,
                engine=EngineConfig(kind="scan", slot_budget=3),
            )
        cap = exc.value.capability
        assert cap.code == CAP_ACTIVE_SET and not cap.supported
        assert cap.slots_resident > cap.slot_budget == 3
        # still a ValueError telling the operator what to do instead
        assert isinstance(exc.value, ValueError)
        assert "host" in str(exc.value)

    def test_unsupported_config_auto_falls_back_to_host(self, logreg_small):
        from repro.experiments.engine import EngineConfig

        cluster, traces = artificial_fleet(logreg_small)
        cfg = lb_config("dsag")
        auto = run_convergence_batch(
            logreg_small, traces, cfg, 20, seed=0,
            engine=EngineConfig(kind="auto", slot_budget=3),
        )
        host = run_convergence_batch(
            logreg_small, traces, cfg, 20, seed=0, engine=EngineConfig(kind="host")
        )
        assert_results_equal(auto, host)

    def test_legacy_lb_max_slots_monkeypatch_still_gates(
        self, logreg_small, monkeypatch
    ):
        """The module constant is still the default budget."""
        cluster, traces = artificial_fleet(logreg_small)
        cfg = lb_config("dsag")
        monkeypatch.setattr(fused, "LB_MAX_SLOTS", 3)
        with pytest.warns(DeprecationWarning, match="scan_capability"):
            reason = fused.scan_unsupported_reason(
                logreg_small, cfg, traces.num_workers
            )
        assert reason is not None


class TestJitOptimizerInvariances:
    """The empirical CPU properties the cross-engine contract rests on."""

    def test_estimate_h_row_independent_of_batch(self):
        """A scenario's h draws depend only on its own moments — not on its
        row position or on which scenarios share the batch."""
        from repro.lb.optimizer import LoadBalanceOptimizer, OptimizerInputs

        rng = np.random.default_rng(0)
        S, N = 3, 5
        e_comp = rng.uniform(1e-3, 3e-3, (S, N))
        e_comm = rng.uniform(1e-4, 3e-4, (S, N))

        def inputs(rows):
            return OptimizerInputs(
                e_comm=e_comm[rows],
                v_comm=(0.1 * e_comm[rows]) ** 2,
                e_comp=e_comp[rows],
                v_comp=(0.1 * e_comp[rows]) ** 2,
                samples_per_worker=np.full((len(rows), N), 80.0),
                w=3,
            )

        opt = LoadBalanceOptimizer(seed=0, sim_iterations=30, ladder=(2, 4, 8))
        p = np.full((S, N), 4, dtype=np.int64)
        full = opt.update_batch(p, inputs(range(S)))[0]
        sub = opt.update_batch(p[1:], inputs([1, 2]))[0]
        np.testing.assert_array_equal(full[1:], sub)

    def test_moment_buffer_batch_invariance(self):
        """Row s of the [S, N, T] moments kernel equals the [1, N, T] call."""
        from repro.latency.profiler import MomentBuffer

        rng = np.random.default_rng(1)
        S, N, T = 3, 4, 6
        buf = MomentBuffer(S, N, T)
        for s in range(S):
            for i in range(N):
                for t in range(T - 1):
                    buf.record(
                        s, i, t,
                        rng.uniform(0, 5), rng.uniform(0.1, 1), rng.uniform(0.01, 0.5),
                    )
        now = rng.uniform(4, 6, S)
        full = buf.moments(now)
        for s in range(S):
            one = MomentBuffer(1, N, T)
            one.t_rec[0] = buf.t_rec[s]
            one.comm[0] = buf.comm[s]
            one.comp[0] = buf.comp[s]
            one.valid[0] = buf.valid[s]
            single = one.moments(now[s : s + 1])
            for a, b in zip(full, single):
                np.testing.assert_array_equal(a[s], b[0])
