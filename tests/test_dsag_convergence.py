"""End-to-end Tier-3 convergence tests reproducing the paper's §7 claims."""

import numpy as np
import pytest

from repro.cluster.simulator import MethodConfig, TrainingSimulator
from repro.core.problems import (
    LogisticRegressionProblem,
    PCAProblem,
    make_genomics_like_matrix,
    make_higgs_like,
)
from repro.latency.model import clear_slowdowns, make_paper_artificial_cluster


@pytest.fixture(scope="module")
def pca_problem():
    X = make_genomics_like_matrix(4096, 96, seed=0)
    return PCAProblem(X=X, k=3)


@pytest.fixture(scope="module")
def logreg_problem():
    X, y = make_higgs_like(8192, seed=0)
    return LogisticRegressionProblem(X=X, y=y)


def _run(problem, name, w, iters, eta, lb=False, sp=10, N=12, seed=0):
    c_task = problem.compute_cost(1, max(problem.num_samples // (N * sp), 1))
    cluster = make_paper_artificial_cluster(num_workers=N, load_unit=c_task, seed=1)
    events = [(1.0, lambda c: clear_slowdowns(c, range(N - 3, N)))]
    cfg = MethodConfig(name=name, w=w, eta=eta, subpartitions=sp, load_balance=lb)
    sim = TrainingSimulator(
        problem, cluster, cfg, eval_every=10, timed_events=events, seed=seed
    )
    return sim.run(iters)


class TestPCAClaims:
    def test_gd_is_power_method_and_converges(self, pca_problem):
        h = _run(pca_problem, "gd", 0, 60, eta=1.0)
        assert h.suboptimality[-1] < 1e-7  # fp32-iterate floor

    def test_dsag_converges_to_optimum_with_small_w(self, pca_problem):
        """The paper's headline: DSAG reaches the optimum even with w << N."""
        h = _run(pca_problem, "dsag", 3, 300, eta=0.9)
        assert h.suboptimality[-1] < 1e-6  # fp32-iterate floor

    def test_sag_with_small_w_stalls_above_dsag(self, pca_problem):
        """SAG with w<N stops converging (straggler samples never enter);
        DSAG with the same w reaches far lower gaps (paper Fig. 8)."""
        h_sag = _run(pca_problem, "sag", 3, 300, eta=0.9)
        h_dsag = _run(pca_problem, "dsag", 3, 300, eta=0.9)
        assert h_dsag.suboptimality[-1] < h_sag.suboptimality[-1] * 1e-2

    def test_dsag_iterations_faster_than_sag_full_wait(self, pca_problem):
        h_sagN = _run(pca_problem, "sag", 12, 200, eta=0.9)
        h_dsag = _run(pca_problem, "dsag", 3, 200, eta=0.9)
        assert h_dsag.times[-1] < h_sagN.times[-1]

    def test_coded_latency_exceeds_stochastic(self, pca_problem):
        """Coded computing pays 1/r extra compute; per-iteration latency is
        above DSAG's (paper: 'more than twice as fast as coded')."""
        h_coded = _run(pca_problem, "coded", 0, 50, eta=1.0)
        h_dsag = _run(pca_problem, "dsag", 3, 50, eta=0.9)
        assert h_dsag.times[-1] < h_coded.times[-1]


class TestLogregClaims:
    def test_dsag_converges(self, logreg_problem):
        h = _run(logreg_problem, "dsag", 3, 400, eta=0.25)
        assert h.suboptimality[np.isfinite(h.suboptimality)][-1] < 5e-3

    def test_dsag_beats_sag_small_w(self, logreg_problem):
        """SAG w<N oscillates around ~2e-3 (missing straggler samples) while
        DSAG keeps converging — visible from ~600 iterations on."""
        h_sag = _run(logreg_problem, "sag", 3, 1000, eta=0.25)
        h_dsag = _run(logreg_problem, "dsag", 3, 1000, eta=0.25)
        gap_sag = h_sag.suboptimality[np.isfinite(h_sag.suboptimality)][-1]
        gap_dsag = h_dsag.suboptimality[np.isfinite(h_dsag.suboptimality)][-1]
        assert gap_dsag < gap_sag / 5.0

    def test_sgd_stalls_without_variance_reduction(self, logreg_problem):
        h_sgd = _run(logreg_problem, "sgd", 3, 400, eta=0.25)
        h_dsag = _run(logreg_problem, "dsag", 3, 400, eta=0.25)
        gap_sgd = h_sgd.suboptimality[np.isfinite(h_sgd.suboptimality)][-1]
        gap_dsag = h_dsag.suboptimality[np.isfinite(h_dsag.suboptimality)][-1]
        assert gap_dsag < gap_sgd


class TestDegeneracy:
    def test_dsag_equals_sag_when_all_fresh(self, pca_problem):
        """With w=N every result is fresh, so DSAG == SAG exactly."""
        h_sag = _run(pca_problem, "sag", 12, 80, eta=0.9, seed=3)
        h_dsag = _run(pca_problem, "dsag", 12, 80, eta=0.9, seed=3)
        # identical latency draws (same seeds) and identical updates
        sag_gaps = h_sag.suboptimality[np.isfinite(h_sag.suboptimality)]
        dsag_gaps = h_dsag.suboptimality[np.isfinite(h_dsag.suboptimality)]
        np.testing.assert_allclose(sag_gaps, dsag_gaps, rtol=1e-6)

    def test_load_balancing_reduces_latency_spread(self, logreg_problem):
        h_lb = _run(logreg_problem, "dsag", 3, 400, eta=0.25, lb=True)
        assert len(h_lb.repartition_events) >= 1
        gap = h_lb.suboptimality[np.isfinite(h_lb.suboptimality)][-1]
        assert gap < 5e-3  # still converges with repartitioning evictions
