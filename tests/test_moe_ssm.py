"""Oracle tests for the MoE dispatch and the Mamba2 SSD kernel-free paths."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.layers import init_from_decls
from repro.models.moe import moe_apply, moe_decls, moe_reference
from repro.models.ssm import (
    mamba_decls,
    mamba_forward,
    mamba_reference_recurrent,
)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = dataclasses.replace(get_smoke_config("deepseek-v2-236b"), dtype="float32")
    params = init_from_decls(moe_decls(cfg), jax.random.key(3), jnp.float32)
    return cfg, params


class TestMoE:
    def test_sort_dispatch_matches_dense_reference(self, moe_setup):
        cfg, params = moe_setup
        x = 0.5 * jax.random.normal(jax.random.key(4), (2, 8, cfg.d_model))
        y_fast, _ = moe_apply(cfg, params, x, capacity_factor=8.0)
        y_ref = moe_reference(cfg, params, x)
        np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref), atol=2e-4)

    @pytest.mark.parametrize("shape", [(1, 4), (2, 16), (3, 7)])
    def test_shapes_and_finiteness(self, moe_setup, shape):
        cfg, params = moe_setup
        b, s = shape
        x = 0.5 * jax.random.normal(jax.random.key(5), (b, s, cfg.d_model))
        y, aux = moe_apply(cfg, params, x)
        assert y.shape == x.shape
        assert jnp.isfinite(y).all() and jnp.isfinite(aux)
        # Switch-style aux loss is ~1 at uniform routing, bounded by E
        assert 0.0 < float(aux) <= cfg.num_experts

    def test_capacity_drops_reduce_output_not_crash(self, moe_setup):
        cfg, params = moe_setup
        x = 0.5 * jax.random.normal(jax.random.key(6), (4, 32, cfg.d_model))
        y_low, _ = moe_apply(cfg, params, x, capacity_factor=0.5)
        y_high, _ = moe_apply(cfg, params, x, capacity_factor=8.0)
        assert jnp.isfinite(y_low).all()
        # dropping must change (reduce) the routed contribution
        assert float(jnp.abs(y_low - y_high).max()) > 0.0

    def test_grads_flow_through_dispatch(self, moe_setup):
        cfg, params = moe_setup
        x = 0.5 * jax.random.normal(jax.random.key(7), (2, 8, cfg.d_model))

        def loss(p):
            y, aux = moe_apply(cfg, p, x)
            return jnp.sum(y * y) + aux

        g = jax.grad(loss)(params)
        gnorm = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
        assert np.isfinite(gnorm) and gnorm > 0


class TestSSD:
    @pytest.mark.parametrize("seq", [8, 24, 33])  # incl. non-multiple of chunk
    def test_chunked_matches_recurrent(self, seq):
        cfg = dataclasses.replace(get_smoke_config("mamba2-370m"), dtype="float32")
        params = init_from_decls(mamba_decls(cfg), jax.random.key(1), jnp.float32)
        x = 0.5 * jax.random.normal(jax.random.key(2), (2, seq, cfg.d_model))
        y_chunk = mamba_forward(cfg, params, x)
        y_rec, _ = mamba_reference_recurrent(cfg, params, x)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec), atol=5e-4)

    def test_prefill_state_matches_recurrent_state(self):
        cfg = dataclasses.replace(get_smoke_config("mamba2-370m"), dtype="float32")
        params = init_from_decls(mamba_decls(cfg), jax.random.key(1), jnp.float32)
        x = 0.5 * jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model))
        _, st = mamba_forward(cfg, params, x, return_state=True)
        _, cache = mamba_reference_recurrent(cfg, params, x)
        np.testing.assert_allclose(
            np.asarray(st["state"]), np.asarray(cache["state"]), atol=5e-4
        )

    def test_state_decay_is_stable(self):
        """The SSD decay factors exp(dt*A) must lie in (0, 1] — no blowup."""
        cfg = dataclasses.replace(get_smoke_config("mamba2-370m"), dtype="float32")
        params = init_from_decls(mamba_decls(cfg), jax.random.key(1), jnp.float32)
        x = 3.0 * jax.random.normal(jax.random.key(9), (1, 64, cfg.d_model))
        y = mamba_forward(cfg, params, x)
        assert jnp.isfinite(y).all()
