"""tracelint regression suite: each rule fires when its idiom is removed.

Two layers:

* **mutation fixtures** — for every rule TL001–TL005, a probe with the
  protective idiom surgically removed (the seam dropped, a stray read
  added, the mask deleted, the dtype left weak, a cond pushed into the
  rank loop) must produce that exact rule code, and the intact twin must
  stay clean;
* **HEAD pins** — the production entries are lint-clean under the
  committed ``tracelint.toml`` (and the two known grid-cache TL002
  findings are exactly the suppressed set), plus a subprocess test that
  the CLI gate exits 1 on a non-baselined finding.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.lint import baseline as lint_baseline
from repro.analysis.lint import entries as lint_entries
from repro.analysis.lint import rules as lint_rules
from repro.analysis.lint.entries import EntryProbe
from repro.analysis.lint.runner import run_lint
from repro.experiments import fused
from repro.latency.model import comp_latency_expr

REPO_ROOT = Path(__file__).resolve().parent.parent


def codes(findings):
    return sorted({f.code for f in findings})


# ---------------------------------------------------------------------------
# TL001 — fma-seam
# ---------------------------------------------------------------------------


class TestTL001FmaSeam:
    def test_head_latency_chain_is_clean(self):
        entry = lint_entries.ENTRIES["latency"]()
        assert lint_rules.check_fma_seam(entry) == []

    def test_removing_the_seam_fires(self, monkeypatch):
        """Delete the jnp.maximum(comp_d, 0.0) seam: the compiled chain
        contracts the last multiply into the task_finish_time add and the
        bitwise diff against op-by-op evaluation catches it."""
        monkeypatch.setattr(
            fused,
            "guarded_comp_latency",
            lambda unit, cost, slowdown, factor: comp_latency_expr(
                unit, cost, slowdown, factor
            ),
        )
        entry = lint_entries.ENTRIES["latency"]()
        findings = lint_rules.check_fma_seam(entry)
        assert codes(findings) == ["TL001"]
        assert "seam" in findings[0].message


# ---------------------------------------------------------------------------
# TL002 — carry-copy
# ---------------------------------------------------------------------------


def _table_scan_probe(stray_read: bool) -> EntryProbe:
    """A training-scan body with a rank loop scatter-writing a table.

    ``stray_read=True`` adds the PR 4/5 bug shape: the rank loop *reads*
    the table it is about to scatter-write (``old = values[...]``-style),
    forcing a pre-write copy of the whole table per trip.
    """
    S, E, D = 2, 8, 16

    def body(carry, x):
        table, acc = carry

        def rank_body(r, tab_acc):
            tab, a = tab_acc
            val = jnp.full((S, D), 1.0, dtype=jnp.float32) * x
            if stray_read:
                a = a + tab[:, 0, 0].sum()  # pre-write read of the target
            else:
                a = a + val[0, 0]
            tab = tab.at[:, r % E].set(val)
            return tab, a

        table, acc = jax.lax.fori_loop(0, 3, rank_body, (table, acc))
        return (table, acc), acc

    init = (
        jnp.zeros((S, E, D), jnp.float32),
        jnp.zeros((), jnp.float32),
    )
    jaxpr = jax.make_jaxpr(
        lambda c, xs: jax.lax.scan(body, c, xs)
    )(init, jnp.arange(4, dtype=jnp.float32))
    return EntryProbe(name="synthetic_table_scan", description="", jaxpr=jaxpr)


class TestTL002CarryCopy:
    def test_write_only_rank_loop_is_clean(self):
        assert lint_rules.check_carry_copy(_table_scan_probe(False)) == []

    def test_stray_read_fires(self):
        findings = lint_rules.check_carry_copy(_table_scan_probe(True))
        assert codes(findings) == ["TL002"]
        assert "read inside its loop" in findings[0].message

    def test_production_grid_cache_read_is_detected(self):
        """Positive control on real code: the grid cache's by-design table
        read (fused._apply_cache_events) is exactly what the rule sees —
        this is the finding tracelint.toml baselines."""
        entry = lint_entries.ENTRIES["fused_logreg_grid"]()
        findings = lint_rules.check_carry_copy(entry)
        assert codes(findings) == ["TL002"]

    def test_production_write_only_caches_are_clean(self):
        """The §6 slot-universe and tiled caches keep the wmap/values0
        write-only discipline — the idiom PR 4/5 bisected into existence."""
        for name in ("fused_logreg_lb", "fused_logreg_tiled"):
            entry = lint_entries.ENTRIES[name]()
            assert lint_rules.check_carry_copy(entry) == [], name


# ---------------------------------------------------------------------------
# TL003 — pad-variant-reduce
# ---------------------------------------------------------------------------


def _unmasked_logreg_probe() -> EntryProbe:
    """The logreg sub_blocks kernel with the width mask deleted."""
    prob = lint_entries._probe_logreg()
    Xj = jnp.asarray(prob.X)
    yj = jnp.asarray(prob.y)
    n = prob.num_samples
    pad_w = 16

    def sub_blocks_unmasked(Vb, starts, widths):
        idx = jnp.clip(
            starts[:, None] - 1 + jnp.arange(pad_w)[None, :], 0, n - 1
        )
        xg = Xj[idx]
        yg = yj[idx]  # mask `* (arange < widths)` removed
        z = yg * jnp.sum(xg * Vb[:, None, :], axis=2)
        s = jax.nn.sigmoid(-z)
        return -jnp.sum(xg * (yg * s)[:, :, None], axis=1) / n

    jaxpr = jax.make_jaxpr(sub_blocks_unmasked)(
        jnp.zeros((3, prob.dim), jnp.float32),
        jnp.asarray([1, 17, 33], jnp.int32),
        jnp.asarray([11, 16, 13], jnp.int32),
    )
    return EntryProbe(
        name="synthetic_unmasked_kernel",
        description="",
        jaxpr=jaxpr,
        padded_axis_sizes=(pad_w,),
    )


class TestTL003PadVariantReduce:
    def test_removing_the_width_mask_fires(self):
        findings = lint_rules.check_pad_variant_reduce(_unmasked_logreg_probe())
        assert codes(findings) == ["TL003"]
        assert "padded axis" in findings[0].message

    @pytest.mark.parametrize("name", ["kernels_logreg", "kernels_pca"])
    def test_production_kernels_carry_mask_evidence(self, name):
        entry = lint_entries.ENTRIES[name]()
        assert lint_rules.check_pad_variant_reduce(entry) == []


# ---------------------------------------------------------------------------
# TL004 — dtype-leak
# ---------------------------------------------------------------------------


def _weak_carry_probe(explicit_dtype: bool) -> EntryProbe:
    def body(c, x):
        return c * np.float32(0.99), c.sum()

    if explicit_dtype:
        c0 = jnp.full((4,), 0.5, dtype=jnp.float32)
    else:
        c0 = jnp.full((4,), 0.5)  # python-float fill: weakly typed
    jaxpr = jax.make_jaxpr(
        lambda c, xs: jax.lax.scan(body, c, xs)
    )(c0, jnp.arange(3, dtype=jnp.float32))
    return EntryProbe(name="synthetic_weak_carry", description="", jaxpr=jaxpr)


class TestTL004DtypeLeak:
    def test_weak_float_carry_fires(self):
        findings = lint_rules.check_dtype_leak(_weak_carry_probe(False))
        assert codes(findings) == ["TL004"]
        assert "weakly typed" in findings[0].message

    def test_explicit_dtype_is_clean(self):
        assert lint_rules.check_dtype_leak(_weak_carry_probe(True)) == []

    def test_kernel_output_dtype_contract_fires_on_promotion(self):
        """A float64 cast leaking out of a kernel declared float32."""
        prob = lint_entries._probe_logreg()
        kernels = prob.fused_kernels()
        from jax.experimental import enable_x64

        with enable_x64():
            jaxpr = jax.make_jaxpr(
                lambda Vb, st, wd: kernels.sub_blocks(Vb, st, wd, 16).astype(
                    jnp.float64
                )
            )(
                jnp.zeros((3, prob.dim), jnp.float32),
                jnp.asarray([1, 17, 33], jnp.int64),
                jnp.asarray([11, 16, 13], jnp.int64),
            )
        probe = EntryProbe(
            name="synthetic_promoted_kernel",
            description="",
            jaxpr=jaxpr,
            declared_output_dtypes=(np.dtype(kernels.value_dtype),),
        )
        findings = lint_rules.check_dtype_leak(probe)
        assert codes(findings) == ["TL004"]
        assert "value_dtype" in findings[0].message

    def test_fused_entries_have_strong_carries(self):
        """The PR 6 fix: lat/h_min/next_lb are filled with explicit
        dtypes, so the LB scan carries no weak types."""
        entry = lint_entries.ENTRIES["fused_logreg_lb"]()
        assert lint_rules.check_dtype_leak(entry) == []


# ---------------------------------------------------------------------------
# TL005 — cond-capture
# ---------------------------------------------------------------------------


def _cond_probe(in_rank_loop: bool) -> EntryProbe:
    big = jnp.zeros((64, 64), jnp.float32)  # 16 KiB: at the rule threshold

    def rank_cond(r, a):
        return jax.lax.cond(r > 0, lambda: a + big[0, 0], lambda: a - big[0, 0])

    def body(c, x):
        if in_rank_loop:
            c = jax.lax.fori_loop(0, 3, rank_cond, c)
        else:
            c = rank_cond(1, c)  # body-level cond: legitimate
        return c, c

    jaxpr = jax.make_jaxpr(
        lambda c, xs: jax.lax.scan(body, c, xs)
    )(jnp.float32(0.0), jnp.arange(4, dtype=jnp.float32))
    return EntryProbe(
        name="synthetic_cond",
        description="",
        jaxpr=jaxpr,
        cond_depth_threshold=1,  # the training scan itself, as in fused
    )


class TestTL005CondCapture:
    def test_cond_in_rank_loop_capturing_table_fires(self):
        findings = lint_rules.check_cond_capture(_cond_probe(True))
        assert codes(findings) == ["TL005"]
        assert "captures" in findings[0].message

    def test_body_level_cond_is_exempt(self):
        assert lint_rules.check_cond_capture(_cond_probe(False)) == []

    def test_production_rank_loops_have_no_conds(self):
        for name in ("fused_logreg_lb", "fused_logreg_tiled", "lb_update"):
            entry = lint_entries.ENTRIES[name]()
            assert lint_rules.check_cond_capture(entry) == [], name


# ---------------------------------------------------------------------------
# churn — the elastic-fleet scan body idioms, one mutation per rule
# ---------------------------------------------------------------------------


def _churn_latency_chain(times, sd_rows, unit, cost, factor, start, comm):
    """The churn slowdown path: per-start row lookup feeding the §3 product."""
    row = jnp.searchsorted(times, start, side="right")
    comp = fused.guarded_comp_latency(unit, cost, sd_rows[row], factor)
    from repro.cluster.simulator import task_finish_time

    return task_finish_time(start, comp, comm)


def _churn_latency_probe() -> EntryProbe:
    from jax.experimental import enable_x64

    with enable_x64():
        batches = []
        for seed in (0, 1, 2, 3):
            rng = np.random.default_rng(seed)
            times = jnp.asarray(np.sort(rng.uniform(0.1, 3.0, 2)), jnp.float64)
            sd_rows = jnp.asarray(rng.uniform(1.0, 1.5, (3, 64)), jnp.float64)
            rest = tuple(
                jnp.asarray(rng.uniform(0.1, 3.0, size=64), dtype=jnp.float64)
                for _ in range(5)
            )
            batches.append((times, sd_rows) + rest)
    return EntryProbe(
        name="synthetic_churn_latency",
        description="",
        latency_probe=(_churn_latency_chain, batches),
    )


def _churn_clear_probe(values_in_fori_carry: bool) -> EntryProbe:
    """The death-clear loop shape: per-entry subtraction from running sums.

    The production idiom (``fused._clear_dead_dense``) keeps the values
    table OUT of the fori carry — the loop reads it from the enclosing
    scan carry at loop-invariant positions, so in-place aliasing of the
    scatter-written tables survives.  ``values_in_fori_carry=True`` is
    the mutation: threading the table through the clear loop's carry
    (written by the zero-out scatter AND read by the subtraction) forces
    a pre-write copy of the whole table per trip.
    """
    S, E, D = 2, 8, 16

    def body(carry, x):
        values, sums = carry

        if values_in_fori_carry:

            def clear_body(e, val_su):
                vals, su = val_su
                su = su - vals[:, e % E]
                vals = vals.at[:, e % E].set(jnp.zeros((S, D), jnp.float32))
                return vals, su

            values, sums = jax.lax.fori_loop(0, 3, clear_body, (values, sums))
        else:

            def clear_body(e, su):
                return su - values[:, e % E]

            sums = jax.lax.fori_loop(0, 3, clear_body, sums)
            values = values.at[:, 0].set(jnp.zeros((S, D), jnp.float32) + x)
        return (values, sums), sums[0, 0]

    init = (
        jnp.zeros((S, E, D), jnp.float32),
        jnp.zeros((S, D), jnp.float32),
    )
    jaxpr = jax.make_jaxpr(
        lambda c, xs: jax.lax.scan(body, c, xs)
    )(init, jnp.arange(4, dtype=jnp.float32))
    return EntryProbe(name="synthetic_churn_clear", description="", jaxpr=jaxpr)


def _churn_tau_probe(masked: bool) -> EntryProbe:
    """The liveness-masked w-th order statistic over a padded worker axis.

    ``masked=False`` drops the ``alive & (iota < width)`` select before
    the reduction — dead/pad workers' finish times silently enter tau.
    """
    pad_n = 16

    def tau(finish, width):
        if masked:
            lane = jnp.arange(pad_n)[None, :]
            finish = jnp.where(lane < width[:, None], finish, jnp.inf)
        return jnp.min(finish, axis=1)

    jaxpr = jax.make_jaxpr(tau)(
        jnp.zeros((3, pad_n), jnp.float32),
        jnp.asarray([4, 6, 5], jnp.int32),
    )
    return EntryProbe(
        name="synthetic_churn_tau",
        description="",
        jaxpr=jaxpr,
        padded_axis_sizes=(pad_n,),
    )


def _churn_boundary_probe(explicit_dtype: bool) -> EntryProbe:
    """The reactive-LB carry: ``lb_since`` starts at the -inf boundary.

    A python-float fill leaves the carry weakly typed — the first
    ``where(changed, boundary, lb_since)`` against it could re-promote.
    """
    S = 2

    def body(c, x):
        row, since = c
        return (row + 1, jnp.maximum(since, x)), since.sum()

    if explicit_dtype:
        since0 = jnp.full((S,), -jnp.inf, dtype=jnp.float32)
    else:
        since0 = jnp.full((S,), -np.inf)
    init = (jnp.zeros((S,), jnp.int32), since0)
    jaxpr = jax.make_jaxpr(
        lambda c, xs: jax.lax.scan(body, c, xs)
    )(init, jnp.arange(3, dtype=jnp.float32))
    return EntryProbe(
        name="synthetic_churn_boundary", description="", jaxpr=jaxpr
    )


def _churn_cond_clear_probe(branchless: bool) -> EntryProbe:
    """Per-entry clear decisions must be branchless masked arithmetic.

    A ``lax.cond`` on ``clear[e]`` inside the clear loop captures the
    values table in both branches — TL005's copy-amplification shape.
    """
    values = jnp.zeros((64, 64), jnp.float32)  # 16 KiB: at the threshold
    clear = jnp.asarray([True, False, True], bool)

    def clear_body(e, su):
        if branchless:
            return su + jnp.where(clear[e % 3], values[0, 0], 0.0)
        return jax.lax.cond(
            clear[e % 3],
            lambda: su + values[0, 0],
            lambda: su - values[0, 0],
        )

    def body(c, x):
        c = jax.lax.fori_loop(0, 3, clear_body, c)
        return c, c

    jaxpr = jax.make_jaxpr(
        lambda c, xs: jax.lax.scan(body, c, xs)
    )(jnp.float32(0.0), jnp.arange(4, dtype=jnp.float32))
    return EntryProbe(
        name="synthetic_churn_cond",
        description="",
        jaxpr=jaxpr,
        cond_depth_threshold=1,
    )


class TestChurnScanIdioms:
    def test_production_churn_entry_is_clean_under_every_rule(self):
        entry = lint_entries.ENTRIES["fused_logreg_churn"]()
        assert lint_rules.check_carry_copy(entry) == []
        assert lint_rules.check_dtype_leak(entry) == []
        assert lint_rules.check_cond_capture(entry) == []
        assert lint_rules.check_pad_variant_reduce(entry) == []

    def test_tl001_churn_row_lookup_keeps_the_seam(self, monkeypatch):
        assert lint_rules.check_fma_seam(_churn_latency_probe()) == []
        monkeypatch.setattr(
            fused,
            "guarded_comp_latency",
            lambda unit, cost, slowdown, factor: comp_latency_expr(
                unit, cost, slowdown, factor
            ),
        )
        findings = lint_rules.check_fma_seam(_churn_latency_probe())
        assert codes(findings) == ["TL001"]

    def test_tl002_values_threaded_through_the_clear_loop_fires(self):
        assert lint_rules.check_carry_copy(_churn_clear_probe(False)) == []
        findings = lint_rules.check_carry_copy(_churn_clear_probe(True))
        assert codes(findings) == ["TL002"]
        assert "read inside its loop" in findings[0].message

    def test_tl003_unmasked_tau_over_padded_workers_fires(self):
        assert lint_rules.check_pad_variant_reduce(_churn_tau_probe(True)) == []
        findings = lint_rules.check_pad_variant_reduce(_churn_tau_probe(False))
        assert codes(findings) == ["TL003"]

    def test_tl004_weak_lb_since_carry_fires(self):
        assert lint_rules.check_dtype_leak(_churn_boundary_probe(True)) == []
        findings = lint_rules.check_dtype_leak(_churn_boundary_probe(False))
        assert codes(findings) == ["TL004"]
        assert "weakly typed" in findings[0].message

    def test_tl005_cond_on_clear_mask_fires(self):
        assert lint_rules.check_cond_capture(_churn_cond_clear_probe(True)) == []
        findings = lint_rules.check_cond_capture(_churn_cond_clear_probe(False))
        assert codes(findings) == ["TL005"]


# ---------------------------------------------------------------------------
# baseline layer
# ---------------------------------------------------------------------------


class TestBaseline:
    def test_parse_and_match(self):
        supps = lint_baseline.parse_baseline(
            '[tracelint]\nversion = 1\n\n'
            '[[suppress]]\ncode = "TL002"\nentry = "fused_logreg_grid"\n'
            'contains = "gather"\nreason = "accepted"\n'
        )
        assert len(supps) == 1
        from repro.analysis.lint.findings import Finding

        hit = Finding("TL002", "fused_logreg_grid", "x:gather", "msg")
        miss_entry = Finding("TL002", "fused_logreg_lb", "x:gather", "msg")
        miss_code = Finding("TL004", "fused_logreg_grid", "x:gather", "msg")
        assert supps[0].matches(hit)
        assert not supps[0].matches(miss_entry)
        assert not supps[0].matches(miss_code)

    def test_reason_is_mandatory(self):
        with pytest.raises(ValueError, match="reason"):
            lint_baseline.parse_baseline('[[suppress]]\ncode = "TL001"\n')

    def test_committed_baseline_parses(self):
        supps = lint_baseline.load_baseline(REPO_ROOT / "tracelint.toml")
        assert all(s.reason for s in supps)
        assert {s.code for s in supps} == {"TL002"}


# ---------------------------------------------------------------------------
# HEAD state + the CI gate
# ---------------------------------------------------------------------------


class TestHeadAndGate:
    def test_head_is_clean_under_committed_baseline(self):
        """The acceptance pin: every entry, zero active findings, and the
        suppressed set is exactly the two known grid-cache reads."""
        report = run_lint("all", baseline_path=REPO_ROOT / "tracelint.toml")
        assert report.findings == []
        assert report.exit_code == 0
        suppressed = sorted((f.code, f.entry) for f, _ in report.suppressed)
        assert suppressed == [
            ("TL002", "fused_logreg_grid"),
            ("TL002", "fused_pca_grid"),
        ]

    def _run_cli(self, *args):
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = str(REPO_ROOT / "src")
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis.lint", *args],
            capture_output=True,
            text=True,
            env=env,
            cwd=REPO_ROOT,
            timeout=300,
        )

    def test_cli_gate_fails_on_non_baselined_finding(self):
        """The CI gate demonstration: without the baseline, the grid-cache
        TL002 finding turns the build red (exit 1) and is reported in the
        JSON artifact."""
        proc = self._run_cli(
            "--entry", "fused_logreg_grid", "--no-baseline", "--format", "json"
        )
        assert proc.returncode == 1, proc.stderr
        payload = json.loads(proc.stdout)
        assert [f["code"] for f in payload["findings"]] == ["TL002"]
        assert payload["suppressed"] == []

    def test_cli_green_with_committed_baseline(self):
        proc = self._run_cli(
            "--entry", "fused_logreg_grid", "--format", "json"
        )
        assert proc.returncode == 0, proc.stderr
        payload = json.loads(proc.stdout)
        assert payload["findings"] == []
        assert [f["code"] for f in payload["suppressed"]] == ["TL002"]
