"""Tests for §6 load balancing: partition arithmetic, Algorithm 2, Algorithm 1."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.lb.partitioner import (
    Subpartitioner,
    _align,
    align_partitions,
    build_p_ladder,
    cyclic_increment,
    ladder_intervals,
    p_start,
    p_stop,
    p_trans,
)
from repro.lb.optimizer import LoadBalanceOptimizer, OptimizerInputs


class TestPartitionArithmetic:
    def test_partitions_tile_the_range(self):
        for n in (10, 17, 100):
            for p in (1, 2, 3, 7, n):
                covered = []
                for i in range(1, p + 1):
                    covered.extend(range(p_start(n, p, i), p_stop(n, p, i) + 1))
                assert covered == list(range(1, n + 1))

    def test_paper_example3_values(self):
        # n=10, p=2: [1..5],[6..10]; p'=3: [1..3],[4..6],[7..10]
        assert p_start(10, 2, 1) == 1 and p_stop(10, 2, 1) == 5
        assert p_start(10, 3, 2) == 4 and p_stop(10, 3, 2) == 6
        assert p_trans(10, 2, 3, 2) == 2  # partition containing sample 6 -> ceil(6*3/10)=2
        # Algorithm 2 walk from the paper: k1=1 -> increment -> k=2, ends k=k'=1
        k, k_new = align_partitions(10, 2, 3, 1)
        assert (k, k_new) == (1, 1)
        assert p_start(10, 2, k) == p_start(10, 3, k_new)

    def test_alignment_nontrivial_solution(self):
        # paper: n=10, p=2, p'=4 has solution k=2, k'=3 (both start at sample 6)
        k, k_new = _align(10, 2, 4, 2)
        assert (k, k_new) == (2, 3)
        assert p_start(10, 2, 2) == p_start(10, 4, 3) == 6

    def test_cyclic_increment(self):
        assert cyclic_increment(1, 3) == 2
        assert cyclic_increment(3, 3) == 1


@settings(max_examples=300, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=10_000),
    p=st.integers(min_value=1, max_value=64),
    p_new=st.integers(min_value=1, max_value=64),
    data=st.data(),
)
def test_algorithm2_terminates_and_aligns(n, p, p_new, data):
    p = min(p, n)
    p_new = min(p_new, n)
    k = data.draw(st.integers(min_value=1, max_value=p))
    k_out, k_new = align_partitions(n, p, p_new, k)
    assert 1 <= k_out <= p and 1 <= k_new <= p_new
    assert p_start(n, p, k_out) == p_start(n, p_new, k_new)


@settings(max_examples=200, deadline=None)
@given(
    base=st.integers(min_value=1, max_value=1000),
    width=st.integers(min_value=1, max_value=500),
    p=st.integers(min_value=1, max_value=32),
    steps=st.lists(st.integers(min_value=1, max_value=32), max_size=8),
)
def test_subpartitioner_intervals_stay_in_range_across_repartitions(
    base, width, p, steps
):
    sub = Subpartitioner(base_start=base, base_stop=base + width - 1, p=p)
    seen = set()
    for p_new in steps + [sub.p]:
        for _ in range(3):
            lo, hi = sub.next_interval_and_advance()
            assert base <= lo <= hi <= base + width - 1
            seen.add((lo, hi))
        sub.repartition(p_new)
    # after repartition, the next interval must start at an old boundary
    lo, _ = sub.current_interval()


def test_subpartitioner_cycles_cover_local_range():
    sub = Subpartitioner(base_start=11, base_stop=30, p=4)
    covered = set()
    for _ in range(4):
        lo, hi = sub.next_interval_and_advance()
        covered.update(range(lo, hi + 1))
    assert covered == set(range(11, 31))


def test_repartition_alignment_minimizes_evictions():
    """After p: 2 -> 3 on a 10-sample worker, the first interval processed
    must start at an existing boundary (paper Example 2/3)."""
    sub = Subpartitioner(base_start=1, base_stop=10, p=2)
    sub.next_interval_and_advance()  # processed [1..5], k now 2
    sub.repartition(3)
    lo, hi = sub.current_interval()
    # old boundaries start at {1, 6}; new partition starts at an old boundary
    assert lo in (1, 6)


# ---------------------------------------------------------------------------
# p-ladder property tests (full-coverage / no-overlap / index-monotonicity
# across arbitrary p -> p' repartition chains on the ladder)
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(n=st.integers(min_value=1, max_value=5000), p0=st.integers(min_value=1, max_value=64))
def test_ladder_is_sorted_valid_and_contains_p0(n, p0):
    ladder = build_p_ladder(p0, n)
    assert list(ladder) == sorted(set(ladder))
    assert all(1 <= v <= n for v in ladder)
    # the (clipped) initial subpartition count is always a rung
    assert min(max(p0, ladder[0]), ladder[-1]) in ladder


@settings(max_examples=200, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    p0=st.integers(min_value=1, max_value=64),
    data=st.data(),
)
def test_ladder_partitions_tile_without_overlap(n, p0, data):
    """Every ladder rung's partition grid covers [1, n] exactly once, with
    monotone boundaries — the §6.3 arithmetic the slot universe is built on."""
    ladder = build_p_ladder(p0, n)
    p = data.draw(st.sampled_from(ladder))
    starts = [p_start(n, p, k) for k in range(1, p + 1)]
    stops = [p_stop(n, p, k) for k in range(1, p + 1)]
    assert starts[0] == 1 and stops[-1] == n  # full coverage
    for k in range(p - 1):
        assert stops[k] + 1 == starts[k + 1]  # no overlap, no gap
        assert starts[k] < starts[k + 1]  # index-monotone boundaries
    assert all(a <= b for a, b in zip(starts, stops))


@settings(max_examples=200, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    p=st.integers(min_value=1, max_value=64),
    p_new=st.integers(min_value=1, max_value=64),
)
def test_p_trans_is_monotone_and_identity(n, p, p_new):
    p, p_new = min(p, n), min(p_new, n)
    trans = [p_trans(n, p, p_new, k) for k in range(1, p + 1)]
    assert all(a <= b for a, b in zip(trans, trans[1:]))  # index-monotone
    assert all(1 <= t <= p_new for t in trans)
    # p' = p maps every index to itself
    assert [p_trans(n, p, p, k) for k in range(1, p + 1)] == list(range(1, p + 1))


@settings(max_examples=150, deadline=None)
@given(
    base=st.integers(min_value=1, max_value=1000),
    width=st.integers(min_value=1, max_value=200),
    p0=st.integers(min_value=1, max_value=32),
    data=st.data(),
)
def test_repartition_chains_on_the_ladder_preserve_invariants(
    base, width, p0, data
):
    """Arbitrary p -> p' chains restricted to the ladder: every repartition
    aligns the next subpartition to an *old* boundary (Algorithm 2), and a
    full cycle after any repartition still covers the worker's local range
    exactly once."""
    ladder = build_p_ladder(p0, width)
    sub = Subpartitioner(base_start=base, base_stop=base + width - 1, p=min(p0, width))
    chain = data.draw(st.lists(st.sampled_from(ladder), min_size=1, max_size=5))
    for p_new in chain:
        old_p = sub.p
        old_boundaries = {p_start(sub.n_local, old_p, k) for k in range(1, old_p + 1)}
        sub.advance()  # mid-cycle, like a worker between tasks
        sub.repartition(p_new)
        lo, hi = sub.current_interval()
        assert base <= lo <= hi <= base + width - 1
        # Algorithm-2 alignment: the next interval starts at an old boundary
        assert (lo - base + 1) in old_boundaries
        # one full cycle covers the local range exactly once (no overlap)
        seen = []
        for _ in range(sub.p):
            a, b = sub.next_interval_and_advance()
            seen.extend(range(a, b + 1))
        assert sorted(seen) == list(range(base, base + width))
        assert len(seen) == width


@settings(max_examples=100, deadline=None)
@given(
    base=st.integers(min_value=1, max_value=500),
    width=st.integers(min_value=1, max_value=120),
    p0=st.integers(min_value=1, max_value=32),
)
def test_ladder_intervals_enumerate_every_reachable_interval(base, width, p0):
    """The slot universe really is a superset of anything a ladder chain can
    produce: every (rung, cyclic index) interval appears exactly once, in
    sorted order, inside the worker's range."""
    ladder = build_p_ladder(p0, width)
    ivs = ladder_intervals(base, base + width - 1, ladder)
    assert ivs == sorted(set(ivs))
    assert all(base <= a <= b <= base + width - 1 for a, b in ivs)
    universe = set(ivs)
    for raw in ladder:
        p = min(raw, width)
        for k in range(1, p + 1):
            lo = base + p_start(width, p, k) - 1
            hi = base + p_stop(width, p, k) - 1
            assert (lo, hi) in universe


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------


def _inputs(e_comp, w=4):
    n = len(e_comp)
    e_comp = np.asarray(e_comp, dtype=np.float64)
    return OptimizerInputs(
        e_comm=np.full(n, 1e-4),
        v_comm=np.full(n, 1e-10),
        e_comp=e_comp,
        v_comp=(0.1 * e_comp) ** 2,
        samples_per_worker=np.full(n, 1000.0),
        w=w,
    )


def test_optimizer_gives_slow_workers_less_work():
    opt = LoadBalanceOptimizer(seed=0, sim_iterations=60)
    p0 = np.full(8, 10, dtype=np.int64)
    e_comp = np.linspace(1e-3, 2e-3, 8)  # worker 7 is 2x slower
    p_new = opt.optimize(p0, _inputs(e_comp))
    # slower workers should end up with (weakly) more subpartitions = less work
    assert p_new[-1] >= p_new[0]
    # and the latency spread should narrow
    e0 = 1e-4 + e_comp
    e1 = 1e-4 + e_comp * p0 / p_new
    assert e1.max() / e1.min() <= e0.max() / e0.min() + 1e-9


def test_optimizer_respects_bounds():
    opt = LoadBalanceOptimizer(seed=0, sim_iterations=40)
    p0 = np.full(4, 5, dtype=np.int64)
    p_new = opt.optimize(p0, _inputs([1e-3, 1e-3, 1e-3, 5e-3], w=2))
    assert (p_new >= 1).all()


def test_should_publish_requires_improvement():
    opt = LoadBalanceOptimizer(seed=0, improvement_threshold=0.10)
    inputs = _inputs([1e-3] * 4)
    p = np.full(4, 10, dtype=np.int64)
    # identical p -> no improvement -> do not publish
    assert not opt.should_publish(p, p, inputs)


def test_equalization_caps_subpartitions_at_sample_count():
    """Regression: a worker whose comm latency sits just below the slowest
    worker's total gets a near-zero equalization denominator, and the old
    equalize phase emitted p'_j > n_j — more subpartitions than the worker
    has samples.  p' must stay within [1, n_j] for every worker."""
    n = 6
    e_comp = np.full(n, 1e-4)
    e_comm = np.full(n, 1e-4)
    # worker 0: very slow compute -> the equalization target
    e_comp[0] = 10e-3
    # worker 1: comm-heavy, total just below worker 0's -> tiny denominator
    e_comm[1] = 9.9e-3
    e_comp[1] = 1e-4
    inputs = OptimizerInputs(
        e_comm=e_comm,
        v_comm=(0.1 * e_comm) ** 2,
        e_comp=e_comp,
        v_comp=(0.1 * e_comp) ** 2,
        samples_per_worker=np.full(n, 4.0),  # tiny local datasets
        w=3,
    )
    opt = LoadBalanceOptimizer(seed=0, sim_iterations=40)
    p_new = opt.optimize(np.full(n, 10, dtype=np.int64), inputs)
    assert (p_new >= 1).all()
    assert (p_new <= inputs.samples_per_worker).all(), p_new


def test_slack_phase_reports_h_of_the_returned_vector():
    """Regression: when the slack phase backs out a violating step it must
    also restore the pre-step h, so the h it reports corresponds to the p'
    it returns.  The estimator is deterministic given (inputs, p, p'), so
    re-estimating at the returned vector must reproduce last_h exactly."""
    opt = LoadBalanceOptimizer(seed=0, sim_iterations=40)
    p0 = np.full(8, 10, dtype=np.int64)
    inputs = _inputs(np.linspace(1e-3, 3e-3, 8))
    p_new = opt.optimize(p0, inputs)
    assert opt.h_min is not None and opt.last_h is not None
    h_at_returned = opt.estimate_h(inputs, p0, p_new)
    assert opt.last_h == h_at_returned


def test_batched_optimize_matches_scalar_per_scenario():
    """optimize_batch must reproduce per-scenario scalar optimize calls —
    the convergence engine's LB equivalence rests on it."""
    rng = np.random.default_rng(1)
    S, N = 3, 6
    e_comp = rng.uniform(1e-3, 3e-3, size=(S, N))
    e_comm = rng.uniform(1e-4, 3e-4, size=(S, N))
    inputs2d = OptimizerInputs(
        e_comm=e_comm,
        v_comm=(0.1 * e_comm) ** 2,
        e_comp=e_comp,
        v_comp=(0.1 * e_comp) ** 2,
        samples_per_worker=np.full((S, N), 1000.0),
        w=4,
    )
    p0 = np.full((S, N), 10, dtype=np.int64)
    batch_opt = LoadBalanceOptimizer(seed=0, sim_iterations=40)
    p_batch, h_min_batch, last_h_batch = batch_opt.optimize_batch(p0, inputs2d)
    for s in range(S):
        scal = LoadBalanceOptimizer(seed=0, sim_iterations=40)
        inputs1d = OptimizerInputs(
            e_comm=e_comm[s],
            v_comm=(0.1 * e_comm[s]) ** 2,
            e_comp=e_comp[s],
            v_comp=(0.1 * e_comp[s]) ** 2,
            samples_per_worker=np.full(N, 1000.0),
            w=4,
        )
        p_scalar = scal.optimize(p0[s], inputs1d)
        np.testing.assert_array_equal(p_scalar, p_batch[s])
        assert scal.h_min == h_min_batch[s]
        assert scal.last_h == last_h_batch[s]
