"""Semantics tests for the Tier-1 compiled DSAG step."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import TrainConfig
from repro.core.dsag_pjit import (
    GroupSpec,
    dsag_update,
    init_dsag_state,
    init_train_state,
    make_train_step,
)


def tc(**kw):
    base = dict(optimizer="sgd", learning_rate=0.1, grad_clip=0.0, weight_decay=0.0)
    base.update(kw)
    return TrainConfig(**base)


def quad_loss(params, batch):
    """Mean-squared loss of a linear model — analytic gradients available."""
    x, y = batch["x"], batch["y"]
    pred = x @ params["w"]
    return jnp.mean((pred - y) ** 2)


def make_problem(p=4, bsz=8, dim=6, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(dim, 1)).astype(np.float32)
    x = rng.normal(size=(p, bsz, dim)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.normal(size=(p, bsz, 1)).astype(np.float32)
    params = {"w": jnp.zeros((dim, 1), jnp.float32)}
    return params, {"x": jnp.asarray(x), "y": jnp.asarray(y)}


class TestDsagUpdateRule:
    def test_full_mask_equals_mean_gradient_path(self):
        """mask=1 everywhere: Ĥ == mean of per-group grads (SAG == sync DP)."""
        params, batch = make_problem()
        gs = GroupSpec(4, ())
        cfg = tc(dsag=True)
        step = make_train_step(quad_loss, cfg, gs)
        state = init_train_state(params, cfg, gs)
        ones = jnp.ones(4, bool)
        zeros = jnp.zeros(4, bool)
        new_state, m1 = jax.jit(step)(state, batch, ones, zeros)

        cfg2 = tc(dsag=False)
        step2 = make_train_step(quad_loss, cfg2, gs)
        state2 = init_train_state(params, cfg2, gs)
        new_state2, m2 = jax.jit(step2)(state2, batch, ones, zeros)
        # bf16 cache storage rounds Ĥ (the price of the exact H == Σ cache
        # invariant); agreement is to bf16 precision, not fp32
        np.testing.assert_allclose(
            np.asarray(new_state["params"]["w"]),
            np.asarray(new_state2["params"]["w"]),
            atol=2e-3,
        )

    def test_masked_group_keeps_stale_cache(self):
        params, batch = make_problem()
        gs = GroupSpec(4, ())
        cfg = tc()
        step = jax.jit(make_train_step(quad_loss, cfg, gs))
        state = init_train_state(params, cfg, gs)
        ones = jnp.ones(4, bool)
        zeros = jnp.zeros(4, bool)
        state, _ = step(state, batch, ones, zeros)
        cache_before = np.asarray(state["dsag"]["cache"]["w"])
        # group 2 masked out: its slot must be byte-identical afterwards
        mask = jnp.array([True, True, False, True])
        state, metrics = step(state, batch, mask, zeros)
        cache_after = np.asarray(state["dsag"]["cache"]["w"])
        np.testing.assert_array_equal(cache_before[2], cache_after[2])
        assert float(metrics["xi"]) == 1.0  # filled earlier, coverage holds

    def test_flush_integrates_stale_gradient(self):
        """A straggler's pending gradient enters H on the flush step — and H
        equals the sum of cache slots throughout (the paper's invariant)."""
        params, batch = make_problem()
        gs = GroupSpec(4, ())
        cfg = tc()
        step = jax.jit(make_train_step(quad_loss, cfg, gs))
        state = init_train_state(params, cfg, gs)
        ones = jnp.ones(4, bool)
        zeros = jnp.zeros(4, bool)
        mask_no2 = jnp.array([True, True, False, True])
        flush_2 = jnp.array([False, False, True, False])
        state, _ = step(state, batch, ones, zeros)
        state, _ = step(state, batch, mask_no2, zeros)  # group 2 goes dark
        assert bool(state["dsag"]["pending_valid"][2])
        state, _ = step(state, batch, mask_no2, flush_2)  # stale result lands
        h = np.asarray(state["dsag"]["h"]["w"])
        cache_sum = np.asarray(state["dsag"]["cache"]["w"]).astype(np.float64).sum(0)
        np.testing.assert_allclose(h[:, 0], cache_sum[:, 0], atol=1e-4)

    def test_xi_scales_partial_coverage(self):
        params, batch = make_problem()
        gs = GroupSpec(4, ())
        cfg = tc()
        step = jax.jit(make_train_step(quad_loss, cfg, gs))
        state = init_train_state(params, cfg, gs)
        mask = jnp.array([True, True, False, False])
        state, metrics = step(state, batch, mask, jnp.zeros(4, bool))
        assert float(metrics["xi"]) == pytest.approx(0.5)

    def test_training_converges_under_straggling(self):
        """Random 1-of-4 dropout per step with flushes: loss must still fall
        to near-zero (the paper's central convergence claim, compiled form)."""
        params, batch_proto = make_problem(seed=3)
        gs = GroupSpec(4, ())
        cfg = tc(learning_rate=0.05)
        step = jax.jit(make_train_step(quad_loss, cfg, gs))
        state = init_train_state(params, cfg, gs)
        rng = np.random.default_rng(0)
        dark = -1
        losses = []
        for _it in range(300):
            mask = np.ones(4, bool)
            flush = np.zeros(4, bool)
            if dark >= 0:
                flush[dark] = True
                dark = -1
            else:
                dark = int(rng.integers(0, 4))
                mask[dark] = False
            state, metrics = step(
                state, batch_proto, jnp.asarray(mask), jnp.asarray(flush)
            )
            losses.append(float(metrics["loss"]))
        assert losses[-1] < 1e-2, losses[-5:]

    def test_int8_cache_roundtrip_close(self):
        params, batch = make_problem()
        gs = GroupSpec(4, ())
        cfg_bf = tc()
        cfg_i8 = tc(dsag_cache_dtype="int8")
        s_bf = init_train_state(params, cfg_bf, gs)
        s_i8 = init_train_state(params, cfg_i8, gs)
        step_bf = jax.jit(make_train_step(quad_loss, cfg_bf, gs))
        step_i8 = jax.jit(make_train_step(quad_loss, cfg_i8, gs))
        ones = jnp.ones(4, bool)
        zeros = jnp.zeros(4, bool)
        for _ in range(3):
            s_bf, m_bf = step_bf(s_bf, batch, ones, zeros)
            s_i8, m_i8 = step_i8(s_i8, batch, ones, zeros)
        np.testing.assert_allclose(
            np.asarray(s_bf["params"]["w"]), np.asarray(s_i8["params"]["w"]), atol=2e-2
        )


@settings(max_examples=30, deadline=None)
@given(
    masks=st.lists(
        st.lists(st.booleans(), min_size=4, max_size=4), min_size=2, max_size=8
    )
)
def test_h_always_equals_sum_of_cache(masks):
    """Property: H ≡ Σ_i cache_i after any mask sequence (no flushes)."""
    params, batch = make_problem(seed=7)
    gs = GroupSpec(4, ())
    cfg = tc()
    step = jax.jit(make_train_step(quad_loss, cfg, gs))
    state = init_train_state(params, cfg, gs)
    for m in masks:
        state, _ = step(state, batch, jnp.asarray(m), jnp.zeros(4, bool))
    h = np.asarray(state["dsag"]["h"]["w"], np.float64)
    cache_sum = np.asarray(state["dsag"]["cache"]["w"], np.float64).sum(0)
    np.testing.assert_allclose(h, cache_sum, atol=1e-3)
