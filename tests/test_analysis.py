"""Pins for the HLO-text cost model (analysis/hlo.py, analysis/roofline.py).

The module's whole reason to exist is that XLA-CPU's ``cost_analysis()``
counts while-loop bodies once; these tests pin the loop-aware numbers on a
committed, hand-written HLO fixture (``tests/data/scan_allreduce.hlo``: a
5-trip while whose body runs a 16x16x16 dot and a 4-way all-reduce, plus a
fusion outside the loop).  Every expected value below is derived by hand
from the fixture so a regression in parsing, trip resolution, or the
byte/FLOP accounting shows up as an exact-number diff, not drift.
"""

import math
from pathlib import Path

import pytest

from repro.analysis import hlo, roofline
from repro.configs.base import ModelConfig, ShapeConfig

FIXTURE = Path(__file__).parent / "data" / "scan_allreduce.hlo"

# hand-derived fixture constants
TRIPS = 5
DOT_FLOPS = 2 * 16 * 16 * 16  # 8192 per trip
TABLE_BYTES = 16 * 16 * 4  # 1024, one f32[16,16] buffer
# per-trip body HBM bytes: counter add (2*4) + dot operand reads (2*1024)
# + dot result (2*1024) + all-reduce result (2*1024)
BODY_BYTES = 8 + 2 * TABLE_BYTES + 2 * TABLE_BYTES + 2 * TABLE_BYTES
ENTRY_BYTES = TRIPS * BODY_BYTES + 2 * TABLE_BYTES  # + the fusion result
# 4-way ring all-reduce: 2 * (n-1)/n * payload, once per trip
WIRE_BYTES = TRIPS * 2.0 * 3 / 4 * TABLE_BYTES


@pytest.fixture(scope="module")
def text():
    return FIXTURE.read_text()


class TestParse:
    def test_computations_and_entry(self, text):
        comps, entry = hlo.parse_computations(text)
        assert entry == "main"
        assert sorted(comps) == ["add", "body", "cond", "fused", "main"]

    def test_operands_resolved(self, text):
        comps, _ = hlo.parse_computations(text)
        body = comps["body"]
        assert body.by_name["y"].op == "dot"
        assert body.by_name["y"].operands == ["x", "x"]
        assert comps["main"].by_name["w"].operands == ["init"]

    def test_parameters_have_no_operands(self, text):
        comps, _ = hlo.parse_computations(text)
        assert comps["body"].by_name["state"].operands == []


class TestLoopMultiplicities:
    def test_while_body_counts_per_trip(self, text):
        comps, entry = hlo.parse_computations(text)
        mult = hlo.loop_multiplicities(comps, entry)
        assert mult == {"main": 1.0, "fused": 1.0, "body": float(TRIPS)}

    def test_follow_calls_false_skips_fusion_bodies(self, text):
        comps, entry = hlo.parse_computations(text)
        mult = hlo.loop_multiplicities(comps, entry, follow_calls=False)
        assert mult == {"main": 1.0, "body": float(TRIPS)}


class TestAnalyzeHlo:
    def test_flops_multiply_by_trip_count(self, text):
        cost = hlo.analyze_hlo(text)
        assert cost.flops == TRIPS * DOT_FLOPS

    def test_hbm_bytes(self, text):
        cost = hlo.analyze_hlo(text)
        assert cost.bytes == ENTRY_BYTES

    def test_collective_totals(self, text):
        cost = hlo.analyze_hlo(text)
        assert cost.coll_counts == {"all-reduce": float(TRIPS)}
        assert cost.coll_result_bytes["all-reduce"] == TRIPS * TABLE_BYTES
        assert cost.total_operand_bytes == TRIPS * TABLE_BYTES
        assert cost.total_wire_bytes == WIRE_BYTES

    def test_top_costs_ranked_by_trip_weighted_bytes(self, text):
        top = hlo.top_costs(text, k=3)
        # the per-trip dot and all-reduce results dominate at 2*1024*5
        assert top["bytes"][0][0] == 2 * TABLE_BYTES * TRIPS
        assert top["bytes"][0][1] == "body"
        assert len(top["collectives"]) == 1
        wire, comp_name, op, _ = top["collectives"][0]
        assert (wire, comp_name, op) == (WIRE_BYTES, "body", "all-reduce")

    def test_sxs_buffer_bytes_trip_weighted(self, text):
        # square f32[16,16] buffers: fusion result (1x) + dot and
        # all-reduce results inside the loop (5x each)
        expect = 2 * TABLE_BYTES * (1 + 2 * TRIPS)
        assert hlo.sxs_buffer_bytes(text, min_dim=16) == expect
        assert hlo.sxs_buffer_bytes(text) == 0.0  # default 1024 floor


def _tiny_model():
    return ModelConfig(
        name="t",
        family="dense",
        num_layers=1,
        d_model=8,
        num_heads=2,
        num_kv_heads=2,
        d_ff=16,
        vocab_size=32,
    )


class TestRoofline:
    SHAPE = ShapeConfig("train_4k", 4096, 256, "train")

    def test_dominant_term_collective(self, text):
        r = roofline.derive(_tiny_model(), self.SHAPE, 1000, {}, text, 4)
        assert r.flops_per_device == TRIPS * DOT_FLOPS
        assert r.bytes_per_device == ENTRY_BYTES
        assert math.isclose(r.compute_s, TRIPS * DOT_FLOPS / roofline.PEAK_FLOPS)
        assert math.isclose(r.memory_s, ENTRY_BYTES / roofline.HBM_BW)
        assert math.isclose(r.collective_s, WIRE_BYTES / roofline.LINK_BW)
        # the fixture's wire term is the largest of the three
        assert r.dominant == "collective"
        assert r.step_time_s == r.collective_s

    def test_dominant_term_memory_without_collective(self, text):
        # same graph with the all-reduce demoted to a copy: identical HBM
        # traffic, zero wire bytes -> the memory term must win
        variant = text.replace(
            "all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add",
            "copy(%y)",
        )
        r = roofline.derive(_tiny_model(), self.SHAPE, 1000, {}, variant, 4)
        assert r.bytes_per_device == ENTRY_BYTES
        assert r.collective_s == 0.0
        assert r.dominant == "memory"
        assert r.step_time_s == r.memory_s

    def test_model_flops_and_mfu(self, text):
        r = roofline.derive(_tiny_model(), self.SHAPE, 1000, {}, text, 4)
        mf = 6.0 * 1000 * 4096 * 256 / 4  # 6ND train, per device
        assert math.isclose(r.model_flops_per_device, mf)
        assert math.isclose(
            r.useful_flops_fraction, mf / (TRIPS * DOT_FLOPS)
        )
        assert math.isclose(
            r.mfu, (mf / roofline.PEAK_FLOPS) / r.step_time_s
        )
