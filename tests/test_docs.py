"""The docs subsystem stays truthful: links resolve and examples execute.

CI runs the same checks as a dedicated job (`docs` in
``.github/workflows/ci.yml``); this tier-1 copy catches broken links and
doctest rot locally before a push.
"""

import doctest
import importlib
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

#: modules with executable docstring examples (mirrored in the CI docs job)
DOCTEST_MODULES = ["repro.core.gradient_cache", "repro.lb.partitioner"]


def test_docs_links_resolve():
    sys.path.insert(0, str(REPO_ROOT / "docs"))
    try:
        check_docs = importlib.import_module("check_docs")
    finally:
        sys.path.pop(0)
    errors = []
    files = sorted((REPO_ROOT / "docs").glob("*.md")) + [REPO_ROOT / "README.md"]
    assert len(files) >= 4  # ARCHITECTURE, BENCHMARKS, PAPER_MAP, README
    for f in files:
        errors.extend(check_docs.check_file(f, REPO_ROOT))
    assert not errors, "\n".join(errors)


def test_required_docs_exist():
    for name in ("ARCHITECTURE.md", "PAPER_MAP.md", "BENCHMARKS.md"):
        assert (REPO_ROOT / "docs" / name).is_file(), name
    readme = (REPO_ROOT / "README.md").read_text()
    for name in ("ARCHITECTURE.md", "PAPER_MAP.md", "BENCHMARKS.md"):
        assert f"docs/{name}" in readme, f"README does not link docs/{name}"


def test_doctest_modules_pass():
    for modname in DOCTEST_MODULES:
        mod = importlib.import_module(modname)
        result = doctest.testmod(mod)
        assert result.attempted > 0, f"{modname} lost its doctest examples"
        assert result.failed == 0, f"{modname}: {result.failed} doctest failures"
