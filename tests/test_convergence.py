"""Tests for the batched convergence engine (repro.experiments.convergence).

The load-bearing property mirrors PR 1's sweep guarantee, one level up the
stack: the batched engine running the *full training loop* (gradient cache,
coverage scaling, §5.1 margin, stale integration, §6 load balancing) over a
scenario batch must reproduce the scalar ``TrainingSimulator`` replaying
each scenario through ``TraceLatencySource`` — bit for bit, not just
statistically.
"""

import time

import numpy as np
import pytest

from repro.cluster.simulator import (
    LatencySource,
    MethodConfig,
    TraceLatencySource,
    TrainingSimulator,
)
from repro.core.gradient_cache import BatchedGradientCache, GradientCache
from repro.core.problems import (
    LogisticRegressionProblem,
    PCAProblem,
    make_genomics_like_matrix,
    make_higgs_like,
)
from repro.experiments.convergence import (
    default_convergence_methods,
    run_convergence_batch,
    run_convergence_sweep,
    scalar_convergence_run,
    scalar_convergence_seconds,
)
from repro.experiments.grid import HEAVY_BURSTS
from repro.experiments.results import convergence_ordering, write_bench_convergence
from repro.latency.model import (
    make_heterogeneous_cluster,
    make_paper_artificial_cluster,
    sample_fleet,
)


@pytest.fixture(scope="module")
def logreg_small():
    X, y = make_higgs_like(240, seed=0)
    return LogisticRegressionProblem(X=X, y=y)


@pytest.fixture(scope="module")
def pca_small():
    return PCAProblem(X=make_genomics_like_matrix(240, 48, seed=0), k=3)


def small_fleet(n_workers=6, n_scenarios=3, horizon=25, seed=3):
    cluster = make_heterogeneous_cluster(
        n_workers, seed=seed, burst_rate=0.0, comp_range=(1.1e-3, 2.5e-3)
    )
    traces = sample_fleet(
        cluster,
        n_scenarios,
        horizon,
        burst_rate=3.0,
        burst_factor_mean=3.0,
        burst_duration_mean=5e-3,
        seed=seed + 8,
    )
    return cluster, traces


def assert_bitexact(problem, cluster, traces, cfg, T, *, eval_every=2, seed=0):
    res = run_convergence_batch(
        problem, traces, cfg, T, eval_every=eval_every, seed=seed
    )
    for s in range(traces.num_scenarios):
        sim = TrainingSimulator(
            problem,
            cluster,
            cfg,
            eval_every=eval_every,
            seed=seed,
            latency_source=TraceLatencySource(traces, s),
        )
        h = sim.run(T)
        np.testing.assert_array_equal(h.times, res.times[s])
        np.testing.assert_array_equal(h.suboptimality, res.suboptimality[s])
        np.testing.assert_array_equal(h.fresh_counts, res.fresh_counts[s])
        np.testing.assert_array_equal(
            h.per_worker_latency, res.per_worker_latency[s]
        )
        assert list(h.repartition_events) == list(res.repartition_events[s])
        assert h.evictions == res.evictions[s]
        assert h.rejected_stale == res.rejected_stale[s]
    return res


class TestScalarEquivalence:
    @pytest.mark.parametrize(
        "name,w",
        [("dsag", 2), ("sag", 6), ("sgd", 3), ("gd", 0), ("coded", 0)],
    )
    def test_logreg_methods_bitexact(self, logreg_small, name, w):
        cluster, traces = small_fleet()
        cfg = MethodConfig(name=name, w=w, eta=0.25, subpartitions=3)
        assert_bitexact(logreg_small, cluster, traces, cfg, 25)

    @pytest.mark.parametrize("name,w", [("dsag", 2), ("sag", 6)])
    def test_pca_methods_bitexact(self, pca_small, name, w):
        cluster, traces = small_fleet()
        cfg = MethodConfig(name=name, w=w, eta=0.9, subpartitions=3)
        assert_bitexact(pca_small, cluster, traces, cfg, 25)

    def test_margin_case_collects_post_w_stragglers(self, logreg_small):
        # a wide §5.1 margin makes the post-w collection window visible:
        # some iterations must count more than w fresh results, and the
        # batched path must still match the scalar loop exactly
        cluster, traces = small_fleet(horizon=30)
        cfg = MethodConfig(name="dsag", w=2, eta=0.25, subpartitions=3, margin=0.25)
        res = assert_bitexact(logreg_small, cluster, traces, cfg, 30)
        assert (res.fresh_counts > 2).any()

    def test_load_balancing_case_bitexact(self):
        """The tentpole gate: §6 in the loop — profiler moments, Algorithm 1,
        publication schedule, and Algorithm-2 repartitions all batched."""
        X, y = make_higgs_like(480, seed=0)
        prob = LogisticRegressionProblem(X=X, y=y)
        N = 6
        c_task = prob.compute_cost(1, max(prob.num_samples // (N * 4), 1))
        cluster = make_paper_artificial_cluster(num_workers=N, load_unit=c_task, seed=1)
        traces = sample_fleet(cluster, 3, 40, seed=11)
        cfg = MethodConfig(
            name="dsag", w=3, eta=0.25, subpartitions=4,
            load_balance=True, lb_startup_delay=0.005, lb_interval=0.01,
        )
        res = assert_bitexact(prob, cluster, traces, cfg, 40)
        # the balancer must actually publish (otherwise this gate is vacuous)
        assert any(len(ev) > 0 for ev in res.repartition_events)

    def test_horizon_too_short_raises(self, logreg_small):
        cluster, traces = small_fleet(horizon=5)
        cfg = MethodConfig(name="dsag", w=2, subpartitions=3)
        with pytest.raises(ValueError, match="draws/worker"):
            run_convergence_batch(logreg_small, traces, cfg, 6)


class TestBatchedCacheEquivalence:
    def _random_inserts(self, rng, n, num_events):
        events = []
        for _ in range(num_events):
            start = int(rng.integers(1, n))
            stop = int(min(n, start + rng.integers(0, 8)))
            it = int(rng.integers(0, 12))
            events.append((start, stop, it, rng.normal(size=(4,)).astype(np.float32)))
        return events

    def test_matches_scalar_cache_under_random_overlapping_inserts(self):
        rng = np.random.default_rng(0)
        n, S = 40, 3
        batched = BatchedGradientCache(S, n, np.zeros(4))
        scalars = [GradientCache(n, np.zeros(4)) for _ in range(S)]
        for s in range(S):
            for start, stop, it, val in self._random_inserts(rng, n, 120):
                a = batched.insert(s, start, stop, it, val)
                b = scalars[s].insert(start, stop, it, val)
                assert a == b
        batched.check_invariants()
        for s in range(S):
            scalars[s].check_invariants()
            np.testing.assert_array_equal(batched.sums[s], scalars[s].sum)
            assert batched.coverage[s] == scalars[s].coverage
            assert batched.evictions[s] == scalars[s].evictions
            assert batched.rejected_stale[s] == scalars[s].rejected_stale

    def test_scenarios_are_independent(self):
        cache = BatchedGradientCache(2, 10, np.zeros(2))
        cache.insert(0, 1, 5, 0, np.ones(2))
        assert cache.coverage[0] == 0.5 and cache.coverage[1] == 0.0
        np.testing.assert_array_equal(cache.sums[1], np.zeros(2))

    def test_interval_validation(self):
        cache = BatchedGradientCache(1, 10, np.zeros(2))
        with pytest.raises(ValueError, match="outside"):
            cache.insert(0, 0, 5, 0, np.ones(2))


class TestConvergenceSweep:
    def test_speedup_and_ordering_on_small_grid(self, tmp_path):
        """Mini version of the BENCH_convergence acceptance grid."""
        X, y = make_higgs_like(4096, seed=0)
        prob = LogisticRegressionProblem(X=X, y=y)
        N, sp = 40, 10
        c_task = prob.compute_cost(1, max(prob.num_samples // (N * sp), 1))
        cluster = make_heterogeneous_cluster(
            N, seed=0, burst_rate=0.0, load_unit=c_task
        )
        methods = default_convergence_methods(N, w=32, eta=0.25, subpartitions=sp)
        out = run_convergence_sweep(
            prob, cluster, methods,
            n_scenarios=6, num_iterations=40, eval_every=4,
            regime=HEAVY_BURSTS, seed=0,
        )
        # ordering: DSAG must reach a mid-range gap before SAG and coded
        gap = 0.2
        o = convergence_ordering(out, gap)
        assert o["sag_over_dsag"] > 1.0, o
        assert o["coded_over_dsag"] > 1.0, o
        assert o["dsag_fastest_to_gap"] == 1.0
        # speed: batched engine vs the scalar loop on a subset, extrapolated.
        # The acceptance benchmark records >=10x on the full 10x100 grid; use
        # a low bar here so shared-runner scheduler noise cannot flake it.
        t0 = time.perf_counter()
        run_convergence_batch(
            prob, out.traces, methods["dsag"], 40, eval_every=4, seed=0
        )
        batched_dsag = time.perf_counter() - t0
        measured, extrapolated = scalar_convergence_seconds(
            out, methods=("dsag",), max_scenarios=2
        )
        assert extrapolated > 3.0 * batched_dsag, (extrapolated, batched_dsag)
        # artifact round-trips; the scalar timing covered only dsag, so the
        # writer must record the subset and omit the apples-to-oranges
        # top-level speedup ratio
        path = tmp_path / "BENCH_convergence.json"
        payload = write_bench_convergence(
            out, str(path), gap=gap, scalar_seconds=extrapolated,
            scalar_seconds_measured=measured, scalar_methods=["dsag"],
        )
        import json

        on_disk = json.loads(path.read_text())
        assert on_disk == payload
        assert on_disk["grid"]["n_workers"] == N
        assert on_disk["ordering"]["dsag_fastest_to_gap"] == 1.0
        assert on_disk["scalar_methods"] == ["dsag"]
        assert "speedup_vs_scalar" not in on_disk

    def test_history_view_matches_scalar_run(self, logreg_small):
        cluster, traces = small_fleet()
        del traces  # the sweep draws its own traces
        methods = {"dsag": MethodConfig(name="dsag", w=2, eta=0.25, subpartitions=3)}
        out = run_convergence_sweep(
            logreg_small, cluster, methods,
            n_scenarios=2, num_iterations=15, eval_every=3, seed=0,
        )
        h = scalar_convergence_run(out, "dsag", 1)
        view = out.results["dsag"].history(1)
        np.testing.assert_array_equal(h.times, view.times)
        np.testing.assert_array_equal(h.suboptimality, view.suboptimality)

    def test_time_to_gap_vectorized(self):
        from repro.experiments.convergence import ConvergenceBatchResult

        res = ConvergenceBatchResult(
            times=np.array([[1.0, 2.0, 3.0], [1.0, 2.0, 3.0]]),
            suboptimality=np.array([[0.5, 0.05, np.nan], [0.5, 0.4, 0.3]]),
            fresh_counts=np.zeros((2, 3), np.int64),
            per_worker_latency=np.zeros((2, 3, 1)),
            repartition_events=[[], []],
            evictions=np.zeros(2, np.int64),
            rejected_stale=np.zeros(2, np.int64),
        )
        ttg = res.time_to_gap(0.1)
        assert ttg[0] == 2.0 and np.isinf(ttg[1])


def _fake_result(ttgs):
    """A ConvergenceBatchResult whose time_to_gap(0.1) equals ``ttgs``."""
    from repro.experiments.convergence import ConvergenceBatchResult

    S = len(ttgs)
    times = np.tile(np.array([1.0, 2.0]), (S, 1))
    sub = np.full((S, 2), 0.5)
    for s, t in enumerate(ttgs):
        if np.isfinite(t):
            times[s] = [t, t + 1.0]
            sub[s, 0] = 0.05
    return ConvergenceBatchResult(
        times=times,
        suboptimality=sub,
        fresh_counts=np.zeros((S, 2), np.int64),
        per_worker_latency=np.zeros((S, 2, 1)),
        repartition_events=[[] for _ in range(S)],
        evictions=np.zeros(S, np.int64),
        rejected_stale=np.zeros(S, np.int64),
    )


class _FakeOutcome:
    def __init__(self, results):
        self.results = results


class TestConvergenceOrdering:
    def test_single_missed_scenario_does_not_flip_the_verdict(self):
        # 4 of 5 dsag scenarios reach the gap: the median must stay finite
        # and the verdict must hold (one straggler-heavy draw cannot flip it)
        out = _FakeOutcome(
            {
                "dsag": _fake_result([1.0, 1.1, 1.2, 1.3, np.inf]),
                "sag": _fake_result([3.0] * 5),
                "coded": _fake_result([4.0] * 5),
            }
        )
        o = convergence_ordering(out, 0.1)
        assert np.isfinite(o["median_time_to_gap_dsag"])
        assert o["reached_gap_frac_dsag"] == pytest.approx(0.8)
        assert o["dsag_fastest_to_gap"] == 1.0
        assert o["ordering_dsag_sag_coded"] == 1.0

    def test_verdict_omitted_when_baselines_missing(self):
        # no sag/coded columns: the paper-ordering verdict must not
        # vacuously read "DSAG beats SAG and coded"
        out = _FakeOutcome({"dsag": _fake_result([1.0, 1.1])})
        o = convergence_ordering(out, 0.1)
        assert "dsag_fastest_to_gap" not in o
        assert "ordering_dsag_sag_coded" not in o

    def test_artifact_is_strict_json_even_with_unreached_gaps(self, tmp_path):
        # a method that never reaches the gap yields inf medians; the
        # artifact must still be strict JSON (null, not Infinity)
        out = _FakeOutcome(
            {
                "dsag": _fake_result([1.0, 1.1]),
                "sag": _fake_result([np.inf, np.inf]),
                "coded": _fake_result([4.0, 4.0]),
            }
        )
        out.methods = {
            name: MethodConfig(name=name if name != "coded" else "coded", w=2)
            for name in out.results
        }
        out.num_iterations = 2
        out.engine_seconds = 1.0

        class _P:
            num_samples = 8

        out.problem = _P()

        class _T:
            num_workers = 2
            num_scenarios = 2

        out.traces = _T()
        path = tmp_path / "bench.json"
        payload = write_bench_convergence(out, str(path), gap=0.1)
        import json

        on_disk = json.loads(path.read_text())  # raises on Infinity tokens
        assert "Infinity" not in path.read_text()
        assert on_disk == payload
        assert on_disk["methods"]["sag"]["median_time_to_gap"] is None


class _FixedLatency(LatencySource):
    """Deterministic per-worker latency for semantics tests."""

    def __init__(self, comps):
        self.comps = comps

    def task_latency(self, worker, cost, now):
        return self.comps[worker], 0.0


class TestLatencyAttribution:
    def test_stale_completion_lands_in_its_own_iteration_row(self, logreg_small):
        """A stale result must be attributed to the iteration it was
        assigned in (RunHistory semantics), not the iteration the
        coordinator was collecting when it arrived."""
        cfg = MethodConfig(name="dsag", w=1, eta=0.25, subpartitions=2, margin=0.0)
        cluster = make_heterogeneous_cluster(2, seed=0, burst_rate=0.0)
        sim = TrainingSimulator(
            logreg_small, cluster, cfg, seed=0,
            latency_source=_FixedLatency([0.1, 0.25]),
        )
        h = sim.run(3)
        # worker 1's iteration-0 task (latency 0.25) completes during
        # iteration 2 (which starts at 0.2): row 0 must hold it, row 2 must
        # stay empty for worker 1 (its iteration-2 task returns after t=3)
        assert h.per_worker_latency[0, 1] == pytest.approx(0.25)
        assert np.isnan(h.per_worker_latency[2, 1])
        # fresh completions stay on their own rows
        assert h.per_worker_latency[0, 0] == pytest.approx(0.1)
