"""Tests for the §3 latency model and §4 predictors."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.latency.model import (
    ClusterLatencyModel,
    GammaParams,
    WorkerLatencyModel,
    fit_gamma,
    make_heterogeneous_cluster,
    make_paper_artificial_cluster,
)
from repro.latency.order_stats import (
    empirical_order_statistic,
    predict_order_statistics_all,
    predict_order_statistics_iid,
)
from repro.latency.event_sim import (
    EventDrivenSimulator,
    naive_iteration_times,
    simulate_iteration_times,
)
from repro.latency.profiler import LatencyProfiler, LatencySample


class TestGamma:
    def test_moment_roundtrip(self):
        g = GammaParams.from_mean_var(2.0, 0.5)
        assert g.mean == pytest.approx(2.0)
        assert g.var == pytest.approx(0.5)

    @settings(max_examples=50, deadline=None)
    @given(
        mean=st.floats(min_value=1e-6, max_value=1e3),
        cv=st.floats(min_value=0.01, max_value=2.0),
    )
    def test_moment_roundtrip_property(self, mean, cv):
        var = (cv * mean) ** 2
        g = GammaParams.from_mean_var(mean, var)
        assert g.mean == pytest.approx(mean, rel=1e-9)
        assert g.var == pytest.approx(var, rel=1e-9)

    def test_fit_recovers_parameters(self):
        rng = np.random.default_rng(0)
        g = GammaParams.from_mean_var(3.0, 0.9)
        samples = g.sample(rng, size=20_000)
        fitted = fit_gamma(samples)
        assert fitted.mean == pytest.approx(3.0, rel=0.05)
        assert fitted.var == pytest.approx(0.9, rel=0.15)


class TestLatencyScaling:
    def test_mean_latency_linear_in_load(self):
        """Paper Fig. 1: mean computation latency is linear in load c."""
        w = WorkerLatencyModel(
            comm=GammaParams.from_mean_var(1e-4, 1e-10),
            comp_per_unit=GammaParams.from_mean_var(1e-6, 1e-14),
        )
        rng = np.random.default_rng(0)
        means = []
        loads = [1e3, 2e3, 4e3]
        for c in loads:
            means.append(np.mean([w.sample_comp(c, rng) for _ in range(4000)]))
        assert means[1] / means[0] == pytest.approx(2.0, rel=0.05)
        assert means[2] / means[0] == pytest.approx(4.0, rel=0.05)

    def test_artificial_cluster_slowdown_profile(self):
        cl = make_paper_artificial_cluster(num_workers=49, load_unit=1.0)
        slows = [w.slowdown for w in cl.workers]
        assert slows[0] == pytest.approx(1.0 + (1 / 49) * 0.4)
        assert slows[-1] == pytest.approx(1.4)
        assert all(s2 >= s1 for s1, s2 in zip(slows, slows[1:]))


class TestOrderStats:
    def test_non_iid_prediction_beats_iid(self):
        """Paper Fig. 5: the per-worker model predicts the w-th order statistic
        accurately; the pooled-iid model mispredicts."""
        # persistent stragglers: worker means spread 2.3x, tight per-worker
        # distributions (cv 5%), like the paper's Azure traces (Fig. 3)
        cl = make_heterogeneous_cluster(
            36, seed=3, burst_rate=0.0, comp_range=(1.1e-3, 2.5e-3),
            cv_comp=0.05, cv_comm=0.1,
        )
        c = 1e5
        empirical = empirical_order_statistic(
            ClusterLatencyModel(cl.workers, seed=99).sample_matrix(c, 800)
        )
        ours = predict_order_statistics_all(cl, c, num_trials=800, seed=7)
        iid = predict_order_statistics_iid(cl, c, num_trials=800, seed=7)
        err_ours = np.abs(ours - empirical) / empirical
        err_iid = np.abs(iid - empirical) / empirical
        # our model within a few % everywhere; iid off by ~10% at the tails
        assert err_ours.max() < 0.03
        assert err_iid.max() > 0.05


class TestEventSim:
    def test_w_equals_n_matches_naive_model(self):
        """Paper Fig. 6: for w=N both models agree."""
        cl = make_heterogeneous_cluster(24, seed=1, burst_rate=0.0)
        c = 1e5
        t_event = simulate_iteration_times(cl, 24, c, 300)
        cl2 = make_heterogeneous_cluster(24, seed=1, burst_rate=0.0)
        t_naive = naive_iteration_times(cl2, 24, c, 300)
        assert t_event[-1] == pytest.approx(t_naive[-1], rel=0.1)

    def test_naive_model_underestimates_for_small_w(self):
        """Paper Fig. 6: for w << N the §4.1 model underestimates because it
        ignores workers staying busy across iterations."""
        cl = make_heterogeneous_cluster(24, seed=1, burst_rate=0.0)
        c = 1e5
        t_event = simulate_iteration_times(cl, 3, c, 400)
        cl2 = make_heterogeneous_cluster(24, seed=1, burst_rate=0.0)
        t_naive = naive_iteration_times(cl2, 3, c, 400)
        assert t_naive[-1] < t_event[-1]

    def test_iteration_times_monotone(self):
        cl = make_heterogeneous_cluster(8, seed=0)
        t = simulate_iteration_times(cl, 4, 1e4, 100)
        assert (np.diff(t) > 0).all()

    def test_participation_sums_reasonably(self):
        cl = make_heterogeneous_cluster(10, seed=0, burst_rate=0.0)
        sim = EventDrivenSimulator(cl, [1e4] * 10)
        u = sim.estimate_participation(5, num_iterations=200)
        assert u.shape == (10,)
        assert (u >= 0).all() and (u <= 1).all()
        # on average at least w fresh results arrive per iteration
        assert u.sum() >= 5 - 0.25


class TestProfiler:
    def test_moving_window_eviction(self):
        p = LatencyProfiler(2, window=10.0)
        p.record(LatencySample(0, t_recorded=0.0, round_trip=2.0, compute=1.5, load=10.0))
        p.record(LatencySample(0, t_recorded=8.0, round_trip=3.0, compute=2.0, load=10.0))
        s = p.stats(0, now=9.0)
        assert s.num_samples == 2
        s = p.stats(0, now=11.0)  # first sample (t=0) falls out of the window
        assert s.num_samples == 1
        assert s.e_comp == pytest.approx(2.0)
        assert s.e_comm == pytest.approx(1.0)

    def test_comm_is_roundtrip_minus_compute(self):
        p = LatencyProfiler(1, window=100.0)
        p.record(LatencySample(0, 0.0, round_trip=5.0, compute=4.0, load=1.0))
        s = p.stats(0, now=1.0)
        assert s.e_comm == pytest.approx(1.0)
        assert s.e_total == pytest.approx(5.0)
