"""The sim-to-live cross-layer pin.

For one shared ``FleetTraces`` scenario, the Tier-2
:class:`~repro.ft.runtime.DeadlineController` must produce the *same*
(mask, flush, evict) step-input streams as the scalar
:class:`~repro.cluster.simulator.TrainingSimulator` — bit-for-bit, at
identical virtual times.  If these drift, the live trainer is running
different §5/§5.1/§6.3 semantics than the engines every other test pins.

Also covers the flush/evict/rejoin interplay in the compiled Tier-1
``dsag_update``: an evicted group that rejoins and then receives a flush
must not reinsert its pre-failure pending gradient into H.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.simulator import MethodConfig
from repro.configs import TrainConfig
from repro.core.dsag_pjit import GroupSpec, dsag_update, init_dsag_state
from repro.core.problems import LogisticRegressionProblem, make_higgs_like
from repro.ft.validation import controller_streams, group_loads, pin_streams
from repro.latency.model import (
    ChurnSchedule,
    make_heterogeneous_cluster,
    sample_fleet,
)

N = 8
STEPS = 30


@pytest.fixture(scope="module")
def setup():
    X, y = make_higgs_like(512, seed=0)
    prob = LogisticRegressionProblem(X=X, y=y)
    c_task = prob.compute_cost(1, max(prob.num_samples // N, 1))
    cluster = make_heterogeneous_cluster(N, seed=3, burst_rate=0.0, load_unit=c_task)
    traces = sample_fleet(cluster, 2, 800, seed=7)
    return prob, cluster, traces


def method(name, margin=0.02):
    return MethodConfig(name=name, w=6, eta=0.25, margin=margin, subpartitions=1)


class TestControllerSimulatorPin:
    @pytest.mark.parametrize("name,margin", [("dsag", 0.02), ("dsag", 0.0), ("sag", 0.02)])
    def test_streams_match_bit_exactly(self, setup, name, margin):
        prob, cluster, traces = setup
        for scenario in range(traces.num_scenarios):
            ctrl, sim, hist = pin_streams(
                prob, cluster, traces, scenario, method(name, margin), STEPS
            )
            assert ctrl == sim, ctrl.mismatch_summary(sim)
            # identical event machines -> identical virtual step times
            np.testing.assert_array_equal(ctrl.times, sim.times)

    def test_dsag_streams_contain_real_straggling(self, setup):
        """The pin is vacuous if nothing ever misses: with w=6 of 8, two
        groups per step are outside the wait set, so misses and flushes
        must actually occur in the trace."""
        prob, cluster, traces = setup
        ctrl, sim, hist = pin_streams(prob, cluster, traces, 0, method("dsag"), STEPS)
        assert not ctrl.mask.all(), "every group always fresh: no straggling"
        assert ctrl.flush.any(), "no stale arrivals: margin rule untested"

    def test_streams_match_under_churn(self, setup):
        """Worker death (evict) and rejoin replay identically through the
        controller's generation-bump machinery."""
        prob, cluster, traces0 = setup
        base = controller_streams(
            traces0, 0, w=6, num_iterations=STEPS, loads=group_loads(prob, N)
        )
        # kill workers 2 and 5 a third of the way in; rejoin 2 later
        t_die = float(base.times[STEPS // 3])
        t_rejoin = float(base.times[2 * STEPS // 3])
        alive = np.ones((3, N), dtype=bool)
        alive[1, [2, 5]] = False
        alive[2, 5] = False
        churn = ChurnSchedule(
            times=np.array([t_die, t_rejoin]),
            slowdown=np.tile(traces0.slowdown, (3, 1)),
            alive=alive,
        )
        traces = sample_fleet(
            make_heterogeneous_cluster(
                N,
                seed=3,
                burst_rate=0.0,
                load_unit=prob.compute_cost(1, max(prob.num_samples // N, 1)),
            ),
            2,
            800,
            seed=7,
        ).with_churn(churn)
        for name in ("dsag", "sag"):
            ctrl, sim, hist = pin_streams(
                prob, cluster, traces, 0, method(name), STEPS
            )
            assert ctrl == sim, ctrl.mismatch_summary(sim)
            np.testing.assert_array_equal(ctrl.times, sim.times)
            assert ctrl.evict.sum() == 2  # both deaths cleared a cache slot

    def test_live_trainer_observes_the_pinned_streams(self, setup):
        """End to end: launch/train.py on a paper problem, replaying the
        same trace, logs exactly the simulator's (mask, flush, evict)."""
        from repro.launch.paper_jobs import paper_train_config
        from repro.launch.train import Trainer, TrainerOptions

        prob, cluster, traces = setup
        cfg = method("dsag")
        ctrl, sim, hist = pin_streams(prob, cluster, traces, 1, cfg, 20)
        opts = TrainerOptions(
            arch="logreg",
            steps=20,
            samples=512,
            num_groups=N,
            dsag_w=6,
            method="dsag",
            traces=traces,
            scenario=1,
            train_config=paper_train_config(0.25),
            simulate_stragglers=False,
            failure_max_misses=10_000,  # detector must not perturb the pin
            log_every=100,
        )
        live = Trainer(opts).run()
        np.testing.assert_array_equal(np.stack(live["mask_stream"]), sim.mask[:20])
        np.testing.assert_array_equal(np.stack(live["flush_stream"]), sim.flush[:20])
        np.testing.assert_array_equal(np.stack(live["evict_stream"]), sim.evict[:20])
        # and the live loss actually went down while straggled
        assert live["loss"][-1] < live["loss"][0]


class TestFlushEvictRejoinInterplay:
    """Tier-1 ``dsag_update`` through a fail -> rejoin -> flush sequence."""

    def _setup(self, P=4, d=6):
        gs = GroupSpec(P, ())
        tc = TrainConfig(dsag=True, dsag_cache_dtype="float32")
        dsag = init_dsag_state(jnp.zeros((d,), jnp.float32), gs, tc)
        rng = np.random.default_rng(0)
        grads = [
            jnp.asarray(rng.normal(size=(P, d)).astype(np.float32)) for _ in range(5)
        ]
        return dsag, grads, P

    @staticmethod
    def _check_h_invariant(dsag):
        np.testing.assert_allclose(
            np.asarray(dsag["h"]),
            np.asarray(dsag["cache"]).astype(np.float32).sum(axis=0),
            rtol=1e-6,
            atol=1e-6,
        )

    def test_rejoin_flush_does_not_reinsert_prefailure_pending(self):
        dsag, g, P = self._setup()
        ones = jnp.ones(P, bool)
        zeros = jnp.zeros(P, bool)
        e0 = jnp.array([True, False, False, False])
        m_no0 = jnp.array([False, True, True, True])

        # step 1: all fresh — cache filled, xi = 1
        dsag, _, xi = dsag_update(dsag, g[0], ones, zeros)
        assert float(xi) == 1.0
        self._check_h_invariant(dsag)

        # step 2: group 0 misses; its gradient g[1][0] parks in pending
        dsag, _, xi = dsag_update(dsag, g[1], m_no0, zeros)
        assert bool(dsag["pending_valid"][0])
        assert float(xi) == 1.0  # stale cache entry still counts (§5)
        self._check_h_invariant(dsag)

        # step 3: group 0 fails -> evicted.  Its cache entry leaves H, its
        # in-flight pending gradient died with the group.
        dsag, _, xi = dsag_update(dsag, g[2], m_no0, zeros, evict=e0)
        assert not bool(dsag["filled"][0])
        assert not bool(dsag["pending_valid"][0])  # the satellite-4 fix
        np.testing.assert_array_equal(np.asarray(dsag["cache"])[0], 0.0)
        assert float(xi) == pytest.approx(0.75)
        self._check_h_invariant(dsag)
        h_after_evict = np.asarray(dsag["h"]).copy()

        # step 4: group 0 rejoined; a (spurious) flush arrives before any
        # fresh result.  Pre-fix this reinserted g[1][0] into H.
        flush0 = jnp.array([True, False, False, False])
        dsag, _, xi = dsag_update(dsag, g[3], m_no0, flush0)
        assert float(xi) == pytest.approx(0.75)  # nothing arrived for group 0
        np.testing.assert_array_equal(np.asarray(dsag["cache"])[0], 0.0)
        # H unchanged for group 0's slice: only groups 1..3 updated it
        self._check_h_invariant(dsag)
        assert not np.allclose(np.asarray(dsag["h"]), h_after_evict)  # others moved

        # step 5: a real fresh result refills the slot; coverage recovers
        dsag, _, xi = dsag_update(dsag, g[4], ones, zeros)
        assert float(xi) == 1.0
        assert bool(dsag["filled"][0])
        self._check_h_invariant(dsag)

    def test_evict_clears_pending_even_with_simultaneous_flush(self):
        """Tier-2 race: eviction and a flush bit in the same step — the
        eviction wins (mask/flush are zeroed for evicted groups and the
        pending slot is invalidated)."""
        dsag, g, P = self._setup()
        ones = jnp.ones(P, bool)
        zeros = jnp.zeros(P, bool)
        dsag, _, _ = dsag_update(dsag, g[0], ones, zeros)
        m_no0 = jnp.array([False, True, True, True])
        dsag, _, _ = dsag_update(dsag, g[1], m_no0, zeros)
        both0 = jnp.array([True, False, False, False])
        dsag, _, xi = dsag_update(dsag, g[2], m_no0, both0, evict=both0)
        np.testing.assert_array_equal(np.asarray(dsag["cache"])[0], 0.0)
        assert not bool(dsag["pending_valid"][0])
        assert float(xi) == pytest.approx(0.75)
        self._check_h_invariant(dsag)
