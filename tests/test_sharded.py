"""Sharded fused-scan bit-exactness over the scenario axis (the tentpole).

``EngineConfig(num_devices=D)`` wraps the fused-scan driver in
``shard_map`` on a 1-D ``"data"`` mesh.  Every per-scenario iteration is
row-independent, so the sharded grid must reproduce the single-device
scan **bit for bit** — which joins the existing equality chain
(scan == host == scalar ``TrainingSimulator``).  These tests pin that
join, including the §6 load-balanced path and the edge-padded
``S % num_devices != 0`` remainder.

On a single-CPU-device interpreter the multi-device in-process tests
skip; CI re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` where they
execute for real.  The subprocess smoke test at the bottom always runs:
it spawns a fresh 4-device interpreter so single-device tier-1 runs
still exercise the sharded code path end to end.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.cluster.simulator import MethodConfig
from repro.core.problems import LogisticRegressionProblem, make_higgs_like
from repro.experiments.convergence import run_convergence_batch
from repro.experiments.engine import EngineConfig
from repro.latency.model import make_paper_artificial_cluster, sample_fleet


def _fleet(problem, n_workers=6, n_scenarios=3, horizon=40, seed=11):
    sp = 4
    c_task = problem.compute_cost(
        1, max(problem.num_samples // (n_workers * sp), 1)
    )
    cluster = make_paper_artificial_cluster(
        num_workers=n_workers, load_unit=c_task, seed=1
    )
    return sample_fleet(cluster, n_scenarios, horizon, seed=seed)


def _config(load_balance=False, **kw):
    if load_balance:
        kw.setdefault("lb_startup_delay", 0.005)
        kw.setdefault("lb_interval", 0.01)
    return MethodConfig(
        name="dsag", w=3, eta=0.25, subpartitions=4,
        load_balance=load_balance, **kw
    )


def assert_results_equal(a, b):
    np.testing.assert_array_equal(a.times, b.times)
    np.testing.assert_array_equal(a.suboptimality, b.suboptimality)
    np.testing.assert_array_equal(a.fresh_counts, b.fresh_counts)
    np.testing.assert_array_equal(a.per_worker_latency, b.per_worker_latency)
    np.testing.assert_array_equal(a.evictions, b.evictions)
    np.testing.assert_array_equal(a.rejected_stale, b.rejected_stale)
    assert a.repartition_events == b.repartition_events


@pytest.fixture(scope="module")
def logreg_small():
    X, y = make_higgs_like(480, seed=0)
    return LogisticRegressionProblem(X=X, y=y)


def needs_devices(n):
    return pytest.mark.skipif(
        len(jax.devices()) < n,
        reason=f"needs >= {n} devices (CI re-runs with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4)",
    )


class TestShardedEqualsUnsharded:
    """sharded grid == single-device scan, bit for bit."""

    def test_one_device_mesh_is_bitexact(self, logreg_small):
        """D=1 shard_map is a degenerate shard but a distinct code path
        (runs everywhere, even on a single-device interpreter)."""
        traces = _fleet(logreg_small)
        cfg = _config()
        plain = run_convergence_batch(
            logreg_small, traces, cfg, 40, seed=0,
            engine=EngineConfig(kind="scan"),
        )
        sharded = run_convergence_batch(
            logreg_small, traces, cfg, 40, seed=0,
            engine=EngineConfig(kind="scan", num_devices=1),
        )
        assert_results_equal(plain, sharded)

    @needs_devices(2)
    def test_two_devices_with_remainder(self, logreg_small):
        """S=3 over D=2: the edge-padded remainder row must not leak."""
        traces = _fleet(logreg_small, n_scenarios=3)
        cfg = _config()
        plain = run_convergence_batch(
            logreg_small, traces, cfg, 40, seed=0,
            engine=EngineConfig(kind="scan"),
        )
        sharded = run_convergence_batch(
            logreg_small, traces, cfg, 40, seed=0,
            engine=EngineConfig(kind="scan", num_devices=2),
        )
        assert_results_equal(plain, sharded)

    @needs_devices(4)
    def test_four_devices_even_split(self, logreg_small):
        traces = _fleet(logreg_small, n_scenarios=4)
        cfg = _config()
        plain = run_convergence_batch(
            logreg_small, traces, cfg, 40, seed=0,
            engine=EngineConfig(kind="scan"),
        )
        sharded = run_convergence_batch(
            logreg_small, traces, cfg, 40, seed=0,
            engine=EngineConfig(kind="scan", num_devices=4),
        )
        assert_results_equal(plain, sharded)

    @needs_devices(4)
    def test_four_devices_lb_config_with_remainder(self, logreg_small):
        """§6 load balancing sharded: the balancer's dynamic trip counts
        (``n_ranks``, ``n_sub``) vary across shards, so this pins that
        the extra no-op trips on the smaller shard are exact no-ops."""
        traces = _fleet(logreg_small, n_scenarios=5)
        cfg = _config(load_balance=True)
        plain = run_convergence_batch(
            logreg_small, traces, cfg, 40, seed=0,
            engine=EngineConfig(kind="scan"),
        )
        sharded = run_convergence_batch(
            logreg_small, traces, cfg, 40, seed=0,
            engine=EngineConfig(kind="scan", num_devices=4),
        )
        assert_results_equal(plain, sharded)
        # vacuity guard: the balancer must actually publish here
        assert any(len(ev) > 0 for ev in plain.repartition_events)

    def test_too_many_devices_is_a_clear_error(self, logreg_small):
        traces = _fleet(logreg_small)
        n_avail = len(jax.devices())
        with pytest.raises(ValueError, match="XLA_FLAGS"):
            run_convergence_batch(
                logreg_small, traces, _config(), 10, seed=0,
                engine=EngineConfig(kind="scan", num_devices=n_avail + 1),
            )


def test_sharded_smoke_subprocess():
    """Always-on end-to-end pin: a fresh 4-device interpreter runs the §6
    LB grid sharded (S=3, so both remainder padding and the balancer are
    in play) and checks it against the unsharded scan bit for bit."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
assert len(jax.devices()) >= 4, jax.devices()

from repro.cluster.simulator import MethodConfig
from repro.core.problems import LogisticRegressionProblem, make_higgs_like
from repro.experiments.convergence import run_convergence_batch
from repro.experiments.engine import EngineConfig
from repro.latency.model import make_paper_artificial_cluster, sample_fleet

X, y = make_higgs_like(480, seed=0)
problem = LogisticRegressionProblem(X=X, y=y)
cfg = MethodConfig(name="dsag", w=3, eta=0.25, subpartitions=4,
                   load_balance=True, lb_startup_delay=0.005,
                   lb_interval=0.01)
c_task = problem.compute_cost(1, max(problem.num_samples // 24, 1))
cluster = make_paper_artificial_cluster(num_workers=6, load_unit=c_task,
                                        seed=1)
traces = sample_fleet(cluster, 3, 40, seed=11)

plain = run_convergence_batch(problem, traces, cfg, 30, seed=0,
                              engine=EngineConfig(kind="scan"))
sharded = run_convergence_batch(
    problem, traces, cfg, 30, seed=0,
    engine=EngineConfig(kind="scan", num_devices=4))
np.testing.assert_array_equal(plain.times, sharded.times)
np.testing.assert_array_equal(plain.suboptimality, sharded.suboptimality)
np.testing.assert_array_equal(plain.fresh_counts, sharded.fresh_counts)
np.testing.assert_array_equal(plain.evictions, sharded.evictions)
assert plain.repartition_events == sharded.repartition_events
assert any(len(ev) > 0 for ev in plain.repartition_events)
print("SHARDED_SMOKE_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=600,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_SMOKE_OK" in proc.stdout
