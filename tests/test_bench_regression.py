"""Tests for the benchmark-regression gate (benchmarks/bench_regression.py)."""

import copy

from benchmarks.bench_regression import compare_sweep, method_ranking


def make_payload():
    return {
        "grid": {"regimes": ["calm", "heavy_bursts"]},
        "cells": {
            "calm/dsag/w8": {"mean_iter_time": 1.0},
            "calm/dsag/w10": {"mean_iter_time": 1.4},  # worse w cell, ignored
            "calm/sag/w10": {"mean_iter_time": 2.0},
            "calm/coded/w9": {"mean_iter_time": 3.0},
            "heavy_bursts/dsag/w8": {"mean_iter_time": 2.0},
            "heavy_bursts/sag/w10": {"mean_iter_time": 6.0},
            "heavy_bursts/coded/w9": {"mean_iter_time": 9.0},
        },
        "ordering": {
            "calm": {
                "sag_over_dsag": 2.0,
                "coded_over_dsag": 3.0,
                "dsag_beats_sag_and_coded": 1.0,
            },
            "heavy_bursts": {
                "sag_over_dsag": 3.0,
                "coded_over_dsag": 4.5,
                "dsag_beats_sag_and_coded": 1.0,
            },
        },
    }


def test_identical_payloads_pass():
    committed = make_payload()
    failures, warnings = compare_sweep(committed, copy.deepcopy(committed))
    assert failures == [] and warnings == []


def test_ranking_uses_best_w_cell():
    assert method_ranking(make_payload()["cells"], "calm") == [
        "dsag", "sag", "coded",
    ]


def test_ordering_flip_fails():
    fresh = make_payload()
    # sag overtakes dsag in the burst regime
    fresh["cells"]["heavy_bursts/sag/w10"]["mean_iter_time"] = 1.0
    fresh["ordering"]["heavy_bursts"]["sag_over_dsag"] = 0.5
    fresh["ordering"]["heavy_bursts"]["dsag_beats_sag_and_coded"] = 0.0
    failures, _ = compare_sweep(make_payload(), fresh)
    assert any("ordering flipped" in f for f in failures)
    assert any("dsag_beats_sag_and_coded" in f for f in failures)


def test_speedup_drift_only_warns():
    fresh = make_payload()
    fresh["ordering"]["heavy_bursts"]["sag_over_dsag"] = 3.6  # +20% drift
    failures, warnings = compare_sweep(make_payload(), fresh)
    assert failures == []
    assert any("sag_over_dsag" in w and "20%" in w for w in warnings)


def test_missing_regime_fails():
    fresh = make_payload()
    fresh["grid"]["regimes"] = ["calm"]
    failures, _ = compare_sweep(make_payload(), fresh)
    assert any("missing" in f for f in failures)


def test_rerun_refuses_unknown_regime():
    import pytest

    from benchmarks.bench_regression import GridMismatch, rerun_grid

    committed = make_payload()
    committed["grid"].update(
        {"n_workers": 8, "n_seeds": 2, "num_iterations": 5,
         "regimes": ["made_up_regime"]}
    )
    with pytest.raises(GridMismatch, match="not a known preset"):
        rerun_grid(committed)


def test_rerun_refuses_unreconstructable_cells():
    import pytest

    from benchmarks.bench_regression import GridMismatch, rerun_grid

    # a real (tiny) grid whose committed cells claim a w the rerun's
    # reconstruction cannot produce -> explicit mismatch, not a silent diff
    committed = {
        "grid": {"n_workers": 8, "n_seeds": 2, "num_iterations": 5,
                 "regimes": ["calm"], "seed": 0},
        "cells": {"calm/dsag/w6": {"mean_iter_time": 1.0},
                  "calm/extra_method/w6": {"mean_iter_time": 1.0}},
        "ordering": {"calm": {}},
    }
    with pytest.raises(GridMismatch, match="different grid cells"):
        rerun_grid(committed)
