"""Tests for the benchmark-regression gate (benchmarks/bench_regression.py)."""

import copy

import pytest

from benchmarks.bench_regression import (
    compare_convergence,
    compare_sweep,
    convergence_ranking,
    method_ranking,
)


def make_payload():
    return {
        "grid": {"regimes": ["calm", "heavy_bursts"]},
        "cells": {
            "calm/dsag/w8": {"mean_iter_time": 1.0},
            "calm/dsag/w10": {"mean_iter_time": 1.4},  # worse w cell, ignored
            "calm/sag/w10": {"mean_iter_time": 2.0},
            "calm/coded/w9": {"mean_iter_time": 3.0},
            "heavy_bursts/dsag/w8": {"mean_iter_time": 2.0},
            "heavy_bursts/sag/w10": {"mean_iter_time": 6.0},
            "heavy_bursts/coded/w9": {"mean_iter_time": 9.0},
        },
        "ordering": {
            "calm": {
                "sag_over_dsag": 2.0,
                "coded_over_dsag": 3.0,
                "dsag_beats_sag_and_coded": 1.0,
            },
            "heavy_bursts": {
                "sag_over_dsag": 3.0,
                "coded_over_dsag": 4.5,
                "dsag_beats_sag_and_coded": 1.0,
            },
        },
    }


def test_identical_payloads_pass():
    committed = make_payload()
    failures, warnings = compare_sweep(committed, copy.deepcopy(committed))
    assert failures == [] and warnings == []


def test_ranking_uses_best_w_cell():
    assert method_ranking(make_payload()["cells"], "calm") == [
        "dsag", "sag", "coded",
    ]


def test_ordering_flip_fails():
    fresh = make_payload()
    # sag overtakes dsag in the burst regime
    fresh["cells"]["heavy_bursts/sag/w10"]["mean_iter_time"] = 1.0
    fresh["ordering"]["heavy_bursts"]["sag_over_dsag"] = 0.5
    fresh["ordering"]["heavy_bursts"]["dsag_beats_sag_and_coded"] = 0.0
    failures, _ = compare_sweep(make_payload(), fresh)
    assert any("ordering flipped" in f for f in failures)
    assert any("dsag_beats_sag_and_coded" in f for f in failures)


def test_speedup_drift_only_warns():
    fresh = make_payload()
    fresh["ordering"]["heavy_bursts"]["sag_over_dsag"] = 3.6  # +20% drift
    failures, warnings = compare_sweep(make_payload(), fresh)
    assert failures == []
    assert any("sag_over_dsag" in w and "20%" in w for w in warnings)


def test_missing_regime_fails():
    fresh = make_payload()
    fresh["grid"]["regimes"] = ["calm"]
    failures, _ = compare_sweep(make_payload(), fresh)
    assert any("missing" in f for f in failures)


def test_rerun_refuses_unknown_regime():
    import pytest

    from benchmarks.bench_regression import GridMismatch, rerun_grid

    committed = make_payload()
    committed["grid"].update(
        {"n_workers": 8, "n_seeds": 2, "num_iterations": 5,
         "regimes": ["made_up_regime"]}
    )
    with pytest.raises(GridMismatch, match="not a known preset"):
        rerun_grid(committed)


def test_rerun_refuses_unreconstructable_cells():
    import pytest

    from benchmarks.bench_regression import GridMismatch, rerun_grid

    # a real (tiny) grid whose committed cells claim a w the rerun's
    # reconstruction cannot produce -> explicit mismatch, not a silent diff
    committed = {
        "grid": {"n_workers": 8, "n_seeds": 2, "num_iterations": 5,
                 "regimes": ["calm"], "seed": 0},
        "cells": {"calm/dsag/w6": {"mean_iter_time": 1.0},
                  "calm/extra_method/w6": {"mean_iter_time": 1.0}},
        "ordering": {"calm": {}},
    }
    with pytest.raises(GridMismatch, match="different grid cells"):
        rerun_grid(committed)


# ---------------------------------------------------------------------------
# BENCH_convergence.json gate
# ---------------------------------------------------------------------------


def make_convergence_payload():
    return {
        "methods": {
            "dsag": {"median_time_to_gap": 0.1},
            "sgd": {"median_time_to_gap": None},  # never reaches the gap
            "sag": {"median_time_to_gap": 0.3},
            "coded": {"median_time_to_gap": 0.6},
        },
        "ordering": {
            "dsag_fastest_to_gap": 1.0,
            "ordering_dsag_sag_coded": 1.0,
            "sag_over_dsag": 3.0,
            "coded_over_dsag": 6.0,
        },
        "lb_scan": {
            "bitexact_scan_vs_host": True,
            "speedup_scan_over_host": 2.0,
            "lb_scan_faster_than_host": True,
            "ordering": {"dsag_lb_fastest_to_gap": 1.0},
        },
        "churn": {
            "bitexact_scan_vs_host": True,
            "methods": {
                "dsag": {"median_time_to_gap": 0.2},
                "sag": {"median_time_to_gap": 0.35},
                "coded": {"median_time_to_gap": 0.4},
            },
            "ordering": {
                "ordering_dsag_sag_coded": 1.0,
                "sag_over_dsag": 1.75,
                "coded_over_dsag": 2.0,
            },
        },
        "kernel_backend": {
            "platform": "cpu",
            "bitexact_pallas_vs_xla": True,
            "max_rel_diff_pallas_vs_xla": 0.0,
            "problems": {
                "logreg": {
                    "methods": {
                        "dsag": {
                            "median_final_subopt_xla": 0.1,
                            "median_final_subopt_pallas": 0.1,
                            "digest_xla": "aa11",
                            "digest_pallas": "aa11",
                        },
                        "sag": {
                            "median_final_subopt_xla": 0.2,
                            "median_final_subopt_pallas": 0.2,
                            "digest_xla": "bb22",
                            "digest_pallas": "bb22",
                        },
                    },
                    "ranking_xla": ["dsag", "sag"],
                    "ranking_pallas": ["dsag", "sag"],
                },
            },
        },
    }


def test_convergence_identical_payloads_pass():
    committed = make_convergence_payload()
    failures, warnings = compare_convergence(committed, copy.deepcopy(committed))
    assert failures == [] and warnings == []


def test_convergence_ranking_puts_unreached_methods_last():
    assert convergence_ranking(make_convergence_payload()["methods"]) == [
        "dsag", "sag", "coded", "sgd",
    ]


def test_convergence_ranking_flip_fails():
    fresh = make_convergence_payload()
    fresh["methods"]["sag"]["median_time_to_gap"] = 0.05  # overtakes dsag
    fresh["ordering"]["dsag_fastest_to_gap"] = 0.0
    failures, _ = compare_convergence(make_convergence_payload(), fresh)
    assert any("ranking flipped" in f for f in failures)
    assert any("dsag_fastest_to_gap" in f for f in failures)


def test_convergence_speedup_drift_only_warns():
    fresh = make_convergence_payload()
    fresh["ordering"]["sag_over_dsag"] = 3.6  # +20%
    failures, warnings = compare_convergence(make_convergence_payload(), fresh)
    assert failures == []
    assert any("sag_over_dsag" in w for w in warnings)


def test_lb_scan_bitexactness_loss_fails():
    fresh = make_convergence_payload()
    fresh["lb_scan"]["bitexact_scan_vs_host"] = False
    failures, _ = compare_convergence(make_convergence_payload(), fresh)
    assert any("bit-exact" in f for f in failures)


def test_lb_scan_ordering_flip_fails():
    fresh = make_convergence_payload()
    fresh["lb_scan"]["ordering"]["dsag_lb_fastest_to_gap"] = 0.0
    failures, _ = compare_convergence(make_convergence_payload(), fresh)
    assert any("dsag_lb_fastest_to_gap" in f for f in failures)


def test_lb_scan_wall_clock_flip_only_warns():
    """The scan-vs-host speedup is wall clock: a noisy runner flipping the
    faster-than-host bit (or drifting the ratio) must not block CI."""
    fresh = make_convergence_payload()
    fresh["lb_scan"]["lb_scan_faster_than_host"] = False
    fresh["lb_scan"]["speedup_scan_over_host"] = 0.9
    failures, warnings = compare_convergence(make_convergence_payload(), fresh)
    assert failures == []
    assert any("lb_scan_faster_than_host" in w for w in warnings)
    assert any("speedup_scan_over_host" in w for w in warnings)


def test_churn_bitexactness_loss_fails():
    fresh = make_convergence_payload()
    fresh["churn"]["bitexact_scan_vs_host"] = False
    failures, _ = compare_convergence(make_convergence_payload(), fresh)
    assert any("churn" in f and "bit-exact" in f for f in failures)


def test_churn_ordering_flip_fails():
    fresh = make_convergence_payload()
    # sag overtakes dsag once workers start dying
    fresh["churn"]["methods"]["sag"]["median_time_to_gap"] = 0.15
    fresh["churn"]["ordering"]["ordering_dsag_sag_coded"] = 0.0
    fresh["churn"]["ordering"]["sag_over_dsag"] = 0.75
    failures, _ = compare_convergence(make_convergence_payload(), fresh)
    assert any("churn" in f and "ranking flipped" in f for f in failures)
    assert any(
        "churn" in f and "ordering_dsag_sag_coded" in f for f in failures
    )


def test_churn_speedup_drift_only_warns():
    fresh = make_convergence_payload()
    fresh["churn"]["ordering"]["sag_over_dsag"] = 2.1  # +20%
    failures, warnings = compare_convergence(make_convergence_payload(), fresh)
    assert failures == []
    assert any("churn" in w and "sag_over_dsag" in w for w in warnings)


def test_churn_column_rerun_refuses_foreign_recipe():
    from benchmarks.bench_regression import GridMismatch, run_churn_column

    with pytest.raises(GridMismatch, match="not reproducible"):
        run_churn_column({"problem": "something_else"})
    with pytest.raises(GridMismatch, match="unknown regime"):
        run_churn_column({"regime": "made_up_regime"})


def test_committed_churn_column_recipe_is_complete():
    """The committed artifact's churn column must carry the full recipe the
    gate rerun needs (every CHURN_RECIPE key), so a rerun reconstructs the
    identical schedule rather than silently defaulting."""
    import json
    from pathlib import Path

    from benchmarks.bench_regression import CHURN_RECIPE

    path = Path(__file__).resolve().parent.parent / "BENCH_convergence.json"
    committed = json.loads(path.read_text())
    assert "churn" in committed
    col = committed["churn"]
    assert set(CHURN_RECIPE) <= set(col["recipe"])
    assert col["bitexact_scan_vs_host"] is True
    assert col["ordering"]["ordering_dsag_sag_coded"] == 1.0


def test_kernel_backend_bitexactness_loss_on_cpu_fails():
    fresh = make_convergence_payload()
    fresh["kernel_backend"]["bitexact_pallas_vs_xla"] = False
    fresh["kernel_backend"]["max_rel_diff_pallas_vs_xla"] = 1e-7
    failures, _ = compare_convergence(make_convergence_payload(), fresh)
    assert any("kernel_backend" in f and "bit-exact" in f for f in failures)


def test_kernel_backend_cross_platform_diff_is_tolerance_gated():
    """On a non-cpu platform (real Pallas compile) a sub-tolerance
    Pallas-vs-XLA diff warns; above tolerance it fails."""
    fresh = make_convergence_payload()
    kb = fresh["kernel_backend"]
    kb["platform"] = "tpu"
    kb["bitexact_pallas_vs_xla"] = False
    kb["max_rel_diff_pallas_vs_xla"] = 1e-6
    failures, warnings = compare_convergence(make_convergence_payload(), fresh)
    assert failures == []
    assert any("within" in w and "tolerance" in w for w in warnings)
    kb["max_rel_diff_pallas_vs_xla"] = 0.5
    failures, _ = compare_convergence(make_convergence_payload(), fresh)
    assert any("exceeds tolerance" in f for f in failures)


def test_kernel_backend_digest_change_fails_same_platform_only():
    fresh = make_convergence_payload()
    meth = fresh["kernel_backend"]["problems"]["logreg"]["methods"]
    meth["dsag"]["digest_pallas"] = "deadbeef"
    failures, _ = compare_convergence(make_convergence_payload(), fresh)
    assert any("digest changed" in f for f in failures)
    # a rerun on a different platform cannot reproduce the bits: skipped
    fresh["kernel_backend"]["platform"] = "tpu"
    failures, _ = compare_convergence(make_convergence_payload(), fresh)
    assert not any("digest changed" in f for f in failures)


def test_kernel_backend_ranking_flip_fails():
    fresh = make_convergence_payload()
    fresh["kernel_backend"]["problems"]["logreg"]["ranking_pallas"] = [
        "sag", "dsag",
    ]
    failures, _ = compare_convergence(make_convergence_payload(), fresh)
    assert any(
        "kernel_backend" in f and "ranking flipped" in f for f in failures
    )


def test_kernel_backend_subopt_drift_only_warns():
    fresh = make_convergence_payload()
    meth = fresh["kernel_backend"]["problems"]["logreg"]["methods"]
    meth["sag"]["median_final_subopt_xla"] = 0.24  # +20%
    # keep the digest consistent with "same bits" being violated elsewhere:
    # drift alone (e.g. cross-platform rerun) must not fail
    fresh["kernel_backend"]["platform"] = "tpu"
    failures, warnings = compare_convergence(make_convergence_payload(), fresh)
    assert failures == []
    assert any("median_final_subopt" in w for w in warnings)


def test_kernel_backend_column_rerun_refuses_unknown_regime():
    from benchmarks.bench_regression import (
        GridMismatch,
        run_kernel_backend_column,
    )

    with pytest.raises(GridMismatch, match="unknown regime"):
        run_kernel_backend_column({"regime": "made_up_regime"})


def test_committed_kernel_backend_column_is_complete():
    """The committed artifact's kernel_backend column must carry its full
    recipe, per-backend digests for every method, and the bit-exact pin."""
    import json
    from pathlib import Path

    from benchmarks.bench_regression import KERNEL_BACKEND_RECIPE

    path = Path(__file__).resolve().parent.parent / "BENCH_convergence.json"
    committed = json.loads(path.read_text())
    assert "kernel_backend" in committed
    col = committed["kernel_backend"]
    assert set(KERNEL_BACKEND_RECIPE) <= set(col["recipe"])
    assert col["bitexact_pallas_vs_xla"] is True
    assert col["max_rel_diff_pallas_vs_xla"] == 0.0
    assert set(col["problems"]) == {"logreg", "pca"}
    for pname, pcol in col["problems"].items():
        for m, entry in pcol["methods"].items():
            assert entry["digest_xla"] == entry["digest_pallas"], (pname, m)
        assert pcol["ranking_xla"] == pcol["ranking_pallas"]


def test_rerun_convergence_refuses_missing_recipe():
    from benchmarks.bench_regression import GridMismatch, rerun_convergence

    committed = make_convergence_payload()  # no recipe section
    with pytest.raises(GridMismatch, match="recipe"):
        rerun_convergence(committed)


def test_convergence_ranking_ties_break_by_name_not_dict_order():
    # two methods that never reach the gap: order must not depend on dict
    # insertion (committed JSON is key-sorted, fresh payloads are not)
    methods = {
        "sgd": {"median_time_to_gap": None},
        "coded": {"median_time_to_gap": None},
        "dsag": {"median_time_to_gap": 0.1},
    }
    assert convergence_ranking(methods) == ["dsag", "coded", "sgd"]
    reordered = {k: methods[k] for k in ("coded", "dsag", "sgd")}
    assert convergence_ranking(reordered) == ["dsag", "coded", "sgd"]


def test_gate_mode_rerun_without_wall_clock_fields_is_quiet():
    """The single-run gate rerun omits warm wall-clock fields; comparing it
    against a full committed artifact must neither fail nor warn."""
    fresh = make_convergence_payload()
    for key in ("speedup_scan_over_host", "lb_scan_faster_than_host"):
        del fresh["lb_scan"][key]
    failures, warnings = compare_convergence(make_convergence_payload(), fresh)
    assert failures == [] and warnings == []
