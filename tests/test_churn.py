"""Elastic-fleet churn: cross-engine pins, §7.2 replay, and properties.

The tentpole contract: a :class:`~repro.latency.model.ChurnSchedule` on the
traces (time-varying slowdown rows + a per-iteration liveness mask) runs
**bit-for-bit identically** through all three engines — the scalar
:class:`~repro.cluster.simulator.TrainingSimulator`, the batched host
convergence loop, and the fused scan — under worker death, late join,
latency bursts, and the reactive §6 load balancer.  This file pins that
chain (death-only, join-only, death+join+burst, LB under churn with both
the dense-universe and tiled caches, and the sharded scan), the repaired
§7.2 artificial-slowdown trace replay (structured
:class:`~repro.latency.model.SlowdownRemoval` timed events now fold into a
churn schedule instead of being refused), and the churn invariants as
hypothesis properties (dead workers contribute nothing, revived workers
re-enter empty, cleared caches stay disjoint within the active-slot
capacity bound, and the all-alive schedule is bit-identical to the static
path).
"""

import numpy as np
import pytest

import jax

from hypothesis import given, settings, strategies as st

from repro.cluster.simulator import (
    MethodConfig,
    TraceLatencySource,
    TrainingSimulator,
)
from repro.core.gradient_cache import (
    BatchedGradientCache,
    GradientCache,
    active_slot_capacity,
    build_slot_universe,
)
from repro.core.problems import LogisticRegressionProblem, make_higgs_like
from repro.experiments.convergence import run_convergence_batch
from repro.experiments.engine import EngineConfig
from repro.experiments.fused import prepare_scan_inputs, run_convergence_scan
from repro.experiments.sweep import replay_batch
from repro.latency.model import (
    ChurnSchedule,
    SlowdownRemoval,
    churn_from_removals,
    make_heterogeneous_cluster,
    make_paper_artificial_cluster,
    paper_artificial_churn,
    sample_fleet,
)

N_WORKERS, N_SCEN, HORIZON = 6, 3, 30
T_ITERS = 24


@pytest.fixture(scope="module")
def logreg_small():
    X, y = make_higgs_like(240, seed=0)
    return LogisticRegressionProblem(X=X, y=y)


@pytest.fixture(scope="module")
def cluster():
    return make_heterogeneous_cluster(
        N_WORKERS, seed=3, burst_rate=0.0, comp_range=(1.1e-3, 2.5e-3)
    )


@pytest.fixture(scope="module")
def traces(cluster):
    return sample_fleet(cluster, N_SCEN, HORIZON, seed=11)


@pytest.fixture(scope="module")
def bursty_traces(cluster):
    return sample_fleet(
        cluster,
        N_SCEN,
        HORIZON,
        seed=11,
        burst_rate=3.0,
        burst_factor_mean=3.0,
        burst_duration_mean=5e-3,
    )


def death_only_churn(traces):
    """Worker 4 dies at t=0.02 and never returns."""
    sd = np.asarray(traces.slowdown)
    alive0 = np.ones(traces.num_workers, bool)
    alive1 = alive0.copy()
    alive1[4] = False
    return ChurnSchedule(
        times=np.array([0.02]),
        slowdown=np.stack([sd, sd]),
        alive=np.stack([alive0, alive1]),
    )


def join_only_churn(traces):
    """Worker 2 is absent from the start and joins at t=0.03."""
    sd = np.asarray(traces.slowdown)
    alive0 = np.ones(traces.num_workers, bool)
    alive0[2] = False
    alive1 = np.ones(traces.num_workers, bool)
    return ChurnSchedule(
        times=np.array([0.03]),
        slowdown=np.stack([sd, sd]),
        alive=np.stack([alive0, alive1]),
    )


def death_join_drift_churn(traces):
    """Worker 1 dies then revives while worker 4 dies; slowdowns drift."""
    n = traces.num_workers
    sd0 = np.asarray(traces.slowdown)
    sd1 = sd0 * np.linspace(1.0, 1.5, n)
    alive0 = np.ones(n, bool)
    alive1 = alive0.copy()
    alive1[1] = False
    alive2 = np.ones(n, bool)
    alive2[4] = False
    return ChurnSchedule(
        times=np.array([0.02, 0.06]),
        slowdown=np.stack([sd0, sd1, sd0]),
        alive=np.stack([alive0, alive1, alive2]),
    )


def assert_results_equal(a, b):
    np.testing.assert_array_equal(a.times, b.times)
    np.testing.assert_array_equal(a.suboptimality, b.suboptimality)
    np.testing.assert_array_equal(a.fresh_counts, b.fresh_counts)
    np.testing.assert_array_equal(a.per_worker_latency, b.per_worker_latency)
    np.testing.assert_array_equal(a.evictions, b.evictions)
    np.testing.assert_array_equal(a.rejected_stale, b.rejected_stale)
    assert a.repartition_events == b.repartition_events


def assert_three_engines_agree(
    problem, cluster, churned, cfg, num_iterations=T_ITERS, slot_budget=None
):
    """scalar == host == scan, every RunHistory field, every scenario."""
    host = run_convergence_batch(
        problem, churned, cfg, num_iterations, eval_every=2, seed=0,
        engine=EngineConfig(kind="host"),
    )
    eng = EngineConfig(kind="scan", slot_budget=slot_budget)
    scan = run_convergence_scan(
        problem, churned, cfg, num_iterations, eval_every=2, seed=0, engine=eng
    )
    assert_results_equal(scan, host)
    for s in range(churned.num_scenarios):
        sim = TrainingSimulator(
            problem, cluster, cfg, eval_every=2, seed=0,
            latency_source=TraceLatencySource(churned, s),
        )
        h = sim.run(num_iterations)
        hb = host.history(s)
        np.testing.assert_array_equal(h.times, hb.times)
        np.testing.assert_array_equal(h.suboptimality, hb.suboptimality)
        np.testing.assert_array_equal(h.fresh_counts, hb.fresh_counts)
        np.testing.assert_array_equal(
            h.per_worker_latency, hb.per_worker_latency
        )
        assert h.repartition_events == hb.repartition_events
        assert h.evictions == hb.evictions
        assert h.rejected_stale == hb.rejected_stale
    return host


class TestCrossEngineChurn:
    """scalar == host == scan under fleet churn, bit for bit."""

    def test_death_only(self, logreg_small, cluster, traces):
        churned = traces.with_churn(death_only_churn(traces))
        cfg = MethodConfig(name="dsag", w=4, eta=0.25, subpartitions=2)
        host = assert_three_engines_agree(logreg_small, cluster, churned, cfg)
        # vacuity guard: the death must actually bite (later iterations can
        # never collect more fresh results than living workers)
        post = host.times[:, :-1] >= 0.02
        assert post.any()
        assert (host.fresh_counts[:, 1:][post] <= N_WORKERS - 1).all()

    def test_join_only(self, logreg_small, cluster, traces):
        churned = traces.with_churn(join_only_churn(traces))
        cfg = MethodConfig(name="sag", w=N_WORKERS, eta=0.25, subpartitions=2)
        host = assert_three_engines_agree(logreg_small, cluster, churned, cfg)
        # before the join at most N-1 workers can be fresh; afterwards the
        # full fleet must show up at least once (the joiner participates)
        assert (host.fresh_counts[:, 0] <= N_WORKERS - 1).all()
        assert (host.fresh_counts.max(axis=1) == N_WORKERS).all()

    def test_death_join_and_bursts(self, logreg_small, cluster, bursty_traces):
        churned = bursty_traces.with_churn(death_join_drift_churn(bursty_traces))
        cfg = MethodConfig(name="dsag", w=4, eta=0.25, subpartitions=2)
        assert_three_engines_agree(logreg_small, cluster, churned, cfg)

    def test_lb_under_churn_universe_cache(
        self, logreg_small, cluster, bursty_traces
    ):
        churned = bursty_traces.with_churn(death_join_drift_churn(bursty_traces))
        cfg = MethodConfig(
            name="dsag", w=4, eta=0.25, subpartitions=2, load_balance=True,
            lb_interval=0.01, lb_startup_delay=0.005,
        )
        spec, _, _ = prepare_scan_inputs(
            logreg_small, churned, cfg, T_ITERS, seed=0
        )
        assert spec.cache_mode == "universe" and spec.has_churn
        assert_three_engines_agree(logreg_small, cluster, churned, cfg)

    def test_lb_under_churn_tiled_cache(
        self, logreg_small, cluster, bursty_traces
    ):
        churned = bursty_traces.with_churn(death_join_drift_churn(bursty_traces))
        cfg = MethodConfig(
            name="dsag", w=4, eta=0.25, subpartitions=2, load_balance=True,
            lb_interval=0.01, lb_startup_delay=0.005,
        )
        spec, _, _ = prepare_scan_inputs(
            logreg_small, churned, cfg, T_ITERS, seed=0, slot_budget=50
        )
        assert spec.cache_mode == "tiled" and spec.has_churn
        assert_three_engines_agree(
            logreg_small, cluster, churned, cfg, slot_budget=50
        )

    def test_all_alive_schedule_matches_the_static_path(
        self, logreg_small, traces
    ):
        """Churn machinery engaged but nothing changes: bit-identical to the
        churn-free engines (the sort+gather tau and the per-start slowdown
        row lookups select the same floats)."""
        sd = np.asarray(traces.slowdown)
        churn = ChurnSchedule(
            times=np.array([0.02, 0.05]),
            slowdown=np.stack([sd, sd, sd]),
            alive=np.ones((3, traces.num_workers), bool),
        )
        cfg = MethodConfig(name="dsag", w=4, eta=0.25, subpartitions=2)
        for kind, runner in [
            ("host", run_convergence_batch),
            ("scan", run_convergence_scan),
        ]:
            eng = EngineConfig(kind=kind)
            plain = runner(
                logreg_small, traces, cfg, T_ITERS, eval_every=2, seed=0,
                engine=eng,
            )
            churned = runner(
                logreg_small, traces.with_churn(churn), cfg, T_ITERS,
                eval_every=2, seed=0, engine=eng,
            )
            assert_results_equal(plain, churned)


def needs_devices(n):
    return pytest.mark.skipif(
        len(jax.devices()) < n,
        reason=f"needs >= {n} devices (CI re-runs with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4)",
    )


class TestShardedChurn:
    """The churn operands are replicated; shards reproduce the plain bits."""

    def test_one_device_mesh_is_bitexact(self, logreg_small, bursty_traces):
        churned = bursty_traces.with_churn(death_join_drift_churn(bursty_traces))
        cfg = MethodConfig(name="dsag", w=4, eta=0.25, subpartitions=2)
        plain = run_convergence_scan(
            logreg_small, churned, cfg, T_ITERS, seed=0,
            engine=EngineConfig(kind="scan"),
        )
        sharded = run_convergence_scan(
            logreg_small, churned, cfg, T_ITERS, seed=0,
            engine=EngineConfig(kind="scan", num_devices=1),
        )
        assert_results_equal(plain, sharded)

    @needs_devices(4)
    def test_four_devices_lb_churn_with_remainder(self, logreg_small, cluster):
        """S=5 over D=4 (S % D != 0): edge padding + per-shard dynamic trip
        counts (cache clears, event ranks, LB rounds) under churn."""
        traces5 = sample_fleet(
            cluster, 5, HORIZON, seed=11,
            burst_rate=3.0, burst_factor_mean=3.0, burst_duration_mean=5e-3,
        )
        churned = traces5.with_churn(death_join_drift_churn(traces5))
        cfg = MethodConfig(
            name="dsag", w=4, eta=0.25, subpartitions=2, load_balance=True,
            lb_interval=0.01, lb_startup_delay=0.005,
        )
        plain = run_convergence_scan(
            logreg_small, churned, cfg, T_ITERS, seed=0,
            engine=EngineConfig(kind="scan"),
        )
        sharded = run_convergence_scan(
            logreg_small, churned, cfg, T_ITERS, seed=0,
            engine=EngineConfig(kind="scan", num_devices=4),
        )
        assert_results_equal(plain, sharded)


class TestPaperSlowdownReplay:
    """§7.2: the artificial-slowdown scenario replays instead of refusing."""

    N = 8
    REMOVE_AT = 0.04
    T = 40

    def _setup(self, problem):
        c_task = problem.compute_cost(1, max(problem.num_samples // self.N, 1))
        cluster = make_paper_artificial_cluster(
            num_workers=self.N, load_unit=c_task, seed=1
        )
        traces = sample_fleet(cluster, N_SCEN, self.T, seed=7)
        return cluster, traces

    def test_slowdown_removal_replays_through_all_three_engines(
        self, logreg_small
    ):
        cluster, traces = self._setup(logreg_small)
        removal = SlowdownRemoval(
            time=self.REMOVE_AT, workers=tuple(range(self.N - 4, self.N))
        )
        cfg = MethodConfig(name="sag", w=self.N, eta=0.25, subpartitions=2)
        # the scalar path folds the structured timed event into a churn
        # schedule on its trace source (this used to raise ValueError)
        churned = traces.with_churn(
            churn_from_removals(traces.slowdown, [removal])
        )
        host = run_convergence_batch(
            logreg_small, churned, cfg, self.T, eval_every=2, seed=0,
            engine=EngineConfig(kind="host"),
        )
        scan = run_convergence_scan(
            logreg_small, churned, cfg, self.T, eval_every=2, seed=0
        )
        assert_results_equal(scan, host)
        for s in range(N_SCEN):
            sim = TrainingSimulator(
                logreg_small, cluster, cfg, eval_every=2, seed=0,
                latency_source=TraceLatencySource(traces, s),
                timed_events=[(self.REMOVE_AT, removal)],
            )
            h = sim.run(self.T)
            hb = host.history(s)
            np.testing.assert_array_equal(h.times, hb.times)
            np.testing.assert_array_equal(h.suboptimality, hb.suboptimality)
            np.testing.assert_array_equal(h.fresh_counts, hb.fresh_counts)

    def test_recovery_ordering_after_removal(self, logreg_small):
        """The paper's §7.2 signature: once the last workers' artificial
        slowdown is removed, iterations get faster (the fleet recovers)."""
        _, traces = self._setup(logreg_small)
        churned = traces.with_churn(
            churn_from_removals(
                traces.slowdown,
                [SlowdownRemoval(
                    time=self.REMOVE_AT,
                    workers=tuple(range(self.N - 4, self.N)),
                )],
            )
        )
        cfg = MethodConfig(name="sag", w=self.N, eta=0.25, subpartitions=2)
        host = run_convergence_batch(
            logreg_small, churned, cfg, self.T, eval_every=2, seed=0,
            engine=EngineConfig(kind="host"),
        )
        durations = np.diff(host.times, axis=1, prepend=0.0)
        pre = durations[:, 1:][host.times[:, 1:] < self.REMOVE_AT]
        post = durations[:, 1:][host.times[:, :-1] >= self.REMOVE_AT]
        assert pre.size and post.size
        assert post.mean() < pre.mean()

    def test_opaque_callables_are_still_refused(self, logreg_small):
        cluster, traces = self._setup(logreg_small)
        with pytest.raises(ValueError, match="timed_events"):
            TrainingSimulator(
                logreg_small, cluster,
                MethodConfig(name="dsag", w=4, subpartitions=2),
                timed_events=[(1.0, lambda c: None)],
                latency_source=TraceLatencySource(traces, 0),
            )

    def test_paper_artificial_churn_is_the_folded_schedule(self):
        churn = paper_artificial_churn(
            num_workers=self.N, remove_at=self.REMOVE_AT, num_removed=4
        )
        assert churn.times.tolist() == [self.REMOVE_AT]
        np.testing.assert_allclose(
            churn.slowdown[0], 1.0 + (np.arange(1, self.N + 1) / self.N) * 0.4
        )
        assert (churn.slowdown[1][-4:] == 1.0).all()
        np.testing.assert_allclose(
            churn.slowdown[1][: self.N - 4], churn.slowdown[0][: self.N - 4]
        )
        assert churn.alive.all()


class TestChurnScheduleValidation:
    def test_rejects_unordered_times_and_dead_fleets(self):
        sd = np.ones((3, 4))
        ok = np.ones((3, 4), bool)
        with pytest.raises(ValueError, match="strictly increasing"):
            ChurnSchedule(times=np.array([0.3, 0.2]), slowdown=sd, alive=ok)
        dead = ok.copy()
        dead[1] = False
        with pytest.raises(ValueError, match="at least one worker alive"):
            ChurnSchedule(times=np.array([0.1, 0.2]), slowdown=sd, alive=dead)
        with pytest.raises(ValueError, match="state rows"):
            ChurnSchedule(times=np.array([0.1]), slowdown=sd, alive=ok)

    def test_row_lookup_conventions(self):
        sd = np.ones((3, 2))
        churn = ChurnSchedule(
            times=np.array([1.0, 2.0]), slowdown=sd, alive=np.ones((3, 2), bool)
        )
        assert churn.row_at(0.0) == 0
        assert churn.row_at(1.0) == 1  # boundary belongs to the new row
        np.testing.assert_array_equal(churn.row_at(np.array([0.5, 2.5])), [0, 2])
        b = churn.boundary_before(np.array([0, 1, 2]))
        assert b[0] == -np.inf and b[1] == 1.0 and b[2] == 2.0


class TestChurnProperties:
    """Hypothesis invariants of the churn semantics."""

    @given(
        seed=st.integers(0, 10_000),
        n=st.integers(2, 8),
        cuts=st.integers(1, 3),
        w_frac=st.floats(0.3, 1.0),
    )
    @settings(max_examples=10, deadline=None)
    def test_all_alive_schedule_is_bit_identical_to_static(
        self, seed, n, cuts, w_frac
    ):
        cl = make_heterogeneous_cluster(n, seed=seed % 5, burst_rate=0.0)
        traces = sample_fleet(cl, 2, 12, seed=seed)
        rng = np.random.default_rng(seed)
        times = np.sort(rng.uniform(1e-4, 0.05, size=cuts))
        times = np.unique(times)
        churn = ChurnSchedule(
            times=times,
            slowdown=np.repeat(
                np.asarray(traces.slowdown)[None, :], times.size + 1, axis=0
            ),
            alive=np.ones((times.size + 1, n), bool),
        )
        w = max(1, int(round(w_frac * n)))
        a = replay_batch(traces, w, 12)
        b = replay_batch(traces.with_churn(churn), w, 12)
        np.testing.assert_array_equal(a.iteration_times, b.iteration_times)
        np.testing.assert_array_equal(a.fresh_counts, b.fresh_counts)
        np.testing.assert_array_equal(a.participation, b.participation)

    @given(
        seed=st.integers(0, 10_000),
        n=st.integers(3, 8),
        data=st.data(),
    )
    @settings(max_examples=10, deadline=None)
    def test_dead_workers_contribute_no_finishes_or_draws(
        self, seed, n, data
    ):
        """After a worker's death boundary it never finishes a task: its
        task records are NaN and its participation stops growing."""
        cl = make_heterogeneous_cluster(n, seed=seed % 5, burst_rate=0.0)
        traces = sample_fleet(cl, 2, 16, seed=seed)
        dead_worker = data.draw(st.integers(0, n - 1), label="dead_worker")
        t_die = data.draw(st.floats(1e-3, 0.04), label="t_die")
        alive0 = np.ones(n, bool)
        alive1 = alive0.copy()
        alive1[dead_worker] = False
        sd = np.asarray(traces.slowdown)
        churn = ChurnSchedule(
            times=np.array([t_die]),
            slowdown=np.stack([sd, sd]),
            alive=np.stack([alive0, alive1]),
        )
        res = replay_batch(
            traces.with_churn(churn), max(1, n // 2), 16, record_tasks=True
        )
        dead_iters = res.task_assigned >= t_die  # [S, T]
        assert np.isnan(res.task_finish[:, :, dead_worker][dead_iters]).all()
        assert np.isnan(res.task_start[:, :, dead_worker][dead_iters]).all()

    @given(seed=st.integers(0, 10_000), data=st.data())
    @settings(max_examples=15, deadline=None)
    def test_clear_range_is_exact_and_idempotent(self, seed, data):
        """Clearing a dead worker's range removes exactly its coverage and
        running-sum contribution; clearing again is a no-op; a revived
        worker re-inserts into an empty range."""
        rng = np.random.default_rng(seed)
        n_samples = 120
        n_workers = 4
        per = n_samples // n_workers
        cache = GradientCache(n_samples, np.zeros(3))
        # one disjoint entry per worker's base range
        for i in range(n_workers):
            cache.insert(
                i * per + 1, (i + 1) * per, 0, rng.normal(size=3)
            )
        cache.check_invariants()
        victim = data.draw(st.integers(0, n_workers - 1), label="victim")
        lo, hi = victim * per + 1, (victim + 1) * per
        cov_before = cache.coverage
        removed = cache.clear_range(lo, hi)
        assert removed == 1
        cache.check_invariants()
        assert cache.coverage == pytest.approx(cov_before - per / n_samples)
        assert not any(e.overlaps(lo, hi) for e in cache.entries())
        assert cache.clear_range(lo, hi) == 0  # idempotent
        # revival: the range accepts a fresh insert with a clean slate
        v = rng.normal(size=3)
        assert cache.insert(lo, hi, 5, v)
        cache.check_invariants()

    @given(seed=st.integers(0, 10_000), n_ops=st.integers(5, 40))
    @settings(max_examples=10, deadline=None)
    def test_batched_cache_stays_disjoint_under_insert_clear_interleaving(
        self, seed, n_ops
    ):
        """Random §5 traffic interleaved with death clears keeps every
        scenario's active set disjoint with consistent coverage/sums, and
        each worker's active entries within the tiled capacity bound."""
        rng = np.random.default_rng(seed)
        n_samples, n_workers, S = 96, 3, 2
        per = n_samples // n_workers
        ladder = (1, 2, 4)
        base_start = [i * per + 1 for i in range(n_workers)]
        base_stop = [(i + 1) * per for i in range(n_workers)]
        universe = build_slot_universe(base_start, base_stop, ladder)
        cap = active_slot_capacity(universe)
        cache = BatchedGradientCache(S, n_samples, np.zeros(2))
        for it in range(n_ops):
            s = int(rng.integers(S))
            i = int(rng.integers(n_workers))
            if rng.random() < 0.25:
                cache.clear_range(s, base_start[i], base_stop[i])
            else:
                p = int(rng.choice(ladder))
                k = int(rng.integers(1, p + 1))
                nl = per
                lo = base_start[i] + (k - 1) * nl // p
                hi = base_start[i] + k * nl // p - 1
                cache.insert(s, lo, hi, it, rng.normal(size=2))
            cache.check_invariants()
            for s2 in range(S):
                for j in range(n_workers):
                    active_j = sum(
                        1
                        for slot, (a, _stop) in enumerate(cache._intervals)
                        if cache._iters[slot, s2] >= 0
                        and base_start[j] <= a <= base_stop[j]
                    )
                    assert active_j <= cap[j]
