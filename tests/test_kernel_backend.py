"""Cross-backend pins: ``kernel_backend="pallas"`` == ``"xla"``, bit for bit.

The fused scan's two hot paths — the §3 width-bucketed block-subgradient
gather and the §5 grid-cache event application — can route through the
``repro.kernels`` Pallas twins (``EngineConfig(kernel_backend="pallas")``,
interpret mode on CPU).  These tests pin that on the same platform the
Pallas path reproduces the XLA path bit for bit across the committed
method grids (logreg: dsag/sag/sgd/gd/coded; PCA: dsag/sag), the §6
load-balanced configs (dense universe and tiled active-slot cache — §3
only there, the §6 cache walks stay XLA), elastic-fleet churn, and the
scenario-sharded driver; plus the structured capability reasons for
configs that cannot take the Pallas path.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.cluster.simulator import MethodConfig
from repro.core.problems import (
    LogisticRegressionProblem,
    PCAProblem,
    make_genomics_like_matrix,
    make_higgs_like,
)
from repro.experiments.convergence import run_convergence_batch
from repro.experiments.engine import (
    CAP_PALLAS_DTYPE,
    CAP_PALLAS_HOST,
    CAP_PALLAS_UNAVAILABLE,
    EngineCapabilityError,
    EngineConfig,
)
from repro.experiments.fused import kernel_backend_capability, scan_capability
from repro.latency.model import (
    ChurnSchedule,
    make_heterogeneous_cluster,
    make_paper_artificial_cluster,
    sample_fleet,
)


@pytest.fixture(scope="module")
def logreg_small():
    X, y = make_higgs_like(240, seed=0)
    return LogisticRegressionProblem(X=X, y=y)


@pytest.fixture(scope="module")
def pca_small():
    return PCAProblem(X=make_genomics_like_matrix(240, 48, seed=0), k=3)


def small_fleet(n_workers=6, n_scenarios=3, horizon=25, seed=3):
    cluster = make_heterogeneous_cluster(
        n_workers, seed=seed, burst_rate=0.0, comp_range=(1.1e-3, 2.5e-3)
    )
    traces = sample_fleet(
        cluster, n_scenarios, horizon,
        burst_rate=3.0, burst_factor_mean=3.0, burst_duration_mean=5e-3,
        seed=seed + 8,
    )
    return traces


def assert_results_equal(a, b):
    np.testing.assert_array_equal(a.times, b.times)
    np.testing.assert_array_equal(a.suboptimality, b.suboptimality)
    np.testing.assert_array_equal(a.fresh_counts, b.fresh_counts)
    np.testing.assert_array_equal(a.per_worker_latency, b.per_worker_latency)
    np.testing.assert_array_equal(a.evictions, b.evictions)
    np.testing.assert_array_equal(a.rejected_stale, b.rejected_stale)
    assert a.repartition_events == b.repartition_events


def run_both(problem, traces, cfg, T, **eng_kw):
    xla = run_convergence_batch(
        problem, traces, cfg, T, eval_every=2, seed=0,
        engine=EngineConfig(kind="scan", **eng_kw),
    )
    pal = run_convergence_batch(
        problem, traces, cfg, T, eval_every=2, seed=0,
        engine=EngineConfig(kind="scan", kernel_backend="pallas", **eng_kw),
    )
    return xla, pal


class TestPallasEqualsXla:
    @pytest.mark.parametrize(
        "name,w",
        [("dsag", 2), ("sag", 6), ("sgd", 3), ("gd", 0), ("coded", 0)],
    )
    def test_logreg_methods(self, logreg_small, name, w):
        traces = small_fleet()
        cfg = MethodConfig(name=name, w=w, eta=0.25, subpartitions=3)
        xla, pal = run_both(logreg_small, traces, cfg, 25)
        assert_results_equal(xla, pal)

    @pytest.mark.parametrize("name,w", [("dsag", 2), ("sag", 6)])
    def test_pca_methods(self, pca_small, name, w):
        traces = small_fleet()
        cfg = MethodConfig(name=name, w=w, eta=0.9, subpartitions=3)
        xla, pal = run_both(pca_small, traces, cfg, 25)
        assert_results_equal(xla, pal)

    def test_churn_config(self, logreg_small):
        """Worker death mid-run: the churn body's gather widths and §5
        events still route identically through the Pallas twins."""
        traces = small_fleet(n_scenarios=2, horizon=30)
        sd = np.asarray(traces.slowdown)
        alive0 = np.ones(traces.num_workers, bool)
        alive1 = alive0.copy()
        alive1[4] = False
        churned = traces.with_churn(ChurnSchedule(
            times=np.array([0.02]),
            slowdown=np.stack([sd, sd]),
            alive=np.stack([alive0, alive1]),
        ))
        cfg = MethodConfig(name="dsag", w=2, eta=0.25, subpartitions=3)
        xla, pal = run_both(logreg_small, churned, cfg, 30)
        assert_results_equal(xla, pal)


class TestPallasEqualsXlaLB:
    """§6 configs: Pallas covers the §3 gather only (the universe/tiled
    cache walks have no Pallas twin), but the full run must still match."""

    @pytest.fixture(scope="class")
    def lb_problem(self):
        X, y = make_higgs_like(480, seed=0)
        return LogisticRegressionProblem(X=X, y=y)

    def _lb_setup(self, problem):
        sp, nw = 4, 6
        c_task = problem.compute_cost(
            1, max(problem.num_samples // (nw * sp), 1)
        )
        cluster = make_paper_artificial_cluster(
            num_workers=nw, load_unit=c_task, seed=1
        )
        traces = sample_fleet(cluster, 3, 40, seed=11)
        cfg = MethodConfig(
            name="dsag", w=3, eta=0.25, subpartitions=sp, load_balance=True,
            lb_startup_delay=0.005, lb_interval=0.01, margin=0.02,
        )
        return traces, cfg

    def test_lb_universe(self, lb_problem):
        traces, cfg = self._lb_setup(lb_problem)
        xla, pal = run_both(lb_problem, traces, cfg, 40)
        assert_results_equal(xla, pal)
        # vacuity guard: the balancer must actually publish on this fleet
        assert any(len(ev) > 0 for ev in xla.repartition_events)

    def test_lb_tiled(self, lb_problem):
        traces, cfg = self._lb_setup(lb_problem)
        cap = scan_capability(lb_problem, cfg, traces.num_workers)
        budget = cap.slots_total - 1  # forces the tiled layout
        xla, pal = run_both(lb_problem, traces, cfg, 40, slot_budget=budget)
        assert_results_equal(xla, pal)


class TestShardedPallas:
    def test_one_device_mesh_is_bitexact(self, logreg_small):
        """shard_map + Pallas interpret compose (D=1 runs everywhere)."""
        traces = small_fleet()
        cfg = MethodConfig(name="dsag", w=2, eta=0.25, subpartitions=3)
        plain = run_convergence_batch(
            logreg_small, traces, cfg, 25, seed=0,
            engine=EngineConfig(kind="scan", kernel_backend="pallas"),
        )
        sharded = run_convergence_batch(
            logreg_small, traces, cfg, 25, seed=0,
            engine=EngineConfig(
                kind="scan", kernel_backend="pallas", num_devices=1
            ),
        )
        assert_results_equal(plain, sharded)

    @pytest.mark.skipif(
        len(jax.devices()) < 4,
        reason="needs >= 4 devices (CI re-runs with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4)",
    )
    def test_four_devices_vs_xla(self, logreg_small):
        traces = small_fleet(n_scenarios=4)
        cfg = MethodConfig(name="dsag", w=2, eta=0.25, subpartitions=3)
        xla, pal = run_both(logreg_small, traces, cfg, 25, num_devices=4)
        assert_results_equal(xla, pal)


class TestCapabilityReasons:
    def test_xla_always_supported(self, logreg_small):
        cap = kernel_backend_capability(logreg_small, "xla")
        assert cap.supported

    def test_pallas_supported_for_committed_problems(
        self, logreg_small, pca_small
    ):
        for prob in (logreg_small, pca_small):
            cap = kernel_backend_capability(prob, "pallas")
            assert cap.supported, cap.detail

    def test_problem_without_pallas_kernels(self):
        """A problem that publishes no Pallas twins reports the structured
        unavailable code instead of failing inside the trace."""
        X, y = make_higgs_like(60, seed=1)
        prob = LogisticRegressionProblem(X=X, y=y)
        kernels = prob.fused_kernels()
        prob._kernels = dataclasses.replace(kernels, sub_blocks_pallas=None)
        cap = kernel_backend_capability(prob, "pallas")
        assert not cap.supported
        assert cap.code == CAP_PALLAS_UNAVAILABLE
        traces = small_fleet(n_workers=4, n_scenarios=1, horizon=10)
        cfg = MethodConfig(name="dsag", w=2, eta=0.25, subpartitions=2)
        with pytest.raises(EngineCapabilityError) as ei:
            run_convergence_batch(
                prob, traces, cfg, 10, seed=0,
                engine=EngineConfig(kind="scan", kernel_backend="pallas"),
            )
        assert ei.value.capability.code == CAP_PALLAS_UNAVAILABLE

    def test_float64_problem_reports_dtype_code(self):
        prob = PCAProblem(
            X=make_genomics_like_matrix(60, 16, seed=2).astype(np.float64), k=2
        )
        cap = kernel_backend_capability(prob, "pallas")
        assert not cap.supported
        assert cap.code == CAP_PALLAS_DTYPE

    def test_host_engine_rejects_pallas(self, logreg_small):
        traces = small_fleet(n_workers=4, n_scenarios=1, horizon=10)
        cfg = MethodConfig(name="dsag", w=2, eta=0.25, subpartitions=2)
        with pytest.raises(EngineCapabilityError) as ei:
            run_convergence_batch(
                logreg_small, traces, cfg, 10, seed=0,
                engine=EngineConfig(kind="host", kernel_backend="pallas"),
            )
        assert ei.value.capability.code == CAP_PALLAS_HOST

    def test_unknown_backend_rejected_at_config(self):
        with pytest.raises(ValueError, match="kernel_backend"):
            EngineConfig(kernel_backend="cuda")
