"""End-to-end system tests: trainer loop, checkpoint/restart continuity,
serving, and the subprocess mini dry-run (8 fake devices)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig
from repro.launch.train import Trainer, TrainerOptions


def make_trainer(tmp, steps=24, restore=False, dsag=True, arch="qwen1.5-0.5b",
                 lr=1e-3):
    tc = TrainConfig(
        dsag=dsag,
        optimizer="adamw",
        learning_rate=lr,
        checkpoint_every=10,
        dsag_cache_dtype="bfloat16",
    )
    return Trainer(
        TrainerOptions(
            arch=arch,
            smoke=True,
            steps=steps,
            global_batch=8,
            seq_len=64,
            checkpoint_dir=str(tmp),
            restore=restore,
            train_config=tc,
            log_every=100,
        )
    )


class TestTrainerLoop:
    def test_loss_decreases_with_dsag_and_stragglers(self, tmp_path):
        hist = make_trainer(tmp_path / "a", steps=40).run()
        first = np.mean(hist["loss"][:5])
        last = np.mean(hist["loss"][-5:])
        assert last < first, (first, last)
        # straggler masks actually fired at least once
        assert min(hist["mask_count"]) < 4

    def test_checkpoint_restart_continues(self, tmp_path):
        d = tmp_path / "ckpt"
        t1 = make_trainer(d, steps=12)
        h1 = t1.run()
        t2 = make_trainer(d, steps=20, restore=True)
        state = t2.init_state()
        restored, start = t2.maybe_restore(state)
        assert start > 0
        # params actually came from disk, not the fresh init
        fresh = np.asarray(jax.tree.leaves(state["params"])[0], np.float32)
        loaded = np.asarray(jax.tree.leaves(restored["params"])[0], np.float32)
        assert not np.allclose(fresh, loaded)

    def test_failed_group_does_not_block_progress(self, tmp_path):
        """Permanently killing one group still trains (the paper's point)."""
        t = make_trainer(tmp_path / "f", steps=80, lr=3e-3)
        # sabotage: group 0's simulated latency is infinite
        orig = t._group_latencies

        def latencies(step):
            lat = orig(step)
            lat[0] = 1e9
            return lat

        t._group_latencies = latencies
        hist = t.run()
        # pre-eviction this FAILED (the dead group's frozen cache entry biased
        # H upward); §6.3-style eviction restores monotone progress
        assert np.mean(hist["loss"][-10:]) < np.mean(hist["loss"][:10])
        assert t.failures.failed[0]


@pytest.mark.slow
def test_mini_dryrun_subprocess(tmp_path):
    """Compile a reduced config on an 8-device fake mesh in a subprocess —
    catches sharding regressions without the full 512-device sweep."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config, TrainConfig
from repro.core.dsag_pjit import (GroupSpec, init_train_state, make_train_step,
                                  train_state_specs)
from repro.launch.mesh import make_test_mesh
from repro.models import build_model
from repro.models.sharding import set_mesh

mesh = make_test_mesh((2, 4))
set_mesh(mesh)
cfg = get_smoke_config("qwen2-7b")
model = build_model(cfg)
tc = TrainConfig(dsag=True, dsag_groups="dp", fsdp=True)
gs = GroupSpec(2, ("data",))
specs = model.param_specs(True)

def loss_fn(p, b):
    return model.train_loss(p, b)

step = make_train_step(loss_fn, tc, gs, mesh, specs)
params = model.init(jax.random.key(0))
state = init_train_state(params, tc, gs)
sspecs = train_state_specs(tc, gs, specs)
state = jax.tree.map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), state, sspecs,
    is_leaf=lambda x: hasattr(x, "shape"),
)
batch = {"tokens": jnp.zeros((2, 4, 32), jnp.int32)}
mask = jnp.ones(2, bool)
new_state, metrics = jax.jit(step)(state, batch, mask, ~mask)
print("MINI_DRYRUN_OK", float(metrics["loss"]))
"""
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=600,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MINI_DRYRUN_OK" in proc.stdout


def test_dryrun_results_complete_and_ok():
    """All 32 single-pod cells must exist and be status=ok (the sweep runs
    out-of-band; this test asserts on its artifacts)."""
    base = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "experiments", "dryrun", "16x16")
    if not os.path.isdir(base):
        pytest.skip("single-pod dry-run sweep has not been run yet")
    files = [f for f in os.listdir(base) if f.endswith(".json")]
    assert len(files) >= 32
    for f in files:
        with open(os.path.join(base, f)) as fh:
            data = json.load(fh)
        assert data.get("status") == "ok", f"{f}: {data.get('error', '')[:200]}"
        rl = data["roofline"]
        assert rl["flops_per_device"] > 0
        assert rl["step_time_s"] > 0
