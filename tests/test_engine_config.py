"""API pins for the typed engine-selection surface (no heavy compute).

``EngineConfig`` replaced the stringly ``engine: str = "auto"`` kwarg;
these tests pin the coercion contract (legacy strings keep working but
warn), the validation errors, the structured capability report the fused
engine raises instead of prose-matched ``ValueError`` text, and the
deprecation hygiene: warnings attribute to the *caller's* line and fire
exactly once per call site.
"""

import dataclasses
import warnings

import pytest

from repro.experiments.engine import (
    CAP_ACTIVE_SET,
    CAP_OK,
    CAP_TILED,
    EngineCapability,
    EngineCapabilityError,
    EngineConfig,
    as_engine_config,
)


class TestEngineConfig:
    def test_defaults(self):
        cfg = EngineConfig()
        assert cfg.kind == "auto"
        assert cfg.num_devices is None and cfg.mesh is None
        assert cfg.slot_budget is None
        assert cfg.eval_every == 1

    def test_frozen(self):
        with pytest.raises(dataclasses.FrozenInstanceError):
            EngineConfig().kind = "scan"

    @pytest.mark.parametrize("kind", ["auto", "scan", "host"])
    def test_valid_kinds(self, kind):
        assert EngineConfig(kind=kind).kind == kind

    def test_invalid_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown engine kind"):
            EngineConfig(kind="scann")

    @pytest.mark.parametrize(
        "kwargs",
        [{"num_devices": 0}, {"slot_budget": 0}, {"eval_every": 0}],
    )
    def test_invalid_numbers_rejected(self, kwargs):
        with pytest.raises(ValueError):
            EngineConfig(**kwargs)


class TestAsEngineConfig:
    def test_none_is_defaults(self):
        assert as_engine_config(None) == EngineConfig()

    def test_config_passes_through_unchanged(self):
        cfg = EngineConfig(kind="scan", num_devices=2)
        assert as_engine_config(cfg) is cfg

    @pytest.mark.parametrize("kind", ["auto", "scan", "host"])
    def test_legacy_strings_warn_and_map(self, kind):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            cfg = as_engine_config(kind)
        assert cfg == EngineConfig(kind=kind)

    def test_bad_type_rejected(self):
        with pytest.raises(TypeError, match="EngineConfig or a legacy string"):
            as_engine_config(42)


class TestDeprecationHygiene:
    """Stacklevel + once-per-call-site semantics of the legacy aliases."""

    def test_direct_call_attributes_warning_to_caller(self):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            as_engine_config("host")
        assert len(caught) == 1
        assert caught[0].filename == __file__

    def test_forwarding_entry_point_attributes_to_its_caller(self):
        """run_convergence_batch forwards its engine kwarg; the warning
        must point at the line that wrote the string, not at the
        forwarding frame inside convergence.py."""
        import numpy as np

        from repro.cluster.simulator import MethodConfig
        from repro.core.problems import LogisticRegressionProblem, make_higgs_like
        from repro.experiments.convergence import run_convergence_batch
        from repro.latency.model import make_heterogeneous_cluster, sample_fleet

        X, y = make_higgs_like(32, seed=0)
        prob = LogisticRegressionProblem(X=X, y=y)
        cluster = make_heterogeneous_cluster(
            2, seed=3, burst_rate=0.0, comp_range=(1.1e-3, 2.5e-3)
        )
        traces = sample_fleet(cluster, 1, 4, burst_rate=0.0, seed=1)
        cfg = MethodConfig(name="sgd", w=2)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            res = run_convergence_batch(prob, traces, cfg, 2, engine="host")
        assert np.isfinite(res.times).all()
        dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1
        assert dep[0].filename == __file__

    def test_engine_string_warns_once_per_call_site(self):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("default")
            for _ in range(3):
                as_engine_config("host")  # one call site, three calls
            as_engine_config("host")  # a second call site
        dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 2

    def test_scan_unsupported_reason_warns_once_per_call_site(self):
        from repro.cluster.simulator import MethodConfig
        from repro.core.problems import LogisticRegressionProblem, make_higgs_like
        from repro.experiments import fused

        X, y = make_higgs_like(32, seed=0)
        prob = LogisticRegressionProblem(X=X, y=y)
        cfg = MethodConfig(name="dsag", w=2, subpartitions=2)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("default")
            for _ in range(3):
                assert fused.scan_unsupported_reason(prob, cfg, 2) is None
        dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
        assert len(dep) == 1
        assert dep[0].filename == __file__


class TestEngineCapability:
    def test_codes_are_distinct_stable_strings(self):
        assert len({CAP_OK, CAP_TILED, CAP_ACTIVE_SET}) == 3

    def test_error_carries_capability_and_is_valueerror(self):
        cap = EngineCapability(
            supported=False,
            code=CAP_ACTIVE_SET,
            detail="too many active slots",
            slots_total=100,
            slots_resident=60,
            slot_budget=50,
        )
        err = EngineCapabilityError(cap)
        assert isinstance(err, ValueError)
        assert err.capability is cap
        assert str(err) == "too many active slots"
