"""API pins for the typed engine-selection surface (no heavy compute).

``EngineConfig`` replaced the stringly ``engine: str = "auto"`` kwarg;
these tests pin the coercion contract (legacy strings keep working but
warn), the validation errors, and the structured capability report the
fused engine raises instead of prose-matched ``ValueError`` text.
"""

import dataclasses

import pytest

from repro.experiments.engine import (
    CAP_ACTIVE_SET,
    CAP_OK,
    CAP_TILED,
    EngineCapability,
    EngineCapabilityError,
    EngineConfig,
    as_engine_config,
)


class TestEngineConfig:
    def test_defaults(self):
        cfg = EngineConfig()
        assert cfg.kind == "auto"
        assert cfg.num_devices is None and cfg.mesh is None
        assert cfg.slot_budget is None
        assert cfg.eval_every == 1

    def test_frozen(self):
        with pytest.raises(dataclasses.FrozenInstanceError):
            EngineConfig().kind = "scan"

    @pytest.mark.parametrize("kind", ["auto", "scan", "host"])
    def test_valid_kinds(self, kind):
        assert EngineConfig(kind=kind).kind == kind

    def test_invalid_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown engine kind"):
            EngineConfig(kind="scann")

    @pytest.mark.parametrize(
        "kwargs",
        [{"num_devices": 0}, {"slot_budget": 0}, {"eval_every": 0}],
    )
    def test_invalid_numbers_rejected(self, kwargs):
        with pytest.raises(ValueError):
            EngineConfig(**kwargs)


class TestAsEngineConfig:
    def test_none_is_defaults(self):
        assert as_engine_config(None) == EngineConfig()

    def test_config_passes_through_unchanged(self):
        cfg = EngineConfig(kind="scan", num_devices=2)
        assert as_engine_config(cfg) is cfg

    @pytest.mark.parametrize("kind", ["auto", "scan", "host"])
    def test_legacy_strings_warn_and_map(self, kind):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            cfg = as_engine_config(kind)
        assert cfg == EngineConfig(kind=kind)

    def test_bad_type_rejected(self):
        with pytest.raises(TypeError, match="EngineConfig or a legacy string"):
            as_engine_config(42)


class TestEngineCapability:
    def test_codes_are_distinct_stable_strings(self):
        assert len({CAP_OK, CAP_TILED, CAP_ACTIVE_SET}) == 3

    def test_error_carries_capability_and_is_valueerror(self):
        cap = EngineCapability(
            supported=False,
            code=CAP_ACTIVE_SET,
            detail="too many active slots",
            slots_total=100,
            slots_resident=60,
            slot_budget=50,
        )
        err = EngineCapabilityError(cap)
        assert isinstance(err, ValueError)
        assert err.capability is cap
        assert str(err) == "too many active slots"
