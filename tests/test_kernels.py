"""Per-kernel correctness sweeps: Pallas (interpret mode on CPU) vs ref.py
pure-jnp oracles across shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (
    dsag_cache_update_op,
    dsag_update_ref,
    flash_attention_op,
    flash_attention_ref,
    gram_matvec_op,
    gram_matvec_ref,
)


class TestGramMatvec:
    @pytest.mark.parametrize(
        "n,d,k", [(256, 64, 3), (512, 128, 8), (1024, 96, 16), (300, 50, 3)]
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, n, d, k, dtype):
        kx, kv = jax.random.split(jax.random.key(0))
        x = jax.random.normal(kx, (n, d), dtype)
        v = jax.random.normal(kv, (d, k), dtype)
        got = gram_matvec_op(x, v, block_rows=128, interpret=True)
        want = gram_matvec_ref(x, v)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=tol, atol=tol * np.abs(want).max()
        )

    def test_single_hbm_pass_shape(self):
        x = jnp.ones((512, 64))
        v = jnp.ones((64, 4))
        out = gram_matvec_op(x, v, interpret=True)
        assert out.shape == (64, 4)
        np.testing.assert_allclose(np.asarray(out), 512.0 * 64 * np.ones((64, 4)), rtol=1e-5)


class TestDsagUpdate:
    @pytest.mark.parametrize("p,n", [(4, 4096), (2, 2048), (8, 6000), (1, 2048)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, p, n, dtype):
        k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
        g = jax.random.normal(k1, (p, n), dtype)
        c = jax.random.normal(k2, (p, n), dtype)
        h = jax.random.normal(k3, (n,), jnp.float32)
        mask = (jnp.arange(p) % 2 == 0).astype(jnp.float32)
        new_c, new_h = dsag_cache_update_op(g, c, h, mask, interpret=True)
        ref_c, ref_h = dsag_update_ref(g, c, h, mask)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(new_c, np.float32), np.asarray(ref_c, np.float32), atol=tol
        )
        np.testing.assert_allclose(np.asarray(new_h), np.asarray(ref_h), atol=tol * 4)

    def test_invariant_h_equals_sum_of_cache_deltas(self):
        """After updating from a zero cache with full mask, h == Σ_i g_i."""
        p, n = 3, 2048
        g = jax.random.normal(jax.random.key(2), (p, n))
        c = jnp.zeros((p, n))
        h = jnp.zeros((n,))
        new_c, new_h = dsag_cache_update_op(g, c, h, jnp.ones(p), interpret=True)
        np.testing.assert_allclose(np.asarray(new_h), np.asarray(g.sum(0)), atol=1e-4)
        np.testing.assert_allclose(np.asarray(new_c), np.asarray(g), atol=1e-6)

    def test_masked_groups_untouched(self):
        p, n = 4, 2048
        g = jax.random.normal(jax.random.key(3), (p, n))
        c = jax.random.normal(jax.random.key(4), (p, n))
        h = jnp.zeros((n,))
        new_c, new_h = dsag_cache_update_op(g, c, h, jnp.zeros(p), interpret=True)
        np.testing.assert_allclose(np.asarray(new_c), np.asarray(c), atol=1e-6)
        np.testing.assert_allclose(np.asarray(new_h), 0.0, atol=1e-6)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,h,s,d", [(1, 2, 256, 64), (2, 1, 384, 128), (1, 4, 128, 80)]
    )
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_reference(self, b, h, s, d, causal):
        if not causal and s % 128 != 0:
            pytest.skip("non-causal requires aligned sk")
        k1, k2, k3 = jax.random.split(jax.random.key(5), 3)
        q = jax.random.normal(k1, (b, h, s, d), jnp.float32)
        k = jax.random.normal(k2, (b, h, s, d), jnp.float32)
        v = jax.random.normal(k3, (b, h, s, d), jnp.float32)
        got = flash_attention_op(q, k, v, causal=causal, interpret=True)
        want = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)

    def test_bf16_io(self):
        q = jax.random.normal(jax.random.key(6), (1, 2, 256, 64), jnp.bfloat16)
        k = jax.random.normal(jax.random.key(7), (1, 2, 256, 64), jnp.bfloat16)
        v = jax.random.normal(jax.random.key(8), (1, 2, 256, 64), jnp.bfloat16)
        got = flash_attention_op(q, k, v, causal=True, interpret=True)
        want = flash_attention_ref(q, k, v, causal=True)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
        )

    def test_long_context_streaming_blocks(self):
        """Many kv blocks: the online softmax must stay numerically exact."""
        q = jax.random.normal(jax.random.key(9), (1, 1, 128, 64))
        k = jax.random.normal(jax.random.key(10), (1, 1, 2048, 64))
        v = jax.random.normal(jax.random.key(11), (1, 1, 2048, 64))
        got = flash_attention_op(q, k, v, causal=False, interpret=True)
        want = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)
