"""Per-kernel correctness sweeps: Pallas (interpret mode on CPU) vs ref.py
pure-jnp oracles across shapes and dtypes.

The §3/§5 engine twins (``block_sub``, ``cache_events``) are compared
against the *jitted* refs with ``assert_array_equal``: the fused engine
runs fully under ``jax.jit``, so bit-exactness is defined against XLA's
jitted fusion of the same expressions (which differs from eager dispatch
at the last ulp — matching eager would be matching the wrong contract).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.experimental import enable_x64

from repro.kernels import ops, ref
from repro.kernels.block_sub import logreg_block_sub, pca_block_sub
from repro.kernels.cache_events import grid_cache_update
from repro.kernels.ops import (
    dsag_cache_update_op,
    dsag_update_ref,
    flash_attention_op,
    flash_attention_ref,
    gram_matvec_op,
    gram_matvec_ref,
)


class TestGramMatvec:
    @pytest.mark.parametrize(
        "n,d,k", [(256, 64, 3), (512, 128, 8), (1024, 96, 16), (300, 50, 3)]
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, n, d, k, dtype):
        kx, kv = jax.random.split(jax.random.key(0))
        x = jax.random.normal(kx, (n, d), dtype)
        v = jax.random.normal(kv, (d, k), dtype)
        got = gram_matvec_op(x, v, block_rows=128, interpret=True)
        want = gram_matvec_ref(x, v)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=tol, atol=tol * np.abs(want).max()
        )

    def test_single_hbm_pass_shape(self):
        x = jnp.ones((512, 64))
        v = jnp.ones((64, 4))
        out = gram_matvec_op(x, v, interpret=True)
        assert out.shape == (64, 4)
        np.testing.assert_allclose(np.asarray(out), 512.0 * 64 * np.ones((64, 4)), rtol=1e-5)

    @pytest.mark.parametrize("n,d,k", [(0, 8, 3), (16, 0, 3), (16, 8, 0)])
    def test_degenerate_shapes_route_to_oracle(self, n, d, k):
        """Zero-size dims would launch empty/never-written Pallas grids;
        the wrapper must return the oracle's exact empty-contraction."""
        x = jnp.zeros((n, d), jnp.float32)
        v = jnp.zeros((d, k), jnp.float32)
        out = gram_matvec_op(x, v, interpret=True)
        assert out.shape == (d, k)
        np.testing.assert_array_equal(np.asarray(out), np.zeros((d, k)))

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=300),
        d=st.integers(min_value=1, max_value=40),
        k=st.integers(min_value=1, max_value=12),
    )
    def test_shape_sweep_non_multiple_n_small_k(self, n, d, k):
        """Non-multiple n and k < 128 exercise both padding paths."""
        kx, kv = jax.random.split(jax.random.key(n * 1000 + d * 16 + k))
        x = jax.random.normal(kx, (n, d), jnp.float32)
        v = jax.random.normal(kv, (d, k), jnp.float32)
        got = gram_matvec_op(x, v, block_rows=128, interpret=True)
        want = gram_matvec_ref(x, v)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4,
            atol=1e-4 * max(np.abs(np.asarray(want)).max(), 1.0),
        )


class TestDsagUpdate:
    @pytest.mark.parametrize("p,n", [(4, 4096), (2, 2048), (8, 6000), (1, 2048)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, p, n, dtype):
        k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
        g = jax.random.normal(k1, (p, n), dtype)
        c = jax.random.normal(k2, (p, n), dtype)
        h = jax.random.normal(k3, (n,), jnp.float32)
        mask = (jnp.arange(p) % 2 == 0).astype(jnp.float32)
        new_c, new_h = dsag_cache_update_op(g, c, h, mask, interpret=True)
        ref_c, ref_h = dsag_update_ref(g, c, h, mask)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(new_c, np.float32), np.asarray(ref_c, np.float32), atol=tol
        )
        np.testing.assert_allclose(np.asarray(new_h), np.asarray(ref_h), atol=tol * 4)

    def test_invariant_h_equals_sum_of_cache_deltas(self):
        """After updating from a zero cache with full mask, h == Σ_i g_i."""
        p, n = 3, 2048
        g = jax.random.normal(jax.random.key(2), (p, n))
        c = jnp.zeros((p, n))
        h = jnp.zeros((n,))
        new_c, new_h = dsag_cache_update_op(g, c, h, jnp.ones(p), interpret=True)
        np.testing.assert_allclose(np.asarray(new_h), np.asarray(g.sum(0)), atol=1e-4)
        np.testing.assert_allclose(np.asarray(new_c), np.asarray(g), atol=1e-6)

    def test_masked_groups_untouched(self):
        p, n = 4, 2048
        g = jax.random.normal(jax.random.key(3), (p, n))
        c = jax.random.normal(jax.random.key(4), (p, n))
        h = jnp.zeros((n,))
        new_c, new_h = dsag_cache_update_op(g, c, h, jnp.zeros(p), interpret=True)
        np.testing.assert_allclose(np.asarray(new_c), np.asarray(c), atol=1e-6)
        np.testing.assert_allclose(np.asarray(new_h), 0.0, atol=1e-6)

    @pytest.mark.parametrize("p,n", [(0, 64), (3, 0), (0, 0)])
    def test_degenerate_shapes_route_to_oracle(self, p, n):
        """p == 0 makes the inner grid empty (the h accumulator scratch is
        never initialized — its output would be garbage, not zeros); the
        wrapper must detect it and return the oracle's empty-sum."""
        g = jnp.zeros((p, n), jnp.float32)
        c = jnp.zeros((p, n), jnp.float32)
        h = jnp.arange(n, dtype=jnp.float32)
        mask = jnp.ones((p,), jnp.float32)
        new_c, new_h = dsag_cache_update_op(g, c, h, mask, interpret=True)
        assert new_c.shape == (p, n) and new_h.shape == (n,)
        np.testing.assert_array_equal(np.asarray(new_h), np.asarray(h))

    @settings(max_examples=10, deadline=None)
    @given(
        p=st.integers(min_value=1, max_value=6),
        n=st.integers(min_value=1, max_value=3000),
    )
    def test_shape_sweep_non_multiple_n(self, p, n):
        """n not a multiple of the row block (including n < block)."""
        k1, k2, k3 = jax.random.split(jax.random.key(p * 5000 + n), 3)
        g = jax.random.normal(k1, (p, n), jnp.float32)
        c = jax.random.normal(k2, (p, n), jnp.float32)
        h = jax.random.normal(k3, (n,), jnp.float32)
        mask = (jnp.arange(p) % 2 == 0).astype(jnp.float32)
        new_c, new_h = dsag_cache_update_op(g, c, h, mask, block=2048, interpret=True)
        ref_c, ref_h = dsag_update_ref(g, c, h, mask)
        np.testing.assert_allclose(np.asarray(new_c), np.asarray(ref_c), atol=1e-5)
        np.testing.assert_allclose(np.asarray(new_h), np.asarray(ref_h), atol=4e-5)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,h,s,d", [(1, 2, 256, 64), (2, 1, 384, 128), (1, 4, 128, 80)]
    )
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_reference(self, b, h, s, d, causal):
        if not causal and s % 128 != 0:
            pytest.skip("non-causal requires aligned sk")
        k1, k2, k3 = jax.random.split(jax.random.key(5), 3)
        q = jax.random.normal(k1, (b, h, s, d), jnp.float32)
        k = jax.random.normal(k2, (b, h, s, d), jnp.float32)
        v = jax.random.normal(k3, (b, h, s, d), jnp.float32)
        got = flash_attention_op(q, k, v, causal=causal, interpret=True)
        want = flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)

    def test_bf16_io(self):
        q = jax.random.normal(jax.random.key(6), (1, 2, 256, 64), jnp.bfloat16)
        k = jax.random.normal(jax.random.key(7), (1, 2, 256, 64), jnp.bfloat16)
        v = jax.random.normal(jax.random.key(8), (1, 2, 256, 64), jnp.bfloat16)
        got = flash_attention_op(q, k, v, causal=True, interpret=True)
        want = flash_attention_ref(q, k, v, causal=True)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
        )

    def test_long_context_streaming_blocks(self):
        """Many kv blocks: the online softmax must stay numerically exact."""
        q = jax.random.normal(jax.random.key(9), (1, 1, 128, 64))
        k = jax.random.normal(jax.random.key(10), (1, 1, 2048, 64))
        v = jax.random.normal(jax.random.key(11), (1, 1, 2048, 64))
        got = flash_attention_op(q, k, v, causal=False, interpret=True)
        want = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)

    @pytest.mark.parametrize(
        "sq,sk", [(128, 256), (128, 300), (64, 200), (96, 300), (100, 100)]
    )
    def test_causal_decode_shapes_match_reference(self, sq, sk):
        """sq != sk causal (decode-style): the mask must align bottom-right
        to the true lengths and exclude padded tail keys — the pre-fix
        kernel silently applied a top-left mask over padded buffers."""
        k1, k2, k3 = jax.random.split(jax.random.key(12), 3)
        q = jax.random.normal(k1, (1, 2, sq, 64), jnp.float32)
        k = jax.random.normal(k2, (1, 2, sk, 64), jnp.float32)
        v = jax.random.normal(k3, (1, 2, sk, 64), jnp.float32)
        got = flash_attention_op(q, k, v, causal=True, interpret=True)
        want = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)

    def test_causal_sq_gt_sk_raises(self):
        """Bottom-right alignment gives leading queries zero attendable
        keys (an empty softmax): reject instead of mis-masking."""
        q = jnp.zeros((1, 1, 256, 64))
        k = jnp.zeros((1, 1, 128, 64))
        with pytest.raises(ValueError, match="sq <= sk"):
            flash_attention_op(q, k, v=k, causal=True, interpret=True)

    def test_noncausal_unaligned_sk_raises(self):
        q = jnp.zeros((1, 1, 128, 64))
        k = jnp.zeros((1, 1, 200, 64))
        with pytest.raises(ValueError, match="sk % block_k"):
            flash_attention_op(q, k, v=k, causal=False, interpret=True)


class TestInterpretResolution:
    """S2 discipline: interpret=None is resolved from the *current* default
    backend at every call, never baked into a cached jit executable."""

    def test_default_resolved_per_call(self, monkeypatch):
        calls = []
        real = ops._interpret_default

        def recorder():
            calls.append(True)
            return real()

        monkeypatch.setattr(ops, "_interpret_default", recorder)
        x = jnp.ones((8, 4))
        v = jnp.ones((4, 2))
        ops.gram_matvec_op(x, v)
        ops.gram_matvec_op(x, v)
        assert len(calls) == 2, (
            "interpret default must be re-read on every call — a trace-time "
            "read would be cached with the first executable and go stale"
        )

    def test_explicit_interpret_skips_default(self, monkeypatch):
        monkeypatch.setattr(
            ops, "_interpret_default",
            lambda: (_ for _ in ()).throw(AssertionError("must not be read")),
        )
        x = jnp.ones((8, 4))
        v = jnp.ones((4, 2))
        out = ops.gram_matvec_op(x, v, interpret=True)
        assert out.shape == (4, 2)


def _jit_ref(fn, static_argnums):
    return jax.jit(fn, static_argnums=static_argnums)


class TestBlockSubTwins:
    """§3 engine twins: Pallas rows bit-identical to the jitted XLA form."""

    def _problem_data(self, n, d, seed):
        kx, ky = jax.random.split(jax.random.key(seed))
        X = jax.random.normal(kx, (n, d), jnp.float32)
        y = jnp.where(jax.random.uniform(ky, (n,)) < 0.5, 1.0, -1.0).astype(
            jnp.float32
        )
        return X, y

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(min_value=4, max_value=200),
        d=st.integers(min_value=1, max_value=32),
        g=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**20),
    )
    def test_logreg_bitexact_vs_jitted_ref(self, n, d, g, seed):
        with enable_x64():
            key = jax.random.key(seed)
            X, y = self._problem_data(n, d, seed)
            pad = int(min(1 << int(np.random.default_rng(seed).integers(0, 4)), n))
            k1, k2, k3 = jax.random.split(key, 3)
            starts = jax.random.randint(k1, (g,), 1, n - pad + 2).astype(jnp.int64)
            widths = jax.random.randint(k2, (g,), 1, pad + 1).astype(jnp.int64)
            Vb = jax.random.normal(k3, (g, d), jnp.float32)
            got = logreg_block_sub(X, y, Vb, starts, widths, pad, interpret=True)
            want = _jit_ref(ref.block_sub_logreg_ref, 5)(X, y, Vb, starts, widths, pad)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(min_value=4, max_value=200),
        d=st.integers(min_value=1, max_value=24),
        k=st.integers(min_value=1, max_value=4),
        g=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**20),
    )
    def test_pca_bitexact_vs_jitted_ref(self, n, d, k, g, seed):
        with enable_x64():
            key = jax.random.key(seed)
            X = (jax.random.uniform(key, (n, d)) < 0.3).astype(jnp.float32)
            pad = int(min(1 << int(np.random.default_rng(seed).integers(0, 4)), n))
            k1, k2, k3 = jax.random.split(key, 3)
            starts = jax.random.randint(k1, (g,), 1, n - pad + 2).astype(jnp.int64)
            widths = jax.random.randint(k2, (g,), 1, pad + 1).astype(jnp.int64)
            Vb = jax.random.normal(k3, (g, d, k), jnp.float32)
            got = pca_block_sub(X, Vb, starts, widths, pad, interpret=True)
            want = _jit_ref(ref.block_sub_pca_ref, 4)(X, Vb, starts, widths, pad)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_full_range_width(self):
        """pad == n (the gd/coded full-dataset bucket): off = 0, no roll."""
        with enable_x64():
            n, d = 50, 7
            X, y = self._problem_data(n, d, 0)
            Vb = jax.random.normal(jax.random.key(1), (2, d), jnp.float32)
            starts = jnp.ones((2,), jnp.int64)
            widths = jnp.full((2,), n, jnp.int64)
            got = logreg_block_sub(X, y, Vb, starts, widths, n, interpret=True)
            want = _jit_ref(ref.block_sub_logreg_ref, 5)(X, y, Vb, starts, widths, n)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_single_row_blocks(self):
        """pad == 1 (width-1 intervals): every window is one row."""
        with enable_x64():
            n, d = 20, 5
            X, y = self._problem_data(n, d, 3)
            Vb = jax.random.normal(jax.random.key(2), (4, d), jnp.float32)
            starts = jnp.asarray([1, 7, 19, 20], jnp.int64)
            widths = jnp.ones((4,), jnp.int64)
            got = logreg_block_sub(X, y, Vb, starts, widths, 1, interpret=True)
            want = _jit_ref(ref.block_sub_logreg_ref, 5)(X, y, Vb, starts, widths, 1)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bad_pad_width_rejected(self):
        with enable_x64():
            n, d = 20, 5
            X, y = self._problem_data(n, d, 4)
            Vb = jnp.zeros((1, d), jnp.float32)
            idx = jnp.ones((1,), jnp.int64)
            for bad in (0, n + 1):
                with pytest.raises(ValueError, match="pad_width"):
                    logreg_block_sub(X, y, Vb, idx, idx, bad, interpret=True)
            with pytest.raises(ValueError, match="pad_width"):
                pca_block_sub(X, jnp.zeros((1, d, 2)), idx, idx, 0, interpret=True)


class TestGridCacheUpdateTwin:
    """§5 engine twin: the fused rank walk bit-identical to the jitted ref."""

    def _random_case(self, seed, S, R, E, F):
        rng = np.random.default_rng(seed)
        valid_r = jnp.asarray(rng.random((S, R)) < 0.7)
        slot_r = jnp.asarray(rng.integers(0, E, (S, R)), jnp.int64)
        tag_r = jnp.asarray(rng.integers(0, 5, (S, R)), jnp.int64)
        vals_r = jnp.asarray(rng.normal(size=(S, R, F)))
        sums = jnp.asarray(rng.normal(size=(S, F)))
        values = jnp.asarray(rng.normal(size=(S, E, F)))
        iters = jnp.asarray(rng.integers(-1, 4, (S, E)), jnp.int64)
        covered = jnp.asarray(rng.integers(0, 30, (S,)), jnp.int64)
        rejected = jnp.asarray(rng.integers(0, 5, (S,)), jnp.int64)
        slot_width = jnp.asarray(rng.integers(1, 9, (E,)), jnp.int64)
        return (valid_r, slot_r, tag_r, vals_r, sums, values, iters,
                covered, rejected, slot_width)

    @settings(max_examples=10, deadline=None)
    @given(
        S=st.integers(min_value=1, max_value=4),
        R=st.integers(min_value=1, max_value=10),
        E=st.integers(min_value=1, max_value=8),
        F=st.integers(min_value=1, max_value=6),
        seed=st.integers(min_value=0, max_value=2**20),
    )
    def test_bitexact_vs_jitted_ref(self, S, R, E, F, seed):
        with enable_x64():
            args = self._random_case(seed, S, R, E, F)
            got = grid_cache_update(*args, interpret=True)
            want = jax.jit(ref.grid_cache_update_ref)(*args)
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_stale_dominated_events_rejected(self):
        """An event older than its slot's resident iteration must bump the
        rejected counter and leave the table untouched."""
        with enable_x64():
            S, R, E, F = 1, 1, 2, 3
            valid_r = jnp.ones((S, R), bool)
            slot_r = jnp.zeros((S, R), jnp.int64)
            tag_r = jnp.zeros((S, R), jnp.int64)  # tag 0 vs resident iter 5
            vals_r = jnp.ones((S, R, F), jnp.float64)
            sums = jnp.zeros((S, F), jnp.float64)
            values = jnp.full((S, E, F), 7.0, jnp.float64)
            iters = jnp.full((S, E), 5, jnp.int64)
            covered = jnp.zeros((S,), jnp.int64)
            rejected = jnp.zeros((S,), jnp.int64)
            slot_width = jnp.ones((E,), jnp.int64)
            out = grid_cache_update(
                valid_r, slot_r, tag_r, vals_r, sums, values, iters,
                covered, rejected, slot_width, interpret=True,
            )
            np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(values))
            np.testing.assert_array_equal(np.asarray(out[4]), np.ones((S,)))
