"""Checkpointing, compression, and fault-tolerance runtime tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.ft import DeadlineController, FailureDetector, elastic_remap_groups
from repro.optim.compression import (
    Quantized,
    dequantize,
    quantization_error_bound,
    quantize,
)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16), "step": jnp.int32(7)},
        }
        path = save_checkpoint(str(tmp_path), 7, tree)
        restored = restore_checkpoint(path, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_quantized_state_roundtrips(self, tmp_path):
        q = quantize(jnp.linspace(-3, 5, 512).reshape(2, 256))
        path = save_checkpoint(str(tmp_path), 1, {"cache": q})
        restored = restore_checkpoint(path, {"cache": q})
        np.testing.assert_array_equal(np.asarray(q.q), np.asarray(restored["cache"].q))

    def test_atomicity_and_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"w": jnp.ones((4,))}
        for step in (1, 2, 3, 4):
            mgr.save(step, tree, blocking=True)
        dirs = sorted(os.listdir(tmp_path))
        assert dirs == ["step_00000003", "step_00000004"]

    def test_async_save_then_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        tree = {"w": jnp.full((8,), 3.0)}
        mgr.save(11, tree, blocking=False)
        restored, step = mgr.restore_latest(tree)
        assert step == 11
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))

    def test_restore_missing_returns_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path / "empty"))
        restored, step = mgr.restore_latest({"w": jnp.ones(1)})
        assert restored is None and step == -1

    def test_shape_mismatch_raises(self, tmp_path):
        path = save_checkpoint(str(tmp_path), 0, {"w": jnp.ones((4,))})
        with pytest.raises(ValueError):
            restore_checkpoint(path, {"w": jnp.ones((5,))})


class TestCompression:
    @settings(max_examples=60, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=1500),
        scale=st.floats(min_value=1e-6, max_value=1e6),
        block=st.sampled_from([64, 256, 1024]),
    )
    def test_roundtrip_error_bound(self, n, scale, block):
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
        q = quantize(x, block=block)
        back = dequantize(q, jnp.float32)
        bound = np.repeat(np.asarray(quantization_error_bound(x, block)), block)[: len(x)]
        # bf16 scale storage adds ~0.4% relative slack on top of the bound
        assert (np.abs(np.asarray(back) - np.asarray(x)) <= bound + 0.01 * np.abs(np.asarray(x)) + 1e-6).all()

    def test_zeros_roundtrip_exactly(self):
        x = jnp.zeros((3, 512))
        np.testing.assert_array_equal(np.asarray(dequantize(quantize(x))), 0.0)

    def test_compression_ratio(self):
        x = jnp.ones((4, 4096), jnp.float32)
        q = quantize(x, block=256)
        raw = x.size * 4
        packed = q.q.size + q.scale.size * 2
        assert packed < raw / 3.5


class TestFailureRuntime:
    def test_deadline_masks_straggler(self):
        ctl = DeadlineController(num_groups=4, w=3, margin=0.02)
        rng = np.random.default_rng(0)
        for step in range(30):
            lat = np.array([1.0, 1.05, 0.95, 1.0]) + 0.01 * rng.random(4)
            lat[3] = 3.0 if step >= 10 else lat[3]  # group 3 starts straggling
            mask, flush = ctl.step_masks(lat, step)
            if step >= 14:  # a few steps for the order-stat deadline to adapt
                assert not mask[3]
                assert mask[:3].all()

    def test_flush_gated_on_completion(self):
        """A straggler's flush fires on the step its completion time falls
        in — NOT unconditionally one step after the miss (a 3.5x straggler
        must not 'land' while it is still running)."""
        ctl = DeadlineController(num_groups=2, w=1, margin=0.0)
        m, f = ctl.step_masks(np.array([1.0, 3.5]), step=0)
        assert m.tolist() == [True, False] and not f.any()
        # virtual time is 1.0; the straggler finishes at 3.5 — still busy,
        # so the next two steps must not flush it
        m, f = ctl.step_masks(np.array([1.0, 1.0]), step=1)
        assert m[0] and not m[1] and not f[1]
        m, f = ctl.step_masks(np.array([1.0, 1.0]), step=2)
        assert not f[1]
        # step 3 spans virtual time 3.0 -> 4.0: the 3.5 completion lands now
        m, f = ctl.step_masks(np.array([1.0, 1.0]), step=3)
        assert f[1]

    def test_oldest_inflight_survives_consecutive_misses(self):
        """Consecutive misses must not overwrite the oldest in-flight step:
        the straggler's first task (the one Tier-1 keeps as its oldest
        pending gradient) is the one whose completion triggers the flush;
        later assignments just overwrite the length-1 FILO queue."""
        ctl = DeadlineController(num_groups=2, w=1, margin=0.0)
        # group 1's first task takes 10 virtual seconds; each later step it
        # is still busy, so it misses steps 0..9 without starting anything
        m, f = ctl.step_masks(np.array([1.0, 10.0]), step=0)
        assert not m[1]
        flushed_at = None
        for step in range(1, 12):
            m, f = ctl.step_masks(np.array([1.0, 1.0]), step=step)
            if f[1]:
                flushed_at = step
                break
            assert not m[1]  # still straggling: no fresh result either
        # completion at t=10 falls in step 9's window (virtual 9 -> 10);
        # exactly one flush, at the completion step, not at step 1
        assert flushed_at == 9

    def test_sag_mode_never_flushes(self):
        """accepts_stale=False (SAG): stale completions are dropped, so no
        flush bits ever fire; collection stops at the w-th fresh result."""
        ctl = DeadlineController(num_groups=2, w=1, margin=0.0, accepts_stale=False)
        ctl.step_masks(np.array([1.0, 3.5]), step=0)
        for step in range(1, 8):
            m, f = ctl.step_masks(np.array([1.0, 1.0]), step=step)
            assert not f.any()

    def test_deadline_draws_vary_across_calls(self):
        """The Monte-Carlo order statistic must use a persistent RNG — a
        reseeded generator returns byte-identical draws every call, hiding
        profile drift."""
        ctl = DeadlineController(num_groups=4, w=3, margin=0.02)
        rng = np.random.default_rng(7)
        for g in range(4):
            for _ in range(8):
                ctl.record(g, 1.0 + 0.2 * rng.random())
        d1 = ctl.deadline()
        d2 = ctl.deadline()  # same profile, fresh draws -> different estimate
        assert d1 != d2
        assert abs(d1 - d2) < 0.2 * d1  # but the estimator is stable

    def test_failure_detector(self):
        det = FailureDetector(num_groups=3, max_misses=3)
        for _ in range(3):
            det.observe(np.array([True, True, False]))
        assert det.failed.tolist() == [False, False, True]
        det.rejoin(2)
        assert not det.failed[2]

    def test_elastic_identity_preserves_all_cache(self):
        k_new, survivors = elastic_remap_groups(1000, p_old=4, p_new=4, k_old=2)
        assert 1 <= k_new <= 4
        assert survivors.all()  # unchanged geometry: every slot carries over

    def test_elastic_grow_requires_exact_range_match(self):
        k_new, survivors = elastic_remap_groups(1000, p_old=4, p_new=5, k_old=2)
        assert 1 <= k_new <= 5
        # old ranges (1,250)(251,500)(501,750)(751,1000); new (1,200)
        # (201,400)(401,600)(601,800)(801,1000).  Group 0's START aligns
        # (sample 1) but its range shrank — carrying the old (1,250) cache
        # entry over a (1,200) group would leave H covering samples
        # 201-250 twice once the new layout refills.  No survivors.
        assert not survivors.any()

    def test_elastic_shrink_requires_exact_range_match(self):
        k_new, survivors = elastic_remap_groups(1024, p_old=8, p_new=4, k_old=1)
        # halving: every NEW group's start coincides with an old boundary,
        # but each new range spans two old groups — a carried-over entry
        # would cover only half its group's samples, silently biasing H
        # (this was the start-only-matching bug)
        assert not survivors.any()
