"""Checkpointing, compression, and fault-tolerance runtime tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.ft import DeadlineController, FailureDetector, elastic_remap_groups
from repro.optim.compression import (
    Quantized,
    dequantize,
    quantization_error_bound,
    quantize,
)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16), "step": jnp.int32(7)},
        }
        path = save_checkpoint(str(tmp_path), 7, tree)
        restored = restore_checkpoint(path, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_quantized_state_roundtrips(self, tmp_path):
        q = quantize(jnp.linspace(-3, 5, 512).reshape(2, 256))
        path = save_checkpoint(str(tmp_path), 1, {"cache": q})
        restored = restore_checkpoint(path, {"cache": q})
        np.testing.assert_array_equal(np.asarray(q.q), np.asarray(restored["cache"].q))

    def test_atomicity_and_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"w": jnp.ones((4,))}
        for step in (1, 2, 3, 4):
            mgr.save(step, tree, blocking=True)
        dirs = sorted(os.listdir(tmp_path))
        assert dirs == ["step_00000003", "step_00000004"]

    def test_async_save_then_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        tree = {"w": jnp.full((8,), 3.0)}
        mgr.save(11, tree, blocking=False)
        restored, step = mgr.restore_latest(tree)
        assert step == 11
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))

    def test_restore_missing_returns_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path / "empty"))
        restored, step = mgr.restore_latest({"w": jnp.ones(1)})
        assert restored is None and step == -1

    def test_shape_mismatch_raises(self, tmp_path):
        path = save_checkpoint(str(tmp_path), 0, {"w": jnp.ones((4,))})
        with pytest.raises(ValueError):
            restore_checkpoint(path, {"w": jnp.ones((5,))})


class TestCompression:
    @settings(max_examples=60, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=1500),
        scale=st.floats(min_value=1e-6, max_value=1e6),
        block=st.sampled_from([64, 256, 1024]),
    )
    def test_roundtrip_error_bound(self, n, scale, block):
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
        q = quantize(x, block=block)
        back = dequantize(q, jnp.float32)
        bound = np.repeat(np.asarray(quantization_error_bound(x, block)), block)[: len(x)]
        # bf16 scale storage adds ~0.4% relative slack on top of the bound
        assert (np.abs(np.asarray(back) - np.asarray(x)) <= bound + 0.01 * np.abs(np.asarray(x)) + 1e-6).all()

    def test_zeros_roundtrip_exactly(self):
        x = jnp.zeros((3, 512))
        np.testing.assert_array_equal(np.asarray(dequantize(quantize(x))), 0.0)

    def test_compression_ratio(self):
        x = jnp.ones((4, 4096), jnp.float32)
        q = quantize(x, block=256)
        raw = x.size * 4
        packed = q.q.size + q.scale.size * 2
        assert packed < raw / 3.5


class TestFailureRuntime:
    def test_deadline_masks_straggler(self):
        ctl = DeadlineController(num_groups=4, w=3, margin=0.02)
        rng = np.random.default_rng(0)
        for step in range(30):
            lat = np.array([1.0, 1.05, 0.95, 1.0]) + 0.01 * rng.random(4)
            lat[3] = 3.0 if step >= 10 else lat[3]  # group 3 starts straggling
            mask, flush = ctl.step_masks(lat, step)
            if step >= 14:  # a few steps for the order-stat deadline to adapt
                assert not mask[3]
                assert mask[:3].all()

    def test_flush_follows_miss(self):
        ctl = DeadlineController(num_groups=2, w=1, margin=0.0)
        for _step in range(10):
            ctl.record(0, 1.0)
            ctl.record(1, 1.0)
        m1, f1 = ctl.step_masks(np.array([1.0, 50.0]), step=100)
        assert not m1[1] and not f1[1]
        m2, f2 = ctl.step_masks(np.array([1.0, 1.0]), step=101)
        assert f2[1]  # the late result lands on the next step

    def test_failure_detector(self):
        det = FailureDetector(num_groups=3, max_misses=3)
        for _ in range(3):
            det.observe(np.array([True, True, False]))
        assert det.failed.tolist() == [False, False, True]
        det.rejoin(2)
        assert not det.failed[2]

    def test_elastic_remap_alignment(self):
        k_new, survivors = elastic_remap_groups(1000, p_old=4, p_new=5, k_old=2)
        assert 1 <= k_new <= 5
        # old boundaries at 1, 251, 501, 751; new at 1, 201, 401, 601, 801
        assert survivors[0]  # group starting at sample 1 always survives
        assert survivors.sum() >= 1

    def test_elastic_shrink_preserves_some_cache(self):
        k_new, survivors = elastic_remap_groups(1024, p_old=8, p_new=4, k_old=1)
        # halving: every new boundary coincides with an old one
        assert survivors.all()
