"""Tests for the fused ``jax.lax.scan`` convergence engine and the kernel
properties it rests on.

The load-bearing chain: problems expose one set of JAX kernels
(:class:`~repro.core.problems.FusedKernels`); the scalar simulator, the
batched host engine, and the fused scan all delegate to them; block
subgradients are evaluated on the static
:func:`~repro.core.problems.width_bucket` ladder so a given (iterate,
interval) always runs at the same static shape.  These tests pin (a) the
two empirical CPU properties the delegation needs — batch-size invariance
and mask-multiply neutrality — and (b) end-to-end bit-exactness of
scan == host == scalar, including the §5.1 margin and the §6
load-balancing routing.
"""

import numpy as np
import pytest

from repro.cluster.simulator import MethodConfig, TraceLatencySource, TrainingSimulator
from repro.core.problems import (
    LogisticRegressionProblem,
    PCAProblem,
    make_genomics_like_matrix,
    make_higgs_like,
    width_bucket,
)
from repro.experiments.convergence import (
    PAPER_SCALE_PCA,
    paper_scale_pca_sweep,
    run_convergence_batch,
)
from repro.experiments.fused import run_convergence_scan
from repro.experiments.results import convergence_ordering
from repro.latency.model import make_heterogeneous_cluster, sample_fleet


@pytest.fixture(scope="module")
def logreg_small():
    X, y = make_higgs_like(240, seed=0)
    return LogisticRegressionProblem(X=X, y=y)


@pytest.fixture(scope="module")
def pca_small():
    return PCAProblem(X=make_genomics_like_matrix(240, 48, seed=0), k=3)


def small_fleet(n_workers=6, n_scenarios=3, horizon=25, seed=3):
    cluster = make_heterogeneous_cluster(
        n_workers, seed=seed, burst_rate=0.0, comp_range=(1.1e-3, 2.5e-3)
    )
    traces = sample_fleet(
        cluster,
        n_scenarios,
        horizon,
        burst_rate=3.0,
        burst_factor_mean=3.0,
        burst_duration_mean=5e-3,
        seed=seed + 8,
    )
    return cluster, traces


def assert_results_equal(a, b):
    np.testing.assert_array_equal(a.times, b.times)
    np.testing.assert_array_equal(a.suboptimality, b.suboptimality)
    np.testing.assert_array_equal(a.fresh_counts, b.fresh_counts)
    np.testing.assert_array_equal(a.per_worker_latency, b.per_worker_latency)
    np.testing.assert_array_equal(a.evictions, b.evictions)
    np.testing.assert_array_equal(a.rejected_stale, b.rejected_stale)


class TestKernelProperties:
    def test_width_bucket_ladder(self):
        assert width_bucket(1, 100) == 1
        assert width_bucket(5, 100) == 8
        assert width_bucket(16, 100) == 16
        assert width_bucket(17, 100) == 32
        # the full range keeps its exact width (no 2x gather for gd/coded)
        assert width_bucket(100, 100) == 100

    @pytest.mark.parametrize("which", ["logreg", "pca"])
    def test_masked_matches_equal_width_kernel(
        self, which, logreg_small, pca_small
    ):
        """subgradient_blocks_masked rows == subgradient_blocks rows, even
        at widths where the padded reduction shape differs from the raw
        one — the bucket ladder routes both calls to the same shape."""
        prob = logreg_small if which == "logreg" else pca_small
        V = prob.init(0) + (0.01 if which == "logreg" else 0.0)
        for m in (5, 13, 17, 40):
            starts = np.array([1, 41, 81], dtype=np.int64)
            stops = starts + m - 1
            Vs = np.repeat(V[None], 3, axis=0)
            a = prob.subgradient_blocks(Vs, starts, stops)
            b = prob.subgradient_blocks_masked(Vs, starts, stops)
            np.testing.assert_array_equal(a, b)

    @pytest.mark.parametrize("which", ["logreg", "pca"])
    def test_mixed_width_masked_rows_match_scalar(
        self, which, logreg_small, pca_small
    ):
        prob = logreg_small if which == "logreg" else pca_small
        V = prob.init(0) + (0.01 if which == "logreg" else 0.0)
        starts = np.array([1, 31, 61, 101], dtype=np.int64)
        stops = np.array([13, 47, 77, 240], dtype=np.int64)  # widths 13/17/17/140
        out = prob.subgradient_blocks_masked(
            np.repeat(V[None], 4, axis=0), starts, stops
        )
        for g in range(4):
            np.testing.assert_array_equal(
                out[g], prob.subgradient(V, int(starts[g]), int(stops[g]))
            )

    @pytest.mark.parametrize("which", ["logreg", "pca"])
    def test_suboptimality_batch_invariant(self, which, logreg_small, pca_small):
        """Row s of the [S] kernel equals the S = 1 call bit-for-bit (the
        scalar simulator delegates at S = 1, so equivalence needs this)."""
        prob = logreg_small if which == "logreg" else pca_small
        rng = np.random.default_rng(0)
        Vs = np.stack(
            [prob.init(0) + rng.normal(scale=0.01, size=prob.init(0).shape)
             .astype(np.float32) for _ in range(4)]
        )
        batch = prob.suboptimality_batch(Vs)
        for s in range(4):
            assert batch[s] == prob.suboptimality(Vs[s])

    def test_pca_projection_batch_invariant(self, pca_small):
        rng = np.random.default_rng(1)
        Vs = rng.normal(size=(5, pca_small.dim, pca_small.k)).astype(np.float32)
        batch = pca_small.project_batch(Vs)
        for s in range(5):
            np.testing.assert_array_equal(batch[s], pca_small.project(Vs[s]))


class TestScanVsHost:
    """The tentpole gate: the lax.scan engine reproduces the host batched
    engine (and therefore the scalar simulator) bit for bit."""

    @pytest.mark.parametrize(
        "name,w",
        [("dsag", 2), ("sag", 6), ("sgd", 3), ("gd", 0), ("coded", 0)],
    )
    def test_logreg_methods(self, logreg_small, name, w):
        cluster, traces = small_fleet()
        cfg = MethodConfig(name=name, w=w, eta=0.25, subpartitions=3)
        host = run_convergence_batch(
            logreg_small, traces, cfg, 25, eval_every=2, seed=0, engine="host"
        )
        scan = run_convergence_batch(
            logreg_small, traces, cfg, 25, eval_every=2, seed=0, engine="scan"
        )
        assert_results_equal(host, scan)

    @pytest.mark.parametrize("name,w", [("dsag", 2), ("sag", 6)])
    def test_pca_methods(self, pca_small, name, w):
        cluster, traces = small_fleet()
        cfg = MethodConfig(name=name, w=w, eta=0.9, subpartitions=3)
        host = run_convergence_batch(
            pca_small, traces, cfg, 25, eval_every=2, seed=0, engine="host"
        )
        scan = run_convergence_batch(
            pca_small, traces, cfg, 25, eval_every=2, seed=0, engine="scan"
        )
        assert_results_equal(host, scan)

    def test_margin_case(self, logreg_small):
        """§5.1 margin: post-w collection window resolved inside the scan."""
        cluster, traces = small_fleet(horizon=30)
        cfg = MethodConfig(name="dsag", w=2, eta=0.25, subpartitions=3, margin=0.25)
        host = run_convergence_batch(
            logreg_small, traces, cfg, 30, seed=0, engine="host"
        )
        scan = run_convergence_batch(
            logreg_small, traces, cfg, 30, seed=0, engine="scan"
        )
        assert (host.fresh_counts > 2).any()
        assert_results_equal(host, scan)

    def test_scan_matches_scalar_simulator(self, logreg_small):
        """Direct scan-vs-scalar check (not only via the host engine)."""
        cluster, traces = small_fleet()
        cfg = MethodConfig(name="dsag", w=2, eta=0.25, subpartitions=3)
        scan = run_convergence_scan(logreg_small, traces, cfg, 25, eval_every=2, seed=0)
        for s in range(traces.num_scenarios):
            sim = TrainingSimulator(
                logreg_small,
                cluster,
                cfg,
                eval_every=2,
                seed=0,
                latency_source=TraceLatencySource(traces, s),
            )
            h = sim.run(25)
            np.testing.assert_array_equal(h.times, scan.times[s])
            np.testing.assert_array_equal(h.suboptimality, scan.suboptimality[s])
            np.testing.assert_array_equal(
                h.per_worker_latency, scan.per_worker_latency[s]
            )
            assert h.rejected_stale == scan.rejected_stale[s]

    def test_load_balance_runs_in_scan(self, logreg_small):
        """§6 configs now run inside the scan: engine='auto' keeps them on
        the fused path and the result stays bit-exact vs the scalar
        simulator on the same traces (the full cross-engine §6 suite lives
        in tests/test_lb_scan.py)."""
        cluster, traces = small_fleet(horizon=30)
        cfg = MethodConfig(
            name="dsag", w=2, eta=0.25, subpartitions=3,
            load_balance=True, lb_startup_delay=0.005, lb_interval=0.01,
        )
        scan = run_convergence_scan(logreg_small, traces, cfg, 30, seed=0)
        auto = run_convergence_batch(logreg_small, traces, cfg, 30, seed=0)
        np.testing.assert_array_equal(scan.times, auto.times)
        sim = TrainingSimulator(
            logreg_small, cluster, cfg, seed=0,
            latency_source=TraceLatencySource(traces, 0),
        )
        h = sim.run(30)
        np.testing.assert_array_equal(h.times, auto.times[0])
        np.testing.assert_array_equal(h.suboptimality, auto.suboptimality[0])
        assert list(h.repartition_events) == list(auto.repartition_events[0])

    def test_unknown_engine_rejected(self, logreg_small):
        cluster, traces = small_fleet()
        cfg = MethodConfig(name="dsag", w=2, subpartitions=3)
        with pytest.raises(ValueError, match="unknown engine"):
            run_convergence_batch(logreg_small, traces, cfg, 5, engine="gpu")

    def test_float64_problem_matrix(self):
        """A float64 data matrix must not break the scan carry (the
        in-flight value buffer dtype follows the kernels' value dtype)."""
        X = make_genomics_like_matrix(240, 48, seed=0).astype(np.float64)
        prob = PCAProblem(X=X, k=3)
        cluster, traces = small_fleet()
        cfg = MethodConfig(name="dsag", w=2, eta=0.9, subpartitions=3)
        host = run_convergence_batch(prob, traces, cfg, 15, seed=0, engine="host")
        scan = run_convergence_batch(prob, traces, cfg, 15, seed=0, engine="scan")
        assert_results_equal(host, scan)


@pytest.mark.slow
class TestPaperScalePCA:
    def test_paper_scale_smoke(self):
        """Shrunk paper-scale PCA run (n=12.5k): the fused engine handles
        the genomics-like workload end to end and DSAG reaches the
        calibrated gap before SAG and the coded bound."""
        out, gap = paper_scale_pca_sweep(scale=0.25, seed=0)
        assert out.problem.num_samples == PAPER_SCALE_PCA["n_rows"] // 4
        for res in out.results.values():
            assert np.isfinite(res.times).all()
        # at 1/4 scale the full gap ladder is not guaranteed; use a looser
        # mid-range gap for the ordering check
        o = convergence_ordering(out, 1e-3)
        assert o["dsag_fastest_to_gap"] == 1.0, o
