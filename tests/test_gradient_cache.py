"""Unit + property tests for the §5 gradient cache."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.gradient_cache import GradientCache


def make_cache(n=100, dim=4):
    return GradientCache(n, np.zeros(dim))


class TestBasics:
    def test_insert_and_sum(self):
        c = make_cache()
        v1 = np.ones(4)
        assert c.insert(1, 50, 0, v1)
        np.testing.assert_allclose(c.sum, v1)
        assert c.coverage == 0.5
        v2 = 2 * np.ones(4)
        assert c.insert(51, 100, 0, v2)
        np.testing.assert_allclose(c.sum, v1 + v2)
        assert c.coverage == 1.0
        c.check_invariants()

    def test_exact_match_inplace_update(self):
        """Paper remark: same-interval fresh result degrades to the SAG update."""
        c = make_cache()
        c.insert(1, 50, 0, np.ones(4))
        assert c.insert(1, 50, 3, 5 * np.ones(4))
        np.testing.assert_allclose(c.sum, 5 * np.ones(4))
        assert c.num_entries == 1
        assert c.evictions == 0  # in-place, not an eviction
        c.check_invariants()

    def test_staleness_dominance(self):
        """A received subgradient older than any overlapping entry is dropped."""
        c = make_cache()
        c.insert(1, 50, 5, np.ones(4))
        assert not c.insert(20, 60, 4, 7 * np.ones(4))  # t=4 < cached t=5
        assert not c.insert(20, 60, 5, 7 * np.ones(4))  # ties lose too (t' >= t)
        np.testing.assert_allclose(c.sum, np.ones(4))
        assert c.rejected_stale == 2
        c.check_invariants()

    def test_overlap_eviction_example1(self):
        """Paper Example 1: repartitioning 2->3 partitions on worker 1."""
        c = GradientCache(20, np.zeros(2))
        c.insert(1, 5, 0, np.array([1.0, 0.0]))
        c.insert(6, 10, 0, np.array([2.0, 0.0]))
        c.insert(11, 15, 0, np.array([3.0, 0.0]))
        c.insert(16, 20, 0, np.array([4.0, 0.0]))
        assert c.coverage == 1.0
        # worker 1 re-partitioned to [1:3],[4:6],[7:10]; sends gradient on [4:6]
        assert c.insert(4, 6, 1, np.array([10.0, 0.0]))
        # both [1:5] and [6:10] must be evicted
        assert c.evictions == 2
        np.testing.assert_allclose(c.sum, np.array([10.0 + 3 + 4, 0.0]))
        assert c.coverage == (3 + 5 + 5) / 20
        c.check_invariants()

    def test_newer_replaces_with_boundary_change(self):
        c = make_cache()
        c.insert(1, 50, 0, np.ones(4))
        assert c.insert(40, 70, 2, 3 * np.ones(4))
        np.testing.assert_allclose(c.sum, 3 * np.ones(4))
        assert c.num_entries == 1
        assert c.coverage == 31 / 100
        c.check_invariants()

    def test_bounds_validation(self):
        c = make_cache()
        with pytest.raises(ValueError):
            c.insert(0, 10, 0, np.zeros(4))
        with pytest.raises(ValueError):
            c.insert(5, 101, 0, np.zeros(4))
        with pytest.raises(ValueError):
            c.insert(10, 5, 0, np.zeros(4))


# ---------------------------------------------------------------------------
# Property-based: arbitrary insert sequences keep all invariants
# ---------------------------------------------------------------------------

interval_strategy = st.tuples(
    st.integers(min_value=1, max_value=64), st.integers(min_value=1, max_value=64)
).map(lambda ab: (min(ab), max(ab)))


@settings(max_examples=200, deadline=None)
@given(
    ops=st.lists(
        st.tuples(interval_strategy, st.integers(min_value=0, max_value=20)),
        min_size=1,
        max_size=60,
    )
)
def test_cache_invariants_hold_under_arbitrary_inserts(ops):
    c = GradientCache(64, np.zeros(3))
    rng = np.random.default_rng(0)
    for (start, stop), t in ops:
        c.insert(start, stop, t, rng.normal(size=3))
    c.check_invariants()
    # intervals sorted & disjoint, coverage in [0, 1]
    assert 0.0 <= c.coverage <= 1.0


@settings(max_examples=100, deadline=None)
@given(
    ops=st.lists(
        st.tuples(interval_strategy, st.integers(min_value=0, max_value=20)),
        min_size=1,
        max_size=40,
    )
)
def test_cache_accepts_only_strictly_fresher_overlaps(ops):
    """After any insert sequence, every cached entry's iteration must not be
    dominated by a later-rejected fresher insert (acceptance monotonicity)."""
    c = GradientCache(64, np.zeros(1))
    for (start, stop), t in ops:
        before = {(e.start, e.stop): e.iteration for e in c.entries()}
        accepted = c.insert(start, stop, t, np.ones(1))
        if accepted:
            # all overlapping entries must have been strictly older
            for (s, e), it in before.items():
                if not (e < start or stop < s):
                    assert it < t


# ---------------------------------------------------------------------------
# Slot-universe tiling invariants (the fused engine's tiled active-slot
# cache replaces the precomputed dense overlap tables with runtime
# interval arithmetic against a small per-worker active set; these
# properties are what make that substitution sound).
# ---------------------------------------------------------------------------

def _universe_from(n_locals, ladder):
    from repro.core.gradient_cache import build_slot_universe

    n = np.asarray(n_locals, dtype=np.int64)
    stops = np.cumsum(n)
    starts = stops - n + 1
    ladder = tuple(sorted(set(ladder)))
    return build_slot_universe(starts, stops, ladder), ladder


def _worker_slots(universe, i):
    tbl = universe.slot_table[i]
    return np.unique(tbl[tbl >= 0])


@settings(max_examples=60, deadline=None)
@given(
    n_locals=st.lists(st.integers(min_value=1, max_value=12),
                      min_size=1, max_size=4),
    ladder=st.lists(st.integers(min_value=1, max_value=8),
                    min_size=1, max_size=4),
)
def test_universe_without_overlaps_matches_dense(n_locals, ladder):
    """``with_overlaps=False`` must differ from the dense build only in
    the ``overlap_idx`` placeholder, and the dense ``overlap_idx`` must
    equal brute-force interval arithmetic — the invariant that lets the
    tiled cache compute overlaps at runtime instead."""
    from repro.core.gradient_cache import build_slot_universe

    dense, lad = _universe_from(n_locals, ladder)
    n = np.asarray(n_locals, dtype=np.int64)
    stops = np.cumsum(n)
    starts = stops - n + 1
    lean = build_slot_universe(starts, stops, lad, with_overlaps=False)
    np.testing.assert_array_equal(dense.starts, lean.starts)
    np.testing.assert_array_equal(dense.stops, lean.stops)
    np.testing.assert_array_equal(dense.widths, lean.widths)
    np.testing.assert_array_equal(dense.slot_table, lean.slot_table)
    assert np.all(lean.overlap_idx == -1)
    for i in range(len(n_locals)):
        sl = _worker_slots(dense, i)
        for e in sl:
            listed = dense.overlap_idx[e]
            listed = set(listed[listed >= 0].tolist())
            brute = {
                int(o) for o in sl if o != e
                and dense.starts[o] <= dense.stops[e]
                and dense.starts[e] <= dense.stops[o]
            }
            assert listed == brute


@settings(max_examples=60, deadline=None)
@given(
    n_locals=st.lists(st.integers(min_value=1, max_value=12),
                      min_size=1, max_size=4),
    ladder=st.lists(st.integers(min_value=1, max_value=8),
                    min_size=1, max_size=4),
)
def test_active_slot_capacity_is_max_disjoint_subset(n_locals, ladder):
    """The greedy capacity must equal the true optimum (max cardinality
    of a pairwise-disjoint subset), computed here by an independent DP."""
    from repro.core.gradient_cache import active_slot_capacity

    universe, _ = _universe_from(n_locals, ladder)
    caps = active_slot_capacity(universe)
    for i in range(len(n_locals)):
        sl = _worker_slots(universe, i)
        iv = sorted(
            (int(universe.stops[e]), int(universe.starts[e])) for e in sl
        )
        # classic interval-scheduling DP over intervals sorted by stop
        best = [0] * (len(iv) + 1)
        for j, (b, a) in enumerate(iv, start=1):
            compat = 0
            for k in range(j - 1, 0, -1):
                if iv[k - 1][0] < a:
                    compat = k
                    break
            best[j] = max(best[j - 1], best[compat] + 1)
        assert caps[i] == best[len(iv)]


@settings(max_examples=60, deadline=None)
@given(
    n_locals=st.lists(st.integers(min_value=1, max_value=12),
                      min_size=1, max_size=4),
    ladder=st.lists(st.integers(min_value=1, max_value=8),
                    min_size=1, max_size=4),
    picks=st.lists(st.tuples(st.integers(min_value=0, max_value=10**6),
                             st.integers(min_value=0, max_value=10**6)),
                   min_size=1, max_size=40),
)
def test_tiled_active_set_never_exceeds_capacity(n_locals, ladder, picks):
    """Replay the tiled cache's insert discipline (evict overlapping
    entries, then insert) with arbitrary slot sequences: the per-worker
    active set must stay pairwise disjoint and never exceed the
    ``active_slot_capacity`` bound — the guarantee that sizes the tiled
    entry tables and makes a free entry always available at insert time."""
    from repro.core.gradient_cache import active_slot_capacity

    universe, _ = _universe_from(n_locals, ladder)
    caps = active_slot_capacity(universe)
    active = {i: set() for i in range(len(n_locals))}
    for wi, si in picks:
        i = wi % len(n_locals)
        sl = _worker_slots(universe, i)
        e = int(sl[si % sl.size])
        lo, hi = int(universe.starts[e]), int(universe.stops[e])
        evicted = {
            o for o in active[i]
            if universe.starts[o] <= hi and lo <= universe.stops[o]
        }
        active[i] -= evicted
        active[i].add(e)
        assert len(active[i]) <= caps[i]
        ivs = sorted(
            (int(universe.starts[o]), int(universe.stops[o]))
            for o in active[i]
        )
        for (a1, b1), (a2, _) in zip(ivs, ivs[1:]):
            assert b1 < a2  # pairwise disjoint
