"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs (full configs are exercised only via
the dry-run)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cell_is_runnable, get_config, get_smoke_config
from repro.models import build_model
from repro.models.layers import round_up


def make_batch(cfg, b=2, s=16, key=0):
    toks = jax.random.randint(jax.random.key(key), (b, s), 0, cfg.vocab_size)
    if cfg.family == "enc_dec":
        return {
            "tokens": toks,
            "audio_embed": 0.1
            * jax.random.normal(
                jax.random.key(key + 1), (b, cfg.encoder_seq, cfg.d_model)
            ),
        }
    if cfg.family == "vlm":
        return {
            "tokens": toks,
            "image_embed": 0.1
            * jax.random.normal(
                jax.random.key(key + 1), (b, cfg.num_image_tokens, cfg.d_model)
            ),
        }
    return {"tokens": toks}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(lambda p: model.train_loss(p, batch))(params)
    assert jnp.isfinite(loss), f"{arch}: loss={loss}"
    # roughly ln(vocab) at random init
    assert 1.0 < float(loss) < 20.0
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert jnp.isfinite(gnorm) and float(gnorm) > 0.0, f"{arch}: grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 12
    batch = make_batch(cfg, b=b, s=s)
    total = s + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    logits, cache = model.prefill(params, batch, cache_len=total + 4)
    v_pad = round_up(cfg.vocab_size, 256)
    assert logits.shape == (b, 1, v_pad)
    nxt = jnp.argmax(logits[:, -1], -1).reshape(b, 1)
    logits2, cache2 = model.decode_step(params, nxt, cache, jnp.int32(total))
    assert logits2.shape == (b, 1, v_pad)
    assert jnp.isfinite(logits2.astype(jnp.float32)).all()
    # cache trees keep their structure/shapes across steps
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
    for a, b_ in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)):
        assert a.shape == b_.shape


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """Greedy decode of token s from an (s-1)-token cache must reproduce the
    teacher-forced logits of the full s-token prefill (fp32)."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 12
    batch = make_batch(cfg, b=b, s=s, key=5)
    toks = batch["tokens"]
    total = s + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    logits_pf, _ = model.prefill(params, batch, cache_len=total + 4)
    batch_m1 = dict(batch, tokens=toks[:, :-1])
    _, cache_m1 = model.prefill(params, batch_m1, cache_len=total + 4)
    logits_dec, _ = model.decode_step(params, toks[:, -1:], cache_m1, jnp.int32(total - 1))
    np.testing.assert_allclose(
        np.asarray(logits_pf[:, -1]), np.asarray(logits_dec[:, -1]), atol=5e-4, rtol=1e-3
    )


def test_full_configs_construct_and_count_params():
    """Full production configs must build abstract params with plausible
    parameter counts (no allocation)."""
    expected = {
        "starcoder2-15b": (14e9, 18e9),
        "qwen1.5-0.5b": (0.4e9, 0.8e9),
        "qwen2-7b": (6.5e9, 9e9),
        "qwen1.5-32b": (30e9, 37e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "grok-1-314b": (290e9, 340e9),
        "pixtral-12b": (11e9, 14e9),
        "zamba2-2.7b": (2.4e9, 3.4e9),
        "whisper-base": (0.05e9, 0.2e9),
    }
    for arch in ARCHS:
        cfg = get_config(arch)
        model = build_model(cfg)
        n = model.num_params()
        lo, hi = expected[arch]
        assert lo <= n <= hi, f"{arch}: {n:,} params outside [{lo:.2g}, {hi:.2g}]"


def test_long_context_cell_rules():
    for arch in ARCHS:
        cfg = get_config(arch)
        runnable = cell_is_runnable(cfg, SHAPES["long_500k"])
        assert runnable == (arch in ("mamba2-370m", "zamba2-2.7b"))
