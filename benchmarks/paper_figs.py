"""Benchmarks reproducing the paper's figures/tables (Tier 3, simulated
cluster + real JAX compute).  Each function mirrors one paper artifact and
reports a quantitative 'derived' verdict."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import record, time_fn
from repro.cluster.simulator import MethodConfig, TrainingSimulator
from repro.core.problems import (
    LogisticRegressionProblem,
    PCAProblem,
    make_genomics_like_matrix,
    make_higgs_like,
)
from repro.latency.event_sim import naive_iteration_times, simulate_iteration_times
from repro.latency.model import (
    ClusterLatencyModel,
    GammaParams,
    WorkerLatencyModel,
    clear_slowdowns,
    make_heterogeneous_cluster,
    make_paper_artificial_cluster,
)
from repro.latency.order_stats import (
    empirical_order_statistic,
    predict_order_statistics_all,
    predict_order_statistics_iid,
)


def fig1_latency_scaling() -> None:
    """Fig. 1: mean computation latency linear in computational load."""
    w = WorkerLatencyModel(
        comm=GammaParams.from_mean_var(1e-4, 1e-10),
        comp_per_unit=GammaParams.from_mean_var(1e-9, 1e-20),
    )
    rng = np.random.default_rng(0)
    loads = np.array([1e6, 2e6, 4e6, 8e6, 16e6])
    t0 = time.perf_counter()
    means = np.array(
        [np.mean([w.sample_comp(c, rng) for _ in range(2000)]) for c in loads]
    )
    us = (time.perf_counter() - t0) * 1e6 / len(loads)
    # linear fit through the origin; derived = max relative deviation
    slope = (means @ loads) / (loads @ loads)
    dev = float(np.max(np.abs(means - slope * loads) / (slope * loads)))
    record("fig1_latency_scaling", us, f"max_dev_from_linear={dev:.3f}")


def fig3_gamma_fit() -> None:
    """Figs. 2-3: steady-state latency is gamma-shaped; moment fit recovers
    the distribution (KS-style max CDF gap)."""
    g = GammaParams.from_mean_var(2.2e-2, (0.1 * 2.2e-2) ** 2)
    rng = np.random.default_rng(1)
    t0 = time.perf_counter()
    samples = np.sort(g.sample(rng, size=4000))
    from repro.latency.model import fit_gamma

    fitted = fit_gamma(samples)
    # empirical CDF vs fitted CDF via sampling quantiles
    ref = np.sort(fitted.sample(np.random.default_rng(2), size=4000))
    gap = float(np.max(np.abs(samples - ref) / samples.mean()))
    us = (time.perf_counter() - t0) * 1e6
    record("fig3_gamma_fit", us, f"max_quantile_gap={gap:.3f}")


def fig5_order_stats() -> None:
    """Fig. 5: non-iid order-statistic prediction accurate; iid model off."""
    cl = make_heterogeneous_cluster(
        72, seed=3, burst_rate=0.0, comp_range=(1.1e-3, 2.5e-3), cv_comp=0.05,
        cv_comm=0.1,
    )
    c = 1e5
    t0 = time.perf_counter()
    emp = empirical_order_statistic(
        ClusterLatencyModel(cl.workers, seed=99).sample_matrix(c, 600)
    )
    ours = predict_order_statistics_all(cl, c, num_trials=600, seed=7)
    iid = predict_order_statistics_iid(cl, c, num_trials=600, seed=7)
    us = (time.perf_counter() - t0) * 1e6
    err_ours = float(np.max(np.abs(ours - emp) / emp))
    err_iid = float(np.max(np.abs(iid - emp) / emp))
    record("fig5_order_stats", us, f"err_ours={err_ours:.4f};err_iid={err_iid:.4f}")


def fig6_event_sim() -> None:
    """Fig. 6: naive per-iteration model underestimates cumulative latency
    for w << N; the event-driven simulator stays accurate."""
    c = 1e5
    t0 = time.perf_counter()
    cl1 = make_heterogeneous_cluster(72, seed=1, burst_rate=0.0)
    t_event_w9 = simulate_iteration_times(cl1, 9, c, 300)[-1]
    cl2 = make_heterogeneous_cluster(72, seed=1, burst_rate=0.0)
    t_naive_w9 = naive_iteration_times(cl2, 9, c, 300)[-1]
    cl3 = make_heterogeneous_cluster(72, seed=1, burst_rate=0.0)
    t_event_wN = simulate_iteration_times(cl3, 72, c, 300)[-1]
    cl4 = make_heterogeneous_cluster(72, seed=1, burst_rate=0.0)
    t_naive_wN = naive_iteration_times(cl4, 72, c, 300)[-1]
    us = (time.perf_counter() - t0) * 1e6
    record(
        "fig6_event_sim",
        us,
        f"naive/event_w9={t_naive_w9 / t_event_w9:.3f};"
        f"naive/event_wN={t_naive_wN / t_event_wN:.3f}",
    )


def fig7_load_balancing() -> None:
    """Fig. 7: per-worker latency with/without LB under an injected slowdown
    + speedup; derived = final-phase max latency ratio (unbalanced/balanced)."""
    X, y = make_higgs_like(8192, seed=0)
    prob = LogisticRegressionProblem(X=X, y=y)
    N, sp = 8, 10
    c_task = prob.compute_cost(1, max(prob.num_samples // (N * sp), 1))

    def make_cluster():
        return make_paper_artificial_cluster(num_workers=N, load_unit=c_task, seed=1)

    def slow_then_fast(cluster):
        # slow 3 workers at iteration ~40, speed 3 others at ~90 (fig. 7)
        pass

    results = {}
    t0 = time.perf_counter()
    for lb in (False, True):
        cl = make_cluster()
        events = [
            (0.05, lambda c: [setattr(c.workers[i], "slowdown", 2.0) for i in (1, 3, 5)]),
            (0.30, lambda c: [setattr(c.workers[i], "slowdown", 0.7) for i in (0, 2, 4)]),
        ]
        cfg = MethodConfig(
            name="dsag", w=N, eta=0.25, subpartitions=sp, load_balance=lb,
            lb_startup_delay=0.02, lb_interval=0.05,
        )
        sim = TrainingSimulator(prob, cl, cfg, eval_every=50, timed_events=events, seed=0)
        h = sim.run(160)
        tail = h.per_worker_latency[-20:]
        results[lb] = float(np.nanmax(np.nanmean(tail, axis=0)))
    us = (time.perf_counter() - t0) * 1e6
    ratio = results[False] / results[True]
    record("fig7_load_balancing", us, f"tail_latency_ratio_unbal_over_bal={ratio:.2f}")


def fig8_convergence() -> None:
    """Fig. 8: full method comparison on PCA + logreg; derived = DSAG wins."""
    # --- PCA ---
    X = make_genomics_like_matrix(8192, 128, seed=0)
    pca = PCAProblem(X=X, k=3)
    N, sp = 16, 10
    c_task = pca.compute_cost(1, max(pca.num_samples // (N * sp), 1))

    def run(problem, name, w, iters, eta, lb=False, spp=sp):
        cl = make_paper_artificial_cluster(num_workers=N, load_unit=c_task, seed=1)
        events = [(1.0, lambda c: clear_slowdowns(c, range(N - 4, N)))]
        cfg = MethodConfig(name=name, w=w, eta=eta, subpartitions=spp, load_balance=lb)
        sim = TrainingSimulator(problem, cl, cfg, eval_every=20, timed_events=events, seed=0)
        return sim.run(iters)

    t0 = time.perf_counter()
    h = {}
    h["gd"] = run(pca, "gd", 0, 120, 1.0)
    h["coded"] = run(pca, "coded", 0, 120, 1.0)
    h["sagN"] = run(pca, "sag", N, 400, 0.9)
    h["sag4"] = run(pca, "sag", 4, 400, 0.9)
    h["dsag4"] = run(pca, "dsag", 4, 400, 0.9)
    h["sgd4"] = run(pca, "sgd", 4, 400, 0.2)
    gap = 1e-6
    t_dsag = h["dsag4"].time_to_gap(gap)
    t_sagN = h["sagN"].time_to_gap(gap)
    t_gd = h["gd"].time_to_gap(gap)
    t_coded = h["coded"].time_to_gap(gap)
    sag4_stalls = not np.isfinite(h["sag4"].time_to_gap(gap))
    sgd_stalls = not np.isfinite(h["sgd4"].time_to_gap(gap))
    us = (time.perf_counter() - t0) * 1e6
    record(
        "fig8_pca",
        us,
        f"dsag_vs_sagN_speedup={t_sagN / t_dsag:.2f};"
        f"dsag_vs_coded_speedup={t_coded / t_dsag:.2f};"
        f"dsag_vs_gd_speedup={t_gd / t_dsag:.2f};"
        f"sag_w4_stalls={sag4_stalls};sgd_stalls={sgd_stalls}",
    )

    # --- logistic regression ---
    Xl, yl = make_higgs_like(16384, seed=0)
    lr = LogisticRegressionProblem(X=Xl, y=yl)
    c_task = lr.compute_cost(1, max(lr.num_samples // (N * sp), 1))
    t0 = time.perf_counter()
    hl = {}
    hl["gd"] = run(lr, "gd", 0, 250, 1.0)
    hl["coded"] = run(lr, "coded", 0, 250, 1.0)
    hl["sagN"] = run(lr, "sag", N, 1200, 0.25)
    hl["sag4"] = run(lr, "sag", 4, 1200, 0.25)
    hl["dsag4"] = run(lr, "dsag", 4, 1200, 0.25)
    hl["dsag4lb"] = run(lr, "dsag", 4, 1200, 0.25, lb=True)
    gap = 1e-4
    t_dsag = hl["dsag4"].time_to_gap(gap)
    t_dsag_lb = hl["dsag4lb"].time_to_gap(gap)
    t_sagN = hl["sagN"].time_to_gap(gap)
    t_coded = hl["coded"].time_to_gap(gap)
    sag4_gap = np.nanmin(
        np.where(np.isfinite(hl["sag4"].suboptimality), hl["sag4"].suboptimality, np.nan)
    )
    us = (time.perf_counter() - t0) * 1e6
    record(
        "fig8_logreg",
        us,
        f"dsag_vs_sagN_speedup={t_sagN / t_dsag:.2f};"
        f"dsaglb_vs_sagN_speedup={t_sagN / t_dsag_lb:.2f};"
        f"dsag_vs_coded_speedup={t_coded / t_dsag:.2f};"
        f"sag_w4_best_gap={sag4_gap:.1e}",
    )


def table1_latency() -> None:
    """Table 1: comm/comp latency ranges of the stochastic methods."""
    X, y = make_higgs_like(8192, seed=0)
    prob = LogisticRegressionProblem(X=X, y=y)
    N, sp = 16, 10
    c_task = prob.compute_cost(1, max(prob.num_samples // (N * sp), 1))
    cl = make_heterogeneous_cluster(N, load_unit=c_task, seed=2, burst_rate=0.0)
    cfg = MethodConfig(name="dsag", w=4, eta=0.25, subpartitions=sp)
    t0 = time.perf_counter()
    sim = TrainingSimulator(prob, cl, cfg, eval_every=100, seed=0)
    hist = sim.run(150)
    stats = sim.profiler.all_stats(now=float(hist.times[-1]))
    comps = [s.e_comp for s in stats.values()]
    comms = [s.e_comm for s in stats.values()]
    us = (time.perf_counter() - t0) * 1e6
    record(
        "table1_latency",
        us,
        f"comp_range=[{min(comps):.2e},{max(comps):.2e}];"
        f"comm_range=[{min(comms):.2e},{max(comms):.2e}]",
    )


def fig9_scenario_sweep() -> None:
    """Figs. 8-9 as a *sweep*: 100 workers x 5 methods x 10 seeds x 3 burst
    regimes through the vectorized engine, checked against the scalar event
    loop for wall-clock; emits the BENCH_sweep.json artifact."""
    from repro.experiments import run_sweep, scalar_sweep_seconds, write_bench_sweep

    out = run_sweep(n_workers=100, n_seeds=10, num_iterations=100)
    scalar_s = scalar_sweep_seconds(out)
    payload = write_bench_sweep(out, "BENCH_sweep.json", scalar_seconds=scalar_s)
    burst = payload["ordering"]["heavy_bursts"]
    record(
        "fig9_scenario_sweep",
        out.engine_seconds * 1e6,
        f"speedup_vs_scalar={payload['speedup_vs_scalar']:.1f};"
        f"sag_over_dsag={burst['sag_over_dsag']:.2f};"
        f"coded_over_dsag={burst['coded_over_dsag']:.2f};"
        f"dsag_beats_sag_and_coded={bool(burst['dsag_beats_sag_and_coded'])}",
    )


def fig10_12_convergence_sweep() -> None:
    """Figs. 10-12 (time-to-suboptimality) as a batched *convergence* sweep:
    DSAG/SAG/SGD/coded through the full training loop on a 100-worker,
    10-scenario heavy-burst fleet via the fused-scan engine, with the scalar
    TrainingSimulator timed on a subset for the speedup claim, plus the
    paper-scale PCA column (n=50k genomics-like matrix, the paper's actual
    workload size) and the pca_grid_sharded column (10x that scenario grid
    through the shard_map scenario mesh, bit-exact vs the single-device
    scan) and the kernel_backend column (both method grids under
    kernel_backend="xla" and "pallas", bit-exact with per-backend
    digests) and the live_validation column (a real CPU logreg job
    through the live trainer under injected stragglers, stream-pinned
    and wall-clock-validated against the scalar simulator); emits the
    BENCH_convergence.json artifact."""
    from repro.experiments import (
        convergence_payload,
        default_convergence_methods,
        paper_scale_pca_sweep,
        run_convergence_sweep,
        scalar_convergence_seconds,
        write_bench_convergence,
    )
    from repro.experiments.grid import HEAVY_BURSTS

    X, y = make_higgs_like(16384, seed=0)
    prob = LogisticRegressionProblem(X=X, y=y)
    N, sp = 100, 10
    c_task = prob.compute_cost(1, max(prob.num_samples // (N * sp), 1))
    cluster = make_heterogeneous_cluster(N, seed=0, burst_rate=0.0, load_unit=c_task)
    methods = default_convergence_methods(N, w=80, eta=0.25, subpartitions=sp)
    out = run_convergence_sweep(
        prob, cluster, methods,
        n_scenarios=10, num_iterations=60, eval_every=5,
        regime=HEAVY_BURSTS, seed=0,
    )
    # scalar baseline: 2 scenarios of the DSAG-vs-SAG pair, extrapolated to
    # the acceptance grid (the full scalar grid takes minutes by design)
    measured, extrapolated = scalar_convergence_seconds(
        out, methods=("dsag", "sag"), max_scenarios=2
    )
    import time as _time

    t0 = _time.perf_counter()
    from repro.experiments import run_convergence_batch

    for name in ("dsag", "sag"):
        run_convergence_batch(
            prob, out.traces, methods[name], 60, eval_every=5, seed=0
        )
    batched_pair = _time.perf_counter() - t0

    # paper-scale PCA column: the n=50k genomics-like matrix through the
    # same fused engine (calibrated eta/gap — see PAPER_SCALE_PCA)
    pca_out, pca_gap = paper_scale_pca_sweep(seed=0)
    pca_payload = convergence_payload(pca_out, pca_gap)

    # pca_grid_sharded column: 10x that scenario grid in one dispatch
    # through the shard_map scenario mesh, checked bit-exact against the
    # single-device scan (CPU demo: run under
    # XLA_FLAGS=--xla_force_host_platform_device_count=4)
    from benchmarks.bench_regression import run_pca_grid_sharded_column

    sharded_payload = run_pca_grid_sharded_column(
        n_scenarios=10 * pca_out.traces.num_scenarios, seed=0
    )

    gap = 0.2
    # §6 lb_scan column: DSAG with the load balancer in the loop, through
    # the fused scan AND the host engine on the same traces — the fused LB
    # path must stay bit-exact and (warm) faster, at unchanged orderings
    import dataclasses as _dc

    from benchmarks.bench_regression import run_lb_scan_column

    lb_schedule = {"lb_startup_delay": 0.05, "lb_interval": 0.1}
    base_medians = {
        name: float(np.median(res.time_to_gap(gap)))
        for name, res in out.results.items()
    }
    lb_payload = run_lb_scan_column(
        prob,
        out.traces,
        _dc.replace(methods["dsag"], **lb_schedule),
        num_iterations=60,
        eval_every=5,
        seed=0,
        gap=gap,
        base_medians=base_medians,
    )

    # churn column: the elastic-fleet pin — dsag/sag/coded on a fleet
    # where the slowest fifth dies mid-run (half rejoining later), scan
    # bit-exact vs host and the dsag < sag < coded ordering surviving
    from benchmarks.bench_regression import run_churn_column

    churn_payload = run_churn_column()

    # kernel_backend column: the per-backend pinning tier — the logreg and
    # PCA method grids through the fused scan under both kernel backends,
    # Pallas (interpret on CPU) bit-exact vs XLA with per-backend digests
    from benchmarks.bench_regression import run_kernel_backend_column

    kernel_backend_payload = run_kernel_backend_column()

    # live_validation column: the sim-to-live gap — a real CPU logreg job
    # through launch/train.py under injected stragglers, its (mask, flush,
    # evict) streams pinned bit-for-bit against the scalar simulator on
    # the same trace, and its measured wall-clock time-to-gap per method
    # validated against the simulator's prediction
    from benchmarks.bench_regression import run_live_validation_column

    live_validation_payload = run_live_validation_column()

    payload = write_bench_convergence(
        out, "BENCH_convergence.json", gap=gap,
        scalar_seconds=extrapolated,
        scalar_seconds_measured=measured,
        # the scalar timing covers only the DSAG-vs-SAG pair, so the
        # like-for-like acceptance speedup lives in pair_grid (same two
        # methods batched and scalar) and no top-level ratio is emitted
        scalar_methods=["dsag", "sag"],
        extra={
            "pair_grid": {
                "methods": ["dsag", "sag"],
                "batched_seconds": batched_pair,
                "scalar_seconds_extrapolated": extrapolated,
                "speedup": extrapolated / max(batched_pair, 1e-12),
            },
            "pca_paper_scale": pca_payload,
            "pca_grid_sharded": sharded_payload,
            "lb_scan": lb_payload,
            "churn": churn_payload,
            "kernel_backend": kernel_backend_payload,
            "live_validation": live_validation_payload,
            # everything the regression gate needs to re-execute this grid
            # (benchmarks/bench_regression.py rerun_convergence)
            "recipe": {
                "problem": "logreg_higgs",
                "num_samples": 16384,
                "n_workers": N,
                "subpartitions": sp,
                "w": 80,
                "eta": 0.25,
                "n_scenarios": 10,
                "num_iterations": 60,
                "eval_every": 5,
                "regime": "heavy_bursts",
                "seed": 0,
                "gap": gap,
                "lb": lb_schedule,
            },
        },
    )
    o = payload["ordering"]
    po = pca_payload["ordering"]
    record(
        "fig10_12_convergence_sweep",
        out.engine_seconds * 1e6,
        f"pair_speedup_vs_scalar={payload['pair_grid']['speedup']:.1f};"
        f"sag_over_dsag={o['sag_over_dsag']:.2f};"
        f"coded_over_dsag={o['coded_over_dsag']:.2f};"
        f"ordering_dsag_sag_coded={bool(o['ordering_dsag_sag_coded'])}",
    )
    record(
        "fig10_12_pca_paper_scale",
        pca_out.engine_seconds * 1e6,
        f"n={pca_out.problem.num_samples};gap={pca_gap:g};"
        f"sag_over_dsag={po['sag_over_dsag']:.2f};"
        f"coded_over_dsag={po['coded_over_dsag']:.2f};"
        f"ordering_dsag_sag_coded={bool(po['ordering_dsag_sag_coded'])}",
    )
    so = sharded_payload["ordering"]
    record(
        "fig10_12_pca_grid_sharded",
        sharded_payload["sharded_seconds"] * 1e6,
        f"scenarios={sharded_payload['grid']['n_scenarios']};"
        f"devices={sharded_payload['num_devices']};"
        f"bitexact={sharded_payload['bitexact_sharded_vs_unsharded']};"
        f"device_scaling={sharded_payload['device_scaling']:.2f};"
        f"sag_over_dsag={so['sag_over_dsag']:.2f};"
        f"ordering_dsag_sag_coded={bool(so['ordering_dsag_sag_coded'])}",
    )
    lv = live_validation_payload
    lvo = lv["ordering"]
    record(
        "fig10_12_live_validation",
        lv["methods"]["dsag"]["wall_seconds"] * 1e6,
        f"streams_match={all(m['streams_match_simulator'] for m in lv['methods'].values())};"
        f"live_dsag_faster_than_sag={bool(lvo.get('live_dsag_faster_than_sag', 0))};"
        f"sag_over_dsag_wall={lvo.get('sag_over_dsag_wall', float('nan')):.2f};"
        f"dsag_measured_over_predicted={lv['methods']['dsag'].get('measured_over_predicted', float('nan')):.2f}",
    )
    record(
        "fig10_12_lb_scan",
        lb_payload["scan_seconds"] * 1e6,
        f"speedup_scan_over_host={lb_payload['speedup_scan_over_host']:.2f};"
        f"bitexact={lb_payload['bitexact_scan_vs_host']};"
        f"dsag_lb_fastest={bool(lb_payload['ordering'].get('dsag_lb_fastest_to_gap', 0))};"
        f"repartitions_mean={lb_payload['repartitions_mean']:.1f}",
    )


def run_all() -> None:
    fig1_latency_scaling()
    fig3_gamma_fit()
    fig5_order_stats()
    fig6_event_sim()
    fig7_load_balancing()
    fig8_convergence()
    fig9_scenario_sweep()
    fig10_12_convergence_sweep()
    table1_latency()
