"""Kernel microbenchmarks: Pallas (interpret on CPU — correctness-path
timing) vs the jnp reference path (XLA-compiled), plus analytic TPU roofline
projections for each kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import record, time_fn
from repro.analysis.roofline import HBM_BW, PEAK_FLOPS
from repro.kernels.ops import (
    dsag_cache_update_op,
    dsag_update_ref,
    flash_attention_op,
    flash_attention_ref,
    gram_matvec_op,
    gram_matvec_ref,
)


def bench_gram_matvec() -> None:
    n, d, k = 4096, 512, 8
    x = jax.random.normal(jax.random.key(0), (n, d), jnp.float32)
    v = jax.random.normal(jax.random.key(1), (d, k), jnp.float32)
    ref = jax.jit(gram_matvec_ref)
    us_ref = time_fn(lambda: jax.block_until_ready(ref(x, v)))
    # TPU projection: 1 HBM pass over X vs 2 for the two-einsum form
    flops = 4.0 * n * d * k
    bytes_one_pass = n * d * 4 + 2 * d * k * 4
    bytes_two_pass = 2 * n * d * 4 + n * k * 8 + 2 * d * k * 4
    t_kernel = max(flops / PEAK_FLOPS, bytes_one_pass / HBM_BW) * 1e6
    t_naive = max(flops / PEAK_FLOPS, bytes_two_pass / HBM_BW) * 1e6
    record(
        "kernel_gram_matvec",
        us_ref,
        f"tpu_projected_speedup={t_naive / t_kernel:.2f};cpu_ref_us={us_ref:.0f}",
    )


def bench_dsag_update() -> None:
    p, n = 8, 1 << 20
    g = jax.random.normal(jax.random.key(2), (p, n), jnp.bfloat16)
    c = jax.random.normal(jax.random.key(3), (p, n), jnp.bfloat16)
    h = jnp.zeros((n,), jnp.float32)
    mask = jnp.ones((p,))
    ref = jax.jit(dsag_update_ref)
    us_ref = time_fn(lambda: jax.block_until_ready(ref(g, c, h, mask)))
    # memory-bound: fused = read g+c+h, write c+h; naive adds a second c pass
    fused = (2 * p * n * 2 + 2 * n * 4) + (p * n * 2 + n * 4)
    naive = fused + p * n * 2 * 2
    record(
        "kernel_dsag_update",
        us_ref,
        f"tpu_projected_speedup={naive / fused:.2f};cpu_ref_us={us_ref:.0f}",
    )


def bench_flash_attention() -> None:
    b, h, s, d = 1, 4, 1024, 128
    q = jax.random.normal(jax.random.key(4), (b, h, s, d), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(5), (b, h, s, d), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(6), (b, h, s, d), jnp.bfloat16)
    ref = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us_ref = time_fn(lambda: jax.block_until_ready(ref(q, k, v)))
    flops = 4.0 * b * h * s * s * d
    bytes_flash = 3 * b * h * s * d * 2 + b * h * s * d * 2
    bytes_naive = bytes_flash + 2 * b * h * s * s * 4  # S^2 scores round-trip
    t_flash = max(flops / PEAK_FLOPS, bytes_flash / HBM_BW)
    t_naive = max(flops / PEAK_FLOPS, bytes_naive / HBM_BW)
    record(
        "kernel_flash_attention",
        us_ref,
        f"tpu_projected_speedup={t_naive / t_flash:.2f};cpu_ref_us={us_ref:.0f}",
    )


def run_all() -> None:
    bench_gram_matvec()
    bench_dsag_update()
    bench_flash_attention()
