"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Runs named TrainConfig variants of the three chosen cells and appends every
iteration (hypothesis text, overrides, the three roofline terms, verdict) to
experiments/perf_log.json.  EXPERIMENTS.md §Perf renders from that log.

  PYTHONPATH=src:. python -m benchmarks.hillclimb --cell qwen05 --iter fused_loss
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

BASE = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "experiments")
LOG = os.path.join(BASE, "perf_log.json")

# (cell key) -> (arch, shape)
CELLS = {
    "qwen05": ("qwen1.5-0.5b", "train_4k"),
    "deepseek": ("deepseek-v2-236b", "train_4k"),
    "qwen7b": ("qwen2-7b", "train_4k"),
}

# iteration name -> (hypothesis, TrainConfig overrides)
ITERATIONS = {
    "baseline": ("paper-faithful DSAG step, full remat, plain CE loss", {}),
    "fused_loss": (
        "memory term is dominated by [B,S,152k] logits (bf16 + fp32 casts "
        "~3.5 GiB/device each way); fusing CE with the unembed matmul and "
        "chunking over vocab removes the logit round-trips -> expect the "
        "memory term to drop by >30% on small-model cells",
        {"fused_loss": True},
    ),
    "fused_loss_selective": (
        "with logits gone, full-remat recompute (+1 fwd of compute and "
        "activation traffic) is the next memory/compute cost; selective "
        "remat (save dot outputs) trades VMEM for ~25% less recompute",
        {"fused_loss": True, "remat": "selective"},
    ),
    "int8_gather": (
        "collective term is dominated by per-layer FSDP weight all-gathers "
        "(bf16); int8 per-row-scaled gathers halve that wire volume -> "
        "expect collective term ~-40% on FSDP-bound cells",
        {"fused_loss": True, "quantized_fsdp_allgather": True},
    ),
    "bf16_reduce": (
        "qwen05 lesson: the memory AND collective terms are dominated by "
        "fp32 attention-score buffers and fp32 TP all-reduces riding the "
        "dot accumulator type, NOT by logits (hypothesis 'fused_loss' was "
        "refuted).  Emitting sharded-contraction dots in bf16 halves the "
        "activation all-reduce wire volume -> expect collective ~-30%",
        {"fused_loss": True, "bf16_reduce": True},
    ),
    "bf16_reduce_int8": (
        "stack int8 FSDP weight gathers on bf16 TP-reduces: weight all-"
        "gathers are the other half of the collective term on FSDP cells",
        {"fused_loss": True, "bf16_reduce": True, "quantized_fsdp_allgather": True},
    ),
    "flash_kernel": (
        "S x S score buffers (fp32, fwd+remat+bwd) dominate the memory term "
        "(qwen05: ~75%% of bytes); the Pallas flash-attention kernel "
        "(validated vs ref in interpret mode) keeps them in VMEM.  XLA-CPU "
        "cannot execute the TPU kernel, so this iteration reports the "
        "analyzer's fused-scores memory term (memory_s_flash) alongside the "
        "measured one",
        {"fused_loss": True},
    ),
    "int8_gather_cf1": (
        "MoE dispatch buffers and EP combine collectives scale with the "
        "capacity factor; cf 1.25 -> 1.0 cuts expert-path traffic 20% at "
        "the cost of more token drops (training-quality tradeoff noted)",
        {"fused_loss": True, "quantized_fsdp_allgather": True},
    ),
}


def log_append(entry: dict) -> None:
    os.makedirs(BASE, exist_ok=True)
    log = []
    if os.path.exists(LOG):
        with open(LOG) as f:
            log = json.load(f)
    log.append(entry)
    with open(LOG, "w") as f:
        json.dump(log, f, indent=2)


def run_iteration(cell_key: str, iter_name: str) -> dict:
    arch, shape = CELLS[cell_key]
    hypothesis, overrides = ITERATIONS[iter_name]
    # subprocess for a fresh XLA (device-count env must be first)
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_cell
res = run_cell({arch!r}, {shape!r}, False, overrides={overrides!r})
print("RESULT" + json.dumps(res["roofline"] | {{"mem_gib": res["memory"]["peak_estimate_bytes"] / 2**30}}))
"""
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=1800, env=env
    )
    if proc.returncode != 0:
        entry = {
            "cell": cell_key, "arch": arch, "shape": shape, "iteration": iter_name,
            "hypothesis": hypothesis, "overrides": overrides, "status": "fail",
            "error": proc.stderr[-1500:],
        }
        log_append(entry)
        print(f"[hillclimb] {cell_key}/{iter_name} FAILED")
        return entry
    rl = json.loads(proc.stdout.split("RESULT", 1)[1])
    entry = {
        "cell": cell_key, "arch": arch, "shape": shape, "iteration": iter_name,
        "hypothesis": hypothesis, "overrides": overrides, "status": "ok",
        "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
        "collective_s": rl["collective_s"], "dominant": rl["dominant"],
        "mfu": rl["mfu"], "mem_gib": rl["mem_gib"],
        "useful_flops_fraction": rl["useful_flops_fraction"],
        "memory_s_flash": rl.get("memory_s_flash", 0.0),
        "attn_score_gib": rl.get("attn_score_bytes", 0.0) / 2**30,
    }
    log_append(entry)
    print(
        f"[hillclimb] {cell_key}/{iter_name}: c/m/x = "
        f"{rl['compute_s']:.3f}/{rl['memory_s']:.3f}/{rl['collective_s']:.3f} s "
        f"dom={rl['dominant']} mfu={rl['mfu']:.3f}"
    )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS), required=True)
    ap.add_argument("--iter", choices=list(ITERATIONS), required=True)
    args = ap.parse_args()
    run_iteration(args.cell, args.iter)


if __name__ == "__main__":
    main()
