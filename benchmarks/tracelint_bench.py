"""Timing of the tracelint entry-point probes (build + full rule pass).

The analyzer runs in CI on every push, so its cost is part of the build
budget: these rows time (a) building each registered probe (tracing the
production entry point into a jaxpr) and (b) the full five-rule pass over
it, via the same ``repro.analysis.lint`` registry the CI gate and
``tests/test_tracelint.py`` use.  Emits the repo's
``name,us_per_call,derived`` CSV rows; ``bench_regression.py --kind
tracelint`` gates on the derived finding counts (never on wall time).
"""

from __future__ import annotations

from benchmarks.common import record, time_fn


def run_all() -> None:
    from repro.analysis.lint.entries import ENTRIES
    from repro.analysis.lint.rules import ALL_RULES

    for name, build in ENTRIES.items():
        us_build = time_fn(build, warmup=1, iters=3)
        entry = build()

        def rule_pass(e=entry):
            return [f for _, rule in ALL_RULES for f in rule(e)]

        us_rules = time_fn(rule_pass, warmup=1, iters=3)
        findings = rule_pass()
        codes = "+".join(sorted({f.code for f in findings})) or "clean"
        record(f"tracelint_build_{name}", us_build, "probe trace")
        record(f"tracelint_rules_{name}", us_rules, f"findings={codes}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run_all()
