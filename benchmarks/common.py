"""Shared benchmark utilities: timing + the ``name,us_per_call,derived`` CSV
contract of ``benchmarks.run``."""

from __future__ import annotations

import time
from collections.abc import Callable

ROWS: list[tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
