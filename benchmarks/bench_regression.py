"""Benchmark-regression gate: rerun the sweep grid and diff the committed
``BENCH_sweep.json`` artifact.

The vectorized sweep engine is deterministic given its seeds, so a rerun of
the committed grid must reproduce the artifact's *method ordering* exactly;
drift means a semantic change to the engine or the latency model.  The gate:

* **fail** when a regime's method ranking (by best-w mean iteration time)
  changes, or when the ``dsag_beats_sag_and_coded`` verdict flips;
* **warn** (exit 0) when the DSAG speedup ratios (``sag_over_dsag``,
  ``coded_over_dsag``) drift by more than 15% — noisy-but-directionally-
  intact changes are surfaced without blocking.

Run from the repo root:

    PYTHONPATH=src python benchmarks/bench_regression.py [BENCH_sweep.json]
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List, Tuple

SPEEDUP_DRIFT_TOLERANCE = 0.15
SPEEDUP_KEYS = ("sag_over_dsag", "coded_over_dsag")


class GridMismatch(RuntimeError):
    """The committed artifact's grid cannot be reproduced by the rerun."""


def method_ranking(cells: Dict[str, dict], regime: str) -> List[str]:
    """Methods sorted fastest-first by their best-w mean iteration time."""
    best: Dict[str, float] = {}
    for key, cell in cells.items():
        reg, method, _w = key.split("/")
        if reg != regime:
            continue
        t = cell["mean_iter_time"]
        if method not in best or t < best[method]:
            best[method] = t
    return sorted(best, key=best.get)


def compare_sweep(committed: dict, fresh: dict) -> Tuple[List[str], List[str]]:
    """Diff two BENCH_sweep payloads; returns (failures, warnings)."""
    failures: List[str] = []
    warnings: List[str] = []
    for regime in committed["grid"]["regimes"]:
        if regime not in fresh["grid"]["regimes"]:
            failures.append(f"{regime}: regime missing from rerun")
            continue
        old_rank = method_ranking(committed["cells"], regime)
        new_rank = method_ranking(fresh["cells"], regime)
        if old_rank != new_rank:
            failures.append(
                f"{regime}: method ordering flipped {old_rank} -> {new_rank}"
            )
        old_o = committed["ordering"].get(regime, {})
        new_o = fresh["ordering"].get(regime, {})
        old_verdict = old_o.get("dsag_beats_sag_and_coded")
        new_verdict = new_o.get("dsag_beats_sag_and_coded")
        if old_verdict != new_verdict:
            failures.append(
                f"{regime}: dsag_beats_sag_and_coded flipped "
                f"{old_verdict} -> {new_verdict}"
            )
        for key in SPEEDUP_KEYS:
            if key in old_o and key in new_o and old_o[key] > 0:
                drift = abs(new_o[key] / old_o[key] - 1.0)
                if drift > SPEEDUP_DRIFT_TOLERANCE:
                    warnings.append(
                        f"{regime}: {key} drifted {drift:.0%} "
                        f"({old_o[key]:.2f} -> {new_o[key]:.2f})"
                    )
    return failures, warnings


def rerun_grid(committed: dict) -> dict:
    """Re-execute the committed artifact's grid (engine only, no scalar
    timing) and summarize it with the same results layer.

    The artifact's ``grid`` section does not record every sweep parameter,
    so the swept w values are reconstructed from the cell keys, the regimes
    are matched by name against the known regime presets, and any cell-key
    mismatch between the rerun and the artifact is an explicit failure
    (raised as ``GridMismatch``) rather than a silent comparison of
    different grids.
    """
    from repro.experiments import outcome_to_dict, run_sweep
    from repro.experiments.grid import DEFAULT_REGIMES

    grid = committed["grid"]
    known_regimes = {r.name: r for r in DEFAULT_REGIMES}
    regimes = []
    for name in grid["regimes"]:
        if name not in known_regimes:
            raise GridMismatch(
                f"regime {name!r} in the committed artifact is not a known "
                "preset; rerun cannot reproduce the grid"
            )
        regimes.append(known_regimes[name])
    # swept w values: the w cells of the w-swept methods (sgd / dsag)
    w_values = sorted(
        {
            int(key.split("/")[2][1:])
            for key in committed["cells"]
            if key.split("/")[1] in ("sgd", "dsag")
        }
    )
    outcome = run_sweep(
        n_workers=grid["n_workers"],
        n_seeds=grid["n_seeds"],
        num_iterations=grid["num_iterations"],
        w_values=w_values,
        w_fracs=(),
        regimes=regimes,
        seed=grid.get("seed", 0),
    )
    fresh = outcome_to_dict(outcome)
    if set(fresh["cells"]) != set(committed["cells"]):
        missing = set(committed["cells"]) - set(fresh["cells"])
        added = set(fresh["cells"]) - set(committed["cells"])
        raise GridMismatch(
            f"rerun produced different grid cells (missing {sorted(missing)}, "
            f"unexpected {sorted(added)}); the artifact was generated with "
            "parameters the rerun cannot reconstruct — regenerate it"
        )
    return fresh


def main(argv: List[str]) -> int:
    path = argv[1] if len(argv) > 1 else "BENCH_sweep.json"
    try:
        with open(path) as fh:
            committed = json.load(fh)
    except FileNotFoundError:
        print(f"FAIL: committed artifact {path} not found")
        return 1
    try:
        fresh = rerun_grid(committed)
    except GridMismatch as exc:
        print(f"FAIL: {exc}")
        return 1
    failures, warnings = compare_sweep(committed, fresh)
    for w in warnings:
        print(f"WARN: {w}")
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        print(f"benchmark regression: {len(failures)} ordering flip(s)")
        return 1
    print(
        f"benchmark regression: ordering stable across "
        f"{len(committed['grid']['regimes'])} regimes"
        + (f" ({len(warnings)} drift warning(s))" if warnings else "")
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
