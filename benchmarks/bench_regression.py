"""Benchmark-regression gate: rerun the committed grids and diff the
``BENCH_sweep.json`` / ``BENCH_convergence.json`` artifacts.

The engines are deterministic given their seeds, so a rerun of a committed
grid must reproduce the artifact's *orderings* exactly; drift means a
semantic change to an engine or the latency model.  The gate:

* **fail** when an ordering changes — a sweep regime's method ranking (by
  best-w mean iteration time), the ``dsag_beats_sag_and_coded`` verdict,
  the convergence grid's time-to-gap ranking or
  ``dsag_fastest_to_gap`` / ``ordering_dsag_sag_coded`` verdicts, the
  ``lb_scan`` column's DSAG-with-LB verdict, the §6 scan-vs-host
  bit-exactness, the ``churn`` column's elastic-fleet pins (scan-vs-
  host bit-exactness under worker death/rejoin and the dsag < sag <
  coded ordering surviving churn), or the ``kernel_backend`` column's
  per-backend pins (Pallas-vs-XLA bit-exactness on the artifact's
  platform, per-backend trajectory digests, per-backend method
  rankings; cross-platform the Pallas-vs-XLA diff is gated by a
  relative tolerance instead), or the ``live_validation`` column's
  sim-to-live pins (the *live* trainer's (mask, flush, evict) streams
  must match the scalar simulator bit-for-bit on the shared trace, and
  the measured wall-clock dsag-before-sag time-to-gap ordering under
  injected stragglers must survive);
* **warn** (exit 0) when speedup ratios drift by more than 15% — both
  the deterministic DSAG-over-baseline ratios and the wall-clock
  ``lb_scan`` scan-vs-host speedup (machine-dependent by nature, so a
  flip of ``lb_scan_faster_than_host`` on a noisy runner also only
  warns).

The convergence artifact's ``pca_paper_scale`` column is *not* re-run
here (it takes minutes by design); its orderings are covered at reduced
scale by the slow-marked tests.  The ``pca_grid_sharded`` column *is*
re-run: the 10x scenario grid goes through the sharded scan (however many
devices the runner exposes — CI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) and through the
single-device scan; ordering flips and any sharded-vs-unsharded
bit-exactness break fail, while the wall-clock device-scaling ratio only
warns (fake host devices timeslice a single core).

Run from the repo root:

    PYTHONPATH=src python benchmarks/bench_regression.py [BENCH_sweep.json]
    PYTHONPATH=src python benchmarks/bench_regression.py BENCH_convergence.json --kind convergence
"""

from __future__ import annotations

import dataclasses
import json
import sys
import time

SPEEDUP_DRIFT_TOLERANCE = 0.15
SPEEDUP_KEYS = ("sag_over_dsag", "coded_over_dsag")
CONV_SPEEDUP_KEYS = ("sag_over_dsag", "coded_over_dsag", "sgd_over_dsag")


class GridMismatch(RuntimeError):
    """The committed artifact's grid cannot be reproduced by the rerun."""


def method_ranking(cells: dict[str, dict], regime: str) -> list[str]:
    """Methods sorted fastest-first by their best-w mean iteration time."""
    best: dict[str, float] = {}
    for key, cell in cells.items():
        reg, method, _w = key.split("/")
        if reg != regime:
            continue
        t = cell["mean_iter_time"]
        if method not in best or t < best[method]:
            best[method] = t
    return sorted(best, key=best.get)


def compare_sweep(committed: dict, fresh: dict) -> tuple[list[str], list[str]]:
    """Diff two BENCH_sweep payloads; returns (failures, warnings)."""
    failures: list[str] = []
    warnings: list[str] = []
    for regime in committed["grid"]["regimes"]:
        if regime not in fresh["grid"]["regimes"]:
            failures.append(f"{regime}: regime missing from rerun")
            continue
        old_rank = method_ranking(committed["cells"], regime)
        new_rank = method_ranking(fresh["cells"], regime)
        if old_rank != new_rank:
            failures.append(
                f"{regime}: method ordering flipped {old_rank} -> {new_rank}"
            )
        old_o = committed["ordering"].get(regime, {})
        new_o = fresh["ordering"].get(regime, {})
        old_verdict = old_o.get("dsag_beats_sag_and_coded")
        new_verdict = new_o.get("dsag_beats_sag_and_coded")
        if old_verdict != new_verdict:
            failures.append(
                f"{regime}: dsag_beats_sag_and_coded flipped "
                f"{old_verdict} -> {new_verdict}"
            )
        for key in SPEEDUP_KEYS:
            if key in old_o and key in new_o and old_o[key] > 0:
                drift = abs(new_o[key] / old_o[key] - 1.0)
                if drift > SPEEDUP_DRIFT_TOLERANCE:
                    warnings.append(
                        f"{regime}: {key} drifted {drift:.0%} "
                        f"({old_o[key]:.2f} -> {new_o[key]:.2f})"
                    )
    return failures, warnings


def rerun_grid(committed: dict) -> dict:
    """Re-execute the committed artifact's grid (engine only, no scalar
    timing) and summarize it with the same results layer.

    The artifact's ``grid`` section does not record every sweep parameter,
    so the swept w values are reconstructed from the cell keys, the regimes
    are matched by name against the known regime presets, and any cell-key
    mismatch between the rerun and the artifact is an explicit failure
    (raised as ``GridMismatch``) rather than a silent comparison of
    different grids.
    """
    from repro.experiments import outcome_to_dict, run_sweep
    from repro.experiments.grid import DEFAULT_REGIMES

    grid = committed["grid"]
    known_regimes = {r.name: r for r in DEFAULT_REGIMES}
    regimes = []
    for name in grid["regimes"]:
        if name not in known_regimes:
            raise GridMismatch(
                f"regime {name!r} in the committed artifact is not a known "
                "preset; rerun cannot reproduce the grid"
            )
        regimes.append(known_regimes[name])
    # swept w values: the w cells of the w-swept methods (sgd / dsag)
    w_values = sorted(
        {
            int(key.split("/")[2][1:])
            for key in committed["cells"]
            if key.split("/")[1] in ("sgd", "dsag")
        }
    )
    outcome = run_sweep(
        n_workers=grid["n_workers"],
        n_seeds=grid["n_seeds"],
        num_iterations=grid["num_iterations"],
        w_values=w_values,
        w_fracs=(),
        regimes=regimes,
        seed=grid.get("seed", 0),
    )
    fresh = outcome_to_dict(outcome)
    if set(fresh["cells"]) != set(committed["cells"]):
        missing = set(committed["cells"]) - set(fresh["cells"])
        added = set(fresh["cells"]) - set(committed["cells"])
        raise GridMismatch(
            f"rerun produced different grid cells (missing {sorted(missing)}, "
            f"unexpected {sorted(added)}); the artifact was generated with "
            "parameters the rerun cannot reconstruct — regenerate it"
        )
    return fresh


# ---------------------------------------------------------------------------
# BENCH_convergence.json (time-to-suboptimality grid + the lb_scan column)
# ---------------------------------------------------------------------------


def convergence_ranking(methods: dict[str, dict]) -> list[str]:
    """Methods sorted fastest-first by median time-to-gap (None/inf last).

    Ties (e.g. two methods that both never reach the gap) break by method
    name: the committed artifact is key-sorted JSON while a fresh payload
    is insertion-ordered, so a dict-order tie-break would flip spuriously.
    """

    def key(name: str):
        t = methods[name].get("median_time_to_gap")
        return (float("inf") if t is None else float(t), name)

    return sorted(methods, key=key)


def compare_convergence(committed: dict, fresh: dict) -> tuple[list[str], list[str]]:
    """Diff two BENCH_convergence payloads; returns (failures, warnings)."""
    failures: list[str] = []
    warnings: list[str] = []
    old_rank = convergence_ranking(committed["methods"])
    new_rank = convergence_ranking(fresh["methods"])
    if old_rank != new_rank:
        failures.append(
            f"convergence: time-to-gap ranking flipped {old_rank} -> {new_rank}"
        )
    old_o, new_o = committed["ordering"], fresh["ordering"]
    for verdict in ("dsag_fastest_to_gap", "ordering_dsag_sag_coded"):
        if old_o.get(verdict) != new_o.get(verdict):
            failures.append(
                f"convergence: {verdict} flipped "
                f"{old_o.get(verdict)} -> {new_o.get(verdict)}"
            )
    for key in CONV_SPEEDUP_KEYS:
        if key in old_o and key in new_o and old_o[key] and old_o[key] > 0:
            drift = abs(new_o[key] / old_o[key] - 1.0)
            if drift > SPEEDUP_DRIFT_TOLERANCE:
                warnings.append(
                    f"convergence: {key} drifted {drift:.0%} "
                    f"({old_o[key]:.2f} -> {new_o[key]:.2f})"
                )
    old_lb = committed.get("lb_scan")
    new_lb = fresh.get("lb_scan")
    if old_lb is not None and new_lb is not None:
        if not new_lb.get("bitexact_scan_vs_host", False):
            failures.append(
                "lb_scan: fused scan no longer bit-exact vs the host engine"
            )
        olo, nlo = old_lb.get("ordering", {}), new_lb.get("ordering", {})
        if olo.get("dsag_lb_fastest_to_gap") != nlo.get("dsag_lb_fastest_to_gap"):
            failures.append(
                f"lb_scan: dsag_lb_fastest_to_gap flipped "
                f"{olo.get('dsag_lb_fastest_to_gap')} -> "
                f"{nlo.get('dsag_lb_fastest_to_gap')}"
            )
        # wall-clock properties only warn: CI runners are noisy by nature
        # (and the gate's single-run rerun omits them entirely)
        if (
            "lb_scan_faster_than_host" in old_lb
            and "lb_scan_faster_than_host" in new_lb
            and bool(old_lb["lb_scan_faster_than_host"])
            != bool(new_lb["lb_scan_faster_than_host"])
        ):
            warnings.append(
                f"lb_scan: lb_scan_faster_than_host flipped "
                f"{old_lb.get('lb_scan_faster_than_host')} -> "
                f"{new_lb.get('lb_scan_faster_than_host')} (wall clock)"
            )
        os_, ns_ = old_lb.get("speedup_scan_over_host"), new_lb.get(
            "speedup_scan_over_host"
        )
        if os_ and ns_ and os_ > 0:
            drift = abs(ns_ / os_ - 1.0)
            if drift > SPEEDUP_DRIFT_TOLERANCE:
                warnings.append(
                    f"lb_scan: speedup_scan_over_host drifted {drift:.0%} "
                    f"({os_:.2f} -> {ns_:.2f})"
                )
    old_ps = committed.get("pca_grid_sharded")
    new_ps = fresh.get("pca_grid_sharded")
    if old_ps is not None and new_ps is not None:
        ps_failures, ps_warnings = compare_pca_grid_sharded(old_ps, new_ps)
        failures.extend(ps_failures)
        warnings.extend(ps_warnings)
    old_ch = committed.get("churn")
    new_ch = fresh.get("churn")
    if old_ch is not None and new_ch is not None:
        ch_failures, ch_warnings = compare_churn_column(old_ch, new_ch)
        failures.extend(ch_failures)
        warnings.extend(ch_warnings)
    old_kb = committed.get("kernel_backend")
    new_kb = fresh.get("kernel_backend")
    if old_kb is not None and new_kb is not None:
        kb_failures, kb_warnings = compare_kernel_backend_column(old_kb, new_kb)
        failures.extend(kb_failures)
        warnings.extend(kb_warnings)
    old_lv = committed.get("live_validation")
    new_lv = fresh.get("live_validation")
    if old_lv is not None and new_lv is not None:
        lv_failures, lv_warnings = compare_live_validation_column(old_lv, new_lv)
        failures.extend(lv_failures)
        warnings.extend(lv_warnings)
    return failures, warnings


def run_lb_scan_column(
    problem,
    traces,
    dsag_config,
    *,
    num_iterations: int,
    eval_every: int,
    seed: int,
    gap: float,
    base_medians: dict[str, float] | None = None,
    warm_timings: bool = True,
) -> dict:
    """Run the §6 DSAG config through both engines; build the lb_scan column.

    With ``warm_timings`` (artifact generation) each engine runs twice —
    cold runs carry one-time jit compiles, and the headline speedup
    compares warm against warm.  The regression gate passes
    ``warm_timings=False``: one run per engine suffices for everything
    that can *fail* (bit-exactness, the DSAG-with-LB verdict), and the
    wall-clock fields are then omitted instead of emitting
    apples-to-oranges cold numbers (their drift checks skip on absence).
    Always asserts bit-exactness and records the DSAG-with-LB time-to-gap
    verdict against the non-LB baselines' medians from the main grid
    (same traces, common random numbers).
    """
    import numpy as np

    from repro.experiments import EngineConfig, run_convergence_batch

    cfg = dataclasses.replace(dsag_config, load_balance=True)

    def run(kind: str):
        t0 = time.perf_counter()
        res = run_convergence_batch(
            problem, traces, cfg, num_iterations,
            eval_every=eval_every, seed=seed, engine=EngineConfig(kind=kind),
        )
        return res, time.perf_counter() - t0

    host, host_cold_s = run("host")
    scan, scan_cold_s = run("scan")
    if warm_timings:
        _, host_s = run("host")
        _, scan_s = run("scan")
    else:
        host_s = scan_s = None
    bitexact = bool(
        np.array_equal(host.times, scan.times)
        and np.array_equal(host.suboptimality, scan.suboptimality, equal_nan=True)
        and np.array_equal(host.fresh_counts, scan.fresh_counts)
        and np.array_equal(
            host.per_worker_latency, scan.per_worker_latency, equal_nan=True
        )
        and host.repartition_events == scan.repartition_events
        and np.array_equal(host.evictions, scan.evictions)
        and np.array_equal(host.rejected_stale, scan.rejected_stale)
    )
    ttg = scan.time_to_gap(gap)
    t_lb = float(np.median(ttg))
    ordering = {
        "gap": gap,
        "median_time_to_gap_dsag_lb": t_lb,
        "reached_gap_frac_dsag_lb": float(np.isfinite(ttg).mean()),
    }
    if base_medians:
        for name, t in base_medians.items():
            if name != "dsag" and t and t > 0:
                ordering[f"{name}_over_dsag_lb"] = t / t_lb
        sag_t = base_medians.get("sag")
        coded_t = base_medians.get("coded")
        if sag_t is not None and coded_t is not None:
            ordering["dsag_lb_fastest_to_gap"] = float(
                t_lb < sag_t and t_lb < coded_t
            )
    out = {
        "config": {
            "w": cfg.w,
            "subpartitions": cfg.subpartitions,
            "eta": cfg.eta,
            "lb_startup_delay": cfg.lb_startup_delay,
            "lb_interval": cfg.lb_interval,
        },
        "host_seconds_cold": host_cold_s,
        "scan_seconds_cold": scan_cold_s,
        "bitexact_scan_vs_host": bitexact,
        "repartitions_mean": float(
            np.mean([len(ev) for ev in scan.repartition_events])
        ),
        "ordering": ordering,
    }
    if warm_timings:
        out.update(
            host_seconds=host_s,
            scan_seconds=scan_s,
            speedup_scan_over_host=host_s / max(scan_s, 1e-12),
            lb_scan_faster_than_host=bool(scan_s < host_s),
        )
    return out


#: every parameter of the churn column's run — stored inside the column
#: itself so the gate rerun reproduces it without guessing
CHURN_RECIPE = {
    "problem": "logreg_higgs",
    "num_samples": 4096,
    "n_workers": 40,
    "subpartitions": 4,
    "w": 32,
    "eta": 0.25,
    "n_scenarios": 5,
    "num_iterations": 40,
    "eval_every": 5,
    "regime": "heavy_bursts",
    "seed": 0,
    "gap": 0.2,
    # elastic-fleet schedule, as fractions of the churn-free run length:
    # the slowest fifth of the fleet dies at 30% of the run and half of
    # the dead workers rejoin at 70%
    "death_frac": 0.2,
    "death_at_frac": 0.3,
    "revive_frac": 0.5,
    "revive_at_frac": 0.7,
}


def run_churn_column(recipe: dict | None = None) -> dict:
    """DSAG/SAG/coded through an elastic-fleet churn schedule, both engines.

    Builds the same kind of heterogeneous heavy-burst fleet as the main
    convergence grid (smaller: the recipe's N/S/T), derives a
    death-then-partial-rejoin :class:`~repro.latency.model.ChurnSchedule`
    from a churn-free latency replay (deterministic given the seed, so the
    gate rerun lands on the identical schedule), and runs each method
    through the host loop AND the fused scan on the churned traces.
    Fail-able outputs: per-field scan-vs-host bit-exactness under churn
    and the dsag < sag < coded time-to-gap ordering (the paper's §7
    straggler-resilience claim must survive workers dying mid-run).
    """
    import numpy as np

    from repro.core.problems import LogisticRegressionProblem, make_higgs_like
    from repro.experiments import (
        EngineConfig,
        default_convergence_methods,
        run_convergence_batch,
    )
    from repro.experiments.grid import DEFAULT_REGIMES
    from repro.experiments.sweep import replay_batch
    from repro.latency.model import (
        ChurnSchedule,
        make_heterogeneous_cluster,
        sample_fleet,
    )

    r = dict(CHURN_RECIPE)
    if recipe:
        r.update(recipe)
    if r["problem"] != "logreg_higgs":
        raise GridMismatch(
            f"churn recipe problem {r['problem']!r} is not reproducible here"
        )
    regimes = {reg.name: reg for reg in DEFAULT_REGIMES}
    if r["regime"] not in regimes:
        raise GridMismatch(f"unknown regime {r['regime']!r} in churn recipe")
    regime = regimes[r["regime"]]
    X, y = make_higgs_like(r["num_samples"], seed=r["seed"])
    prob = LogisticRegressionProblem(X=X, y=y)
    N, sp, T = r["n_workers"], r["subpartitions"], r["num_iterations"]
    c_task = prob.compute_cost(1, max(prob.num_samples // (N * sp), 1))
    cluster = make_heterogeneous_cluster(
        N, seed=r["seed"], burst_rate=0.0, load_unit=c_task
    )
    traces = sample_fleet(
        cluster,
        r["n_scenarios"],
        T,
        burst_rate=regime.rate,
        burst_factor_mean=regime.factor_mean,
        burst_duration_mean=regime.duration_mean,
        seed=r["seed"] + 1,
    )
    # anchor the schedule to the churn-free run length (latency replay
    # only — no gradients), then kill the slowest workers and revive half
    base = replay_batch(traces, r["w"], T)
    total = float(np.median(base.iteration_times[:, -1]))
    death_at = r["death_at_frac"] * total
    revive_at = r["revive_at_frac"] * total
    sd = np.asarray(traces.slowdown)
    n_dead = max(1, int(round(r["death_frac"] * N)))
    dead = np.argsort(-sd, kind="stable")[:n_dead]
    n_back = int(round(r["revive_frac"] * n_dead))
    revived = dead[:n_back]
    alive0 = np.ones(N, bool)
    alive1 = alive0.copy()
    alive1[dead] = False
    alive2 = alive1.copy()
    alive2[revived] = True
    churn = ChurnSchedule(
        times=np.array([death_at, revive_at]),
        slowdown=np.stack([sd, sd, sd]),
        alive=np.stack([alive0, alive1, alive2]),
    )
    churned = traces.with_churn(churn)
    methods = default_convergence_methods(
        N, w=r["w"], eta=r["eta"], subpartitions=sp
    )
    bitexact = True
    cols: dict[str, dict] = {}
    for name in ("dsag", "sag", "coded"):
        host = run_convergence_batch(
            prob, churned, methods[name], T,
            eval_every=r["eval_every"], seed=r["seed"],
            engine=EngineConfig(kind="host"),
        )
        scan = run_convergence_batch(
            prob, churned, methods[name], T,
            eval_every=r["eval_every"], seed=r["seed"],
            engine=EngineConfig(kind="scan"),
        )
        bitexact = bitexact and bool(
            np.array_equal(host.times, scan.times)
            and np.array_equal(
                host.suboptimality, scan.suboptimality, equal_nan=True
            )
            and np.array_equal(host.fresh_counts, scan.fresh_counts)
            and np.array_equal(
                host.per_worker_latency, scan.per_worker_latency,
                equal_nan=True,
            )
            and host.repartition_events == scan.repartition_events
            and np.array_equal(host.evictions, scan.evictions)
            and np.array_equal(host.rejected_stale, scan.rejected_stale)
        )
        ttg = scan.time_to_gap(r["gap"])
        med = float(np.median(ttg))
        cols[name] = {
            "median_time_to_gap": med if np.isfinite(med) else None,
            "reached_gap_frac": float(np.isfinite(ttg).mean()),
        }
    t_dsag = cols["dsag"]["median_time_to_gap"]
    t_sag = cols["sag"]["median_time_to_gap"]
    t_coded = cols["coded"]["median_time_to_gap"]
    finite = (
        t_dsag is not None and t_sag is not None and t_coded is not None
    )
    ordering = {
        "gap": r["gap"],
        "ordering_dsag_sag_coded": float(
            finite and t_dsag < t_sag < t_coded
        ),
    }
    if finite and t_dsag > 0:
        ordering["sag_over_dsag"] = t_sag / t_dsag
        ordering["coded_over_dsag"] = t_coded / t_dsag
    return {
        "recipe": r,
        "schedule": {
            "death_at": death_at,
            "revive_at": revive_at,
            "dead_workers": [int(i) for i in dead],
            "revived_workers": [int(i) for i in revived],
        },
        "bitexact_scan_vs_host": bitexact,
        "methods": cols,
        "ordering": ordering,
    }


def compare_churn_column(committed: dict, fresh: dict) -> tuple[list[str], list[str]]:
    """Diff the ``churn`` columns; returns (failures, warnings)."""
    failures: list[str] = []
    warnings: list[str] = []
    if not fresh.get("bitexact_scan_vs_host", False):
        failures.append(
            "churn: fused scan no longer bit-exact vs the host engine "
            "under fleet churn"
        )
    old_rank = convergence_ranking(committed["methods"])
    new_rank = convergence_ranking(fresh["methods"])
    if old_rank != new_rank:
        failures.append(
            f"churn: time-to-gap ranking flipped {old_rank} -> {new_rank}"
        )
    old_o, new_o = committed["ordering"], fresh["ordering"]
    if old_o.get("ordering_dsag_sag_coded") != new_o.get(
        "ordering_dsag_sag_coded"
    ):
        failures.append(
            f"churn: ordering_dsag_sag_coded flipped "
            f"{old_o.get('ordering_dsag_sag_coded')} -> "
            f"{new_o.get('ordering_dsag_sag_coded')}"
        )
    for key in SPEEDUP_KEYS:
        if key in old_o and key in new_o and old_o[key] > 0:
            drift = abs(new_o[key] / old_o[key] - 1.0)
            if drift > SPEEDUP_DRIFT_TOLERANCE:
                warnings.append(
                    f"churn: {key} drifted {drift:.0%} "
                    f"({old_o[key]:.2f} -> {new_o[key]:.2f})"
                )
    return failures, warnings


#: every parameter of the live_validation column's run — stored inside the
#: column itself so the gate rerun reproduces it without guessing.  margin
#: is 0 so dsag and sag share masks (identical collection windows) and the
#: comparison isolates the §5 stale-acceptance semantics; the §5.1 margin
#: rule is pinned separately by the test suite.
LIVE_VALIDATION_RECIPE = {
    "problem": "logreg_higgs",
    "num_samples": 512,
    "n_workers": 8,
    "w": 6,
    "eta": 0.25,
    "margin": 0.0,
    "n_scenarios": 2,
    "scenario": 0,
    "num_iterations": 80,
    "eval_every": 5,
    "regime": "heavy_bursts",
    "seed": 0,
    "gap": 0.05,
    #: real seconds slept per unit of virtual straggler time — large enough
    #: that the dsag/sag collection-time difference dominates step compute
    "time_scale": 25.0,
}


def run_live_validation_column(recipe: dict | None = None) -> dict:
    """Run the *live* trainer under injected stragglers; validate it against
    the scalar convergence engine on the same trace.

    The sim-to-live gap, closed twice over:

    * **streams**: the trainer's Tier-2 controller must log exactly the
      (mask, flush, evict) step inputs the scalar simulator records for
      the shared ``FleetTraces`` scenario (the cross-layer pin — fails the
      gate if the live control plane drifts from §5/§6.3 semantics);
    * **wall clock**: ``time_scale`` turns virtual straggler waits into
      real sleeps, so the measured wall time-to-gap per method must
      reproduce the simulator's *predicted* time-to-gap (drift warns) and
      the paper's dsag-before-sag ordering must survive on real hardware
      (a flip fails).
    """
    import numpy as np

    from repro.cluster.simulator import MethodConfig
    from repro.core.problems import LogisticRegressionProblem, make_higgs_like
    from repro.experiments.grid import DEFAULT_REGIMES
    from repro.ft.validation import pin_streams
    from repro.latency.model import make_heterogeneous_cluster, sample_fleet
    from repro.launch.paper_jobs import paper_train_config
    from repro.launch.train import Trainer, TrainerOptions

    r = dict(LIVE_VALIDATION_RECIPE)
    if recipe:
        r.update(recipe)
    if r["problem"] != "logreg_higgs":
        raise GridMismatch(
            f"live_validation recipe problem {r['problem']!r} is not "
            "reproducible here"
        )
    regimes = {reg.name: reg for reg in DEFAULT_REGIMES}
    if r["regime"] not in regimes:
        raise GridMismatch(
            f"unknown regime {r['regime']!r} in live_validation recipe"
        )
    regime = regimes[r["regime"]]
    X, y = make_higgs_like(r["num_samples"], seed=r["seed"])
    prob = LogisticRegressionProblem(X=X, y=y)
    N, T = r["n_workers"], r["num_iterations"]
    c_task = prob.compute_cost(1, max(prob.num_samples // N, 1))
    cluster = make_heterogeneous_cluster(
        N, seed=r["seed"] + 3, burst_rate=0.0, load_unit=c_task
    )
    traces = sample_fleet(
        cluster,
        r["n_scenarios"],
        4 * T,
        burst_rate=regime.rate,
        burst_factor_mean=regime.factor_mean,
        burst_duration_mean=regime.duration_mean,
        seed=r["seed"] + 7,
    )
    methods: dict[str, dict] = {}
    for name in ("dsag", "sag"):
        cfg = MethodConfig(
            name=name, w=r["w"], eta=r["eta"], margin=r["margin"],
            subpartitions=1,
        )
        ctrl, sim, hist = pin_streams(
            prob, cluster, traces, r["scenario"], cfg, T, seed=r["seed"]
        )
        tc = dataclasses.replace(
            paper_train_config(r["eta"]), dsag_margin=r["margin"]
        )
        opts = TrainerOptions(
            arch="logreg",
            steps=T,
            samples=r["num_samples"],
            num_groups=N,
            dsag_w=r["w"],
            method=name,
            traces=traces,
            scenario=r["scenario"],
            train_config=tc,
            simulate_stragglers=False,
            # the detector must not perturb the pin: persistent stragglers
            # are the *subject* here, not failures
            failure_max_misses=10**6,
            time_scale=r["time_scale"],
            eval_every=r["eval_every"],
            log_every=10**6,
            seed=r["seed"],
        )
        live = Trainer(opts).run()
        streams_match = bool(
            ctrl == sim
            and np.array_equal(np.stack(live["mask_stream"]), sim.mask)
            and np.array_equal(np.stack(live["flush_stream"]), sim.flush)
            and np.array_equal(np.stack(live["evict_stream"]), sim.evict)
        )
        virtual_ttg = hist.time_to_gap(r["gap"])
        measured = next(
            (wall for (_s, wall, _v, g) in live["eval"] if g <= r["gap"]), None
        )
        methods[name] = {
            "streams_match_simulator": streams_match,
            "virtual_time_to_gap": (
                float(virtual_ttg) if np.isfinite(virtual_ttg) else None
            ),
            "predicted_time_to_gap_s": (
                float(virtual_ttg * r["time_scale"])
                if np.isfinite(virtual_ttg)
                else None
            ),
            "measured_wall_to_gap_s": (
                float(measured) if measured is not None else None
            ),
            "final_gap_live": float(live["eval"][-1][3]),
            "wall_seconds": float(live["wall_seconds"][0]),
        }
    d, s = methods["dsag"], methods["sag"]
    ordering: dict = {"gap": r["gap"]}
    if (
        d["virtual_time_to_gap"] is not None
        and s["virtual_time_to_gap"] is not None
    ):
        ordering["predicted_dsag_faster_than_sag"] = float(
            d["virtual_time_to_gap"] <= s["virtual_time_to_gap"]
        )
    if (
        d["measured_wall_to_gap_s"] is not None
        and s["measured_wall_to_gap_s"] is not None
    ):
        ordering["live_dsag_faster_than_sag"] = float(
            d["measured_wall_to_gap_s"] < s["measured_wall_to_gap_s"]
        )
        ordering["sag_over_dsag_wall"] = (
            s["measured_wall_to_gap_s"] / d["measured_wall_to_gap_s"]
        )
    for name, m in methods.items():
        if m["predicted_time_to_gap_s"] and m["measured_wall_to_gap_s"]:
            m["measured_over_predicted"] = (
                m["measured_wall_to_gap_s"] / m["predicted_time_to_gap_s"]
            )
    return {"recipe": r, "methods": methods, "ordering": ordering}


def compare_live_validation_column(
    committed: dict, fresh: dict
) -> tuple[list[str], list[str]]:
    """Diff the ``live_validation`` columns; returns (failures, warnings)."""
    failures: list[str] = []
    warnings: list[str] = []
    for name, m in fresh.get("methods", {}).items():
        if not m.get("streams_match_simulator", False):
            failures.append(
                f"live_validation: {name} live trainer streams no longer "
                "match the scalar simulator (sim-to-live pin broken)"
            )
        if m.get("measured_wall_to_gap_s") is None:
            failures.append(
                f"live_validation: live {name} run never reached the gap"
            )
    old_o, new_o = committed.get("ordering", {}), fresh.get("ordering", {})
    # the deterministic (virtual) ordering and the measured wall-clock
    # ordering must both survive — the latter is the paper's actual claim
    for verdict in ("predicted_dsag_faster_than_sag", "live_dsag_faster_than_sag"):
        if old_o.get(verdict) != new_o.get(verdict):
            failures.append(
                f"live_validation: {verdict} flipped "
                f"{old_o.get(verdict)} -> {new_o.get(verdict)}"
            )
    os_, ns_ = old_o.get("sag_over_dsag_wall"), new_o.get("sag_over_dsag_wall")
    if os_ and ns_ and os_ > 0:
        drift = abs(ns_ / os_ - 1.0)
        if drift > SPEEDUP_DRIFT_TOLERANCE:
            warnings.append(
                f"live_validation: sag_over_dsag_wall drifted {drift:.0%} "
                f"({os_:.2f} -> {ns_:.2f}) (wall clock)"
            )
    for name, m in fresh.get("methods", {}).items():
        om = committed.get("methods", {}).get(name, {})
        ov, nv = om.get("measured_over_predicted"), m.get("measured_over_predicted")
        if ov and nv and ov > 0:
            drift = abs(nv / ov - 1.0)
            if drift > SPEEDUP_DRIFT_TOLERANCE:
                warnings.append(
                    f"live_validation: {name} measured_over_predicted drifted "
                    f"{drift:.0%} ({ov:.2f} -> {nv:.2f}) (wall clock)"
                )
    return failures, warnings


#: cross-backend tolerance on the Pallas-vs-XLA suboptimality trajectories.
#: On one platform the comparison must be *bit-exact* (CPU CI runs the
#: Pallas twins in interpret mode against the same jitted arithmetic); the
#: relative tolerance only applies when the artifact and the rerun disagree
#: on platform, where a real Pallas compile may round differently.
KERNEL_BACKEND_REL_TOL = 1e-3

#: every parameter of the kernel_backend column's run — stored inside the
#: column itself so the gate rerun reproduces it without guessing
KERNEL_BACKEND_RECIPE = {
    "seed": 0,
    "n_scenarios": 3,
    "num_iterations": 30,
    "eval_every": 5,
    "n_workers": 8,
    "subpartitions": 3,
    "regime": "heavy_bursts",
    "logreg": {"num_samples": 1024, "w": 6, "eta": 0.25,
               "methods": ["dsag", "sag", "coded"]},
    "pca": {"n_rows": 512, "n_cols": 64, "k": 4, "w": 6, "eta": 0.9,
            "methods": ["dsag", "sag"]},
}


def _trajectory_digest(res) -> str:
    """Short sha256 over a result's deterministic trajectory arrays.

    The artifact stores digests instead of the arrays themselves, so the
    gate rerun can check "bit-exact within a backend" (same platform, same
    backend, same bits) without committing megabytes of trajectories.
    """
    import hashlib

    import numpy as np

    h = hashlib.sha256()
    for arr in (res.times, res.suboptimality, res.fresh_counts):
        h.update(np.ascontiguousarray(np.asarray(arr)).tobytes())
    return h.hexdigest()[:16]


def run_kernel_backend_column(recipe: dict | None = None) -> dict:
    """Pin ``kernel_backend="pallas"`` against ``"xla"`` on both problems.

    Runs the recipe's logreg and PCA method grids through the fused scan
    twice — once per kernel backend — on identical fleets (common random
    numbers).  Fail-able outputs: same-platform Pallas-vs-XLA
    bit-exactness across every result field, per-backend trajectory
    digests (a rerun on the artifact's platform must reproduce each
    backend's bits exactly), and the per-backend method rankings by median
    final suboptimality.  Cross-platform, the digest check is skipped and
    the Pallas-vs-XLA diff is gated by :data:`KERNEL_BACKEND_REL_TOL`
    instead.
    """
    import jax
    import numpy as np

    from repro.core.problems import (
        LogisticRegressionProblem,
        PCAProblem,
        make_genomics_like_matrix,
        make_higgs_like,
    )
    from repro.experiments import (
        EngineConfig,
        default_convergence_methods,
        run_convergence_batch,
    )
    from repro.experiments.grid import DEFAULT_REGIMES
    from repro.latency.model import make_heterogeneous_cluster, sample_fleet

    r = dict(KERNEL_BACKEND_RECIPE)
    if recipe:
        r.update(recipe)
    regimes = {reg.name: reg for reg in DEFAULT_REGIMES}
    if r["regime"] not in regimes:
        raise GridMismatch(
            f"unknown regime {r['regime']!r} in kernel_backend recipe"
        )
    regime = regimes[r["regime"]]
    lr, pc = r["logreg"], r["pca"]
    X, y = make_higgs_like(lr["num_samples"], seed=r["seed"])
    problems = {
        "logreg": (LogisticRegressionProblem(X=X, y=y), lr),
        "pca": (
            PCAProblem(
                X=make_genomics_like_matrix(
                    pc["n_rows"], pc["n_cols"], seed=r["seed"]
                ),
                k=pc["k"],
            ),
            pc,
        ),
    }
    N, sp, T = r["n_workers"], r["subpartitions"], r["num_iterations"]
    bitexact = True
    max_rel = 0.0
    cols: dict[str, dict] = {}
    for pname, (prob, pr) in problems.items():
        c_task = prob.compute_cost(1, max(prob.num_samples // (N * sp), 1))
        cluster = make_heterogeneous_cluster(
            N, seed=r["seed"], burst_rate=0.0, load_unit=c_task
        )
        traces = sample_fleet(
            cluster,
            r["n_scenarios"],
            T,
            burst_rate=regime.rate,
            burst_factor_mean=regime.factor_mean,
            burst_duration_mean=regime.duration_mean,
            seed=r["seed"] + 1,
        )
        methods: dict[str, dict] = {}
        for name in pr["methods"]:
            cfg = default_convergence_methods(
                N, w=pr["w"], eta=pr["eta"], subpartitions=sp
            )[name]
            runs = {}
            for backend in ("xla", "pallas"):
                runs[backend] = run_convergence_batch(
                    prob, traces, cfg, T,
                    eval_every=r["eval_every"], seed=r["seed"],
                    engine=EngineConfig(kind="scan", kernel_backend=backend),
                )
            xla, pal = runs["xla"], runs["pallas"]
            bitexact = bitexact and bool(
                np.array_equal(xla.times, pal.times)
                and np.array_equal(
                    xla.suboptimality, pal.suboptimality, equal_nan=True
                )
                and np.array_equal(xla.fresh_counts, pal.fresh_counts)
                and np.array_equal(
                    xla.per_worker_latency, pal.per_worker_latency,
                    equal_nan=True,
                )
                and xla.repartition_events == pal.repartition_events
                and np.array_equal(xla.evictions, pal.evictions)
                and np.array_equal(xla.rejected_stale, pal.rejected_stale)
            )
            a = np.asarray(xla.suboptimality)
            b = np.asarray(pal.suboptimality)
            fa, fb = np.isfinite(a), np.isfinite(b)
            if not np.array_equal(fa, fb):
                max_rel = float("inf")
            elif fa.any():
                rel = np.abs(a[fa] - b[fa]) / np.maximum(np.abs(a[fa]), 1e-12)
                max_rel = max(max_rel, float(np.max(rel)))
            entry = {}
            for backend, res in runs.items():
                entry[f"median_final_subopt_{backend}"] = float(
                    np.median(np.asarray(res.suboptimality)[:, -1])
                )
                entry[f"digest_{backend}"] = _trajectory_digest(res)
            methods[name] = entry
        rankings = {}
        for backend in ("xla", "pallas"):
            col = f"median_final_subopt_{backend}"
            rankings[backend] = sorted(
                methods, key=lambda m, c=col: (methods[m][c], m)
            )
        cols[pname] = {
            "methods": methods,
            "ranking_xla": rankings["xla"],
            "ranking_pallas": rankings["pallas"],
        }
    return {
        "recipe": r,
        "platform": jax.default_backend(),
        "bitexact_pallas_vs_xla": bitexact,
        "max_rel_diff_pallas_vs_xla": max_rel,
        "problems": cols,
    }


def compare_kernel_backend_column(
    committed: dict, fresh: dict
) -> tuple[list[str], list[str]]:
    """Diff the ``kernel_backend`` columns; returns (failures, warnings)."""
    failures: list[str] = []
    warnings: list[str] = []
    same_platform = committed.get("platform") == fresh.get("platform")
    if not fresh.get("bitexact_pallas_vs_xla", False):
        rel = fresh.get("max_rel_diff_pallas_vs_xla")
        if fresh.get("platform") == "cpu":
            failures.append(
                "kernel_backend: pallas (interpret) no longer bit-exact vs "
                "xla on cpu"
            )
        elif rel is None or rel > KERNEL_BACKEND_REL_TOL:
            failures.append(
                f"kernel_backend: pallas vs xla max relative diff {rel} "
                f"exceeds tolerance {KERNEL_BACKEND_REL_TOL}"
            )
        else:
            warnings.append(
                f"kernel_backend: pallas vs xla not bit-exact on "
                f"{fresh.get('platform')} (max rel diff {rel:.1e}, within "
                "cross-backend tolerance)"
            )
    for pname, old_p in committed.get("problems", {}).items():
        new_p = fresh.get("problems", {}).get(pname)
        if new_p is None:
            failures.append(
                f"kernel_backend: problem column {pname!r} missing from rerun"
            )
            continue
        for backend in ("xla", "pallas"):
            ork = old_p.get(f"ranking_{backend}")
            nrk = new_p.get(f"ranking_{backend}")
            if ork != nrk:
                failures.append(
                    f"kernel_backend: {pname} {backend} final-suboptimality "
                    f"ranking flipped {ork} -> {nrk}"
                )
            for m, om in old_p.get("methods", {}).items():
                nm = new_p.get("methods", {}).get(m, {})
                if same_platform and om.get(f"digest_{backend}") != nm.get(
                    f"digest_{backend}"
                ):
                    failures.append(
                        f"kernel_backend: {pname}/{m} {backend} trajectory "
                        "digest changed (no longer bit-exact within backend)"
                    )
                ov = om.get(f"median_final_subopt_{backend}")
                nv = nm.get(f"median_final_subopt_{backend}")
                if ov and nv and ov > 0:
                    drift = abs(nv / ov - 1.0)
                    if drift > SPEEDUP_DRIFT_TOLERANCE:
                        warnings.append(
                            f"kernel_backend: {pname}/{m} {backend} "
                            f"median_final_subopt drifted {drift:.0%} "
                            f"({ov:.3g} -> {nv:.3g})"
                        )
    return failures, warnings


def run_pca_grid_sharded_column(
    *,
    n_scenarios: int = 40,
    num_devices: int | None = None,
    seed: int = 0,
) -> dict:
    """10x the calibrated paper-scale PCA grid through the *sharded* scan.

    Runs the grid twice — once on a ``num_devices``-wide scenario mesh
    (clamped to the devices actually present; CPU demo via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``) and once on the
    single-device scan — and records per-method bit-exactness between the
    two plus the wall-clock device-scaling ratio.  Orderings and
    bit-exactness are deterministic (gate failures); the scaling ratio is
    wall clock and only ever warns (a single-core runner timeslices its
    fake host devices, so ~1x there is expected).
    """
    import jax
    import numpy as np

    from repro.experiments import (
        EngineConfig,
        convergence_payload,
        paper_scale_pca_sweep,
    )

    avail = len(jax.devices())
    D = min(num_devices if num_devices is not None else 4, avail)
    sharded_out, gap = paper_scale_pca_sweep(
        seed=seed,
        n_scenarios=n_scenarios,
        engine=EngineConfig(kind="scan", num_devices=D),
    )
    plain_out, _ = paper_scale_pca_sweep(
        seed=seed, n_scenarios=n_scenarios, engine=EngineConfig(kind="scan")
    )
    bitexact = all(
        np.array_equal(
            sharded_out.results[m].times, plain_out.results[m].times
        )
        and np.array_equal(
            sharded_out.results[m].suboptimality,
            plain_out.results[m].suboptimality,
            equal_nan=True,
        )
        for m in sharded_out.results
    )
    payload = convergence_payload(sharded_out, gap)
    payload.update(
        num_devices=D,
        seed=seed,
        bitexact_sharded_vs_unsharded=bool(bitexact),
        sharded_seconds=sharded_out.engine_seconds,
        unsharded_seconds=plain_out.engine_seconds,
        device_scaling=plain_out.engine_seconds
        / max(sharded_out.engine_seconds, 1e-12),
    )
    return payload


def compare_pca_grid_sharded(committed: dict, fresh: dict) -> tuple[list[str], list[str]]:
    """Diff the ``pca_grid_sharded`` columns; returns (failures, warnings)."""
    failures: list[str] = []
    warnings: list[str] = []
    if not fresh.get("bitexact_sharded_vs_unsharded", False):
        failures.append(
            "pca_grid_sharded: sharded grid no longer bit-exact vs the "
            "single-device scan"
        )
    old_rank = convergence_ranking(committed["methods"])
    new_rank = convergence_ranking(fresh["methods"])
    if old_rank != new_rank:
        failures.append(
            f"pca_grid_sharded: time-to-gap ranking flipped "
            f"{old_rank} -> {new_rank}"
        )
    old_o, new_o = committed["ordering"], fresh["ordering"]
    for verdict in ("dsag_fastest_to_gap", "ordering_dsag_sag_coded"):
        if old_o.get(verdict) != new_o.get(verdict):
            failures.append(
                f"pca_grid_sharded: {verdict} flipped "
                f"{old_o.get(verdict)} -> {new_o.get(verdict)}"
            )
    for key in CONV_SPEEDUP_KEYS:
        if key in old_o and key in new_o and old_o[key] and old_o[key] > 0:
            drift = abs(new_o[key] / old_o[key] - 1.0)
            if drift > SPEEDUP_DRIFT_TOLERANCE:
                warnings.append(
                    f"pca_grid_sharded: {key} drifted {drift:.0%} "
                    f"({old_o[key]:.2f} -> {new_o[key]:.2f})"
                )
    # the device-scaling ratio is wall clock (and ~1x on a single-core
    # runner timeslicing fake host devices) — drift only warns
    os_, ns_ = committed.get("device_scaling"), fresh.get("device_scaling")
    if os_ and ns_ and os_ > 0:
        drift = abs(ns_ / os_ - 1.0)
        if drift > SPEEDUP_DRIFT_TOLERANCE:
            warnings.append(
                f"pca_grid_sharded: device_scaling drifted {drift:.0%} "
                f"({os_:.2f} -> {ns_:.2f}) on "
                f"{fresh.get('num_devices')} device(s) (wall clock)"
            )
    return failures, warnings


def rerun_convergence(committed: dict) -> dict:
    """Re-execute the committed convergence grid from its ``recipe``.

    The recipe section records every parameter of the committed run
    (problem constructor, cluster, methods, LB schedule); artifacts
    without one predate the gate and must be regenerated
    (:class:`GridMismatch`).  The scalar-timing and ``pca_paper_scale``
    sections are not re-run.
    """
    import numpy as np

    from repro.core.problems import LogisticRegressionProblem, make_higgs_like
    from repro.experiments import (
        convergence_payload,
        default_convergence_methods,
        run_convergence_sweep,
    )
    from repro.experiments.grid import DEFAULT_REGIMES
    from repro.latency.model import make_heterogeneous_cluster

    recipe = committed.get("recipe")
    if recipe is None:
        raise GridMismatch(
            "the committed BENCH_convergence.json has no recipe section; "
            "regenerate it with benchmarks.paper_figs.fig10_12_convergence_sweep"
        )
    if recipe["problem"] != "logreg_higgs":
        raise GridMismatch(
            f"recipe problem {recipe['problem']!r} is not reproducible here"
        )
    regimes = {r.name: r for r in DEFAULT_REGIMES}
    if recipe["regime"] not in regimes:
        raise GridMismatch(f"unknown regime {recipe['regime']!r} in recipe")
    X, y = make_higgs_like(recipe["num_samples"], seed=recipe["seed"])
    prob = LogisticRegressionProblem(X=X, y=y)
    N, sp = recipe["n_workers"], recipe["subpartitions"]
    c_task = prob.compute_cost(1, max(prob.num_samples // (N * sp), 1))
    cluster = make_heterogeneous_cluster(
        N, seed=recipe["seed"], burst_rate=0.0, load_unit=c_task
    )
    methods = default_convergence_methods(
        N, w=recipe["w"], eta=recipe["eta"], subpartitions=sp
    )
    out = run_convergence_sweep(
        prob,
        cluster,
        methods,
        n_scenarios=recipe["n_scenarios"],
        num_iterations=recipe["num_iterations"],
        eval_every=recipe["eval_every"],
        regime=regimes[recipe["regime"]],
        seed=recipe["seed"],
    )
    payload = convergence_payload(out, recipe["gap"])
    if "lb_scan" in committed:
        lb_cfg = dataclasses.replace(
            methods["dsag"],
            lb_startup_delay=recipe["lb"]["lb_startup_delay"],
            lb_interval=recipe["lb"]["lb_interval"],
        )
        base_medians = {
            name: float(np.median(res.time_to_gap(recipe["gap"])))
            for name, res in out.results.items()
        }
        payload["lb_scan"] = run_lb_scan_column(
            prob,
            out.traces,
            lb_cfg,
            num_iterations=recipe["num_iterations"],
            eval_every=recipe["eval_every"],
            seed=recipe["seed"],
            gap=recipe["gap"],
            base_medians=base_medians,
            # gate mode: one run per engine covers every fail-able check;
            # the warn-only wall-clock fields are left out
            warm_timings=False,
        )
    if "pca_grid_sharded" in committed:
        ps = committed["pca_grid_sharded"]
        payload["pca_grid_sharded"] = run_pca_grid_sharded_column(
            n_scenarios=ps["grid"]["n_scenarios"],
            num_devices=ps.get("num_devices"),
            seed=ps.get("seed", 0),
        )
    if "churn" in committed:
        payload["churn"] = run_churn_column(committed["churn"].get("recipe"))
    if "kernel_backend" in committed:
        payload["kernel_backend"] = run_kernel_backend_column(
            committed["kernel_backend"].get("recipe")
        )
    if "live_validation" in committed:
        payload["live_validation"] = run_live_validation_column(
            committed["live_validation"].get("recipe")
        )
    return payload


def main(argv: list[str]) -> int:
    args = [a for a in argv[1:] if not a.startswith("--")]
    path = args[0] if args else "BENCH_sweep.json"
    kind = "sweep"
    if "--kind" in argv:
        kind = argv[argv.index("--kind") + 1]
    elif "convergence" in path:
        kind = "convergence"
    if kind == "tracelint":
        # gate mode over the static-analysis registry: any non-baselined
        # finding fails; a suppression that no longer matches anything
        # warns (stale documented debt — delete it)
        from repro.analysis.lint import load_baseline, run_lint

        report = run_lint("all", baseline_path="tracelint.toml")
        used = [s for _, s in report.suppressed]
        for supp in load_baseline("tracelint.toml"):
            if supp not in used:
                print(f"WARN: stale suppression {supp.code} ({supp.entry})")
        for f in report.findings:
            print(f"FAIL: {f.render()}")
        if report.findings:
            print(f"tracelint regression: {len(report.findings)} finding(s)")
            return 1
        print(
            f"tracelint: clean across {len(report.entries_run)} entries "
            f"({len(report.suppressed)} baselined finding(s))"
        )
        return 0
    try:
        with open(path) as fh:
            committed = json.load(fh)
    except FileNotFoundError:
        print(f"FAIL: committed artifact {path} not found")
        return 1
    try:
        if kind == "convergence":
            fresh = rerun_convergence(committed)
            failures, warnings = compare_convergence(committed, fresh)
            scope = "convergence grid + lb_scan column"
            if "pca_grid_sharded" in committed:
                scope += " + pca_grid_sharded column"
            if "churn" in committed:
                scope += " + churn column"
            if "kernel_backend" in committed:
                scope += " + kernel_backend column"
            if "live_validation" in committed:
                scope += " + live_validation column"
        else:
            fresh = rerun_grid(committed)
            failures, warnings = compare_sweep(committed, fresh)
            scope = f"{len(committed['grid']['regimes'])} regimes"
    except GridMismatch as exc:
        print(f"FAIL: {exc}")
        return 1
    for w in warnings:
        print(f"WARN: {w}")
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        print(f"benchmark regression: {len(failures)} ordering flip(s)")
        return 1
    print(
        f"benchmark regression: ordering stable across {scope}"
        + (f" ({len(warnings)} drift warning(s))" if warnings else "")
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
