"""Aggregate the dry-run artifacts into the §Roofline table.

Reads experiments/dryrun/<mesh>/*.json and emits (a) CSV rows via the
benchmark contract and (b) a markdown table at experiments/roofline.md that
EXPERIMENTS.md embeds."""

from __future__ import annotations

import json
import os

from benchmarks.common import record

BASE = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "experiments")


def load_cells(mesh: str) -> list[dict]:
    d = os.path.join(BASE, "dryrun", mesh)
    if not os.path.isdir(d):
        return []
    cells = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                cells.append(json.load(fh))
    return cells


def bottleneck_hint(cell: dict) -> str:
    rl = cell["roofline"]
    dom = rl["dominant"]
    if dom == "collective":
        return "reduce collective volume (sharding/compression/overlap)"
    if dom == "memory":
        if cell["shape"].startswith("decode"):
            return "KV-cache traffic bound: quantize cache / batch heads"
        return "activation+logit traffic: fuse loss, selective remat"
    return "MXU-bound: raise arithmetic intensity / reduce padding waste"


def run_all() -> None:
    rows = []
    for cell in load_cells("16x16"):
        if cell.get("status") != "ok":
            continue
        rl = cell["roofline"]
        name = f"roofline_{cell['arch']}_{cell['shape']}"
        derived = (
            f"c={rl['compute_s']:.3g}s;m={rl['memory_s']:.3g}s;"
            f"x={rl['collective_s']:.3g}s;dom={rl['dominant']};"
            f"mfu={rl['mfu']:.3f};useful={rl['useful_flops_fraction']:.2f}"
        )
        record(name, rl["step_time_s"] * 1e6, derived)
        rows.append(cell)

    # markdown table
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | MFU bound | GiB/device | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for cell in rows:
        rl = cell["roofline"]
        mem = cell["memory"]["peak_estimate_bytes"] / 2**30
        lines.append(
            f"| {cell['arch']} | {cell['shape']} | {rl['compute_s']:.4g} | "
            f"{rl['memory_s']:.4g} | {rl['collective_s']:.4g} | {rl['dominant']} | "
            f"{rl['useful_flops_fraction']:.2f} | {rl['mfu']:.3f} | {mem:.1f} | "
            f"{bottleneck_hint(cell)} |"
        )
    os.makedirs(BASE, exist_ok=True)
    with open(os.path.join(BASE, "roofline.md"), "w") as f:
        f.write("\n".join(lines) + "\n")
    n_multi = sum(1 for c in load_cells("2x16x16") if c.get("status") == "ok")
    record("dryrun_multipod_cells_ok", 0.0, f"count={n_multi}")
