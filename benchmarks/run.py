"""Benchmark driver: one benchmark per paper table/figure plus kernel
microbenchmarks and the dry-run roofline report.

Prints ``name,us_per_call,derived`` CSV rows (the contract of this repo)."""

from __future__ import annotations


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import kernels_bench, paper_figs, roofline_report, tracelint_bench

    paper_figs.run_all()
    kernels_bench.run_all()
    roofline_report.run_all()
    tracelint_bench.run_all()


if __name__ == "__main__":
    main()
