"""Batched serving: prefill a prompt batch on the hybrid (zamba2) smoke model
and decode greedily with the O(1)-state SSM cache.

  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-2.7b
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.launch.serve import Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    srv = Server(args.arch, smoke=True, max_len=args.prompt_len + args.tokens + 8)
    cfg = srv.cfg
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
        )
    }
    if cfg.family == "enc_dec":
        batch["audio_embed"] = jnp.asarray(
            0.1 * rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
    elif cfg.family == "vlm":
        batch["image_embed"] = jnp.asarray(
            0.1 * rng.normal(size=(args.batch, cfg.num_image_tokens, cfg.d_model)), jnp.bfloat16)
    t0 = time.time()
    out = srv.generate(batch, args.tokens)
    dt = time.time() - t0
    print(f"[{args.arch}] generated {out.shape[0]}x{out.shape[1]} tokens "
          f"in {dt:.2f}s ({out.size / dt:.1f} tok/s, CPU smoke config)")
    print("first sequence:", np.asarray(out[0])[:16], "...")


if __name__ == "__main__":
    main()
