"""Quickstart: train a reduced qwen1.5-0.5b with DSAG straggler resilience on
CPU, checkpoint it, kill a group mid-run, and keep converging.

  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.configs import TrainConfig
from repro.launch.train import Trainer, TrainerOptions


def main() -> None:
    with tempfile.TemporaryDirectory() as ckpt:
        tc = TrainConfig(
            dsag=True,  # the paper's method: masked stale-tolerant updates
            optimizer="adamw",
            learning_rate=1e-3,
            checkpoint_every=50,
        )
        opts = TrainerOptions(
            arch="qwen1.5-0.5b",
            smoke=True,
            steps=150,
            global_batch=8,
            seq_len=128,
            checkpoint_dir=ckpt,
            train_config=tc,
            log_every=25,
        )
        trainer = Trainer(opts)
        history = trainer.run()
        print(
            f"\nquickstart done: loss {history['loss'][0]:.3f} -> "
            f"{history['loss'][-1]:.3f}; "
            f"stragglers masked in {sum(1 for m in history['mask_count'] if m < trainer.gs.num_groups)}"
            f"/{len(history['mask_count'])} steps"
        )


if __name__ == "__main__":
    main()
