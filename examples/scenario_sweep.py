"""Run a §7 scenario sweep with the vectorized engine and print the grid.

  PYTHONPATH=src python examples/scenario_sweep.py
  PYTHONPATH=src python examples/scenario_sweep.py --workers 100 --seeds 10 \
      --iters 100 --out BENCH_sweep.json --check-scalar

Sweeps (seeds x methods x w x burst regimes) in one batched pass — GD, the
idealized coded bound, SGD, SAG, and DSAG across calm / paper / heavy burst
regimes — and reports the paper's headline ordering (DSAG faster than SAG
and coded under burst stragglers).
"""

import argparse

from repro.experiments import (
    paper_ordering,
    run_sweep,
    scalar_sweep_seconds,
    write_bench_sweep,
)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=50)
    ap.add_argument("--seeds", type=int, default=5)
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--w-frac", type=float, nargs="+", default=[0.8])
    ap.add_argument("--out", default=None, help="write BENCH-style JSON here")
    ap.add_argument(
        "--check-scalar",
        action="store_true",
        help="also time the scalar event-loop baseline (slow)",
    )
    args = ap.parse_args()

    out = run_sweep(
        n_workers=args.workers,
        n_seeds=args.seeds,
        num_iterations=args.iters,
        w_fracs=tuple(args.w_frac),
    )
    print(
        f"{len(out.results)} cells x {args.seeds} seeds in "
        f"{out.engine_seconds:.3f}s (vectorized engine)"
    )
    scalar_s = None
    if args.check_scalar:
        scalar_s = scalar_sweep_seconds(out)
        print(f"scalar event loop: {scalar_s:.2f}s "
              f"({scalar_s / out.engine_seconds:.1f}x slower)")

    header = f"{'regime':>14} {'method':>6} {'w':>4} {'mean iter (ms)':>15} {'fresh':>6}"
    print(header)
    print("-" * len(header))
    seen = set()
    for r in out.rows:
        key = (r.regime, r.method, r.w)
        if key in seen:
            continue
        seen.add(key)
        print(
            f"{r.regime:>14} {r.method:>6} {r.w:>4} "
            f"{1e3 * out.mean_iter_time(r.regime, r.method, r.w):>15.4f} "
            f"{r.mean_fresh:>6.1f}"
        )

    for regime in sorted({r.regime for r in out.rows}):
        o = paper_ordering(out, regime)
        print(
            f"{regime}: sag/dsag={o['sag_over_dsag']:.2f}x "
            f"coded/dsag={o['coded_over_dsag']:.2f}x "
            f"dsag_beats_sag_and_coded={bool(o['dsag_beats_sag_and_coded'])}"
        )

    if args.out:
        write_bench_sweep(out, args.out, scalar_seconds=scalar_s)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
