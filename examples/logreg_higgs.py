"""The paper's logistic-regression experiment (§7, Fig. 8 right) with dynamic
load balancing: HIGGS-like data, 16 workers, DSAG vs DSAG-LB vs SAG.

  PYTHONPATH=src python examples/logreg_higgs.py
"""

import numpy as np

from repro.cluster.simulator import MethodConfig, TrainingSimulator
from repro.core.problems import LogisticRegressionProblem, make_higgs_like
from repro.latency.model import clear_slowdowns, make_paper_artificial_cluster


def main() -> None:
    X, y = make_higgs_like(16384, seed=0)
    problem = LogisticRegressionProblem(X=X, y=y)  # lambda = 1/n, as the paper
    N, SP = 16, 10
    c_task = problem.compute_cost(1, problem.num_samples // (N * SP))

    def run(name, w, iters, eta, lb=False):
        cluster = make_paper_artificial_cluster(num_workers=N, load_unit=c_task, seed=1)
        events = [(1.0, lambda c: clear_slowdowns(c, range(N - 4, N)))]
        cfg = MethodConfig(name=name, w=w, eta=eta, subpartitions=SP, load_balance=lb)
        sim = TrainingSimulator(problem, cluster, cfg, eval_every=25,
                                timed_events=events, seed=0)
        h = sim.run(iters)
        gap = h.suboptimality[np.isfinite(h.suboptimality)][-1]
        tag = name + ("-lb" if lb else "")
        print(f"  {tag:8s} w={w:3d}: gap {gap:.2e}  sim {h.times[-1]:.2f} s  "
              f"repartitions={len(h.repartition_events)}")
        return h

    print(f"Logistic regression, n={problem.num_samples}, N={N} workers:")
    h_sagN = run("sag", N, 1200, 0.25)
    run("sag", 4, 1200, 0.25)
    h = run("dsag", 4, 1200, 0.25)
    h_lb = run("dsag", 4, 1200, 0.25, lb=True)
    gap = 1e-4
    print(f"\ntime to {gap:.0e} gap: SAG(w=N) {h_sagN.time_to_gap(gap):.2f} s, "
          f"DSAG {h.time_to_gap(gap):.2f} s, DSAG-LB {h_lb.time_to_gap(gap):.2f} s")


if __name__ == "__main__":
    main()
