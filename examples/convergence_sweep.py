"""Run a batched §7 *convergence* sweep (time-to-suboptimality) and print
each method's time-to-gap across scenarios.

  PYTHONPATH=src python examples/convergence_sweep.py
  PYTHONPATH=src python examples/convergence_sweep.py --workers 100 \
      --scenarios 10 --iters 60 --gap 0.2 --out BENCH_convergence.json \
      --check-scalar
  PYTHONPATH=src python examples/convergence_sweep.py --problem pca \
      --paper-scale                     # the n=50k genomics-like matrix

Runs DSAG, SAG (w = N), SGD, and the idealized coded bound through the full
training loop (gradient cache, §5.1 margin, stale integration) on one
shared heavy-burst trace draw — all scenarios resolved at once by the fused
``jax.lax.scan`` convergence engine (``--engine host`` selects the
numpy-driven batched loop instead), which is bit-exact against the scalar
``TrainingSimulator`` (``--check-scalar`` verifies one scenario end to end
and times the scalar loop for the speedup report).  ``--devices D`` shards
the scenario axis over a D-device mesh (bit-exact vs the single-device
scan); on CPU demo with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.

``--problem pca`` switches the workload to PCA of a synthetic genomics-like
matrix (paper §2); ``--paper-scale`` applies the calibrated paper-scale
configuration (n=50k rows, 50 workers, eta/gap per
``repro.experiments.convergence.PAPER_SCALE_PCA``) — the committed
``BENCH_convergence.json`` carries this run as its ``pca_paper_scale``
column.
"""

import argparse

import numpy as np

from repro.cluster.simulator import effective_w
from repro.core.problems import (
    LogisticRegressionProblem,
    PCAProblem,
    make_genomics_like_matrix,
    make_higgs_like,
)
from repro.experiments import (
    PAPER_SCALE_PCA,
    EngineConfig,
    convergence_ordering,
    default_convergence_methods,
    paper_scale_pca_sweep,
    run_convergence_sweep,
    scalar_convergence_run,
    scalar_convergence_seconds,
    write_bench_convergence,
)
from repro.experiments.grid import HEAVY_BURSTS
from repro.latency.model import make_heterogeneous_cluster


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--problem", choices=("logreg", "pca"), default="logreg")
    ap.add_argument(
        "--paper-scale",
        action="store_true",
        help="run the calibrated paper-scale PCA sweep (implies --problem pca; "
        "n=50k rows, 50 workers, gap per PAPER_SCALE_PCA)",
    )
    ap.add_argument("--workers", type=int, default=40)
    ap.add_argument("--scenarios", type=int, default=6)
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--cols", type=int, default=96,
                    help="columns of the PCA matrix (pca only)")
    ap.add_argument("--w-frac", type=float, default=0.8)
    ap.add_argument("--subpartitions", type=int, default=10)
    ap.add_argument("--eta", type=float, default=None,
                    help="step size (default 0.25 for logreg, 0.9 for pca)")
    ap.add_argument("--gap", type=float, default=None,
                    help="time-to-gap threshold (default 0.2 logreg, 1e-4 pca)")
    ap.add_argument("--eval-every", type=int, default=4)
    ap.add_argument("--engine", choices=("auto", "scan", "host"), default="auto",
                    help="fused jax.lax.scan engine (auto/scan) or the "
                    "numpy-driven batched host loop")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the scenario axis of the fused scan over "
                    "this many devices (CPU demo: set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    ap.add_argument("--slot-budget", type=int, default=None,
                    help="override the fused engine's §6 slot budget "
                    "(default repro.experiments.fused.LB_MAX_SLOTS)")
    ap.add_argument("--kernel-backend", choices=("xla", "pallas"),
                    default="xla",
                    help="route the fused scan's §3 block-subgradient and "
                    "§5 grid-cache hot paths through the Pallas kernel "
                    "twins (interpret mode on CPU; bit-exact vs xla)")
    ap.add_argument("--load-balance", action="store_true",
                    help="run DSAG with the §6 load balancer in the loop "
                    "(runs inside the fused scan; slot universes above the "
                    "budget use the tiled active-slot cache)")
    ap.add_argument("--out", default=None, help="write BENCH-style JSON here")
    ap.add_argument(
        "--check-scalar",
        action="store_true",
        help="verify one scenario against the scalar TrainingSimulator "
        "(bit-exact) and time the scalar loop (slow)",
    )
    args = ap.parse_args()
    if args.paper_scale:
        args.problem = "pca"
    engine = EngineConfig(
        kind=args.engine,
        num_devices=args.devices,
        slot_budget=args.slot_budget,
        eval_every=args.eval_every,
        kernel_backend=args.kernel_backend,
    )

    if args.paper_scale:
        out, default_gap = paper_scale_pca_sweep(seed=0, engine=engine)
        N = out.traces.num_workers
        print(
            f"paper-scale PCA: n={out.problem.num_samples} rows, {N} workers, "
            f"{out.traces.num_scenarios} scenarios, {out.num_iterations} iters "
            f"(PAPER_SCALE_PCA={PAPER_SCALE_PCA})"
        )
    else:
        if args.problem == "pca":
            prob = PCAProblem(
                X=make_genomics_like_matrix(args.samples, args.cols, seed=0), k=3
            )
            eta = 0.9 if args.eta is None else args.eta
            default_gap = 1e-4
        else:
            X, y = make_higgs_like(args.samples, seed=0)
            prob = LogisticRegressionProblem(X=X, y=y)
            eta = 0.25 if args.eta is None else args.eta
            default_gap = 0.2
        N, sp = args.workers, args.subpartitions
        c_task = prob.compute_cost(1, max(prob.num_samples // (N * sp), 1))
        cluster = make_heterogeneous_cluster(
            N, seed=0, burst_rate=0.0, load_unit=c_task
        )
        w = min(max(round(args.w_frac * N), 1), N)
        methods = default_convergence_methods(
            N, w=w, eta=eta, subpartitions=sp,
            load_balance_dsag=args.load_balance,
        )
        out = run_convergence_sweep(
            prob, cluster, methods,
            n_scenarios=args.scenarios, num_iterations=args.iters,
            eval_every=args.eval_every, regime=HEAVY_BURSTS, seed=0,
            engine=engine,
        )
    gap = default_gap if args.gap is None else args.gap
    print(
        f"{len(out.methods)} methods x {out.traces.num_scenarios} scenarios x "
        f"{out.num_iterations} iterations in {out.engine_seconds:.2f}s "
        f"({args.engine} engine"
        + (f", {args.devices}-device grid" if args.devices else "")
        + (", pallas kernels" if args.kernel_backend == "pallas" else "")
        + ")"
    )

    scalar_s = measured = None
    if args.check_scalar:
        h = scalar_convergence_run(out, "dsag", 0)
        res = out.results["dsag"]
        assert np.array_equal(h.times, res.times[0])
        assert np.array_equal(h.suboptimality, res.suboptimality[0], equal_nan=True)
        print("scalar TrainingSimulator replay of scenario 0: bit-exact")
        measured, scalar_s = scalar_convergence_seconds(
            out, methods=("dsag", "sag"), max_scenarios=2
        )
        print(f"scalar loop (dsag+sag pair, extrapolated): {scalar_s:.1f}s")

    header = f"{'method':>6} {'w':>4} {'median t->gap (s)':>18} {'final gap':>11} {'total t (s)':>12}"
    print(header)
    print("-" * len(header))
    for name, res in out.results.items():
        ttg = res.time_to_gap(gap)
        print(
            f"{name:>6} {effective_w(out.methods[name], N):>4} "
            f"{np.median(ttg):>18.4f} "
            f"{np.nanmean(res.suboptimality[:, -1]):>11.2e} "
            f"{res.times[:, -1].mean():>12.3f}"
        )
    o = convergence_ordering(out, gap)
    print(
        f"gap={gap}: sag/dsag={o['sag_over_dsag']:.2f}x "
        f"coded/dsag={o['coded_over_dsag']:.2f}x "
        f"dsag_fastest={bool(o['dsag_fastest_to_gap'])}"
    )

    if args.out:
        write_bench_convergence(
            out, args.out, gap=gap,
            scalar_seconds=scalar_s, scalar_seconds_measured=measured,
            scalar_methods=["dsag", "sag"] if scalar_s is not None else None,
        )
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
