"""Run a batched §7 *convergence* sweep (time-to-suboptimality) and print
each method's time-to-gap across scenarios.

  PYTHONPATH=src python examples/convergence_sweep.py
  PYTHONPATH=src python examples/convergence_sweep.py --workers 100 \
      --scenarios 10 --iters 60 --gap 0.2 --out BENCH_convergence.json \
      --check-scalar

Runs DSAG, SAG (w = N), SGD, and the idealized coded bound through the full
training loop (gradient cache, §5.1 margin, stale integration) on one
shared heavy-burst trace draw — all scenarios resolved at once by the
batched convergence engine, which is bit-exact against the scalar
``TrainingSimulator`` (``--check-scalar`` verifies one scenario end to end
and times the scalar loop for the speedup report).
"""

import argparse

import numpy as np

from repro.cluster.simulator import effective_w
from repro.core.problems import LogisticRegressionProblem, make_higgs_like
from repro.experiments import (
    convergence_ordering,
    default_convergence_methods,
    run_convergence_sweep,
    scalar_convergence_run,
    scalar_convergence_seconds,
    write_bench_convergence,
)
from repro.experiments.grid import HEAVY_BURSTS
from repro.latency.model import make_heterogeneous_cluster


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workers", type=int, default=40)
    ap.add_argument("--scenarios", type=int, default=6)
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--w-frac", type=float, default=0.8)
    ap.add_argument("--subpartitions", type=int, default=10)
    ap.add_argument("--eta", type=float, default=0.25)
    ap.add_argument("--gap", type=float, default=0.2)
    ap.add_argument("--eval-every", type=int, default=4)
    ap.add_argument("--load-balance", action="store_true",
                    help="run DSAG with the §6 load balancer in the loop")
    ap.add_argument("--out", default=None, help="write BENCH-style JSON here")
    ap.add_argument(
        "--check-scalar",
        action="store_true",
        help="verify one scenario against the scalar TrainingSimulator "
        "(bit-exact) and time the scalar loop (slow)",
    )
    args = ap.parse_args()

    X, y = make_higgs_like(args.samples, seed=0)
    prob = LogisticRegressionProblem(X=X, y=y)
    N, sp = args.workers, args.subpartitions
    c_task = prob.compute_cost(1, max(prob.num_samples // (N * sp), 1))
    cluster = make_heterogeneous_cluster(N, seed=0, burst_rate=0.0, load_unit=c_task)
    w = min(max(round(args.w_frac * N), 1), N)
    methods = default_convergence_methods(
        N, w=w, eta=args.eta, subpartitions=sp,
        load_balance_dsag=args.load_balance,
    )
    out = run_convergence_sweep(
        prob, cluster, methods,
        n_scenarios=args.scenarios, num_iterations=args.iters,
        eval_every=args.eval_every, regime=HEAVY_BURSTS, seed=0,
    )
    print(
        f"{len(methods)} methods x {args.scenarios} scenarios x {args.iters} "
        f"iterations in {out.engine_seconds:.2f}s (batched engine)"
    )

    scalar_s = measured = None
    if args.check_scalar:
        h = scalar_convergence_run(out, "dsag", 0)
        res = out.results["dsag"]
        assert np.array_equal(h.times, res.times[0])
        assert np.array_equal(h.suboptimality, res.suboptimality[0], equal_nan=True)
        print("scalar TrainingSimulator replay of scenario 0: bit-exact")
        measured, scalar_s = scalar_convergence_seconds(
            out, methods=("dsag", "sag"), max_scenarios=2
        )
        print(f"scalar loop (dsag+sag pair, extrapolated): {scalar_s:.1f}s")

    header = f"{'method':>6} {'w':>4} {'median t->gap (s)':>18} {'final gap':>11} {'total t (s)':>12}"
    print(header)
    print("-" * len(header))
    for name, res in out.results.items():
        ttg = res.time_to_gap(args.gap)
        print(
            f"{name:>6} {effective_w(out.methods[name], N):>4} "
            f"{np.median(ttg):>18.4f} "
            f"{np.nanmean(res.suboptimality[:, -1]):>11.4f} "
            f"{res.times[:, -1].mean():>12.3f}"
        )
    o = convergence_ordering(out, args.gap)
    print(
        f"gap={args.gap}: sag/dsag={o['sag_over_dsag']:.2f}x "
        f"coded/dsag={o['coded_over_dsag']:.2f}x "
        f"dsag_fastest={bool(o['dsag_fastest_to_gap'])}"
    )

    if args.out:
        write_bench_convergence(
            out, args.out, gap=args.gap,
            scalar_seconds=scalar_s, scalar_seconds_measured=measured,
            scalar_methods=["dsag", "sag"] if scalar_s is not None else None,
        )
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
