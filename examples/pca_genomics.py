"""The paper's PCA experiment (§7, Fig. 8 left): distributed power-method PCA
of a genomics-like sparse binary matrix on a simulated 16-worker cluster,
comparing GD / SAG / DSAG / coded computing under persistent stragglers.

  PYTHONPATH=src python examples/pca_genomics.py
"""

import numpy as np

from repro.cluster.simulator import MethodConfig, TrainingSimulator
from repro.core.problems import PCAProblem, make_genomics_like_matrix
from repro.latency.model import clear_slowdowns, make_paper_artificial_cluster


def main() -> None:
    X = make_genomics_like_matrix(8192, 128, density=0.0536, seed=0)
    problem = PCAProblem(X=X, k=3)  # top-3 principal components, as the paper
    N, SP = 16, 10
    c_task = problem.compute_cost(1, problem.num_samples // (N * SP))

    def run(name, w, iters, eta):
        cluster = make_paper_artificial_cluster(num_workers=N, load_unit=c_task, seed=1)
        events = [(1.0, lambda c: clear_slowdowns(c, range(N - 4, N)))]
        cfg = MethodConfig(name=name, w=w, eta=eta, subpartitions=SP)
        sim = TrainingSimulator(problem, cluster, cfg, eval_every=20,
                                timed_events=events, seed=0)
        h = sim.run(iters)
        gap = h.suboptimality[np.isfinite(h.suboptimality)][-1]
        print(f"  {name:6s} w={w:3d}: final gap {gap:.2e}  sim time {h.times[-1]:.2f} s")
        return h

    print(f"PCA of {X.shape} matrix (density {X.mean():.3f}), N={N} workers:")
    run("gd", N, 120, 1.0)       # == the power method (paper §7)
    run("coded", N, 120, 1.0)    # idealized MDS bound, rate 45/49
    run("sag", N, 400, 0.9)
    run("sag", 4, 400, 0.9)      # stalls: straggler samples never enter
    h = run("dsag", 4, 400, 0.9)  # converges with w << N
    print(f"\nDSAG time to 1e-6 gap: {h.time_to_gap(1e-6):.2f} s (simulated)")


if __name__ == "__main__":
    main()
