"""Docs link checker (the CI docs job).

Scans ``docs/*.md`` and ``README.md`` for markdown links and inline-code
path references and verifies that every *repo-relative* target exists.
External (``http(s)://``) links are not fetched — CI must not depend on
network availability — but their markdown syntax is validated.

Run from anywhere inside the repo:

    python docs/check_docs.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")
# backtick path references like `src/repro/core/problems.py` or `docs/FOO.md`
CODE_PATH_RE = re.compile(
    r"`([A-Za-z0-9_./-]+\.(?:py|md|json|txt|toml|yml))`"
)


def check_file(path: Path, repo_root: Path) -> list:
    errors = []
    text = path.read_text()
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        resolved = (path.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{path}: broken link -> {target}")
    for m in CODE_PATH_RE.finditer(text):
        target = m.group(1)
        # only treat it as a path claim when it names a directory we ship
        if not target.split("/")[0] in (
            "src", "docs", "tests", "benchmarks", "examples", ".github"
        ) and "/" in target:
            continue
        if "/" not in target:
            continue
        if not (repo_root / target).exists():
            errors.append(f"{path}: referenced path missing -> {target}")
    return errors


def main() -> int:
    repo_root = Path(__file__).resolve().parent.parent
    files = sorted((repo_root / "docs").glob("*.md")) + [repo_root / "README.md"]
    errors = []
    for f in files:
        errors.extend(check_file(f, repo_root))
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"checked {len(files)} files: " + ("FAIL" if errors else "ok"))
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
