"""Paper problems (§2/§7) packaged for the live two-tier trainer.

``launch/train.py`` drives the compiled Tier-1 ``dsag_update`` through a
``loss_fn(params, batch)`` with a leading group dim; this module adapts
:class:`~repro.core.problems.LogisticRegressionProblem` and
:class:`~repro.core.problems.PCAProblem` to that interface so a real CPU
logreg/PCA job can run through the *live* system and be validated against
the convergence engines (the ``live_validation`` BENCH column).

Group g owns the paper's partition ``[p_start(n, G, g+1), p_stop(...)]``
and its per-group loss is scaled so that the mean over groups equals the
full objective:

    logreg:  L_g(V) = G/n · Σ_{i∈g} log(1 + e^{-y_i x_i·V}) + λ/2 ‖V‖²
    pca:     L_g(V) = -G/2 · ‖X_g V‖²_F + 1/2 ‖V‖²_F

so each group gradient is ``G·(block subgradient) + (regularizer grad)``
— exactly G times the scalar simulator's cached task value plus the
regularizer, making the Tier-1 estimate Ĥ = H/(ξG) track the simulator's
``cache.sum/ξ + regularizer_grad`` (up to regularizer staleness on
non-fresh entries and float-accumulation order).  PCA additionally
re-projects onto the Stiefel manifold after each optimizer step
(``project_fn``), matching the paper's projected subgradient method.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.core.problems import (
    FiniteSumProblem,
    LogisticRegressionProblem,
    PCAProblem,
    make_genomics_like_matrix,
    make_higgs_like,
)
from repro.lb.partitioner import p_start, p_stop

PAPER_ARCHES = ("logreg", "pca")


def paper_train_config(eta: float, *, dsag: bool = True) -> TrainConfig:
    """The TrainConfig under which the live step is plain ``V - η·Ĥ``.

    The model zoo's defaults (momentum, weight decay, grad clipping,
    bf16 cache) are all *off* so the Tier-1 update matches the
    simulator's iterate rule exactly.
    """
    return TrainConfig(
        dsag=dsag,
        optimizer="sgd",
        learning_rate=eta,
        beta1=0.0,  # make_optimizer maps beta1 -> sgd momentum
        weight_decay=0.0,
        grad_clip=0.0,
        dsag_cache_dtype="float32",
    )


@dataclasses.dataclass
class PaperJob:
    """One paper problem wired for ``launch/train.py``.

    ``num_groups`` must divide ``num_samples`` (equal partitions — the
    regime of the live trainer and of the paper's §7 experiments).
    """

    problem: FiniteSumProblem
    num_groups: int
    name: str  # logreg | pca

    def __post_init__(self):
        n = self.problem.num_samples
        G = self.num_groups
        if n % G:
            raise ValueError(f"{n} samples not divisible by {G} groups")
        bounds = [(p_start(n, G, i), p_stop(n, G, i)) for i in range(1, G + 1)]
        # 1-based inclusive -> numpy slices; equal widths by divisibility
        self._X = jnp.asarray(
            np.stack([np.asarray(self.problem.X)[s - 1 : e] for s, e in bounds])
        )
        if self.name == "logreg":
            self._y = jnp.asarray(
                np.stack([np.asarray(self.problem.y)[s - 1 : e] for s, e in bounds])
            )
        self.loads = np.array(
            [self.problem.compute_cost(s, e) for s, e in bounds], dtype=np.float64
        )

    # -- the live trainer's model interface --------------------------------
    def init_params(self, seed: int) -> jnp.ndarray:
        return jnp.asarray(self.problem.init(seed), dtype=jnp.float32)

    def loss_fn(self, params, batch) -> jnp.ndarray:
        """Per-group loss (vmapped over the leading group dim by Tier 1)."""
        n = self.problem.num_samples
        G = self.num_groups
        if self.name == "logreg":
            z = batch["y"] * jnp.sum(batch["X"] * params[None, :], axis=1)
            data = (G / n) * jnp.sum(jnp.logaddexp(0.0, -z))
            lam = self.problem.lam
            return data + 0.5 * lam * jnp.sum(params * params)
        xv = batch["X"] @ params  # [m, k]
        return -0.5 * G * jnp.sum(xv * xv) + 0.5 * jnp.sum(params * params)

    def project_fn(self, params):
        """Stiefel re-projection after the optimizer step (PCA only)."""
        if self.name != "pca":
            return params
        q, r = jnp.linalg.qr(params)
        diag = jnp.diagonal(r, axis1=-2, axis2=-1)
        return q * jnp.sign(diag)[..., None, :]

    def batch_iterator(self) -> Iterator[dict[str, Any]]:
        """Full-partition batches: every step re-evaluates group g on its
        whole sample range, like the simulator's subpartitions=1 workers."""
        batch = {"X": self._X}
        if self.name == "logreg":
            batch["y"] = self._y
        while True:
            yield batch

    def suboptimality(self, params) -> float:
        return self.problem.suboptimality(np.asarray(params, dtype=np.float64))


def make_paper_job(
    arch: str, num_groups: int, *, samples: int = 1024, seed: int = 0
) -> PaperJob:
    """Build the CPU-scale live job for ``--arch logreg`` / ``--arch pca``."""
    if arch == "logreg":
        X, y = make_higgs_like(samples, seed=seed)
        return PaperJob(
            problem=LogisticRegressionProblem(X=X, y=y),
            num_groups=num_groups,
            name="logreg",
        )
    if arch == "pca":
        X = make_genomics_like_matrix(samples, 64, seed=seed)
        return PaperJob(
            problem=PCAProblem(X=X), num_groups=num_groups, name="pca"
        )
    raise ValueError(f"unknown paper arch {arch!r}; expected one of {PAPER_ARCHES}")
