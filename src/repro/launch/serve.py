"""Batched serving driver: prefill a prompt batch, then decode greedily.

CPU-scale demo + the lowering target for the decode/prefill dry-run cells.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.models.sharding import set_mesh


class Server:
    def __init__(self, arch: str, *, smoke: bool = True, mesh=None, max_len: int = 256):
        self.cfg = get_smoke_config(arch) if smoke else get_config(arch)
        self.model = build_model(self.cfg)
        set_mesh(mesh)
        self.max_len = max_len
        self.params = self.model.init(jax.random.key(0))
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len=self.max_len)
        )
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))

    def generate(self, batch, num_tokens: int):
        """Greedy generation; returns [b, num_tokens] token ids."""
        cfg = self.cfg
        prompt_len = batch["tokens"].shape[1] + (
            cfg.num_image_tokens if cfg.family == "vlm" else 0
        )
        logits, cache = self._prefill(self.params, batch)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out = [tok]
        index = prompt_len
        for _ in range(num_tokens - 1):
            logits, cache = self._decode(self.params, tok, cache, jnp.int32(index))
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
            index += 1
        return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    srv = Server(args.arch, smoke=True, max_len=args.prompt_len + args.tokens + 8)
    cfg = srv.cfg
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
        )
    }
    if cfg.family == "enc_dec":
        batch["audio_embed"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)) * 0.1,
            jnp.bfloat16,
        )
    elif cfg.family == "vlm":
        batch["image_embed"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.num_image_tokens, cfg.d_model)) * 0.1,
            jnp.bfloat16,
        )
    t0 = time.time()
    toks = srv.generate(batch, args.tokens)
    dt = time.time() - t0
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print(np.asarray(toks[0][:16]))


if __name__ == "__main__":
    main()
