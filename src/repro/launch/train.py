"""End-to-end trainer: DSAG Tier-1 step + Tier-2 control loop.

Runs anywhere: on CPU it trains reduced configs for real (examples/
quickstart.py), on a pod slice it is the production entry point.  Wires
together:

  model zoo / paper problems -> dsag_pjit step -> deadline controller
  (mask/flush/evict) -> failure detector -> checkpoint manager ->
  (optional) straggler simulation

Two kinds of jobs share the loop:

* transformer archs from the model zoo (``--arch qwen1.5-0.5b``), the
  scaffold's LLM smoke path;
* the paper's problems (``--arch logreg`` / ``--arch pca``,
  ``launch/paper_jobs.py``), which is the *live* counterpart of the
  convergence engines — replay a ``FleetTraces`` scenario through the
  controller (``TrainerOptions.traces``) and the (mask, flush, evict)
  streams match the scalar ``TrainingSimulator`` bit-for-bit (the
  cross-layer pin; see ``repro/ft/validation.py``), while
  ``time_scale > 0`` turns the virtual straggler waits into real sleeps
  so measured wall-clock reflects each method's §5 semantics.

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 100 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch logreg --smoke \
      --steps 20 --check
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_config, get_smoke_config
from repro.core.dsag_pjit import (
    GroupSpec,
    init_train_state,
    make_group_spec,
    make_train_step,
    train_state_specs,
)
from repro.data import make_batch_iterator
from repro.ft import DeadlineController, FailureDetector
from repro.ft.validation import trace_latency_fn
from repro.latency.model import make_heterogeneous_cluster
from repro.launch.paper_jobs import (
    PAPER_ARCHES,
    make_paper_job,
    paper_train_config,
)
from repro.models import build_model
from repro.models.sharding import set_mesh


@dataclasses.dataclass
class TrainerOptions:
    arch: str = "qwen1.5-0.5b"
    smoke: bool = True
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    checkpoint_dir: str | None = None
    restore: bool = False
    mesh: Any | None = None
    train_config: TrainConfig = dataclasses.field(default_factory=TrainConfig)
    #: simulate straggling groups (CPU runs): per-step latency draws feed the
    #: deadline controller exactly like real step timings would on a pod
    simulate_stragglers: bool = True
    dsag_w: int | None = None  # wait-for-w groups (default: 3/4 of P)
    log_every: int = 10
    # ---- paper-problem / live-validation options -------------------------
    num_groups: int | None = None  # group count for paper archs (default 4)
    samples: int = 1024  # problem size for paper archs
    method: str = "dsag"  # dsag | sag (controller stale-acceptance mode)
    #: replay a pre-sampled FleetTraces scenario through the controller
    #: instead of live-sampling the straggler cluster (the pinned path)
    traces: Any | None = None
    scenario: int = 0
    #: seconds of real sleep per unit of virtual straggler time; > 0 makes
    #: measured wall-clock reflect the method's §5 collection behavior
    time_scale: float = 0.0
    eval_every: int = 0  # paper archs: suboptimality eval cadence (0 = off)
    failure_max_misses: int = 5


class Trainer:
    def __init__(self, opts: TrainerOptions):
        self.opts = opts
        tc = opts.train_config
        if opts.method not in ("dsag", "sag"):
            raise ValueError(f"method {opts.method!r} not in ('dsag', 'sag')")
        self.job = None
        if opts.arch in PAPER_ARCHES:
            G = opts.num_groups or 4
            self.gs = GroupSpec(num_groups=G, axes=())
            self.job = make_paper_job(
                opts.arch, G, samples=opts.samples, seed=opts.seed
            )
            self.data = self.job.batch_iterator()
            loss_fn = self.job.loss_fn
            project_fn = self.job.project_fn if opts.arch == "pca" else None
            self.state_shardings = None
            step = make_train_step(
                loss_fn, tc, self.gs, None, None, project_fn=project_fn
            )
            self.step_fn = jax.jit(step, donate_argnums=(0,))
        else:
            cfg = get_smoke_config(opts.arch) if opts.smoke else get_config(opts.arch)
            self.cfg = cfg
            self.model = build_model(cfg)
            set_mesh(opts.mesh)
            self.gs = make_group_spec(tc, opts.mesh)
            if opts.global_batch % self.gs.num_groups:
                raise ValueError(
                    f"global batch {opts.global_batch} not divisible by "
                    f"{self.gs.num_groups} DSAG groups"
                )
            self.data = make_batch_iterator(
                cfg, self.gs.num_groups, opts.global_batch, opts.seq_len, seed=opts.seed
            )

            def loss_fn(params, batch):
                return self.model.train_loss(params, batch, remat=tc.remat)

            param_specs = (
                self.model.param_specs(tc.fsdp) if opts.mesh is not None else None
            )
            step = make_train_step(loss_fn, tc, self.gs, opts.mesh, param_specs)
            if opts.mesh is not None:
                from jax.sharding import NamedSharding

                specs = train_state_specs(tc, self.gs, self.model.param_specs(tc.fsdp))
                self.state_shardings = jax.tree.map(
                    lambda s: NamedSharding(opts.mesh, s),
                    specs,
                    is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec),
                )
            else:
                self.state_shardings = None
            self.step_fn = jax.jit(step, donate_argnums=(0,))

        # Tier-2 control plane
        w = opts.dsag_w or max(1, (3 * self.gs.num_groups) // 4)
        self.deadlines = DeadlineController(
            self.gs.num_groups,
            w=w,
            margin=tc.dsag_margin,
            accepts_stale=opts.method == "dsag",
        )
        self.failures = FailureDetector(
            self.gs.num_groups, max_misses=opts.failure_max_misses
        )
        self.ckpt = (
            CheckpointManager(opts.checkpoint_dir, keep=tc.keep_checkpoints)
            if opts.checkpoint_dir
            else None
        )
        if opts.traces is not None:
            loads = (
                self.job.loads
                if self.job is not None
                else np.ones(self.gs.num_groups)
            )
            self._latency_of = trace_latency_fn(opts.traces, opts.scenario, loads)
            self._churn = opts.traces.churn
            self.straggler_sim = None
        else:
            self._latency_of = None
            self._churn = None
            self.straggler_sim = (
                make_heterogeneous_cluster(
                    self.gs.num_groups,
                    comp_range=(0.9, 1.4),
                    comm_range=(0.01, 0.05),
                    cv_comp=0.08,
                    seed=opts.seed + 3,
                )
                if opts.simulate_stragglers
                else None
            )

    # -- lifecycle ---------------------------------------------------------
    def init_state(self):
        if self.job is not None:
            params = self.job.init_params(self.opts.seed)
        else:
            params = self.model.init(jax.random.key(self.opts.seed))
        state = init_train_state(params, self.opts.train_config, self.gs)
        if self.state_shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, self.state_shardings
            )
        return state

    def maybe_restore(self, state):
        if self.ckpt is None or not self.opts.restore:
            return state, 0
        restored, step = self.ckpt.restore_latest(state, self.state_shardings)
        if restored is None:
            return state, 0
        print(f"[train] restored checkpoint at step {step}")
        return restored, step + 1

    def _group_latencies(self, step: int) -> np.ndarray:
        if self.straggler_sim is None:
            return np.ones(self.gs.num_groups)
        return self.straggler_sim.sample_all(c=1.0, now=float(step))

    def _step_inputs(self, step: int):
        """One Tier-2 decision: (mask, flush, evict, virtual elapsed)."""
        if self._latency_of is not None:
            alive = (
                self._churn.alive_at(self.deadlines.now)
                if self._churn is not None
                else None
            )
            si = self.deadlines.step_inputs(self._latency_of, alive=alive)
            mask_np, flush_np, evict_np = si.mask, si.flush, si.evict
            elapsed = si.elapsed
        else:
            lat = self._group_latencies(step)
            mask_np, flush_np = self.deadlines.step_masks(lat, step)
            evict_np = np.zeros(self.gs.num_groups, dtype=bool)
            elapsed = 0.0
        was_failed = self.failures.failed.copy()
        self.failures.observe(mask_np)
        # failed groups cannot flush; newly-failed groups get their cache
        # entry evicted (paper §6.3) so H stays unbiased
        flush_np = np.logical_and(flush_np, ~self.failures.failed)
        evict_np = np.logical_or(
            evict_np, np.logical_and(self.failures.failed, ~was_failed)
        )
        return mask_np, flush_np, evict_np, elapsed

    # -- main loop ----------------------------------------------------------
    def run(self) -> dict[str, list]:
        opts = self.opts
        tc = opts.train_config
        state = self.init_state()
        state, start_step = self.maybe_restore(state)
        history: dict[str, list] = {
            "loss": [],
            "xi": [],
            "mask_count": [],
            "step_time": [],
            "virtual": [],
            "eval": [],  # (step, wall s, virtual s, suboptimality)
            # per-step Tier-2 decisions, for the cross-layer pin against the
            # scalar simulator's recorded streams (ft/validation.py)
            "mask_stream": [],
            "flush_stream": [],
            "evict_stream": [],
        }
        #: device-side metric buffer — materialized every log_every steps
        #: (and at the end) so the host never forces a per-step sync
        pending: list[tuple[int, dict, float]] = []

        def drain():
            for s, m, dt in pending:
                history["loss"].append(float(m["loss"]))
                history["xi"].append(float(m["xi"]))
                history["mask_count"].append(int(m["mask_count"]))
                history["step_time"].append(dt)
            pending.clear()

        wall0 = time.perf_counter()
        for step in range(start_step, opts.steps):
            batch = next(self.data)
            if tc.dsag:
                mask_np, flush_np, evict_np, elapsed = self._step_inputs(step)
                history["mask_stream"].append(mask_np.copy())
                history["flush_stream"].append(flush_np.copy())
                history["evict_stream"].append(evict_np.copy())
            else:
                mask_np = np.ones(self.gs.num_groups, bool)
                flush_np = np.zeros(self.gs.num_groups, bool)
                evict_np = flush_np
                elapsed = 0.0
            if opts.time_scale > 0 and elapsed > 0:
                # make the virtual straggler wait real: measured wall-clock
                # then reflects the method's §5 collection behavior
                time.sleep(elapsed * opts.time_scale)
            t0 = time.perf_counter()
            state, metrics = self.step_fn(
                state,
                jax.tree.map(jnp.asarray, batch),
                jnp.asarray(mask_np),
                jnp.asarray(flush_np),
                jnp.asarray(evict_np),
            )
            pending.append((step, metrics, time.perf_counter() - t0))
            history["virtual"].append(float(self.deadlines.now))
            if (
                self.job is not None
                and opts.eval_every > 0
                and (step % opts.eval_every == 0 or step == opts.steps - 1)
            ):
                # pulls the params (a sync point) — keep the cadence coarse
                gap = self.job.suboptimality(state["params"])
                history["eval"].append(
                    (step, time.perf_counter() - wall0, float(self.deadlines.now), gap)
                )
            if step % opts.log_every == 0:
                drain()
                print(
                    f"[train] step {step:5d} loss {history['loss'][-1]:.4f} "
                    f"xi {history['xi'][-1]:.2f} "
                    f"fresh {history['mask_count'][-1]}/{self.gs.num_groups} "
                    f"({history['step_time'][-1]*1e3:.0f} ms)"
                )
            if self.ckpt and (step + 1) % tc.checkpoint_every == 0:
                self.ckpt.save(step, state)
        drain()
        if self.ckpt and opts.steps > start_step:
            self.ckpt.save(opts.steps - 1, state, blocking=True)
        history["wall_seconds"] = [time.perf_counter() - wall0]
        return history


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--arch",
        default="qwen1.5-0.5b",
        help=f"model-zoo arch, or one of {PAPER_ARCHES} for the paper's "
        "live CPU problems",
    )
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--samples", type=int, default=1024)
    ap.add_argument("--groups", type=int, default=None)
    ap.add_argument("--method", default="dsag", choices=["dsag", "sag"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--no-dsag", action="store_true")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument(
        "--check",
        action="store_true",
        help="assert ξ reached 1.0 and the loss decreased (CI smoke gate)",
    )
    args = ap.parse_args(argv)
    if args.arch in PAPER_ARCHES:
        lr = args.lr if args.lr != 3e-4 else 0.25  # paper-scale step size
        tc = paper_train_config(lr, dsag=not args.no_dsag)
    else:
        tc = TrainConfig(
            dsag=not args.no_dsag, optimizer=args.optimizer, learning_rate=args.lr
        )
    opts = TrainerOptions(
        arch=args.arch,
        smoke=args.smoke,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        samples=args.samples,
        num_groups=args.groups,
        method=args.method,
        checkpoint_dir=args.checkpoint_dir,
        restore=args.restore,
        train_config=tc,
    )
    hist = Trainer(opts).run()
    if hist["loss"]:
        print(f"[train] done; final loss {hist['loss'][-1]:.4f}")
    else:
        # e.g. --restore resumed at or past --steps: nothing ran, nothing
        # to report (this used to IndexError)
        print("[train] done; no steps to run")
    if args.check:
        if not hist["loss"]:
            raise SystemExit("[check] FAILED: no steps ran")
        first = float(np.mean(hist["loss"][: max(1, len(hist["loss"]) // 4)]))
        last = float(np.mean(hist["loss"][-max(1, len(hist["loss"]) // 4) :]))
        xi_max = max(hist["xi"])
        ok = last < first and xi_max >= 1.0 - 1e-6
        print(
            f"[check] loss {first:.4f} -> {last:.4f}; max xi {xi_max:.3f}: "
            f"{'OK' if ok else 'FAILED'}"
        )
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
