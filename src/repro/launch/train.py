"""End-to-end trainer: DSAG Tier-1 step + Tier-2 control loop.

Runs anywhere: on CPU it trains reduced configs for real (examples/
quickstart.py), on a pod slice it is the production entry point.  Wires
together:

  model zoo -> dsag_pjit step -> deadline controller (masks) ->
  failure detector -> checkpoint manager -> (optional) straggler simulation

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 100 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_config, get_smoke_config
from repro.core.dsag_pjit import (
    GroupSpec,
    init_train_state,
    make_group_spec,
    make_train_step,
    train_state_specs,
)
from repro.data import make_batch_iterator
from repro.ft import DeadlineController, FailureDetector
from repro.latency.model import make_heterogeneous_cluster
from repro.models import build_model
from repro.models.sharding import set_mesh


@dataclasses.dataclass
class TrainerOptions:
    arch: str = "qwen1.5-0.5b"
    smoke: bool = True
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    checkpoint_dir: str | None = None
    restore: bool = False
    mesh: Any | None = None
    train_config: TrainConfig = dataclasses.field(default_factory=TrainConfig)
    #: simulate straggling groups (CPU runs): per-step latency draws feed the
    #: deadline controller exactly like real step timings would on a pod
    simulate_stragglers: bool = True
    dsag_w: int | None = None  # wait-for-w groups (default: 3/4 of P)
    log_every: int = 10


class Trainer:
    def __init__(self, opts: TrainerOptions):
        self.opts = opts
        tc = opts.train_config
        cfg = get_smoke_config(opts.arch) if opts.smoke else get_config(opts.arch)
        self.cfg = cfg
        self.model = build_model(cfg)
        set_mesh(opts.mesh)
        self.gs = make_group_spec(tc, opts.mesh)
        if opts.global_batch % self.gs.num_groups:
            raise ValueError(
                f"global batch {opts.global_batch} not divisible by "
                f"{self.gs.num_groups} DSAG groups"
            )
        self.data = make_batch_iterator(
            cfg, self.gs.num_groups, opts.global_batch, opts.seq_len, seed=opts.seed
        )

        def loss_fn(params, batch):
            return self.model.train_loss(params, batch, remat=tc.remat)

        param_specs = self.model.param_specs(tc.fsdp) if opts.mesh is not None else None
        step = make_train_step(loss_fn, tc, self.gs, opts.mesh, param_specs)
        if opts.mesh is not None:
            from jax.sharding import NamedSharding

            specs = train_state_specs(tc, self.gs, self.model.param_specs(tc.fsdp))
            self.state_shardings = jax.tree.map(
                lambda s: NamedSharding(opts.mesh, s),
                specs,
                is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec),
            )
            self.step_fn = jax.jit(step, donate_argnums=(0,))
        else:
            self.state_shardings = None
            self.step_fn = jax.jit(step, donate_argnums=(0,))

        # Tier-2 control plane
        w = opts.dsag_w or max(1, (3 * self.gs.num_groups) // 4)
        self.deadlines = DeadlineController(self.gs.num_groups, w=w, margin=tc.dsag_margin)
        self.failures = FailureDetector(self.gs.num_groups)
        self.ckpt = (
            CheckpointManager(opts.checkpoint_dir, keep=tc.keep_checkpoints)
            if opts.checkpoint_dir
            else None
        )
        self.straggler_sim = (
            make_heterogeneous_cluster(
                self.gs.num_groups,
                comp_range=(0.9, 1.4),
                comm_range=(0.01, 0.05),
                cv_comp=0.08,
                seed=opts.seed + 3,
            )
            if opts.simulate_stragglers
            else None
        )

    # -- lifecycle ---------------------------------------------------------
    def init_state(self):
        params = self.model.init(jax.random.key(self.opts.seed))
        state = init_train_state(params, self.opts.train_config, self.gs)
        if self.state_shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, self.state_shardings
            )
        return state

    def maybe_restore(self, state):
        if self.ckpt is None or not self.opts.restore:
            return state, 0
        restored, step = self.ckpt.restore_latest(state, self.state_shardings)
        if restored is None:
            return state, 0
        print(f"[train] restored checkpoint at step {step}")
        return restored, step + 1

    def _group_latencies(self, step: int) -> np.ndarray:
        if self.straggler_sim is None:
            return np.ones(self.gs.num_groups)
        return self.straggler_sim.sample_all(c=1.0, now=float(step))

    # -- main loop ----------------------------------------------------------
    def run(self) -> dict[str, list]:
        opts = self.opts
        tc = opts.train_config
        state = self.init_state()
        state, start_step = self.maybe_restore(state)
        history = {"loss": [], "xi": [], "mask_count": [], "step_time": []}
        for step in range(start_step, opts.steps):
            batch = next(self.data)
            if tc.dsag:
                lat = self._group_latencies(step)
                mask_np, flush_np = self.deadlines.step_masks(lat, step)
                was_failed = self.failures.failed.copy()
                self.failures.observe(mask_np)
                # failed groups cannot flush; newly-failed groups get their
                # cache entry evicted (paper §6.3) so H stays unbiased
                flush_np = np.logical_and(flush_np, ~self.failures.failed)
                evict_np = np.logical_and(self.failures.failed, ~was_failed)
            else:
                mask_np = np.ones(self.gs.num_groups, bool)
                flush_np = np.zeros(self.gs.num_groups, bool)
                evict_np = flush_np
            t0 = time.time()
            state, metrics = self.step_fn(
                state,
                jax.tree.map(jnp.asarray, batch),
                jnp.asarray(mask_np),
                jnp.asarray(flush_np),
                jnp.asarray(evict_np),
            )
            loss = float(metrics["loss"])
            history["loss"].append(loss)
            history["xi"].append(float(metrics["xi"]))
            history["mask_count"].append(int(metrics["mask_count"]))
            history["step_time"].append(time.time() - t0)
            if step % opts.log_every == 0:
                print(
                    f"[train] step {step:5d} loss {loss:.4f} xi {float(metrics['xi']):.2f} "
                    f"fresh {int(metrics['mask_count'])}/{self.gs.num_groups} "
                    f"({history['step_time'][-1]*1e3:.0f} ms)"
                )
            if self.ckpt and (step + 1) % tc.checkpoint_every == 0:
                self.ckpt.save(step, state)
        if self.ckpt:
            self.ckpt.save(opts.steps - 1, state, blocking=True)
        return history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--no-dsag", action="store_true")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    tc = TrainConfig(dsag=not args.no_dsag, optimizer=args.optimizer, learning_rate=args.lr)
    opts = TrainerOptions(
        arch=args.arch,
        smoke=args.smoke,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        checkpoint_dir=args.checkpoint_dir,
        restore=args.restore,
        train_config=tc,
    )
    hist = Trainer(opts).run()
    print(f"[train] done; final loss {hist['loss'][-1]:.4f}")


if __name__ == "__main__":
    main()
