"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; callers (dryrun/train)
decide when devices are materialized.
"""

from __future__ import annotations

import jax

# jax.sharding.AxisType landed after 0.4.x; on older jax every mesh axis is
# implicitly Auto, which is exactly what we request on newer versions — so
# the fallback just omits the kwarg.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _make_mesh(shape, axes):
    if _AXIS_TYPE is not None:
        return jax.make_mesh(shape, axes, axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(devices_per_axis=(2, 4)):
    """Small mesh for subprocess tests (8 fake devices by default)."""
    axes = ("data", "model") if len(devices_per_axis) == 2 else ("pod", "data", "model")
    return _make_mesh(devices_per_axis, axes)


def make_scenario_mesh(num_devices=None):
    """1-D mesh over the batch (``"data"``) axis for scenario-sharded engines.

    The fused-scan convergence engine shards its ``[S, ...]`` scenario
    batches over this mesh with ``shard_map``.  ``num_devices=None`` uses
    every visible device; otherwise the first ``num_devices`` are taken
    (on CPU, grow the pool with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    avail = len(jax.devices())
    if num_devices is None:
        num_devices = avail
    if not 1 <= num_devices <= avail:
        raise ValueError(
            f"make_scenario_mesh: requested {num_devices} devices but only "
            f"{avail} are visible (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N on CPU)"
        )
    return _make_mesh((num_devices,), ("data",))
