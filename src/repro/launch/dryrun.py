import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory / cost / collective analyses.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 host devices.

  one cell:  PYTHONPATH=src python -m repro.launch.dryrun \
                 --arch qwen2-7b --shape train_4k [--multi-pod]
  all cells: PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
             (spawns one subprocess per cell; resumes from existing JSON)

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json and are the
inputs for EXPERIMENTS.md §Dry-run / §Roofline.
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as roofline_mod
from repro.configs import (
    ARCHS,
    SHAPES,
    TrainConfig,
    cell_is_runnable,
    get_config,
    input_specs,
)
from repro.core.dsag_pjit import (
    GroupSpec,
    init_train_state,
    make_group_spec,
    make_train_step,
    train_state_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.model import cache_abstract, cache_specs
from repro.models.sharding import set_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Per-arch training configuration heuristics (production defaults)
# ---------------------------------------------------------------------------


def default_train_config(num_params: int, multi_pod: bool, overrides: dict | None = None) -> TrainConfig:
    big = num_params > 50e9
    kwargs: dict[str, Any] = dict(
        optimizer="adafactor" if big else "adamw",
        fsdp=num_params > 1e9,
        dsag=True,
        dsag_cache_dtype="int8" if num_params > 10e9 else "bfloat16",
        remat="full",
    )
    if big:
        # pod-granularity groups multi-pod; ZeRO-layout time-sliced groups on
        # a single pod (see DESIGN.md §6 memory discussion)
        kwargs.update(
            dsag_groups="pod" if multi_pod else "zero", dsag_num_groups=2
        )
    else:
        kwargs.update(dsag_groups="dp")
    if overrides:
        kwargs.update(overrides)
    return TrainConfig(**kwargs)


def sanitize_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes from dims they do not evenly divide (e.g. batch=1 cells
    cannot shard the batch axis)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ent = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, ent):
        axes = e if isinstance(e, tuple) else (e,) if e else ()
        factor = 1
        for a in axes:
            factor *= sizes[a]
        out.append(e if factor and dim % factor == 0 else None)
    return P(*out)


def _attach(abstract_tree, spec_tree, mesh):
    """Zip ShapeDtypeStructs with PartitionSpecs (flatten-order aligned)."""
    a_leaves, a_def = jax.tree_util.tree_flatten(abstract_tree)
    s_leaves = [
        s
        for s in jax.tree_util.tree_leaves(
            spec_tree, is_leaf=lambda x: isinstance(x, P)
        )
    ]
    assert len(a_leaves) == len(s_leaves), (len(a_leaves), len(s_leaves))
    out = [
        jax.ShapeDtypeStruct(
            a.shape,
            a.dtype,
            sharding=NamedSharding(mesh, sanitize_spec(s, a.shape, mesh)),
        )
        for a, s in zip(a_leaves, s_leaves)
    ]
    return jax.tree_util.tree_unflatten(a_def, out)


def _grouped_batch_abstract(cfg, shape, gs: GroupSpec, mesh):
    """[P, B/P, ...] train-batch stand-ins with group-aware shardings."""
    flat = input_specs(cfg, shape, mesh=None)
    pcount = gs.num_groups
    inner_dp = tuple(
        a for a in mesh.axis_names if a in ("pod", "data") and a not in gs.axes
    )
    inner = inner_dp if len(inner_dp) > 1 else (inner_dp[0] if inner_dp else None)
    out = {}
    for name, sds in flat.items():
        b = sds.shape[0]
        assert b % pcount == 0, (name, b, pcount)
        shape_g = (pcount, b // pcount) + sds.shape[1:]
        spec = P(gs.group_partition, inner, *([None] * (len(sds.shape) - 1)))
        out[name] = jax.ShapeDtypeStruct(
            shape_g, sds.dtype, sharding=NamedSharding(mesh, spec)
        )
    return out


# ---------------------------------------------------------------------------
# Cell runners
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool, overrides=None) -> dict:
    """overrides: TrainConfig field overrides (hillclimb iterations)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh(mesh)
    model = build_model(cfg)
    nparams = model.num_params()
    t0 = time.time()

    if shape.kind == "train":
        tc = default_train_config(nparams, multi_pod, overrides)
        if tc.bf16_reduce:
            from repro.models.layers import set_tp_reduce_dtype

            set_tp_reduce_dtype(jnp.bfloat16)
        gs = make_group_spec(tc, mesh)
        param_specs = model.param_specs(tc.fsdp)

        def loss_fn(p, b):
            return model.train_loss(p, b, remat=tc.remat, fused_loss=tc.fused_loss)

        step = make_train_step(loss_fn, tc, gs, mesh, param_specs)
        params_abs = model.abstract()
        state_abs = jax.eval_shape(lambda pa: init_train_state(pa, tc, gs), params_abs)
        state_specs = train_state_specs(tc, gs, param_specs)
        state_in = _attach(state_abs, state_specs, mesh)
        batch_in = _grouped_batch_abstract(cfg, shape, gs, mesh)
        mask_in = jax.ShapeDtypeStruct(
            (gs.num_groups,), jnp.bool_, sharding=NamedSharding(mesh, P())
        )
        lowered = jax.jit(step).lower(state_in, batch_in, mask_in, mask_in)
        extra = {"train_config": dataclasses.asdict(tc), "num_groups": gs.num_groups}
    elif shape.kind == "prefill":
        param_specs = model.param_specs(nparams > 1e9)
        params_in = _attach(model.abstract(), param_specs, mesh)
        batch_in = input_specs(cfg, shape, mesh=mesh)

        def prefill(p, b):
            from repro.models.sharding import degather

            p = degather(p, param_specs, mesh)
            return model.prefill(p, b, cache_len=shape.seq_len)

        lowered = jax.jit(prefill).lower(params_in, batch_in)
        extra = {}
    else:  # decode
        param_specs = model.param_specs(nparams > 1e9)
        params_in = _attach(model.abstract(), param_specs, mesh)
        tok_raw = input_specs(cfg, shape, mesh=None)["tokens"]
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        dp = dp if len(dp) > 1 else dp[0]
        tok_in = jax.ShapeDtypeStruct(
            tok_raw.shape,
            tok_raw.dtype,
            sharding=NamedSharding(
                mesh, sanitize_spec(P(dp, None), tok_raw.shape, mesh)
            ),
        )
        cache_abs = cache_abstract(cfg, shape.global_batch, shape.seq_len)
        cache_in = _attach(cache_abs, cache_specs(cfg), mesh)
        idx_in = jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=NamedSharding(mesh, P())
        )
        def decode(p, tok, cache, idx):
            from repro.models.sharding import degather

            p = degather(p, param_specs, mesh)
            return model.decode_step(p, tok, cache, idx)

        lowered = jax.jit(decode, donate_argnums=(2,)).lower(
            params_in, tok_in, cache_in, idx_in
        )
        extra = {}

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    rl = roofline_mod.derive(cfg, shape, nparams, cost, hlo, mesh.devices.size)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "num_params": nparams,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {k: v for k, v in cost.items() if "flops" in k or k == "bytes accessed"},
        "roofline": rl.as_dict(),
        **extra,
    }
    return result


def result_path(arch: str, shape_name: str, multi_pod: bool) -> str:
    mesh_dir = "2x16x16" if multi_pod else "16x16"
    d = os.path.join(RESULTS_DIR, mesh_dir)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape_name}.json")


def run_all(multi_pod: bool, force: bool = False) -> int:
    """Spawn one subprocess per cell (fresh XLA each time); resume-safe."""
    failures = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if not cell_is_runnable(cfg, shape):
                continue
            path = result_path(arch, shape_name, multi_pod)
            if os.path.exists(path) and not force:
                with open(path) as f:
                    if json.load(f).get("status") == "ok":
                        print(f"[dryrun] skip (done): {arch} x {shape_name}")
                        continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape_name,
            ] + (["--multi-pod"] if multi_pod else [])
            print(f"[dryrun] {arch} x {shape_name} ({'2x16x16' if multi_pod else '16x16'}) ...", flush=True)
            proc = subprocess.run(cmd, capture_output=True, text=True, timeout=3600)
            if proc.returncode != 0:
                failures += 1
                err = (proc.stderr or "")[-2000:]
                with open(path, "w") as f:
                    json.dump(
                        {"arch": arch, "shape": shape_name, "status": "fail",
                         "mesh": "2x16x16" if multi_pod else "16x16",
                         "error": err},
                        f, indent=2,
                    )
                print(f"[dryrun]   FAIL:\n{err}")
            else:
                print(proc.stdout.strip().splitlines()[-1] if proc.stdout.strip() else "")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.all:
        failures = run_all(args.multi_pod, force=args.force)
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    result = run_cell(args.arch, args.shape, args.multi_pod)
    path = result_path(args.arch, args.shape, args.multi_pod)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    mem_gb = result["memory"]["peak_estimate_bytes"] / 2**30
    rl = result["roofline"]
    print(
        f"[dryrun] {args.arch} x {args.shape} OK: compile {result['compile_s']:.0f}s, "
        f"~{mem_gb:.2f} GiB/device, terms c/m/x = "
        f"{rl['compute_s']:.4f}/{rl['memory_s']:.4f}/{rl['collective_s']:.4f} s, "
        f"dominant={rl['dominant']}, mfu={rl['mfu']:.3f}"
    )


if __name__ == "__main__":
    main()
