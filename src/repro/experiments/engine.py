"""Typed execution configuration for the convergence engines.

Historically :func:`repro.experiments.convergence.run_convergence_batch`
took a stringly-typed ``engine: str = "auto"`` kwarg plus scattered
execution keywords, and the fused scan signalled its one unsupported case
by raising a ``ValueError`` whose *text* callers string-matched.  This
module replaces both:

* :class:`EngineConfig` — a frozen dataclass bundling every execution
  decision: engine kind, the scenario-axis device mesh, the §6
  slot-universe residency budget, and the evaluation cadence.  Legacy
  ``engine="scan"|"host"|"auto"`` strings keep working as deprecated
  aliases (:func:`as_engine_config` emits a ``DeprecationWarning``).
* :class:`EngineCapability` — a structured capability report with stable
  reason codes (``CAP_*``), so ``auto`` routing, error messages, and
  tests compare codes instead of exception prose.  The fused engine
  raises :class:`EngineCapabilityError` (a ``ValueError`` carrying the
  report) for genuinely unsupported configs.

This module is dependency-light on purpose: :mod:`repro.experiments.fused`
imports it, never the other way around.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

#: capability reason codes (stable API — tests compare these, not prose)
CAP_OK = "ok"
#: the §6 ladder universe exceeds the dense residency budget; the scan
#: runs anyway with the tiled active-slot cache (supported, informational)
CAP_TILED = "slot-universe-tiled"
#: even the tiled cache's resident active-slot set exceeds the budget —
#: the one genuinely unsupported fused-scan case (route to the host engine)
CAP_ACTIVE_SET = "active-slots-exceed-budget"
#: kernel_backend="pallas" requested but the problem publishes no Pallas
#: kernels (FusedKernels.sub_blocks_pallas is None)
CAP_PALLAS_UNAVAILABLE = "pallas-kernels-unavailable"
#: kernel_backend="pallas" requested for a problem whose in-flight value
#: dtype the Pallas kernels don't cover (only float32 is validated)
CAP_PALLAS_DTYPE = "pallas-unsupported-dtype"
#: kernel_backend="pallas" requested together with the host engine, which
#: drives the problem's numpy wrappers and never takes the Pallas path
CAP_PALLAS_HOST = "pallas-requires-scan-engine"

_KINDS = ("auto", "scan", "host")
_KERNEL_BACKENDS = ("xla", "pallas")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Execution configuration of one convergence-batch run.

    ``kind`` selects the implementation (``"scan"`` — the fused
    ``jax.lax.scan`` engine, ``"host"`` — the numpy-driven batched loop,
    ``"auto"`` — scan unless :func:`repro.experiments.fused.scan_capability`
    reports the config unsupported).

    ``num_devices`` / ``mesh`` shard the *scenario axis* of the fused scan
    over devices via ``shard_map`` (see
    :func:`repro.launch.mesh.make_scenario_mesh`).  ``None`` runs
    unsharded on the default device; an explicit ``mesh`` (a 1-D
    ``jax.sharding.Mesh`` over the batch axis) takes precedence over
    ``num_devices``.  Per-scenario results are bit-exact against the
    unsharded scan for any device count (uneven ``S % D`` batches are
    edge-padded and sliced back).

    ``slot_budget`` caps how many §6 slot-universe entries the fused scan
    keeps *densely resident* per scenario (default
    ``fused.LB_MAX_SLOTS``).  Universes above the budget run with the
    tiled active-slot cache instead of falling back to the host engine.

    ``eval_every`` is the suboptimality evaluation cadence (iterations).

    ``kernel_backend`` selects how the fused scan evaluates its two hot
    paths (the §3 block-subgradient gather and the §5 grid-cache event
    application): ``"xla"`` — the jnp forms (default), ``"pallas"`` — the
    ``repro.kernels`` Pallas twins (``interpret=True`` on CPU so CI
    exercises the path everywhere; compiled on TPU).  Results are pinned
    bit-exact across backends on the same platform.
    """

    kind: str = "auto"
    num_devices: int | None = None
    mesh: Any | None = None  # a 1-D jax.sharding.Mesh over the batch axis
    slot_budget: int | None = None
    eval_every: int = 1
    kernel_backend: str = "xla"

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown engine kind {self.kind!r}; expected one of {_KINDS}"
            )
        if self.kernel_backend not in _KERNEL_BACKENDS:
            raise ValueError(
                f"unknown kernel_backend {self.kernel_backend!r}; expected "
                f"one of {_KERNEL_BACKENDS}"
            )
        if self.num_devices is not None and self.num_devices < 1:
            raise ValueError("num_devices must be >= 1")
        if self.slot_budget is not None and self.slot_budget < 1:
            raise ValueError("slot_budget must be >= 1")
        if self.eval_every < 1:
            raise ValueError("eval_every must be >= 1")


def as_engine_config(engine, *, _stacklevel: int = 2) -> EngineConfig:
    """Coerce ``engine`` to an :class:`EngineConfig`.

    Accepts an :class:`EngineConfig` (returned unchanged), ``None`` (the
    defaults), or a legacy ``"auto"|"scan"|"host"`` string — the
    deprecated alias for ``EngineConfig(kind=...)``, kept working with a
    ``DeprecationWarning``.

    ``_stacklevel`` lets the engine entry points that merely forward
    their ``engine`` kwarg here (e.g. ``run_convergence_batch``) attribute
    the warning to *their* caller — the line that actually wrote the
    legacy string — instead of to the forwarding frame.  The default
    points at a direct caller of this function.
    """
    if engine is None:
        return EngineConfig()
    if isinstance(engine, EngineConfig):
        return engine
    if isinstance(engine, str):
        warnings.warn(
            f"engine={engine!r} strings are deprecated; pass "
            f"EngineConfig(kind={engine!r}) instead",
            DeprecationWarning,
            stacklevel=_stacklevel,
        )
        return EngineConfig(kind=engine)
    raise TypeError(
        f"engine must be an EngineConfig or a legacy string, got {type(engine)}"
    )


@dataclasses.dataclass(frozen=True)
class EngineCapability:
    """Structured report of whether the fused scan can run a config.

    ``code`` is one of the ``CAP_*`` constants; ``supported`` says whether
    ``engine kind="scan"`` will run (possibly tiled) or raise.  The slot
    accounting fields let callers and error messages name the limit
    without re-deriving it: ``slots_total`` is the full §6 ladder
    universe, ``slots_resident`` how many slots the selected cache layout
    keeps densely materialized per scenario, ``slot_budget`` the budget
    they were compared against.
    """

    supported: bool
    code: str
    detail: str = ""
    slots_total: int = 0
    slots_resident: int = 0
    slot_budget: int = 0


class EngineCapabilityError(ValueError):
    """Raised by the fused engine for genuinely unsupported configs.

    A ``ValueError`` for backwards compatibility; carries the structured
    :class:`EngineCapability` as ``.capability`` so callers branch on
    ``capability.code`` instead of matching the message text.
    """

    def __init__(self, capability: EngineCapability):
        super().__init__(capability.detail)
        self.capability = capability
