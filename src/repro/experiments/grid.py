"""Scenario-grid driver for the §7 sweeps (seeds x methods x w x regimes).

One :class:`FleetTraces` draw is shared by every method within a burst
regime — common random numbers, so method comparisons are paired across
seeds exactly like the paper's figures pair runs on the same cluster.  The
scenario axis batches the seeds; methods and w-values (few) loop on the
outside, each resolved by the vectorized engine in
:mod:`repro.experiments.sweep`.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections.abc import Sequence

import numpy as np

from repro.experiments.sweep import (
    BatchedRunResult,
    replay_batch,
    scalar_reference,
    scalar_sync_reference,
    synchronous_times_batch,
)
from repro.latency.model import (
    ClusterLatencyModel,
    FleetTraces,
    make_heterogeneous_cluster,
    sample_fleet,
)


@dataclasses.dataclass(frozen=True)
class BurstRegime:
    """One burst environment of the sweep (paper §3.2 / Fig. 4)."""

    name: str
    rate: float  # burst arrivals per second per worker (0 = burst-free)
    factor_mean: float = 1.12  # mean multiplicative slowdown of a burst
    duration_mean: float = 60.0  # mean burst duration (s)


#: Burst-free cluster: straggling comes only from gamma tails.
CALM = BurstRegime("calm", 0.0)
#: The paper's measured regime (Fig. 4: ~12% slowdowns, ~1 min, every ~90 s).
PAPER_BURSTS = BurstRegime("paper_bursts", 1.0 / 90.0, 1.12, 60.0)
#: Heavy straggler regime: frequent multi-x slowdowns — where DSAG's
#: stale-tolerance should pay off most (paper §7.2-style stragglers).
HEAVY_BURSTS = BurstRegime("heavy_bursts", 1.0 / 20.0, 4.0, 30.0)

DEFAULT_REGIMES: tuple[BurstRegime, ...] = (CALM, PAPER_BURSTS, HEAVY_BURSTS)


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """One method column of the sweep, in engine terms.

    ``w = 0`` means "take the grid's w-value" (the wait-for-w sweep axis);
    ``rel_load`` is the per-task computational load relative to one
    subpartition task of the stochastic methods; ``sync`` selects the
    fully-vectorized no-queue-feedback fast path (GD / idealized coded).
    """

    name: str
    w: int
    margin: float = 0.0
    rel_load: float = 1.0
    sync: bool = False


def default_methods(
    n_workers: int,
    *,
    subpartitions: int = 10,
    code_rate: float = 45.0 / 49.0,
) -> tuple[MethodSpec, ...]:
    """The five §7 columns: GD, coded bound, SGD, SAG, DSAG.

    GD and coded process the full local block (load = subpartitions tasks,
    coded inflated by 1/rate); SAG has no staleness mechanism so it must run
    synchronously (w = N); SGD and DSAG take the swept w, DSAG with the
    §5.1 2% margin.
    """
    N = n_workers
    return (
        MethodSpec("gd", N, rel_load=float(subpartitions), sync=True),
        MethodSpec(
            "coded",
            int(math.ceil(code_rate * N)),
            rel_load=float(subpartitions) / code_rate,
            sync=True,
        ),
        MethodSpec("sgd", 0),
        MethodSpec("sag", N),
        MethodSpec("dsag", 0, margin=0.02),
    )


@dataclasses.dataclass
class SweepRow:
    """One (regime, method, w, seed) cell of the grid."""

    regime: str
    method: str
    w: int
    seed: int
    mean_iter_time: float
    total_time: float
    mean_fresh: float
    min_participation: float


@dataclasses.dataclass
class SweepOutcome:
    rows: list[SweepRow]
    n_workers: int
    n_seeds: int
    num_iterations: int
    engine_seconds: float
    results: dict[tuple[str, str, int], BatchedRunResult]
    traces: dict[str, FleetTraces]
    methods: tuple[MethodSpec, ...] = ()
    seed: int = 0  # base seed of the grid (recorded in the BENCH artifact)

    def mean_iter_time(self, regime: str, method: str, w: int | None = None) -> float:
        sel = [
            r.mean_iter_time
            for r in self.rows
            if r.regime == regime and r.method == method and (w is None or r.w == w)
        ]
        if not sel:
            raise KeyError(f"no rows for ({regime}, {method}, w={w})")
        return float(np.mean(sel))


def _run_method(
    traces: FleetTraces,
    spec: MethodSpec,
    w_eff: int,
    num_iterations: int,
) -> BatchedRunResult:
    if spec.sync:
        times, participation = synchronous_times_batch(
            traces, w_eff, num_iterations, loads=spec.rel_load,
            return_participation=True,
        )
        S = traces.num_scenarios
        return BatchedRunResult(
            iteration_times=times,
            fresh_counts=np.full((S, num_iterations), w_eff, dtype=np.int64),
            participation=participation,
        )
    return replay_batch(
        traces, w_eff, num_iterations, margin=spec.margin, loads=spec.rel_load
    )


def run_sweep(
    n_workers: int = 100,
    n_seeds: int = 10,
    num_iterations: int = 100,
    *,
    w_values: Sequence[int] = (),
    w_fracs: Sequence[float] = (0.8,),
    methods: Sequence[MethodSpec] | None = None,
    regimes: Sequence[BurstRegime] = DEFAULT_REGIMES,
    subpartitions: int = 10,
    cluster: ClusterLatencyModel | None = None,
    seed: int = 0,
) -> SweepOutcome:
    """Run the full (seeds x methods x w x regimes) grid, batched over seeds.

    ``w_values`` (absolute) or ``w_fracs`` (fractions of N) define the
    wait-for-w axis applied to the methods with ``w == 0`` (SGD, DSAG);
    fixed-w methods (GD, coded, SAG) run once per regime.
    """
    ws = sorted(
        {min(max(int(v), 1), n_workers) for v in w_values}
        | {min(max(round(f * n_workers), 1), n_workers) for f in w_fracs}
    )
    if not ws:
        raise ValueError("need at least one w value")
    methods = tuple(methods) if methods is not None else default_methods(
        n_workers, subpartitions=subpartitions
    )
    if cluster is None:
        cluster = make_heterogeneous_cluster(n_workers, burst_rate=0.0, seed=seed)
    elif cluster.num_workers != n_workers:
        # a silent mismatch would run "synchronous" methods at w < N and
        # stamp the artifact with the wrong fleet size
        raise ValueError(
            f"cluster has {cluster.num_workers} workers but n_workers={n_workers}"
        )

    rows: list[SweepRow] = []
    results: dict[tuple[str, str, int], BatchedRunResult] = {}
    traces_by_regime: dict[str, FleetTraces] = {}
    t0 = time.perf_counter()
    for ri, regime in enumerate(regimes):
        traces = sample_fleet(
            cluster,
            n_seeds,
            num_iterations,
            burst_rate=regime.rate,
            burst_factor_mean=regime.factor_mean,
            burst_duration_mean=regime.duration_mean,
            load_hint=max(m.rel_load for m in methods),
            seed=seed + 1000 * (ri + 1),
        )
        traces_by_regime[regime.name] = traces
        for spec in methods:
            for w in ws if spec.w == 0 else (spec.w,):
                w_eff = min(max(w, 1), n_workers)
                res = _run_method(traces, spec, w_eff, num_iterations)
                results[(regime.name, spec.name, w_eff)] = res
                iter_means = res.mean_iteration_time
                for s in range(n_seeds):
                    rows.append(
                        SweepRow(
                            regime=regime.name,
                            method=spec.name,
                            w=w_eff,
                            seed=s,
                            mean_iter_time=float(iter_means[s]),
                            total_time=float(res.iteration_times[s, -1]),
                            mean_fresh=float(res.fresh_counts[s].mean()),
                            min_participation=float(res.participation[s].min()),
                        )
                    )
    engine_seconds = time.perf_counter() - t0
    return SweepOutcome(
        rows=rows,
        n_workers=n_workers,
        n_seeds=n_seeds,
        num_iterations=num_iterations,
        engine_seconds=engine_seconds,
        results=results,
        traces=traces_by_regime,
        methods=methods,
        seed=seed,
    )


def scalar_sweep_seconds(outcome: SweepOutcome) -> float:
    """Wall-clock of the same grid through the scalar event loop.

    Replays every (regime, method, w, seed) cell of ``outcome`` one draw at
    a time — queue-feedback cells through the scalar event loop
    (:func:`scalar_reference`), sync cells through the scalar synchronous
    loop (:func:`scalar_sync_reference`), so each cell times the *same*
    dynamics its vectorized counterpart ran.  Uses the method specs the
    sweep was actually run with (margin / rel_load must match or the timing
    would compare different workloads).
    """
    specs = outcome.methods or default_methods(outcome.n_workers)
    spec_by_name = {m.name: m for m in specs}
    t0 = time.perf_counter()
    for (regime, method, w), _ in outcome.results.items():
        spec = spec_by_name[method]
        traces = outcome.traces[regime]
        for s in range(outcome.n_seeds):
            if spec.sync:
                scalar_sync_reference(
                    traces, s, w, outcome.num_iterations, loads=spec.rel_load
                )
            else:
                scalar_reference(
                    traces,
                    s,
                    w,
                    outcome.num_iterations,
                    margin=spec.margin,
                    loads=spec.rel_load,
                )
    return time.perf_counter() - t0
