"""Fused ``jax.lax.scan`` convergence engine.

The host engine (:func:`repro.experiments.convergence.run_convergence_batch`
with ``engine="host"``) runs one Python iteration per training iteration and
dispatches batched kernels from it.  This module compiles the *entire*
iteration body — §4.2 event algebra, §3 trace replay, block subgradients,
the §5 cache update as masked scatters, the iterate update, and the
suboptimality evaluation — into one jittable function and scans it over the
whole run: a single XLA dispatch for a complete ``[S]``-scenario training
sweep, ready for accelerators.

Bit-exactness contract (pinned by ``tests/test_fused.py``): for every
scenario, the scan produces the same bits as the host engine and the scalar
:class:`~repro.cluster.simulator.TrainingSimulator` replaying the same
trace.  Three ingredients make that possible:

* every float expression is shared: the problems'
  :class:`~repro.core.problems.FusedKernels` are called from all three
  engines, and the event algebra mirrors
  :func:`~repro.cluster.simulator.task_finish_time` /
  :func:`~repro.cluster.simulator.margin_deadline` term by term;
* block subgradients are evaluated at the static
  :func:`~repro.core.problems.width_bucket` ladder — one kernel call per
  possible bucket, rows selected by their actual width — so a given
  (iterate, interval) is always computed at the same static shape;
* the §5 cache is a *fixed slot universe*: without §6 repartitioning the
  interval set is exactly the initial subpartition grid, so per-scenario
  cache state is dense ``[S, E]`` arrays and each event rank applies as one
  masked scatter, sequenced per scenario in event-time order by an inner
  ``fori_loop`` (float accumulation order preserved).

§6 load-balanced configs run inside the scan too (``_run_scan_lb``): the
carry additionally holds the profiler's task-slot sample buffers, the
per-worker ladder index of the current subpartition count, the optimizer's
``h_min``/schedule state, and pending repartitions; Algorithm 1 itself is
the jittable :mod:`repro.lb.jit_optimizer` (the same traceable functions
the host optimizer jits), and the cache's slot universe is pre-allocated
over every interval the p-ladder can reach
(:func:`repro.core.gradient_cache.build_slot_universe`), so a repartition
is a mask flip over static shapes.  The one genuinely unsupported case —
a slot universe larger than :data:`LB_MAX_SLOTS` — raises a
``ValueError`` here; ``engine="auto"`` routes only that case to the host
engine (the documented escape hatch).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.cluster.simulator import (
    MethodConfig,
    effective_w,
    lb_ladder_for,
    margin_deadline,
    task_finish_time,
)
from repro.core.gradient_cache import SlotUniverse, build_slot_universe
from repro.core.problems import FiniteSumProblem, FusedKernels, width_bucket
from repro.latency.model import FleetTraces, comp_latency_expr
from repro.lb import jit_optimizer as jlb
from repro.lb.partitioner import p_start, p_stop

#: ceiling on the pre-allocated §6 slot universe (per-slot float64 value
#: buffers are the fused engine's memory trade-off); configs above it are
#: the documented host-engine escape hatch of ``engine="auto"``
LB_MAX_SLOTS = 250_000


@dataclasses.dataclass(frozen=True)
class _StaticSpec:
    """Hashable static configuration of one fused-scan compilation."""

    name: str
    w_wait: int
    eta: float
    margin: float  # effective margin (0.0 when unused)
    comp_scale: float
    process_full: bool
    uses_cache: bool
    accepts_stale: bool
    num_iterations: int
    base_start: Tuple[int, ...]
    base_stop: Tuple[int, ...]
    sub_p: Tuple[int, ...]  # initial (and, without §6, permanent) p_i
    buckets: Tuple[int, ...]  # static width_bucket ladder, ascending
    slot_offsets: Tuple[int, ...]  # per-worker first slot (cache methods)
    num_slots: int
    # §6 load balancing (empty/zero for non-LB specs)
    load_balance: bool = False
    ladder: Tuple[int, ...] = ()  # the p-ladder Algorithm 1 climbs
    lb_interval: float = 0.0
    lb_startup_delay: float = 0.0
    lb_margin: float = 0.0  # optimizer-input margin (= config.margin)
    lb_p0: int = 0  # the optimizer-facing initial p (config.subpartitions)


def _possible_widths(n_local: int, p: int, full: bool) -> set:
    if full:
        return {n_local}
    return {k * n_local // p - (k - 1) * n_local // p for k in range(1, p + 1)}


def _static_spec(
    problem: FiniteSumProblem,
    config: MethodConfig,
    num_workers: int,
    num_iterations: int,
    cost_scale: float,
    universe: Optional[SlotUniverse] = None,
) -> _StaticSpec:
    n = problem.num_samples
    N = num_workers
    cfg = config
    base_start = tuple(p_start(n, N, i + 1) for i in range(N))
    base_stop = tuple(p_stop(n, N, i + 1) for i in range(N))
    n_local = [b - a + 1 for a, b in zip(base_start, base_stop)]
    process_full = cfg.name in ("gd", "coded")
    sub_p = tuple(min(cfg.subpartitions, nl) for nl in n_local)
    widths = set()
    for nl, p in zip(n_local, sub_p):
        widths |= _possible_widths(nl, p, process_full)
    ladder: Tuple[int, ...] = ()
    if cfg.load_balance:
        ladder = lb_ladder_for(cfg, np.asarray(n_local))
        if not process_full:
            # any ladder interval's width can appear once repartitions start
            for a, b in zip(base_start, base_stop):
                nl = b - a + 1
                for raw in ladder:
                    widths |= _possible_widths(nl, min(raw, nl), False)
    buckets = tuple(sorted({width_bucket(m, n) for m in widths}))
    if cfg.uses_cache:
        if cfg.load_balance:
            assert universe is not None
            slot_offsets = (0,) * N  # slots come from the universe table
            num_slots = universe.num_slots
        else:
            offsets = np.concatenate([[0], np.cumsum(sub_p)])
            slot_offsets = tuple(int(o) for o in offsets[:-1])
            num_slots = int(offsets[-1])
    else:
        slot_offsets = (0,) * N
        num_slots = 0
    margin_eff = cfg.margin if (cfg.uses_margin and cfg.margin > 0) else 0.0
    return _StaticSpec(
        name=cfg.name,
        w_wait=effective_w(cfg, N),
        eta=float(cfg.eta),
        margin=float(margin_eff),
        comp_scale=float(
            cost_scale * (1.0 / cfg.code_rate if cfg.name == "coded" else 1.0)
        ),
        process_full=process_full,
        uses_cache=cfg.uses_cache,
        accepts_stale=cfg.accepts_stale,
        num_iterations=num_iterations,
        base_start=base_start,
        base_stop=base_stop,
        sub_p=sub_p,
        buckets=buckets,
        slot_offsets=slot_offsets,
        num_slots=num_slots,
        load_balance=bool(cfg.load_balance),
        ladder=ladder,
        lb_interval=float(cfg.lb_interval),
        lb_startup_delay=float(cfg.lb_startup_delay),
        lb_margin=float(cfg.margin),
        lb_p0=int(cfg.subpartitions),
    )


def _bcast(mask, value_ndim: int):
    """Reshape an [S] mask so it broadcasts over value dimensions."""
    return mask.reshape(mask.shape + (1,) * value_ndim)


def _subgradients(kernels: FusedKernels, spec: _StaticSpec, V, lo, hi):
    """[S, N, ...] block subgradients via the static width-bucket ladder.

    One kernel dispatch per possible bucket (all S*N tasks each time), rows
    selected by their actual width — bit-identical to the host wrapper,
    which routes each row to the same bucket.
    """
    S, N = lo.shape
    n = kernels.num_samples
    widths = hi - lo + 1
    vdim = len(kernels.value_shape)
    Vb = jnp.broadcast_to(
        V[:, None], (S, N) + kernels.value_shape
    ).reshape((S * N,) + kernels.value_shape)
    lo_f = lo.reshape(-1)
    w_f = widths.reshape(-1)
    out = None
    prev = 0
    for b in spec.buckets:
        block = kernels.sub_blocks(Vb, lo_f, w_f, b).reshape(
            (S, N) + kernels.value_shape
        )
        if b == n:
            sel = widths == n
        else:
            sel = (widths != n) & (widths <= b) & (widths > prev)
        out = block if out is None else jnp.where(_bcast(sel, vdim), block, out)
        prev = b
    return out


def _apply_cache_events(
    spec: _StaticSpec,
    slot_width,
    cache_state,
    ev_valid,
    ev_time,
    ev_slot,
    ev_tag,
    ev_vals,
):
    """The §5 update for one iteration's events, as masked scatters.

    ``ev_*`` are ``[S, E_ev]`` tables (stale then fresh halves for DSAG,
    fresh only for SAG).  Events are ranked per scenario by a stable sort
    on event time (+inf where invalid) and applied rank by rank: one rank
    holds at most one event per scenario, so its updates are a single
    vectorized masked scatter, and the per-scenario float accumulation
    order of the running sums matches the host cache's time-ordered
    inserts bit for bit.  With a fixed slot universe an active exact-match
    slot is the only possible overlap, so the scalar cache's eviction walk
    reduces to staleness dominance + in-place update (the SAG fast path).
    """
    sums, values, iters, covered, rejected = cache_state
    S, E_ev = ev_time.shape
    vdim = values.ndim - 2
    order = jnp.argsort(jnp.where(ev_valid, ev_time, jnp.inf), axis=1, stable=True)
    s_idx = jnp.arange(S)
    flat_vals = ev_vals.reshape((S * E_ev,) + ev_vals.shape[2:])

    def rank_body(j, state):
        sums, values, iters, covered, rejected = state
        e = order[:, j]
        flat = s_idx * E_ev + e
        valid = ev_valid.reshape(-1)[flat]
        slot = jnp.clip(ev_slot.reshape(-1)[flat], 0, spec.num_slots - 1)
        tag = ev_tag.reshape(-1)[flat]
        v64 = flat_vals[flat].astype(jnp.float64)
        cur_it = iters[s_idx, slot]
        active = cur_it >= 0
        dom = active & (cur_it >= tag)
        acc = valid & ~dom
        rej = valid & dom
        old = values[s_idx, slot]
        delta = v64 - jnp.where(_bcast(active, vdim), old, 0.0)
        sums = jnp.where(_bcast(acc, vdim), sums + delta, sums)
        values = values.at[s_idx, slot].set(jnp.where(_bcast(acc, vdim), v64, old))
        iters = iters.at[s_idx, slot].set(jnp.where(acc, tag, cur_it))
        covered = covered + jnp.where(acc & ~active, slot_width[slot], 0)
        rejected = rejected + rej.astype(rejected.dtype)
        return sums, values, iters, covered, rejected

    return jax.lax.fori_loop(
        0, E_ev, rank_body, (sums, values, iters, covered, rejected)
    )


def _apply_cache_events_lb(
    spec: _StaticSpec,
    slot_width,
    overlap_idx,
    cache_state,
    ev_valid,
    ev_time,
    ev_slot,
    ev_tag,
    ev_vals,
):
    """The full §5 update over the pre-allocated §6 slot universe.

    Like :func:`_apply_cache_events`, but once repartitions are possible an
    event's interval can overlap *other* active slots.  ``overlap_idx[e]``
    statically lists the same-worker slots intersecting slot ``e``
    (sorted by interval start, -1 padded); per event rank the update is
    the scalar cache's walk verbatim: staleness dominance over all active
    overlaps, sequential eviction subtraction in start order (a masked
    ``fori_loop``, preserving the scalar float grouping), then the insert
    — the SAG-style in-place delta when the event's own slot is active
    (disjointness makes it the only possible overlap), a plain add
    otherwise.  Also maintains the eviction counter the host caches track.

    Performance shape (load-bearing — the first implementation was ~100x
    slower than the host engine): inside the rank loop the big ``[S, E,
    ...]`` value table is **write-only**.  Reading it there (for eviction
    subtraction or the in-place delta) defeats XLA's in-place aliasing of
    the loop carry under ``lax.scan`` and copies the whole table once per
    event rank (~minutes per 100-worker run); ``lax.cond`` is no escape
    (~9 ms per rank on the CPU thunk runtime).  Instead, the live value
    of any slot is *reconstructed* from small read-only buffers: ``wmap``
    maps each slot to the rank of its last accepted write this iteration
    (so the value is a row of the ranked event table), and slots not yet
    written this iteration read from ``values0``, the frozen loop-entry
    buffer — one table copy per iteration instead of one per rank.  Both
    sources hold bit-identical float64 values to what the table itself
    would return.  The rank loop and the eviction sub-loop run to
    *dynamic* trip counts (deepest valid rank / last evicted overlap), so
    empty ranks and the no-eviction common case cost nothing.
    """
    sums, values, iters, covered, rejected, evictions = cache_state
    S, E_ev = ev_time.shape
    E = spec.num_slots
    Omax = overlap_idx.shape[1]
    vdim = values.ndim - 2
    order = jnp.argsort(jnp.where(ev_valid, ev_time, jnp.inf), axis=1, stable=True)
    s_idx = jnp.arange(S)
    # event tables in rank order: one gather each, outside the rank loop
    valid_r = jnp.take_along_axis(ev_valid, order, axis=1)
    slot_r = jnp.clip(jnp.take_along_axis(ev_slot, order, axis=1), 0, E - 1)
    tag_r = jnp.take_along_axis(ev_tag, order, axis=1)
    vals_r = jnp.take_along_axis(
        ev_vals, order.reshape(order.shape + (1,) * vdim), axis=1
    ).astype(jnp.float64)
    values0 = values  # frozen pre-iteration table (read-only below)
    wmap0 = jnp.full((S, E), -1, jnp.int32)
    # ranks beyond every scenario's valid events are exact no-ops: skip
    n_ranks = jnp.max(jnp.sum(valid_r, axis=1))

    def rank_body(j, state):
        sums, values, iters, covered, rejected, evictions, wmap = state
        valid = valid_r[:, j]
        slot = slot_r[:, j]
        tag = tag_r[:, j]
        v64 = vals_r[:, j]
        ov = overlap_idx[slot]  # [S, Omax]
        ov_safe = jnp.clip(ov, 0, E - 1)
        ov_iters = iters[s_idx[:, None], ov_safe]
        ov_active = (ov >= 0) & (ov_iters >= 0)
        own_it = iters[s_idx, slot]
        own_active = own_it >= 0
        # staleness dominance over every active overlapping entry
        dom = (own_active & (own_it >= tag)) | jnp.any(
            ov_active & (ov_iters >= tag[:, None]), axis=1
        )
        acc = valid & ~dom
        rej = valid & dom
        evict = ov_active & acc[:, None]
        # live values of the overlap candidates, reconstructed (see above)
        widx = wmap[s_idx[:, None], ov_safe]  # [S, Omax]
        v_new = vals_r[s_idx[:, None], jnp.clip(widx, 0, E_ev - 1)]
        v_old = values0[s_idx[:, None], ov_safe]
        v_sub = jnp.where(_bcast(widx >= 0, vdim), v_new, v_old)

        def sub_body(o, acc_sm):
            return jnp.where(
                _bcast(evict[:, o], vdim), acc_sm - v_sub[:, o], acc_sm
            )

        # masked sequential subtraction in start order (overlap lists are
        # pre-sorted); trip count = last evicted overlap, usually 0
        n_sub = jnp.max(jnp.where(evict, jnp.arange(Omax) + 1, 0))
        sums = jax.lax.fori_loop(0, n_sub, sub_body, sums)
        # deactivate evicted slots via an O(S*Omax) scatter-min: evicted
        # slots get -1, padding writes a huge sentinel (a no-op under
        # min), so duplicate indices from the -1 padding clip cannot
        # corrupt real slots
        upd = jnp.where(evict, jnp.int64(-1), jnp.iinfo(jnp.int64).max)
        iters = iters.at[s_idx[:, None], ov_safe].min(upd)
        removed = jnp.sum(jnp.where(evict, slot_width[ov_safe], 0), axis=1)
        evictions = evictions + jnp.sum(evict, axis=1)
        # insert: exact-active match -> in-place delta (degrades to SAG);
        # otherwise v - 0.0 == v, the scalar slow path's plain add.  The
        # old value is reconstructed, never read from the live table.
        own_wi = wmap[s_idx, slot]
        own_live = jnp.where(
            _bcast(own_wi >= 0, vdim),
            vals_r[s_idx, jnp.clip(own_wi, 0, E_ev - 1)],
            values0[s_idx, slot],
        )
        delta = v64 - jnp.where(_bcast(own_active, vdim), own_live, 0.0)
        sums = jnp.where(_bcast(acc, vdim), sums + delta, sums)
        values = values.at[s_idx, slot].set(
            jnp.where(_bcast(acc, vdim), v64, own_live)
        )
        # the event's own slot is never in its own overlap list, so the
        # scatter-min above cannot have touched own_it
        iters = iters.at[s_idx, slot].set(jnp.where(acc, tag, own_it))
        wmap = wmap.at[s_idx, slot].set(jnp.where(acc, jnp.int32(j), own_wi))
        covered = covered + jnp.where(
            acc, jnp.where(own_active, 0, slot_width[slot]) - removed, 0
        )
        rejected = rejected + rej.astype(rejected.dtype)
        return sums, values, iters, covered, rejected, evictions, wmap

    out = jax.lax.fori_loop(
        0,
        n_ranks,
        rank_body,
        (sums, values, iters, covered, rejected, evictions, wmap0),
    )
    return out[:6]


def _fresh_accumulate(kernels, fresh, finish, vals):
    """gd/sgd: sum fresh values per scenario in event-time order."""
    S, N = fresh.shape
    vdim = len(kernels.value_shape)
    order = jnp.argsort(jnp.where(fresh, finish, jnp.inf), axis=1, stable=True)
    s_idx = jnp.arange(S)
    flat_vals = vals.reshape((S * N,) + vals.shape[2:])
    grad0 = jnp.zeros((S,) + kernels.value_shape, dtype=jnp.float64)

    def rank_body(j, grad_acc):
        e = order[:, j]
        flat = s_idx * N + e
        valid = fresh.reshape(-1)[flat]
        v64 = flat_vals[flat].astype(jnp.float64)
        return jnp.where(_bcast(valid, vdim), grad_acc + v64, grad_acc)

    return jax.lax.fori_loop(0, N, rank_body, grad0)


def _run_scan(
    kernels: FusedKernels,
    spec: _StaticSpec,
    comm,
    comp_unit,
    slowdown,
    burst_start,
    burst_end,
    burst_factor,
    V0,
    eval_mask,
):
    """The jitted driver: precompute static tables, scan the fused body."""
    S, N, _K = comm.shape
    T = spec.num_iterations
    n = kernels.num_samples
    vshape = kernels.value_shape
    vdim = len(vshape)
    base_start = jnp.asarray(spec.base_start, dtype=jnp.int64)
    base_stop = jnp.asarray(spec.base_stop, dtype=jnp.int64)
    n_local = base_stop - base_start + 1
    sub_p = jnp.asarray(spec.sub_p, dtype=jnp.int64)
    offsets = jnp.asarray(spec.slot_offsets, dtype=jnp.int64)
    E = spec.num_slots
    if spec.uses_cache:
        # static slot universe: slot (i, k) -> interval width
        sw = []
        for i in range(N):
            nl, p = spec.base_stop[i] - spec.base_start[i] + 1, spec.sub_p[i]
            if spec.process_full:
                sw.extend([nl] * p)
            else:
                sw.extend([k * nl // p - (k - 1) * nl // p for k in range(1, p + 1)])
        slot_width = jnp.asarray(sw, dtype=jnp.int64)
    else:
        slot_width = jnp.zeros((0,), dtype=jnp.int64)

    s_idx2 = jnp.arange(S)[:, None]
    w_idx2 = jnp.arange(N)[None, :]

    def burst_factor_at(start):
        if burst_start.shape[2] == 0:
            return jnp.ones_like(start)
        tt = start[:, :, None]
        active = (burst_start <= tt) & (tt < burst_end)
        return jnp.where(active, burst_factor, 1.0).max(axis=2)

    def body(carry, xs):
        (
            V,
            free_at,
            iter_end,
            draw_idx,
            sub_k,
            flight_slot,
            flight_titer,
            flight_comp,
            flight_comm,
            flight_val,
            cache_state,
            lat_matrix,
        ) = carry
        t, do_eval = xs
        assign = iter_end
        idle = free_at <= assign[:, None]

        if spec.process_full:
            lo = jnp.broadcast_to(base_start, (S, N))
            hi = jnp.broadcast_to(base_stop, (S, N))
        else:
            lo = base_start[None, :] + (sub_k - 1) * n_local[None, :] // sub_p[None, :]
            hi = base_start[None, :] + sub_k * n_local[None, :] // sub_p[None, :] - 1
        cost = (kernels.cost_per_row * (hi - lo + 1)) * spec.comp_scale

        # -- §3 trace replay (THE shared latency expression) ----------------
        start = jnp.where(idle, assign[:, None], free_at)
        comm_d = jnp.take_along_axis(comm, draw_idx[:, :, None], axis=2)[:, :, 0]
        unit = jnp.take_along_axis(comp_unit, draw_idx[:, :, None], axis=2)[:, :, 0]
        comp_d = comp_latency_expr(
            unit, cost, slowdown[None, :], burst_factor_at(start)
        )
        # finalize the §3 product before the event algebra consumes it: the
        # LLVM backend otherwise contracts the last multiply into the
        # task_finish_time add as an FMA (skipping the intermediate
        # rounding the host engine's numpy performs), which changes the
        # final ULP whenever slowdown/burst factors are not exactly 1.0.
        # max(x, 0) is exact for the positive latencies and is a pattern
        # the contraction cannot see through (lax.optimization_barrier is
        # erased before LLVM and does NOT prevent this).
        comp_d = jnp.maximum(comp_d, 0.0)

        # -- event resolution (the shared method-semantics helpers) ---------
        finish = task_finish_time(start, comp_d, comm_d)
        tau_w = jnp.sort(finish, axis=1)[:, spec.w_wait - 1]
        if spec.margin > 0.0:
            deadline = margin_deadline(tau_w, assign, spec.margin)
        else:
            deadline = tau_w
        started = idle | (free_at <= deadline[:, None])
        fresh = started & (finish <= deadline[:, None])
        stale_done = (~idle) & (free_at <= deadline[:, None])
        fresh_cnt = fresh.sum(axis=1)
        stale_ev = jnp.where(stale_done, free_at, -jnp.inf)
        fresh_ev = jnp.where(fresh, finish, -jnp.inf)
        iter_end_new = jnp.maximum(
            jnp.maximum(stale_ev.max(axis=1), fresh_ev.max(axis=1)), tau_w
        )

        # -- latency attribution by the task's own iteration ----------------
        titer_safe = jnp.clip(flight_titer, 0, T - 1)
        cur = lat_matrix[s_idx2, titer_safe, w_idx2]
        lat_matrix = lat_matrix.at[s_idx2, titer_safe, w_idx2].set(
            jnp.where(stale_done, flight_comp + flight_comm, cur)
        )
        lat_matrix = lat_matrix.at[:, t, :].set(
            jnp.where(fresh, comp_d + comm_d, lat_matrix[:, t, :])
        )

        # -- batched subgradients (skipped entirely for coded) --------------
        if spec.name != "coded":
            vals = _subgradients(kernels, spec, V, lo, hi)
        else:
            vals = None

        # -- §5 cache / gradient accumulation -------------------------------
        slot_cur = offsets[None, :] + sub_k - 1 if spec.uses_cache else None
        if spec.uses_cache:
            if spec.accepts_stale:  # dsag: stale half then fresh half
                ev_valid = jnp.concatenate([stale_done, fresh], axis=1)
                ev_time = jnp.concatenate([free_at, finish], axis=1)
                ev_slot = jnp.concatenate([flight_slot, slot_cur], axis=1)
                ev_tag = jnp.concatenate(
                    [flight_titer, jnp.full((S, N), 1, jnp.int64) * t], axis=1
                )
                ev_vals = jnp.concatenate([flight_val, vals], axis=1)
            else:  # sag: fresh results only
                ev_valid, ev_time = fresh, finish
                ev_slot = slot_cur
                ev_tag = jnp.full((S, N), 1, jnp.int64) * t
                ev_vals = vals
            cache_state = _apply_cache_events(
                spec, slot_width, cache_state, ev_valid, ev_time, ev_slot,
                ev_tag, ev_vals,
            )
            sums, _, _, covered, _ = cache_state
            xi = jnp.maximum(covered / n, 1e-12)
            grad = sums / _bcast(xi, vdim) + kernels.regularizer_grad(V)
        elif spec.name == "coded":
            # idealized MDS bound: exact gradient at full-range width
            g = kernels.sub_blocks(
                V,
                jnp.ones((S,), jnp.int64),
                jnp.full((S,), n, jnp.int64),
                n,
            ).astype(jnp.float64)
            grad = g + kernels.regularizer_grad(V)
        elif spec.name == "gd":
            grad = _fresh_accumulate(kernels, fresh, finish, vals) + (
                kernels.regularizer_grad(V)
            )
        else:  # sgd: scale the partial sum by observed coverage
            grad_acc = _fresh_accumulate(kernels, fresh, finish, vals)
            covered_f = jnp.sum(jnp.where(fresh, hi - lo + 1, 0), axis=1)
            xi = jnp.maximum(covered_f / n, 1e-12)
            grad = grad_acc / _bcast(xi, vdim) + kernels.regularizer_grad(V)

        # -- iterate update + suboptimality ---------------------------------
        V_new = kernels.project((V - spec.eta * grad).astype(V.dtype))
        subopt_t = jax.lax.cond(
            do_eval,
            lambda v: kernels.suboptimality(v),
            lambda v: jnp.full((S,), jnp.nan, dtype=jnp.float64),
            V_new,
        )

        # -- commit worker state for started tasks --------------------------
        if not spec.process_full:
            sub_k = jnp.where(started, sub_k % sub_p[None, :] + 1, sub_k)
        free_at = jnp.where(started, finish, free_at)
        draw_idx = draw_idx + started.astype(jnp.int64)
        if spec.uses_cache:
            flight_slot = jnp.where(started, slot_cur, flight_slot)
        flight_titer = jnp.where(started, t, flight_titer)
        flight_comp = jnp.where(started, comp_d, flight_comp)
        flight_comm = jnp.where(started, comm_d, flight_comm)
        if spec.accepts_stale:
            flight_val = jnp.where(_bcast(started, vdim), vals, flight_val)

        carry = (
            V_new,
            free_at,
            iter_end_new,
            draw_idx,
            sub_k,
            flight_slot,
            flight_titer,
            flight_comp,
            flight_comm,
            flight_val,
            cache_state,
            lat_matrix,
        )
        return carry, (iter_end_new, subopt_t, fresh_cnt)

    val_dtype = jnp.dtype(kernels.value_dtype)
    cache0 = (
        jnp.zeros((S,) + vshape, dtype=jnp.float64),  # sums
        jnp.zeros((S, max(E, 1)) + vshape, dtype=jnp.float64),  # values
        jnp.full((S, max(E, 1)), -1, dtype=jnp.int64),  # iters
        jnp.zeros((S,), dtype=jnp.int64),  # covered
        jnp.zeros((S,), dtype=jnp.int64),  # rejected_stale
    )
    carry0 = (
        V0,
        jnp.zeros((S, N)),  # free_at
        jnp.zeros((S,)),  # iter_end
        jnp.zeros((S, N), dtype=jnp.int64),  # draw_idx
        jnp.ones((S, N), dtype=jnp.int64),  # sub_k
        jnp.full((S, N), -1, dtype=jnp.int64),  # flight_slot
        jnp.full((S, N), -1, dtype=jnp.int64),  # flight_titer
        jnp.zeros((S, N)),  # flight_comp
        jnp.zeros((S, N)),  # flight_comm
        jnp.zeros((S, N) + vshape, dtype=val_dtype),  # flight_val
        cache0,
        jnp.full((S, T, N), jnp.nan),  # lat_matrix
    )
    xs = (jnp.arange(T, dtype=jnp.int64), eval_mask)
    carry, ys = jax.lax.scan(body, carry0, xs)
    times, subopt, fresh_counts = ys
    cache_state = carry[10]
    return (
        times.T,
        subopt.T,
        fresh_counts.T,
        carry[11],  # lat_matrix
        cache_state[4],  # rejected_stale
    )


def _run_scan_lb(
    kernels: FusedKernels,
    spec: _StaticSpec,
    slot_table,
    slot_width,
    overlap_idx,
    comm,
    comp_unit,
    slowdown,
    burst_start,
    burst_end,
    burst_factor,
    V0,
    eval_mask,
    lb_key,
):
    """The jitted driver for §6 load-balanced configs.

    The :func:`_run_scan` body plus the load-balancer in the carry:
    task-slot profiler buffers, ladder indices of each worker's current
    subpartition count, pending/published p vectors, ``h_min`` and the
    publication schedule.  Algorithm 1 runs inside the scan via
    :mod:`repro.lb.jit_optimizer` (behind ``lax.cond`` so iterations with
    no due scenario skip it), repartitions resolve with the vectorized
    Algorithm-2 walk, and cache slots come from the pre-allocated ladder
    universe (``slot_table``), so every shape stays static.
    """
    S, N, _K = comm.shape
    T = spec.num_iterations
    n = kernels.num_samples
    vshape = kernels.value_shape
    vdim = len(vshape)
    base_start = jnp.asarray(spec.base_start, dtype=jnp.int64)
    base_stop = jnp.asarray(spec.base_stop, dtype=jnp.int64)
    n_local = base_stop - base_start + 1
    E = max(spec.num_slots, 1)
    L = len(spec.ladder)
    raw = jnp.asarray(spec.ladder, dtype=jnp.int64)
    # per-worker effective ladder (int twin of jlb.ladder_tables)
    eff = jnp.minimum(raw[None, :], n_local[:, None])  # [N, L]
    idx_cap = jnp.minimum(jnp.sum(raw[None, :] < n_local[:, None], axis=1), L - 1)
    n_j_b = jnp.broadcast_to(n_local.astype(jnp.float64), (S, N))

    s_idx2 = jnp.arange(S)[:, None]
    w_idx2 = jnp.arange(N)[None, :]

    def snap_int(p_vals):
        """Ladder index of exact-member p values ([S, N] int)."""
        cnt = jnp.sum(eff[None, :, :] <= p_vals[:, :, None], axis=-1)
        return jnp.clip(cnt - 1, 0, idx_cap[None, :])

    def burst_factor_at(start):
        if burst_start.shape[2] == 0:
            return jnp.ones_like(start)
        tt = start[:, :, None]
        active = (burst_start <= tt) & (tt < burst_end)
        return jnp.where(active, burst_factor, 1.0).max(axis=2)

    def body(carry, xs):
        (
            V,
            free_at,
            iter_end,
            draw_idx,
            sub_idx,
            sub_k,
            pending_p,
            current_p,
            h_min,
            next_lb,
            flight_slot,
            flight_titer,
            flight_comp,
            flight_comm,
            flight_assigned,
            flight_val,
            cache_state,
            lat_matrix,
            prof,
        ) = carry
        prof_t, prof_comm, prof_comp, prof_valid = prof
        t, do_eval = xs
        assign = iter_end
        idle = free_at <= assign[:, None]

        # -- Algorithm-2 alignment for pending repartitions (tentative) -----
        cur_p = eff[w_idx2, sub_idx]
        p_req = jnp.clip(pending_p, 1, n_local[None, :])
        needs = (pending_p >= 0) & (p_req != cur_p)
        _, k_new = jlb.align_batch(n_local[None, :], cur_p, p_req, sub_k, needs)
        cand_idx = jnp.where(needs, snap_int(p_req), sub_idx)
        cand_k = jnp.where(needs, k_new, sub_k)
        cand_p = jnp.where(needs, p_req, cur_p)

        if spec.process_full:
            lo = jnp.broadcast_to(base_start, (S, N))
            hi = jnp.broadcast_to(base_stop, (S, N))
        else:
            lo = base_start[None, :] + (cand_k - 1) * n_local[None, :] // cand_p
            hi = base_start[None, :] + cand_k * n_local[None, :] // cand_p - 1
        cost = (kernels.cost_per_row * (hi - lo + 1)) * spec.comp_scale

        # -- §3 trace replay (THE shared latency expression) ----------------
        start = jnp.where(idle, assign[:, None], free_at)
        comm_d = jnp.take_along_axis(comm, draw_idx[:, :, None], axis=2)[:, :, 0]
        unit = jnp.take_along_axis(comp_unit, draw_idx[:, :, None], axis=2)[:, :, 0]
        comp_d = comp_latency_expr(
            unit, cost, slowdown[None, :], burst_factor_at(start)
        )
        # finalize the §3 product before the event algebra consumes it: the
        # LLVM backend otherwise contracts the last multiply into the
        # task_finish_time add as an FMA (skipping the intermediate
        # rounding the host engine's numpy performs), which changes the
        # final ULP whenever slowdown/burst factors are not exactly 1.0.
        # max(x, 0) is exact for the positive latencies and is a pattern
        # the contraction cannot see through (lax.optimization_barrier is
        # erased before LLVM and does NOT prevent this).
        comp_d = jnp.maximum(comp_d, 0.0)

        # -- event resolution (the shared method-semantics helpers) ---------
        finish = task_finish_time(start, comp_d, comm_d)
        tau_w = jnp.sort(finish, axis=1)[:, spec.w_wait - 1]
        if spec.margin > 0.0:
            deadline = margin_deadline(tau_w, assign, spec.margin)
        else:
            deadline = tau_w
        started = idle | (free_at <= deadline[:, None])
        fresh = started & (finish <= deadline[:, None])
        stale_done = (~idle) & (free_at <= deadline[:, None])
        fresh_cnt = fresh.sum(axis=1)
        stale_ev = jnp.where(stale_done, free_at, -jnp.inf)
        fresh_ev = jnp.where(fresh, finish, -jnp.inf)
        iter_end_new = jnp.maximum(
            jnp.maximum(stale_ev.max(axis=1), fresh_ev.max(axis=1)), tau_w
        )

        # -- latency attribution by the task's own iteration ----------------
        titer_safe = jnp.clip(flight_titer, 0, T - 1)
        cur = lat_matrix[s_idx2, titer_safe, w_idx2]
        lat_matrix = lat_matrix.at[s_idx2, titer_safe, w_idx2].set(
            jnp.where(stale_done, flight_comp + flight_comm, cur)
        )
        lat_matrix = lat_matrix.at[:, t, :].set(
            jnp.where(fresh, comp_d + comm_d, lat_matrix[:, t, :])
        )

        # -- §6.1 profiler feed: one task-slot sample per observed
        # completion (same slots and float expressions as MomentBuffer) -----
        stale_rt = free_at - flight_assigned
        stale_comm = jnp.maximum(stale_rt - flight_comp, 0.0)
        prof_t = prof_t.at[s_idx2, w_idx2, titer_safe].set(
            jnp.where(stale_done, free_at, prof_t[s_idx2, w_idx2, titer_safe])
        )
        prof_comm = prof_comm.at[s_idx2, w_idx2, titer_safe].set(
            jnp.where(stale_done, stale_comm, prof_comm[s_idx2, w_idx2, titer_safe])
        )
        prof_comp = prof_comp.at[s_idx2, w_idx2, titer_safe].set(
            jnp.where(stale_done, flight_comp, prof_comp[s_idx2, w_idx2, titer_safe])
        )
        prof_valid = prof_valid.at[s_idx2, w_idx2, titer_safe].set(
            prof_valid[s_idx2, w_idx2, titer_safe] | stale_done
        )
        fresh_rt = finish - assign[:, None]
        fresh_comm = jnp.maximum(fresh_rt - comp_d, 0.0)
        prof_t = prof_t.at[:, :, t].set(jnp.where(fresh, finish, prof_t[:, :, t]))
        prof_comm = prof_comm.at[:, :, t].set(
            jnp.where(fresh, fresh_comm, prof_comm[:, :, t])
        )
        prof_comp = prof_comp.at[:, :, t].set(
            jnp.where(fresh, comp_d, prof_comp[:, :, t])
        )
        prof_valid = prof_valid.at[:, :, t].set(prof_valid[:, :, t] | fresh)

        # -- batched subgradients (skipped entirely for coded) --------------
        if spec.name != "coded":
            vals = _subgradients(kernels, spec, V, lo, hi)
        else:
            vals = None

        # -- §5 cache / gradient accumulation over the slot universe --------
        if spec.uses_cache:
            slot_cur = slot_table[w_idx2, cand_idx, cand_k - 1]
            if spec.accepts_stale:  # dsag: stale half then fresh half
                ev_valid = jnp.concatenate([stale_done, fresh], axis=1)
                ev_time = jnp.concatenate([free_at, finish], axis=1)
                ev_slot = jnp.concatenate([flight_slot, slot_cur], axis=1)
                ev_tag = jnp.concatenate(
                    [flight_titer, jnp.full((S, N), 1, jnp.int64) * t], axis=1
                )
                ev_vals = jnp.concatenate([flight_val, vals], axis=1)
            else:  # sag: fresh results only
                ev_valid, ev_time = fresh, finish
                ev_slot = slot_cur
                ev_tag = jnp.full((S, N), 1, jnp.int64) * t
                ev_vals = vals
            cache_state = _apply_cache_events_lb(
                spec, slot_width, overlap_idx, cache_state, ev_valid, ev_time,
                ev_slot, ev_tag, ev_vals,
            )
            sums, _, _, covered, _, _ = cache_state
            xi = jnp.maximum(covered / n, 1e-12)
            grad = sums / _bcast(xi, vdim) + kernels.regularizer_grad(V)
        elif spec.name == "coded":
            slot_cur = None
            g = kernels.sub_blocks(
                V,
                jnp.ones((S,), jnp.int64),
                jnp.full((S,), n, jnp.int64),
                n,
            ).astype(jnp.float64)
            grad = g + kernels.regularizer_grad(V)
        elif spec.name == "gd":
            slot_cur = None
            grad = _fresh_accumulate(kernels, fresh, finish, vals) + (
                kernels.regularizer_grad(V)
            )
        else:  # sgd: scale the partial sum by observed coverage
            slot_cur = None
            grad_acc = _fresh_accumulate(kernels, fresh, finish, vals)
            covered_f = jnp.sum(jnp.where(fresh, hi - lo + 1, 0), axis=1)
            xi = jnp.maximum(covered_f / n, 1e-12)
            grad = grad_acc / _bcast(xi, vdim) + kernels.regularizer_grad(V)

        # -- iterate update + suboptimality ---------------------------------
        V_new = kernels.project((V - spec.eta * grad).astype(V.dtype))
        subopt_t = jax.lax.cond(
            do_eval,
            lambda v: kernels.suboptimality(v),
            lambda v: jnp.full((S,), jnp.nan, dtype=jnp.float64),
            V_new,
        )

        # -- commit worker state for started tasks --------------------------
        sub_idx = jnp.where(started, cand_idx, sub_idx)
        if spec.process_full:
            sub_k = jnp.where(started, cand_k, sub_k)
        else:
            sub_k = jnp.where(started, cand_k % cand_p + 1, sub_k)
        pending_p = jnp.where(started, -1, pending_p)
        free_at = jnp.where(started, finish, free_at)
        draw_idx = draw_idx + started.astype(jnp.int64)
        if spec.uses_cache:
            flight_slot = jnp.where(started, slot_cur, flight_slot)
        flight_titer = jnp.where(started, t, flight_titer)
        flight_comp = jnp.where(started, comp_d, flight_comp)
        flight_comm = jnp.where(started, comm_d, flight_comm)
        flight_assigned = jnp.where(started, assign[:, None], flight_assigned)
        if spec.accepts_stale:
            flight_val = jnp.where(_bcast(started, vdim), vals, flight_val)

        # -- §6 background load balancer (Algorithm 1, jittable) ------------
        due = iter_end_new >= next_lb
        prof_new = (prof_t, prof_comm, prof_comp, prof_valid)

        def lb_block(args):
            pending_p, current_p, h_min, next_lb = args
            e_cm, v_cm, e_cp, v_cp, cnt = jlb.window_moments(
                prof_t, prof_comm, prof_comp, prof_valid, iter_end_new,
                jlb.PROFILER_WINDOW,
            )
            ready = jnp.all(cnt >= 1, axis=1)
            next_lb2 = jnp.where(due, iter_end_new + spec.lb_interval, next_lb)
            act = due & ready

            def run_opt(_):
                # the make_optimizer_inputs variance floors, verbatim
                p_new, h_min2, _, publish = jlb.lb_update(
                    current_p.astype(jnp.float64),
                    e_cm,
                    jnp.maximum(v_cm, 1e-18),
                    e_cp,
                    jnp.maximum(v_cp, 1e-18),
                    n_j_b,
                    h_min,
                    act,
                    ladder=spec.ladder,
                    w=spec.w_wait,
                    margin=spec.lb_margin,
                    key=lb_key,
                )
                changed = publish[:, None] & (p_new != current_p)
                return (
                    jnp.where(changed, p_new, pending_p),
                    jnp.where(publish[:, None], p_new, current_p),
                    h_min2,
                    publish,
                )

            def no_opt(_):
                return pending_p, current_p, h_min, jnp.zeros((S,), bool)

            pending2, current2, h_min2, publish = jax.lax.cond(
                jnp.any(act), run_opt, no_opt, None
            )
            return pending2, current2, h_min2, next_lb2, publish

        def no_lb(args):
            pending_p, current_p, h_min, next_lb = args
            return pending_p, current_p, h_min, next_lb, jnp.zeros((S,), bool)

        pending_p, current_p, h_min, next_lb, published = jax.lax.cond(
            jnp.any(due), lb_block, no_lb, (pending_p, current_p, h_min, next_lb)
        )

        carry = (
            V_new,
            free_at,
            iter_end_new,
            draw_idx,
            sub_idx,
            sub_k,
            pending_p,
            current_p,
            h_min,
            next_lb,
            flight_slot,
            flight_titer,
            flight_comp,
            flight_comm,
            flight_assigned,
            flight_val,
            cache_state,
            lat_matrix,
            prof_new,
        )
        return carry, (iter_end_new, subopt_t, fresh_cnt, published)

    val_dtype = jnp.dtype(kernels.value_dtype)
    cache0 = (
        jnp.zeros((S,) + vshape, dtype=jnp.float64),  # sums
        jnp.zeros((S, E) + vshape, dtype=jnp.float64),  # values
        jnp.full((S, E), -1, dtype=jnp.int64),  # iters
        jnp.zeros((S,), dtype=jnp.int64),  # covered
        jnp.zeros((S,), dtype=jnp.int64),  # rejected_stale
        jnp.zeros((S,), dtype=jnp.int64),  # evictions
    )
    sub_p0 = jnp.asarray(spec.sub_p, dtype=jnp.int64)
    idx0 = jnp.clip(
        jnp.sum(eff <= sub_p0[:, None], axis=1) - 1, 0, idx_cap
    )
    prof0 = (
        jnp.zeros((S, N, T)),
        jnp.zeros((S, N, T)),
        jnp.zeros((S, N, T)),
        jnp.zeros((S, N, T), dtype=bool),
    )
    carry0 = (
        V0,
        jnp.zeros((S, N)),  # free_at
        jnp.zeros((S,)),  # iter_end
        jnp.zeros((S, N), dtype=jnp.int64),  # draw_idx
        jnp.broadcast_to(idx0, (S, N)),  # sub_idx
        jnp.ones((S, N), dtype=jnp.int64),  # sub_k
        jnp.full((S, N), -1, dtype=jnp.int64),  # pending_p
        jnp.full((S, N), spec.lb_p0, dtype=jnp.int64),  # current_p (optimizer view)
        jnp.full((S,), jnp.nan),  # h_min
        jnp.full((S,), spec.lb_startup_delay),  # next_lb
        jnp.full((S, N), -1, dtype=jnp.int64),  # flight_slot
        jnp.full((S, N), -1, dtype=jnp.int64),  # flight_titer
        jnp.zeros((S, N)),  # flight_comp
        jnp.zeros((S, N)),  # flight_comm
        jnp.zeros((S, N)),  # flight_assigned
        jnp.zeros((S, N) + vshape, dtype=val_dtype),  # flight_val
        cache0,
        jnp.full((S, T, N), jnp.nan),  # lat_matrix
        prof0,
    )
    xs = (jnp.arange(T, dtype=jnp.int64), eval_mask)
    carry, ys = jax.lax.scan(body, carry0, xs)
    times, subopt, fresh_counts, published = ys
    cache_state = carry[16]
    return (
        times.T,
        subopt.T,
        fresh_counts.T,
        carry[17],  # lat_matrix
        cache_state[4],  # rejected_stale
        cache_state[5],  # evictions
        published.T,  # [S, T] publication schedule
    )


def _scan_jit_for(kernels: FusedKernels, *, lb: bool = False):
    """Per-kernels jitted driver.

    The jit cache is owned by the kernels object rather than a module-level
    callable: a module-level ``jax.jit`` would keep every problem's data
    matrices (captured by the static ``kernels`` argument) alive for the
    process lifetime; this way the compiled executables are garbage
    collected with the problem.
    """
    attr = "_scan_driver_jit_lb" if lb else "_scan_driver_jit"
    jitted = getattr(kernels, attr, None)
    if jitted is None:
        jitted = jax.jit(_run_scan_lb if lb else _run_scan, static_argnums=(0, 1))
        setattr(kernels, attr, jitted)
    return jitted


def scan_unsupported_reason(
    problem: FiniteSumProblem, config: MethodConfig, num_workers: int
) -> Optional[str]:
    """Why the fused scan cannot run this config (None = it can).

    The only remaining limitation is a §6 slot universe larger than
    :data:`LB_MAX_SLOTS`: the pre-allocated ladder universe would need
    more per-slot value buffers than the memory budget allows.
    ``engine="auto"`` routes exactly this case to the host engine."""
    if not (config.load_balance and config.uses_cache):
        return None
    n = problem.num_samples
    N = num_workers
    n_local = np.array(
        [p_stop(n, N, i + 1) - p_start(n, N, i + 1) + 1 for i in range(N)]
    )
    ladder = lb_ladder_for(config, n_local)
    upper = int(sum(min(r, int(n_local.max())) for r in ladder)) * N
    if upper > LB_MAX_SLOTS:
        return (
            f"§6 ladder slot universe needs up to {upper} slots "
            f"(> LB_MAX_SLOTS={LB_MAX_SLOTS}): the fused scan pre-allocates "
            "per-slot cache value buffers and cannot hold this config; "
            "use engine='host'"
        )
    return None


def run_convergence_scan(
    problem: FiniteSumProblem,
    traces: FleetTraces,
    config: MethodConfig,
    num_iterations: int,
    *,
    cost_scale: float = 1.0,
    eval_every: int = 1,
    seed: int = 0,
):
    """Train ``config`` on every scenario of ``traces`` in one XLA dispatch.

    Bit-exact against the host engine and the scalar simulator on the same
    traces (see module docstring), §6 load-balanced configs included.
    Raises ``ValueError`` for the one unsupported case
    (:func:`scan_unsupported_reason`)."""
    from repro.experiments.convergence import ConvergenceBatchResult

    reason = scan_unsupported_reason(problem, config, traces.num_workers)
    if reason is not None:
        raise ValueError(reason)
    S = traces.num_scenarios
    T = num_iterations
    if T > traces.horizon:
        raise ValueError(
            f"traces hold {traces.horizon} draws/worker but {T} iterations requested"
        )
    lb = bool(config.load_balance)
    universe = None
    if lb and config.uses_cache:
        n = problem.num_samples
        N = traces.num_workers
        base_start = [p_start(n, N, i + 1) for i in range(N)]
        base_stop = [p_stop(n, N, i + 1) for i in range(N)]
        n_local = np.asarray(base_stop) - np.asarray(base_start) + 1
        universe = build_slot_universe(
            base_start, base_stop, lb_ladder_for(config, n_local)
        )
    spec = _static_spec(
        problem, config, traces.num_workers, T, cost_scale, universe=universe
    )
    kernels = problem.fused_kernels()
    V0 = np.repeat(problem.init(seed)[None], S, axis=0)
    eval_mask = np.zeros(T, dtype=bool)
    eval_mask[::eval_every] = True
    eval_mask[T - 1] = True
    with enable_x64():
        empty = jnp.zeros((S, traces.num_workers, 0))
        has_b = traces.has_bursts
        trace_args = (
            jnp.asarray(traces.comm),
            jnp.asarray(traces.comp_unit),
            jnp.asarray(traces.slowdown),
            jnp.asarray(traces.burst_start) if has_b else empty,
            jnp.asarray(traces.burst_end) if has_b else empty,
            jnp.asarray(traces.burst_factor) if has_b else empty,
            jnp.asarray(V0),
            jnp.asarray(eval_mask),
        )
        if lb:
            if universe is not None:
                slot_table = jnp.asarray(universe.slot_table)
                slot_width = jnp.asarray(universe.widths)
                overlap_idx = jnp.asarray(universe.overlap_idx)
            else:  # non-cache methods: no slots, keep shapes minimal
                N = traces.num_workers
                L = max(len(spec.ladder), 1)
                pmax = max(spec.ladder) if spec.ladder else 1
                slot_table = jnp.zeros((N, L, pmax), dtype=jnp.int64)
                slot_width = jnp.zeros((1,), dtype=jnp.int64)
                overlap_idx = jnp.full((1, 1), -1, dtype=jnp.int64)
            times, subopt, fresh, lat, rejected, evictions, published = (
                _scan_jit_for(kernels, lb=True)(
                    kernels,
                    spec,
                    slot_table,
                    slot_width,
                    overlap_idx,
                    *trace_args,
                    jax.random.PRNGKey(seed),
                )
            )
            published = np.asarray(published)
            times_np = np.asarray(times)
            repartition_events = [
                [float(times_np[s, t]) for t in np.flatnonzero(published[s])]
                for s in range(S)
            ]
            evictions_np = np.asarray(evictions, dtype=np.int64)
        else:
            times, subopt, fresh, lat, rejected = _scan_jit_for(kernels)(
                kernels, spec, *trace_args
            )
            times_np = np.asarray(times)
            repartition_events = [[] for _ in range(S)]
            evictions_np = np.zeros(S, dtype=np.int64)
    return ConvergenceBatchResult(
        times=times_np,
        suboptimality=np.asarray(subopt),
        fresh_counts=np.asarray(fresh, dtype=np.int64),
        per_worker_latency=np.asarray(lat),
        repartition_events=repartition_events,
        evictions=evictions_np,
        rejected_stale=np.asarray(rejected, dtype=np.int64),
    )
