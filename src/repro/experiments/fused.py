"""Fused ``jax.lax.scan`` convergence engine.

The host engine (:func:`repro.experiments.convergence.run_convergence_batch`
with ``EngineConfig(kind="host")``) runs one Python iteration per training
iteration and dispatches batched kernels from it.  This module compiles the
*entire* iteration body — §4.2 event algebra, §3 trace replay, block
subgradients, the §5 cache update as masked scatters, the iterate update,
and the suboptimality evaluation — into one jittable function and scans it
over the whole run: a single XLA dispatch for a complete ``[S]``-scenario
training sweep, ready for accelerators.

Bit-exactness contract (pinned by ``tests/test_fused.py``): for every
scenario, the scan produces the same bits as the host engine and the scalar
:class:`~repro.cluster.simulator.TrainingSimulator` replaying the same
trace.  Three ingredients make that possible:

* every float expression is shared: the problems'
  :class:`~repro.core.problems.FusedKernels` are called from all three
  engines, and the event algebra mirrors
  :func:`~repro.cluster.simulator.task_finish_time` /
  :func:`~repro.cluster.simulator.margin_deadline` term by term;
* block subgradients are evaluated at the static
  :func:`~repro.core.problems.width_bucket` ladder — one kernel call per
  possible bucket, rows selected by their actual width — so a given
  (iterate, interval) is always computed at the same static shape;
* the §5 cache applies events rank by rank in per-scenario event-time
  order (an inner ``fori_loop``), preserving the host cache's float
  accumulation order bit for bit.

There is ONE per-iteration scan body (:func:`_run_scan`), parameterized by
the static :class:`_StaticSpec` along two axes:

* the **(lo, hi, slot) source** — the fixed subpartition grid for plain
  configs, or the §6 candidate after the Algorithm-2 alignment walk for
  load-balanced ones (which also carry the profiler buffers, ladder
  indices, ``h_min``/schedule state, and run the jittable Algorithm 1 of
  :mod:`repro.lb.jit_optimizer` inside the scan);
* the **cache layout** (``spec.cache_mode``):

  - ``"grid"`` — no §6: the interval set is exactly the initial
    subpartition grid, state is dense ``[S, E]``, an active exact-match
    slot is the only possible overlap (the SAG fast path).
  - ``"universe"`` — §6 with the pre-allocated ladder universe
    (:func:`repro.core.gradient_cache.build_slot_universe`): dense
    ``[S, E]`` state over every interval the p-ladder can reach, with
    the statically tabulated overlap lists driving the scalar cache's
    eviction walk.
  - ``"tiled"`` — §6 universes above the slot budget: per-worker
    *active-entry* tables of capacity
    :func:`repro.core.gradient_cache.active_slot_capacity` (the greedy
    interval-scheduling bound on simultaneously active disjoint
    intervals).  Overlaps are computed against the small active set at
    runtime from the universe's start/stop tables, so memory drops from
    ``E ≈ N * sum(ladder)`` to ``N * A`` value buffers while keeping the
    scalar walk's float order.  This is how arbitrarily large §6 configs
    stay on the scan path instead of tripping :data:`LB_MAX_SLOTS`.

Multi-device: :func:`run_convergence_scan` shards the scenario axis over a
1-D ``"data"`` mesh (:func:`repro.launch.mesh.make_scenario_mesh`) with
``shard_map`` when the :class:`~repro.experiments.engine.EngineConfig`
names devices.  Every per-scenario quantity is row-independent; the only
cross-scenario values are dynamic trip counts and ``lax.cond`` decisions
whose skipped work is an exact no-op, so per-device shards produce the
same bits as the single-device scan (pinned by ``tests/test_sharded.py``).
Uneven ``S % num_devices`` batches are edge-padded and sliced back.

Capability: :func:`scan_capability` reports whether a config runs (and
with which cache layout) as a structured
:class:`~repro.experiments.engine.EngineCapability` with stable reason
codes; the one genuinely unsupported case — an *active-entry* footprint
above the slot budget — raises
:class:`~repro.experiments.engine.EngineCapabilityError`.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import PartitionSpec as P

from repro.cluster.simulator import (
    MethodConfig,
    effective_w,
    lb_ladder_for,
    margin_deadline,
    task_finish_time,
)
from repro.core.gradient_cache import (
    SlotUniverse,
    active_slot_capacity,
    build_slot_universe,
)
from repro.core.problems import FiniteSumProblem, FusedKernels, width_bucket
from repro.experiments.engine import (
    CAP_ACTIVE_SET,
    CAP_OK,
    CAP_PALLAS_DTYPE,
    CAP_PALLAS_UNAVAILABLE,
    CAP_TILED,
    EngineCapability,
    EngineCapabilityError,
    EngineConfig,
    as_engine_config,
)
from repro.kernels.cache_events import grid_cache_update
from repro.latency.model import FleetTraces, comp_latency_expr
from repro.lb import jit_optimizer as jlb
from repro.lb.partitioner import p_start, p_stop

#: default budget on densely resident §6 slot-universe entries (per-slot
#: float64 value buffers are the fused engine's memory trade-off).
#: Universes above it run with the tiled active-slot cache; only configs
#: whose *active-entry* footprint also exceeds the budget are unsupported.
#: Override per run via ``EngineConfig(slot_budget=...)``.
LB_MAX_SLOTS = 250_000


def guarded_comp_latency(comp_unit_draw, load, slowdown, factor):
    """The §3 latency product with its FMA-contraction seam (tracelint TL001).

    Finalizes the §3 product before the event algebra consumes it: the
    LLVM backend otherwise contracts the last multiply into the
    ``task_finish_time`` add as an FMA (skipping the intermediate
    rounding the host engine's numpy performs), which changes the final
    ULP whenever slowdown/burst factors are not exactly 1.0.
    ``max(x, 0)`` is exact for the positive latencies and is a pattern
    the contraction cannot see through (``lax.optimization_barrier`` is
    erased before LLVM and does NOT prevent this).

    Kept as a module-level function so the tracelint TL001 probe
    (``repro.analysis.lint``) exercises the exact production expression:
    it compiles this chain with and without the seam and diffs against
    an op-by-op evaluation.
    """
    return jnp.maximum(
        comp_latency_expr(comp_unit_draw, load, slowdown, factor), 0.0
    )


@dataclasses.dataclass(frozen=True)
class _StaticSpec:
    """Hashable static configuration of one fused-scan compilation."""

    name: str
    w_wait: int
    eta: float
    margin: float  # effective margin (0.0 when unused)
    comp_scale: float
    process_full: bool
    uses_cache: bool
    accepts_stale: bool
    num_iterations: int
    base_start: tuple[int, ...]
    base_stop: tuple[int, ...]
    sub_p: tuple[int, ...]  # initial (and, without §6, permanent) p_i
    buckets: tuple[int, ...]  # static width_bucket ladder, ascending
    slot_offsets: tuple[int, ...]  # per-worker first slot (grid cache)
    num_slots: int
    cache_mode: str = "none"  # "none" | "grid" | "universe" | "tiled"
    active_cap: int = 0  # per-worker entry capacity of the tiled cache
    # §6 load balancing (empty/zero for non-LB specs)
    load_balance: bool = False
    ladder: tuple[int, ...] = ()  # the p-ladder Algorithm 1 climbs
    lb_interval: float = 0.0
    lb_startup_delay: float = 0.0
    lb_margin: float = 0.0  # optimizer-input margin (= config.margin)
    lb_p0: int = 0  # the optimizer-facing initial p (config.subpartitions)
    # elastic-fleet churn (traces carry a ChurnSchedule): time-varying
    # slowdown rows + a per-iteration liveness mask.  False compiles the
    # exact pre-churn body — the churn operands are then unused.
    has_churn: bool = False
    # hot-path kernel backend: "xla" (jnp forms) or "pallas" (the
    # repro.kernels twins).  kernel_interpret is resolved eagerly by
    # prepare_scan_inputs — never read jax.default_backend() at trace
    # time (a stale-cache hazard; see kernels/ops.py) — and is part of
    # this hashable spec, hence of every jit key.
    kernel_backend: str = "xla"
    kernel_interpret: bool = True


def _possible_widths(n_local: int, p: int, full: bool) -> set:
    if full:
        return {n_local}
    return {k * n_local // p - (k - 1) * n_local // p for k in range(1, p + 1)}


def _static_spec(
    problem: FiniteSumProblem,
    config: MethodConfig,
    num_workers: int,
    num_iterations: int,
    cost_scale: float,
    universe: SlotUniverse | None = None,
    tiled: bool = False,
    active_cap: int = 0,
    has_churn: bool = False,
    kernel_backend: str = "xla",
    kernel_interpret: bool = True,
) -> _StaticSpec:
    n = problem.num_samples
    N = num_workers
    cfg = config
    base_start = tuple(p_start(n, N, i + 1) for i in range(N))
    base_stop = tuple(p_stop(n, N, i + 1) for i in range(N))
    n_local = [b - a + 1 for a, b in zip(base_start, base_stop)]
    process_full = cfg.name in ("gd", "coded")
    sub_p = tuple(min(cfg.subpartitions, nl) for nl in n_local)
    widths = set()
    for nl, p in zip(n_local, sub_p):
        widths |= _possible_widths(nl, p, process_full)
    ladder: tuple[int, ...] = ()
    if cfg.load_balance:
        ladder = lb_ladder_for(cfg, np.asarray(n_local))
        if not process_full:
            # any ladder interval's width can appear once repartitions start
            for a, b in zip(base_start, base_stop):
                nl = b - a + 1
                for raw in ladder:
                    widths |= _possible_widths(nl, min(raw, nl), False)
    buckets = tuple(sorted({width_bucket(m, n) for m in widths}))
    if cfg.uses_cache:
        if cfg.load_balance:
            assert universe is not None
            slot_offsets = (0,) * N  # slots come from the universe table
            num_slots = universe.num_slots
            cache_mode = "tiled" if tiled else "universe"
        else:
            offsets = np.concatenate([[0], np.cumsum(sub_p)])
            slot_offsets = tuple(int(o) for o in offsets[:-1])
            num_slots = int(offsets[-1])
            cache_mode = "grid"
    else:
        slot_offsets = (0,) * N
        num_slots = 0
        cache_mode = "none"
    margin_eff = cfg.margin if (cfg.uses_margin and cfg.margin > 0) else 0.0
    return _StaticSpec(
        name=cfg.name,
        w_wait=effective_w(cfg, N),
        eta=float(cfg.eta),
        margin=float(margin_eff),
        comp_scale=float(
            cost_scale * (1.0 / cfg.code_rate if cfg.name == "coded" else 1.0)
        ),
        process_full=process_full,
        uses_cache=cfg.uses_cache,
        accepts_stale=cfg.accepts_stale,
        num_iterations=num_iterations,
        base_start=base_start,
        base_stop=base_stop,
        sub_p=sub_p,
        buckets=buckets,
        slot_offsets=slot_offsets,
        num_slots=num_slots,
        cache_mode=cache_mode,
        active_cap=int(active_cap),
        load_balance=bool(cfg.load_balance),
        ladder=ladder,
        lb_interval=float(cfg.lb_interval),
        lb_startup_delay=float(cfg.lb_startup_delay),
        lb_margin=float(cfg.margin),
        lb_p0=int(cfg.subpartitions),
        has_churn=bool(has_churn),
        kernel_backend=kernel_backend,
        kernel_interpret=bool(kernel_interpret),
    )


def _bcast(mask, value_ndim: int):
    """Reshape a mask so it broadcasts over trailing value dimensions."""
    return mask.reshape(mask.shape + (1,) * value_ndim)


def _sub_blocks_for(kernels: FusedKernels, spec: _StaticSpec):
    """The §3 block-subgradient callable for the spec's kernel backend.

    ``"pallas"`` binds the problem's Pallas twin with the spec's static
    interpret flag (capability-checked by :func:`kernel_backend_capability`
    before any spec with it is built); both return the same
    ``(Vb, starts, widths, pad_width) -> [G, ...]`` signature.
    """
    if spec.kernel_backend == "pallas":
        pallas_fn = kernels.sub_blocks_pallas
        assert pallas_fn is not None, "capability check admitted a None twin"
        return functools.partial(pallas_fn, interpret=spec.kernel_interpret)
    return kernels.sub_blocks


def _subgradients(kernels: FusedKernels, spec: _StaticSpec, V, lo, hi):
    """[S, N, ...] block subgradients via the static width-bucket ladder.

    One kernel dispatch per possible bucket (all S*N tasks each time), rows
    selected by their actual width — bit-identical to the host wrapper,
    which routes each row to the same bucket.
    """
    S, N = lo.shape
    n = kernels.num_samples
    widths = hi - lo + 1
    vdim = len(kernels.value_shape)
    Vb = jnp.broadcast_to(
        V[:, None], (S, N) + kernels.value_shape
    ).reshape((S * N,) + kernels.value_shape)
    lo_f = lo.reshape(-1)
    w_f = widths.reshape(-1)
    out = None
    prev = 0
    sub_blocks = _sub_blocks_for(kernels, spec)
    for b in spec.buckets:
        block = sub_blocks(Vb, lo_f, w_f, b).reshape(
            (S, N) + kernels.value_shape
        )
        if b == n:
            sel = widths == n
        else:
            sel = (widths != n) & (widths <= b) & (widths > prev)
        out = block if out is None else jnp.where(_bcast(sel, vdim), block, out)
        prev = b
    return out


def _apply_cache_events(
    spec: _StaticSpec,
    slot_width,
    cache_state,
    ev_valid,
    ev_time,
    ev_slot,
    ev_tag,
    ev_vals,
):
    """The §5 update for one iteration's events, as masked scatters.

    ``ev_*`` are ``[S, E_ev]`` tables (stale then fresh halves for DSAG,
    fresh only for SAG).  Events are ranked per scenario by a stable sort
    on event time (+inf where invalid) and applied rank by rank: one rank
    holds at most one event per scenario, so its updates are a single
    vectorized masked scatter, and the per-scenario float accumulation
    order of the running sums matches the host cache's time-ordered
    inserts bit for bit.  With a fixed slot grid an active exact-match
    slot is the only possible overlap, so the scalar cache's eviction walk
    reduces to staleness dominance + in-place update (the SAG fast path).
    """
    st = cache_state
    S, E_ev = ev_time.shape
    vdim = st["values"].ndim - 2
    order = jnp.argsort(jnp.where(ev_valid, ev_time, jnp.inf), axis=1, stable=True)
    s_idx = jnp.arange(S)
    flat_vals = ev_vals.reshape((S * E_ev,) + ev_vals.shape[2:])

    def rank_body(j, state):
        sums, values, iters, covered, rejected = state
        e = order[:, j]
        flat = s_idx * E_ev + e
        valid = ev_valid.reshape(-1)[flat]
        slot = jnp.clip(ev_slot.reshape(-1)[flat], 0, spec.num_slots - 1)
        tag = ev_tag.reshape(-1)[flat]
        v64 = flat_vals[flat].astype(jnp.float64)
        cur_it = iters[s_idx, slot]
        active = cur_it >= 0
        dom = active & (cur_it >= tag)
        acc = valid & ~dom
        rej = valid & dom
        old = values[s_idx, slot]
        delta = v64 - jnp.where(_bcast(active, vdim), old, 0.0)
        sums = jnp.where(_bcast(acc, vdim), sums + delta, sums)
        values = values.at[s_idx, slot].set(jnp.where(_bcast(acc, vdim), v64, old))
        iters = iters.at[s_idx, slot].set(jnp.where(acc, tag, cur_it))
        covered = covered + jnp.where(acc & ~active, slot_width[slot], 0)
        rejected = rejected + rej.astype(rejected.dtype)
        return sums, values, iters, covered, rejected

    sums, values, iters, covered, rejected = jax.lax.fori_loop(
        0,
        E_ev,
        rank_body,
        (st["sums"], st["values"], st["iters"], st["covered"], st["rejected"]),
    )
    return dict(
        sums=sums, values=values, iters=iters, covered=covered, rejected=rejected
    )


def _apply_cache_events_pallas(
    spec: _StaticSpec,
    slot_width,
    cache_state,
    ev_valid,
    ev_time,
    ev_slot,
    ev_tag,
    ev_vals,
):
    """The §5 grid-cache update through the fused Pallas kernel.

    Ranking and pre-gathering stay in XLA (the stable argsort +
    ``take_along_axis`` moves :func:`_apply_cache_events` performs inside
    its loop, hoisted out — pure data movement, bit-identical operands);
    the rank walk itself runs as ``kernels/cache_events.grid_cache_update``,
    one program per scenario, fusing the value-table scatter and the
    running-sum update into a single pass.  Value dimensions are flattened
    to one feature axis for the kernel and reshaped back (a bitwise no-op).
    """
    st = cache_state
    S, E_ev = ev_time.shape
    E = spec.num_slots
    vdim = st["values"].ndim - 2
    vshape = st["values"].shape[2:]
    F = int(np.prod(vshape)) if vdim else 1
    order = jnp.argsort(jnp.where(ev_valid, ev_time, jnp.inf), axis=1, stable=True)
    valid_r = jnp.take_along_axis(ev_valid, order, axis=1)
    slot_r = jnp.clip(jnp.take_along_axis(ev_slot, order, axis=1), 0, E - 1)
    tag_r = jnp.take_along_axis(ev_tag, order, axis=1)
    vals_r = jnp.take_along_axis(
        ev_vals, order.reshape(order.shape + (1,) * vdim), axis=1
    ).astype(jnp.float64)
    sums, values, iters, covered, rejected = grid_cache_update(
        valid_r,
        slot_r,
        tag_r,
        vals_r.reshape(S, E_ev, F),
        st["sums"].reshape(S, F),
        st["values"].reshape(S, E, F),
        st["iters"],
        st["covered"],
        st["rejected"],
        slot_width,
        interpret=spec.kernel_interpret,
    )
    return dict(
        sums=sums.reshape(st["sums"].shape),
        values=values.reshape(st["values"].shape),
        iters=iters,
        covered=covered,
        rejected=rejected,
    )


def _apply_cache_events_lb(
    spec: _StaticSpec,
    slot_width,
    overlap_idx,
    cache_state,
    ev_valid,
    ev_time,
    ev_slot,
    ev_tag,
    ev_vals,
):
    """The full §5 update over the pre-allocated §6 slot universe.

    Like :func:`_apply_cache_events`, but once repartitions are possible an
    event's interval can overlap *other* active slots.  ``overlap_idx[e]``
    statically lists the same-worker slots intersecting slot ``e``
    (sorted by interval start, -1 padded); per event rank the update is
    the scalar cache's walk verbatim: staleness dominance over all active
    overlaps, sequential eviction subtraction in start order (a masked
    ``fori_loop``, preserving the scalar float grouping), then the insert
    — the SAG-style in-place delta when the event's own slot is active
    (disjointness makes it the only possible overlap), a plain add
    otherwise.  Also maintains the eviction counter the host caches track.

    Performance shape (load-bearing — the first implementation was ~100x
    slower than the host engine): inside the rank loop the big ``[S, E,
    ...]`` value table is **write-only** (tracelint TL002 machine-checks
    this).  Reading it there (for eviction subtraction or the in-place
    delta) defeats XLA's in-place aliasing of the loop carry under
    ``lax.scan`` and copies the whole table once per event rank (~minutes
    per 100-worker run); ``lax.cond`` is no escape (~9 ms per rank on the
    CPU thunk runtime — the capture pattern tracelint TL005 flags).  Instead, the live value
    of any slot is *reconstructed* from small read-only buffers: ``wmap``
    maps each slot to the rank of its last accepted write this iteration
    (so the value is a row of the ranked event table), and slots not yet
    written this iteration read from ``values0``, the frozen loop-entry
    buffer — one table copy per iteration instead of one per rank.  Both
    sources hold bit-identical float64 values to what the table itself
    would return.  The rank loop and the eviction sub-loop run to
    *dynamic* trip counts (deepest valid rank / last evicted overlap), so
    empty ranks and the no-eviction common case cost nothing.
    """
    st = cache_state
    S, E_ev = ev_time.shape
    E = spec.num_slots
    Omax = overlap_idx.shape[1]
    vdim = st["values"].ndim - 2
    order = jnp.argsort(jnp.where(ev_valid, ev_time, jnp.inf), axis=1, stable=True)
    s_idx = jnp.arange(S)
    # event tables in rank order: one gather each, outside the rank loop
    valid_r = jnp.take_along_axis(ev_valid, order, axis=1)
    slot_r = jnp.clip(jnp.take_along_axis(ev_slot, order, axis=1), 0, E - 1)
    tag_r = jnp.take_along_axis(ev_tag, order, axis=1)
    vals_r = jnp.take_along_axis(
        ev_vals, order.reshape(order.shape + (1,) * vdim), axis=1
    ).astype(jnp.float64)
    values0 = st["values"]  # frozen pre-iteration table (read-only below)
    wmap0 = jnp.full((S, E), -1, jnp.int32)
    # ranks beyond every scenario's valid events are exact no-ops: skip
    n_ranks = jnp.max(jnp.sum(valid_r, axis=1))

    def rank_body(j, state):
        sums, values, iters, covered, rejected, evictions, wmap = state
        valid = valid_r[:, j]
        slot = slot_r[:, j]
        tag = tag_r[:, j]
        v64 = vals_r[:, j]
        ov = overlap_idx[slot]  # [S, Omax]
        ov_safe = jnp.clip(ov, 0, E - 1)
        ov_iters = iters[s_idx[:, None], ov_safe]
        ov_active = (ov >= 0) & (ov_iters >= 0)
        own_it = iters[s_idx, slot]
        own_active = own_it >= 0
        # staleness dominance over every active overlapping entry
        dom = (own_active & (own_it >= tag)) | jnp.any(
            ov_active & (ov_iters >= tag[:, None]), axis=1
        )
        acc = valid & ~dom
        rej = valid & dom
        evict = ov_active & acc[:, None]
        # live values of the overlap candidates, reconstructed (see above)
        widx = wmap[s_idx[:, None], ov_safe]  # [S, Omax]
        v_new = vals_r[s_idx[:, None], jnp.clip(widx, 0, E_ev - 1)]
        v_old = values0[s_idx[:, None], ov_safe]
        v_sub = jnp.where(_bcast(widx >= 0, vdim), v_new, v_old)

        def sub_body(o, acc_sm):
            return jnp.where(
                _bcast(evict[:, o], vdim), acc_sm - v_sub[:, o], acc_sm
            )

        # masked sequential subtraction in start order (overlap lists are
        # pre-sorted); trip count = last evicted overlap, usually 0
        n_sub = jnp.max(jnp.where(evict, jnp.arange(Omax) + 1, 0))
        sums = jax.lax.fori_loop(0, n_sub, sub_body, sums)
        # deactivate evicted slots via an O(S*Omax) scatter-min: evicted
        # slots get -1, padding writes a huge sentinel (a no-op under
        # min), so duplicate indices from the -1 padding clip cannot
        # corrupt real slots
        upd = jnp.where(evict, jnp.int64(-1), jnp.iinfo(jnp.int64).max)
        iters = iters.at[s_idx[:, None], ov_safe].min(upd)
        removed = jnp.sum(jnp.where(evict, slot_width[ov_safe], 0), axis=1)
        evictions = evictions + jnp.sum(evict, axis=1)
        # insert: exact-active match -> in-place delta (degrades to SAG);
        # otherwise v - 0.0 == v, the scalar slow path's plain add.  The
        # old value is reconstructed, never read from the live table.
        own_wi = wmap[s_idx, slot]
        own_live = jnp.where(
            _bcast(own_wi >= 0, vdim),
            vals_r[s_idx, jnp.clip(own_wi, 0, E_ev - 1)],
            values0[s_idx, slot],
        )
        delta = v64 - jnp.where(_bcast(own_active, vdim), own_live, 0.0)
        sums = jnp.where(_bcast(acc, vdim), sums + delta, sums)
        values = values.at[s_idx, slot].set(
            jnp.where(_bcast(acc, vdim), v64, own_live)
        )
        # the event's own slot is never in its own overlap list, so the
        # scatter-min above cannot have touched own_it
        iters = iters.at[s_idx, slot].set(jnp.where(acc, tag, own_it))
        wmap = wmap.at[s_idx, slot].set(jnp.where(acc, jnp.int32(j), own_wi))
        covered = covered + jnp.where(
            acc, jnp.where(own_active, 0, slot_width[slot]) - removed, 0
        )
        rejected = rejected + rej.astype(rejected.dtype)
        return sums, values, iters, covered, rejected, evictions, wmap

    out = jax.lax.fori_loop(
        0,
        n_ranks,
        rank_body,
        (
            st["sums"],
            st["values"],
            st["iters"],
            st["covered"],
            st["rejected"],
            st["evictions"],
            wmap0,
        ),
    )
    return dict(
        sums=out[0],
        values=out[1],
        iters=out[2],
        covered=out[3],
        rejected=out[4],
        evictions=out[5],
    )


def _apply_cache_events_tiled(
    spec: _StaticSpec,
    slot_width,
    slot_starts,
    slot_stops,
    ev_worker,
    cache_state,
    ev_valid,
    ev_time,
    ev_slot,
    ev_tag,
    ev_vals,
):
    """The §5 update over per-worker *active-entry* tables (tiled §6 cache).

    Same scalar-cache walk as :func:`_apply_cache_events_lb`, but instead
    of dense ``[S, E]`` state over the whole ladder universe, each worker
    owns ``A = spec.active_cap`` entry rows (``slots``/``iters``/values),
    where ``A`` is the greedy interval-scheduling bound on simultaneously
    active disjoint intervals (:func:`~repro.core.gradient_cache.
    active_slot_capacity`).  Overlap candidates are the event worker's own
    ``A`` entries, tested at runtime against the universe's start/stop
    tables — within-worker overlap is the only kind the partitioner can
    produce, so the candidate set is complete.  Eviction subtraction is
    sorted by interval start to reproduce the scalar walk's float order,
    and the insert lands in the exact active entry (in-place delta) or the
    first free row (a free row always exists: active set ∪ new interval is
    disjoint, hence within ``A``).

    The same write-only value-table discipline as the dense path applies:
    inside the rank loop ``values`` (``[S, N, A, ...]``) is only ever
    scattered to; live entry values are reconstructed from the ranked
    event table via ``wmap`` or from ``values0``, the frozen loop-entry
    copy.
    """
    st = cache_state
    S, E_ev = ev_time.shape
    E = spec.num_slots
    values = st["values"]  # [S, N, A, *vshape]
    N, A = values.shape[1], values.shape[2]
    vdim = values.ndim - 3
    order = jnp.argsort(jnp.where(ev_valid, ev_time, jnp.inf), axis=1, stable=True)
    s_idx = jnp.arange(S)
    a_idx = jnp.arange(A)
    valid_r = jnp.take_along_axis(ev_valid, order, axis=1)
    slot_r = jnp.clip(jnp.take_along_axis(ev_slot, order, axis=1), 0, E - 1)
    tag_r = jnp.take_along_axis(ev_tag, order, axis=1)
    vals_r = jnp.take_along_axis(
        ev_vals, order.reshape(order.shape + (1,) * vdim), axis=1
    ).astype(jnp.float64)
    worker_r = jnp.take_along_axis(
        jnp.broadcast_to(ev_worker[None, :], (S, E_ev)), order, axis=1
    )
    values0 = values  # frozen pre-iteration table (read-only below)
    wmap0 = jnp.full((S, N, A), -1, jnp.int32)
    n_ranks = jnp.max(jnp.sum(valid_r, axis=1))

    def rank_body(j, state):
        sums, values, iters, slots, covered, rejected, evictions, wmap = state
        valid = valid_r[:, j]
        slot = slot_r[:, j]
        tag = tag_r[:, j]
        v64 = vals_r[:, j]
        w_e = worker_r[:, j]
        # the event worker's entry rows: [S, A] gathers of small tables
        es = slots[s_idx, w_e]
        ei = iters[s_idx, w_e]
        wm = wmap[s_idx, w_e]
        active = ei >= 0
        es_safe = jnp.clip(es, 0, E - 1)
        e_lo = slot_starts[es_safe]
        e_hi = slot_stops[es_safe]
        ev_lo = slot_starts[slot][:, None]
        ev_hi = slot_stops[slot][:, None]
        ovl = active & (e_lo <= ev_hi) & (ev_lo <= e_hi)
        exact = ovl & (es == slot[:, None])
        dom = jnp.any(ovl & (ei >= tag[:, None]), axis=1)
        acc = valid & ~dom
        rej = valid & dom
        evict = ovl & ~exact & acc[:, None]
        # live entry values, reconstructed (write-only table discipline)
        v_new = vals_r[s_idx[:, None], jnp.clip(wm, 0, E_ev - 1)]
        v_old = values0[s_idx[:, None], w_e[:, None], a_idx[None, :]]
        v_live = jnp.where(_bcast(wm >= 0, vdim), v_new, v_old)  # [S, A, ...]

        def sub_body(o, acc_sm):
            eidx = ord_e[:, o]
            m = evict[s_idx, eidx]
            return jnp.where(
                _bcast(m, vdim), acc_sm - v_live[s_idx, eidx], acc_sm
            )

        # eviction subtraction in interval-start order (the scalar walk's
        # order; active disjoint intervals have distinct starts, so the
        # order is unique); trip count = number evicted, usually 0
        big = jnp.iinfo(jnp.int64).max
        ord_e = jnp.argsort(jnp.where(evict, e_lo, big), axis=1, stable=True)
        n_sub = jnp.max(jnp.sum(evict, axis=1))
        sums = jax.lax.fori_loop(0, n_sub, sub_body, sums)
        ei = jnp.where(evict, jnp.int64(-1), ei)
        removed = jnp.sum(jnp.where(evict, slot_width[es_safe], 0), axis=1)
        evictions = evictions + jnp.sum(evict, axis=1)
        # insert target: the exact active entry (in-place delta; by
        # disjointness it is then the only overlap and nothing was
        # evicted), else the first free row post-eviction
        exact_any = jnp.any(exact, axis=1)
        tgt = jnp.where(
            exact_any, jnp.argmax(exact, axis=1), jnp.argmax(ei < 0, axis=1)
        )
        own_live = v_live[s_idx, tgt]
        delta = v64 - jnp.where(_bcast(exact_any, vdim), own_live, 0.0)
        sums = jnp.where(_bcast(acc, vdim), sums + delta, sums)
        values = values.at[s_idx, w_e, tgt].set(
            jnp.where(_bcast(acc, vdim), v64, own_live)
        )
        ei = ei.at[s_idx, tgt].set(jnp.where(acc, tag, ei[s_idx, tgt]))
        es = es.at[s_idx, tgt].set(jnp.where(acc, slot, es[s_idx, tgt]))
        wm = wm.at[s_idx, tgt].set(jnp.where(acc, jnp.int32(j), wm[s_idx, tgt]))
        iters = iters.at[s_idx, w_e].set(ei)
        slots = slots.at[s_idx, w_e].set(es)
        wmap = wmap.at[s_idx, w_e].set(wm)
        covered = covered + jnp.where(
            acc, jnp.where(exact_any, 0, slot_width[slot]) - removed, 0
        )
        rejected = rejected + rej.astype(rejected.dtype)
        return sums, values, iters, slots, covered, rejected, evictions, wmap

    out = jax.lax.fori_loop(
        0,
        n_ranks,
        rank_body,
        (
            st["sums"],
            values,
            st["iters"],
            st["slots"],
            st["covered"],
            st["rejected"],
            st["evictions"],
            wmap0,
        ),
    )
    return dict(
        sums=out[0],
        values=out[1],
        iters=out[2],
        slots=out[3],
        covered=out[4],
        rejected=out[5],
        evictions=out[6],
    )


def _clear_dead_dense(slot_width, cache_state, clear, order_key):
    """Drop dead workers' active §5 entries from a dense ``[S, E]`` cache.

    The churn twin of ``GradientCache.clear_range``: ``clear`` marks the
    entries to remove, and the running sums subtract them *sequentially*
    in interval-start order — ``order_key`` is the slot index for the grid
    cache (index order == start order there) and the universe start table
    otherwise.  The host caches clear per dead worker in worker order
    (disjoint worker-ordered base ranges) and walk each worker's entries
    start-ascending, so one global start-ascending walk reproduces their
    float grouping bit for bit.  Clearing is NOT an eviction: the counter
    is untouched.  The value table is read only at loop-invariant
    positions (it is not part of the fori_loop carry), so the TL002
    per-rank-copy hazard of the event loops does not arise; the trip
    count is the deepest per-scenario clear, zero in churn-free stretches.
    """
    st = cache_state
    S, _E = clear.shape
    vdim = st["values"].ndim - 2
    s_idx = jnp.arange(S)
    big = jnp.iinfo(jnp.int64).max
    order = jnp.argsort(
        jnp.where(clear, order_key[None, :], big), axis=1, stable=True
    )
    n_clear = jnp.max(jnp.sum(clear, axis=1))
    values = st["values"]

    def sub_body(j, sums):
        e = order[:, j]
        m = clear[s_idx, e]
        return jnp.where(_bcast(m, vdim), sums - values[s_idx, e], sums)

    out = dict(st)
    out["sums"] = jax.lax.fori_loop(0, n_clear, sub_body, st["sums"])
    out["covered"] = st["covered"] - jnp.sum(
        jnp.where(clear, slot_width[None, :], 0), axis=1
    )
    out["iters"] = jnp.where(clear, jnp.int64(-1), st["iters"])
    return out


def _clear_dead_tiled(spec, slot_width, slot_starts, cache_state, dead):
    """Dead-worker §5 clear for the tiled per-worker active-entry tables.

    Same order contract as :func:`_clear_dead_dense`: active intervals are
    disjoint within a worker and base ranges disjoint across workers, so
    sorting every cleared entry by its interval start reproduces the host
    cache's per-worker start-ascending walk globally.  Cleared rows keep
    their stale ``slots`` value — deactivated entries (``iters == -1``)
    are invisible to both the overlap test and the free-row search.
    """
    st = cache_state
    iters = st["iters"]  # [S, N, A]
    S, N, A = iters.shape
    E = spec.num_slots
    vdim = st["values"].ndim - 3
    clear = dead[:, :, None] & (iters >= 0)
    es_safe = jnp.clip(st["slots"], 0, E - 1)
    clear_f = clear.reshape(S, N * A)
    big = jnp.iinfo(jnp.int64).max
    order = jnp.argsort(
        jnp.where(clear_f, slot_starts[es_safe].reshape(S, N * A), big),
        axis=1,
        stable=True,
    )
    n_clear = jnp.max(jnp.sum(clear_f, axis=1))
    s_idx = jnp.arange(S)
    vals_f = st["values"].reshape((S, N * A) + st["values"].shape[3:])

    def sub_body(j, sums):
        e = order[:, j]
        m = clear_f[s_idx, e]
        return jnp.where(_bcast(m, vdim), sums - vals_f[s_idx, e], sums)

    out = dict(st)
    out["sums"] = jax.lax.fori_loop(0, n_clear, sub_body, st["sums"])
    out["covered"] = st["covered"] - jnp.sum(
        jnp.where(clear, slot_width[es_safe], 0), axis=(1, 2)
    )
    out["iters"] = jnp.where(clear, jnp.int64(-1), iters)
    return out


def _fresh_accumulate(kernels, fresh, finish, vals):
    """gd/sgd: sum fresh values per scenario in event-time order."""
    S, N = fresh.shape
    vdim = len(kernels.value_shape)
    order = jnp.argsort(jnp.where(fresh, finish, jnp.inf), axis=1, stable=True)
    s_idx = jnp.arange(S)
    flat_vals = vals.reshape((S * N,) + vals.shape[2:])
    grad0 = jnp.zeros((S,) + kernels.value_shape, dtype=jnp.float64)

    def rank_body(j, grad_acc):
        e = order[:, j]
        flat = s_idx * N + e
        valid = fresh.reshape(-1)[flat]
        v64 = flat_vals[flat].astype(jnp.float64)
        return jnp.where(_bcast(valid, vdim), grad_acc + v64, grad_acc)

    return jax.lax.fori_loop(0, N, rank_body, grad0)


def _run_scan(
    kernels: FusedKernels,
    spec: _StaticSpec,
    slot_table,
    slot_width,
    slot_starts,
    slot_stops,
    overlap_idx,
    comm,
    comp_unit,
    slowdown,
    burst_start,
    burst_end,
    burst_factor,
    V0,
    eval_mask,
    churn_times,
    churn_slowdown,
    churn_alive,
    slot_owner,
    lb_key,
):
    """THE per-iteration scan body + driver, shared by every configuration.

    ``spec`` statically selects the (lo, hi, slot) source — the fixed
    subpartition grid, or the §6 candidate after Algorithm-2 alignment —
    and the cache layout (``spec.cache_mode``); everything else (trace
    replay, event algebra, subgradients, iterate update, telemetry) is
    written once.  Under ``shard_map`` this function sees the local
    scenario shard: every per-scenario value is row-independent, and the
    cross-shard-varying dynamic trip counts / ``lax.cond`` decisions only
    skip work that is an exact no-op, so shards reproduce the
    single-device bits.
    """
    S, N, _K = comm.shape
    T = spec.num_iterations
    n = kernels.num_samples
    vshape = kernels.value_shape
    vdim = len(vshape)
    base_start = jnp.asarray(spec.base_start, dtype=jnp.int64)
    base_stop = jnp.asarray(spec.base_stop, dtype=jnp.int64)
    n_local = base_stop - base_start + 1
    sub_p = jnp.asarray(spec.sub_p, dtype=jnp.int64)
    offsets = jnp.asarray(spec.slot_offsets, dtype=jnp.int64)
    E = spec.num_slots
    if spec.cache_mode == "grid":
        # static slot grid: slot (i, k) -> interval width
        sw = []
        for i in range(N):
            nl, p = spec.base_stop[i] - spec.base_start[i] + 1, spec.sub_p[i]
            if spec.process_full:
                sw.extend([nl] * p)
            else:
                sw.extend([k * nl // p - (k - 1) * nl // p for k in range(1, p + 1)])
        slot_width = jnp.asarray(sw, dtype=jnp.int64)

    s_idx2 = jnp.arange(S)[:, None]
    w_idx2 = jnp.arange(N)[None, :]

    if spec.load_balance:
        L = len(spec.ladder)
        raw = jnp.asarray(spec.ladder, dtype=jnp.int64)
        # per-worker effective ladder (int twin of jlb.ladder_tables)
        eff = jnp.minimum(raw[None, :], n_local[:, None])  # [N, L]
        idx_cap = jnp.minimum(
            jnp.sum(raw[None, :] < n_local[:, None], axis=1), L - 1
        )
        n_j_b = jnp.broadcast_to(n_local.astype(jnp.float64), (S, N))

        def snap_int(p_vals):
            """Ladder index of exact-member p values ([S, N] int)."""
            cnt = jnp.sum(eff[None, :, :] <= p_vals[:, :, None], axis=-1)
            return jnp.clip(cnt - 1, 0, idx_cap[None, :])

    if spec.accepts_stale:
        ev_worker = jnp.concatenate([jnp.arange(N), jnp.arange(N)])
    else:
        ev_worker = jnp.arange(N)

    if spec.has_churn:
        # boundary_before: the time that opened each churn row (-inf for
        # row 0) — the §6 re-profiling cutoff after a fleet change
        churn_bound = jnp.concatenate(
            [jnp.full((1,), -jnp.inf, dtype=jnp.float64), churn_times]
        )
        if spec.uses_cache and spec.cache_mode != "tiled":
            if spec.cache_mode == "grid":
                # per-worker contiguous slot blocks: index order == start
                # order, and the owner map is static
                own = []
                for i in range(N):
                    own.extend([i] * spec.sub_p[i])
                owner_of_slot = jnp.asarray(own, dtype=jnp.int64)
                clear_key = jnp.arange(E, dtype=jnp.int64)
            else:  # universe: slots are (worker, rung) blocks, so index
                # order is NOT start order — use the universe tables
                owner_of_slot = slot_owner
                clear_key = slot_starts

    def burst_factor_at(start):
        if burst_start.shape[2] == 0:
            return jnp.ones_like(start)
        tt = start[:, :, None]
        active = (burst_start <= tt) & (tt < burst_end)
        return jnp.where(active, burst_factor, 1.0).max(axis=2)

    def body(carry, xs):
        t, do_eval = xs
        V = carry["V"]
        free_at = carry["free_at"]
        sub_k = carry["sub_k"]
        cache_state = carry["cache"]
        lat_matrix = carry["lat"]
        assign = carry["iter_end"]

        if spec.has_churn:
            # liveness sampled once per iteration at assignment time (the
            # scalar simulator / host engine convention).  A worker dead at
            # assignment has its in-flight completion discarded: it goes
            # idle with no stale event, no cache write, no profiler sample.
            rows_assign = jnp.searchsorted(
                churn_times, assign, side="right"
            ).astype(jnp.int64)
            alive = churn_alive[rows_assign]
            free_at = jnp.where(alive, free_at, assign[:, None])
            if spec.load_balance:
                changed = rows_assign != carry["prev_row"]
                # fleet changed: drop the contribution floor so Algorithm 1
                # re-baselines, and re-profile from the churn boundary
                h_min_cur = jnp.where(changed, jnp.nan, carry["h_min"])
                lb_since = jnp.where(
                    changed, churn_bound[rows_assign], carry["lb_since"]
                )
            if spec.uses_cache:
                if spec.cache_mode == "tiled":
                    cache_state = _clear_dead_tiled(
                        spec, slot_width, slot_starts, cache_state, ~alive
                    )
                else:
                    clear = (~alive)[:, owner_of_slot] & (
                        cache_state["iters"] >= 0
                    )
                    cache_state = _clear_dead_dense(
                        slot_width, cache_state, clear, clear_key
                    )
        idle = free_at <= assign[:, None]

        # -- the (lo, hi, slot) source --------------------------------------
        if spec.load_balance:
            # Algorithm-2 alignment for pending repartitions (tentative)
            sub_idx = carry["sub_idx"]
            pending_p = carry["pending_p"]
            cur_p = eff[w_idx2, sub_idx]
            p_req = jnp.clip(pending_p, 1, n_local[None, :])
            needs = (pending_p >= 0) & (p_req != cur_p)
            _, k_new = jlb.align_batch(n_local[None, :], cur_p, p_req, sub_k, needs)
            cand_idx = jnp.where(needs, snap_int(p_req), sub_idx)
            cand_k = jnp.where(needs, k_new, sub_k)
            cand_p = jnp.where(needs, p_req, cur_p)
        else:
            cand_k = sub_k
            cand_p = sub_p[None, :]

        if spec.process_full:
            lo = jnp.broadcast_to(base_start, (S, N))
            hi = jnp.broadcast_to(base_stop, (S, N))
        else:
            lo = base_start[None, :] + (cand_k - 1) * n_local[None, :] // cand_p
            hi = base_start[None, :] + cand_k * n_local[None, :] // cand_p - 1
        cost = (kernels.cost_per_row * (hi - lo + 1)) * spec.comp_scale

        # -- §3 trace replay (THE shared latency expression) ----------------
        start = jnp.where(idle, assign[:, None], free_at)
        comm_d = jnp.take_along_axis(comm, carry["draw_idx"][:, :, None], axis=2)[
            :, :, 0
        ]
        unit = jnp.take_along_axis(
            comp_unit, carry["draw_idx"][:, :, None], axis=2
        )[:, :, 0]
        # guarded_comp_latency carries the FMA seam (tracelint TL001): the
        # jnp.maximum(..., 0.0) inside it keeps LLVM from contracting the
        # last §3 multiply into the task_finish_time add below.
        if spec.has_churn:
            # per-task slowdown row at the task's START time (the traced
            # twin of ChurnSchedule.slowdown_at)
            sd = churn_slowdown[
                jnp.searchsorted(churn_times, start, side="right"), w_idx2
            ]
        else:
            sd = slowdown[None, :]
        comp_d = guarded_comp_latency(unit, cost, sd, burst_factor_at(start))

        # -- event resolution (the shared method-semantics helpers) ---------
        finish = task_finish_time(start, comp_d, comm_d)
        if spec.has_churn:
            # dead workers never contribute finish times; wait for
            # min(w, #alive) of the living fleet (sort+gather picks the
            # same element as the static top-w, so all-alive churn stays
            # bit-identical to the churn-free body)
            finish_eff = jnp.where(alive, finish, jnp.inf)
            w_eff = jnp.minimum(spec.w_wait, jnp.sum(alive, axis=1))
            tau_w = jnp.take_along_axis(
                jnp.sort(finish_eff, axis=1), w_eff[:, None] - 1, axis=1
            )[:, 0]
        else:
            tau_w = jnp.sort(finish, axis=1)[:, spec.w_wait - 1]
        if spec.margin > 0.0:
            deadline = margin_deadline(tau_w, assign, spec.margin)
        else:
            deadline = tau_w
        started = idle | (free_at <= deadline[:, None])
        if spec.has_churn:
            started = started & alive
        fresh = started & (finish <= deadline[:, None])
        stale_done = (~idle) & (free_at <= deadline[:, None])
        fresh_cnt = fresh.sum(axis=1)
        stale_ev = jnp.where(stale_done, free_at, -jnp.inf)
        fresh_ev = jnp.where(fresh, finish, -jnp.inf)
        iter_end_new = jnp.maximum(
            jnp.maximum(stale_ev.max(axis=1), fresh_ev.max(axis=1)), tau_w
        )

        # -- latency attribution by the task's own iteration ----------------
        flight_titer = carry["flight_titer"]
        flight_comp = carry["flight_comp"]
        flight_comm = carry["flight_comm"]
        titer_safe = jnp.clip(flight_titer, 0, T - 1)
        cur = lat_matrix[s_idx2, titer_safe, w_idx2]
        lat_matrix = lat_matrix.at[s_idx2, titer_safe, w_idx2].set(
            jnp.where(stale_done, flight_comp + flight_comm, cur)
        )
        lat_matrix = lat_matrix.at[:, t, :].set(
            jnp.where(fresh, comp_d + comm_d, lat_matrix[:, t, :])
        )

        if spec.load_balance:
            # -- §6.1 profiler feed: one task-slot sample per observed
            # completion (same slots and float expressions as MomentBuffer)
            prof_t, prof_comm, prof_comp, prof_valid = carry["prof"]
            flight_assigned = carry["flight_assigned"]
            stale_rt = free_at - flight_assigned
            stale_comm = jnp.maximum(stale_rt - flight_comp, 0.0)
            prof_t = prof_t.at[s_idx2, w_idx2, titer_safe].set(
                jnp.where(stale_done, free_at, prof_t[s_idx2, w_idx2, titer_safe])
            )
            prof_comm = prof_comm.at[s_idx2, w_idx2, titer_safe].set(
                jnp.where(
                    stale_done, stale_comm, prof_comm[s_idx2, w_idx2, titer_safe]
                )
            )
            prof_comp = prof_comp.at[s_idx2, w_idx2, titer_safe].set(
                jnp.where(
                    stale_done, flight_comp, prof_comp[s_idx2, w_idx2, titer_safe]
                )
            )
            prof_valid = prof_valid.at[s_idx2, w_idx2, titer_safe].set(
                prof_valid[s_idx2, w_idx2, titer_safe] | stale_done
            )
            fresh_rt = finish - assign[:, None]
            fresh_comm = jnp.maximum(fresh_rt - comp_d, 0.0)
            prof_t = prof_t.at[:, :, t].set(jnp.where(fresh, finish, prof_t[:, :, t]))
            prof_comm = prof_comm.at[:, :, t].set(
                jnp.where(fresh, fresh_comm, prof_comm[:, :, t])
            )
            prof_comp = prof_comp.at[:, :, t].set(
                jnp.where(fresh, comp_d, prof_comp[:, :, t])
            )
            prof_valid = prof_valid.at[:, :, t].set(prof_valid[:, :, t] | fresh)

        # -- batched subgradients (skipped entirely for coded) --------------
        if spec.name != "coded":
            vals = _subgradients(kernels, spec, V, lo, hi)
        else:
            vals = None

        # -- §5 cache / gradient accumulation -------------------------------
        if spec.uses_cache:
            if spec.load_balance:
                slot_cur = slot_table[w_idx2, cand_idx, cand_k - 1]
            else:
                slot_cur = offsets[None, :] + sub_k - 1
            if spec.accepts_stale:  # dsag: stale half then fresh half
                flight_slot = carry["flight_slot"]
                ev_valid = jnp.concatenate([stale_done, fresh], axis=1)
                ev_time = jnp.concatenate([free_at, finish], axis=1)
                ev_slot = jnp.concatenate([flight_slot, slot_cur], axis=1)
                ev_tag = jnp.concatenate(
                    [flight_titer, jnp.full((S, N), 1, jnp.int64) * t], axis=1
                )
                ev_vals = jnp.concatenate([carry["flight_val"], vals], axis=1)
            else:  # sag: fresh results only
                ev_valid, ev_time = fresh, finish
                ev_slot = slot_cur
                ev_tag = jnp.full((S, N), 1, jnp.int64) * t
                ev_vals = vals
            if spec.cache_mode == "universe":
                cache_state = _apply_cache_events_lb(
                    spec, slot_width, overlap_idx, cache_state, ev_valid,
                    ev_time, ev_slot, ev_tag, ev_vals,
                )
            elif spec.cache_mode == "tiled":
                cache_state = _apply_cache_events_tiled(
                    spec, slot_width, slot_starts, slot_stops, ev_worker,
                    cache_state, ev_valid, ev_time, ev_slot, ev_tag, ev_vals,
                )
            elif spec.kernel_backend == "pallas":
                # grid cache only: the §6 universe/tiled walks stay XLA
                # (their eviction logic has no Pallas twin yet — ROADMAP)
                cache_state = _apply_cache_events_pallas(
                    spec, slot_width, cache_state, ev_valid, ev_time, ev_slot,
                    ev_tag, ev_vals,
                )
            else:
                cache_state = _apply_cache_events(
                    spec, slot_width, cache_state, ev_valid, ev_time, ev_slot,
                    ev_tag, ev_vals,
                )
            xi = jnp.maximum(cache_state["covered"] / n, 1e-12)
            grad = cache_state["sums"] / _bcast(xi, vdim) + (
                kernels.regularizer_grad(V)
            )
        elif spec.name == "coded":
            slot_cur = None
            # idealized MDS bound: exact gradient at full-range width
            g = _sub_blocks_for(kernels, spec)(
                V,
                jnp.ones((S,), jnp.int64),
                jnp.full((S,), n, jnp.int64),
                n,
            ).astype(jnp.float64)
            grad = g + kernels.regularizer_grad(V)
        elif spec.name == "gd":
            slot_cur = None
            grad = _fresh_accumulate(kernels, fresh, finish, vals) + (
                kernels.regularizer_grad(V)
            )
        else:  # sgd: scale the partial sum by observed coverage
            slot_cur = None
            grad_acc = _fresh_accumulate(kernels, fresh, finish, vals)
            covered_f = jnp.sum(jnp.where(fresh, hi - lo + 1, 0), axis=1)
            xi = jnp.maximum(covered_f / n, 1e-12)
            grad = grad_acc / _bcast(xi, vdim) + kernels.regularizer_grad(V)

        # -- iterate update + suboptimality ---------------------------------
        V_new = kernels.project((V - spec.eta * grad).astype(V.dtype))
        subopt_t = jax.lax.cond(
            do_eval,
            lambda v: kernels.suboptimality(v),
            lambda v: jnp.full((S,), jnp.nan, dtype=jnp.float64),
            V_new,
        )

        # -- commit worker state for started tasks --------------------------
        out = dict(carry)
        if spec.load_balance:
            out["sub_idx"] = jnp.where(started, cand_idx, sub_idx)
            out["pending_p"] = jnp.where(started, -1, pending_p)
            out["flight_assigned"] = jnp.where(
                started, assign[:, None], carry["flight_assigned"]
            )
        if spec.process_full:
            if spec.load_balance:
                sub_k = jnp.where(started, cand_k, sub_k)
        else:
            sub_k = jnp.where(started, cand_k % cand_p + 1, sub_k)
        out["sub_k"] = sub_k
        out["free_at"] = jnp.where(started, finish, free_at)
        out["draw_idx"] = carry["draw_idx"] + started.astype(jnp.int64)
        if spec.uses_cache:
            out["flight_slot"] = jnp.where(started, slot_cur, carry["flight_slot"])
        out["flight_titer"] = jnp.where(started, t, flight_titer)
        out["flight_comp"] = jnp.where(started, comp_d, flight_comp)
        out["flight_comm"] = jnp.where(started, comm_d, flight_comm)
        if spec.accepts_stale:
            out["flight_val"] = jnp.where(
                _bcast(started, vdim), vals, carry["flight_val"]
            )
        out["V"] = V_new
        out["iter_end"] = iter_end_new
        out["cache"] = cache_state
        out["lat"] = lat_matrix

        # -- §6 background load balancer (Algorithm 1, jittable) ------------
        if spec.load_balance:
            current_p = carry["current_p"]
            h_min = h_min_cur if spec.has_churn else carry["h_min"]
            next_lb = carry["next_lb"]
            pending_p = out["pending_p"]
            due = iter_end_new >= next_lb
            out["prof"] = (prof_t, prof_comm, prof_comp, prof_valid)
            if spec.has_churn:
                out["prev_row"] = rows_assign
                out["lb_since"] = lb_since

            def lb_block(args):
                pending_p, current_p, h_min, next_lb = args
                e_cm, v_cm, e_cp, v_cp, cnt = jlb.window_moments(
                    prof_t, prof_comm, prof_comp, prof_valid, iter_end_new,
                    jlb.PROFILER_WINDOW,
                    since=lb_since if spec.has_churn else None,
                )
                if spec.has_churn:
                    # dead workers can't produce samples — don't wait on them
                    ready = jnp.all((cnt >= 1) | ~alive, axis=1)
                else:
                    ready = jnp.all(cnt >= 1, axis=1)
                next_lb2 = jnp.where(due, iter_end_new + spec.lb_interval, next_lb)
                act = due & ready

                def run_opt(_):
                    # the make_optimizer_inputs variance floors, verbatim
                    p_new, h_min2, _, publish = jlb.lb_update(
                        current_p.astype(jnp.float64),
                        e_cm,
                        jnp.maximum(v_cm, 1e-18),
                        e_cp,
                        jnp.maximum(v_cp, 1e-18),
                        n_j_b,
                        h_min,
                        act,
                        ladder=spec.ladder,
                        w=spec.w_wait,
                        margin=spec.lb_margin,
                        key=lb_key,
                        alive=alive if spec.has_churn else None,
                    )
                    changed = publish[:, None] & (p_new != current_p)
                    return (
                        jnp.where(changed, p_new, pending_p),
                        jnp.where(publish[:, None], p_new, current_p),
                        h_min2,
                        publish,
                    )

                def no_opt(_):
                    return pending_p, current_p, h_min, jnp.zeros((S,), bool)

                pending2, current2, h_min2, publish = jax.lax.cond(
                    jnp.any(act), run_opt, no_opt, None
                )
                return pending2, current2, h_min2, next_lb2, publish

            def no_lb(args):
                pending_p, current_p, h_min, next_lb = args
                return pending_p, current_p, h_min, next_lb, jnp.zeros((S,), bool)

            pending_p, current_p, h_min, next_lb, published = jax.lax.cond(
                jnp.any(due), lb_block, no_lb,
                (pending_p, current_p, h_min, next_lb),
            )
            out["pending_p"] = pending_p
            out["current_p"] = current_p
            out["h_min"] = h_min
            out["next_lb"] = next_lb
        else:
            published = jnp.zeros((S,), bool)

        return out, (iter_end_new, subopt_t, fresh_cnt, published)

    val_dtype = jnp.dtype(kernels.value_dtype)
    if spec.cache_mode == "grid":
        cache0 = dict(
            sums=jnp.zeros((S,) + vshape, dtype=jnp.float64),
            values=jnp.zeros((S, max(E, 1)) + vshape, dtype=jnp.float64),
            iters=jnp.full((S, max(E, 1)), -1, dtype=jnp.int64),
            covered=jnp.zeros((S,), dtype=jnp.int64),
            rejected=jnp.zeros((S,), dtype=jnp.int64),
        )
    elif spec.cache_mode == "universe":
        cache0 = dict(
            sums=jnp.zeros((S,) + vshape, dtype=jnp.float64),
            values=jnp.zeros((S, max(E, 1)) + vshape, dtype=jnp.float64),
            iters=jnp.full((S, max(E, 1)), -1, dtype=jnp.int64),
            covered=jnp.zeros((S,), dtype=jnp.int64),
            rejected=jnp.zeros((S,), dtype=jnp.int64),
            evictions=jnp.zeros((S,), dtype=jnp.int64),
        )
    elif spec.cache_mode == "tiled":
        A = max(spec.active_cap, 1)
        cache0 = dict(
            sums=jnp.zeros((S,) + vshape, dtype=jnp.float64),
            values=jnp.zeros((S, N, A) + vshape, dtype=jnp.float64),
            iters=jnp.full((S, N, A), -1, dtype=jnp.int64),
            slots=jnp.full((S, N, A), -1, dtype=jnp.int64),
            covered=jnp.zeros((S,), dtype=jnp.int64),
            rejected=jnp.zeros((S,), dtype=jnp.int64),
            evictions=jnp.zeros((S,), dtype=jnp.int64),
        )
    else:
        cache0 = dict(rejected=jnp.zeros((S,), dtype=jnp.int64))
    carry0 = dict(
        V=V0,
        free_at=jnp.zeros((S, N)),
        iter_end=jnp.zeros((S,)),
        draw_idx=jnp.zeros((S, N), dtype=jnp.int64),
        sub_k=jnp.ones((S, N), dtype=jnp.int64),
        flight_slot=jnp.full((S, N), -1, dtype=jnp.int64),
        flight_titer=jnp.full((S, N), -1, dtype=jnp.int64),
        flight_comp=jnp.zeros((S, N)),
        flight_comm=jnp.zeros((S, N)),
        flight_val=jnp.zeros((S, N) + vshape, dtype=val_dtype),
        cache=cache0,
        # explicit dtype: python-float fills would enter the scan carry
        # weakly typed (tracelint TL004)
        lat=jnp.full((S, T, N), jnp.nan, dtype=jnp.float64),
    )
    if spec.load_balance:
        sub_p0 = jnp.asarray(spec.sub_p, dtype=jnp.int64)
        idx0 = jnp.clip(jnp.sum(eff <= sub_p0[:, None], axis=1) - 1, 0, idx_cap)
        carry0["sub_idx"] = jnp.broadcast_to(idx0, (S, N))
        carry0["pending_p"] = jnp.full((S, N), -1, dtype=jnp.int64)
        # current_p is the optimizer's view of the published p
        carry0["current_p"] = jnp.full((S, N), spec.lb_p0, dtype=jnp.int64)
        carry0["h_min"] = jnp.full((S,), jnp.nan, dtype=jnp.float64)
        carry0["next_lb"] = jnp.full(
            (S,), spec.lb_startup_delay, dtype=jnp.float64
        )
        carry0["flight_assigned"] = jnp.zeros((S, N))
        if spec.has_churn:
            # churn times are strictly positive, so row 0 is active at t=0
            # and its opening boundary is -inf (the static `since`)
            carry0["prev_row"] = jnp.zeros((S,), dtype=jnp.int64)
            carry0["lb_since"] = jnp.full((S,), -jnp.inf, dtype=jnp.float64)
        carry0["prof"] = (
            jnp.zeros((S, N, T)),
            jnp.zeros((S, N, T)),
            jnp.zeros((S, N, T)),
            jnp.zeros((S, N, T), dtype=bool),
        )
    xs = (jnp.arange(T, dtype=jnp.int64), eval_mask)
    carry, ys = jax.lax.scan(body, carry0, xs)
    times, subopt, fresh_counts, published = ys
    evictions = carry["cache"].get(
        "evictions", jnp.zeros((S,), dtype=jnp.int64)
    )
    return (
        times.T,
        subopt.T,
        fresh_counts.T,
        carry["lat"],
        carry["cache"]["rejected"],
        evictions,
        published.T,  # [S, T] publication schedule (all-False without §6)
    )


def _scan_jit_for(kernels: FusedKernels, mesh=None):
    """Per-kernels jitted driver, keyed by the scenario mesh.

    The jit cache is owned by the kernels object rather than a module-level
    callable: a module-level ``jax.jit`` would keep every problem's data
    matrices (captured by the static ``kernels`` argument) alive for the
    process lifetime; this way the compiled executables are garbage
    collected with the problem.  With a mesh, the driver is wrapped in
    ``shard_map`` over the ``"data"`` (scenario) axis: the five slot
    tables, ``slowdown``, ``eval_mask`` and the PRNG key are replicated,
    every ``[S, ...]`` array is sharded on its leading axis, and so is
    every output.
    """
    cache = getattr(kernels, "_scan_driver_jits", None)
    if cache is None:
        cache = {}
        kernels._scan_driver_jits = cache
    key = (
        None
        if mesh is None
        else (mesh.axis_names, tuple(d.id for d in mesh.devices.flat))
    )
    fn = cache.get(key)
    if fn is None:
        if mesh is None:
            fn = jax.jit(_run_scan, static_argnums=(0, 1))
        else:
            from jax.experimental.shard_map import shard_map

            repl, data = P(), P("data")
            in_specs = (repl,) * 5 + (
                data, data, repl, data, data, data, data, repl,
            ) + (repl,) * 5  # churn tables, slot owners, PRNG key
            out_specs = (data,) * 7

            def sharded(kernels_, spec_, *arrays):
                body = functools.partial(_run_scan, kernels_, spec_)
                # check_rep=False: jax 0.4.x has no replication rule for
                # while_loop (the §6 aligner), and every output here is
                # data-sharded anyway, so the static check buys nothing.
                return shard_map(
                    body, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs, check_rep=False,
                )(*arrays)

            fn = jax.jit(sharded, static_argnums=(0, 1))
        cache[key] = fn
    return fn


def scan_capability(
    problem: FiniteSumProblem,
    config: MethodConfig,
    num_workers: int,
    *,
    slot_budget: int | None = None,
) -> EngineCapability:
    """Structured report of how the fused scan would run this config.

    * :data:`~repro.experiments.engine.CAP_OK` — supported; §6 configs fit
      the dense slot universe within ``slot_budget``.
    * :data:`~repro.experiments.engine.CAP_TILED` — supported; the §6
      ladder universe exceeds the budget, so the scan uses the tiled
      active-slot cache (``slots_resident`` names its footprint).
    * :data:`~repro.experiments.engine.CAP_ACTIVE_SET` — unsupported: even
      the tiled cache's resident entries exceed the budget; route to the
      host engine.

    ``slot_budget`` defaults to :data:`LB_MAX_SLOTS`.  Bounds here are
    cheap overestimates (no universe is built): the dense bound is
    ``N * sum(min(rung, max n_local))``; the tiled bound is the
    minimum-interval-width packing cap per worker, which the exact greedy
    capacity (:func:`~repro.core.gradient_cache.active_slot_capacity`)
    never exceeds.
    """
    budget = int(LB_MAX_SLOTS if slot_budget is None else slot_budget)
    if not (config.load_balance and config.uses_cache):
        return EngineCapability(
            supported=True,
            code=CAP_OK,
            detail="fused scan supports this config",
            slot_budget=budget,
        )
    n = problem.num_samples
    N = num_workers
    n_local = np.array(
        [p_stop(n, N, i + 1) - p_start(n, N, i + 1) + 1 for i in range(N)]
    )
    ladder = lb_ladder_for(config, n_local)
    total = int(sum(min(int(r), int(n_local.max())) for r in ladder)) * N
    if total <= budget:
        return EngineCapability(
            supported=True,
            code=CAP_OK,
            detail=(
                f"§6 ladder slot universe fits densely "
                f"({total} slots <= budget {budget})"
            ),
            slots_total=total,
            slots_resident=total,
            slot_budget=budget,
        )
    p_top = max(int(r) for r in ladder)
    cap = 0
    for nl in n_local:
        w_min = max(int(nl) // min(p_top, int(nl)), 1)
        cap = max(cap, int(nl) // w_min)
    resident = N * cap
    if resident <= budget:
        return EngineCapability(
            supported=True,
            code=CAP_TILED,
            detail=(
                f"§6 ladder slot universe needs up to {total} slots "
                f"(> slot budget {budget}); running the fused scan with the "
                f"tiled active-slot cache (<= {resident} resident entries)"
            ),
            slots_total=total,
            slots_resident=resident,
            slot_budget=budget,
        )
    return EngineCapability(
        supported=False,
        code=CAP_ACTIVE_SET,
        detail=(
            f"even the tiled active-slot cache needs up to {resident} "
            f"resident entries (> slot budget {budget}); the fused scan "
            f"cannot hold this config — use EngineConfig(kind='host') or "
            f"raise slot_budget"
        ),
        slots_total=total,
        slots_resident=resident,
        slot_budget=budget,
    )


def scan_unsupported_reason(
    problem: FiniteSumProblem, config: MethodConfig, num_workers: int
) -> str | None:
    """Why the fused scan cannot run this config (None = it can).

    Deprecated string shim over :func:`scan_capability` — callers should
    branch on the structured report's ``code`` instead of this text.
    Note that since the tiled cache landed, oversized §6 universes are
    *supported* (they return None here); only configs whose active-entry
    footprint exceeds the budget report a reason.
    """
    warnings.warn(
        "scan_unsupported_reason is deprecated; use scan_capability and "
        "branch on the structured report's code",
        DeprecationWarning,
        stacklevel=2,
    )
    cap = scan_capability(problem, config, num_workers)
    return None if cap.supported else cap.detail


def kernel_backend_capability(
    problem: FiniteSumProblem, kernel_backend: str = "xla"
) -> EngineCapability:
    """Whether the fused scan can route this problem's hot paths to Pallas.

    ``"xla"`` is always supported.  ``"pallas"`` requires the problem to
    publish Pallas twins (``FusedKernels.sub_blocks_pallas``) and a
    float32 in-flight value dtype (the only dtype the kernels are
    validated for — see ``kernels/block_sub.py``).  Reported codes:
    :data:`~repro.experiments.engine.CAP_PALLAS_UNAVAILABLE`,
    :data:`~repro.experiments.engine.CAP_PALLAS_DTYPE`.
    """
    if kernel_backend != "pallas":
        return EngineCapability(
            supported=True, code=CAP_OK, detail="xla kernel backend"
        )
    kernels = problem.fused_kernels()
    if kernels.sub_blocks_pallas is None:
        return EngineCapability(
            supported=False,
            code=CAP_PALLAS_UNAVAILABLE,
            detail=(
                f"kernel_backend='pallas' requested but "
                f"{type(problem).__name__} publishes no Pallas kernels "
                f"(FusedKernels.sub_blocks_pallas is None); use "
                f"kernel_backend='xla'"
            ),
        )
    if np.dtype(kernels.value_dtype) != np.float32:
        return EngineCapability(
            supported=False,
            code=CAP_PALLAS_DTYPE,
            detail=(
                f"kernel_backend='pallas' supports float32 in-flight "
                f"values only; {type(problem).__name__} declares "
                f"{np.dtype(kernels.value_dtype).name}"
            ),
        )
    return EngineCapability(
        supported=True, code=CAP_OK, detail="pallas kernel backend available"
    )


def prepare_scan_inputs(
    problem: FiniteSumProblem,
    traces: FleetTraces,
    config: MethodConfig,
    num_iterations: int,
    *,
    cost_scale: float = 1.0,
    eval_every: int = 1,
    seed: int = 0,
    slot_budget: int | None = None,
    pad: int = 0,
    kernel_backend: str = "xla",
):
    """Static spec + kernels + the full ``_run_scan`` operand tuple.

    The one place the fused engine's positional calling convention is
    encoded.  Shared between :func:`run_convergence_scan` and the
    tracelint entry registry (``repro.analysis.lint.entries``), so the
    static analyzer always traces the production scan body with
    production-shaped operands instead of a hand-maintained replica.
    ``pad`` edge-pads the scenario axis with copies of the last scenario
    (``shard_map`` divisibility).  Raises
    :class:`~repro.experiments.engine.EngineCapabilityError` for
    genuinely unsupported configs.
    """
    cap = scan_capability(
        problem, config, traces.num_workers, slot_budget=slot_budget
    )
    if not cap.supported:
        raise EngineCapabilityError(cap)
    kcap = kernel_backend_capability(problem, kernel_backend)
    if not kcap.supported:
        raise EngineCapabilityError(kcap)
    # resolve the interpret decision NOW, outside any trace: reading
    # jax.default_backend() inside a jitted wrapper bakes a stale value
    # into the cached executable (the kernels/ops.py bug class)
    kernel_interpret = jax.default_backend() == "cpu"
    tiled = cap.code == CAP_TILED
    S = traces.num_scenarios
    T = num_iterations
    if T > traces.horizon:
        raise ValueError(
            f"traces hold {traces.horizon} draws/worker but {T} iterations requested"
        )
    universe = None
    active_cap = 0
    if config.load_balance and config.uses_cache:
        n = problem.num_samples
        N = traces.num_workers
        base_start = [p_start(n, N, i + 1) for i in range(N)]
        base_stop = [p_stop(n, N, i + 1) for i in range(N)]
        n_local = np.asarray(base_stop) - np.asarray(base_start) + 1
        universe = build_slot_universe(
            base_start,
            base_stop,
            lb_ladder_for(config, n_local),
            with_overlaps=not tiled,
        )
        if tiled:
            active_cap = int(active_slot_capacity(universe).max())
    spec = _static_spec(
        problem,
        config,
        traces.num_workers,
        T,
        cost_scale,
        universe=universe,
        tiled=tiled,
        active_cap=active_cap,
        has_churn=traces.churn is not None,
        kernel_backend=kernel_backend,
        kernel_interpret=kernel_interpret,
    )
    kernels = problem.fused_kernels()
    V0 = np.repeat(problem.init(seed)[None], S, axis=0)
    eval_mask = np.zeros(T, dtype=bool)
    eval_mask[::eval_every] = True
    eval_mask[T - 1] = True

    def padded(a):
        if pad == 0:
            return a
        return np.concatenate([a, np.repeat(a[-1:], pad, axis=0)], axis=0)

    with enable_x64():
        empty = jnp.zeros((S + pad, traces.num_workers, 0))
        has_b = traces.has_bursts
        trace_args = (
            jnp.asarray(padded(traces.comm)),
            jnp.asarray(padded(traces.comp_unit)),
            jnp.asarray(traces.slowdown),
            jnp.asarray(padded(traces.burst_start)) if has_b else empty,
            jnp.asarray(padded(traces.burst_end)) if has_b else empty,
            jnp.asarray(padded(traces.burst_factor)) if has_b else empty,
            jnp.asarray(padded(V0)),
            jnp.asarray(eval_mask),
        )
        if universe is not None:
            slot_table = jnp.asarray(universe.slot_table)
            slot_width = jnp.asarray(universe.widths)
            slot_starts = jnp.asarray(universe.starts)
            slot_stops = jnp.asarray(universe.stops)
            overlap_idx = jnp.asarray(universe.overlap_idx)
        else:  # grid / non-cache configs: keep the unused tables minimal
            N = traces.num_workers
            L = max(len(spec.ladder), 1)
            pmax = max(spec.ladder) if spec.ladder else 1
            slot_table = jnp.zeros((N, L, pmax), dtype=jnp.int64)
            slot_width = jnp.zeros((1,), dtype=jnp.int64)
            slot_starts = jnp.zeros((1,), dtype=jnp.int64)
            slot_stops = jnp.zeros((1,), dtype=jnp.int64)
            overlap_idx = jnp.full((1, 1), -1, dtype=jnp.int64)
        ch = traces.churn
        if ch is not None:
            churn_times = jnp.asarray(ch.times, dtype=jnp.float64)
            churn_slowdown = jnp.asarray(ch.slowdown, dtype=jnp.float64)
            churn_alive = jnp.asarray(ch.alive, dtype=bool)
        else:  # unused by the traced body (spec.has_churn gates it out);
            # fixed operand count keeps one calling convention
            churn_times = jnp.zeros((0,), dtype=jnp.float64)
            churn_slowdown = jnp.zeros((0, traces.num_workers), jnp.float64)
            churn_alive = jnp.zeros((0, traces.num_workers), dtype=bool)
        slot_owner = (
            jnp.asarray(universe.owners)
            if universe is not None
            else jnp.zeros((1,), dtype=jnp.int64)
        )
        scan_args = (
            slot_table,
            slot_width,
            slot_starts,
            slot_stops,
            overlap_idx,
            *trace_args,
            churn_times,
            churn_slowdown,
            churn_alive,
            slot_owner,
            jax.random.PRNGKey(seed),
        )
    return spec, kernels, scan_args


def run_convergence_scan(
    problem: FiniteSumProblem,
    traces: FleetTraces,
    config: MethodConfig,
    num_iterations: int,
    *,
    cost_scale: float = 1.0,
    eval_every: int = 1,
    seed: int = 0,
    engine: EngineConfig | None = None,
):
    """Train ``config`` on every scenario of ``traces`` in one XLA dispatch.

    Bit-exact against the host engine and the scalar simulator on the same
    traces (see module docstring), §6 load-balanced configs included.
    ``engine`` supplies the scenario mesh (``mesh`` / ``num_devices``),
    the slot budget, and the ``kernel_backend``; its ``kind`` is ignored
    here — this *is* the scan engine.  Raises :class:`~repro.experiments.engine.EngineCapabilityError`
    for the one unsupported case (see :func:`scan_capability`)."""
    from repro.experiments.convergence import ConvergenceBatchResult

    eng = as_engine_config(engine, _stacklevel=3)
    mesh = eng.mesh
    if mesh is None and eng.num_devices is not None:
        from repro.launch.mesh import make_scenario_mesh

        mesh = make_scenario_mesh(eng.num_devices)
    D = 1 if mesh is None else int(np.prod(mesh.devices.shape))
    S = traces.num_scenarios
    # shard_map needs the scenario axis divisible by the mesh: edge-pad
    # with copies of the last scenario (exact per-row math makes padding
    # rows inert) and slice every output back to S
    pad = (-S) % D
    spec, kernels, scan_args = prepare_scan_inputs(
        problem,
        traces,
        config,
        num_iterations,
        cost_scale=cost_scale,
        eval_every=eval_every,
        seed=seed,
        slot_budget=eng.slot_budget,
        pad=pad,
        kernel_backend=eng.kernel_backend,
    )
    with enable_x64():
        outs = _scan_jit_for(kernels, mesh)(kernels, spec, *scan_args)
        times, subopt, fresh, lat, rejected, evictions, published = (
            np.asarray(o)[:S] for o in outs
        )
    repartition_events = [
        [float(times[s, t]) for t in np.flatnonzero(published[s])]
        for s in range(S)
    ]
    return ConvergenceBatchResult(
        times=times,
        suboptimality=subopt,
        fresh_counts=np.asarray(fresh, dtype=np.int64),
        per_worker_latency=lat,
        repartition_events=repartition_events,
        evictions=np.asarray(evictions, dtype=np.int64),
        rejected_stale=np.asarray(rejected, dtype=np.int64),
    )
