"""Fused ``jax.lax.scan`` convergence engine.

The host engine (:func:`repro.experiments.convergence.run_convergence_batch`
with ``engine="host"``) runs one Python iteration per training iteration and
dispatches batched kernels from it.  This module compiles the *entire*
iteration body — §4.2 event algebra, §3 trace replay, block subgradients,
the §5 cache update as masked scatters, the iterate update, and the
suboptimality evaluation — into one jittable function and scans it over the
whole run: a single XLA dispatch for a complete ``[S]``-scenario training
sweep, ready for accelerators.

Bit-exactness contract (pinned by ``tests/test_fused.py``): for every
scenario, the scan produces the same bits as the host engine and the scalar
:class:`~repro.cluster.simulator.TrainingSimulator` replaying the same
trace.  Three ingredients make that possible:

* every float expression is shared: the problems'
  :class:`~repro.core.problems.FusedKernels` are called from all three
  engines, and the event algebra mirrors
  :func:`~repro.cluster.simulator.task_finish_time` /
  :func:`~repro.cluster.simulator.margin_deadline` term by term;
* block subgradients are evaluated at the static
  :func:`~repro.core.problems.width_bucket` ladder — one kernel call per
  possible bucket, rows selected by their actual width — so a given
  (iterate, interval) is always computed at the same static shape;
* the §5 cache is a *fixed slot universe*: without §6 repartitioning the
  interval set is exactly the initial subpartition grid, so per-scenario
  cache state is dense ``[S, E]`` arrays and each event rank applies as one
  masked scatter, sequenced per scenario in event-time order by an inner
  ``fori_loop`` (float accumulation order preserved).

Load-balanced configs are rejected: §6 Algorithm 1 (profiler moments +
hill-climbing) is host code, and a repartition would grow the slot
universe mid-scan.  ``run_convergence_batch`` routes those to the host
engine, which shares all the kernels above.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.cluster.simulator import (
    MethodConfig,
    effective_w,
    margin_deadline,
    task_finish_time,
)
from repro.core.problems import FiniteSumProblem, FusedKernels, width_bucket
from repro.latency.model import FleetTraces, comp_latency_expr
from repro.lb.partitioner import p_start, p_stop


@dataclasses.dataclass(frozen=True)
class _StaticSpec:
    """Hashable static configuration of one fused-scan compilation."""

    name: str
    w_wait: int
    eta: float
    margin: float  # effective margin (0.0 when unused)
    comp_scale: float
    process_full: bool
    uses_cache: bool
    accepts_stale: bool
    num_iterations: int
    base_start: Tuple[int, ...]
    base_stop: Tuple[int, ...]
    sub_p: Tuple[int, ...]  # initial (and, without §6, permanent) p_i
    buckets: Tuple[int, ...]  # static width_bucket ladder, ascending
    slot_offsets: Tuple[int, ...]  # per-worker first slot (cache methods)
    num_slots: int


def _possible_widths(n_local: int, p: int, full: bool) -> set:
    if full:
        return {n_local}
    return {k * n_local // p - (k - 1) * n_local // p for k in range(1, p + 1)}


def _static_spec(
    problem: FiniteSumProblem,
    config: MethodConfig,
    num_workers: int,
    num_iterations: int,
    cost_scale: float,
) -> _StaticSpec:
    n = problem.num_samples
    N = num_workers
    cfg = config
    base_start = tuple(p_start(n, N, i + 1) for i in range(N))
    base_stop = tuple(p_stop(n, N, i + 1) for i in range(N))
    n_local = [b - a + 1 for a, b in zip(base_start, base_stop)]
    process_full = cfg.name in ("gd", "coded")
    sub_p = tuple(min(cfg.subpartitions, nl) for nl in n_local)
    widths = set()
    for nl, p in zip(n_local, sub_p):
        widths |= _possible_widths(nl, p, process_full)
    buckets = tuple(sorted({width_bucket(m, n) for m in widths}))
    if cfg.uses_cache:
        offsets = np.concatenate([[0], np.cumsum(sub_p)])
        slot_offsets = tuple(int(o) for o in offsets[:-1])
        num_slots = int(offsets[-1])
    else:
        slot_offsets = (0,) * N
        num_slots = 0
    margin_eff = cfg.margin if (cfg.uses_margin and cfg.margin > 0) else 0.0
    return _StaticSpec(
        name=cfg.name,
        w_wait=effective_w(cfg, N),
        eta=float(cfg.eta),
        margin=float(margin_eff),
        comp_scale=float(
            cost_scale * (1.0 / cfg.code_rate if cfg.name == "coded" else 1.0)
        ),
        process_full=process_full,
        uses_cache=cfg.uses_cache,
        accepts_stale=cfg.accepts_stale,
        num_iterations=num_iterations,
        base_start=base_start,
        base_stop=base_stop,
        sub_p=sub_p,
        buckets=buckets,
        slot_offsets=slot_offsets,
        num_slots=num_slots,
    )


def _bcast(mask, value_ndim: int):
    """Reshape an [S] mask so it broadcasts over value dimensions."""
    return mask.reshape(mask.shape + (1,) * value_ndim)


def _subgradients(kernels: FusedKernels, spec: _StaticSpec, V, lo, hi):
    """[S, N, ...] block subgradients via the static width-bucket ladder.

    One kernel dispatch per possible bucket (all S*N tasks each time), rows
    selected by their actual width — bit-identical to the host wrapper,
    which routes each row to the same bucket.
    """
    S, N = lo.shape
    n = kernels.num_samples
    widths = hi - lo + 1
    vdim = len(kernels.value_shape)
    Vb = jnp.broadcast_to(
        V[:, None], (S, N) + kernels.value_shape
    ).reshape((S * N,) + kernels.value_shape)
    lo_f = lo.reshape(-1)
    w_f = widths.reshape(-1)
    out = None
    prev = 0
    for b in spec.buckets:
        block = kernels.sub_blocks(Vb, lo_f, w_f, b).reshape(
            (S, N) + kernels.value_shape
        )
        if b == n:
            sel = widths == n
        else:
            sel = (widths != n) & (widths <= b) & (widths > prev)
        out = block if out is None else jnp.where(_bcast(sel, vdim), block, out)
        prev = b
    return out


def _apply_cache_events(
    spec: _StaticSpec,
    slot_width,
    cache_state,
    ev_valid,
    ev_time,
    ev_slot,
    ev_tag,
    ev_vals,
):
    """The §5 update for one iteration's events, as masked scatters.

    ``ev_*`` are ``[S, E_ev]`` tables (stale then fresh halves for DSAG,
    fresh only for SAG).  Events are ranked per scenario by a stable sort
    on event time (+inf where invalid) and applied rank by rank: one rank
    holds at most one event per scenario, so its updates are a single
    vectorized masked scatter, and the per-scenario float accumulation
    order of the running sums matches the host cache's time-ordered
    inserts bit for bit.  With a fixed slot universe an active exact-match
    slot is the only possible overlap, so the scalar cache's eviction walk
    reduces to staleness dominance + in-place update (the SAG fast path).
    """
    sums, values, iters, covered, rejected = cache_state
    S, E_ev = ev_time.shape
    vdim = values.ndim - 2
    order = jnp.argsort(jnp.where(ev_valid, ev_time, jnp.inf), axis=1, stable=True)
    s_idx = jnp.arange(S)
    flat_vals = ev_vals.reshape((S * E_ev,) + ev_vals.shape[2:])

    def rank_body(j, state):
        sums, values, iters, covered, rejected = state
        e = order[:, j]
        flat = s_idx * E_ev + e
        valid = ev_valid.reshape(-1)[flat]
        slot = jnp.clip(ev_slot.reshape(-1)[flat], 0, spec.num_slots - 1)
        tag = ev_tag.reshape(-1)[flat]
        v64 = flat_vals[flat].astype(jnp.float64)
        cur_it = iters[s_idx, slot]
        active = cur_it >= 0
        dom = active & (cur_it >= tag)
        acc = valid & ~dom
        rej = valid & dom
        old = values[s_idx, slot]
        delta = v64 - jnp.where(_bcast(active, vdim), old, 0.0)
        sums = jnp.where(_bcast(acc, vdim), sums + delta, sums)
        values = values.at[s_idx, slot].set(jnp.where(_bcast(acc, vdim), v64, old))
        iters = iters.at[s_idx, slot].set(jnp.where(acc, tag, cur_it))
        covered = covered + jnp.where(acc & ~active, slot_width[slot], 0)
        rejected = rejected + rej.astype(rejected.dtype)
        return sums, values, iters, covered, rejected

    return jax.lax.fori_loop(
        0, E_ev, rank_body, (sums, values, iters, covered, rejected)
    )


def _fresh_accumulate(kernels, fresh, finish, vals):
    """gd/sgd: sum fresh values per scenario in event-time order."""
    S, N = fresh.shape
    vdim = len(kernels.value_shape)
    order = jnp.argsort(jnp.where(fresh, finish, jnp.inf), axis=1, stable=True)
    s_idx = jnp.arange(S)
    flat_vals = vals.reshape((S * N,) + vals.shape[2:])
    grad0 = jnp.zeros((S,) + kernels.value_shape, dtype=jnp.float64)

    def rank_body(j, grad_acc):
        e = order[:, j]
        flat = s_idx * N + e
        valid = fresh.reshape(-1)[flat]
        v64 = flat_vals[flat].astype(jnp.float64)
        return jnp.where(_bcast(valid, vdim), grad_acc + v64, grad_acc)

    return jax.lax.fori_loop(0, N, rank_body, grad0)


def _run_scan(
    kernels: FusedKernels,
    spec: _StaticSpec,
    comm,
    comp_unit,
    slowdown,
    burst_start,
    burst_end,
    burst_factor,
    V0,
    eval_mask,
):
    """The jitted driver: precompute static tables, scan the fused body."""
    S, N, _K = comm.shape
    T = spec.num_iterations
    n = kernels.num_samples
    vshape = kernels.value_shape
    vdim = len(vshape)
    base_start = jnp.asarray(spec.base_start, dtype=jnp.int64)
    base_stop = jnp.asarray(spec.base_stop, dtype=jnp.int64)
    n_local = base_stop - base_start + 1
    sub_p = jnp.asarray(spec.sub_p, dtype=jnp.int64)
    offsets = jnp.asarray(spec.slot_offsets, dtype=jnp.int64)
    E = spec.num_slots
    if spec.uses_cache:
        # static slot universe: slot (i, k) -> interval width
        sw = []
        for i in range(N):
            nl, p = spec.base_stop[i] - spec.base_start[i] + 1, spec.sub_p[i]
            if spec.process_full:
                sw.extend([nl] * p)
            else:
                sw.extend([k * nl // p - (k - 1) * nl // p for k in range(1, p + 1)])
        slot_width = jnp.asarray(sw, dtype=jnp.int64)
    else:
        slot_width = jnp.zeros((0,), dtype=jnp.int64)

    s_idx2 = jnp.arange(S)[:, None]
    w_idx2 = jnp.arange(N)[None, :]

    def burst_factor_at(start):
        if burst_start.shape[2] == 0:
            return jnp.ones_like(start)
        tt = start[:, :, None]
        active = (burst_start <= tt) & (tt < burst_end)
        return jnp.where(active, burst_factor, 1.0).max(axis=2)

    def body(carry, xs):
        (
            V,
            free_at,
            iter_end,
            draw_idx,
            sub_k,
            flight_slot,
            flight_titer,
            flight_comp,
            flight_comm,
            flight_val,
            cache_state,
            lat_matrix,
        ) = carry
        t, do_eval = xs
        assign = iter_end
        idle = free_at <= assign[:, None]

        if spec.process_full:
            lo = jnp.broadcast_to(base_start, (S, N))
            hi = jnp.broadcast_to(base_stop, (S, N))
        else:
            lo = base_start[None, :] + (sub_k - 1) * n_local[None, :] // sub_p[None, :]
            hi = base_start[None, :] + sub_k * n_local[None, :] // sub_p[None, :] - 1
        cost = (kernels.cost_per_row * (hi - lo + 1)) * spec.comp_scale

        # -- §3 trace replay (THE shared latency expression) ----------------
        start = jnp.where(idle, assign[:, None], free_at)
        comm_d = jnp.take_along_axis(comm, draw_idx[:, :, None], axis=2)[:, :, 0]
        unit = jnp.take_along_axis(comp_unit, draw_idx[:, :, None], axis=2)[:, :, 0]
        comp_d = comp_latency_expr(
            unit, cost, slowdown[None, :], burst_factor_at(start)
        )

        # -- event resolution (the shared method-semantics helpers) ---------
        finish = task_finish_time(start, comp_d, comm_d)
        tau_w = jnp.sort(finish, axis=1)[:, spec.w_wait - 1]
        if spec.margin > 0.0:
            deadline = margin_deadline(tau_w, assign, spec.margin)
        else:
            deadline = tau_w
        started = idle | (free_at <= deadline[:, None])
        fresh = started & (finish <= deadline[:, None])
        stale_done = (~idle) & (free_at <= deadline[:, None])
        fresh_cnt = fresh.sum(axis=1)
        stale_ev = jnp.where(stale_done, free_at, -jnp.inf)
        fresh_ev = jnp.where(fresh, finish, -jnp.inf)
        iter_end_new = jnp.maximum(
            jnp.maximum(stale_ev.max(axis=1), fresh_ev.max(axis=1)), tau_w
        )

        # -- latency attribution by the task's own iteration ----------------
        titer_safe = jnp.clip(flight_titer, 0, T - 1)
        cur = lat_matrix[s_idx2, titer_safe, w_idx2]
        lat_matrix = lat_matrix.at[s_idx2, titer_safe, w_idx2].set(
            jnp.where(stale_done, flight_comp + flight_comm, cur)
        )
        lat_matrix = lat_matrix.at[:, t, :].set(
            jnp.where(fresh, comp_d + comm_d, lat_matrix[:, t, :])
        )

        # -- batched subgradients (skipped entirely for coded) --------------
        if spec.name != "coded":
            vals = _subgradients(kernels, spec, V, lo, hi)
        else:
            vals = None

        # -- §5 cache / gradient accumulation -------------------------------
        slot_cur = offsets[None, :] + sub_k - 1 if spec.uses_cache else None
        if spec.uses_cache:
            if spec.accepts_stale:  # dsag: stale half then fresh half
                ev_valid = jnp.concatenate([stale_done, fresh], axis=1)
                ev_time = jnp.concatenate([free_at, finish], axis=1)
                ev_slot = jnp.concatenate([flight_slot, slot_cur], axis=1)
                ev_tag = jnp.concatenate(
                    [flight_titer, jnp.full((S, N), 1, jnp.int64) * t], axis=1
                )
                ev_vals = jnp.concatenate([flight_val, vals], axis=1)
            else:  # sag: fresh results only
                ev_valid, ev_time = fresh, finish
                ev_slot = slot_cur
                ev_tag = jnp.full((S, N), 1, jnp.int64) * t
                ev_vals = vals
            cache_state = _apply_cache_events(
                spec, slot_width, cache_state, ev_valid, ev_time, ev_slot,
                ev_tag, ev_vals,
            )
            sums, _, _, covered, _ = cache_state
            xi = jnp.maximum(covered / n, 1e-12)
            grad = sums / _bcast(xi, vdim) + kernels.regularizer_grad(V)
        elif spec.name == "coded":
            # idealized MDS bound: exact gradient at full-range width
            g = kernels.sub_blocks(
                V,
                jnp.ones((S,), jnp.int64),
                jnp.full((S,), n, jnp.int64),
                n,
            ).astype(jnp.float64)
            grad = g + kernels.regularizer_grad(V)
        elif spec.name == "gd":
            grad = _fresh_accumulate(kernels, fresh, finish, vals) + (
                kernels.regularizer_grad(V)
            )
        else:  # sgd: scale the partial sum by observed coverage
            grad_acc = _fresh_accumulate(kernels, fresh, finish, vals)
            covered_f = jnp.sum(jnp.where(fresh, hi - lo + 1, 0), axis=1)
            xi = jnp.maximum(covered_f / n, 1e-12)
            grad = grad_acc / _bcast(xi, vdim) + kernels.regularizer_grad(V)

        # -- iterate update + suboptimality ---------------------------------
        V_new = kernels.project((V - spec.eta * grad).astype(V.dtype))
        subopt_t = jax.lax.cond(
            do_eval,
            lambda v: kernels.suboptimality(v),
            lambda v: jnp.full((S,), jnp.nan, dtype=jnp.float64),
            V_new,
        )

        # -- commit worker state for started tasks --------------------------
        if not spec.process_full:
            sub_k = jnp.where(started, sub_k % sub_p[None, :] + 1, sub_k)
        free_at = jnp.where(started, finish, free_at)
        draw_idx = draw_idx + started.astype(jnp.int64)
        if spec.uses_cache:
            flight_slot = jnp.where(started, slot_cur, flight_slot)
        flight_titer = jnp.where(started, t, flight_titer)
        flight_comp = jnp.where(started, comp_d, flight_comp)
        flight_comm = jnp.where(started, comm_d, flight_comm)
        if spec.accepts_stale:
            flight_val = jnp.where(_bcast(started, vdim), vals, flight_val)

        carry = (
            V_new,
            free_at,
            iter_end_new,
            draw_idx,
            sub_k,
            flight_slot,
            flight_titer,
            flight_comp,
            flight_comm,
            flight_val,
            cache_state,
            lat_matrix,
        )
        return carry, (iter_end_new, subopt_t, fresh_cnt)

    val_dtype = jnp.dtype(kernels.value_dtype)
    cache0 = (
        jnp.zeros((S,) + vshape, dtype=jnp.float64),  # sums
        jnp.zeros((S, max(E, 1)) + vshape, dtype=jnp.float64),  # values
        jnp.full((S, max(E, 1)), -1, dtype=jnp.int64),  # iters
        jnp.zeros((S,), dtype=jnp.int64),  # covered
        jnp.zeros((S,), dtype=jnp.int64),  # rejected_stale
    )
    carry0 = (
        V0,
        jnp.zeros((S, N)),  # free_at
        jnp.zeros((S,)),  # iter_end
        jnp.zeros((S, N), dtype=jnp.int64),  # draw_idx
        jnp.ones((S, N), dtype=jnp.int64),  # sub_k
        jnp.full((S, N), -1, dtype=jnp.int64),  # flight_slot
        jnp.full((S, N), -1, dtype=jnp.int64),  # flight_titer
        jnp.zeros((S, N)),  # flight_comp
        jnp.zeros((S, N)),  # flight_comm
        jnp.zeros((S, N) + vshape, dtype=val_dtype),  # flight_val
        cache0,
        jnp.full((S, T, N), jnp.nan),  # lat_matrix
    )
    xs = (jnp.arange(T, dtype=jnp.int64), eval_mask)
    carry, ys = jax.lax.scan(body, carry0, xs)
    times, subopt, fresh_counts = ys
    cache_state = carry[10]
    return (
        times.T,
        subopt.T,
        fresh_counts.T,
        carry[11],  # lat_matrix
        cache_state[4],  # rejected_stale
    )


def _scan_jit_for(kernels: FusedKernels):
    """Per-kernels jitted driver.

    The jit cache is owned by the kernels object rather than a module-level
    callable: a module-level ``jax.jit`` would keep every problem's data
    matrices (captured by the static ``kernels`` argument) alive for the
    process lifetime; this way the compiled executables are garbage
    collected with the problem.
    """
    jitted = getattr(kernels, "_scan_driver_jit", None)
    if jitted is None:
        jitted = jax.jit(_run_scan, static_argnums=(0, 1))
        kernels._scan_driver_jit = jitted
    return jitted


def run_convergence_scan(
    problem: FiniteSumProblem,
    traces: FleetTraces,
    config: MethodConfig,
    num_iterations: int,
    *,
    cost_scale: float = 1.0,
    eval_every: int = 1,
    seed: int = 0,
):
    """Train ``config`` on every scenario of ``traces`` in one XLA dispatch.

    Bit-exact against the host engine and the scalar simulator on the same
    traces (see module docstring).  Raises for load-balanced configs.
    """
    from repro.experiments.convergence import ConvergenceBatchResult

    if config.load_balance:
        raise ValueError(
            "the fused scan cannot run §6 load balancing (Algorithm 1 is "
            "host code); use engine='host'"
        )
    S = traces.num_scenarios
    T = num_iterations
    if T > traces.horizon:
        raise ValueError(
            f"traces hold {traces.horizon} draws/worker but {T} iterations requested"
        )
    spec = _static_spec(problem, config, traces.num_workers, T, cost_scale)
    kernels = problem.fused_kernels()
    V0 = np.repeat(problem.init(seed)[None], S, axis=0)
    eval_mask = np.zeros(T, dtype=bool)
    eval_mask[::eval_every] = True
    eval_mask[T - 1] = True
    with enable_x64():
        empty = jnp.zeros((S, traces.num_workers, 0))
        has_b = traces.has_bursts
        times, subopt, fresh, lat, rejected = _scan_jit_for(kernels)(
            kernels,
            spec,
            jnp.asarray(traces.comm),
            jnp.asarray(traces.comp_unit),
            jnp.asarray(traces.slowdown),
            jnp.asarray(traces.burst_start) if has_b else empty,
            jnp.asarray(traces.burst_end) if has_b else empty,
            jnp.asarray(traces.burst_factor) if has_b else empty,
            jnp.asarray(V0),
            jnp.asarray(eval_mask),
        )
    return ConvergenceBatchResult(
        times=np.asarray(times),
        suboptimality=np.asarray(subopt),
        fresh_counts=np.asarray(fresh, dtype=np.int64),
        per_worker_latency=np.asarray(lat),
        repartition_events=[[] for _ in range(S)],
        evictions=np.zeros(S, dtype=np.int64),
        rejected_stale=np.asarray(rejected, dtype=np.int64),
    )
