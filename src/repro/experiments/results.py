"""Results layer of the scenario sweeps: ordering verdicts, the §6.1
profiler feed from batched traces, and the ``BENCH_sweep.json`` /
``BENCH_convergence.json`` artifacts."""

from __future__ import annotations

import json
import os

import numpy as np

from repro.experiments.grid import SweepOutcome
from repro.experiments.sweep import BatchedRunResult
from repro.latency.profiler import LatencyProfiler


def paper_ordering(outcome: SweepOutcome, regime: str) -> dict[str, float]:
    """DSAG-vs-baselines verdict for one regime (paper Figs. 8-9 ordering).

    Returns mean-iteration-time ratios (baseline / DSAG, i.e. > 1 means DSAG
    is faster) plus the boolean the benchmark gates on: DSAG faster than
    both SAG and the coded bound.  When several w values were swept, each
    method is taken at its *best* swept w (w is an operating point the
    deployer tunes; averaging across w cells would blend incomparable
    configurations and let a poorly chosen extra w flip the verdict).
    Empty when the sweep ran custom methods without a "dsag" column.
    """

    def best_cell(method: str):
        ws = {r.w for r in outcome.rows if r.regime == regime and r.method == method}
        if not ws:
            raise KeyError(method)
        cells = {w: outcome.mean_iter_time(regime, method, w) for w in ws}
        w = min(cells, key=cells.get)
        return cells[w], w

    try:
        t_dsag, dsag_w = best_cell("dsag")
    except KeyError:
        return {}
    ratios = {}
    for baseline in ("sag", "coded", "gd", "sgd"):
        try:
            ratios[f"{baseline}_over_dsag"] = best_cell(baseline)[0] / t_dsag
        except KeyError:
            continue
    ratios["dsag_mean_iter_time"] = t_dsag
    ratios["dsag_w"] = float(dsag_w)
    ratios["dsag_beats_sag_and_coded"] = float(
        ratios.get("sag_over_dsag", 0.0) > 1.0
        and ratios.get("coded_over_dsag", 0.0) > 1.0
    )
    return ratios


def feed_profiler(
    result: BatchedRunResult,
    scenario: int,
    *,
    load: float = 1.0,
    window: float = np.inf,
    profiler: LatencyProfiler | None = None,
) -> LatencyProfiler:
    """Feed one scenario's batched task records into a §6.1 profiler.

    The batched engine records (assignment, start, finish, compute) per
    (iteration, worker); this flattens them into the profiler's per-worker
    moving-window deques via :meth:`LatencyProfiler.record_batch`, giving
    the load-balancing optimizer the same moment estimates it would have
    collected live.  Requires ``replay_batch(..., record_tasks=True)``.
    """
    if result.task_finish is None:
        raise ValueError("run replay_batch with record_tasks=True to feed the profiler")
    T, N = result.task_finish.shape[1:]
    if profiler is None:
        profiler = LatencyProfiler(N, window=window)
    finish = result.task_finish[scenario]  # [T, N]
    comp = result.task_comp[scenario]
    assigned = result.task_assigned[scenario][:, None]  # [T, 1]
    workers = np.broadcast_to(np.arange(N)[None, :], (T, N))
    profiler.record_batch(
        workers=workers,
        t_recorded=finish,
        round_trip=finish - assigned,
        compute=comp,
        load=load,
    )
    return profiler


def outcome_to_dict(
    outcome: SweepOutcome,
    *,
    scalar_seconds: float | None = None,
    extra: dict | None = None,
) -> dict:
    """JSON-serializable summary of a sweep (the BENCH_sweep payload)."""
    agg: dict[str, dict] = {}
    for r in outcome.rows:
        key = f"{r.regime}/{r.method}/w{r.w}"
        agg.setdefault(key, {"mean_iter_time": [], "mean_fresh": []})
        agg[key]["mean_iter_time"].append(r.mean_iter_time)
        agg[key]["mean_fresh"].append(r.mean_fresh)
    cells = {
        key: {
            "mean_iter_time": float(np.mean(v["mean_iter_time"])),
            "std_iter_time": float(np.std(v["mean_iter_time"])),
            "mean_fresh": float(np.mean(v["mean_fresh"])),
            "n_seeds": len(v["mean_iter_time"]),
        }
        for key, v in agg.items()
    }
    regimes = sorted({r.regime for r in outcome.rows})
    payload = {
        "grid": {
            "n_workers": outcome.n_workers,
            "n_seeds": outcome.n_seeds,
            "num_iterations": outcome.num_iterations,
            "n_cells": len(outcome.results),
            "regimes": regimes,
            "seed": outcome.seed,
        },
        "engine_seconds": outcome.engine_seconds,
        "cells": cells,
        "ordering": {reg: paper_ordering(outcome, reg) for reg in regimes},
    }
    if scalar_seconds is not None:
        payload["scalar_seconds"] = scalar_seconds
        payload["speedup_vs_scalar"] = scalar_seconds / max(
            outcome.engine_seconds, 1e-12
        )
    if extra:
        payload.update(extra)
    return payload


def _json_safe(obj):
    """Replace non-finite floats with None: json.dump would otherwise emit
    the non-standard Infinity/NaN tokens and produce invalid strict JSON."""
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def _write_json(payload: dict, path: str) -> dict:
    payload = _json_safe(payload)
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)
    return payload


def write_bench_sweep(
    outcome: SweepOutcome,
    path: str = "BENCH_sweep.json",
    *,
    scalar_seconds: float | None = None,
    extra: dict | None = None,
) -> dict:
    """Write the sweep summary to ``path`` (repo-root BENCH artifact)."""
    payload = outcome_to_dict(outcome, scalar_seconds=scalar_seconds, extra=extra)
    return _write_json(payload, path)


# ---------------------------------------------------------------------------
# Convergence sweeps (time-to-suboptimality, Figs. 10-12)
# ---------------------------------------------------------------------------


def convergence_ordering(outcome, gap: float) -> dict[str, float]:
    """Time-to-gap verdict across methods (the paper's headline numbers).

    Returns each method's median (across scenarios) time to reach
    ``suboptimality <= gap``, the speedup ratios over DSAG, and the boolean
    the benchmark gates on: DSAG reaching the gap before SAG and before the
    coded bound (``dsag < sag < coded`` as *times*, i.e. DSAG fastest).
    Medians over the scenario axis pair runs on common random numbers, so a
    single straggler-heavy draw cannot flip the verdict.
    """
    out: dict[str, float] = {"gap": gap}
    medians: dict[str, float] = {}
    for name, res in outcome.results.items():
        ttg = res.time_to_gap(gap)
        # the median of [finite..., inf] stays finite while fewer than half
        # the scenarios miss the gap — a single straggler-heavy draw cannot
        # flip the verdict; the miss rate is reported separately
        medians[name] = float(np.median(ttg))
        out[f"median_time_to_gap_{name}"] = medians[name]
        out[f"reached_gap_frac_{name}"] = float(np.isfinite(ttg).mean())
    if "dsag" in medians:
        t_dsag = medians["dsag"]
        for name, t in medians.items():
            if name != "dsag":
                out[f"{name}_over_dsag"] = (
                    t / t_dsag if np.isfinite(t_dsag) else float("nan")
                )
        # the paper-ordering verdict is only meaningful when both baselines
        # actually ran; a missing method must not read as "DSAG beat it"
        if "sag" in medians and "coded" in medians:
            sag_t, coded_t = medians["sag"], medians["coded"]
            out["dsag_fastest_to_gap"] = float(
                np.isfinite(t_dsag) and t_dsag < sag_t and t_dsag < coded_t
            )
            out["ordering_dsag_sag_coded"] = float(
                np.isfinite(t_dsag) and t_dsag < sag_t <= coded_t
            )
    return out


def convergence_payload(outcome, gap: float) -> dict:
    """JSON-serializable summary of one convergence sweep (grid, per-method
    time-to-gap columns, and the ordering verdict) — the building block of
    ``BENCH_convergence.json``; extra workloads (e.g. the paper-scale PCA
    column) nest their own payload beside the main one."""
    methods = {}
    for name, res in outcome.results.items():
        ttg = res.time_to_gap(gap)
        final_gap = res.suboptimality[:, -1]
        methods[name] = {
            "median_time_to_gap": float(np.median(ttg)),
            "mean_total_time": float(res.times[:, -1].mean()),
            "mean_final_gap": float(np.nanmean(final_gap)),
            "mean_fresh": float(res.fresh_counts.mean()),
            "w": outcome.methods[name].w,
            "load_balance": bool(outcome.methods[name].load_balance),
        }
    return {
        "grid": {
            "n_workers": outcome.traces.num_workers,
            "n_scenarios": outcome.traces.num_scenarios,
            "num_iterations": outcome.num_iterations,
            "problem": type(outcome.problem).__name__,
            "num_samples": outcome.problem.num_samples,
        },
        "gap": gap,
        "engine_seconds": outcome.engine_seconds,
        "methods": methods,
        "ordering": convergence_ordering(outcome, gap),
    }


def write_bench_convergence(
    outcome,
    path: str = "BENCH_convergence.json",
    *,
    gap: float,
    scalar_seconds: float | None = None,
    scalar_seconds_measured: float | None = None,
    scalar_methods: list | None = None,
    extra: dict | None = None,
) -> dict:
    """Write the convergence-sweep summary to ``path``.

    ``scalar_seconds`` is the (possibly extrapolated) wall-clock through the
    scalar :class:`TrainingSimulator`; ``scalar_seconds_measured`` the
    actually-timed subset.  When the scalar timing covers only a subset of
    the engine's methods, pass ``scalar_methods`` — the top-level
    ``speedup_vs_scalar`` (scalar over ``engine_seconds``) is then omitted,
    because dividing a subset's scalar time by the full grid's engine time
    would be an apples-to-oranges ratio; record the like-for-like number via
    ``extra`` instead.
    """
    payload = convergence_payload(outcome, gap)
    if scalar_seconds is not None:
        payload["scalar_seconds"] = scalar_seconds
        if scalar_methods is None:
            payload["speedup_vs_scalar"] = scalar_seconds / max(
                outcome.engine_seconds, 1e-12
            )
        else:
            payload["scalar_methods"] = list(scalar_methods)
    if scalar_seconds_measured is not None:
        payload["scalar_seconds_measured"] = scalar_seconds_measured
    if extra:
        payload.update(extra)
    return _write_json(payload, path)
