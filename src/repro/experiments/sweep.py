"""Vectorized scenario-sweep engine for the paper's §7 comparisons.

The scalar simulators (:class:`repro.latency.event_sim.EventDrivenSimulator`,
:class:`repro.cluster.simulator.TrainingSimulator`) replay the §4.2 busy/idle
worker fleet one heap event at a time — minutes of wall-clock for a single
100-worker comparison.  This module batches the *scenario* axis: all latency
draws are pre-sampled with :func:`repro.latency.model.sample_fleet`, and the
per-iteration event dynamics are resolved with [S, N] array operations, one
numpy pass per iteration instead of one Python heap operation per event.

The key observation that makes the event loop vectorizable without a
fixed-point: within one iteration, a busy worker's fresh completion
``f_i = F_i + d_i`` can only be among the ``w`` earliest if its previous
task's completion ``F_i`` is below the iteration deadline (``F_i < f_i``),
in which case its queued task *did* start — so the w-th order statistic of
the candidate finish times over all workers is exactly the scalar
simulator's w-th fresh arrival, with no per-event sequencing needed.  The
remaining quantities (margin deadline, which workers actually started,
iteration end time = last processed event) are pure array reductions.

``replay_batch`` reproduces the scalar event loop *bit-exactly* on the same
pre-sampled traces (see ``tests/test_sweep.py``); ``synchronous_times_batch``
is the fully-vectorized fast path for methods without cross-iteration queue
feedback (GD, the idealized coded bound).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.cluster.simulator import margin_deadline, task_finish_time
from repro.latency.event_sim import EventDrivenSimulator, SimResult
from repro.latency.model import FleetTraces


@dataclasses.dataclass
class BatchedRunResult:
    """Per-scenario traces of one batched method run.

    ``iteration_times`` matches the scalar simulator's
    ``SimResult.iteration_times`` per scenario; the ``task_*`` arrays (only
    filled with ``record_tasks=True``) hold per-(scenario, iteration, worker)
    samples for the §6.1 profiler feed (NaN where the worker never started
    that iteration's task).
    """

    iteration_times: np.ndarray  # [S, T] completion time of each iteration
    fresh_counts: np.ndarray  # [S, T]
    participation: np.ndarray  # [S, N] fraction of iterations fresh
    task_assigned: np.ndarray | None = None  # [S, T] assignment time
    task_start: np.ndarray | None = None  # [S, T, N]
    task_finish: np.ndarray | None = None  # [S, T, N]
    task_comp: np.ndarray | None = None  # [S, T, N] compute-only latency

    @property
    def mean_iteration_time(self) -> np.ndarray:
        """[S] mean per-iteration latency of each scenario."""
        t = self.iteration_times
        return t[:, -1] / t.shape[1]


def _broadcast_loads(loads, S: int, N: int) -> np.ndarray:
    return np.broadcast_to(np.asarray(loads, dtype=np.float64), (S, N))


def replay_batch(
    traces: FleetTraces,
    w: int,
    num_iterations: int,
    *,
    margin: float = 0.0,
    loads=1.0,
    record_tasks: bool = False,
) -> BatchedRunResult:
    """Run the §4.2 w-of-N event dynamics for every scenario at once.

    Exactly equivalent (bit-for-bit, up to measure-zero event-time ties) to
    running :class:`EventDrivenSimulator` per scenario with
    ``traces.scalar_latency_provider`` — but resolved with [S, N] array
    operations per iteration.
    """
    S, N, K = traces.comm.shape
    if not (1 <= w <= N):
        raise ValueError(f"w={w} not in 1..{N}")
    if num_iterations > K:
        raise ValueError(
            f"traces hold {K} draws/worker but {num_iterations} iterations requested"
        )
    loads_b = _broadcast_loads(loads, S, N)
    churn = traces.churn

    free_at = np.zeros((S, N))  # F_i: when each worker's current task finishes
    iter_end = np.zeros(S)  # E: last processed event of the previous iteration
    draw_idx = np.zeros((S, N), dtype=np.int64)
    times = np.empty((S, num_iterations))
    fresh_counts = np.empty((S, num_iterations), dtype=np.int64)
    part_accum = np.zeros((S, N), dtype=np.int64)
    if record_tasks:
        assigned_rec = np.empty((S, num_iterations))
        start_rec = np.full((S, num_iterations, N), np.nan)
        finish_rec = np.full((S, num_iterations, N), np.nan)
        comp_rec = np.full((S, num_iterations, N), np.nan)

    for t in range(num_iterations):
        assign = iter_end  # all idle workers start now; busy workers queue
        if churn is not None:
            # liveness sampled once per iteration at assignment time: a dead
            # worker discards its in-flight task (no stale event, no draw
            # consumed) and a revived one re-enters idle at this assign
            alive = churn.alive_at(assign)
            free_at = np.where(alive, free_at, assign[:, None])
        idle = free_at <= assign[:, None]
        start = np.where(idle, assign[:, None], free_at)
        comm_d, comp_d = traces.task_latency_parts(draw_idx, start, loads_b)
        finish = task_finish_time(start, comp_d, comm_d)

        # w-th fresh arrival: any busy worker contributing to the first w has
        # free_at < finish <= tau_w, i.e. its queued task provably started.
        if churn is None:
            tau_w = np.partition(finish, w - 1, axis=1)[:, w - 1]
        else:
            # dead workers never contribute finish times; the order statistic
            # waits for min(w, #alive) of the living fleet.  sort+gather picks
            # the same exact element as partition, so the all-alive schedule
            # stays bit-identical to the static path.
            finish_eff = np.where(alive, finish, np.inf)
            w_eff = np.minimum(w, alive.sum(axis=1))
            tau_w = np.sort(finish_eff, axis=1)[np.arange(S), w_eff - 1]
        if margin > 0.0:
            # paper §5.1: keep collecting `margin` longer than the time the
            # first w fresh results took this iteration
            deadline = margin_deadline(tau_w, assign, margin)
        else:
            deadline = tau_w
        started = idle | (free_at <= deadline[:, None])
        if churn is not None:
            started &= alive
        fresh = started & (finish <= deadline[:, None])
        fresh_counts[:, t] = fresh.sum(axis=1)
        part_accum += fresh

        # iteration ends at the last processed event <= deadline: either a
        # fresh completion or a busy->idle transition that started a queued task
        stale_events = np.where(~idle & (free_at <= deadline[:, None]), free_at, -np.inf)
        fresh_events = np.where(fresh, finish, -np.inf)
        iter_end = np.maximum(
            np.maximum(stale_events.max(axis=1), fresh_events.max(axis=1)), tau_w
        )
        times[:, t] = iter_end

        if record_tasks:
            assigned_rec[:, t] = assign
            start_rec[:, t] = np.where(started, start, np.nan)
            finish_rec[:, t] = np.where(started, finish, np.nan)
            comp_rec[:, t] = np.where(started, comp_d, np.nan)

        free_at = np.where(started, finish, free_at)
        draw_idx += started

    return BatchedRunResult(
        iteration_times=times,
        fresh_counts=fresh_counts,
        participation=part_accum / max(num_iterations, 1),
        task_assigned=assigned_rec if record_tasks else None,
        task_start=start_rec if record_tasks else None,
        task_finish=finish_rec if record_tasks else None,
        task_comp=comp_rec if record_tasks else None,
    )


def synchronous_times_batch(
    traces: FleetTraces,
    w: int,
    num_iterations: int,
    *,
    loads=1.0,
    return_participation: bool = False,
):
    """[S, T] cumulative iteration times for methods *without* queue feedback.

    Models fully synchronized rounds (GD, the §7.1 idealized coded bound):
    every worker starts each iteration at the sync point and stragglers'
    leftover work is abandoned, so the iteration latency is the w-th order
    statistic of N fresh draws.  Burst-free traces vectorize over iterations
    too (no sequential dependence at all); with bursts the factor depends on
    the running clock, so iterations are folded sequentially but still [S, N]
    at a time.
    """
    S, N, K = traces.comm.shape
    if not (1 <= w <= N):
        raise ValueError(f"w={w} not in 1..{N}")
    if num_iterations > K:
        raise ValueError(
            f"traces hold {K} draws/worker but {num_iterations} iterations requested"
        )
    loads_b = _broadcast_loads(loads, S, N)
    if not traces.has_bursts:
        d = traces.comm[:, :, :num_iterations] + (
            traces.comp_unit[:, :, :num_iterations]
            * loads_b[:, :, None]
            * traces.slowdown[None, :, None]
        )
        per_iter = np.partition(d, w - 1, axis=1)[:, w - 1, :]
        times = np.cumsum(per_iter, axis=1)
        if return_participation:
            participation = (d <= per_iter[:, None, :]).mean(axis=2)
            return times, participation
        return times
    times = np.empty((S, num_iterations))
    clock = np.zeros(S)
    part_accum = np.zeros((S, N), dtype=np.int64)
    for t in range(num_iterations):
        idx = np.full((S, N), t, dtype=np.int64)
        d = traces.task_latency(idx, np.broadcast_to(clock[:, None], (S, N)), loads_b)
        kth = np.partition(d, w - 1, axis=1)[:, w - 1]
        part_accum += d <= kth[:, None]
        clock = clock + kth
        times[:, t] = clock
    if return_participation:
        return times, part_accum / max(num_iterations, 1)
    return times


def scalar_reference(
    traces: FleetTraces,
    scenario: int,
    w: int,
    num_iterations: int,
    *,
    margin: float = 0.0,
    loads=1.0,
) -> SimResult:
    """Replay one scenario through the *scalar* event loop (ground truth).

    Used by the equivalence tests and the speedup benchmark: same trace
    arrays, same draw-consumption order, one heap event at a time.
    """
    if num_iterations > traces.horizon:
        raise ValueError(
            f"traces hold {traces.horizon} draws/worker but "
            f"{num_iterations} iterations requested"
        )
    N = traces.num_workers
    loads_arr = np.broadcast_to(
        np.asarray(loads, dtype=np.float64),
        (traces.num_scenarios, N) if np.ndim(loads) == 2 else (N,),
    )
    if loads_arr.ndim == 2:
        loads_arr = loads_arr[scenario]
    sim = EventDrivenSimulator(
        None,
        loads_arr,
        latency_provider=traces.scalar_latency_provider(scenario, loads),
    )
    return sim.run(w, num_iterations, margin=margin, churn=traces.churn)


def scalar_sync_reference(
    traces: FleetTraces,
    scenario: int,
    w: int,
    num_iterations: int,
    *,
    loads=1.0,
) -> np.ndarray:
    """Scalar counterpart of :func:`synchronous_times_batch` (one scenario).

    Per iteration: draw every worker's latency at the sync point, advance
    the clock by the w-th smallest.  Same dynamics, one draw at a time —
    the honest baseline for timing the sync fast path.
    """
    if num_iterations > traces.horizon:
        raise ValueError(
            f"traces hold {traces.horizon} draws/worker but "
            f"{num_iterations} iterations requested"
        )
    N = traces.num_workers
    loads_arr = np.broadcast_to(np.asarray(loads, dtype=np.float64), (N,))
    clock = 0.0
    times = np.empty(num_iterations)
    for t in range(num_iterations):
        d = np.empty(N)
        for i in range(N):
            comm, comp = traces.scalar_task_latency(scenario, i, t, clock, loads_arr[i])
            d[i] = comm + comp
        clock = clock + np.sort(d)[w - 1]
        times[t] = clock
    return times
