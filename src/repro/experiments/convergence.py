"""Batched convergence sweeps: the full DSAG/SAG/SGD update rule over all
scenarios of a :class:`~repro.latency.model.FleetTraces` draw at once.

PR 1's sweep engine batched the §7 *iteration-time* dynamics; the paper's
headline claims (DSAG up to ~50% faster than SAG, >2x faster than coded
methods) are about *time-to-suboptimality*, which needs the whole training
loop: gradient cache, coverage scaling ξ, the §5.1 margin, stale
integration, and the §6 load balancer.  This module runs that loop for all
``[S]`` scenarios simultaneously:

* the event dynamics of each iteration are resolved with the same ``[S, N]``
  array algebra as :func:`repro.experiments.sweep.replay_batch` (idle/busy
  resolution, w-th order statistic, margin deadline, queue feedback);
* subgradients are evaluated as ``[S, ...]`` stacks through
  :meth:`~repro.core.problems.FiniteSumProblem.subgradient_blocks` — one JAX
  dispatch per iteration instead of one per (scenario, worker) task;
* per-scenario cache state lives in a
  :class:`~repro.core.gradient_cache.BatchedGradientCache` (shared interval
  slots, ``[S, ...]`` sums);
* the §6 loop is batched end to end: per-scenario
  :class:`~repro.latency.profiler.LatencyProfiler` moments feed ``[S, N]``
  :class:`~repro.lb.optimizer.OptimizerInputs`, and
  :meth:`~repro.lb.optimizer.LoadBalanceOptimizer.optimize_batch` balances
  every due scenario in one call.

The load-bearing property (pinned by ``tests/test_convergence.py``): for
every scenario ``s``, the batched run is *bit-exact* against the scalar
:class:`~repro.cluster.simulator.TrainingSimulator` replaying the same
trace through ``TraceLatencySource(traces, s)`` — times, suboptimality,
fresh counts, per-worker latencies, cache telemetry, and the
load-balancing republication schedule.  The batching is a reformulation of
the method, not an approximation of it.
"""

from __future__ import annotations

import dataclasses
import time
from collections.abc import Sequence

import numpy as np

from repro.cluster.simulator import (
    MethodConfig,
    RunHistory,
    TraceLatencySource,
    TrainingSimulator,
    effective_w,
    lb_ladder_for,
    make_optimizer_inputs,
    margin_deadline,
    task_finish_time,
)
from repro.core.gradient_cache import BatchedGradientCache, scenario_ranks
from repro.core.problems import FiniteSumProblem
from repro.experiments.engine import (
    CAP_PALLAS_HOST,
    EngineCapability,
    EngineCapabilityError,
    EngineConfig,
    as_engine_config,
)
from repro.latency.model import ClusterLatencyModel, FleetTraces, sample_fleet
from repro.latency.profiler import MomentBuffer
from repro.lb.optimizer import LoadBalanceOptimizer
from repro.lb.partitioner import _align, p_start, p_stop


@dataclasses.dataclass
class ConvergenceBatchResult:
    """Per-scenario training traces of one batched convergence run.

    Scenario ``s`` of every array equals the corresponding field of the
    :class:`RunHistory` a scalar ``TrainingSimulator`` produces on the same
    trace, bit for bit.
    """

    times: np.ndarray  # [S, T]
    suboptimality: np.ndarray  # [S, T] (NaN where not evaluated)
    fresh_counts: np.ndarray  # [S, T]
    per_worker_latency: np.ndarray  # [S, T, N] (see RunHistory semantics)
    repartition_events: list[list[float]]  # per scenario
    evictions: np.ndarray  # [S]
    rejected_stale: np.ndarray  # [S]

    @property
    def num_scenarios(self) -> int:
        return self.times.shape[0]

    def history(self, s: int) -> RunHistory:
        """Scenario ``s`` as a scalar :class:`RunHistory`."""
        return RunHistory(
            times=self.times[s],
            suboptimality=self.suboptimality[s],
            fresh_counts=self.fresh_counts[s],
            per_worker_latency=self.per_worker_latency[s],
            repartition_events=list(self.repartition_events[s]),
            evictions=int(self.evictions[s]),
            rejected_stale=int(self.rejected_stale[s]),
        )

    def time_to_gap(self, gap: float) -> np.ndarray:
        """[S] first sim time at which suboptimality <= gap (inf if never)."""
        ok = np.nan_to_num(self.suboptimality, nan=np.inf) <= gap
        any_ok = ok.any(axis=1)
        first = np.argmax(ok, axis=1)
        out = np.full(self.num_scenarios, np.inf)
        rows = np.flatnonzero(any_ok)
        out[rows] = self.times[rows, first[rows]]
        return out


def run_convergence_batch(
    problem: FiniteSumProblem,
    traces: FleetTraces,
    config: MethodConfig,
    num_iterations: int,
    *,
    cost_scale: float = 1.0,
    eval_every: int | None = None,
    seed: int = 0,
    engine: EngineConfig | None = None,
) -> ConvergenceBatchResult:
    """Train ``config`` on every scenario of ``traces`` simultaneously.

    Equivalent to ``TrainingSimulator(problem, cluster, config,
    latency_source=TraceLatencySource(traces, s), ...).run(num_iterations)``
    for each scenario ``s`` — resolved with ``[S, N]`` array operations and
    batched JAX subgradient evaluation instead of a per-event Python loop.

    ``engine`` is an :class:`~repro.experiments.engine.EngineConfig`
    selecting the implementation (default: ``EngineConfig()``):

    * ``kind="scan"`` — the fused ``jax.lax.scan`` engine
      (:func:`repro.experiments.fused.run_convergence_scan`): the whole
      iteration body (event algebra, subgradients, cache scatter, iterate
      update, suboptimality, and the §6 load balancer) is one jittable
      function scanned over iterations; §6 slot universes above the
      config's ``slot_budget`` run with the tiled active-slot cache, and
      ``mesh`` / ``num_devices`` shard the scenario axis over devices.
      Raises :class:`~repro.experiments.engine.EngineCapabilityError` for
      the one genuinely unsupported case
      (:func:`repro.experiments.fused.scan_capability`).
    * ``kind="host"`` — the numpy-driven batched loop below (one Python
      iteration per training iteration, batched kernels inside; the
      device mesh does not apply here).
    * ``kind="auto"`` (default) — ``"scan"`` unless the capability report
      says unsupported, which routes to ``"host"``.

    Legacy ``engine="auto"|"scan"|"host"`` strings still work as
    deprecated aliases (``DeprecationWarning``).  ``eval_every`` defaults
    to the engine config's cadence (itself defaulting to 1); passing it
    explicitly overrides both.

    All engines are bit-exact against each other and against the scalar
    simulator (pinned by ``tests/test_convergence.py`` /
    ``tests/test_fused.py`` / ``tests/test_lb_scan.py`` /
    ``tests/test_sharded.py``).
    """
    eng = as_engine_config(engine, _stacklevel=3)
    if eval_every is None:
        eval_every = eng.eval_every
    kind = eng.kind
    if kind == "auto":
        from repro.experiments.fused import scan_capability

        cap = scan_capability(
            problem, config, traces.num_workers, slot_budget=eng.slot_budget
        )
        kind = "scan" if cap.supported else "host"
    if kind == "host" and eng.kernel_backend == "pallas":
        # the host loop drives the problem's numpy wrappers — there is no
        # Pallas path to take, so honoring the request is impossible
        raise EngineCapabilityError(
            EngineCapability(
                supported=False,
                code=CAP_PALLAS_HOST,
                detail=(
                    "kernel_backend='pallas' requires the fused scan "
                    "engine; this config resolved to kind='host' "
                    "(pass EngineConfig(kind='scan') or drop the Pallas "
                    "backend)"
                ),
            )
        )
    if kind == "scan":
        from repro.experiments.fused import run_convergence_scan

        return run_convergence_scan(
            problem,
            traces,
            config,
            num_iterations,
            cost_scale=cost_scale,
            eval_every=eval_every,
            seed=seed,
            engine=eng,
        )
    S, N = traces.num_scenarios, traces.num_workers
    n = problem.num_samples
    T = num_iterations
    cfg = config
    if T > traces.horizon:
        raise ValueError(
            f"traces hold {traces.horizon} draws/worker but {T} iterations requested"
        )
    w_wait = effective_w(cfg, N)
    comp_scale = cost_scale * (1.0 / cfg.code_rate if cfg.name == "coded" else 1.0)
    process_full = cfg.name in ("gd", "coded")
    margin_eff = cfg.margin if (cfg.uses_margin and cfg.margin > 0) else 0.0

    V0 = problem.init(seed)
    vshape = V0.shape
    V = np.repeat(V0[None], S, axis=0)
    bshape = (S,) + (1,) * len(vshape)  # per-scenario scalar broadcast
    cache = (
        BatchedGradientCache(S, n, np.zeros(vshape, dtype=np.float64))
        if cfg.uses_cache
        else None
    )

    # -- batched subpartition state (paper §6.3, one Subpartitioner per
    # (scenario, worker) flattened into integer arrays) --------------------
    base_start = np.array([p_start(n, N, i + 1) for i in range(N)], dtype=np.int64)
    base_stop = np.array([p_stop(n, N, i + 1) for i in range(N)], dtype=np.int64)
    n_local = base_stop - base_start + 1
    sub_p = np.broadcast_to(
        np.minimum(cfg.subpartitions, n_local), (S, N)
    ).copy()
    sub_k = np.ones((S, N), dtype=np.int64)
    pending_p = np.full((S, N), -1, dtype=np.int64)

    free_at = np.zeros((S, N))
    iter_end = np.zeros(S)
    draw_idx = np.zeros((S, N), dtype=np.int64)

    # in-flight task per (scenario, worker): what the busy worker is
    # computing right now (value captured from the assignment iterate)
    flight_lo = np.zeros((S, N), dtype=np.int64)
    flight_hi = np.zeros((S, N), dtype=np.int64)
    flight_titer = np.full((S, N), -1, dtype=np.int64)
    flight_val: np.ndarray | None = None  # allocated at first evaluation
    flight_comp = np.zeros((S, N))
    flight_comm = np.zeros((S, N))
    flight_assigned = np.zeros((S, N))

    times = np.zeros((S, T))
    subopt = np.full((S, T), np.nan)
    fresh_counts = np.zeros((S, T), dtype=np.int64)
    lat_matrix = np.full((S, T, N), np.nan)
    repartition_events: list[list[float]] = [[] for _ in range(S)]

    needs_values = cfg.name in ("gd", "sgd", "sag", "dsag")
    lbbuf = MomentBuffer(S, N, T) if cfg.load_balance else None
    lb = (
        LoadBalanceOptimizer(seed=seed, ladder=lb_ladder_for(cfg, n_local))
        if cfg.load_balance
        else None
    )
    h_min = np.full(S, np.nan)
    next_lb = np.full(S, cfg.lb_startup_delay if cfg.load_balance else np.inf)
    current_p = np.full((S, N), cfg.subpartitions, dtype=np.int64)
    n_i = n_local.astype(np.float64)

    churn = traces.churn
    alive: np.ndarray | None = None
    if churn is not None:
        prev_row = churn.row_at(np.zeros(S))
        lb_since = np.asarray(churn.boundary_before(prev_row), dtype=np.float64)
    else:
        lb_since = None

    for t in range(T):
        assign = iter_end.copy()
        if churn is not None:
            # liveness sampled once per iteration at assignment time (same
            # convention as the scalar simulator and replay_batch)
            alive = churn.alive_at(assign)
            rows_now = churn.row_at(assign)
            changed = rows_now != prev_row
            if changed.any() and cfg.load_balance:
                # fleet changed: drop the contribution floor so the §6
                # optimizer re-baselines, and re-profile from the boundary
                h_min = np.where(changed, np.nan, h_min)
                lb_since = np.where(
                    changed, churn.boundary_before(rows_now), lb_since
                )
            prev_row = rows_now
            # dead at assignment: the in-flight completion never happens —
            # the worker goes idle with no stale event, no cache write, no
            # profiler sample, no latency attribution
            free_at = np.where(alive, free_at, assign[:, None])
            if cache is not None:
                # clear dead workers' §5 entries; np.nonzero is row-major so
                # within each scenario the clears run in worker order ==
                # interval-start order (the canonical churn float order)
                for s, i in zip(*np.nonzero(~alive)):
                    cache.clear_range(
                        int(s), int(base_start[i]), int(base_stop[i])
                    )
        idle = free_at <= assign[:, None]

        # -- Algorithm-2 alignment for pending repartitions (tentative: the
        # new (p, k) is committed only for workers that actually start) ----
        pend = pending_p >= 0
        if pend.any():
            cand_p = sub_p.copy()
            cand_k = sub_k.copy()
            for s, i in zip(*np.nonzero(pend)):
                p_req = int(min(max(1, pending_p[s, i]), n_local[i]))
                if p_req != sub_p[s, i]:
                    _, k_new = _align(
                        int(n_local[i]), int(sub_p[s, i]), p_req, int(sub_k[s, i])
                    )
                    cand_p[s, i] = p_req
                    cand_k[s, i] = k_new
        else:
            cand_p, cand_k = sub_p, sub_k

        if process_full:
            lo = np.broadcast_to(base_start, (S, N))
            hi = np.broadcast_to(base_stop, (S, N))
        else:
            lo = base_start[None, :] + (cand_k - 1) * n_local[None, :] // cand_p
            hi = base_start[None, :] + cand_k * n_local[None, :] // cand_p - 1
        cost = problem.compute_cost_batch(lo, hi) * comp_scale

        # -- event resolution (same algebra as replay_batch) ---------------
        start = np.where(idle, assign[:, None], free_at)
        comm_d, comp_d = traces.task_latency_parts(draw_idx, start, cost)
        finish = task_finish_time(start, comp_d, comm_d)
        if churn is None:
            tau_w = np.partition(finish, w_wait - 1, axis=1)[:, w_wait - 1]
        else:
            # dead workers never contribute finish times; wait for
            # min(w, #alive) of the living fleet (sort+gather picks the same
            # element as partition, so all-alive stays bit-identical)
            finish_eff = np.where(alive, finish, np.inf)
            w_eff = np.minimum(w_wait, alive.sum(axis=1))
            tau_w = np.sort(finish_eff, axis=1)[np.arange(S), w_eff - 1]
        if margin_eff > 0.0:
            deadline = margin_deadline(tau_w, assign, margin_eff)
        else:
            deadline = tau_w
        started = idle | (free_at <= deadline[:, None])
        if churn is not None:
            started &= alive
        fresh = started & (finish <= deadline[:, None])
        stale_done = (~idle) & (free_at <= deadline[:, None])
        fresh_counts[:, t] = fresh.sum(axis=1)

        stale_ev = np.where(stale_done, free_at, -np.inf)
        fresh_ev = np.where(fresh, finish, -np.inf)
        iter_end = np.maximum(
            np.maximum(stale_ev.max(axis=1), fresh_ev.max(axis=1)), tau_w
        )
        times[:, t] = iter_end

        st_s, st_w = np.nonzero(stale_done)
        f_s, f_w = np.nonzero(fresh)
        # latency attribution by the task's own iteration (RunHistory)
        lat_matrix[st_s, flight_titer[st_s, st_w], st_w] = (
            flight_comp[st_s, st_w] + flight_comm[st_s, st_w]
        )
        lat_matrix[f_s, t, f_w] = comp_d[f_s, f_w] + comm_d[f_s, f_w]

        # -- §6.1 profiler feed (before flight state is overwritten): one
        # task-slot sample per observed completion, read back through the
        # shared jittable window-moments kernel -----------------------------
        if cfg.load_balance:
            lbbuf.record(
                st_s,
                st_w,
                flight_titer[st_s, st_w],
                free_at[st_s, st_w],
                free_at[st_s, st_w] - flight_assigned[st_s, st_w],
                flight_comp[st_s, st_w],
            )
            lbbuf.record(
                f_s,
                f_w,
                np.full(f_s.size, t, np.int64),
                finish[f_s, f_w],
                finish[f_s, f_w] - assign[f_s],
                comp_d[f_s, f_w],
            )

        # -- batched subgradient evaluation --------------------------------
        # dsag integrates stale results, so every started task's value is
        # eventually consumed; the other methods only ever use fresh values
        if cfg.name == "dsag":
            need = started
        elif needs_values:
            need = fresh
        else:  # coded recomputes the exact gradient; task values are unused
            need = np.zeros_like(fresh)
        val_index = np.full((S, N), -1, dtype=np.int64)
        vals: np.ndarray | None = None
        if need.any():
            # one masked-width dispatch for the whole mixed-width task batch
            # (bit-identical to per-width bucketing — pinned by tests)
            v_s, v_w = np.nonzero(need)
            val_index[v_s, v_w] = np.arange(v_s.size)
            vals = problem.subgradient_blocks_masked(
                V[v_s], lo[v_s, v_w], hi[v_s, v_w]
            )

        # -- cache / gradient-accumulator updates in event-time order ------
        if cfg.uses_cache:
            if cfg.accepts_stale:
                ev_s = np.concatenate([st_s, f_s])
                ev_w = np.concatenate([st_w, f_w])
                ev_time = np.concatenate([free_at[st_s, st_w], finish[f_s, f_w]])
                ev_lo = np.concatenate([flight_lo[st_s, st_w], lo[f_s, f_w]])
                ev_hi = np.concatenate([flight_hi[st_s, st_w], hi[f_s, f_w]])
                ev_iter = np.concatenate(
                    [flight_titer[st_s, st_w], np.full(f_s.size, t, np.int64)]
                )
                n_stale = st_s.size
            else:  # sag: fresh results only
                ev_s, ev_w = f_s, f_w
                ev_time = finish[f_s, f_w]
                ev_lo, ev_hi = lo[f_s, f_w], hi[f_s, f_w]
                ev_iter = np.full(f_s.size, t, np.int64)
                n_stale = 0
            if ev_s.size:
                if n_stale:
                    ev_vals = np.concatenate(
                        [
                            flight_val[ev_s[:n_stale], ev_w[:n_stale]],
                            vals[val_index[ev_s[n_stale:], ev_w[n_stale:]]],
                        ]
                    )
                else:
                    ev_vals = vals[val_index[ev_s, ev_w]]
                # time-ordered masked scatters instead of a per-event loop
                # (per-scenario §5 semantics preserved bit for bit)
                order = np.argsort(ev_time, kind="stable")
                cache.insert_events(
                    ev_s[order],
                    ev_lo[order],
                    ev_hi[order],
                    ev_iter[order],
                    ev_vals[order],
                )
        elif cfg.name in ("gd", "sgd"):
            grad_acc = np.zeros((S,) + vshape, dtype=np.float64)
            covered = np.zeros(S, dtype=np.int64)
            if f_s.size:
                order = np.argsort(finish[f_s, f_w], kind="stable")
                os_, ow_ = f_s[order], f_w[order]
                ranks = scenario_ranks(os_)
                for r in range(int(ranks.max()) + 1):
                    sel = ranks == r  # <= one event per scenario: masked add
                    grad_acc[os_[sel]] += vals[val_index[os_[sel], ow_[sel]]]
            np.add.at(covered, f_s, hi[f_s, f_w] - lo[f_s, f_w] + 1)

        # -- commit worker state for started tasks --------------------------
        sub_p = np.where(started, cand_p, sub_p)
        if process_full:
            sub_k = np.where(started, cand_k, sub_k)
        else:
            sub_k = np.where(started, cand_k % cand_p + 1, sub_k)
        pending_p = np.where(started, -1, pending_p)
        free_at = np.where(started, finish, free_at)
        draw_idx += started
        flight_lo = np.where(started, lo, flight_lo)
        flight_hi = np.where(started, hi, flight_hi)
        flight_titer = np.where(started, t, flight_titer)
        flight_comp = np.where(started, comp_d, flight_comp)
        flight_comm = np.where(started, comm_d, flight_comm)
        flight_assigned = np.where(started, assign[:, None], flight_assigned)
        if cfg.name == "dsag" and vals is not None:
            if flight_val is None:
                flight_val = np.zeros((S, N) + vshape, dtype=vals.dtype)
            v_s, v_w = np.nonzero(need)
            flight_val[v_s, v_w] = vals

        # -- iterate update -------------------------------------------------
        if cfg.uses_cache:
            xi = np.maximum(cache.coverage, 1e-12)
            grad = cache.sums / xi.reshape(bshape) + problem.regularizer_grad(V)
        elif cfg.name == "coded":
            g = problem.subgradient_blocks(
                V, np.ones(S, np.int64), np.full(S, n, np.int64)
            ).astype(np.float64)
            grad = g + problem.regularizer_grad(V)
        elif cfg.name == "gd":
            grad = grad_acc + problem.regularizer_grad(V)
        else:  # sgd: scale the partial sum by observed coverage
            xi = np.maximum(covered / n, 1e-12)
            grad = grad_acc / xi.reshape(bshape) + problem.regularizer_grad(V)
        V = problem.project_batch((V - cfg.eta * grad).astype(V.dtype, copy=False))

        if t % eval_every == 0 or t == T - 1:
            # one [S] JAX dispatch (the scalar simulator delegates to the
            # same kernel at S = 1, so the bits agree)
            subopt[:, t] = problem.suboptimality_batch(V)

        # -- load balancing (batched §6 background loop) --------------------
        if cfg.load_balance:
            due = iter_end >= next_lb
            if due.any():
                e_cm, v_cm, e_cp, v_cp, cnt = lbbuf.moments(
                    iter_end, since=lb_since
                )
                ready = cnt >= 1
                if churn is not None:
                    # dead workers can't produce samples — don't wait on them
                    ready = ready | ~alive
                ready = ready.all(axis=1)
                next_lb = np.where(due, iter_end + cfg.lb_interval, next_lb)
                act = due & ready
                if act.any():
                    inputs = make_optimizer_inputs(
                        e_cm, v_cm, e_cp, v_cp,
                        np.broadcast_to(n_i, (S, N)),
                        w_wait,
                        cfg.margin,
                    )
                    p_new, h_min, _, publish = lb.update_batch(
                        current_p, inputs, h_min, active=act, alive=alive
                    )
                    for s in np.flatnonzero(publish):
                        changed = p_new[s] != current_p[s]
                        pending_p[s, changed] = p_new[s, changed]
                        current_p[s] = p_new[s]
                        repartition_events[s].append(float(iter_end[s]))

    return ConvergenceBatchResult(
        times=times,
        suboptimality=subopt,
        fresh_counts=fresh_counts,
        per_worker_latency=lat_matrix,
        repartition_events=repartition_events,
        evictions=cache.evictions.copy() if cache is not None else np.zeros(S, np.int64),
        rejected_stale=(
            cache.rejected_stale.copy() if cache is not None else np.zeros(S, np.int64)
        ),
    )


# ---------------------------------------------------------------------------
# Convergence-sweep driver (Figs. 10-12 made cheap enough for CI)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ConvergenceSweepOutcome:
    """All methods' batched convergence runs on one shared trace draw."""

    results: dict[str, ConvergenceBatchResult]
    methods: dict[str, MethodConfig]
    traces: FleetTraces
    problem: FiniteSumProblem
    cluster: ClusterLatencyModel
    num_iterations: int
    cost_scale: float
    eval_every: int
    seed: int
    engine_seconds: float

    def time_to_gap(self, method: str, gap: float) -> np.ndarray:
        return self.results[method].time_to_gap(gap)


def default_convergence_methods(
    n_workers: int,
    *,
    w: int,
    eta: float = 0.25,
    subpartitions: int = 10,
    load_balance_dsag: bool = False,
) -> dict[str, MethodConfig]:
    """The paper's §7 time-to-gap columns: DSAG, SAG (w = N), SGD, coded."""
    methods = {
        "dsag": MethodConfig(
            name="dsag", w=w, eta=eta, subpartitions=subpartitions,
            load_balance=load_balance_dsag,
        ),
        "sag": MethodConfig(name="sag", w=n_workers, eta=eta,
                            subpartitions=subpartitions),
        "sgd": MethodConfig(name="sgd", w=w, eta=eta, subpartitions=subpartitions),
        "coded": MethodConfig(name="coded", w=0, eta=1.0,
                              subpartitions=subpartitions),
    }
    return methods


def run_convergence_sweep(
    problem: FiniteSumProblem,
    cluster: ClusterLatencyModel,
    methods: dict[str, MethodConfig],
    *,
    n_scenarios: int = 10,
    num_iterations: int = 100,
    cost_scale: float = 1.0,
    eval_every: int = 1,
    regime=None,
    burst_rate: float | None = None,
    burst_factor_mean: float | None = None,
    burst_duration_mean: float | None = None,
    seed: int = 0,
    engine: EngineConfig | None = None,
) -> ConvergenceSweepOutcome:
    """Run every method over one shared scenario batch (common random
    numbers: all methods see the same latency draws, like the paper's
    paired comparisons on one cluster).

    ``regime`` is an optional :class:`~repro.experiments.grid.BurstRegime`
    (the iteration-time grid's burst environments); explicit ``burst_*``
    keywords override its fields.  ``engine`` (an
    :class:`~repro.experiments.engine.EngineConfig` or a deprecated legacy
    string) is forwarded to :func:`run_convergence_batch` per method.
    """
    if regime is not None:
        burst_rate = regime.rate if burst_rate is None else burst_rate
        burst_factor_mean = (
            regime.factor_mean if burst_factor_mean is None else burst_factor_mean
        )
        burst_duration_mean = (
            regime.duration_mean if burst_duration_mean is None else burst_duration_mean
        )
    traces = sample_fleet(
        cluster,
        n_scenarios,
        num_iterations,
        burst_rate=burst_rate,
        burst_factor_mean=burst_factor_mean,
        burst_duration_mean=burst_duration_mean,
        seed=seed + 1,
    )
    eng = as_engine_config(engine, _stacklevel=3)
    results: dict[str, ConvergenceBatchResult] = {}
    t0 = time.perf_counter()
    for name, cfg in methods.items():
        results[name] = run_convergence_batch(
            problem,
            traces,
            cfg,
            num_iterations,
            cost_scale=cost_scale,
            eval_every=eval_every,
            seed=seed,
            engine=eng,
        )
    engine_seconds = time.perf_counter() - t0
    return ConvergenceSweepOutcome(
        results=results,
        methods=dict(methods),
        traces=traces,
        problem=problem,
        cluster=cluster,
        num_iterations=num_iterations,
        cost_scale=cost_scale,
        eval_every=eval_every,
        seed=seed,
        engine_seconds=engine_seconds,
    )


#: Calibrated parameters of the paper-scale PCA convergence sweep (Figs.
#: 10-12 at the genomics matrix's actual row count).  ``gap=1e-4`` sits in
#: the regime where ignoring-stragglers SGD has stalled but the
#: cache-based methods keep converging — the paper's reason for DSAG —
#: while DSAG reaches it ~2.5-3x before SAG and the coded bound
#: (ordering pinned by the committed ``BENCH_convergence.json``).
PAPER_SCALE_PCA = dict(
    n_rows=50_000,
    n_cols=96,
    k=3,
    n_workers=50,
    subpartitions=5,
    w=40,
    eta=0.9,
    gap=1e-4,
    n_scenarios=4,
    num_iterations=80,
    eval_every=4,
)


def make_paper_scale_pca(
    n_rows: int = PAPER_SCALE_PCA["n_rows"],
    n_cols: int = PAPER_SCALE_PCA["n_cols"],
    k: int = PAPER_SCALE_PCA["k"],
    seed: int = 0,
):
    """The n≈50k synthetic genomics matrix as a :class:`PCAProblem`."""
    from repro.core.problems import PCAProblem, make_genomics_like_matrix

    return PCAProblem(X=make_genomics_like_matrix(n_rows, n_cols, seed=seed), k=k)


def paper_scale_pca_sweep(
    *,
    scale: float = 1.0,
    seed: int = 0,
    regime=None,
    engine: EngineConfig | None = None,
    n_scenarios: int | None = None,
) -> tuple[ConvergenceSweepOutcome, float]:
    """Run the calibrated paper-scale PCA convergence sweep.

    ``scale`` shrinks the grid uniformly (rows, iterations, scenarios) for
    smoke tests; 1.0 is the benchmark configuration.  ``n_scenarios``
    overrides the scenario count alone (the ``pca_grid_sharded`` bench
    column runs 10x the calibrated grid through the sharded scan).
    Returns ``(outcome, gap)`` with ``gap`` the calibrated time-to-gap
    threshold.
    """
    from repro.experiments.grid import HEAVY_BURSTS
    from repro.latency.model import make_heterogeneous_cluster

    p = PAPER_SCALE_PCA
    n_rows = max(int(p["n_rows"] * scale), 512)
    n_iter = max(int(p["num_iterations"] * scale), 10)
    n_scen = (
        int(n_scenarios)
        if n_scenarios is not None
        else max(int(p["n_scenarios"] * scale), 2)
    )
    prob = make_paper_scale_pca(n_rows=n_rows, seed=seed)
    N, sp = p["n_workers"], p["subpartitions"]
    c_task = prob.compute_cost(1, max(prob.num_samples // (N * sp), 1))
    cluster = make_heterogeneous_cluster(N, seed=seed, burst_rate=0.0, load_unit=c_task)
    methods = default_convergence_methods(
        N, w=p["w"], eta=p["eta"], subpartitions=sp
    )
    outcome = run_convergence_sweep(
        prob,
        cluster,
        methods,
        n_scenarios=n_scen,
        num_iterations=n_iter,
        eval_every=p["eval_every"],
        regime=regime if regime is not None else HEAVY_BURSTS,
        seed=seed,
        engine=engine,
    )
    return outcome, float(p["gap"])


def scalar_convergence_run(
    outcome: ConvergenceSweepOutcome, method: str, scenario: int
) -> RunHistory:
    """Ground truth: one scenario through the scalar TrainingSimulator."""
    sim = TrainingSimulator(
        outcome.problem,
        outcome.cluster,
        outcome.methods[method],
        cost_scale=outcome.cost_scale,
        eval_every=outcome.eval_every,
        seed=outcome.seed,
        latency_source=TraceLatencySource(outcome.traces, scenario),
    )
    return sim.run(outcome.num_iterations)


def scalar_convergence_seconds(
    outcome: ConvergenceSweepOutcome,
    *,
    methods: Sequence[str] | None = None,
    max_scenarios: int | None = None,
) -> tuple[float, float]:
    """Wall-clock of the same grid through the scalar training simulator.

    Replays ``max_scenarios`` scenarios (all by default) of each method
    through :class:`TrainingSimulator` on the same traces.  Returns
    ``(measured_seconds, extrapolated_seconds)`` where the extrapolation
    scales the measured subset up to the full grid — the honest baseline
    when the full scalar grid would take minutes.
    """
    names = list(methods) if methods is not None else list(outcome.methods)
    S = outcome.traces.num_scenarios
    S_run = S if max_scenarios is None else min(max_scenarios, S)
    t0 = time.perf_counter()
    for name in names:
        for s in range(S_run):
            scalar_convergence_run(outcome, name, s)
    measured = time.perf_counter() - t0
    return measured, measured * (S / max(S_run, 1))
