"""Batched scenario sweeps over the §3/§4.2 simulated fleet (paper §7).

The subsystem has five layers (see ``docs/ARCHITECTURE.md`` for how they
relate to the scalar simulators):

* :mod:`repro.experiments.sweep` — the vectorized event-dynamics engine
  (bit-exact replay of the scalar simulator over a scenario batch) plus the
  fully-vectorized fast path for queue-feedback-free methods;
* :mod:`repro.experiments.convergence` — the batched *convergence* engine:
  the full DSAG/SAG/SGD update rule (gradient cache, coverage scaling,
  §5.1 margin, stale integration, §6 load balancing) over all scenarios at
  once, bit-exact against the scalar ``TrainingSimulator``;
* :mod:`repro.experiments.fused` — the fused ``jax.lax.scan`` convergence
  engine: the whole iteration body as one jittable function, bit-exact
  against the host engine, optionally sharded over the scenario axis
  (execution selected by :class:`~repro.experiments.engine.EngineConfig`);
* :mod:`repro.experiments.grid` — the (seeds x methods x w x regimes) driver
  with common-random-number trace sharing per regime;
* :mod:`repro.experiments.results` — ordering verdicts, the profiler feed,
  and the ``BENCH_sweep.json`` / ``BENCH_convergence.json`` artifacts.
"""

from repro.experiments.grid import (
    CALM,
    DEFAULT_REGIMES,
    HEAVY_BURSTS,
    PAPER_BURSTS,
    BurstRegime,
    MethodSpec,
    SweepOutcome,
    SweepRow,
    default_methods,
    run_sweep,
    scalar_sweep_seconds,
)
from repro.experiments.results import (
    feed_profiler,
    outcome_to_dict,
    paper_ordering,
    write_bench_sweep,
)
from repro.experiments.sweep import (
    BatchedRunResult,
    replay_batch,
    scalar_reference,
    scalar_sync_reference,
    synchronous_times_batch,
)
from repro.experiments.convergence import (
    PAPER_SCALE_PCA,
    ConvergenceBatchResult,
    ConvergenceSweepOutcome,
    default_convergence_methods,
    make_paper_scale_pca,
    paper_scale_pca_sweep,
    run_convergence_batch,
    run_convergence_sweep,
    scalar_convergence_run,
    scalar_convergence_seconds,
)
from repro.experiments.engine import (
    CAP_ACTIVE_SET,
    CAP_OK,
    CAP_TILED,
    EngineCapability,
    EngineCapabilityError,
    EngineConfig,
    as_engine_config,
)
from repro.experiments.fused import run_convergence_scan, scan_capability
from repro.experiments.results import (
    convergence_ordering,
    convergence_payload,
    write_bench_convergence,
)

__all__ = [
    "BatchedRunResult",
    "BurstRegime",
    "CALM",
    "CAP_ACTIVE_SET",
    "CAP_OK",
    "CAP_TILED",
    "ConvergenceBatchResult",
    "ConvergenceSweepOutcome",
    "DEFAULT_REGIMES",
    "EngineCapability",
    "EngineCapabilityError",
    "EngineConfig",
    "HEAVY_BURSTS",
    "MethodSpec",
    "PAPER_BURSTS",
    "PAPER_SCALE_PCA",
    "SweepOutcome",
    "SweepRow",
    "as_engine_config",
    "convergence_ordering",
    "convergence_payload",
    "default_convergence_methods",
    "default_methods",
    "feed_profiler",
    "make_paper_scale_pca",
    "outcome_to_dict",
    "paper_ordering",
    "paper_scale_pca_sweep",
    "replay_batch",
    "run_convergence_batch",
    "run_convergence_scan",
    "run_convergence_sweep",
    "scan_capability",
    "run_sweep",
    "scalar_convergence_run",
    "scalar_convergence_seconds",
    "scalar_reference",
    "scalar_sweep_seconds",
    "scalar_sync_reference",
    "synchronous_times_batch",
    "write_bench_convergence",
    "write_bench_sweep",
]
