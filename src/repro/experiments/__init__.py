"""Batched scenario sweeps over the §3/§4.2 simulated fleet (paper §7).

The subsystem has three layers:

* :mod:`repro.experiments.sweep` — the vectorized event-dynamics engine
  (bit-exact replay of the scalar simulator over a scenario batch) plus the
  fully-vectorized fast path for queue-feedback-free methods;
* :mod:`repro.experiments.grid` — the (seeds x methods x w x regimes) driver
  with common-random-number trace sharing per regime;
* :mod:`repro.experiments.results` — ordering verdicts, the profiler feed,
  and the ``BENCH_sweep.json`` artifact.
"""

from repro.experiments.grid import (
    CALM,
    DEFAULT_REGIMES,
    HEAVY_BURSTS,
    PAPER_BURSTS,
    BurstRegime,
    MethodSpec,
    SweepOutcome,
    SweepRow,
    default_methods,
    run_sweep,
    scalar_sweep_seconds,
)
from repro.experiments.results import (
    feed_profiler,
    outcome_to_dict,
    paper_ordering,
    write_bench_sweep,
)
from repro.experiments.sweep import (
    BatchedRunResult,
    replay_batch,
    scalar_reference,
    scalar_sync_reference,
    synchronous_times_batch,
)

__all__ = [
    "BatchedRunResult",
    "BurstRegime",
    "CALM",
    "DEFAULT_REGIMES",
    "HEAVY_BURSTS",
    "MethodSpec",
    "PAPER_BURSTS",
    "SweepOutcome",
    "SweepRow",
    "default_methods",
    "feed_profiler",
    "outcome_to_dict",
    "paper_ordering",
    "replay_batch",
    "run_sweep",
    "scalar_reference",
    "scalar_sweep_seconds",
    "scalar_sync_reference",
    "synchronous_times_batch",
    "write_bench_sweep",
]
