"""Simulated distributed cluster (Tier 3): the paper's coordinator/worker
protocol run in event time over the §3 latency model, with real JAX compute
for every subgradient."""

from repro.cluster.simulator import (
    LatencySource,
    MethodConfig,
    ModelLatencySource,
    RunHistory,
    TraceLatencySource,
    TrainingSimulator,
)

__all__ = [
    "LatencySource",
    "MethodConfig",
    "ModelLatencySource",
    "RunHistory",
    "TraceLatencySource",
    "TrainingSimulator",
]
