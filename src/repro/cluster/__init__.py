"""Simulated distributed cluster (Tier 3): the paper's coordinator/worker
protocol run in event time over the §3 latency model, with real JAX compute
for every subgradient."""

from repro.cluster.simulator import (
    MethodConfig,
    TrainingSimulator,
    RunHistory,
)

__all__ = ["MethodConfig", "TrainingSimulator", "RunHistory"]
