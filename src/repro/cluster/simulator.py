"""Event-time simulation of the paper's distributed methods (§5, §7).

Workers follow the two-state busy/idle model of §4.2 with a length-1 FILO
task queue; the coordinator implements GD, ignoring-stragglers SGD, SAG
(w <= N), DSAG (stale integration + 2% margin), and the idealized-MDS coded
computing bound of §7.1.  Per-task *latency* is sampled from the §3 gamma
model; per-task *values* are real subgradients computed with JAX.

Load balancing (§6) plugs in as: profiler samples recorded at each task
completion -> Algorithm-1 optimizer invoked periodically in the background
(simulated as an interval + a startup delay matching the paper's 0.5-7 s
first-solution time) -> new subpartition counts shipped with the next task ->
Algorithm-2 alignment at the worker.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections.abc import Callable

import numpy as np

from repro.core.gradient_cache import GradientCache
from repro.core.problems import FiniteSumProblem
from repro.latency.model import (
    ClusterLatencyModel,
    FleetTraces,
    SlowdownRemoval,
    churn_from_removals,
)
from repro.latency.profiler import LatencyProfiler, LatencySample, MomentBuffer
from repro.lb.optimizer import LoadBalanceOptimizer, OptimizerInputs
from repro.lb.partitioner import Subpartitioner, build_p_ladder, p_start, p_stop


# ---------------------------------------------------------------------------
# Method semantics shared by this scalar simulator and the batched
# convergence engine (repro.experiments.convergence).  Both paths call these
# helpers so the float expressions (and therefore every bit of the replayed
# dynamics) cannot drift between the two implementations.
# ---------------------------------------------------------------------------


def task_finish_time(start, comp, comm):
    """Completion time of a task: ``start + (comp + comm)``.

    The grouping matters for bit-exact replay: both engines must add the
    two latency components together before adding the start time.
    """
    return start + (comp + comm)


def margin_deadline(tau_w, iter_start, margin):
    """Paper §5.1: keep collecting ``margin`` longer than the time the
    first w fresh results took this iteration."""
    return tau_w + margin * (tau_w - iter_start)


def effective_w(config: "MethodConfig", num_workers: int) -> int:
    """The wait-for-w actually used by a method on an N-worker fleet."""
    if config.name == "gd":
        return num_workers
    if config.name == "coded":
        return int(math.ceil(config.code_rate * num_workers))
    return min(config.w if config.w > 0 else num_workers, num_workers)


def lb_ladder_for(config: "MethodConfig", n_local) -> tuple:
    """The §6 p-ladder of a run: every engine must climb the same rungs.

    Built from the configured initial subpartition count and the largest
    per-worker sample count; the scalar simulator, the batched host
    engine, and the fused scan all construct their optimizer (and, for the
    scan, the pre-allocated cache slot universe) from this one function.
    """
    return build_p_ladder(max(int(config.subpartitions), 1), int(np.max(n_local)))


def make_optimizer_inputs(
    e_comm: np.ndarray,
    v_comm: np.ndarray,
    e_comp: np.ndarray,
    v_comp: np.ndarray,
    samples_per_worker: np.ndarray,
    w: int,
    margin: float,
) -> OptimizerInputs:
    """§6.1 profiler moments -> Algorithm-1 inputs (variance floors applied).

    Accepts ``[N]`` arrays (scalar simulator) or ``[S, N]`` arrays (batched
    engine); the floors are elementwise either way.
    """
    return OptimizerInputs(
        e_comm=np.asarray(e_comm, dtype=np.float64),
        v_comm=np.maximum(np.asarray(v_comm, dtype=np.float64), 1e-18),
        e_comp=np.asarray(e_comp, dtype=np.float64),
        v_comp=np.maximum(np.asarray(v_comp, dtype=np.float64), 1e-18),
        samples_per_worker=np.asarray(samples_per_worker, dtype=np.float64),
        w=w,
        margin=margin,
    )


class LatencySource:
    """Where per-task (comp, comm) latencies come from.

    The simulator is agnostic about whether latencies are sampled live from
    the §3 gamma/burst model or replayed from a pre-sampled trace; both
    implement ``task_latency``.
    """

    def task_latency(self, worker: int, cost: float, now: float) -> tuple[float, float]:
        """Return ``(comp_latency, comm_latency)`` of one task."""
        raise NotImplementedError


class ModelLatencySource(LatencySource):
    """Live sampling from a :class:`ClusterLatencyModel` (the default).

    Reads the cluster on every draw, so timed events that mutate worker
    state (e.g. §7.2 slowdown removal) keep working.
    """

    def __init__(self, cluster: ClusterLatencyModel):
        self.cluster = cluster

    def task_latency(self, worker: int, cost: float, now: float) -> tuple[float, float]:
        wk = self.cluster.workers[worker]
        comp = wk.sample_comp(cost, self.cluster.rng, now=now)
        comm = wk.sample_comm(self.cluster.rng)
        return comp, comm


class TraceLatencySource(LatencySource):
    """Replay one scenario of pre-sampled :class:`FleetTraces`.

    Each worker consumes its (comm, comp_unit) draw streams sequentially —
    the same consumption order as the batched sweep engine, so a training
    run replayed through this source sees exactly the latencies of the
    corresponding sweep scenario.
    """

    def __init__(self, traces: FleetTraces, scenario: int):
        if not (0 <= scenario < traces.num_scenarios):
            raise ValueError(f"scenario {scenario} out of range")
        self.traces = traces
        self.scenario = scenario
        self._k = np.zeros(traces.num_workers, dtype=np.int64)

    def task_latency(self, worker: int, cost: float, now: float) -> tuple[float, float]:
        k = int(self._k[worker])
        self._k[worker] += 1
        comm, comp = self.traces.scalar_task_latency(
            self.scenario, worker, k, now, cost
        )
        return float(comp), float(comm)


@dataclasses.dataclass
class MethodConfig:
    """One method/configuration of paper §7."""

    name: str  # gd | sgd | sag | dsag | coded
    w: int = 0  # wait-for-w (ignored by gd/coded)
    eta: float = 0.9
    margin: float = 0.02  # post-w extra wait (paper §5.1); dsag/lb methods
    subpartitions: int = 1  # initial p_i (paper: 100 for PCA, 10 for logreg)
    code_rate: float = 45.0 / 49.0  # coded only
    load_balance: bool = False
    lb_interval: float = 1.0  # how often the optimizer publishes (sim s)
    lb_startup_delay: float = 0.5  # first-solution delay (paper: 0.5-7 s)

    def __post_init__(self):
        if self.name not in ("gd", "sgd", "sag", "dsag", "coded"):
            raise ValueError(f"unknown method {self.name}")

    @property
    def uses_cache(self) -> bool:
        return self.name in ("sag", "dsag")

    @property
    def accepts_stale(self) -> bool:
        return self.name == "dsag"

    @property
    def uses_margin(self) -> bool:
        return self.name == "dsag" or self.load_balance


@dataclasses.dataclass
class RunHistory:
    """Convergence trace of one training run.

    ``per_worker_latency[t, i]`` is the total (comp + comm) latency of the
    task worker ``i`` *started for iteration t* — completed results are
    attributed to the task's own iteration ``titer``, not to the iteration
    the coordinator happened to be collecting when the result arrived.  A
    stale DSAG result that took three iterations to come back therefore
    lands in the row it was assigned in (NaN where the worker never started
    that iteration's task, or where the run ended before the result
    returned).  This is the trace the §6.1 profiler view of the fleet is
    judged against; attributing by completion row would smear slow workers'
    latencies onto later iterations.
    """

    times: np.ndarray  # [T] completion time of each iteration (sim s)
    suboptimality: np.ndarray  # [T] gap after each iteration (subsampled = nan)
    fresh_counts: np.ndarray  # [T]
    per_worker_latency: np.ndarray  # [T, N] latency of the task started at t
    repartition_events: list[float]  # sim times at which a new p was published
    evictions: int = 0
    rejected_stale: int = 0
    #: [T, N] bool coordinator decision streams — the Tier-2 pin surface.
    #: mask: worker delivered a fresh (titer == t) result within iteration
    #: t's collection window; flush: a stale result was accepted into the
    #: gradient cache; evict: a death cleared the worker's cache entry.
    #: These are the exact step inputs the live ``dsag_update`` would see,
    #: asserted equal to ``DeadlineController.step_inputs`` streams by
    #: ``tests/test_live_validation.py``.
    mask_stream: np.ndarray | None = None
    flush_stream: np.ndarray | None = None
    evict_stream: np.ndarray | None = None

    def time_to_gap(self, gap: float) -> float:
        """First sim time at which suboptimality <= gap (inf if never)."""
        ok = np.where(np.nan_to_num(self.suboptimality, nan=np.inf) <= gap)[0]
        return float(self.times[ok[0]]) if len(ok) else float("inf")


@dataclasses.dataclass
class _Task:
    iteration: int
    iterate: np.ndarray
    assigned_at: float


class _SimWorker:
    """Two-state worker with a length-1 FILO task queue (paper §4.2)."""

    def __init__(self, idx: int, sub: Subpartitioner):
        self.idx = idx
        self.sub = sub
        self.busy_until = 0.0
        self.queued: _Task | None = None
        self.pending_p: int | None = None  # LB update applied at next task

    def start_task(
        self,
        task: _Task,
        now: float,
        problem: FiniteSumProblem,
        latency_source: LatencySource,
        process_full_block: bool,
        comp_scale: float,
    ) -> tuple[float, tuple]:
        """Begin processing; returns (finish_time, result tuple)."""
        if self.pending_p is not None:
            self.sub.repartition(self.pending_p)  # Algorithm-2 alignment
            self.pending_p = None
        if process_full_block:
            interval = (self.sub.base_start, self.sub.base_stop)
        else:
            interval = self.sub.next_interval_and_advance()
        start, stop = interval
        value = problem.subgradient(task.iterate, start, stop)
        cost = problem.compute_cost(start, stop) * comp_scale
        comp_lat, comm_lat = latency_source.task_latency(self.idx, cost, now)
        finish = task_finish_time(now, comp_lat, comm_lat)
        self.busy_until = finish
        result = (self.idx, interval, task.iteration, value, comp_lat, comm_lat, task.assigned_at)
        return finish, result


class TrainingSimulator:
    """Run one method to completion and record its convergence trace."""

    def __init__(
        self,
        problem: FiniteSumProblem,
        cluster: ClusterLatencyModel,
        config: MethodConfig,
        *,
        cost_scale: float = 1.0,
        eval_every: int = 1,
        timed_events: list[tuple[float, Callable]] | None = None,
        seed: int = 0,
        latency_source: LatencySource | None = None,
    ):
        self.problem = problem
        self.cluster = cluster
        self.config = config
        self.cost_scale = cost_scale
        self.eval_every = eval_every
        #: live model sampling by default; pass a TraceLatencySource to replay
        #: a pre-sampled sweep scenario through the full training simulator.
        self.latency_source = latency_source or ModelLatencySource(cluster)
        if timed_events and isinstance(self.latency_source, TraceLatencySource):
            if all(isinstance(fn, SlowdownRemoval) for _, fn in timed_events):
                # the §7.2 artificial scenario (and any pure slowdown-removal
                # schedule) has an exact trace-replay equivalent: fold the
                # removals into a ChurnSchedule whose rows replace the static
                # slowdown field at each task's start time
                traces = self.latency_source.traces
                if traces.churn is not None:
                    raise ValueError(
                        "traces already carry a churn schedule; fold the "
                        "slowdown removals into it instead of passing "
                        "timed_events"
                    )
                removals = [
                    SlowdownRemoval(time=ev_t, workers=fn.workers)
                    for ev_t, fn in timed_events
                ]
                self.latency_source.traces = traces.with_churn(
                    churn_from_removals(traces.slowdown, removals)
                )
                timed_events = []
            else:
                # opaque timed events mutate the cluster model, which a
                # pre-sampled trace never re-reads — silently ignoring them
                # would fake the §7.2 scenarios, so refuse the combination
                # (structured SlowdownRemoval events take the churn path
                # above)
                raise ValueError(
                    "timed_events require live model sampling; a replayed "
                    "trace cannot react to cluster mutations (use "
                    "SlowdownRemoval events or traces.with_churn for the "
                    "replayable §7.2 path)"
                )
        if (
            isinstance(self.latency_source, TraceLatencySource)
            and self.latency_source.traces.num_workers != cluster.num_workers
        ):
            raise ValueError(
                f"trace has {self.latency_source.traces.num_workers} workers "
                f"but the cluster has {cluster.num_workers}"
            )
        #: (sim_time, fn(cluster)) hooks, e.g. the §7.2 artificial
        #: slowdown-removal at t=1 s
        self.timed_events = sorted(timed_events or [], key=lambda e: e[0])
        self.seed = seed
        n = problem.num_samples
        N = cluster.num_workers
        self.workers = [
            _SimWorker(
                i,
                Subpartitioner(
                    base_start=p_start(n, N, i + 1),
                    base_stop=p_stop(n, N, i + 1),
                    p=config.subpartitions,
                ),
            )
            for i in range(N)
        ]
        self.profiler = LatencyProfiler(N, window=10.0)
        if config.load_balance:
            n_local = np.array([w.sub.n_local for w in self.workers])
            self.lb_optimizer = LoadBalanceOptimizer(
                seed=seed, ladder=lb_ladder_for(config, n_local)
            )
        else:
            self.lb_optimizer = None
        self._next_lb_time = config.lb_startup_delay if config.load_balance else math.inf
        self._lb_buffer: MomentBuffer | None = None  # allocated per run()

    # -- per-method gradient-estimate assembly -----------------------------
    def _effective_w(self) -> int:
        return effective_w(self.config, self.cluster.num_workers)

    def run(self, num_iterations: int) -> RunHistory:
        cfg = self.config
        problem = self.problem
        N = self.cluster.num_workers
        n = problem.num_samples
        w_wait = self._effective_w()
        comp_scale = self.cost_scale * (
            1.0 / cfg.code_rate if cfg.name == "coded" else 1.0
        )
        process_full = cfg.name in ("gd", "coded")

        V = problem.init(self.seed)
        cache = (
            GradientCache(n, np.zeros_like(V, dtype=np.float64))
            if cfg.uses_cache
            else None
        )

        self._lb_buffer = (
            MomentBuffer(1, N, num_iterations) if cfg.load_balance else None
        )
        #: churn comes in through the replayed traces (the live path models
        #: fleet changes as timed_events mutating the cluster instead)
        churn = (
            self.latency_source.traces.churn
            if isinstance(self.latency_source, TraceLatencySource)
            else None
        )
        now = 0.0
        # (finish, seq, generation, result); a worker's generation is bumped
        # when a death discards its in-flight task, invalidating the queued
        # heap event without disturbing the (finish, seq) pop order
        heap: list[tuple[float, int, int, tuple]] = []
        seq = 0
        gen = np.zeros(N, dtype=np.int64)
        times = np.zeros(num_iterations)
        subopt = np.full(num_iterations, np.nan)
        fresh_counts = np.zeros(num_iterations, dtype=np.int64)
        lat_matrix = np.full((num_iterations, N), np.nan)
        mask_stream = np.zeros((num_iterations, N), dtype=bool)
        flush_stream = np.zeros((num_iterations, N), dtype=bool)
        evict_stream = np.zeros((num_iterations, N), dtype=bool)
        repartition_events: list[float] = []
        event_ptr = 0
        current_p = np.full(N, cfg.subpartitions, dtype=np.int64)
        prev_row = int(churn.row_at(now)) if churn is not None else 0
        lb_since = float(churn.boundary_before(prev_row)) if churn is not None else None

        for t in range(num_iterations):
            # fire timed environment events (e.g. §7.2 slowdown removal)
            while event_ptr < len(self.timed_events) and self.timed_events[event_ptr][0] <= now:
                self.timed_events[event_ptr][1](self.cluster)
                event_ptr += 1

            if churn is None:
                alive = None
                w_eff = w_wait
            else:
                # liveness sampled once per iteration at assignment time
                alive = churn.alive_at(now)
                row = int(churn.row_at(now))
                if row != prev_row:
                    # fleet changed: drop the contribution floor so the §6
                    # optimizer re-baselines, and re-profile from the boundary
                    if self.lb_optimizer is not None:
                        self.lb_optimizer.h_min = None
                    lb_since = float(churn.boundary_before(row))
                    prev_row = row
                for i, wk in enumerate(self.workers):
                    if not alive[i]:
                        if wk.busy_until > now or wk.queued is not None:
                            # dead at assignment: the in-flight completion
                            # never happens and the queued task is dropped
                            gen[i] += 1
                            wk.busy_until = now
                            wk.queued = None
                        if cache is not None:
                            # canonical clear order: worker index ascending ==
                            # interval-start ascending (base ranges are
                            # disjoint and worker-ordered); idempotent
                            removed = cache.clear_range(
                                wk.sub.base_start, wk.sub.base_stop
                            )
                            if removed:
                                evict_stream[t, i] = True
                w_eff = min(w_wait, int(alive.sum()))

            task = _Task(iteration=t, iterate=V, assigned_at=now)
            for wk in self.workers:
                if alive is not None and not alive[wk.idx]:
                    continue  # dead workers start nothing, consume no draws
                if wk.busy_until <= now:
                    fin, result = wk.start_task(
                        task, now, problem, self.latency_source, process_full, comp_scale
                    )
                    heapq.heappush(heap, (fin, seq, int(gen[wk.idx]), result))
                    seq += 1
                else:
                    wk.queued = task

            fresh = 0
            fresh_values: list[tuple[tuple[int, int], np.ndarray]] = []  # sgd
            deadline = math.inf
            iter_start = now
            while heap and (fresh < w_eff or heap[0][0] <= deadline):
                fin, sq, g, result = heapq.heappop(heap)
                if g != gen[result[0]]:
                    continue  # discarded by a death event; must not touch `now`
                if fin > deadline:
                    heapq.heappush(heap, (fin, sq, g, result))
                    break
                now = fin
                (widx, interval, titer, value, comp_lat, comm_lat, assigned_at) = result
                wk = self.workers[widx]
                # attribute the latency to the task's own iteration (see
                # RunHistory docstring) — NOT the collection iteration t,
                # which would smear stale DSAG completions onto later rows
                lat_matrix[titer, widx] = comp_lat + comm_lat
                self.profiler.record(
                    LatencySample(
                        worker=widx,
                        t_recorded=now,
                        round_trip=now - assigned_at,
                        compute=comp_lat,
                        load=problem.compute_cost(*interval) * comp_scale,
                    )
                )
                if self._lb_buffer is not None:
                    # task-slot twin of the sample above: the §6 optimizer
                    # reads its moments from here via the shared jittable
                    # kernel (same slots in every engine)
                    self._lb_buffer.record(
                        0, widx, titer, now, now - assigned_at, comp_lat
                    )
                # start queued task immediately (FILO queue of length 1)
                if wk.queued is not None:
                    qt = wk.queued
                    wk.queued = None
                    nfin, nresult = wk.start_task(
                        qt, now, problem, self.latency_source, process_full, comp_scale
                    )
                    heapq.heappush(heap, (nfin, seq, int(gen[widx]), nresult))
                    seq += 1
                else:
                    wk.busy_until = now

                is_fresh = titer == t
                if cfg.uses_cache:
                    if is_fresh or cfg.accepts_stale:
                        inserted = cache.insert(interval[0], interval[1], titer, value)
                        if inserted and not is_fresh:
                            flush_stream[t, widx] = True  # §5 stale flush
                elif is_fresh:  # gd / sgd / coded take fresh results only
                    fresh_values.append((interval, value))
                if is_fresh:
                    mask_stream[t, widx] = True
                    fresh += 1
                    if fresh == w_eff:
                        if cfg.uses_margin and cfg.margin > 0:
                            # paper §5.1: wait 2% longer than the time it took
                            # to collect the w-th fresh result this iteration
                            deadline = margin_deadline(now, iter_start, cfg.margin)
                        else:
                            break

            # ---- iterate update -------------------------------------------
            if cfg.uses_cache:
                xi = max(cache.coverage, 1e-12)
                grad = cache.sum / xi + problem.regularizer_grad(V)
            elif cfg.name == "coded":
                # Idealized MDS bound (§7.1): the exact gradient is recovered
                # from any ceil(rN) results with zero decoding cost — the
                # arrival wait above only determines the *latency*.
                grad = problem.subgradient(V, 1, n).astype(np.float64)
                grad = grad + problem.regularizer_grad(V)
            elif cfg.name == "gd":
                grad = np.zeros_like(V, dtype=np.float64)
                for _, val in fresh_values:
                    grad += val
                grad = grad + problem.regularizer_grad(V)
            else:  # sgd: scale the partial sum by observed coverage
                covered = sum(iv[1] - iv[0] + 1 for iv, _ in fresh_values)
                xi = max(covered / n, 1e-12)
                grad = np.zeros_like(V, dtype=np.float64)
                for _, val in fresh_values:
                    grad += val
                grad = grad / xi + problem.regularizer_grad(V)
            V = problem.project(
                (V - cfg.eta * grad).astype(V.dtype, copy=False)
            )

            times[t] = now
            fresh_counts[t] = fresh
            if t % self.eval_every == 0 or t == num_iterations - 1:
                subopt[t] = problem.suboptimality(V)

            # ---- load balancing (background loop, simulated) ---------------
            if cfg.load_balance and now >= self._next_lb_time:
                published = self._run_load_balancer(
                    now, current_p, w_wait, alive=alive, since=lb_since
                )
                if published is not None:
                    current_p = published
                    repartition_events.append(now)
                self._next_lb_time = now + cfg.lb_interval

        return RunHistory(
            times=times,
            suboptimality=subopt,
            fresh_counts=fresh_counts,
            per_worker_latency=lat_matrix,
            repartition_events=repartition_events,
            evictions=cache.evictions if cache else 0,
            rejected_stale=cache.rejected_stale if cache else 0,
            mask_stream=mask_stream,
            flush_stream=flush_stream,
            evict_stream=evict_stream,
        )

    def _run_load_balancer(
        self,
        now: float,
        current_p: np.ndarray,
        w_wait: int,
        *,
        alive: np.ndarray | None = None,
        since: float | None = None,
    ) -> np.ndarray | None:
        e_comm, v_comm, e_comp, v_comp, cnt = self._lb_buffer.moments(
            np.array([now]),
            since=None if since is None else np.array([since]),
        )
        ready = cnt[0] >= 1
        if alive is not None:
            # dead workers can't produce samples — don't wait on them
            ready = ready | ~alive
        if not ready.all():
            return None  # need at least one window sample per living worker
        n_i = np.array([w.sub.n_local for w in self.workers], dtype=np.float64)
        inputs = make_optimizer_inputs(
            e_comm[0],
            v_comm[0],
            e_comp[0],
            v_comp[0],
            n_i,
            w_wait,
            self.config.margin,
        )
        lb = self.lb_optimizer
        hm = np.array([np.nan if lb.h_min is None else lb.h_min])
        p_new, h_min, last_h, publish = lb.update_batch(
            np.asarray(current_p, np.int64)[None, :],
            inputs.as_batch(),
            hm,
            alive=None if alive is None else np.asarray(alive, bool)[None, :],
        )
        lb.h_min = float(h_min[0])
        lb.last_h = float(last_h[0])
        if not publish[0]:
            return None
        for i, wk in enumerate(self.workers):
            if p_new[0, i] != current_p[i]:
                wk.pending_p = int(p_new[0, i])
        return p_new[0]
