"""Block-wise int8 quantization for gradient caches and collectives.

Used by the DSAG Tier-1 step to (i) store per-group cache/pending slots at
1 byte/element and (ii) compress the FSDP weight all-gather.  Symmetric
per-block scaling: each contiguous block of ``block`` elements along the last
axis shares one bf16 scale (absmax / 127).

The quantizer is exposed as a pair of pure functions over pytrees so it can
sit inside a jitted step; property tests bound the round-trip error at
``absmax / 127 / 2`` per element.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

DEFAULT_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class Quantized:
    """int8 payload + bf16 per-block scales (a pytree node)."""

    q: jnp.ndarray  # int8, shape [..., n]
    scale: jnp.ndarray  # bfloat16, shape [..., n/block]
    block: int

    def tree_flatten(self):
        return (self.q, self.scale), self.block

    @classmethod
    def tree_unflatten(cls, block, leaves):
        return cls(leaves[0], leaves[1], block)


jax.tree_util.register_pytree_node(
    Quantized, Quantized.tree_flatten, Quantized.tree_unflatten
)


def _pad_to_block(x: jnp.ndarray, block: int) -> tuple[jnp.ndarray, int]:
    n = x.shape[-1]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, n


def quantize(x: jnp.ndarray, block: int = DEFAULT_BLOCK) -> Quantized:
    xp, n = _pad_to_block(x.astype(jnp.float32), block)
    shaped = xp.reshape(*xp.shape[:-1], xp.shape[-1] // block, block)
    absmax = jnp.max(jnp.abs(shaped), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(shaped / scale), -127, 127).astype(jnp.int8)
    q = q.reshape(xp.shape)[..., :n]  # store at the original length
    return Quantized(q=q, scale=scale[..., 0].astype(jnp.bfloat16), block=block)


def dequantize(qx: Quantized, dtype=jnp.bfloat16) -> jnp.ndarray:
    q = qx.q
    n = q.shape[-1]
    pad = (-n) % qx.block
    if pad:
        q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    shaped = q.astype(jnp.float32).reshape(
        *q.shape[:-1], q.shape[-1] // qx.block, qx.block
    )
    out = shaped * qx.scale[..., None].astype(jnp.float32)
    return out.reshape(q.shape)[..., :n].astype(dtype)


def quantize_tree(tree: Any, block: int = DEFAULT_BLOCK) -> Any:
    return jax.tree.map(lambda x: quantize(x, block), tree)


def dequantize_tree(tree: Any, dtype=jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda q: dequantize(q, dtype),
        tree,
        is_leaf=lambda x: isinstance(x, Quantized),
    )


def quantization_error_bound(x: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Per-element worst-case |x - deq(quant(x))| = blockwise absmax/254."""
    xp, n = _pad_to_block(x.astype(jnp.float32), block)
    shaped = xp.reshape(*xp.shape[:-1], xp.shape[-1] // block, block)
    absmax = jnp.max(jnp.abs(shaped), axis=-1)
    return absmax / 127.0 / 2.0 + 1e-7
