"""Pure-pytree optimizers: SGD(+momentum), AdamW, Adafactor.

Optax-like ``(init, update)`` pairs without the dependency.  Adafactor uses
factored second moments (row/col statistics) so optimizer state for the
200B+ MoE configs stays ~1 byte-per-param-equivalent instead of 8.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------


def sgd(lr: float, momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
        )
        upd = jax.tree.map(
            lambda m, p: -lr * (m + weight_decay * p.astype(jnp.float32)), mu, params
        )
        return upd, {"mu": mu, "step": state["step"] + 1}

    return Optimizer(init, update)


def adamw(
    lr: float,
    beta1: float = 0.9,
    beta2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        b1c = 1.0 - beta1 ** step.astype(jnp.float32)
        b2c = 1.0 - beta2 ** step.astype(jnp.float32)
        m = jax.tree.map(
            lambda m_, g: beta1 * m_ + (1 - beta1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda v_, g: beta2 * v_ + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        upd = jax.tree.map(
            lambda m_, v_, p: -lr
            * ((m_ / b1c) / (jnp.sqrt(v_ / b2c) + eps) + weight_decay * p.astype(jnp.float32)),
            m, v, params,
        )
        return upd, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def adafactor(
    lr: float,
    decay: float = 0.99,
    eps: float = 1e-30,
    weight_decay: float = 0.0,
    clip_threshold: float = 1.0,
) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern), no first moment."""

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "stats": jax.tree.map(leaf, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(g.shape):
                vr = decay * s["vr"] + (1 - decay) * g2.mean(axis=-1)
                vc = decay * s["vc"] + (1 - decay) * g2.mean(axis=-2)
                r_factor = jax.lax.rsqrt(
                    vr / jnp.clip(vr.mean(axis=-1, keepdims=True), 1e-30)
                )
                c_factor = jax.lax.rsqrt(vc)
                u = g * r_factor[..., None] * c_factor[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = decay * s["v"] + (1 - decay) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr * (u + weight_decay * p.astype(jnp.float32)), new_s

        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state["stats"])
        flat_p = tdef.flatten_up_to(params)
        outs = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        upd = tdef.unflatten([o[0] for o in outs])
        stats = tdef.unflatten([o[1] for o in outs])
        return upd, {"stats": stats, "step": step}

    return Optimizer(init, update)


def make_optimizer(tc: TrainConfig) -> Optimizer:
    if tc.optimizer == "adamw":
        return adamw(tc.learning_rate, tc.beta1, tc.beta2, tc.eps, tc.weight_decay)
    if tc.optimizer == "adafactor":
        return adafactor(tc.learning_rate, weight_decay=tc.weight_decay)
    if tc.optimizer == "sgd":
        return sgd(tc.learning_rate, momentum=tc.beta1, weight_decay=tc.weight_decay)
    raise ValueError(f"unknown optimizer {tc.optimizer}")


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
    )
