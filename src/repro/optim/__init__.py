"""Optimizers + compression."""

from repro.optim.optimizers import (
    Optimizer,
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
    sgd,
)
from repro.optim.compression import (
    Quantized,
    dequantize,
    dequantize_tree,
    quantize,
    quantize_tree,
)
