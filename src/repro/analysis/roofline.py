"""Three-term roofline derivation from a compiled dry-run artifact.

TPU v5e constants (per instruction sheet):
  peak compute 197 TFLOP/s bf16 / chip, HBM 819 GB/s, ICI ~50 GB/s/link.

  compute term    = HLO_FLOPs / peak_flops           (per-device HLO)
  memory term     = HLO_bytes / hbm_bw
  collective term = wire_bytes / link_bw             (ring model, per device)

The dominant term is the bottleneck; roofline fraction for the report is
  max(compute, memory, collective) vs. the ideal compute-only time,
and MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is useful
(remat recompute, MoE capacity slack, head padding all show up here).
"""

from __future__ import annotations

import dataclasses
import math

from repro.analysis.hlo import HloCost, analyze_hlo, sxs_buffer_bytes
from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link (conservative single-link)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collectives: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_device: float
    useful_flops_fraction: float
    step_time_s: float
    mfu: float
    attn_score_bytes: float = 0.0
    memory_s_flash: float = 0.0  # memory term with score traffic fused away

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(
    cfg: ModelConfig, shape: ShapeConfig, num_params: int, active_params: int | None
) -> float:
    """MODEL_FLOPS = 6·N·D for training (N = active params for MoE),
    2·N·D for inference forward passes (D = processed tokens)."""
    n = active_params or num_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def active_params(cfg: ModelConfig, num_params: int) -> int | None:
    """Active parameters per token for MoE models (shared + top-k routed)."""
    if not cfg.num_experts:
        return None
    full_expert = 3 * cfg.d_model * cfg.d_ff_expert  # swiglu
    routed_total = cfg.num_experts * full_expert * cfg.num_layers
    routed_active = cfg.top_k * full_expert * cfg.num_layers
    return num_params - routed_total + routed_active


def derive(
    cfg: ModelConfig,
    shape: ShapeConfig,
    num_params: int,
    cost: dict[str, float],
    hlo_text: str,
    num_devices: int,
) -> Roofline:
    # NOTE: cost_analysis() on the CPU backend counts while-loop bodies once
    # (see analysis/hlo.py header), so all three terms come from the
    # loop-aware HLO analysis; `cost` is kept only as a cross-check input.
    coll = analyze_hlo(hlo_text)
    flops = coll.flops
    bytes_accessed = coll.bytes
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll.total_wire_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, num_params, active_params(cfg, num_params))
    mf_dev = mf / num_devices
    step = max(terms.values())
    score_bytes = sxs_buffer_bytes(hlo_text)
    return Roofline(
        attn_score_bytes=score_bytes,
        memory_s_flash=max(bytes_accessed - score_bytes, 0.0) / HBM_BW,
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collectives=coll.as_dict(),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_per_device=mf_dev,
        useful_flops_fraction=mf_dev / flops if flops else 0.0,
        step_time_s=step,
        mfu=(mf_dev / PEAK_FLOPS) / step if step > 0 else 0.0,
    )
