"""HLO-text analysis with while-loop awareness.

XLA-CPU's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (probe:
an 8-step scan of a 512^3 matmul reports 1x body flops), which silences both
the compute inside scan-over-layers and — worse — the per-layer collectives.
This module re-derives per-device totals from ``compiled.as_text()``:

  * parses every computation and its instructions (shapes, operands);
  * resolves ``while`` trip counts from the condition computation's compare
    constant and multiplies body costs accordingly (nested loops compose);
  * descends into fusion/call bodies for dot/collective accounting;
  * FLOPs: dot/convolution ops (2 * prod(result) * contraction size);
  * collective bytes: per-op result payload + replica-group size -> the
    instruction-sheet operand_bytes and a ring-model wire_bytes;
  * HBM bytes: 2x the sum of materialized result buffers (each top-level
    value is written once and read ~once downstream), plus dot operand
    reads.  Fusion internals and slice *operands* are excluded — a
    dynamic-slice from the stacked layer weights only reads the slice, so
    counting full operands would bill the whole stack every scan step.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INST_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(
    r"^(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+([\w\-]+)"
)
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CALL_TARGET_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_list_bytes(type_str: str) -> int:
    return sum(_shape_bytes(t, d) for t, d in _SHAPE_RE.findall(type_str))


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    op: str
    line: str
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list[Instruction]
    by_name: dict[str, Instruction]


def _parse_operands(rest: str) -> list[str]:
    """Operand names from the first (...) after the op name."""
    m = _OPERANDS_RE.search(rest)
    if not m:
        return []
    out = []
    for tok in m.group(1).split(","):
        tok = tok.strip()
        if tok.startswith("%"):
            out.append(tok[1:])
        else:
            # typed operand like "f32[8,128] %name"
            mm = re.search(r"%([\w\.\-]+)", tok)
            if mm:
                out.append(mm.group(1))
    return out


def parse_computations(text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry: str | None = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    cur = Computation(m.group(1), [], {})
                    if line.strip().startswith("ENTRY"):
                        entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        m2 = _OPNAME_RE.match(rest.strip())
        if not m2:
            continue
        type_str, op = m2.groups()
        inst = Instruction(
            name=name,
            type_str=type_str,
            op=op,
            line=line,
            operands=_parse_operands(rest[m2.end():]) if op != "parameter" else [],
        )
        cur.instructions.append(inst)
        cur.by_name[name] = inst
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the loop condition (scan trip count)."""
    best = 1
    for inst in cond.instructions:
        for c in _CONST_RE.findall(inst.line):
            best = max(best, int(c))
    return best


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    """2 * prod(result dims) * contraction size (batch dims cancel)."""
    shapes = _SHAPE_RE.findall(inst.type_str)
    if not shapes:
        return 0.0
    result_elems = _shape_elems(shapes[0][1])
    # contraction size = prod(lhs dims) * prod(rhs dims) / (result * batch^2)
    # simpler: lhs_elems * rhs_elems / result gives contraction * batch, so
    # use lhs contracting dims explicitly when available.
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
    lhs = comp.by_name.get(inst.operands[0]) if inst.operands else None
    if m and lhs is not None:
        lshapes = _SHAPE_RE.findall(lhs.type_str)
        if lshapes:
            ldims = [int(x) for x in lshapes[0][1].split(",") if x.strip()]
            contraction = 1
            for idx in m.group(1).split(","):
                if idx.strip():
                    contraction *= ldims[int(idx)]
            return 2.0 * result_elems * contraction
    return 2.0 * result_elems  # fallback (no dnums — treat as elementwise-ish)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_counts: dict[str, float] = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_result_bytes: dict[str, float] = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_operand_bytes: dict[str, float] = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_wire_bytes: dict[str, float] = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in other.coll_counts:
            self.coll_counts[k] += other.coll_counts[k] * mult
            self.coll_result_bytes[k] += other.coll_result_bytes[k] * mult
            self.coll_operand_bytes[k] += other.coll_operand_bytes[k] * mult
            self.coll_wire_bytes[k] += other.coll_wire_bytes[k] * mult

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.coll_operand_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.coll_wire_bytes.values())

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "counts": dict(self.coll_counts),
            "result_bytes": dict(self.coll_result_bytes),
            "operand_bytes": dict(self.coll_operand_bytes),
            "wire_bytes": dict(self.coll_wire_bytes),
            "total_operand_bytes": self.total_operand_bytes,
            "total_wire_bytes": self.total_wire_bytes,
        }


def _collective_cost(inst: Instruction, cost: HloCost) -> None:
    op = inst.op.replace("-start", "")
    if op not in _COLLECTIVES:
        return
    size = _shape_list_bytes(inst.type_str)
    n = max(_group_size(inst.line), 1)
    cost.coll_counts[op] += 1
    cost.coll_result_bytes[op] += size
    if op == "all-reduce":
        cost.coll_operand_bytes[op] += size
        cost.coll_wire_bytes[op] += 2.0 * (n - 1) / n * size
    elif op == "all-gather":
        cost.coll_operand_bytes[op] += size / n
        cost.coll_wire_bytes[op] += (n - 1) / n * size
    elif op == "reduce-scatter":
        cost.coll_operand_bytes[op] += size * n
        cost.coll_wire_bytes[op] += float(n - 1) * size
    elif op == "all-to-all":
        cost.coll_operand_bytes[op] += size
        cost.coll_wire_bytes[op] += (n - 1) / n * size
    else:
        cost.coll_operand_bytes[op] += size
        cost.coll_wire_bytes[op] += float(size)


def _computation_cost(
    comp: Computation,
    comps: dict[str, Computation],
    memo: dict,
    top_level: bool,
    trips_hint: int = 1,
) -> HloCost:
    key = (comp.name, top_level, trips_hint)
    if key in memo:
        return memo[key]
    cost = HloCost()
    for inst in comp.instructions:
        op = inst.op
        if op in ("parameter", "constant", "iota"):
            continue
        if op == "while":
            body_name = None
            m = _CALL_TARGET_RE.search(inst.line)
            if m:
                body_name = m.group(1)
            cond_m = _COND_RE.search(inst.line)
            trips = 1
            if cond_m and cond_m.group(1) in comps:
                trips = _trip_count(comps[cond_m.group(1)])
            if body_name and body_name in comps:
                body_cost = _computation_cost(
                    comps[body_name], comps, memo, True, trips_hint=trips
                )
                cost.add(body_cost, mult=trips)
            continue
        if op in ("fusion", "call", "conditional", "map", "reduce", "sort",
                  "reduce-window", "scatter", "select-and-scatter", "custom-call"):
            m = _CALL_TARGET_RE.search(inst.line)
            if m and m.group(1) in comps:
                inner = _computation_cost(comps[m.group(1)], comps, memo, False)
                # only dot flops / collectives escape a fusion body
                sub = HloCost()
                sub.add(inner)
                sub.bytes = 0.0
                cost.add(sub)
            if top_level:
                size = _shape_list_bytes(inst.type_str)
                shapes = _SHAPE_RE.findall(inst.type_str)
                if (
                    trips_hint > 1
                    and len(shapes) == 1
                    and shapes[0][1].split(",")[0].strip() == str(trips_hint)
                ):
                    size //= trips_hint  # in-place loop-stacked buffer
                cost.bytes += 2 * size
            continue
        if op in ("dot", "convolution"):
            cost.flops += _dot_flops(inst, comp)
            for operand in inst.operands:
                ref = comp.by_name.get(operand)
                if ref is not None:
                    cost.bytes += _shape_list_bytes(ref.type_str)
        _collective_cost(inst, cost)
        if top_level and op == "dynamic-update-slice":
            # in-place stack write: traffic is the *update*, not the stack
            upd = comp.by_name.get(inst.operands[1]) if len(inst.operands) > 1 else None
            cost.bytes += 2 * _shape_list_bytes(upd.type_str) if upd else 0
            continue
        if top_level and op not in ("tuple", "get-tuple-element", "bitcast"):
            size = _shape_list_bytes(inst.type_str)
            # loop-stacked in-place buffers (result dim0 == trip count, e.g.
            # the remat-scan saved-residual stack) move ~size/trips per step
            shapes = _SHAPE_RE.findall(inst.type_str)
            if (
                trips_hint > 1
                and len(shapes) == 1
                and shapes[0][1].split(",")[0].strip() == str(trips_hint)
            ):
                size //= trips_hint
            cost.bytes += 2 * size
    memo[key] = cost
    return cost


def analyze_hlo(text: str) -> HloCost:
    comps, entry = parse_computations(text)
    if entry is None or entry not in comps:
        # fall back: treat the largest computation as entry
        entry = max(comps, key=lambda k: len(comps[k].instructions)) if comps else None
        if entry is None:
            return HloCost()
    memo: dict[str, HloCost] = {}
    return _computation_cost(comps[entry], comps, memo, True)


# Back-compat shim used by earlier tests/benchmarks.
def collective_stats(text: str) -> HloCost:
    return analyze_hlo(text)


def loop_multiplicities(
    comps: dict[str, Computation],
    entry: str,
    *,
    follow_calls: bool = True,
) -> dict[str, float]:
    """Trip-count multiplicity of every computation reachable from ``entry``.

    A computation inside a ``while`` body counts once per resolved trip
    (nested loops compose multiplicatively); ``follow_calls`` additionally
    descends into ``fusion``/``call``/``conditional`` bodies at 1x.  A
    computation reachable along several paths accumulates the sum of the
    path multiplicities.  This is the loop-awareness primitive shared by
    :func:`top_costs`, :func:`sxs_buffer_bytes`, and the tracelint HLO
    rules (``repro.analysis.lint``).
    """
    mult: dict[str, float] = defaultdict(float)

    def walk(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        for inst in comp.instructions:
            if inst.op == "while":
                b = _CALL_TARGET_RE.search(inst.line)
                c = _COND_RE.search(inst.line)
                trips = _trip_count(comps[c.group(1)]) if c and c.group(1) in comps else 1
                if b and b.group(1) in comps:
                    walk(b.group(1), m * trips)
            elif follow_calls and inst.op in ("fusion", "call", "conditional"):
                mm = _CALL_TARGET_RE.search(inst.line)
                if mm and mm.group(1) in comps:
                    walk(mm.group(1), m)

    walk(entry, 1.0)
    return dict(mult)


def top_costs(text: str, k: int = 15):
    """Top-k instructions by trip-count-weighted bytes and collective wire
    bytes — the evidence base for the §Perf hillclimb."""
    comps, entry = parse_computations(text)
    if entry is None:
        return {"bytes": [], "collectives": []}
    mult = loop_multiplicities(comps, entry)
    by_bytes = []
    by_wire = []
    for name, m in mult.items():
        comp = comps[name]
        for inst in comp.instructions:
            if inst.op in ("parameter", "constant", "tuple", "get-tuple-element", "bitcast"):
                continue
            size = _shape_list_bytes(inst.type_str)
            by_bytes.append((2 * size * m, name, inst.op, inst.type_str[:60]))
            op = inst.op.replace("-start", "")
            if op in _COLLECTIVES:
                tmp = HloCost()
                _collective_cost(inst, tmp)
                by_wire.append((tmp.total_wire_bytes * m, name, op, inst.type_str[:60]))
    by_bytes.sort(reverse=True)
    by_wire.sort(reverse=True)
    return {"bytes": by_bytes[:k], "collectives": by_wire[:k]}


def sxs_buffer_bytes(text: str, min_dim: int = 1024) -> float:
    """Trip-weighted traffic of [.., S, S] score-shaped buffers (S >= min_dim,
    square trailing dims) — the portion of the memory term that the Pallas
    flash-attention kernel keeps out of HBM entirely."""
    comps, entry = parse_computations(text)
    if entry is None:
        return 0.0
    mult = loop_multiplicities(comps, entry, follow_calls=False)
    total = 0.0
    for name, m in mult.items():
        for inst in comps[name].instructions:
            if inst.op in ("parameter", "constant", "tuple", "get-tuple-element"):
                continue
            shapes = _SHAPE_RE.findall(inst.type_str)
            if len(shapes) != 1:
                continue
            dims = [int(x) for x in shapes[0][1].split(",") if x.strip()]
            if len(dims) >= 2 and dims[-1] == dims[-2] and dims[-1] >= min_dim:
                total += 2 * _shape_list_bytes(inst.type_str) * m
    return total
