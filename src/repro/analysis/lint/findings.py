"""Finding record + the rule catalogue (stable codes)."""

from __future__ import annotations

import dataclasses

#: rule code -> (short name, one-line invariant).  Codes are stable API:
#: baselines, CI artifacts, and the regression tests key on them.
RULES = {
    "TL001": (
        "fma-seam",
        "the §3 latency product must reach task_finish_time through a "
        "contraction-blocking seam (compiled == op-by-op, bit-exact)",
    ),
    "TL002": (
        "carry-copy",
        "scatter-updated loop-carried tables must be write-only inside "
        "their loop (stray reads defeat in-place carry aliasing)",
    ),
    "TL003": (
        "pad-variant-reduce",
        "reductions over width-bucketed padded axes must carry mask "
        "evidence (XLA reductions are not pad-length invariant)",
    ),
    "TL004": (
        "dtype-leak",
        "loop carries and entry outputs must be strongly typed and kernel "
        "outputs must match the declared value_dtype",
    ),
    "TL005": (
        "cond-capture",
        "lax.cond inside a rank loop must not close over large non-carry "
        "buffers (each branch copies its captures every trip)",
    ),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one location of one entry's trace.

    ``symbol`` is a stable within-entry locator (a loop path, carry aval,
    or output index) — ``tracelint.toml`` suppressions can narrow on it
    via substring match, and it keeps JSON artifacts diffable across PRs
    even when messages are reworded.
    """

    code: str
    entry: str
    symbol: str
    message: str

    @property
    def rule_name(self) -> str:
        return RULES[self.code][0]

    def as_dict(self) -> dict:
        return {
            "code": self.code,
            "rule": self.rule_name,
            "entry": self.entry,
            "symbol": self.symbol,
            "message": self.message,
        }

    def render(self) -> str:
        return f"{self.code} [{self.rule_name}] {self.entry} :: {self.symbol}\n    {self.message}"
