"""tracelint baseline: per-rule suppressions from ``tracelint.toml``.

Format (a small TOML subset — parsed with :mod:`tomllib` on 3.11+, with
a built-in fallback parser on the 3.10 container):

.. code-block:: toml

    [tracelint]
    version = 1

    [[suppress]]
    code = "TL002"
    entry = "fused_logreg_grid"
    contains = "values"          # optional: substring of symbol/message
    reason = "why this finding is accepted"

A suppression must carry a non-empty ``reason`` — the baseline is
documentation of accepted debt, not a mute button.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path

try:  # python >= 3.11
    import tomllib as _toml
except ImportError:  # 3.10 container: minimal subset parser below
    _toml = None


@dataclasses.dataclass(frozen=True)
class Suppression:
    code: str
    entry: str = "*"  # "*" matches every entry
    contains: str = ""  # substring of the finding's symbol or message
    reason: str = ""

    def matches(self, finding) -> bool:
        if self.code != finding.code:
            return False
        if self.entry not in ("*", finding.entry):
            return False
        if self.contains and (
            self.contains not in finding.symbol
            and self.contains not in finding.message
        ):
            return False
        return True


def _parse_scalar(tok: str):
    tok = tok.strip()
    if tok.startswith('"') and tok.endswith('"'):
        return tok[1:-1]
    if tok in ("true", "false"):
        return tok == "true"
    try:
        return int(tok)
    except ValueError:
        return tok


def _mini_toml(text: str) -> dict:
    """The subset of TOML the baseline format uses.

    Sections (``[name]``), arrays of tables (``[[name]]``), and scalar
    ``key = value`` lines (strings, ints, booleans).  Enough for
    ``tracelint.toml``; anything richer should move to ``tomllib``.
    """
    root: dict = {}
    cur: dict = root
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].strip() if not raw.strip().startswith('"') else raw.strip()
        if not line:
            continue
        if line.startswith("[[") and line.endswith("]]"):
            name = line[2:-2].strip()
            cur = {}
            root.setdefault(name, []).append(cur)
        elif line.startswith("[") and line.endswith("]"):
            name = line[1:-1].strip()
            cur = root.setdefault(name, {})
        elif "=" in line:
            key, _, val = line.partition("=")
            cur[key.strip()] = _parse_scalar(val)
    return root


def parse_baseline(text: str) -> list:
    data = _toml.loads(text) if _toml is not None else _mini_toml(text)
    supps = []
    for i, raw in enumerate(data.get("suppress", [])):
        if not raw.get("code"):
            raise ValueError(f"suppress[{i}]: missing 'code'")
        if not raw.get("reason"):
            raise ValueError(
                f"suppress[{i}] ({raw.get('code')}): a suppression must "
                f"carry a non-empty 'reason'"
            )
        supps.append(
            Suppression(
                code=str(raw["code"]),
                entry=str(raw.get("entry", "*")),
                contains=str(raw.get("contains", "")),
                reason=str(raw["reason"]),
            )
        )
    return supps


def load_baseline(path) -> list:
    """Suppressions from a ``tracelint.toml`` (empty list if absent)."""
    p = Path(path)
    if not p.exists():
        return []
    return parse_baseline(p.read_text())
