"""tracelint — static analysis of the engines' traced computations.

The repo's cross-engine bit-exactness and scan-performance guarantees
rest on idioms that are invisible to ordinary tests until they regress:
the §3 FMA-contraction seam, the write-only §5 value-table discipline,
width-bucket mask operands, strong dtypes in scan carries, and keeping
``lax.cond`` out of the rank loops.  tracelint walks the jaxprs (and,
where it strengthens a finding, the optimized HLO via
:mod:`repro.analysis.hlo`) of registered entry points and reports
violations with stable rule codes:

=======  ==================  ==============================================
code     name                invariant
=======  ==================  ==============================================
TL001    fma-seam            the §3 latency product reaches
                             ``task_finish_time`` through a
                             contraction-blocking seam (compiled output is
                             bit-identical to op-by-op evaluation)
TL002    carry-copy          scatter-updated loop-carried tables are
                             write-only inside their loop (no stray reads
                             defeating XLA's in-place carry aliasing)
TL003    pad-variant-reduce  reductions over width-bucketed padded axes
                             carry mask evidence (a ``<``/``<=`` style
                             comparison upstream)
TL004    dtype-leak          loop carries and entry outputs are strongly
                             typed; kernel outputs match the declared
                             ``value_dtype``
TL005    cond-capture        no ``lax.cond`` inside the rank loops closes
                             over large non-carry buffers
=======  ==================  ==============================================

Run ``python -m repro.analysis.lint --entry all`` from the repo root;
legitimate findings are suppressed via ``tracelint.toml``.  See
``docs/ARCHITECTURE.md`` ("Checked invariants") for each rule's
motivating incident and the suppression workflow.
"""

from repro.analysis.lint.baseline import Suppression, load_baseline
from repro.analysis.lint.entries import ENTRIES, EntryProbe, build_entries
from repro.analysis.lint.findings import RULES, Finding
from repro.analysis.lint.runner import LintReport, run_lint

__all__ = [
    "ENTRIES",
    "RULES",
    "EntryProbe",
    "Finding",
    "LintReport",
    "Suppression",
    "build_entries",
    "load_baseline",
    "run_lint",
]
