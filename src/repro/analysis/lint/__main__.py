"""CLI: ``python -m repro.analysis.lint --entry all --format text|json``.

Exits 1 on any non-baselined finding (the CI ``tracelint`` gate).  The
baseline defaults to ``tracelint.toml`` in the current directory (the
repo root in CI); ``--no-baseline`` audits everything.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.lint.entries import ENTRIES
from repro.analysis.lint.runner import run_lint


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="tracelint: static analysis of the engines' traced "
        "computations (rules TL001-TL005)",
    )
    parser.add_argument(
        "--entry",
        action="append",
        default=None,
        help=f"entry to lint (repeatable; 'all' = every one of "
        f"{sorted(ENTRIES)})",
    )
    parser.add_argument(
        "--format", choices=("text", "json"), default="text", dest="fmt"
    )
    parser.add_argument(
        "--baseline",
        default="tracelint.toml",
        help="suppression file (default: ./tracelint.toml)",
    )
    parser.add_argument(
        "--no-baseline",
        action="store_true",
        help="ignore the baseline and report every finding",
    )
    args = parser.parse_args(argv)
    entries = args.entry or ["all"]
    if "all" in entries:
        entries = "all"
    baseline = None if args.no_baseline else Path(args.baseline)
    report = run_lint(entries=entries, baseline_path=baseline)
    out = report.render_json() if args.fmt == "json" else report.render_text()
    print(out)
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
