"""Jaxpr-walking primitives shared by the tracelint rules.

Everything here operates on ``jax.core`` jaxprs obtained from
``jax.make_jaxpr`` — no compilation, no device execution.  The helpers
encode the two pieces of structural knowledge the rules need:

* where nested jaxprs hide (``scan``/``while``/``cond``/``pjit``/custom
  calls keep them in ``eqn.params``), and
* how a loop body's invars line up with its carried outputs (``scan``
  splits ``[consts | carries | xs]``, ``while`` splits
  ``[cond_consts? | body_consts | carries]``).
"""

from __future__ import annotations

import dataclasses
from collections.abc import Iterator
from typing import Any

#: primitives that perform an in-place-style indexed write; a chain of
#: these from a loop invar to the loop's carried output is the shape XLA
#: aliases in place (tracelint TL002)
SCATTER_PRIMS = frozenset(
    {
        "scatter",
        "scatter-add",
        "scatter_add",
        "scatter-mul",
        "scatter_mul",
        "scatter-min",
        "scatter_min",
        "scatter-max",
        "scatter_max",
        "dynamic_update_slice",
    }
)

#: loop-introducing primitives (their bodies run once per trip)
LOOP_PRIMS = frozenset({"while", "scan"})


def subjaxprs(eqn) -> list:
    """Every nested jaxpr of one equation, as ``(param_name, jaxpr)``.

    Covers ``scan`` (``jaxpr``), ``while`` (``cond_jaxpr``/``body_jaxpr``),
    ``cond`` (``branches``), ``pjit``/``closed_call`` (``jaxpr``), and any
    custom primitive that stashes (lists of) ClosedJaxprs in its params.
    """
    out = []
    for name, p in eqn.params.items():
        vals = p if isinstance(p, (list, tuple)) else [p]
        for v in vals:
            if hasattr(v, "jaxpr"):  # ClosedJaxpr
                out.append((name, v.jaxpr))
            elif hasattr(v, "eqns"):  # raw Jaxpr
                out.append((name, v))
    return out


def aval_bytes(aval) -> int:
    """Byte size of a shaped aval (0 for abstract tokens etc.)."""
    size = getattr(aval, "size", None)
    dtype = getattr(aval, "dtype", None)
    if size is None or dtype is None:
        return 0
    return int(size) * int(dtype.itemsize)


@dataclasses.dataclass(frozen=True)
class LoopInfo:
    """One ``while``/``scan`` equation plus its resolved carry structure."""

    eqn: Any
    path: str  # e.g. "top/scan/while" — stable finding locator
    depth: int  # number of enclosing loops, this one excluded
    body: Any  # the body jaxpr
    carries: tuple  # ((body_invar, body_outvar), ...) aligned pairs


def _loop_info(eqn, path: str, depth: int) -> LoopInfo | None:
    name = eqn.primitive.name
    if name == "scan":
        body = eqn.params["jaxpr"].jaxpr
        nc = eqn.params["num_consts"]
        ncar = eqn.params["num_carry"]
        pairs = tuple(zip(body.invars[nc : nc + ncar], body.outvars[:ncar]))
        return LoopInfo(eqn, path, depth, body, pairs)
    if name == "while":
        body = eqn.params["body_jaxpr"].jaxpr
        nconsts = eqn.params["body_nconsts"]
        pairs = tuple(zip(body.invars[nconsts:], body.outvars))
        return LoopInfo(eqn, path, depth, body, pairs)
    return None


def iter_loops(jaxpr, path: str = "top", depth: int = 0) -> Iterator[LoopInfo]:
    """All loops (any nesting level) in trace order, with carry pairs."""
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        child_path = f"{path}/{name}"
        info = _loop_info(eqn, child_path, depth)
        if info is not None:
            yield info
        child_depth = depth + (1 if name in LOOP_PRIMS else 0)
        for _, sub in subjaxprs(eqn):
            yield from iter_loops(sub, child_path, child_depth)


def iter_eqns(jaxpr, path: str = "top", depth: int = 0) -> Iterator[tuple]:
    """All equations (any nesting level) as ``(eqn, path, loop_depth)``."""
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        child_path = f"{path}/{name}"
        yield eqn, path, depth
        child_depth = depth + (1 if name in LOOP_PRIMS else 0)
        for _, sub in subjaxprs(eqn):
            yield from iter_eqns(sub, child_path, child_depth)


def iter_eqns_scoped(jaxpr, path: str = "top") -> Iterator[tuple]:
    """Like :func:`iter_eqns` but yields ``(eqn, scope_jaxpr, path)``.

    ``scope_jaxpr`` is the jaxpr the equation lives in — the right frame
    for backward dataflow walks like :func:`reaches_comparison`.
    """
    for eqn in jaxpr.eqns:
        child_path = f"{path}/{eqn.primitive.name}"
        yield eqn, jaxpr, path
        for _, sub in subjaxprs(eqn):
            yield from iter_eqns_scoped(sub, child_path)


def _var_maps(body):
    """Producer (var -> eqn) and consumer (var -> [(eqn, arg_idx)]) maps."""
    producer = {}
    consumers: dict = {}
    for eqn in body.eqns:
        for v in eqn.outvars:
            producer[id(v)] = eqn
        for i, v in enumerate(eqn.invars):
            if hasattr(v, "aval"):  # skip Literals
                consumers.setdefault(id(v), []).append((eqn, i))
    return producer, consumers


def scatter_chain(body, invar, outvar):
    """The scatter write-chain from a carried invar to its outvar.

    Returns the list of chain equations (outermost write last) when the
    carried output is produced *exclusively* by scatter-family updates of
    the carried input — the in-place-aliasable shape — or ``None`` when
    the carry is not scatter-disciplined (produced by arithmetic, a
    nested loop, ...), in which case TL002 does not apply to it.
    """
    producer, _ = _var_maps(body)
    chain = []
    cur = outvar
    seen = set()
    while True:
        if cur is invar:
            return list(reversed(chain))
        if id(cur) in seen:
            return None
        seen.add(id(cur))
        prod = producer.get(id(cur))
        if prod is None or prod.primitive.name not in SCATTER_PRIMS:
            return None
        chain.append(prod)
        cur = prod.invars[0]


def stray_chain_reads(body, invar, outvar):
    """Consumers that read a scatter-chain member (TL002 violations).

    Every variable along the write chain (the carried invar plus each
    intermediate scatter result, the final outvar excluded) may only be
    consumed as operand 0 of the next chain scatter.  Any other consumer
    — a gather, a slice, arithmetic — forces XLA to keep the pre-write
    buffer alive and copies the whole table once per loop trip.

    Returns ``[(primitive_name, aval_str), ...]`` for each stray read;
    empty when the carry is write-only or not scatter-disciplined.
    """
    chain = scatter_chain(body, invar, outvar)
    if not chain:
        return []
    _, consumers = _var_maps(body)
    chain_ids = {id(e): e for e in chain}
    members = [invar] + [e.outvars[0] for e in chain[:-1]]
    strays = []
    for var in members:
        for eqn, arg_idx in consumers.get(id(var), []):
            if id(eqn) in chain_ids and arg_idx == 0:
                continue  # the sanctioned next write
            strays.append((eqn.primitive.name, str(var.aval)))
    return strays


def reaches_comparison(body, var, comparison_prims=("lt", "le", "gt", "ge")) -> bool:
    """Whether ``var``'s backward *value* dataflow contains a comparison.

    Used as mask evidence by TL003: a width-masked reduction's operand is
    (transitively) a product with an ``iota < widths``-style predicate.
    Two pollution sources are excluded so the evidence is not vacuous:

    * the walk follows only operand 0 of ``gather``/``dynamic_slice`` —
      index operands carry jnp's own clamp/wrap comparisons
      (``select_n(lt(idx, 0), idx + n, idx)``) that say nothing about the
      reduced *values*;
    * ``custom_jvp``/``custom_vjp`` call internals are not searched
      (``sigmoid``'s stable-branch comparisons would otherwise count).

    The evidence set is exact comparisons only — ``ne``/``eq`` appear in
    unrelated places and ``clip`` lowers to ``min``/``max``, so neither
    counts.
    """
    producer, _ = _var_maps(body)
    stack = [var]
    seen = set()
    while stack:
        v = stack.pop()
        if id(v) in seen:
            continue
        seen.add(id(v))
        prod = producer.get(id(v))
        if prod is None:
            continue
        name = prod.primitive.name
        if name in comparison_prims:
            return True
        if not name.startswith("custom_"):
            for _, sub in subjaxprs(prod):
                for eqn, _, _ in iter_eqns(sub):
                    if eqn.primitive.name in comparison_prims:
                        return True
        ins = prod.invars[:1] if name in ("gather", "dynamic_slice") else prod.invars
        stack.extend(u for u in ins if hasattr(u, "aval"))
    return False
