"""Run the rules over the registered entries and render findings."""

from __future__ import annotations

import dataclasses
import json

from repro.analysis.lint.baseline import load_baseline
from repro.analysis.lint.entries import build_entries
from repro.analysis.lint.rules import ALL_RULES


@dataclasses.dataclass
class LintReport:
    """Partitioned outcome of one lint run.

    ``findings`` are active (build-failing); ``suppressed`` pairs each
    baselined finding with the suppression that matched it.
    """

    entries_run: list
    findings: list
    suppressed: list  # (Finding, Suppression)

    @property
    def exit_code(self) -> int:
        return 1 if self.findings else 0

    def render_text(self) -> str:
        lines = []
        for f in self.findings:
            lines.append(f.render())
        for f, supp in self.suppressed:
            lines.append(f"suppressed {f.code} {f.entry} :: {f.symbol} ({supp.reason})")
        lines.append(
            f"tracelint: {len(self.entries_run)} entries, "
            f"{len(self.findings)} finding(s), "
            f"{len(self.suppressed)} suppressed"
        )
        return "\n".join(lines)

    def render_json(self) -> str:
        return json.dumps(
            {
                "entries": self.entries_run,
                "findings": [f.as_dict() for f in self.findings],
                "suppressed": [
                    {**f.as_dict(), "reason": supp.reason}
                    for f, supp in self.suppressed
                ],
            },
            indent=2,
            sort_keys=True,
        )


def run_lint(entries="all", baseline_path=None, rules=ALL_RULES) -> LintReport:
    """Build the probes, apply every rule, partition by the baseline."""
    suppressions = load_baseline(baseline_path) if baseline_path else []
    probes = build_entries(entries)
    active, suppressed = [], []
    for probe in probes:
        for _, rule in rules:
            for finding in rule(probe):
                match = next(
                    (s for s in suppressions if s.matches(finding)), None
                )
                if match is None:
                    active.append(finding)
                else:
                    suppressed.append((finding, match))
    return LintReport(
        entries_run=[p.name for p in probes],
        findings=active,
        suppressed=suppressed,
    )
