"""Registered entry points the tracelint rules run against.

Each entry builds a small but *production-shaped* probe: the fused-scan
entries trace the real ``_run_scan`` body through
:func:`repro.experiments.fused.prepare_scan_inputs` (the same operand
builder ``run_convergence_scan`` uses), the kernel entries trace the real
``FusedKernels.sub_blocks`` closures, and so on — the analyzer never
audits a hand-maintained replica of the code it guards.

The registry is shared infrastructure: ``benchmarks/tracelint_bench.py``
times these same probes and ``benchmarks/bench_regression.py --kind
tracelint`` gates on them.
"""

from __future__ import annotations

import dataclasses
import functools
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.cluster.simulator import MethodConfig, task_finish_time
from repro.core.problems import (
    LogisticRegressionProblem,
    PCAProblem,
    make_genomics_like_matrix,
    make_higgs_like,
)
from repro.experiments import fused
from repro.latency.model import make_heterogeneous_cluster, sample_fleet


@dataclasses.dataclass
class EntryProbe:
    """One registered entry point, traced and annotated for the rules.

    ``cond_depth_threshold`` marks how many enclosing loops are "batching"
    loops whose body-level conditionals are legitimate (the fused training
    scan); TL005 audits conds strictly deeper.  ``padded_axis_sizes`` are
    the width-bucket pad lengths TL003 audits reductions over.
    ``declared_output_dtypes`` is the kernel output contract TL004 checks,
    and ``hlo_fn_args`` lets TL002 compile the entry and attach
    HLO-derived copy-traffic evidence to a confirmed finding.
    """

    name: str
    description: str
    jaxpr: Any = None  # ClosedJaxpr for the structural rules
    latency_probe: tuple | None = None  # (fn, [args, ...]) for TL001
    cond_depth_threshold: int = 0
    padded_axis_sizes: tuple = ()
    declared_output_dtypes: tuple | None = None
    hlo_fn_args: tuple | None = None  # (fn, args) lowered on demand


# --------------------------------------------------------------------------
# shared probe fixtures (small, deterministic, CPU-cheap)
# --------------------------------------------------------------------------

_PROBE_WORKERS = 4
_PROBE_SCENARIOS = 2
_PROBE_ITERS = 6


@functools.lru_cache(maxsize=None)
def _probe_logreg():
    X, y = make_higgs_like(64, seed=0)
    return LogisticRegressionProblem(X=X, y=y)


@functools.lru_cache(maxsize=None)
def _probe_pca():
    return PCAProblem(X=make_genomics_like_matrix(64, 24, seed=0), k=2)


@functools.lru_cache(maxsize=None)
def _probe_traces():
    cluster = make_heterogeneous_cluster(
        _PROBE_WORKERS, seed=3, burst_rate=0.0, comp_range=(1.1e-3, 2.5e-3)
    )
    return sample_fleet(cluster, _PROBE_SCENARIOS, 10, burst_rate=0.0, seed=11)


@functools.lru_cache(maxsize=None)
def _probe_churn_traces():
    """The probe fleet under elastic churn: one death inside the probe
    horizon plus a slowdown drift, so ``spec.has_churn`` compiles the
    liveness mask, per-start slowdown rows, and dead-entry cache clears
    into the audited jaxpr."""
    from repro.latency.model import ChurnSchedule

    traces = _probe_traces()
    sd = np.asarray(traces.slowdown)
    alive0 = np.ones(_PROBE_WORKERS, bool)
    alive1 = alive0.copy()
    alive1[3] = False
    return traces.with_churn(
        ChurnSchedule(
            times=np.array([0.004]),
            slowdown=np.stack([sd, sd * 1.2]),
            alive=np.stack([alive0, alive1]),
        )
    )


def _fused_probe(
    problem, config, *, slot_budget=None, traces=None, kernel_backend="xla"
) -> EntryProbe:
    """Trace the production scan body with production-built operands."""
    if traces is None:
        traces = _probe_traces()
    spec, kernels, scan_args = fused.prepare_scan_inputs(
        problem, traces, config, _PROBE_ITERS, slot_budget=slot_budget,
        kernel_backend=kernel_backend,
    )
    fn = functools.partial(fused._run_scan, kernels, spec)
    with enable_x64():
        jaxpr = jax.make_jaxpr(fn)(*scan_args)
    return EntryProbe(
        name="",
        description="",
        jaxpr=jaxpr,
        cond_depth_threshold=1,  # the training scan itself
        hlo_fn_args=(fn, scan_args),
    )


def _latency_chain(unit, cost, slowdown, factor, start, comm):
    # looked up through the module so the TL001 regression test can
    # monkeypatch the seam away and watch the rule fire
    comp = fused.guarded_comp_latency(unit, cost, slowdown, factor)
    return task_finish_time(start, comp, comm)


def _build_latency() -> EntryProbe:
    """TL001 probe: the §3 product feeding ``task_finish_time``.

    The rule compiles this chain and diffs against op-by-op evaluation;
    random strictly-positive draws make any FMA contraction of the final
    multiply-add visible in the last ULP.
    """
    with enable_x64():
        batches = []
        for seed in (0, 1, 2, 3):
            rng = np.random.default_rng(seed)
            batches.append(
                tuple(
                    jnp.asarray(rng.uniform(0.1, 3.0, size=64), dtype=jnp.float64)
                    for _ in range(6)
                )
            )
        jaxpr = jax.make_jaxpr(_latency_chain)(*batches[0])
    return EntryProbe(
        name="latency",
        description="§3 latency product -> task_finish_time (FMA seam)",
        jaxpr=jaxpr,
        latency_probe=(_latency_chain, batches),
    )


def _build_fused_logreg_grid() -> EntryProbe:
    cfg = MethodConfig(name="dsag", w=3, subpartitions=2)
    probe = _fused_probe(_probe_logreg(), cfg)
    probe.name = "fused_logreg_grid"
    probe.description = "fused scan body, logreg, grid §5 cache"
    return probe


def _build_fused_logreg_lb() -> EntryProbe:
    cfg = MethodConfig(name="dsag", w=3, subpartitions=2, load_balance=True)
    probe = _fused_probe(_probe_logreg(), cfg)
    probe.name = "fused_logreg_lb"
    probe.description = "fused scan body, logreg, §6 LB slot-universe cache"
    return probe


def _build_fused_logreg_tiled() -> EntryProbe:
    cfg = MethodConfig(name="dsag", w=3, subpartitions=2, load_balance=True)
    prob = _probe_logreg()
    cap = fused.scan_capability(prob, cfg, _PROBE_WORKERS)
    # a budget of one slot less than the full universe forces the tiled
    # active-slot cache while staying supported
    probe = _fused_probe(prob, cfg, slot_budget=cap.slots_total - 1)
    probe.name = "fused_logreg_tiled"
    probe.description = "fused scan body, logreg, tiled active-slot cache"
    return probe


def _build_fused_logreg_churn() -> EntryProbe:
    cfg = MethodConfig(name="dsag", w=3, subpartitions=2, load_balance=True)
    probe = _fused_probe(_probe_logreg(), cfg, traces=_probe_churn_traces())
    probe.name = "fused_logreg_churn"
    probe.description = (
        "fused scan body, logreg, §6 LB universe cache under fleet churn"
    )
    return probe


def _build_fused_pca_grid() -> EntryProbe:
    cfg = MethodConfig(name="dsag", w=3, subpartitions=2)
    probe = _fused_probe(_probe_pca(), cfg)
    probe.name = "fused_pca_grid"
    probe.description = "fused scan body, PCA, grid §5 cache"
    return probe


def _build_fused_logreg_grid_pallas() -> EntryProbe:
    """The Pallas-backed scan body: the structural walkers recurse into
    ``pallas_call`` kernel jaxprs, so TL002-TL005 audit the §3
    ``block_sub`` and §5 ``cache_events`` kernels in their production
    surroundings (interpret mode traces identically to compiled)."""
    cfg = MethodConfig(name="dsag", w=3, subpartitions=2)
    probe = _fused_probe(_probe_logreg(), cfg, kernel_backend="pallas")
    probe.name = "fused_logreg_grid_pallas"
    probe.description = (
        "fused scan body, logreg, grid §5 cache, Pallas kernel backend"
    )
    return probe


def _build_fused_pca_grid_pallas() -> EntryProbe:
    cfg = MethodConfig(name="dsag", w=3, subpartitions=2)
    probe = _fused_probe(_probe_pca(), cfg, kernel_backend="pallas")
    probe.name = "fused_pca_grid_pallas"
    probe.description = (
        "fused scan body, PCA, grid §5 cache, Pallas kernel backend"
    )
    return probe


def _kernels_probe(problem, name: str, description: str) -> EntryProbe:
    kernels = problem.fused_kernels()
    pad_w = 16  # width_bucket(m, n) for 8 < m <= 16 at n=64
    with enable_x64():
        starts = jnp.asarray([1, 17, 33], dtype=jnp.int64)
        widths = jnp.asarray([11, 16, 13], dtype=jnp.int64)
        Vb = jnp.zeros(
            (3,) + kernels.value_shape, dtype=kernels.value_dtype
        )
        jaxpr = jax.make_jaxpr(
            functools.partial(kernels.sub_blocks, pad_width=pad_w)
        )(Vb, starts, widths)
    return EntryProbe(
        name=name,
        description=description,
        jaxpr=jaxpr,
        padded_axis_sizes=(pad_w,),
        declared_output_dtypes=(np.dtype(kernels.value_dtype),),
    )


def _build_kernels_logreg() -> EntryProbe:
    return _kernels_probe(
        _probe_logreg(),
        "kernels_logreg",
        "FusedKernels.sub_blocks, logreg (width-bucket masked reduce)",
    )


def _build_kernels_pca() -> EntryProbe:
    return _kernels_probe(
        _probe_pca(),
        "kernels_pca",
        "FusedKernels.sub_blocks, PCA (width-bucket masked matmul)",
    )


def _build_lb_update() -> EntryProbe:
    from repro.lb import jit_optimizer as jlb

    S, N = _PROBE_SCENARIOS, _PROBE_WORKERS
    ladder = (1, 2, 4, 8, 16)
    with enable_x64():
        rng = np.random.default_rng(7)
        args = (
            jnp.asarray(np.full((S, N), 2.0)),  # p_cur
            jnp.asarray(rng.uniform(1e-3, 5e-3, (S, N))),  # e_comm
            jnp.asarray(rng.uniform(1e-7, 1e-6, (S, N))),  # v_comm
            jnp.asarray(rng.uniform(1e-2, 5e-2, (S, N))),  # e_comp
            jnp.asarray(rng.uniform(1e-5, 1e-4, (S, N))),  # v_comp
            jnp.asarray(np.full((S, N), 16.0)),  # n_j
            jnp.asarray(np.full((S,), np.nan)),  # h_min
            jnp.asarray(np.ones((S,), bool)),  # active
        )
        fn = functools.partial(
            jlb.lb_update,
            ladder=ladder,
            w=3,
            margin=0.02,
            key=jax.random.PRNGKey(0),
        )
        jaxpr = jax.make_jaxpr(fn)(*args)
    return EntryProbe(
        name="lb_update",
        description="§6 optimizer round (Algorithm 1 + publication gate)",
        jaxpr=jaxpr,
    )


def _build_kernels_ops() -> EntryProbe:
    from repro.kernels import ops

    def probe(x, v, g, c, h, mask):
        gram = ops.gram_matvec_op(x, v, interpret=True)
        new_c, new_h = ops.dsag_cache_update_op(g, c, h, mask, interpret=True)
        return gram, new_c, new_h

    args = (
        jnp.zeros((32, 8), jnp.float32),
        jnp.zeros((8, 4), jnp.float32),
        jnp.zeros((4, 64), jnp.float32),
        jnp.zeros((4, 64), jnp.float32),
        jnp.zeros((64,), jnp.float32),
        jnp.zeros((4,), jnp.bool_),
    )
    jaxpr = jax.make_jaxpr(probe)(*args)
    return EntryProbe(
        name="kernels_ops",
        description="Pallas kernel wrappers (gram_matvec, dsag_cache_update)",
        jaxpr=jaxpr,
    )


def _build_dsag_pjit() -> EntryProbe:
    from repro.configs.base import TrainConfig
    from repro.core.dsag_pjit import GroupSpec, dsag_update, init_dsag_state

    tc = TrainConfig()
    gs = GroupSpec(num_groups=4, axes=())
    params_like = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
    dsag0 = init_dsag_state(params_like, gs, tc)
    grads = {"w": jnp.zeros((4, 8, 16), jnp.float32)}
    mask = jnp.ones((4,), jnp.bool_)
    flush = jnp.zeros((4,), jnp.bool_)
    jaxpr = jax.make_jaxpr(dsag_update)(dsag0, grads, mask, flush)
    return EntryProbe(
        name="dsag_pjit",
        description="live-system DSAG cache rule (core/dsag_pjit.dsag_update)",
        jaxpr=jaxpr,
    )


#: name -> builder.  Names are stable API (baselines and CI artifacts key
#: on them); keep additions append-only.
ENTRIES: dict[str, Callable[[], EntryProbe]] = {
    "latency": _build_latency,
    "fused_logreg_grid": _build_fused_logreg_grid,
    "fused_logreg_lb": _build_fused_logreg_lb,
    "fused_logreg_tiled": _build_fused_logreg_tiled,
    "fused_logreg_churn": _build_fused_logreg_churn,
    "fused_pca_grid": _build_fused_pca_grid,
    "fused_logreg_grid_pallas": _build_fused_logreg_grid_pallas,
    "fused_pca_grid_pallas": _build_fused_pca_grid_pallas,
    "kernels_logreg": _build_kernels_logreg,
    "kernels_pca": _build_kernels_pca,
    "lb_update": _build_lb_update,
    "kernels_ops": _build_kernels_ops,
    "dsag_pjit": _build_dsag_pjit,
}


def build_entries(names) -> list:
    """Build the named probes ('all' or an iterable of registry keys)."""
    if names == "all" or names == ["all"]:
        names = list(ENTRIES)
    unknown = [n for n in names if n not in ENTRIES]
    if unknown:
        raise KeyError(
            f"unknown lint entries {unknown}; known: {sorted(ENTRIES)}"
        )
    return [ENTRIES[n]() for n in names]
