"""The TL001–TL005 rule implementations.

Each rule is a function ``(EntryProbe) -> list[Finding]``; rules skip
entries their annotations don't apply to.  See
:mod:`repro.analysis.lint.findings` for the catalogue and
``docs/ARCHITECTURE.md`` ("Checked invariants") for the incidents behind
each rule.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.experimental import enable_x64

from repro.analysis import hlo
from repro.analysis.lint.entries import EntryProbe
from repro.analysis.lint.findings import Finding
from repro.analysis.lint.jaxpr_utils import (
    aval_bytes,
    iter_eqns,
    iter_eqns_scoped,
    iter_loops,
    reaches_comparison,
    stray_chain_reads,
)

#: reductions with an ``axes`` param (TL003)
_REDUCE_PRIMS = frozenset(
    {"reduce_sum", "reduce_prod", "reduce_max", "reduce_min", "reduce_and", "reduce_or"}
)


def check_fma_seam(entry: EntryProbe) -> list:
    """TL001: the compiled latency chain must match op-by-op evaluation.

    LLVM contracts an unprotected mul→add into an FMA *only* when it sees
    the whole chain at once — i.e. in the jitted graph, never in op-by-op
    eager dispatch.  So a bitwise diff between the two evaluations is a
    direct detector for a missing seam: any mismatch means the §3 product
    reached ``task_finish_time`` contraction-exposed.
    """
    if entry.latency_probe is None:
        return []
    fn, batches = entry.latency_probe
    with enable_x64():
        # wrap in a fresh function object: jax's executable cache is keyed
        # on identity, and a stale entry (e.g. traced before the seam was
        # edited out) would mask a real regression
        jitted = jax.jit(lambda *args: fn(*args))
        for i, args in enumerate(batches):
            compiled = np.asarray(jitted(*args))
            eager = np.asarray(fn(*args))
            mismatches = int(np.count_nonzero(compiled != eager))
            if mismatches:
                return [
                    Finding(
                        code="TL001",
                        entry=entry.name,
                        symbol=f"batch{i}",
                        message=(
                            f"compiled latency chain differs from op-by-op "
                            f"evaluation in {mismatches}/{compiled.size} "
                            f"elements — the §3 product reaches "
                            f"task_finish_time without a contraction-"
                            f"blocking seam (guarded_comp_latency)"
                        ),
                    )
                ]
    return []


def _hlo_copy_evidence(entry: EntryProbe) -> str:
    """Trip-weighted ``copy`` traffic from the entry's optimized HLO.

    Secondary evidence attached to a confirmed TL002 finding: compiles
    the entry once and sums copy-instruction bytes weighted by
    :func:`repro.analysis.hlo.loop_multiplicities` trip counts.
    """
    if entry.hlo_fn_args is None:
        return ""
    fn, args = entry.hlo_fn_args
    try:
        with enable_x64():
            text = jax.jit(fn).lower(*args).compile().as_text()
        comps, hlo_entry = hlo.parse_computations(text)
        if hlo_entry is None:
            return ""
        mult = hlo.loop_multiplicities(comps, hlo_entry)
        copied = 0.0
        for name, m in mult.items():
            for inst in comps[name].instructions:
                if inst.op == "copy":
                    copied += hlo._shape_list_bytes(inst.type_str) * m
        return (
            f"; optimized HLO shows ~{copied / 1e6:.2f} MB of trip-weighted "
            f"copy traffic"
        )
    except Exception:  # evidence is best-effort; the jaxpr finding stands
        return ""


def check_carry_copy(entry: EntryProbe) -> list:
    """TL002: scatter-updated loop-carried tables must be write-only.

    For every loop carry that is a large float table produced by a pure
    scatter write-chain from its own carried input, any *other* consumer
    of a chain member (a gather, slice, arithmetic) forces XLA to
    materialize a pre-write copy of the whole table once per trip — the
    PR 4/5 "copy cliff".  Live values must instead be reconstructed from
    small read-only side tables (see ``fused._apply_cache_events_lb``).
    """
    if entry.jaxpr is None:
        return []
    findings = []
    evidence = None
    for loop in iter_loops(entry.jaxpr.jaxpr):
        # the cliff is about *nested* loops (the per-iteration rank loops):
        # a top-level batching scan reads and rewrites its carries once per
        # training iteration by design
        if loop.depth < 1:
            continue
        for invar, outvar in loop.carries:
            aval = invar.aval
            if getattr(aval, "ndim", 0) < 3:
                continue
            if getattr(aval, "dtype", None) is None or aval.dtype.kind != "f":
                continue
            strays = stray_chain_reads(loop.body, invar, outvar)
            if not strays:
                continue
            if evidence is None:
                evidence = _hlo_copy_evidence(entry)
            reads = ", ".join(sorted({p for p, _ in strays}))
            findings.append(
                Finding(
                    code="TL002",
                    entry=entry.name,
                    symbol=f"{loop.path}:{aval}",
                    message=(
                        f"scatter-carried table {aval} is also read inside "
                        f"its loop by [{reads}] — defeats in-place carry "
                        f"aliasing (one full-table copy per trip)"
                        f"{evidence}"
                    ),
                )
            )
    return findings


def check_pad_variant_reduce(entry: EntryProbe) -> list:
    """TL003: reductions over width-bucket padded axes need mask evidence.

    XLA reductions are NOT pad-length invariant (lane grouping changes
    with the static shape), so every reduction or matmul contraction over
    a ``width_bucket`` padded axis must consume data masked by an
    ``iota < widths``-style comparison — otherwise the pad rows' values
    (gather-clamped copies of real rows) silently enter the sum.
    """
    if entry.jaxpr is None or not entry.padded_axis_sizes:
        return []
    sizes = set(entry.padded_axis_sizes)
    findings = []
    for eqn, scope, path in iter_eqns_scoped(entry.jaxpr.jaxpr):
        name = eqn.primitive.name
        if name in _REDUCE_PRIMS:
            operand = eqn.invars[0]
            shape = getattr(operand.aval, "shape", ())
            padded = [
                ax
                for ax in eqn.params.get("axes", ())
                if ax < len(shape) and shape[ax] in sizes
            ]
            if padded and not reaches_comparison(scope, operand):
                findings.append(
                    Finding(
                        code="TL003",
                        entry=entry.name,
                        symbol=f"{path}/{name}:{operand.aval}",
                        message=(
                            f"{name} over padded axis "
                            f"{padded} of {operand.aval} has no mask "
                            f"evidence (no <=-style comparison upstream)"
                        ),
                    )
                )
        elif name == "dot_general":
            (lc, rc), _ = eqn.params["dimension_numbers"]
            lhs, rhs = eqn.invars[0], eqn.invars[1]
            lshape = getattr(lhs.aval, "shape", ())
            padded = [d for d in lc if d < len(lshape) and lshape[d] in sizes]
            if padded and not (
                reaches_comparison(scope, lhs) or reaches_comparison(scope, rhs)
            ):
                findings.append(
                    Finding(
                        code="TL003",
                        entry=entry.name,
                        symbol=f"{path}/{name}:{lhs.aval}",
                        message=(
                            f"matmul contraction over padded axis {padded} "
                            f"of {lhs.aval} has no mask evidence on either "
                            f"operand"
                        ),
                    )
                )
    return findings


def check_dtype_leak(entry: EntryProbe) -> list:
    """TL004: strong dtypes in loop carries / entry outputs + kernel contract.

    A weak-typed carry or output means a python-scalar-promoted value
    reached a persistent buffer — the next arithmetic against it can
    re-promote and silently change the iterate dtype.  Kernel entries
    additionally pin their traced output dtypes to the declared
    ``FusedKernels.value_dtype`` (the fused engine sizes its in-flight
    buffers with it).
    """
    if entry.jaxpr is None:
        return []
    findings = []
    for loop in iter_loops(entry.jaxpr.jaxpr):
        for invar, _ in loop.carries:
            aval = invar.aval
            if (
                getattr(aval, "ndim", 0) == 0
                and getattr(aval, "dtype", None) is not None
                and aval.dtype.kind in "iub"
            ):
                # fori_loop/while counters are weak int scalars by jax
                # construction; the leak class is float/array carries
                continue
            if getattr(invar.aval, "weak_type", False):
                findings.append(
                    Finding(
                        code="TL004",
                        entry=entry.name,
                        symbol=f"{loop.path}:carry:{invar.aval}",
                        message=(
                            f"loop carry {invar.aval} is weakly typed — "
                            f"initialize with an explicit dtype"
                        ),
                    )
                )
    for i, aval in enumerate(entry.jaxpr.out_avals):
        if getattr(aval, "weak_type", False):
            findings.append(
                Finding(
                    code="TL004",
                    entry=entry.name,
                    symbol=f"output[{i}]:{aval}",
                    message=f"entry output {i} ({aval}) is weakly typed",
                )
            )
    if entry.declared_output_dtypes is not None:
        outs = entry.jaxpr.out_avals
        for i, want in enumerate(entry.declared_output_dtypes):
            if i >= len(outs):
                break
            got = getattr(outs[i], "dtype", None)
            if got is not None and np.dtype(got) != np.dtype(want):
                findings.append(
                    Finding(
                        code="TL004",
                        entry=entry.name,
                        symbol=f"output[{i}]:{outs[i]}",
                        message=(
                            f"kernel output {i} is {got}, declared "
                            f"value_dtype is {np.dtype(want)} — a "
                            f"float64<->float32 leak into the engine's "
                            f"value buffers"
                        ),
                    )
                )
    return findings


def check_cond_capture(entry: EntryProbe, min_capture_bytes: int = 16384) -> list:
    """TL005: no ``lax.cond`` deep in rank loops capturing large buffers.

    Inside a loop, each ``cond`` branch invocation copies its operands on
    the CPU thunk runtime (~9 ms per event rank for the §5 value table in
    PR 4's first attempt).  Conds at the training-scan body level
    (``depth <= cond_depth_threshold``) are per-iteration branches and
    exempt; deeper conds must not take operands at or above
    ``min_capture_bytes``.
    """
    if entry.jaxpr is None:
        return []
    findings = []
    for eqn, path, depth in iter_eqns(entry.jaxpr.jaxpr):
        if eqn.primitive.name != "cond":
            continue
        if depth <= entry.cond_depth_threshold:
            continue
        big = [
            v.aval
            for v in eqn.invars[1:]
            if hasattr(v, "aval") and aval_bytes(v.aval) >= min_capture_bytes
        ]
        if big:
            largest = max(big, key=aval_bytes)
            findings.append(
                Finding(
                    code="TL005",
                    entry=entry.name,
                    symbol=f"{path}/cond:{largest}",
                    message=(
                        f"lax.cond at loop depth {depth} captures "
                        f"{len(big)} large buffer(s) (largest {largest}, "
                        f"{aval_bytes(largest)} bytes) — each trip copies "
                        f"them on the thunk runtime"
                    ),
                )
            )
    return findings


#: rule code -> implementation, in reporting order
ALL_RULES = (
    ("TL001", check_fma_seam),
    ("TL002", check_carry_copy),
    ("TL003", check_pad_variant_reduce),
    ("TL004", check_dtype_leak),
    ("TL005", check_cond_capture),
)
