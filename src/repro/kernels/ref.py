"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gram_matvec_ref(x: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """X^T (X V) in fp32 accumulation.  x: [n, d], v: [d, k] -> [d, k]."""
    xv = jnp.einsum("nd,dk->nk", x.astype(jnp.float32), v.astype(jnp.float32))
    return jnp.einsum("nd,nk->dk", x.astype(jnp.float32), xv)


def dsag_update_ref(
    g: jnp.ndarray,  # [p, n] fresh per-group gradients
    c: jnp.ndarray,  # [p, n] cache slots
    h: jnp.ndarray,  # [n] running sum
    mask: jnp.ndarray,  # [p] float (0/1)
):
    """Fused DSAG cache update:  h += Σ_i m_i (g_i - c_i);  c_i <- m_i?g_i:c_i.
    Returns (new_c, new_h)."""
    gf = g.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    m = mask.astype(jnp.float32)[:, None]
    new_c = m * gf + (1.0 - m) * cf
    new_h = h.astype(jnp.float32) + (m * (gf - cf)).sum(axis=0)
    return new_c.astype(c.dtype), new_h


def block_sub_pca_ref(x, Vb, starts, widths, pad_width: int):
    """§3 PCA block subgradients, clip-gather jnp form (block_sub twin).

    x: [n, d], Vb: [G, d, k], starts/widths: [G] -> [G, d, k].  The same
    expression ``PCAProblem.sub_blocks`` evaluates (pre-batch-padding).
    """
    n = x.shape[0]
    idx = jnp.clip(starts[:, None] - 1 + jnp.arange(pad_width)[None, :], 0, n - 1)
    xg = x[idx]  # [G, pad, d]
    mask = (jnp.arange(pad_width)[None, :] < widths[:, None]).astype(x.dtype)
    xg = xg * mask[:, :, None]
    return -(jnp.swapaxes(xg, 1, 2) @ (xg @ Vb))


def block_sub_logreg_ref(x, y, Vb, starts, widths, pad_width: int):
    """§3 logreg block subgradients, clip-gather jnp form (block_sub twin).

    x: [n, d], y: [n], Vb: [G, d] -> [G, d].  The reduce-based
    (batch-invariant) expression ``LogisticRegressionProblem.sub_blocks``
    evaluates (pre-batch-padding).
    """
    n = x.shape[0]
    idx = jnp.clip(starts[:, None] - 1 + jnp.arange(pad_width)[None, :], 0, n - 1)
    xg = x[idx]  # [G, pad, d]
    yg = y[idx] * (jnp.arange(pad_width)[None, :] < widths[:, None]).astype(y.dtype)
    z = yg * jnp.sum(xg * Vb[:, None, :], axis=2)
    s = jax.nn.sigmoid(-z)
    return -jnp.sum(xg * (yg * s)[:, :, None], axis=1) / n


def grid_cache_update_ref(
    valid_r, slot_r, tag_r, vals_r, sums, values, iters, covered, rejected,
    slot_width,
):
    """§5 grid-cache rank walk, pure-jnp form (cache_events twin).

    Rank-ordered ``[S, R]`` event tables applied to ``[S, E, F]`` cache
    state via the masked-scatter ``fori_loop`` the fused engine's XLA
    path uses; returns ``(sums, values, iters, covered, rejected)``.
    """
    S, R = valid_r.shape
    s_idx = jnp.arange(S)

    def rank_body(j, state):
        sums, values, iters, covered, rejected = state
        valid = valid_r[:, j]
        slot = slot_r[:, j]
        tag = tag_r[:, j]
        v = vals_r[:, j]
        cur_it = iters[s_idx, slot]
        active = cur_it >= 0
        dom = active & (cur_it >= tag)
        acc = valid & ~dom
        rej = valid & dom
        old = values[s_idx, slot]
        delta = v - jnp.where(active[:, None], old, 0.0)
        sums = jnp.where(acc[:, None], sums + delta, sums)
        values = values.at[s_idx, slot].set(jnp.where(acc[:, None], v, old))
        iters = iters.at[s_idx, slot].set(jnp.where(acc, tag, cur_it))
        covered = covered + jnp.where(acc & ~active, slot_width[slot], 0)
        rejected = rejected + rej.astype(rejected.dtype)
        return sums, values, iters, covered, rejected

    return jax.lax.fori_loop(
        0, R, rank_body, (sums, values, iters, covered, rejected)
    )


def flash_attention_ref(
    q: jnp.ndarray,  # [b, h, sq, d]
    k: jnp.ndarray,  # [b, h, sk, d]
    v: jnp.ndarray,  # [b, h, sk, d]
    *,
    causal: bool = True,
) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.arange(sk)[None, :] <= (jnp.arange(sq)[:, None] + (sk - sq))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
