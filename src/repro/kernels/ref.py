"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gram_matvec_ref(x: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """X^T (X V) in fp32 accumulation.  x: [n, d], v: [d, k] -> [d, k]."""
    xv = jnp.einsum("nd,dk->nk", x.astype(jnp.float32), v.astype(jnp.float32))
    return jnp.einsum("nd,nk->dk", x.astype(jnp.float32), xv)


def dsag_update_ref(
    g: jnp.ndarray,  # [p, n] fresh per-group gradients
    c: jnp.ndarray,  # [p, n] cache slots
    h: jnp.ndarray,  # [n] running sum
    mask: jnp.ndarray,  # [p] float (0/1)
):
    """Fused DSAG cache update:  h += Σ_i m_i (g_i - c_i);  c_i <- m_i?g_i:c_i.
    Returns (new_c, new_h)."""
    gf = g.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    m = mask.astype(jnp.float32)[:, None]
    new_c = m * gf + (1.0 - m) * cf
    new_h = h.astype(jnp.float32) + (m * (gf - cf)).sum(axis=0)
    return new_c.astype(c.dtype), new_h


def flash_attention_ref(
    q: jnp.ndarray,  # [b, h, sq, d]
    k: jnp.ndarray,  # [b, h, sk, d]
    v: jnp.ndarray,  # [b, h, sk, d]
    *,
    causal: bool = True,
) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.arange(sk)[None, :] <= (jnp.arange(sq)[:, None] + (sk - sq))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
