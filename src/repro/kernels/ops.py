"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode for
correctness validation; on TPU they compile natively.  Wrappers handle
padding to hardware-aligned tiles and expose the same signatures as the
``ref.py`` oracles.

The public entry points are plain functions that resolve the
``interpret=None`` default *eagerly* (``jax.default_backend()`` is a
process-level lookup — reading it at trace time inside a jitted wrapper
bakes the decision into the cached executable, which goes stale when the
default backend changes) and only then enter an inner jit with the
resolved bool as a static argument, so every interpret decision is part
of the jit key.  Degenerate shapes (empty group axes, zero rows) return
through the ``ref.py`` oracles instead of launching zero-size grids,
whose output buffers Pallas never writes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dsag_update import dsag_cache_update
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gram_matvec import gram_matvec


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _gram_matvec_jit(x, v, *, block_rows: int, interpret: bool):
    n, d = x.shape
    _, k = v.shape
    n_pad = _round_up(n, block_rows)
    k_pad = _round_up(k, 128)
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, k_pad - k)))
    out = gram_matvec(xp, vp, block_rows=block_rows, interpret=interpret)
    return out[:, :k]


def gram_matvec_op(
    x: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_rows: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """G = X^T (X V), MXU-tiled; pads n to the row block and k to 128."""
    interpret = _interpret_default() if interpret is None else interpret
    n, d = x.shape
    _, k = v.shape
    if n == 0 or d == 0 or k == 0:
        # a zero-size dimension would make the row grid empty (the output
        # buffer is never written) or produce degenerate tiles; the oracle
        # is exact here (an empty contraction is all zeros)
        return ref.gram_matvec_ref(x, v)
    return _gram_matvec_jit(x, v, block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _dsag_cache_update_jit(g, c, h, mask, *, block: int, interpret: bool):
    p, n = g.shape
    n_pad = _round_up(n, block)
    gp = jnp.pad(g, ((0, 0), (0, n_pad - n)))
    cp = jnp.pad(c, ((0, 0), (0, n_pad - n)))
    hp = jnp.pad(h, ((0, n_pad - n),))
    new_c, new_h = dsag_cache_update(gp, cp, hp, mask, block=block, interpret=interpret)
    return new_c[:, :n], new_h[:n]


def dsag_cache_update_op(
    g: jnp.ndarray,
    c: jnp.ndarray,
    h: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    block: int = 2048,
    interpret: bool | None = None,
):
    """Fused masked DSAG cache update over flattened [p, n] slots."""
    interpret = _interpret_default() if interpret is None else interpret
    p, n = g.shape
    if p == 0 or n == 0:
        # p == 0 makes the inner grid dim zero — the h accumulator scratch
        # is never initialized or flushed, so new_h would be garbage; the
        # oracle's empty sum (h + 0) is the exact semantics
        return ref.dsag_update_ref(g, c, h, mask)
    return _dsag_cache_update_jit(g, c, h, mask, block=block, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def _flash_attention_jit(q, k, v, *, causal, block_q, block_k, interpret):
    b, h, sq, d = q.shape
    sk = k.shape[2]
    d_pad = _round_up(d, 128)
    sq_pad = _round_up(sq, block_q)
    sk_pad = _round_up(sk, block_k)

    def pad(t, s_pad):
        return jnp.pad(
            t, ((0, 0), (0, 0), (0, s_pad - t.shape[2]), (0, d_pad - d))
        ).reshape(b * h, s_pad, d_pad)

    qp, kp, vp = pad(q, sq_pad), pad(k, sk_pad), pad(v, sk_pad)
    out = flash_attention(
        qp, kp, vp, causal=causal, block_q=block_q, block_k=block_k,
        scale=1.0 / (d ** 0.5),  # true head_dim, not the padded one
        interpret=interpret,
        # true sequence lengths: the causal mask is bottom-right aligned to
        # them and padded tail keys are excluded explicitly, so sq != sk and
        # unaligned sk are handled (not silently mis-masked)
        true_sq=sq,
        true_sk=sk,
    )
    return out.reshape(b, h, sq_pad, d_pad)[:, :, :sq, :d]


def flash_attention_op(
    q: jnp.ndarray,  # [b, h, sq, d]
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Flash attention over [b, h, s, d]; pads head_dim to 128 lanes."""
    interpret = _interpret_default() if interpret is None else interpret
    sq = q.shape[2]
    sk = k.shape[2]
    if not causal and sk % block_k != 0:
        # zero-padded keys would enter a non-causal softmax; callers must
        # align sk (the causal path masks them via the true-length bound)
        raise ValueError(f"non-causal flash requires sk % block_k == 0, got {sk}")
    if causal and sq > sk:
        # bottom-right alignment gives the leading sq - sk query rows zero
        # attendable keys — a softmax over the empty set; reject instead of
        # returning the ref oracle's arbitrary uniform-weight fallback
        raise ValueError(
            f"causal flash requires sq <= sk (bottom-right alignment), "
            f"got sq={sq} > sk={sk}"
        )
    return _flash_attention_jit(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )


# Re-exported oracles so tests/benchmarks import one module.
gram_matvec_ref = ref.gram_matvec_ref
dsag_update_ref = ref.dsag_update_ref
flash_attention_ref = ref.flash_attention_ref
