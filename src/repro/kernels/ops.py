"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode for
correctness validation; on TPU they compile natively.  Wrappers handle
padding to hardware-aligned tiles and expose the same signatures as the
``ref.py`` oracles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dsag_update import dsag_cache_update
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gram_matvec import gram_matvec


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gram_matvec_op(
    x: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_rows: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """G = X^T (X V), MXU-tiled; pads n to the row block and k to 128."""
    interpret = _interpret_default() if interpret is None else interpret
    n, d = x.shape
    _, k = v.shape
    n_pad = _round_up(n, block_rows)
    k_pad = _round_up(k, 128)
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, k_pad - k)))
    out = gram_matvec(xp, vp, block_rows=block_rows, interpret=interpret)
    return out[:, :k]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dsag_cache_update_op(
    g: jnp.ndarray,
    c: jnp.ndarray,
    h: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    block: int = 2048,
    interpret: bool | None = None,
):
    """Fused masked DSAG cache update over flattened [p, n] slots."""
    interpret = _interpret_default() if interpret is None else interpret
    p, n = g.shape
    n_pad = _round_up(n, block)
    gp = jnp.pad(g, ((0, 0), (0, n_pad - n)))
    cp = jnp.pad(c, ((0, 0), (0, n_pad - n)))
    hp = jnp.pad(h, ((0, n_pad - n),))
    new_c, new_h = dsag_cache_update(gp, cp, hp, mask, block=block, interpret=interpret)
    return new_c[:, :n], new_h[:n]


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_op(
    q: jnp.ndarray,  # [b, h, sq, d]
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Flash attention over [b, h, s, d]; pads head_dim to 128 lanes."""
    interpret = _interpret_default() if interpret is None else interpret
    b, h, sq, d = q.shape
    sk = k.shape[2]
    d_pad = _round_up(d, 128)
    sq_pad = _round_up(sq, block_q)
    sk_pad = _round_up(sk, block_k)

    def pad(t, s_pad):
        return jnp.pad(
            t, ((0, 0), (0, 0), (0, s_pad - t.shape[2]), (0, d_pad - d))
        ).reshape(b * h, s_pad, d_pad)

    if not causal and sk % block_k != 0:
        # zero-padded keys would enter a non-causal softmax; callers must
        # align sk (the causal mask already excludes tail pads when sq == sk)
        raise ValueError(f"non-causal flash requires sk % block_k == 0, got {sk}")
    qp, kp, vp = pad(q, sq_pad), pad(k, sk_pad), pad(v, sk_pad)
    out = flash_attention(
        qp, kp, vp, causal=causal, block_q=block_q, block_k=block_k,
        scale=1.0 / (d ** 0.5),  # true head_dim, not the padded one
        interpret=interpret,
    )
    return out.reshape(b, h, sq_pad, d_pad)[:, :, :sq, :d]

# Re-exported oracles so tests/benchmarks import one module.
gram_matvec_ref = ref.gram_matvec_ref
dsag_update_ref = ref.dsag_update_ref
flash_attention_ref = ref.flash_attention_ref
