"""Pallas TPU kernel for the fused DSAG cache update.

The Tier-1 hot loop per parameter leaf is memory-bound:

    h += Σ_i m_i (g_i - c_i)        c_i <- m_i ? g_i : c_i

A naive composition reads c twice and writes c and h in separate passes; the
fused kernel streams (g, c, h) through VMEM once: grid (n_blocks, P) with the
P dim innermost so the h-block accumulator lives in VMEM scratch across the
group sweep and is written exactly once per block.

Masks live in SMEM (scalar prefetch); math is fp32; c storage is bf16 (the
int8 variant dequantizes/requantizes in the same pass via ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dsag_kernel(mask_ref, g_ref, c_ref, h_ref, new_c_ref, new_h_ref, acc_ref):
    j = pl.program_id(0)  # block index (outer)
    i = pl.program_id(1)  # group index (inner)
    del j

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = h_ref[...].astype(jnp.float32).reshape(acc_ref.shape)

    m = mask_ref[i].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)  # (1, block)
    c = c_ref[...].astype(jnp.float32)
    new_val = m * g + (1.0 - m) * c
    acc_ref[...] += new_val - c
    new_c_ref[...] = new_val.astype(new_c_ref.dtype)

    @pl.when(i == pl.num_programs(1) - 1)
    def _flush():
        new_h_ref[...] = acc_ref[...].reshape(new_h_ref.shape)


def dsag_cache_update(
    g: jnp.ndarray,  # [p, n]
    c: jnp.ndarray,  # [p, n]
    h: jnp.ndarray,  # [n]
    mask: jnp.ndarray,  # [p] float32 (0/1)
    *,
    block: int = 2048,
    interpret: bool = False,
):
    """Returns (new_c [p, n], new_h [n]) in one HBM pass over g and c."""
    p, n = g.shape
    assert c.shape == (p, n) and h.shape == (n,), (g.shape, c.shape, h.shape)
    assert n % block == 0, (n, block)
    grid = (n // block, p)
    new_c, new_h = pl.pallas_call(
        _dsag_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block), lambda j, i, *_: (i, j)),
                pl.BlockSpec((1, block), lambda j, i, *_: (i, j)),
                pl.BlockSpec((block,), lambda j, i, *_: (j,)),
            ],
            out_specs=[
                pl.BlockSpec((1, block), lambda j, i, *_: (i, j)),
                pl.BlockSpec((block,), lambda j, i, *_: (j,)),
            ],
            scratch_shapes=[pltpu.VMEM((1, block), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((p, n), c.dtype),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(mask.astype(jnp.float32), g, c, h)
    return new_c, new_h
