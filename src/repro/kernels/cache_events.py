"""Pallas kernel for the §5 grid-cache event application.

Backs ``fused._apply_cache_events`` (cache mode ``"grid"`` — disjoint
fixed partitions, no §6 ladder) when the fused engine runs with
``EngineConfig(kernel_backend="pallas")``.  The XLA form walks event
ranks with a ``fori_loop`` whose every trip scatters into the full
``[S, E, ...]`` value table and re-reads it; here the whole walk is one
``pallas_call`` with grid ``(S,)`` — per scenario, the value/iteration
tables live in the program's output block, the running sums ride a
``fori_loop`` carry, and each rank touches exactly one table row via
dynamic load/store.  That fuses the §5 value-table write and
running-sum update into a single pass over the tables (the
``dsag_update.py`` fusion, generalized to rank-ordered events).

Bit-exactness: events arrive pre-sorted (the caller ranks them with the
same stable argsort + gathers ``_apply_cache_events_lb`` uses — pure
data movement), and each rank applies the literally identical float
expressions as the XLA loop body in the same per-scenario order, so the
results match the XLA path bit for bit (pinned by tests and the bench
kernel-backend tier).

Dtypes are taken from the operands (the engine's cache state is
float64/int64); interpret mode executes them exactly.  A real-TPU
deployment needs the f32/i32 state migration ROADMAP tracks — this
kernel is validated in interpret mode only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scalar(ref, j):
    """One scalar from a [1, R] block at dynamic column ``j``."""
    return pl.load(ref, (pl.dslice(0, 1), pl.dslice(j, 1)))[0, 0]


def _grid_cache_kernel(
    valid_ref,  # [1, R] bool, rank-ordered event validity
    slot_ref,  # [1, R] int, rank-ordered slots (pre-clipped to [0, E))
    tag_ref,  # [1, R] int, rank-ordered iteration tags
    vals_ref,  # [1, R, F] float, rank-ordered event values
    sums0_ref,  # [1, F] running-sum input
    values0_ref,  # [1, E, F] value-table input
    iters0_ref,  # [1, E] iteration-table input (-1 = inactive)
    width_ref,  # [E] per-slot interval widths
    cov0_ref,  # [1] covered-rows input
    rej0_ref,  # [1] rejected-events input
    sums_ref,  # [1, F] out
    values_ref,  # [1, E, F] out
    iters_ref,  # [1, E] out
    cov_ref,  # [1] out
    rej_ref,  # [1] out
):
    R = valid_ref.shape[1]
    # seed the output tables; the rank loop then updates them in place,
    # so "current value/iteration" reads below always see the latest write
    values_ref[...] = values0_ref[...]
    iters_ref[...] = iters0_ref[...]

    def rank_body(j, carry):
        sums, covered, rejected = carry
        valid = _scalar(valid_ref, j)
        slot = _scalar(slot_ref, j)
        tag = _scalar(tag_ref, j)
        v = pl.load(vals_ref, (pl.dslice(0, 1), pl.dslice(j, 1), slice(None)))[0, 0]
        cur_it = _scalar(iters_ref, slot)
        old = pl.load(
            values_ref, (pl.dslice(0, 1), pl.dslice(slot, 1), slice(None))
        )[0, 0]
        # staleness dominance + in-place update — the same expressions as
        # the XLA rank_body in fused._apply_cache_events, scenario-local
        active = cur_it >= 0
        dom = active & (cur_it >= tag)
        acc = valid & ~dom
        rej = valid & dom
        delta = v - jnp.where(active, old, 0.0)
        sums = jnp.where(acc, sums + delta, sums)
        pl.store(
            values_ref,
            (pl.dslice(0, 1), pl.dslice(slot, 1), slice(None)),
            jnp.where(acc, v, old)[None, None],
        )
        pl.store(
            iters_ref,
            (pl.dslice(0, 1), pl.dslice(slot, 1)),
            jnp.where(acc, tag, cur_it)[None, None],
        )
        sw = pl.load(width_ref, (pl.dslice(slot, 1),))[0]
        covered = covered + jnp.where(acc & ~active, sw, 0)
        rejected = rejected + rej.astype(rejected.dtype)
        return sums, covered, rejected

    sums, covered, rejected = jax.lax.fori_loop(
        0,
        R,
        rank_body,
        (sums0_ref[...][0], cov0_ref[...][0], rej0_ref[...][0]),
    )
    sums_ref[...] = sums[None]
    cov_ref[...] = covered[None]
    rej_ref[...] = rejected[None]


def grid_cache_update(
    valid_r: jnp.ndarray,  # [S, R] bool
    slot_r: jnp.ndarray,  # [S, R] int64, pre-clipped to [0, E)
    tag_r: jnp.ndarray,  # [S, R] int64
    vals_r: jnp.ndarray,  # [S, R, F] float64
    sums: jnp.ndarray,  # [S, F] float64
    values: jnp.ndarray,  # [S, E, F] float64
    iters: jnp.ndarray,  # [S, E] int64
    covered: jnp.ndarray,  # [S] int64
    rejected: jnp.ndarray,  # [S] int64
    slot_width: jnp.ndarray,  # [E] int64
    *,
    interpret: bool = False,
):
    """Apply rank-ordered §5 events to the grid cache in one table pass.

    Returns ``(sums, values, iters, covered, rejected)`` bit-identical to
    the XLA rank ``fori_loop`` on the same rank-ordered inputs.
    """
    S, R = valid_r.shape
    _, E, F = values.shape
    assert vals_r.shape == (S, R, F) and sums.shape == (S, F)
    row = lambda s: (s, 0)  # noqa: E731
    cube = lambda s: (s, 0, 0)  # noqa: E731
    return pl.pallas_call(
        _grid_cache_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, R), row),
            pl.BlockSpec((1, R), row),
            pl.BlockSpec((1, R), row),
            pl.BlockSpec((1, R, F), cube),
            pl.BlockSpec((1, F), row),
            pl.BlockSpec((1, E, F), cube),
            pl.BlockSpec((1, E), row),
            pl.BlockSpec((E,), lambda s: (0,)),
            pl.BlockSpec((1,), lambda s: (s,)),
            pl.BlockSpec((1,), lambda s: (s,)),
        ],
        out_specs=[
            pl.BlockSpec((1, F), row),
            pl.BlockSpec((1, E, F), cube),
            pl.BlockSpec((1, E), row),
            pl.BlockSpec((1,), lambda s: (s,)),
            pl.BlockSpec((1,), lambda s: (s,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, F), sums.dtype),
            jax.ShapeDtypeStruct((S, E, F), values.dtype),
            jax.ShapeDtypeStruct((S, E), iters.dtype),
            jax.ShapeDtypeStruct((S,), covered.dtype),
            jax.ShapeDtypeStruct((S,), rejected.dtype),
        ],
        interpret=interpret,
    )(
        valid_r,
        slot_r,
        tag_r,
        vals_r,
        sums,
        values,
        iters,
        slot_width,
        covered,
        rejected,
    )
