"""Pallas TPU kernel for the paper's Eq. (3) hot spot:  G = X^T (X V).

The two-einsum form reads X from HBM twice; this kernel streams X through
VMEM once per iteration: for each row block  Xb [bm, d]  it computes
P = Xb V on the MXU, immediately contracts  Xb^T P  and accumulates into a
fp32 VMEM scratch of shape [d, k].  One HBM pass over X, fp32 accumulation,
MXU-aligned tiles (bm and d multiples of 128 via wrapper padding; k padded
to >= 128 lanes).

Grid: (n // bm,)  — sequential on TPU, so the [d, k] accumulator scratch is
carried across grid steps and flushed on the last one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(x_ref, v_ref, out_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xb = x_ref[...].astype(jnp.float32)  # [bm, d]
    vv = v_ref[...].astype(jnp.float32)  # [d, k]
    p = jnp.dot(xb, vv, preferred_element_type=jnp.float32)  # [bm, k]
    acc_ref[...] += jnp.dot(xb.T, p, preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def gram_matvec(
    x: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """G = X^T (X V).  x: [n, d], v: [d, k] -> [d, k] (fp32)."""
    n, d = x.shape
    d2, k = v.shape
    assert d == d2, (x.shape, v.shape)
    assert n % block_rows == 0, (n, block_rows)
    grid = (n // block_rows,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, k), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, k), jnp.float32)],
        interpret=interpret,
    )(x, v)
