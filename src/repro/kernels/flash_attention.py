"""Pallas TPU flash-attention (forward) kernel.

Online-softmax attention: grid (batch*heads, q_blocks, kv_blocks) with the kv
dim innermost; running max/denominator/accumulator live in VMEM scratch
across the kv sweep, so the S x S score matrix never exists in HBM.  Causal
blocks above the diagonal are skipped entirely (they contribute nothing).

This is the TPU replacement for ``models.attention.chunked_attention`` on the
long-context serving path; training backward uses XLA remat of the jnp path
(writing the flash bwd kernel is tracked as future work in DESIGN.md).
MXU alignment: block_q/block_k multiples of 128; head_dim padded by ops.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale, causal, block_q, block_k, true_sq, true_sk,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    # bottom-right alignment: query row q attends keys <= q + (sk - sq),
    # matching ref.flash_attention_ref for sq != sk (decode-style shapes)
    offs = true_sk - true_sq

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # kv blocks strictly above the (aligned) diagonal or made entirely
        # of zero-padded tail keys contribute nothing
        run = (ki * block_k <= (qi + 1) * block_q - 1 + offs) & (
            ki * block_k < true_sk
        )

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [bq, bk]
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            # the kpos < true_sk bound excludes zero-padded tail keys, which
            # the diagonal alone only masks when sq == sk
            s = jnp.where((kpos <= qpos + offs) & (kpos < true_sk), s, NEG_INF)
        m_prev = m_ref[...]  # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # [bh, sq, d]
    k: jnp.ndarray,  # [bh, sk, d]
    v: jnp.ndarray,  # [bh, sk, d]
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    scale: float | None = None,
    interpret: bool = False,
    true_sq: int | None = None,
    true_sk: int | None = None,
) -> jnp.ndarray:
    """``true_sq`` / ``true_sk`` are the pre-padding sequence lengths; the
    causal mask aligns bottom-right to them and excludes padded tail keys.
    They default to the padded lengths (top-left mask over the full
    buffers — the pre-fix behavior, correct only when no key padding)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    true_sq = sq if true_sq is None else true_sq
    true_sk = sk if true_sk is None else true_sk
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    grid = (bh, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, true_sq=true_sq, true_sk=true_sk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denominator
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
