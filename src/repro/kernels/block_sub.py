"""Pallas kernels for the §3 width-bucketed block-subgradient gather.

These back ``FusedKernels.sub_blocks`` when the fused engine runs with
``EngineConfig(kernel_backend="pallas")``: one kernel dispatch per pow2
``width_bucket``, grid ``(G,)`` over the task batch, streaming each
task's row window of the data matrix through VMEM once (the
``gram_matvec.py`` accumulator pattern, minus the cross-block scratch —
a §3 block fits one program).

Bit-exactness contract (the reason these twins exist at all): the fused
engine's scan == host == scalar pins rest on every engine evaluating a
given width at the same static ``width_bucket`` pad with the same float
expressions.  So each program computes the *literally identical* jnp
expression as the XLA path at the identical ``[1, pad, d]`` shape — in
interpret mode that traces to the same CPU XLA ops, and the repo's
pinned batch-invariance of ``sub_blocks`` closes the loop to the
``[G, pad, d]`` batched form.  Two consequences:

* the XLA path's clip-gather ``X[clip(start-1+arange(pad), 0, n-1)]``
  is replaced by a *contiguous* window load: within-width rows never
  clip (``stop <= n``) and rows past the width are mask-zeroed, so a
  clamped window offset plus a roll moves the same bits into place
  (``off = min(start-1, n-pad)``, roll left by ``start-1-off``);
* the mask is a real ``iota < width`` comparison inside the kernel, so
  the tracelint TL003 mask-evidence walk (which recurses into
  ``pallas_call`` jaxprs) sees the same discipline as the XLA form.

On TPU the window load from ``ANY``-space would be an explicit DMA;
interpret mode (the only validated deployment — see ARCHITECTURE.md)
lowers ``pl.load`` directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _window_table(starts, widths, n: int, pad_width: int):
    """[G, 3] int32 (offset, shift, width) scalar-prefetch table.

    ``offset`` is the clamped contiguous window start, ``shift`` how far
    the roll must move row 0 back into place (0 whenever the window fits
    without clamping; at most ``pad_width - width`` otherwise, so rolled
    rows always land in the masked tail).
    """
    starts_m1 = (starts - 1).astype(jnp.int32)
    off = jnp.minimum(starts_m1, jnp.int32(n - pad_width))
    shift = starts_m1 - off
    return jnp.stack([off, shift, widths.astype(jnp.int32)], axis=1)


def _masked_window(tab_ref, x_ref, pad_width: int, dtype):
    """Load one task's ``[1, pad, d]`` row window plus its ``[1, pad]`` mask."""
    g = pl.program_id(0)
    off = tab_ref[g, 0]
    shift = tab_ref[g, 1]
    width = tab_ref[g, 2]
    win = pl.load(x_ref, (pl.dslice(off, pad_width), slice(None)))
    xg = jnp.roll(win, -shift, axis=0)[None]
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (1, pad_width), 1) < width
    ).astype(dtype)
    return xg, mask, shift


def _pca_kernel(tab_ref, x_ref, v_ref, o_ref, *, pad_width: int):
    xg, mask, _ = _masked_window(tab_ref, x_ref, pad_width, x_ref.dtype)
    xg = xg * mask[:, :, None]
    # identical expression to problems.PCAProblem.sub_blocks at [1, pad, d]
    o_ref[...] = -(jnp.swapaxes(xg, 1, 2) @ (xg @ v_ref[...]))


def _logreg_kernel(tab_ref, x_ref, y_ref, v_ref, o_ref, *, pad_width: int, n: int):
    xg, mask, shift = _masked_window(tab_ref, x_ref, pad_width, y_ref.dtype)
    g = pl.program_id(0)
    off = tab_ref[g, 0]
    yw = pl.load(y_ref, (pl.dslice(off, pad_width),))
    yg = jnp.roll(yw, -shift, axis=0)[None] * mask
    # identical reduce-based expression to LogisticRegressionProblem.sub_blocks
    z = yg * jnp.sum(xg * v_ref[...][:, None, :], axis=2)
    s = jax.nn.sigmoid(-z)
    o_ref[...] = -jnp.sum(xg * (yg * s)[:, :, None], axis=1) / n


def _check_pad(n: int, pad_width: int):
    if not 1 <= pad_width <= n:
        raise ValueError(
            f"pad_width must satisfy 1 <= pad_width <= num_samples "
            f"({pad_width} vs n={n}); width_bucket never exceeds n, so this "
            f"is a caller bug"
        )


def pca_block_sub(
    X: jnp.ndarray,  # [n, d] data matrix (stays in ANY/HBM space)
    Vb: jnp.ndarray,  # [G, d, k] per-task iterates
    starts: jnp.ndarray,  # [G] 1-indexed interval starts
    widths: jnp.ndarray,  # [G] interval widths (rows past each are masked)
    pad_width: int,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """§3 PCA block subgradients ``-X_b^T (X_b V)`` at a static gather width.

    Pallas twin of ``PCAProblem.sub_blocks``'s body (pre-``_pad_pow2``):
    returns ``[G, d, k]`` with row ``g`` bit-identical to the XLA form.
    """
    n, d = X.shape
    G, d2, k = Vb.shape
    assert d == d2, (X.shape, Vb.shape)
    _check_pad(n, pad_width)
    tab = _window_table(starts, widths, n, pad_width)
    return pl.pallas_call(
        functools.partial(_pca_kernel, pad_width=pad_width),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(G,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec((1, d, k), lambda g, tab: (g, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, d, k), lambda g, tab: (g, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((G, d, k), Vb.dtype),
        interpret=interpret,
    )(tab, X, Vb)


def logreg_block_sub(
    X: jnp.ndarray,  # [n, d]
    y: jnp.ndarray,  # [n] labels in {-1, +1}
    Vb: jnp.ndarray,  # [G, d]
    starts: jnp.ndarray,  # [G]
    widths: jnp.ndarray,  # [G]
    pad_width: int,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """§3 logistic-regression block subgradients at a static gather width.

    Pallas twin of ``LogisticRegressionProblem.sub_blocks``'s body
    (pre-``_pad_pow2``), keeping its reduce-based (batch-invariant) form:
    returns ``[G, d]`` with row ``g`` bit-identical to the XLA form.
    """
    n, d = X.shape
    G, d2 = Vb.shape
    assert d == d2 and y.shape == (n,), (X.shape, y.shape, Vb.shape)
    _check_pad(n, pad_width)
    tab = _window_table(starts, widths, n, pad_width)
    return pl.pallas_call(
        functools.partial(_logreg_kernel, pad_width=pad_width, n=n),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(G,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec((1, d), lambda g, tab: (g, 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda g, tab: (g, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((G, d), Vb.dtype),
        interpret=interpret,
    )(tab, X, y, Vb)
