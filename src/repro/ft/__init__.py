"""Fault tolerance: failure detection, deadline-mask selection, elastic rescale."""

from repro.ft.runtime import (
    DeadlineController,
    FailureDetector,
    StepInputs,
    elastic_remap_groups,
)
from repro.ft.validation import (
    ControlStreams,
    controller_streams,
    group_loads,
    pin_streams,
    trace_latency_fn,
)

__all__ = [
    "ControlStreams",
    "DeadlineController",
    "FailureDetector",
    "StepInputs",
    "controller_streams",
    "elastic_remap_groups",
    "group_loads",
    "pin_streams",
    "trace_latency_fn",
]
