"""Fault tolerance: failure detection, deadline-mask selection, elastic rescale."""

from repro.ft.runtime import DeadlineController, FailureDetector, elastic_remap_groups

__all__ = ["DeadlineController", "FailureDetector", "elastic_remap_groups"]
