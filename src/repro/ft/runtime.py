"""Tier-2 runtime control: deadlines, failure handling, elastic rescale.

This is the host-side loop that turns the paper's coordinator behavior into
the mask/flush/evict inputs of the compiled DSAG step:

* :class:`DeadlineController` — a virtual-time twin of the scalar
  :class:`repro.cluster.simulator.TrainingSimulator` event loop.  Each call
  to :meth:`DeadlineController.step_inputs` runs one iteration of the §4.2
  two-state worker machine (length-1 FILO queues, wait-for-w collection,
  the §5.1 margin rule) and returns the (mask, flush, evict) vector the
  compiled Tier-1 step consumes.  Because it uses the same shared float
  helpers (:func:`task_finish_time`, :func:`margin_deadline`) and the same
  heap discipline as the simulator, replaying one ``FleetTraces`` scenario
  through both produces bit-identical step-input streams — the cross-layer
  pin exercised by ``tests/test_live_validation.py``.
* :class:`FailureDetector` — heartbeat bookkeeping: a group missing
  ``max_misses`` consecutive deadlines is declared failed; DSAG proceeds with
  its mask permanently 0 (that is the paper's point — missing partitions only
  freeze ξ, they do not block progress) until the group rejoins.
* :func:`elastic_remap_groups` — on a DP-degree change (node loss / rescale),
  re-map sample->group assignment with the paper's Algorithm-2 alignment so
  surviving cache entries stay aligned to partition boundaries; unaligned
  slots are invalidated (mirrors §6.3 cache evictions).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections.abc import Callable

import numpy as np

from repro.cluster.simulator import margin_deadline, task_finish_time
from repro.latency.model import GammaParams
from repro.lb.partitioner import align_partitions, p_start, p_stop

#: ``latency_of(group, now) -> (comp_latency, comm_latency)`` — called once
#: per *started* task, in worker-index assignment order then queued-restart
#: (pop) order, matching the scalar simulator's draw-consumption order.
LatencyFn = Callable[[int, float], tuple[float, float]]


@dataclasses.dataclass
class StepInputs:
    """One step's coordinator decision, as consumed by ``dsag_update``.

    ``mask[i]``  — group i delivered this step's gradient within the
    collection window (the w-th-fresh margin deadline of §5.1).
    ``flush[i]`` — a *stale* result from group i landed this step and was
    accepted into the gradient cache (§5 staleness-dominance rule).
    ``evict[i]`` — group i died this step and its cache entry was cleared
    (§6.3); ξ drops until the group refills its slot.
    """

    mask: np.ndarray  # [G] bool
    flush: np.ndarray  # [G] bool
    evict: np.ndarray  # [G] bool
    iter_start: float  # virtual time at which this step's tasks were assigned
    elapsed: float  # virtual time the collection took (now - iter_start)
    deadline: float  # §5.1 margin deadline (inf when the margin is inactive)


@dataclasses.dataclass
class DeadlineController:
    """Per-step (mask, flush, evict) selection for the live DSAG trainer.

    The controller is an event machine over virtual time: groups are the
    §4.2 two-state workers, tasks are per-step gradient computations, and
    latencies come from ``latency_of`` (a trace replay, a live sampler, or
    real measured round-trips).  ``accepts_stale=True`` gives DSAG
    semantics (stale arrivals flush into the cache and the §5.1 margin
    keeps collecting past the w-th fresh result); ``False`` gives SAG
    (stale arrivals are dropped, collection stops at the w-th fresh).
    """

    num_groups: int
    w: int  # wait for the w fastest groups
    margin: float = 0.02  # paper §5.1
    window: int = 50  # latency samples kept per group (telemetry/prediction)
    accepts_stale: bool = True  # DSAG; False = SAG-style fresh-only

    def __post_init__(self):
        if not (1 <= self.w <= self.num_groups):
            raise ValueError(f"w={self.w} not in 1..{self.num_groups}")
        self._lat: list[list[float]] = [[] for _ in range(self.num_groups)]
        self._rng = np.random.default_rng(0)  # persistent: fresh draws per call
        # ---- event-machine state (virtual-time twin of the simulator) ----
        self._now = 0.0
        self._step = 0
        self._seq = 0
        #: (finish, seq, generation, group, task_iteration, latency); a
        #: group's generation is bumped when a death discards its in-flight
        #: task, invalidating the queued heap event without disturbing the
        #: (finish, seq) pop order
        self._heap: list[tuple[float, int, int, int, int, float]] = []
        self._gen = np.zeros(self.num_groups, dtype=np.int64)
        self._busy_until = np.zeros(self.num_groups, dtype=np.float64)
        self._queued: list[int | None] = [None] * self.num_groups
        self._filled = np.zeros(self.num_groups, dtype=bool)  # cache slot held

    # ---- telemetry / §5.1 prediction ------------------------------------
    @property
    def now(self) -> float:
        """Current virtual time (completion time of the last step)."""
        return self._now

    def record(self, group: int, latency: float) -> None:
        dq = self._lat[group]
        dq.append(latency)
        if len(dq) > self.window:
            dq.pop(0)

    def deadline(self) -> float:
        """Predicted latency of the w-th fastest group, plus the margin.

        Monte-Carlo order statistic under per-group gammas (§4.1) fitted to
        the profiled moving window.  This is the *predictive* deadline used
        for reporting; the per-step collection window itself is event-driven
        (the §5.1 rule relative to the observed w-th fresh arrival).
        """
        means = np.array(
            [np.mean(l) if l else np.inf for l in self._lat], dtype=np.float64
        )
        if np.isinf(means).any():
            return np.inf  # no profile yet: wait for everyone
        stds = np.array(
            [np.std(l) if len(l) > 1 else means[i] * 0.1 for i, l in enumerate(self._lat)]
        )
        draws = np.stack(
            [
                GammaParams.from_mean_var(m, max(s, 1e-9) ** 2).sample(self._rng, 256)
                for m, s in zip(means, stds)
            ],
            axis=1,
        )
        kth = np.partition(draws, self.w - 1, axis=1)[:, self.w - 1]
        return float(kth.mean()) * (1.0 + self.margin)

    # ---- the event machine ----------------------------------------------
    def step_inputs(
        self,
        latency_of: LatencyFn,
        *,
        alive: np.ndarray | None = None,
    ) -> StepInputs:
        """Run one coordinator iteration and return its step inputs.

        ``latency_of(group, now)`` is invoked exactly once per started task
        (idle groups at assignment, then queued restarts as results pop), so
        a trace-backed callable consumes draws in the same order as the
        scalar simulator's ``TraceLatencySource``.  ``alive`` marks groups
        that are up *at assignment time*; a freshly-dead group's in-flight
        task is discarded and its cache slot eviction is reported.
        """
        G = self.num_groups
        mask = np.zeros(G, dtype=bool)
        flush = np.zeros(G, dtype=bool)
        evict = np.zeros(G, dtype=bool)
        now = self._now
        t = self._step

        if alive is None:
            w_eff = self.w
        else:
            alive = np.asarray(alive, dtype=bool)
            for i in range(G):
                if not alive[i]:
                    if self._busy_until[i] > now or self._queued[i] is not None:
                        # dead at assignment: the in-flight completion never
                        # happens and the queued task is dropped
                        self._gen[i] += 1
                        self._busy_until[i] = now
                        self._queued[i] = None
                    if self._filled[i]:
                        evict[i] = True  # §6.3: clear the dead group's slot
                        self._filled[i] = False
            w_eff = min(self.w, int(alive.sum()))

        # assignment, in group-index order (canonical draw order)
        for i in range(G):
            if alive is not None and not alive[i]:
                continue  # dead groups start nothing, consume no draws
            if self._busy_until[i] <= now:
                comp, comm = latency_of(i, now)
                fin = task_finish_time(now, comp, comm)
                heapq.heappush(
                    self._heap,
                    (fin, self._seq, int(self._gen[i]), i, t, comp + comm),
                )
                self._seq += 1
                self._busy_until[i] = fin
            else:
                self._queued[i] = t  # length-1 FILO queue: overwrite

        fresh = 0
        deadline = math.inf
        iter_start = now
        heap = self._heap
        while heap and (fresh < w_eff or heap[0][0] <= deadline):
            fin, sq, g, widx, titer, lat = heapq.heappop(heap)
            if g != self._gen[widx]:
                continue  # discarded by a death event; must not touch `now`
            if fin > deadline:
                heapq.heappush(heap, (fin, sq, g, widx, titer, lat))
                break
            now = fin
            self.record(widx, float(lat))
            # start the queued task immediately (FILO queue of length 1)
            if self._queued[widx] is not None:
                qt = self._queued[widx]
                self._queued[widx] = None
                comp, comm = latency_of(widx, now)
                nfin = task_finish_time(now, comp, comm)
                heapq.heappush(
                    heap,
                    (nfin, self._seq, int(self._gen[widx]), widx, qt, comp + comm),
                )
                self._seq += 1
                self._busy_until[widx] = nfin
            else:
                self._busy_until[widx] = now

            if titer == t:
                mask[widx] = True
                self._filled[widx] = True
                fresh += 1
                if fresh == w_eff:
                    if self.accepts_stale and self.margin > 0:
                        # paper §5.1: wait `margin` longer than the time it
                        # took to collect the w-th fresh result
                        deadline = margin_deadline(now, iter_start, self.margin)
                    else:
                        break
            elif self.accepts_stale:
                # stale arrival accepted into the cache (§5 staleness
                # dominance: per-group task iterations are monotone, so the
                # arrival always dominates the group's existing entry)
                flush[widx] = True
                self._filled[widx] = True

        self._now = now
        self._step = t + 1
        return StepInputs(
            mask=mask,
            flush=flush,
            evict=evict,
            iter_start=iter_start,
            elapsed=now - iter_start,
            deadline=deadline,
        )

    def step_masks(self, latencies: np.ndarray, step: int) -> tuple[np.ndarray, np.ndarray]:
        """Array-style wrapper over :meth:`step_inputs`.

        ``latencies[i]`` is the round-trip each group *would* take if it
        started a task this step; only groups that actually start (idle at
        assignment) consume their entry, so a straggler's old result lands
        on the step its simulated completion time falls in — not
        unconditionally one step after the miss.
        """
        lat = np.asarray(latencies, dtype=np.float64)
        if lat.shape != (self.num_groups,):
            raise ValueError(f"latencies shape {lat.shape} != ({self.num_groups},)")
        si = self.step_inputs(lambda i, now: (float(lat[i]), 0.0))
        return si.mask, si.flush


@dataclasses.dataclass
class FailureDetector:
    num_groups: int
    max_misses: int = 5

    def __post_init__(self):
        self.misses = np.zeros(self.num_groups, dtype=np.int64)
        self.failed = np.zeros(self.num_groups, dtype=bool)

    def observe(self, mask: np.ndarray) -> np.ndarray:
        """Update with this step's mask; returns the failed-group vector."""
        self.misses = np.where(mask, 0, self.misses + 1)
        self.failed = self.misses >= self.max_misses
        return self.failed

    def rejoin(self, group: int) -> None:
        self.misses[group] = 0
        self.failed[group] = False


def elastic_remap_groups(
    n_samples: int, p_old: int, p_new: int, k_old: int = 1
) -> tuple[int, np.ndarray]:
    """Re-map sample->group assignment when the group count changes.

    Returns (k_new, survivors) where survivors[i] (len p_new) marks new
    groups whose sample range exactly matches an old group's range — their
    cache slots can be carried over; the rest start unfilled (ξ drops, DSAG
    refills them over the next steps, per §6.3).  A new group survives only
    if both its start *and* end line up with one old group: matching starts
    alone would carry a coarse group spanning several old groups over a
    cache entry that covers just part of its range, silently biasing H.
    """
    k_al, k_new = align_partitions(n_samples, p_old, p_new, k_old)
    old_ranges = {
        (p_start(n_samples, p_old, i), p_stop(n_samples, p_old, i))
        for i in range(1, p_old + 1)
    }
    survivors = np.array(
        [
            (p_start(n_samples, p_new, i), p_stop(n_samples, p_new, i)) in old_ranges
            for i in range(1, p_new + 1)
        ]
    )
    return k_new, survivors
