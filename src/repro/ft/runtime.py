"""Tier-2 runtime control: deadlines, failure handling, elastic rescale.

This is the host-side loop that turns the paper's coordinator behavior into
the mask/flush inputs of the compiled DSAG step:

* :class:`DeadlineController` — per-step, per-group deadline selection.  It
  profiles per-group step latencies (moving window, §6.1), predicts the
  w-th order statistic with the §4 model, and sets the deadline to that
  prediction times (1 + margin) (the paper's 2% rule).  Groups over deadline
  get mask 0 now and flush 1 on the step their result lands.
* :class:`FailureDetector` — heartbeat bookkeeping: a group missing
  ``max_misses`` consecutive deadlines is declared failed; DSAG proceeds with
  its mask permanently 0 (that is the paper's point — missing partitions only
  freeze ξ, they do not block progress) until the group rejoins.
* :func:`elastic_remap_groups` — on a DP-degree change (node loss / rescale),
  re-map sample->group assignment with the paper's Algorithm-2 alignment so
  surviving cache entries stay aligned to partition boundaries; unaligned
  slots are invalidated (mirrors §6.3 cache evictions).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.latency.model import GammaParams
from repro.lb.partitioner import align_partitions, p_start


@dataclasses.dataclass
class DeadlineController:
    num_groups: int
    w: int  # wait for the w fastest groups
    margin: float = 0.02  # paper §5.1
    window: int = 50  # latency samples kept per group

    def __post_init__(self):
        self._lat: list[list[float]] = [[] for _ in range(self.num_groups)]
        self._inflight: list[int | None] = [None] * self.num_groups  # step id
        if not (1 <= self.w <= self.num_groups):
            raise ValueError(f"w={self.w} not in 1..{self.num_groups}")

    def record(self, group: int, latency: float) -> None:
        dq = self._lat[group]
        dq.append(latency)
        if len(dq) > self.window:
            dq.pop(0)

    def deadline(self) -> float:
        """Predicted latency of the w-th fastest group, plus the margin."""
        means = np.array(
            [np.mean(l) if l else np.inf for l in self._lat], dtype=np.float64
        )
        if np.isinf(means).any():
            return np.inf  # no profile yet: wait for everyone
        stds = np.array(
            [np.std(l) if len(l) > 1 else means[i] * 0.1 for i, l in enumerate(self._lat)]
        )
        # Monte-Carlo order statistic under per-group gammas (§4.1)
        rng = np.random.default_rng(0)
        draws = np.stack(
            [
                GammaParams.from_mean_var(m, max(s, 1e-9) ** 2).sample(rng, 256)
                for m, s in zip(means, stds)
            ],
            axis=1,
        )
        kth = np.partition(draws, self.w - 1, axis=1)[:, self.w - 1]
        return float(kth.mean()) * (1.0 + self.margin)

    def step_masks(self, latencies: np.ndarray, step: int) -> tuple[np.ndarray, np.ndarray]:
        """Given this step's per-group latencies, return (mask, flush).

        mask_i: group i delivered within the deadline.
        flush_i: group i's previously-late result has now landed (its last
        in-flight step finished before this step started)."""
        deadline = self.deadline()
        mask = latencies <= deadline
        flush = np.zeros(self.num_groups, dtype=bool)
        for i in range(self.num_groups):
            if self._inflight[i] is not None and self._inflight[i] < step:
                flush[i] = True
                self._inflight[i] = None
            if not mask[i]:
                self._inflight[i] = step
            self.record(i, float(latencies[i]))
        return mask, flush


@dataclasses.dataclass
class FailureDetector:
    num_groups: int
    max_misses: int = 5

    def __post_init__(self):
        self.misses = np.zeros(self.num_groups, dtype=np.int64)
        self.failed = np.zeros(self.num_groups, dtype=bool)

    def observe(self, mask: np.ndarray) -> np.ndarray:
        """Update with this step's mask; returns the failed-group vector."""
        self.misses = np.where(mask, 0, self.misses + 1)
        self.failed = self.misses >= self.max_misses
        return self.failed

    def rejoin(self, group: int) -> None:
        self.misses[group] = 0
        self.failed[group] = False


def elastic_remap_groups(
    n_samples: int, p_old: int, p_new: int, k_old: int = 1
) -> tuple[int, np.ndarray]:
    """Re-map sample->group assignment when the group count changes.

    Returns (k_new, survivors) where survivors[i] (len p_new) marks new
    groups whose sample range exactly matches an old group's range — their
    cache slots can be carried over; the rest start unfilled (ξ drops, DSAG
    refills them over the next steps, per §6.3)."""
    k_al, k_new = align_partitions(n_samples, p_old, p_new, k_old)
    old_starts = {p_start(n_samples, p_old, i) for i in range(1, p_old + 1)}
    survivors = np.array(
        [p_start(n_samples, p_new, i) in old_starts for i in range(1, p_new + 1)]
    )
    return k_new, survivors
