"""Cross-layer pin: Tier-2 control plane vs the scalar convergence engine.

The live trainer (``repro.launch.train``) feeds the compiled Tier-1
``dsag_update`` from :class:`repro.ft.runtime.DeadlineController`; the
paper's dynamics are pinned by the scalar
:class:`repro.cluster.simulator.TrainingSimulator`.  This module replays
one pre-sampled :class:`repro.latency.model.FleetTraces` scenario through
the controller's event machine and packages the resulting (mask, flush,
evict) streams so tests and the ``live_validation`` BENCH column can
assert them equal to the simulator's recorded streams — if the two ever
disagree, the live system has drifted from the semantics every engine
pins.

The equivalence holds for ``subpartitions=1`` methods (one sample range
per group, the live trainer's regime): there each group's task iterations
are monotone, so the §5 staleness-dominance rule accepts every stale
arrival and the controller does not need gradient values to know the
cache decision.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.cluster.simulator import MethodConfig, TrainingSimulator, effective_w
from repro.core.problems import FiniteSumProblem
from repro.ft.runtime import DeadlineController, LatencyFn
from repro.latency.model import FleetTraces
from repro.lb.partitioner import p_start, p_stop


@dataclasses.dataclass
class ControlStreams:
    """Per-step coordinator decisions over a whole run ([T, G] bool)."""

    mask: np.ndarray
    flush: np.ndarray
    evict: np.ndarray
    times: np.ndarray  # [T] virtual completion time of each step
    elapsed: np.ndarray  # [T] virtual duration of each step's collection

    def __eq__(self, other) -> bool:  # stream equality is the pin
        if not isinstance(other, ControlStreams):
            return NotImplemented
        return (
            np.array_equal(self.mask, other.mask)
            and np.array_equal(self.flush, other.flush)
            and np.array_equal(self.evict, other.evict)
        )

    def mismatch_summary(self, other: "ControlStreams") -> str:
        """First differing (step, group) per stream — for pin diagnostics."""
        parts = []
        for name in ("mask", "flush", "evict"):
            a, b = getattr(self, name), getattr(other, name)
            diff = np.argwhere(a != b)
            if len(diff):
                t, g = diff[0]
                parts.append(f"{name} first diff at step {t} group {g}")
        return "; ".join(parts) if parts else "streams identical"


def group_loads(problem: FiniteSumProblem, num_groups: int) -> np.ndarray:
    """Per-group compute cost for the live regime (subpartitions=1).

    Group i processes its full base partition every task, so its load is
    the compute cost of that sample range — the same value
    ``_SimWorker.start_task`` feeds the latency source.
    """
    n = problem.num_samples
    return np.array(
        [
            problem.compute_cost(p_start(n, num_groups, i), p_stop(n, num_groups, i))
            for i in range(1, num_groups + 1)
        ],
        dtype=np.float64,
    )


def trace_latency_fn(traces: FleetTraces, scenario: int, loads: np.ndarray) -> LatencyFn:
    """A ``latency_of`` callable replaying one trace scenario.

    Consumes each group's (comm, comp_unit) draw streams sequentially —
    the same order as ``TraceLatencySource`` — so the controller sees
    exactly the latencies the scalar simulator sees on this scenario.
    """
    k = np.zeros(traces.num_workers, dtype=np.int64)

    def latency_of(group: int, now: float) -> tuple[float, float]:
        comm, comp = traces.scalar_task_latency(
            scenario, group, int(k[group]), now, float(loads[group])
        )
        k[group] += 1
        return float(comp), float(comm)

    return latency_of


def controller_streams(
    traces: FleetTraces,
    scenario: int,
    *,
    w: int,
    num_iterations: int,
    loads: np.ndarray,
    margin: float = 0.02,
    accepts_stale: bool = True,
) -> ControlStreams:
    """Replay one trace scenario through the Tier-2 controller.

    Drives :meth:`DeadlineController.step_inputs` for ``num_iterations``
    virtual steps, threading the trace's churn schedule (death/rejoin) in
    as the per-step ``alive`` vector exactly as the simulator samples it
    (once per iteration, at assignment time).
    """
    G = traces.num_workers
    ctrl = DeadlineController(
        num_groups=G, w=w, margin=margin, accepts_stale=accepts_stale
    )
    latency_of = trace_latency_fn(traces, scenario, loads)
    mask = np.zeros((num_iterations, G), dtype=bool)
    flush = np.zeros((num_iterations, G), dtype=bool)
    evict = np.zeros((num_iterations, G), dtype=bool)
    times = np.zeros(num_iterations, dtype=np.float64)
    elapsed = np.zeros(num_iterations, dtype=np.float64)
    churn = traces.churn
    for t in range(num_iterations):
        alive = churn.alive_at(ctrl.now) if churn is not None else None
        si = ctrl.step_inputs(latency_of, alive=alive)
        mask[t] = si.mask
        flush[t] = si.flush
        evict[t] = si.evict
        times[t] = ctrl.now
        elapsed[t] = si.elapsed
    return ControlStreams(mask=mask, flush=flush, evict=evict, times=times, elapsed=elapsed)


def simulator_streams(
    problem: FiniteSumProblem,
    cluster,
    traces: FleetTraces,
    scenario: int,
    config: MethodConfig,
    num_iterations: int,
    *,
    seed: int = 0,
) -> tuple[ControlStreams, "np.ndarray"]:
    """Run the scalar simulator on the same trace; return its streams.

    The second element is the run's ``times`` array (sim-time per
    iteration) — the live-validation column uses it as the predicted
    wall-clock schedule.
    """
    from repro.cluster.simulator import TraceLatencySource

    sim = TrainingSimulator(
        problem,
        cluster,
        config,
        seed=seed,
        latency_source=TraceLatencySource(traces, scenario),
    )
    hist = sim.run(num_iterations)
    streams = ControlStreams(
        mask=hist.mask_stream,
        flush=hist.flush_stream,
        evict=hist.evict_stream,
        times=hist.times,
        elapsed=np.diff(np.concatenate(([0.0], hist.times))),
    )
    return streams, hist


def pin_streams(
    problem: FiniteSumProblem,
    cluster,
    traces: FleetTraces,
    scenario: int,
    config: MethodConfig,
    num_iterations: int,
    *,
    seed: int = 0,
) -> tuple[ControlStreams, ControlStreams, "object"]:
    """Produce (controller, simulator) streams for one shared trace.

    The caller asserts ``ctrl == sim`` — the cross-layer pin.  Requires
    ``subpartitions == 1`` (the live trainer's regime; see module
    docstring) and no load balancing.
    """
    if config.subpartitions != 1 or config.load_balance:
        raise ValueError(
            "the Tier-2 pin covers the live regime: subpartitions=1, no LB"
        )
    if config.name not in ("sag", "dsag"):
        raise ValueError("the live trainer runs cache methods (sag/dsag)")
    loads = group_loads(problem, traces.num_workers)
    ctrl = controller_streams(
        traces,
        scenario,
        w=effective_w(config, traces.num_workers),
        num_iterations=num_iterations,
        loads=loads,
        margin=config.margin,
        accepts_stale=config.accepts_stale,
    )
    sim, hist = simulator_streams(
        problem, cluster, traces, scenario, config, num_iterations, seed=seed
    )
    return ctrl, sim, hist
