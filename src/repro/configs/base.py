"""Configuration dataclasses for models, training, meshes and shapes."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | enc_dec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    head_pad_to: int = 1  # pad query heads up to a multiple of this (TP)
    kv_pad_to: int = 1  # pad kv heads (MHA models shard kv over 'model')
    qkv_bias: bool = False
    mlp_swiglu: bool = True  # False -> 2-matrix GELU MLP (whisper/starcoder2)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    #: dispatch locality: tokens compete for capacity within one of
    #: `moe_dispatch_chunks` chunks of the batch (set = DP shards in
    #: production so dispatch gathers/scatters never cross devices)
    moe_dispatch_chunks: int = 1

    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128

    # --- hybrid (zamba2): one shared attention block every `attn_every` ---
    attn_every: int = 0

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # stub frontend emits this many frame embeddings

    # --- VLM (pixtral): stub frontend emits this many patch embeddings ---
    num_image_tokens: int = 0

    # Max positions for learned-absolute embeddings (0 -> RoPE, no table)
    max_position_embeddings: int = 0

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing -> long_500k cell runs."""
        return self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_training(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Distributed-training configuration (Tier 1)."""

    optimizer: str = "adamw"  # adamw | adafactor | sgd
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0

    # DSAG
    dsag: bool = True
    dsag_groups: str = "dp"  # dp | pod | zero | none  (partition granularity)
    dsag_num_groups: int = 4  # group count for the "zero" layout
    dsag_cache_dtype: str = "bfloat16"  # bfloat16 | int8 | float32
    dsag_cache_layout: str = "group"  # group (P over dp axes) | zero (dims over all)
    dsag_cache_placement: str = "device"  # device | host (host is TPU-only)
    dsag_margin: float = 0.02

    # sharding
    fsdp: bool = False  # shard params/optimizer state over the data axis
    seq_shard_activations: bool = False  # sequence-sharded residual stream
    quantized_fsdp_allgather: bool = False  # int8 weight all-gather
    remat: str = "full"  # full | selective | none
    fused_loss: bool = False  # chunked-vocab CE fused with unembedding
    bf16_reduce: bool = False  # bf16 tensor-parallel all-reduces
    microbatches: int = 1  # grad-accumulation steps inside the jit step

    # fault tolerance
    checkpoint_every: int = 200
    keep_checkpoints: int = 3


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...] = (16, 16)
    axes: tuple[str, ...] = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def dp_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))
