"""pixtral-12b [vlm]: 40L, d_model=5120, 32H (GQA kv=8), d_ff=14336,
vocab=131072 — pixtral-ViT frontend stubbed (input_specs provides 256 patch
embeddings per sample).  [hf:mistralai/Pixtral-12B-2409]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=131072,
        head_dim=128,
        rope_theta=1_000_000_000.0,
        head_pad_to=16,
        num_image_tokens=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        num_image_tokens=8,
    )
