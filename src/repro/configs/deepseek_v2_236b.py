"""deepseek-v2-236b [moe]: 60L, d_model=5120, 128H, MLA kv_lora=512,
vocab=102400, MoE 2 shared + 160 routed top-6, expert d_ff=1536.
[arXiv:2405.04434]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        d_ff=1536,
        vocab_size=102400,
        head_pad_to=16,
        use_mla=True,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        num_experts=160,
        num_shared_experts=2,
        top_k=6,
        d_ff_expert=1536,
        moe_dispatch_chunks=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=64,
        vocab_size=512,
        use_mla=True,
        kv_lora_rank=32,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        num_experts=8,
        num_shared_experts=2,
        top_k=2,
        capacity_factor=8.0,  # no token drops in smoke tests
        d_ff_expert=64,
    )
