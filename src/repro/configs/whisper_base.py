"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H, d_ff=2048,
vocab=51865 — encoder-decoder; conv/mel frontend is a stub (input_specs
provides 1500 precomputed frame embeddings).  [arXiv:2212.04356]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="enc_dec",
        num_layers=6,
        encoder_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        qkv_bias=True,
        mlp_swiglu=False,
        encoder_seq=1500,
        max_position_embeddings=32_768,  # assigned shapes exceed 448
        head_pad_to=16,
        kv_pad_to=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-smoke",
        family="enc_dec",
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
        mlp_swiglu=False,
        encoder_seq=12,
        max_position_embeddings=128,
    )
