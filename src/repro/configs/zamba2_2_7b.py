"""zamba2-2.7b [hybrid]: 54 Mamba2 layers, d_model=2560, a single SHARED
attention block (32H) applied every 6 layers, d_ff=10240, vocab=32000,
ssm_state=64.  [arXiv:2411.15242]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        head_pad_to=16,
        kv_pad_to=16,
        attn_every=6,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,  # d_inner=5120 -> 80 SSD heads
        ssm_chunk=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        family="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        attn_every=2,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=16,
        ssm_chunk=16,
    )
