"""mamba2-370m [ssm]: 48L, d_model=1024, attention-free, vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        tie_embeddings=True,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,  # d_inner=2048 -> 32 SSD heads
        ssm_chunk=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        tie_embeddings=True,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=16,
        ssm_chunk=16,
    )
