"""grok-1-314b [moe]: 64L, d_model=6144, 48H (GQA kv=8), vocab=131072,
MoE 8 experts top-2, expert d_ff=32768.  [hf:xai-org/grok-1]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        head_pad_to=16,
        num_experts=8,  # 8 % 16 != 0 -> per-expert ffn dim TP-sharded
        top_k=2,
        d_ff_expert=32768,
        moe_dispatch_chunks=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        top_k=2,
        capacity_factor=8.0,  # no token drops in smoke tests
        d_ff_expert=128,
    )
