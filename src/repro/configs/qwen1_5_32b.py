"""qwen1.5-32b [dense]: 64L, d_model=5120, 40H (kv=40, MHA), d_ff=27392,
vocab=152064 — QKV bias.  Heads (q and kv) padded 40->48 for TP=16.
[hf:Qwen/Qwen1.5-32B]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        d_ff=27392,
        vocab_size=152064,
        head_dim=128,
        qkv_bias=True,
        head_pad_to=16,
        kv_pad_to=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        qkv_bias=True,
    )
