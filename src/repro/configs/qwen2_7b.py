"""qwen2-7b [dense]: 28L, d_model=3584, 28H (GQA kv=4), d_ff=18944,
vocab=152064 — GQA, QKV bias.  Heads padded 28->32 for TP=16 (DESIGN §6).
[arXiv:2407.10671]"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        family="dense",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        head_pad_to=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        qkv_bias=True,
    )
