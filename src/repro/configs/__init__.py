"""Config registry: ``get_config(arch)`` / ``get_smoke_config(arch)`` plus
``input_specs`` building ShapeDtypeStruct stand-ins for every model input of
an (arch x shape) cell — weak-type-correct, shardable, no device allocation.
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, MeshConfig, ModelConfig, ShapeConfig, TrainConfig

_MODULES: dict[str, str] = {
    "whisper-base": "repro.configs.whisper_base",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}

ARCHS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).config()


def get_smoke_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).smoke_config()


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k runs only for sub-quadratic (SSM/hybrid) archs."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False
    return True


def input_specs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh | None = None,
) -> dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for one cell (training batch or prefill batch).

    Decode-cell *cache* stand-ins come from ``Model.cache_abstract``."""
    b, s = shape.global_batch, shape.seq_len

    def sharded(shp, dtype, spec):
        if mesh is None:
            return jax.ShapeDtypeStruct(shp, dtype)
        return jax.ShapeDtypeStruct(
            shp, dtype, sharding=NamedSharding(mesh, spec)
        )

    dp = (
        tuple(a for a in (mesh.axis_names if mesh else ()) if a in ("pod", "data"))
        or None
    )
    dp = dp if dp is None or len(dp) > 1 else dp[0]

    if shape.kind == "decode":
        out = {"tokens": sharded((b, 1), jnp.int32, P(dp, None))}
        return out

    if cfg.family == "enc_dec":
        return {
            "tokens": sharded((b, s), jnp.int32, P(dp, None)),
            "audio_embed": sharded(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16, P(dp, None, None)
            ),
        }
    if cfg.family == "vlm":
        return {
            "tokens": sharded((b, s - cfg.num_image_tokens), jnp.int32, P(dp, None)),
            "image_embed": sharded(
                (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16, P(dp, None, None)
            ),
        }
    return {"tokens": sharded((b, s), jnp.int32, P(dp, None))}


__all__ = [
    "ARCHS",
    "SHAPES",
    "MeshConfig",
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "cell_is_runnable",
    "get_config",
    "get_smoke_config",
    "input_specs",
]
