"""Load-balancing optimizer (paper §6.2, Algorithm 1) — host entry points.

Given per-worker latency statistics from the profiler, produce an updated
subpartition-count vector p' that (i) equalizes expected total per-iteration
latency across workers and (ii) respects the contribution constraint
h(p') >= h_min, where h is estimated by replaying pre-sampled what-if
latency traces through the batched §4.2 event dynamics.

Since the fused-scan engine learned to run §6 configs, **all numerical
work lives in** :mod:`repro.lb.jit_optimizer` as traceable JAX functions:
the hill-climb moves on the finite p-ladder
(:func:`repro.lb.partitioner.build_p_ladder`), the what-if traces are
``jax.random.gamma`` draws, and every phase operates on masked ``[S, N]``
arrays.  This class is the numpy-facing wrapper those host callers (the
scalar :class:`~repro.cluster.simulator.TrainingSimulator`, the batched
host convergence engine, and the standalone tests) share; the fused scan
traces the very same functions inline, which is what makes the three
engines bit-exact on §6 configs (pinned by ``tests/test_lb_scan.py``).

The §6.2 linearisation is unchanged:

    e'_{Z,i} = e_{Z,i} * p_i / p'_i        (computation mean)
    v'_{Z,i} = v_{Z,i} * p_i^2 / p'_i^2    (computation variance)
    e'_{X,i} = e_{Y,i} + e'_{Z,i}          (total)

and h is evaluated with a 1% tolerance (the paper's noise allowance).
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.lb import jit_optimizer as jlb
from repro.lb.partitioner import build_p_ladder


@dataclasses.dataclass
class OptimizerInputs:
    """Latest profiler statistics.

    Arrays are ``[N]`` for a single scenario (the scalar simulator) or
    ``[S, N]`` for a batch (the vectorized convergence engines); ``w`` and
    ``margin`` are shared across the batch (one method configuration).
    """

    e_comm: np.ndarray  # e_{Y,i}
    v_comm: np.ndarray  # v_{Y,i}
    e_comp: np.ndarray  # e_{Z,i}  (at the CURRENT p_i)
    v_comp: np.ndarray  # v_{Z,i}
    samples_per_worker: np.ndarray  # n_i
    w: int  # wait-for-w setting of the running method
    margin: float = 0.02

    def as_batch(self) -> "OptimizerInputs":
        """View with a leading scenario axis (no copy for 2-D inputs)."""
        if np.ndim(self.e_comm) == 2:
            return self
        return OptimizerInputs(
            e_comm=np.asarray(self.e_comm, np.float64)[None, :],
            v_comm=np.asarray(self.v_comm, np.float64)[None, :],
            e_comp=np.asarray(self.e_comp, np.float64)[None, :],
            v_comp=np.asarray(self.v_comp, np.float64)[None, :],
            samples_per_worker=np.asarray(self.samples_per_worker, np.float64)[None, :],
            w=self.w,
            margin=self.margin,
        )


class LoadBalanceOptimizer:
    """Iterative ladder solver for paper Eq. (7) / Algorithm 1.

    ``ladder`` fixes the candidate subpartition counts; when omitted it is
    built from the first ``optimize*`` call's current p and sample counts
    (:func:`build_p_ladder`).  The convergence engines pass their ladder
    explicitly so the host optimizer and the fused scan climb the exact
    same rungs.
    """

    def __init__(
        self,
        *,
        h_tolerance: float = jlb.H_TOLERANCE,
        sim_iterations: int = jlb.SIM_ITERATIONS,
        max_rounds: int = jlb.MAX_ROUNDS,
        improvement_threshold: float = jlb.IMPROVEMENT_THRESHOLD,
        seed: int = 0,
        ladder: tuple[int, ...] | None = None,
    ):
        self.h_tolerance = h_tolerance
        self.sim_iterations = sim_iterations
        self.max_rounds = max_rounds
        #: only publish a new p if the objective improves by this much
        #: (paper §6.3 first mitigation strategy, default 10%)
        self.improvement_threshold = improvement_threshold
        self.seed = seed
        self.ladder = tuple(ladder) if ladder is not None else None
        self.h_min: float | None = None
        #: h at the *returned* p' of the last optimize() call — kept
        #: consistent with the returned vector even when the slack phase
        #: backs a violating step out
        self.last_h: float | None = None

    # -- shared pieces -----------------------------------------------------
    def _ladder_for(self, p: np.ndarray, n_j: np.ndarray) -> tuple[int, ...]:
        if self.ladder is None:
            self.ladder = build_p_ladder(int(np.max(p)), int(np.max(n_j)))
        return self.ladder

    def _key(self):
        return jax.random.PRNGKey(self.seed)

    @staticmethod
    def objective(e_x: np.ndarray):
        """max/min ratio of expected per-worker total latency (Eq. 7)."""
        lo = np.maximum(e_x.min(axis=-1), 1e-12)
        ratio = e_x.max(axis=-1) / lo
        return float(ratio) if np.ndim(ratio) == 0 else ratio

    # -- h(p) via batched what-if trace replay ------------------------------
    def estimate_h(
        self, inputs: OptimizerInputs, p: Sequence[int], p_new: Sequence[int]
    ) -> float:
        """Scalar convenience: h(p') for one scenario's inputs.

        Deterministic given (seed, inputs, p, p') — the same jitted
        estimator Algorithm 1 calls internally, so re-estimating at a
        returned vector reproduces ``last_h`` exactly.
        """
        b = inputs.as_batch()
        fn = jlb._estimate_h_jitted(
            int(b.w), int(self.sim_iterations), float(b.margin)
        )
        with enable_x64():
            h = fn(
                jnp.asarray(b.e_comm, jnp.float64),
                jnp.asarray(b.v_comm, jnp.float64),
                jnp.asarray(b.e_comp, jnp.float64),
                jnp.asarray(b.v_comp, jnp.float64),
                jnp.asarray(b.samples_per_worker, jnp.float64),
                jnp.asarray(p, jnp.float64)[None, :],
                jnp.asarray(p_new, jnp.float64)[None, :],
                self._key(),
            )
        return float(np.asarray(h)[0])

    # -- Algorithm 1 + publication gate (batched) ---------------------------
    def update_batch(
        self,
        p: np.ndarray,
        inputs: OptimizerInputs,
        h_min: np.ndarray | None = None,
        active: np.ndarray | None = None,
        alive: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Run Algorithm 1 + the §6.3 publish gate for S scenarios at once.

        ``p`` is ``[S, N]`` int, ``inputs`` holds ``[S, N]`` arrays,
        ``h_min`` the per-scenario contribution floor carried across calls
        (NaN = not yet established), and ``active`` masks which scenarios
        actually balance this round (inactive rows pass through).
        ``alive`` ([S, N] bool, optional) is the churn liveness mask: dead
        workers are excluded from the hill-climb and their p frozen (see
        :func:`repro.lb.jit_optimizer.algorithm1`).  Returns
        ``(p_new [S, N] int64, h_min [S], last_h [S], publish [S])``.
        """
        p = np.asarray(p, dtype=np.int64)
        S, N = p.shape
        if h_min is None:
            h_min = np.full(S, np.nan)
        if active is None:
            active = np.ones(S, dtype=bool)
        ladder = self._ladder_for(p, inputs.samples_per_worker)
        fn = jlb._lb_update_jitted(
            ladder,
            int(inputs.w),
            int(self.sim_iterations),
            float(self.h_tolerance),
            int(self.max_rounds),
            float(self.improvement_threshold),
            float(inputs.margin),
            with_alive=alive is not None,
        )
        with enable_x64():
            args = (
                jnp.asarray(p, jnp.float64),
                jnp.asarray(inputs.e_comm, jnp.float64),
                jnp.asarray(inputs.v_comm, jnp.float64),
                jnp.asarray(inputs.e_comp, jnp.float64),
                jnp.asarray(inputs.v_comp, jnp.float64),
                jnp.asarray(inputs.samples_per_worker, jnp.float64),
                jnp.asarray(h_min, jnp.float64),
                jnp.asarray(active, bool),
                self._key(),
            )
            if alive is not None:
                args = args + (jnp.asarray(alive, bool),)
            p_new, h_min_out, last_h, publish = fn(*args)
        return (
            np.asarray(p_new, np.int64),
            np.asarray(h_min_out, np.float64),
            np.asarray(last_h, np.float64),
            np.asarray(publish, bool),
        )

    def optimize_batch(
        self,
        p: np.ndarray,
        inputs: OptimizerInputs,
        h_min: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Algorithm 1 for S scenarios (no publish gate): see update_batch."""
        p_new, h_min_out, last_h, _ = self.update_batch(p, inputs, h_min)
        return p_new, h_min_out, last_h

    def optimize(self, p: Sequence[int], inputs: OptimizerInputs) -> np.ndarray:
        """Scalar entry point: Algorithm 1 for one scenario (S = 1 batch)."""
        hm = None if self.h_min is None else np.array([self.h_min])
        p_new, h_min, last_h = self.optimize_batch(
            np.asarray(p, dtype=np.int64)[None, :], inputs.as_batch(), hm
        )
        self.h_min = float(h_min[0])
        self.last_h = float(last_h[0])
        return p_new[0]

    # -- publication gate (paper §6.3) -------------------------------------
    def should_publish_batch(
        self, p: np.ndarray, p_new: np.ndarray, inputs: OptimizerInputs
    ) -> np.ndarray:
        """[S] bool: Eq.-(7) objective improves by > improvement_threshold."""
        fn = jlb._should_publish_jitted(float(self.improvement_threshold))
        with enable_x64():
            out = fn(
                jnp.asarray(p, jnp.float64),
                jnp.asarray(p_new, jnp.float64),
                jnp.asarray(inputs.e_comm, jnp.float64),
                jnp.asarray(inputs.e_comp, jnp.float64),
            )
        return np.asarray(out, bool)

    def should_publish(
        self, p: Sequence[int], p_new: Sequence[int], inputs: OptimizerInputs
    ) -> bool:
        """Paper §6.3: only distribute p' if the Eq.-(7) objective improves by
        more than ``improvement_threshold`` (cache evictions are costly)."""
        return bool(
            self.should_publish_batch(
                np.asarray(p, np.float64)[None, :],
                np.asarray(p_new, np.float64)[None, :],
                inputs.as_batch(),
            )[0]
        )
