"""Load-balancing optimizer (paper §6.2, Algorithm 1).

Given per-worker latency statistics from the profiler, produce an updated
subpartition-count vector p' that (i) equalizes expected total per-iteration
latency across workers and (ii) respects the contribution constraint
h(p') >= h_min, where h is estimated with the event-driven simulator.

The optimizer works on the §6.2 linearisation:

    e'_{Z,i} = e_{Z,i} * p_i / p'_i        (computation mean)
    v'_{Z,i} = v_{Z,i} * p_i^2 / p'_i^2    (computation variance)
    e'_{X,i} = e_{Y,i} + e'_{Z,i}          (total)

and evaluates h with a 1% tolerance (the paper's noise allowance).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.latency.event_sim import EventDrivenSimulator
from repro.latency.model import ClusterLatencyModel, GammaParams, WorkerLatencyModel


@dataclasses.dataclass
class OptimizerInputs:
    """Latest profiler statistics, one entry per worker."""

    e_comm: np.ndarray  # e_{Y,i}
    v_comm: np.ndarray  # v_{Y,i}
    e_comp: np.ndarray  # e_{Z,i}  (at the CURRENT p_i)
    v_comp: np.ndarray  # v_{Z,i}
    samples_per_worker: np.ndarray  # n_i
    w: int  # wait-for-w setting of the running method
    margin: float = 0.02


class LoadBalanceOptimizer:
    """Iterative small-step solver for paper Eq. (7) / Algorithm 1."""

    def __init__(
        self,
        *,
        h_tolerance: float = 0.01,
        sim_iterations: int = 100,
        max_rounds: int = 200,
        improvement_threshold: float = 0.10,
        seed: int = 0,
    ):
        self.h_tolerance = h_tolerance
        self.sim_iterations = sim_iterations
        self.max_rounds = max_rounds
        #: only publish a new p if the objective improves by this much
        #: (paper §6.3 first mitigation strategy, default 10%)
        self.improvement_threshold = improvement_threshold
        self.seed = seed
        self.h_min: Optional[float] = None

    # -- objective -------------------------------------------------------
    @staticmethod
    def _e_total(inputs: OptimizerInputs, p: np.ndarray, p_new: np.ndarray) -> np.ndarray:
        e_z = inputs.e_comp * p / p_new
        return inputs.e_comm + e_z

    @staticmethod
    def objective(e_x: np.ndarray) -> float:
        """max/min ratio of expected per-worker total latency (Eq. 7)."""
        lo = float(e_x.min())
        return float(e_x.max()) / max(lo, 1e-12)

    # -- h(p) via event-driven simulation ---------------------------------
    def _estimate_h(
        self, inputs: OptimizerInputs, p: np.ndarray, p_new: np.ndarray
    ) -> float:
        n = float(inputs.samples_per_worker.sum())
        workers = []
        for i in range(len(p_new)):
            comm = GammaParams.from_mean_var(
                max(inputs.e_comm[i], 1e-12), max(inputs.v_comm[i], 1e-18)
            )
            # linearised what-if computation latency at p'_i
            e_z = max(inputs.e_comp[i] * p[i] / p_new[i], 1e-12)
            v_z = max(inputs.v_comp[i] * (p[i] / p_new[i]) ** 2, 1e-18)
            comp = GammaParams.from_mean_var(e_z, v_z)
            workers.append(WorkerLatencyModel(comm=comm, comp_per_unit=comp))
        cluster = ClusterLatencyModel(workers=workers, seed=self.seed)
        sim = EventDrivenSimulator(cluster, loads=np.ones(len(p_new)))
        u = sim.estimate_participation(
            inputs.w, num_iterations=self.sim_iterations, margin=inputs.margin
        )
        return float(
            np.sum(u * inputs.samples_per_worker / (p_new * n))
        )

    # -- Algorithm 1 -------------------------------------------------------
    def optimize(self, p: Sequence[int], inputs: OptimizerInputs) -> np.ndarray:
        p = np.asarray(p, dtype=np.int64)
        if self.h_min is None:
            # h_min = h(p_0): the contribution of the baseline partitioning
            self.h_min = self._estimate_h(inputs, p, p)
        p_new = p.astype(np.float64).copy()

        # --- equalize total latency against the slowest worker ---
        e_x = self._e_total(inputs, p, p_new)
        slowest = int(np.argmax(e_x))
        target = inputs.e_comm[slowest] + inputs.e_comp[slowest] * p[slowest] / p_new[slowest]
        for j in range(len(p_new)):
            denom = target - inputs.e_comm[j]
            if denom <= 0:
                p_new[j] = float(inputs.samples_per_worker[j])  # comm-bound: minimal work
                continue
            p_new[j] = max(np.floor(inputs.e_comp[j] * p[j] / denom), 1.0)

        # --- restore contribution: give the fastest workers more work ---
        rounds = 0
        h = self._estimate_h(inputs, p, p_new)
        while h < self.h_min * (1.0 - self.h_tolerance) and rounds < self.max_rounds:
            e_x = self._e_total(inputs, p, p_new)
            fastest = int(np.argmin(e_x))
            reduced = np.floor(0.99 * p_new[fastest])
            if reduced < 1.0 or reduced == p_new[fastest]:
                # cannot increase this worker's load further; try next fastest
                order = np.argsort(e_x)
                moved = False
                for idx in order[1:]:
                    r2 = np.floor(0.99 * p_new[idx])
                    if r2 >= 1.0 and r2 != p_new[idx]:
                        p_new[idx] = r2
                        moved = True
                        break
                if not moved:
                    break
            else:
                p_new[fastest] = reduced
            h = self._estimate_h(inputs, p, p_new)
            rounds += 1

        # --- spend slack: reduce the slowest workers' load while h holds ---
        rounds = 0
        while h >= 0.99 * self.h_min and rounds < self.max_rounds:
            e_x = self._e_total(inputs, p, p_new)
            slowest = int(np.argmax(e_x))
            increased = np.ceil(1.01 * p_new[slowest])
            if increased > inputs.samples_per_worker[slowest] or increased == p_new[slowest]:
                increased = p_new[slowest] + 1
                if increased > inputs.samples_per_worker[slowest]:
                    break
            p_prev = p_new[slowest]
            p_new[slowest] = increased
            h = self._estimate_h(inputs, p, p_new)
            rounds += 1
            if h < 0.99 * self.h_min:
                p_new[slowest] = p_prev  # back out the violating step
                break

        return np.maximum(p_new, 1.0).astype(np.int64)

    def should_publish(
        self, p: Sequence[int], p_new: Sequence[int], inputs: OptimizerInputs
    ) -> bool:
        """Paper §6.3: only distribute p' if the Eq.-(7) objective improves by
        more than ``improvement_threshold`` (cache evictions are costly)."""
        p = np.asarray(p, dtype=np.float64)
        p_new_arr = np.asarray(p_new, dtype=np.float64)
        cur = self.objective(self._e_total(inputs, p, p))
        new = self.objective(self._e_total(inputs, p, p_new_arr))
        return new < cur * (1.0 - self.improvement_threshold)
