"""Load-balancing optimizer (paper §6.2, Algorithm 1).

Given per-worker latency statistics from the profiler, produce an updated
subpartition-count vector p' that (i) equalizes expected total per-iteration
latency across workers and (ii) respects the contribution constraint
h(p') >= h_min, where h is estimated by replaying pre-sampled what-if
latency traces through the batched §4.2 event dynamics
(:func:`repro.experiments.sweep.replay_batch`) — the same dynamics the old
event-driven estimate simulated one heap event at a time, resolved with
array operations instead.

The optimizer works on the §6.2 linearisation:

    e'_{Z,i} = e_{Z,i} * p_i / p'_i        (computation mean)
    v'_{Z,i} = v_{Z,i} * p_i^2 / p'_i^2    (computation variance)
    e'_{X,i} = e_{Y,i} + e'_{Z,i}          (total)

and evaluates h with a 1% tolerance (the paper's noise allowance).

Every phase (equalize / restore / slack) operates on ``[S, N]`` arrays so a
whole batch of scenarios is balanced in one call
(:meth:`LoadBalanceOptimizer.optimize_batch`); the scalar
:meth:`~LoadBalanceOptimizer.optimize` entry point is the S = 1 special
case of the batched path, so the scalar training simulator and the batched
convergence engine cannot drift apart.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class OptimizerInputs:
    """Latest profiler statistics.

    Arrays are ``[N]`` for a single scenario (the scalar simulator) or
    ``[S, N]`` for a batch (the vectorized convergence engine); ``w`` and
    ``margin`` are shared across the batch (one method configuration).
    """

    e_comm: np.ndarray  # e_{Y,i}
    v_comm: np.ndarray  # v_{Y,i}
    e_comp: np.ndarray  # e_{Z,i}  (at the CURRENT p_i)
    v_comp: np.ndarray  # v_{Z,i}
    samples_per_worker: np.ndarray  # n_i
    w: int  # wait-for-w setting of the running method
    margin: float = 0.02

    def as_batch(self) -> "OptimizerInputs":
        """View with a leading scenario axis (no copy for 2-D inputs)."""
        if np.ndim(self.e_comm) == 2:
            return self
        return OptimizerInputs(
            e_comm=np.asarray(self.e_comm, np.float64)[None, :],
            v_comm=np.asarray(self.v_comm, np.float64)[None, :],
            e_comp=np.asarray(self.e_comp, np.float64)[None, :],
            v_comp=np.asarray(self.v_comp, np.float64)[None, :],
            samples_per_worker=np.asarray(self.samples_per_worker, np.float64)[None, :],
            w=self.w,
            margin=self.margin,
        )


class LoadBalanceOptimizer:
    """Iterative small-step solver for paper Eq. (7) / Algorithm 1."""

    def __init__(
        self,
        *,
        h_tolerance: float = 0.01,
        sim_iterations: int = 100,
        max_rounds: int = 200,
        improvement_threshold: float = 0.10,
        seed: int = 0,
    ):
        self.h_tolerance = h_tolerance
        self.sim_iterations = sim_iterations
        self.max_rounds = max_rounds
        #: only publish a new p if the objective improves by this much
        #: (paper §6.3 first mitigation strategy, default 10%)
        self.improvement_threshold = improvement_threshold
        self.seed = seed
        self.h_min: Optional[float] = None
        #: h at the *returned* p' of the last optimize() call — kept
        #: consistent with the returned vector even when the slack phase
        #: backs a violating step out (see optimize_batch)
        self.last_h: Optional[float] = None

    # -- objective -------------------------------------------------------
    @staticmethod
    def _e_total(inputs: OptimizerInputs, p: np.ndarray, p_new: np.ndarray) -> np.ndarray:
        e_z = inputs.e_comp * p / p_new
        return inputs.e_comm + e_z

    @staticmethod
    def objective(e_x: np.ndarray):
        """max/min ratio of expected per-worker total latency (Eq. 7).

        Reduces over the worker axis: returns a float for ``[N]`` input and
        an ``[S]`` array for ``[S, N]`` input.
        """
        lo = np.maximum(e_x.min(axis=-1), 1e-12)
        ratio = e_x.max(axis=-1) / lo
        return float(ratio) if np.ndim(ratio) == 0 else ratio

    # -- h(p) via batched trace replay ------------------------------------
    def _estimate_h_batch(
        self,
        inputs: OptimizerInputs,
        p: np.ndarray,
        p_new: np.ndarray,
        active: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """h(p') for every active scenario (NaN elsewhere).

        Builds the linearised what-if gamma parameters per scenario, draws
        ``sim_iterations`` latency traces per worker (each scenario from its
        own ``default_rng(seed)`` stream, so a scenario's draws do not
        depend on which other scenarios share the batch), and replays all
        scenarios at once through :func:`replay_batch`.
        """
        # deferred: repro.cluster.simulator -> repro.lb.optimizer at import
        # time, and the experiments package imports the cluster simulator
        from repro.experiments.sweep import replay_batch
        from repro.latency.model import FleetTraces

        S, N = p_new.shape
        if active is None:
            active = np.ones(S, dtype=bool)
        idx = np.flatnonzero(active)
        out = np.full(S, np.nan)
        if idx.size == 0:
            return out
        K = self.sim_iterations
        comm = np.empty((idx.size, N, K))
        comp = np.empty((idx.size, N, K))
        for row, s in enumerate(idx):
            e_y = np.maximum(inputs.e_comm[s], 1e-12)
            v_y = np.maximum(inputs.v_comm[s], 1e-18)
            # linearised what-if computation latency at p'_i
            e_z = np.maximum(inputs.e_comp[s] * p[s] / p_new[s], 1e-12)
            v_z = np.maximum(inputs.v_comp[s] * (p[s] / p_new[s]) ** 2, 1e-18)
            rng = np.random.default_rng(self.seed)
            comm[row] = rng.gamma(
                (e_y * e_y / v_y)[:, None], (v_y / e_y)[:, None], size=(N, K)
            )
            comp[row] = rng.gamma(
                (e_z * e_z / v_z)[:, None], (v_z / e_z)[:, None], size=(N, K)
            )
        empty = np.zeros((idx.size, N, 0))
        traces = FleetTraces(
            comm=comm,
            comp_unit=comp,
            slowdown=np.ones(N),
            burst_start=empty,
            burst_end=empty.copy(),
            burst_factor=empty.copy(),
            seed=self.seed,
        )
        res = replay_batch(traces, inputs.w, K, margin=inputs.margin)
        u = res.participation  # [S_active, N]
        n_i = inputs.samples_per_worker[idx]
        n = n_i.sum(axis=1)
        out[idx] = np.sum(u * n_i / (p_new[idx] * n[:, None]), axis=1)
        return out

    def estimate_h(
        self, inputs: OptimizerInputs, p: Sequence[int], p_new: Sequence[int]
    ) -> float:
        """Scalar convenience: h(p') for one scenario's inputs."""
        b = inputs.as_batch()
        p2 = np.asarray(p, np.float64)[None, :]
        p2n = np.asarray(p_new, np.float64)[None, :]
        return float(self._estimate_h_batch(b, p2, p2n)[0])

    # -- Algorithm 1 (batched) ---------------------------------------------
    def optimize_batch(
        self,
        p: np.ndarray,
        inputs: OptimizerInputs,
        h_min: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Run Algorithm 1 for S scenarios at once.

        ``p`` is ``[S, N]`` int, ``inputs`` holds ``[S, N]`` arrays, and
        ``h_min`` is the per-scenario contribution floor carried across
        calls (NaN = not yet established; it is then set to h(p_0)).
        Returns ``(p_new [S, N] int64, h_min [S], last_h [S])`` where
        ``last_h`` is h at the returned vector.
        """
        p = np.asarray(p, dtype=np.int64)
        S, N = p.shape
        rows = np.arange(S)
        n_j = inputs.samples_per_worker
        if h_min is None:
            h_min = np.full(S, np.nan)
        h_min = np.asarray(h_min, dtype=np.float64).copy()
        unset = np.isnan(h_min)
        p_f = p.astype(np.float64)
        if unset.any():
            # h_min = h(p_0): the contribution of the baseline partitioning
            h0 = self._estimate_h_batch(inputs, p_f, p_f, active=unset)
            h_min[unset] = h0[unset]
        p_new = p_f.copy()

        # --- equalize total latency against the slowest worker ---
        e_x = self._e_total(inputs, p_f, p_new)
        slowest = np.argmax(e_x, axis=1)
        target = (
            inputs.e_comm[rows, slowest]
            + inputs.e_comp[rows, slowest] * p_f[rows, slowest] / p_new[rows, slowest]
        )
        denom = target[:, None] - inputs.e_comm
        safe = np.where(denom > 0, denom, 1.0)
        balanced = np.maximum(np.floor(inputs.e_comp * p_f / safe), 1.0)
        # comm-bound workers (denom <= 0) get minimal work: one sample/task
        p_new = np.where(denom <= 0, n_j, balanced)
        # a worker cannot be split finer than its own sample count — without
        # this cap the equalization could emit p'_j > n_j for very slow
        # fleets (only the comm-bound branch used to respect the bound)
        p_new = np.clip(p_new, 1.0, n_j)

        # --- restore contribution: give the fastest workers more work ---
        h = self._estimate_h_batch(inputs, p_f, p_new)
        active = h < h_min * (1.0 - self.h_tolerance)
        rounds = 0
        while active.any() and rounds < self.max_rounds:
            e_x = self._e_total(inputs, p_f, p_new)
            reduced = np.floor(0.99 * p_new)
            valid = (reduced >= 1.0) & (reduced != p_new)
            # the fastest worker whose load can still be increased (i.e.
            # whose p can be reduced); scenarios with no such worker stop
            order = np.argsort(e_x, axis=1)
            valid_ord = np.take_along_axis(valid, order, axis=1)
            movable = valid_ord.any(axis=1)
            pick = order[rows, np.argmax(valid_ord, axis=1)]
            active = active & movable
            if not active.any():
                break
            p_new[active, pick[active]] = reduced[active, pick[active]]
            h_step = self._estimate_h_batch(inputs, p_f, p_new, active=active)
            h[active] = h_step[active]
            rounds += 1
            active = active & (h < h_min * (1.0 - self.h_tolerance))

        # --- spend slack: reduce the slowest workers' load while h holds ---
        active = h >= 0.99 * h_min
        rounds = 0
        while active.any() and rounds < self.max_rounds:
            e_x = self._e_total(inputs, p_f, p_new)
            slowest = np.argmax(e_x, axis=1)
            cur = p_new[rows, slowest]
            cap = n_j[rows, slowest]
            increased = np.ceil(1.01 * cur)
            fallback = (increased > cap) | (increased == cur)
            increased = np.where(fallback, cur + 1.0, increased)
            active = active & ~(increased > cap)  # cannot increase: stop
            if not active.any():
                break
            prev_p = cur
            prev_h = h.copy()
            p_new[active, slowest[active]] = increased[active]
            h_step = self._estimate_h_batch(inputs, p_f, p_new, active=active)
            h[active] = h_step[active]
            rounds += 1
            violating = active & (h < 0.99 * h_min)
            if violating.any():
                # back out the violating step — and restore the pre-step h
                # with it, so the reported h describes the returned p', not
                # the rejected candidate
                p_new[violating, slowest[violating]] = prev_p[violating]
                h[violating] = prev_h[violating]
            active = active & ~violating

        p_out = np.maximum(p_new, 1.0).astype(np.int64)
        return p_out, h_min, h

    def optimize(self, p: Sequence[int], inputs: OptimizerInputs) -> np.ndarray:
        """Scalar entry point: Algorithm 1 for one scenario (S = 1 batch)."""
        hm = None if self.h_min is None else np.array([self.h_min])
        p_new, h_min, last_h = self.optimize_batch(
            np.asarray(p, dtype=np.int64)[None, :], inputs.as_batch(), hm
        )
        self.h_min = float(h_min[0])
        self.last_h = float(last_h[0])
        return p_new[0]

    # -- publication gate (paper §6.3) -------------------------------------
    def should_publish_batch(
        self, p: np.ndarray, p_new: np.ndarray, inputs: OptimizerInputs
    ) -> np.ndarray:
        """[S] bool: Eq.-(7) objective improves by > improvement_threshold."""
        p = np.asarray(p, dtype=np.float64)
        p_new_arr = np.asarray(p_new, dtype=np.float64)
        cur = self.objective(self._e_total(inputs, p, p))
        new = self.objective(self._e_total(inputs, p, p_new_arr))
        return new < cur * (1.0 - self.improvement_threshold)

    def should_publish(
        self, p: Sequence[int], p_new: Sequence[int], inputs: OptimizerInputs
    ) -> bool:
        """Paper §6.3: only distribute p' if the Eq.-(7) objective improves by
        more than ``improvement_threshold`` (cache evictions are costly)."""
        return bool(
            self.should_publish_batch(
                np.asarray(p, np.float64)[None, :],
                np.asarray(p_new, np.float64)[None, :],
                inputs.as_batch(),
            )[0]
        )
