"""Partition arithmetic and alignment (paper §6.3).

All indices are 1-based inclusive, matching the paper:

    p_start(n, p, i) = floor((i-1)n/p) + 1
    p_stop(n, p, i)  = floor(in/p)
    p_trans(n, p, p', k) = ceil(p_start(n, p, k) * p' / n)

``align_partitions`` is Algorithm 2: when a worker's subpartition count
changes p -> p', find (k, k') such that the k'-th of p' partitions starts at
the same sample as the k-th of p partitions, starting the search from the
worker's next cyclic index so the first few subpartitions are not
over-processed.  Termination is guaranteed because k = k' = 1 always aligns.

Example — 10 samples, repartitioned 2 -> 5 after processing partition 1:

>>> from repro.lb.partitioner import align_partitions, p_start, p_stop
>>> p_start(10, 2, 2), p_stop(10, 2, 2)    # old partition 2 covers [6, 10]
(6, 10)
>>> k, k_new = align_partitions(10, 2, 5, 1)  # k=1 processed last
>>> (k, k_new)
(1, 1)
>>> p_start(10, 5, k_new) == p_start(10, 2, k)  # boundaries align
True
"""

from __future__ import annotations

import dataclasses
import math


def p_start(n: int, p: int, i: int) -> int:
    """First (1-based) sample of the i-th of p partitions of n samples."""
    return (i - 1) * n // p + 1


def p_stop(n: int, p: int, i: int) -> int:
    """Last (1-based) sample of the i-th of p partitions of n samples."""
    return i * n // p


def p_trans(n: int, p: int, p_new: int, k: int) -> int:
    """Index of the partition (out of p_new) containing sample
    p_start(n, p, k)."""
    return math.ceil(p_start(n, p, k) * p_new / n)


def cyclic_increment(k: int, p: int) -> int:
    """k <- mod(k, p) + 1 (paper Eq. 8)."""
    return k % p + 1


#: geometric step and half-span of the default §6 p-ladder (see
#: :func:`build_p_ladder`): candidate subpartition counts range over
#: roughly ``[p0 / LADDER_SPAN, p0 * LADDER_SPAN]`` in ~35% steps.
LADDER_RATIO = 1.35
LADDER_SPAN = 4.0


def build_p_ladder(
    p0: int,
    n_cap: int,
    *,
    ratio: float = LADDER_RATIO,
    span: float = LADDER_SPAN,
) -> tuple[int, ...]:
    """The finite ladder of subpartition counts Algorithm 1 climbs on.

    A geometric grid of integers around the initial subpartition count
    ``p0`` (always a member), clipped to ``[1, n_cap]``.  Restricting the
    hill-climb to this ladder is what lets the fused-scan engine
    pre-allocate the §5 cache's slot universe: every interval any
    repartition can ever produce is one of ``sum(ladder)`` intervals per
    worker, enumerable before the scan starts (see
    :func:`repro.core.gradient_cache.build_slot_universe`).  The trade-off
    is that the optimizer can no longer take ±1% steps or hand a
    comm-bound worker exactly ``n_j`` subpartitions — it moves in ~35%
    steps and tops out at ``min(span * p0, n_cap)``.

    >>> build_p_ladder(10, 1000)
    (2, 3, 4, 5, 7, 10, 14, 18, 25, 33, 40)
    >>> build_p_ladder(10, 4)  # tiny worker: ladder clipped to [1, n_j]
    (2, 3, 4)
    """
    if p0 < 1 or n_cap < 1:
        raise ValueError(f"p0={p0} and n_cap={n_cap} must be >= 1")
    lo = min(max(1, int(math.floor(p0 / span))), n_cap)
    hi = max(lo, min(int(math.ceil(p0 * span)), n_cap))
    vals = set()
    k = 0
    while True:
        v = int(round(p0 * ratio**k))
        if v > hi:
            break
        vals.add(max(lo, v))
        k += 1
    k = -1
    while True:
        v = int(round(p0 * ratio**k))
        if v < lo:
            break
        vals.add(min(hi, v))
        k -= 1
    vals.add(min(max(p0, lo), hi))
    vals.add(lo)
    vals.add(hi)  # span top is always reachable (the minimal-work rung)
    return tuple(sorted(v for v in vals if 1 <= v <= n_cap))


def ladder_intervals(
    base_start: int, base_stop: int, ladder: tuple[int, ...]
) -> list[tuple[int, int]]:
    """Every *global* interval a worker can produce on the ladder.

    For each ladder entry ``p`` (clipped to the worker's local sample
    count), the ``p`` cyclic subpartition intervals in global 1-based
    coordinates, deduplicated (nested ladder entries share boundaries) and
    sorted by start.  This is the per-worker slice of the fused engine's
    pre-allocated slot universe.
    """
    n_local = base_stop - base_start + 1
    if n_local < 1:
        raise ValueError("empty worker range")
    seen = set()
    for raw in ladder:
        p = min(raw, n_local)
        for k in range(1, p + 1):
            lo = base_start + p_start(n_local, p, k) - 1
            hi = base_start + p_stop(n_local, p, k) - 1
            seen.add((lo, hi))
    return sorted(seen)


def _align(n: int, p: int, p_new: int, k: int) -> tuple[int, int]:
    """Algorithm 2 lines 2-6: walk down from k until boundaries align.

    Termination: at k_new = 1 the recomputed k is p_trans(n, p_new, p, 1) = 1
    and partition 1 always starts at sample 1 for any partition count, so the
    pair (1, 1) aligns.  As *printed* in the paper the loop can decrement
    k_new below 1 when the initial k_new = 1 is checked against the original
    (unrelated) k — e.g. n=2, p=2 -> p_new=1 with k=2.  We guard that edge
    case by falling back to the always-valid (1, 1) solution."""
    k_new = p_trans(n, p, p_new, k)  # line 2
    while p_start(n, p_new, k_new) != p_start(n, p, k):  # line 3
        k_new -= 1  # line 4
        if k_new < 1:
            return 1, 1  # guaranteed-aligned fallback (see docstring)
        k = p_trans(n, p_new, p, k_new)  # line 5
    return k, k_new


def align_partitions(n: int, p: int, p_new: int, k: int) -> tuple[int, int]:
    """Algorithm 2.  Returns (k_aligned_old, k_new) such that
    ``p_start(n, p_new, k_new) == p_start(n, p, k_aligned_old)``.

    ``k`` is the index of the partition the worker processed *last*; the
    algorithm first advances it cyclically (line 1), then walks down until the
    boundaries align."""
    if not (1 <= p <= n and 1 <= p_new <= n):
        raise ValueError(f"invalid partition counts p={p}, p_new={p_new} for n={n}")
    if not (1 <= k <= p):
        raise ValueError(f"k={k} out of range 1..{p}")
    k = cyclic_increment(k, p)  # line 1
    return _align(n, p, p_new, k)


@dataclasses.dataclass
class Subpartitioner:
    """Per-worker subpartition bookkeeping (paper §6.3).

    The worker owns global samples [base_start, base_stop] (1-based
    inclusive); its n_i samples are split into p subpartitions processed in
    cyclic order k = 1..p.  ``current_interval()`` maps the local subpartition
    to *global* sample indices (what the gradient-cache keys on)."""

    base_start: int
    base_stop: int
    p: int = 1
    k: int = 1  # index of the NEXT subpartition to process

    def __post_init__(self):
        if self.base_stop < self.base_start:
            raise ValueError("empty worker range")
        self.p = min(self.p, self.n_local)

    @property
    def n_local(self) -> int:
        return self.base_stop - self.base_start + 1

    def current_interval(self) -> tuple[int, int]:
        lo = p_start(self.n_local, self.p, self.k)
        hi = p_stop(self.n_local, self.p, self.k)
        return self.base_start + lo - 1, self.base_start + hi - 1

    def advance(self) -> None:
        """Move to the next subpartition (paper Eq. 8)."""
        self.k = cyclic_increment(self.k, self.p)

    def repartition(self, p_new: int) -> None:
        """Change the subpartition count using Algorithm-2 alignment so the
        next processed subpartition starts where a cached one did."""
        p_new = max(1, min(p_new, self.n_local))
        if p_new == self.p:
            return
        # ``self.k`` already points at the NEXT subpartition (advance() ran
        # after the last task), which is what Algorithm 2's line 1 produces —
        # so enter the alignment loop directly at lines 2-6.
        _, k_new = _align(self.n_local, self.p, p_new, self.k)
        self.p = p_new
        self.k = k_new

    def next_interval_and_advance(self) -> tuple[int, int]:
        iv = self.current_interval()
        self.advance()
        return iv
