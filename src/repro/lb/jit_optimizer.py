"""Jittable §6 load balancing: Algorithm 1 as pure JAX, shared by engines.

Everything the load balancer computes with floats lives in this module as
*traceable* functions — profiler window moments (§6.1), the gamma what-if
draws and batched trace replay behind the contribution estimate ``h``
(§6.2), the equalize / restore / slack hill-climb of Algorithm 1, the
§6.3 publication gate, and the Algorithm-2 alignment walk.  The host
:class:`~repro.lb.optimizer.LoadBalanceOptimizer` (used by the scalar
``TrainingSimulator`` and the batched host convergence engine) calls
jitted wrappers of these functions; the fused ``jax.lax.scan`` engine
(:mod:`repro.experiments.fused`) traces the same functions inline in its
scan body.  Bit-exactness of ``scan == host == scalar`` for §6 configs
rests on that sharing plus the CPU batch-invariance of row-independent
kernels that the repo already pins empirically (``tests/test_fused.py``,
``tests/test_lb_scan.py``).

Two deliberate reformulations versus the pre-jittable optimizer:

* **The p-ladder.**  Algorithm 1 no longer takes ±1% steps over all of
  ``[1, n_j]``; it climbs a finite geometric ladder of subpartition
  counts (:func:`repro.lb.partitioner.build_p_ladder`).  That bounds the
  set of intervals any repartition can produce, which is what lets the
  fused engine pre-allocate the §5 cache's slot universe at static
  shapes.  The equalize phase snaps its continuous solution down to the
  ladder; comm-bound workers get the ladder's top rung (least work)
  instead of exactly ``n_j`` subpartitions.
* **Wilson–Hilferty what-if draws.**  The what-if traces behind ``h``
  are gamma draws via the Wilson–Hilferty cube transform of one fixed
  ``[N, K]`` standard-normal draw per optimizer call (key derived from
  the optimizer seed), every scenario transforming the same base draw
  with its own moments — mirroring the host implementation that
  re-seeded ``default_rng(seed)`` per scenario, making a scenario's
  draws depend only on its own moments (never on its row position or on
  which scenarios share the batch), and keeping the estimator a fixed
  elementwise expression instead of a rejection loop (``jax.random.gamma``
  is ~1000x slower than the transform at the 100-worker scale, and
  Algorithm 1 re-estimates h every hill-climb round).

All hill-climb state updates are masked by per-scenario ``active`` flags,
so a whole ``[S]`` batch balances in one call and inactive rows pass
through untouched — the scalar path is literally the ``S = 1`` slice.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Algorithm-1 constants shared by the host optimizer defaults and the
# fused-scan static spec (both must agree for cross-engine bit-exactness).
H_TOLERANCE = 0.01
SIM_ITERATIONS = 100
MAX_ROUNDS = 200
IMPROVEMENT_THRESHOLD = 0.10
#: §6.1 moving-window width (seconds) used by every engine's profiler view
PROFILER_WINDOW = 10.0


# ---------------------------------------------------------------------------
# §6.1 — profiler window moments
# ---------------------------------------------------------------------------


def window_moments(t_rec, comm, comp, valid, now, window, since=None):
    """Moving-window mean/variance per worker (the §6.1 profiler view).

    ``t_rec``/``comm``/``comp``/``valid`` are ``[..., N, T]`` buffers
    indexed by the *iteration that started the task* (one slot per task,
    written when the task's completion is observed); ``now`` is ``[...]``
    per scenario.  A sample is in-window iff ``t_rec >= now - window`` —
    identical to the deque profiler's front eviction because per-worker
    completion times are monotone in the task's iteration.  ``since``
    (``[...]`` per scenario, optional) additionally drops samples recorded
    before it — the churn re-profiling cutoff: after a fleet change the
    optimizer must not mix moments from the previous regime, so engines
    pass the latest churn-boundary time.  ``since = -inf`` is the static
    behaviour.  Returns ``(e_comm, v_comm, e_comp, v_comp, counts)`` with
    the single-sample variance floored to 1e-12 like
    ``LatencyProfiler.stats``.
    """
    cutoff = now[..., None, None] - window
    if since is not None:
        cutoff = jnp.maximum(cutoff, since[..., None, None])
    in_win = valid & (t_rec >= cutoff)
    cnt = jnp.sum(in_win, axis=-1)
    cnt_f = jnp.maximum(cnt, 1).astype(comm.dtype)

    def mean_var(x):
        mean = jnp.sum(jnp.where(in_win, x, 0.0), axis=-1) / cnt_f
        d = x - mean[..., None]
        var = jnp.sum(jnp.where(in_win, d * d, 0.0), axis=-1) / cnt_f
        return mean, jnp.where(cnt > 1, var, 1e-12)

    e_comm, v_comm = mean_var(comm)
    e_comp, v_comp = mean_var(comp)
    return e_comm, v_comm, e_comp, v_comp, cnt


# ---------------------------------------------------------------------------
# §6.2 — objective and the h(p') contribution estimate
# ---------------------------------------------------------------------------


def e_total(e_comm, e_comp, p, p_new):
    """Linearised expected total latency e'_{X,i} (paper §6.2)."""
    return e_comm + e_comp * p / p_new


def objective(e_x):
    """max/min ratio of expected per-worker total latency (Eq. 7)."""
    lo = jnp.maximum(e_x.min(axis=-1), 1e-12)
    return e_x.max(axis=-1) / lo


def _wilson_hilferty_gamma(z, shape, scale):
    """Gamma(shape, scale) draws from standard-normal draws ``z``.

    The Wilson–Hilferty cube transform: X ≈ shape·scale·(1 − 1/(9·shape)
    + z·sqrt(1/(9·shape)))³ — excellent for the moderate-to-large shapes
    the profiler produces (shape = 1/cv² ≈ 10–100) and, unlike rejection
    sampling, a fixed elementwise expression: cheap inside the scan, and
    the draw for a given (worker, iteration) position depends only on
    that position's normal draw and the scenario's own moments.  Clamped
    to a small positive floor (the cube can graze zero for tiny shapes).
    """
    c = 1.0 / (9.0 * shape)
    x = shape * scale * (1.0 - c + z * jnp.sqrt(c)) ** 3
    return jnp.maximum(x, 1e-12)


def _draw_what_if(key, e_y, v_y, e_z, v_z, K: int):
    """[S, N, K] what-if latency draws (comm, comp).

    One ``[N, K]`` standard-normal base draw per component, shared by
    every scenario (the batched counterpart of the host optimizer's
    historical per-scenario ``default_rng(seed)`` streams, which also
    shared one underlying uniform stream), pushed through the
    Wilson–Hilferty gamma transform with each scenario's own moments.  A
    scenario's draws therefore depend only on its parameters — never on
    its row position or on which scenarios share the batch.
    """
    N = e_y.shape[-1]
    k_comm, k_comp = jax.random.split(key)
    z_comm = jax.random.normal(k_comm, (N, K), dtype=e_y.dtype)
    z_comp = jax.random.normal(k_comp, (N, K), dtype=e_y.dtype)
    comm = _wilson_hilferty_gamma(
        z_comm[None], (e_y * e_y / v_y)[:, :, None], (v_y / e_y)[:, :, None]
    )
    comp = _wilson_hilferty_gamma(
        z_comp[None], (e_z * e_z / v_z)[:, :, None], (v_z / e_z)[:, :, None]
    )
    return comm, comp


def _what_if_replay(comm, comp, w: int, K: int, margin: float, alive=None):
    """Participation of each worker over K what-if §4.2 iterations.

    The same idle/busy + w-th order statistic + margin-deadline algebra as
    :func:`repro.experiments.sweep.replay_batch`, traced in jnp (no
    bursts, unit loads — the what-if draws already carry the load).
    ``alive`` ([S, N] bool, optional) is the churn liveness mask at the
    optimizer call: dead workers' draws arrive pre-masked to +inf (see
    :func:`estimate_h`) so their participation is 0, and the order
    statistic waits for ``w_eff = min(w, #alive)`` of the living fleet —
    the what-if mirror of the engines' churn algebra."""
    # deferred: repro.cluster.simulator imports repro.lb.optimizer, which
    # imports this module — a top-level import would be circular
    from repro.cluster.simulator import margin_deadline, task_finish_time

    S, N, _ = comm.shape
    if alive is not None:
        w_eff = jnp.minimum(w, jnp.sum(alive, axis=1)).astype(jnp.int64)

    def body(carry, _):
        free_at, iter_end, draw_idx, part = carry
        idle = free_at <= iter_end[:, None]
        start = jnp.where(idle, iter_end[:, None], free_at)
        comm_d = jnp.take_along_axis(comm, draw_idx[:, :, None], axis=2)[:, :, 0]
        comp_d = jnp.take_along_axis(comp, draw_idx[:, :, None], axis=2)[:, :, 0]
        finish = task_finish_time(start, comp_d, comm_d)
        if alive is None:
            tau_w = jnp.sort(finish, axis=1)[:, w - 1]
        else:
            tau_w = jnp.take_along_axis(
                jnp.sort(finish, axis=1), w_eff[:, None] - 1, axis=1
            )[:, 0]
        if margin > 0.0:
            deadline = margin_deadline(tau_w, iter_end, margin)
        else:
            deadline = tau_w
        started = idle | (free_at <= deadline[:, None])
        fresh = started & (finish <= deadline[:, None])
        stale_ev = jnp.where((~idle) & (free_at <= deadline[:, None]), free_at, -jnp.inf)
        fresh_ev = jnp.where(fresh, finish, -jnp.inf)
        iter_end = jnp.maximum(
            jnp.maximum(stale_ev.max(axis=1), fresh_ev.max(axis=1)), tau_w
        )
        free_at = jnp.where(started, finish, free_at)
        draw_idx = draw_idx + started
        part = part + fresh
        return (free_at, iter_end, draw_idx, part), None

    carry0 = (
        jnp.zeros((S, N), dtype=comm.dtype),
        jnp.zeros((S,), dtype=comm.dtype),
        jnp.zeros((S, N), dtype=jnp.int64),
        jnp.zeros((S, N), dtype=jnp.int64),
    )
    (_, _, _, part), _ = jax.lax.scan(body, carry0, None, length=K)
    return part / max(K, 1)


def estimate_h(
    e_comm, v_comm, e_comp, v_comp, n_j, p_cur, p_new, *, w: int, margin: float,
    key, K: int, alive=None,
):
    """h(p') for every scenario via linearised what-if trace replay.

    With ``alive`` ([S, N] bool), dead workers' what-if comm draws are
    masked to +inf before the replay: they never finish, contribute u = 0,
    and the order statistic waits for ``w_eff`` of the living fleet.  The
    denominator keeps the full dataset size n — a death lowers h (its data
    really is uncovered), which is exactly the signal Algorithm 1 reacts
    to.  An all-True mask is value-identical to ``alive=None``.
    """
    e_y = jnp.maximum(e_comm, 1e-12)
    v_y = jnp.maximum(v_comm, 1e-18)
    ratio = p_cur / p_new
    e_z = jnp.maximum(e_comp * ratio, 1e-12)
    v_z = jnp.maximum(v_comp * ratio * ratio, 1e-18)
    comm, comp = _draw_what_if(key, e_y, v_y, e_z, v_z, K)
    if alive is not None:
        comm = jnp.where(alive[:, :, None], comm, jnp.inf)
    u = _what_if_replay(comm, comp, w, K, margin, alive=alive)
    n_tot = jnp.sum(n_j, axis=1)
    return jnp.sum(u * n_j / (p_new * n_tot[:, None]), axis=1)


# ---------------------------------------------------------------------------
# The p-ladder view
# ---------------------------------------------------------------------------


def ladder_tables(ladder: tuple[int, ...], n_j):
    """(eff [.., N, L], idx_cap [.., N]) — the per-worker effective ladder.

    ``eff[.., i, l] = min(ladder[l], n_j[.., i])`` is strictly increasing
    up to ``idx_cap`` (the last index before the ladder saturates at the
    worker's sample count); hill-climb indices are clipped to
    ``[0, idx_cap]`` so every move changes the value.
    """
    raw = jnp.asarray(ladder, dtype=n_j.dtype)
    eff = jnp.minimum(raw[..., None, :], n_j[..., None])
    idx_cap = jnp.minimum(
        jnp.sum(raw[..., None, :] < n_j[..., None], axis=-1), len(ladder) - 1
    )
    return eff, idx_cap


def ladder_value(eff, idx):
    """eff[.., i, idx[.., i]] — the p value at each worker's ladder index."""
    return jnp.take_along_axis(eff, idx[..., None], axis=-1)[..., 0]


def snap_to_ladder(eff, idx_cap, v):
    """Index of the largest ladder value <= v (clipped into [0, idx_cap])."""
    cnt = jnp.sum(eff <= v[..., None], axis=-1)
    return jnp.clip(cnt - 1, 0, idx_cap)


# ---------------------------------------------------------------------------
# Algorithm 1 on the ladder
# ---------------------------------------------------------------------------


def algorithm1(
    p_cur, e_comm, v_comm, e_comp, v_comp, n_j, h_min, active, *,
    ladder: tuple[int, ...], w: int, margin: float, key,
    K: int = SIM_ITERATIONS, h_tol: float = H_TOLERANCE,
    max_rounds: int = MAX_ROUNDS, alive=None,
):
    """Equalize / restore-contribution / spend-slack (paper Algorithm 1).

    All arrays are ``[S, N]`` (``h_min``/``active`` are ``[S]``); rows
    with ``active`` False pass through untouched.  Returns
    ``(idx_new, p_new, h_min, last_h)`` where ``idx_new`` are ladder
    indices, ``p_new`` their float values, and ``last_h`` is h at the
    returned vector (the slack phase backs violating steps out together
    with their h, so the report always describes the returned p').

    ``alive`` ([S, N] bool, optional) restricts the hill-climb to the
    living fleet: dead workers are excluded from the equalize target and
    the restore/slack argmax/argmin (±inf masks), their p is frozen at
    ``p_cur``, and the what-if h treats them as never finishing.  An
    all-True mask takes the same float path as ``alive=None``; passing
    ``None`` keeps the traced jaxpr byte-identical to the static one.
    """
    S, N = p_cur.shape
    rows = jnp.arange(S)
    eff, idx_cap = ladder_tables(ladder, n_j)

    def h_of(p_new):
        return estimate_h(
            e_comm, v_comm, e_comp, v_comp, n_j, p_cur, p_new,
            w=w, margin=margin, key=key, K=K, alive=alive,
        )

    def only_alive(x):  # mask for max/argmax reductions
        return x if alive is None else jnp.where(alive, x, -jnp.inf)

    # h_min = h(p_0) where not yet established (NaN)
    unset = jnp.isnan(h_min) & active
    h0 = jax.lax.cond(
        jnp.any(unset), h_of, lambda p: jnp.zeros((S,), p_cur.dtype), p_cur
    )
    h_min = jnp.where(unset, h0, h_min)

    # --- equalize total latency against the slowest worker ---
    e_x = e_total(e_comm, e_comp, p_cur, p_cur)
    slowest = jnp.argmax(only_alive(e_x), axis=1)
    target = (
        e_comm[rows, slowest]
        + e_comp[rows, slowest] * p_cur[rows, slowest] / p_cur[rows, slowest]
    )
    denom = target[:, None] - e_comm
    safe = jnp.where(denom > 0, denom, 1.0)
    balanced = jnp.maximum(jnp.floor(e_comp * p_cur / safe), 1.0)
    # comm-bound workers (denom <= 0) get the ladder's least-work rung
    cand = jnp.where(denom <= 0, ladder_value(eff, idx_cap), balanced)
    cand = jnp.clip(cand, 1.0, n_j)
    idx = snap_to_ladder(eff, idx_cap, cand)
    if alive is not None:
        # dead workers keep their current rung (their p is frozen)
        idx = jnp.where(alive, idx, snap_to_ladder(eff, idx_cap, p_cur))
    h = h_of(ladder_value(eff, idx))

    # --- restore contribution: give the fastest workers more work ---
    def restore_cond(st):
        _, _, act, r = st
        return jnp.any(act) & (r < max_rounds)

    def restore_body(st):
        idx, h, act, r = st
        e_now = e_total(e_comm, e_comp, p_cur, ladder_value(eff, idx))
        valid = idx > 0  # one rung down = strictly more work per task
        if alive is not None:
            valid = valid & alive
        order = jnp.argsort(e_now, axis=1, stable=True)
        valid_ord = jnp.take_along_axis(valid, order, axis=1)
        movable = valid_ord.any(axis=1)
        pick = order[rows, jnp.argmax(valid_ord, axis=1)]
        act = act & movable
        idx = idx.at[rows, pick].add(jnp.where(act, -1, 0))
        h_step = h_of(ladder_value(eff, idx))
        h = jnp.where(act, h_step, h)
        act = act & (h < h_min * (1.0 - h_tol))
        return idx, h, act, r + 1

    act0 = active & (h < h_min * (1.0 - h_tol))
    idx, h, _, _ = jax.lax.while_loop(restore_cond, restore_body, (idx, h, act0, 0))

    # --- spend slack: reduce the slowest workers' load while h holds ---
    def slack_cond(st):
        _, _, act, r = st
        return jnp.any(act) & (r < max_rounds)

    def slack_body(st):
        idx, h, act, r = st
        e_now = e_total(e_comm, e_comp, p_cur, ladder_value(eff, idx))
        slowest = jnp.argmax(only_alive(e_now), axis=1)
        act = act & (idx[rows, slowest] < idx_cap[rows, slowest])
        prev_idx, prev_h = idx, h
        idx = idx.at[rows, slowest].add(jnp.where(act, 1, 0))
        h_step = h_of(ladder_value(eff, idx))
        h = jnp.where(act, h_step, h)
        viol = act & (h < 0.99 * h_min)
        # back out the violating step — and its h with it, so the reported
        # h describes the returned p', not the rejected candidate
        idx = jnp.where(viol[:, None], prev_idx, idx)
        h = jnp.where(viol, prev_h, h)
        act = act & ~viol
        return idx, h, act, r + 1

    act0 = active & (h >= 0.99 * h_min)
    idx, h, _, _ = jax.lax.while_loop(slack_cond, slack_body, (idx, h, act0, 0))
    return idx, ladder_value(eff, idx), h_min, h


def should_publish(p_cur, p_new, e_comm, e_comp, threshold: float, alive=None):
    """[S] bool: Eq.-(7) objective improves by > threshold (paper §6.3).

    With ``alive``, the max/min latency ratio is taken over the living
    fleet only — a dead worker's (frozen) expected latency must not gate
    publication for the workers that can still act on it."""
    ex_cur = e_total(e_comm, e_comp, p_cur, p_cur)
    ex_new = e_total(e_comm, e_comp, p_cur, p_new)
    if alive is not None:
        hi = jnp.where(alive, ex_cur, -jnp.inf)
        lo = jnp.where(alive, ex_cur, jnp.inf)
        cur = hi.max(axis=-1) / jnp.maximum(lo.min(axis=-1), 1e-12)
        hi = jnp.where(alive, ex_new, -jnp.inf)
        lo = jnp.where(alive, ex_new, jnp.inf)
        new = hi.max(axis=-1) / jnp.maximum(lo.min(axis=-1), 1e-12)
    else:
        cur = objective(ex_cur)
        new = objective(ex_new)
    return new < cur * (1.0 - threshold)


def lb_update(
    p_cur, e_comm, v_comm, e_comp, v_comp, n_j, h_min, active, *,
    ladder: tuple[int, ...], w: int, margin: float, key,
    K: int = SIM_ITERATIONS, h_tol: float = H_TOLERANCE,
    max_rounds: int = MAX_ROUNDS, threshold: float = IMPROVEMENT_THRESHOLD,
    alive=None,
):
    """One §6 optimizer round: Algorithm 1 + the publication gate.

    Returns ``(p_new [S, N] int64, h_min [S], last_h [S], publish [S])``
    with ``h_min`` updated only for active rows and ``publish`` False for
    inactive ones.  ``alive`` applies the churn masking described on
    :func:`algorithm1`; dead workers' published p equals their current p.
    """
    idx, p_new_f, h_min_out, last_h = algorithm1(
        p_cur, e_comm, v_comm, e_comp, v_comp, n_j, h_min, active,
        ladder=ladder, w=w, margin=margin, key=key, K=K, h_tol=h_tol,
        max_rounds=max_rounds, alive=alive,
    )
    h_min_out = jnp.where(active, h_min_out, h_min)
    pub = should_publish(p_cur, p_new_f, e_comm, e_comp, threshold, alive=alive) & active
    p_out = jnp.maximum(p_new_f, 1.0).astype(jnp.int64)
    p_out = jnp.where(active[:, None], p_out, p_cur.astype(jnp.int64))
    if alive is not None:
        p_out = jnp.where(alive, p_out, p_cur.astype(jnp.int64))
    return p_out, h_min_out, last_h, pub


# ---------------------------------------------------------------------------
# Algorithm 2 — vectorized alignment walk (exact integer arithmetic)
# ---------------------------------------------------------------------------


def _p_start_j(n, p, i):
    return (i - 1) * n // p + 1


def _p_trans_j(n, p, p_new, k):
    s = _p_start_j(n, p, k) * p_new
    return (s + n - 1) // n  # ceil for positive ints


def align_batch(n, p, p_new, k, needs):
    """Vectorized Algorithm-2 walk (``repro.lb.partitioner._align``).

    ``n``/``p``/``p_new``/``k`` are int arrays (``n`` broadcastable);
    entries with ``needs`` False are returned unchanged.  Integer
    arithmetic only, so the result is exactly the scalar walk's.
    """
    one = jnp.ones_like(k)
    n = jnp.broadcast_to(n, k.shape)
    k_new = jnp.where(needs, _p_trans_j(n, p, p_new, k), k)

    def aligned(kk, kn):
        return _p_start_j(n, p_new, kn) == _p_start_j(n, p, kk)

    done = (~needs) | aligned(k, k_new)

    def cond(st):
        return jnp.any(~st[2])

    def body(st):
        kk, kn, dn = st
        kn2 = jnp.where(dn, kn, kn - 1)
        fb = (~dn) & (kn2 < 1)  # guaranteed-aligned (1, 1) fallback
        kk2 = jnp.where(fb, one, jnp.where(dn, kk, _p_trans_j(n, p_new, p, kn2)))
        kn3 = jnp.where(fb, one, kn2)
        dn2 = dn | fb | aligned(kk2, kn3)
        return kk2, kn3, dn2

    k, k_new, _ = jax.lax.while_loop(cond, body, (k, k_new, done))
    return k, k_new


# ---------------------------------------------------------------------------
# Jitted entry points for the host paths
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _lb_update_jitted(ladder, w, K, h_tol, max_rounds, threshold, margin,
                      with_alive=False):
    if with_alive:

        def f(p_cur, e_comm, v_comm, e_comp, v_comp, n_j, h_min, active, key,
              alive):
            return lb_update(
                p_cur, e_comm, v_comm, e_comp, v_comp, n_j, h_min, active,
                ladder=ladder, w=w, margin=margin, key=key, K=K, h_tol=h_tol,
                max_rounds=max_rounds, threshold=threshold, alive=alive,
            )

    else:

        def f(p_cur, e_comm, v_comm, e_comp, v_comp, n_j, h_min, active, key):
            return lb_update(
                p_cur, e_comm, v_comm, e_comp, v_comp, n_j, h_min, active,
                ladder=ladder, w=w, margin=margin, key=key, K=K, h_tol=h_tol,
                max_rounds=max_rounds, threshold=threshold,
            )

    return jax.jit(f)


@functools.lru_cache(maxsize=8)
def _window_moments_jitted(window, with_since=False):
    if with_since:

        def f(t_rec, comm, comp, valid, now, since):
            return window_moments(t_rec, comm, comp, valid, now, window, since)

    else:

        def f(t_rec, comm, comp, valid, now):
            return window_moments(t_rec, comm, comp, valid, now, window)

    return jax.jit(f)


@functools.lru_cache(maxsize=64)
def _estimate_h_jitted(w, K, margin):
    def f(e_comm, v_comm, e_comp, v_comp, n_j, p_cur, p_new, key):
        return estimate_h(
            e_comm, v_comm, e_comp, v_comp, n_j, p_cur, p_new,
            w=w, margin=margin, key=key, K=K,
        )

    return jax.jit(f)


@functools.lru_cache(maxsize=8)
def _should_publish_jitted(threshold):
    def f(p_cur, p_new, e_comm, e_comp):
        return should_publish(p_cur, p_new, e_comm, e_comp, threshold)

    return jax.jit(f)
