"""Dynamic load balancing (paper §6): profiler -> optimizer -> re-partition."""

from repro.lb.partitioner import (
    p_start,
    p_stop,
    p_trans,
    align_partitions,
    cyclic_increment,
    Subpartitioner,
)
from repro.lb.optimizer import LoadBalanceOptimizer, OptimizerInputs

__all__ = [
    "p_start",
    "p_stop",
    "p_trans",
    "align_partitions",
    "cyclic_increment",
    "Subpartitioner",
    "LoadBalanceOptimizer",
    "OptimizerInputs",
]
