"""DSAG gradient cache (paper §5).

The coordinator maintains a set 𝒴 of subgradients keyed by *sample intervals*
``[i, j]`` (1-based, inclusive, matching the paper's notation), each tagged
with the iteration index ``t`` of the iterate it was computed from.  On
receiving ``Y_{i:j}^{(t)}``:

  1. select overlapping cached entries 𝒴';
  2. if any entry of 𝒴' is at least as recent (t' >= t), discard the received
     subgradient (staleness dominance);
  3. otherwise evict 𝒴' and insert the new entry, maintaining the running sum
     ``H = Σ_{y∈𝒴} y`` incrementally:  H += Y - Σ_{y∈𝒴'} y.

Entries are stored in a sorted list keyed by interval start — the ordered-map
stand-in for the paper's tree structure; lookup/insert/delete are
O(log|𝒴| + overlap) via bisect.  The cache also tracks the *coverage*
ξ = (# samples covered)/n used to scale the gradient estimate (paper Eq. 6).

Exact-match fast path: if an entry with identical [i, j] exists, it is
updated in place (paper remark: the update then degrades to SAG's).
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Any, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CacheEntry:
    start: int  # i (inclusive, 1-based)
    stop: int  # j (inclusive, 1-based)
    iteration: int  # t
    value: Any  # the subgradient (numpy/JAX array or pytree leaf container)

    def overlaps(self, start: int, stop: int) -> bool:
        return not (self.stop < start or stop < self.start)

    @property
    def width(self) -> int:
        return self.stop - self.start + 1


class GradientCache:
    """Interval-keyed subgradient cache with incremental sum maintenance."""

    def __init__(self, num_samples: int, zero_like: Any):
        if num_samples <= 0:
            raise ValueError("num_samples must be positive")
        self.num_samples = num_samples
        self._starts: List[int] = []  # sorted entry starts
        self._entries: List[CacheEntry] = []  # parallel to _starts
        self._covered: int = 0
        self._sum = np.array(zero_like, dtype=np.float64, copy=True)
        self.evictions: int = 0  # total entries evicted by overlap (telemetry)
        self.rejected_stale: int = 0

    # -- queries ---------------------------------------------------------
    @property
    def sum(self) -> np.ndarray:
        """H = Σ_{y∈𝒴} y (maintained incrementally)."""
        return self._sum

    @property
    def coverage(self) -> float:
        """ξ: fraction of the n samples covered by cached entries."""
        return self._covered / self.num_samples

    @property
    def num_entries(self) -> int:
        return len(self._entries)

    def entries(self) -> List[CacheEntry]:
        return list(self._entries)

    def _overlapping(self, start: int, stop: int) -> Tuple[int, int]:
        """Return [lo, hi) slice of entries overlapping [start, stop].

        Entries are disjoint and sorted by start, so the overlap range is
        contiguous."""
        # first entry whose stop >= start:
        lo = bisect.bisect_left(self._starts, start)
        if lo > 0 and self._entries[lo - 1].stop >= start:
            lo -= 1
        hi = bisect.bisect_right(self._starts, stop)
        return lo, hi

    # -- the §5 update rule -----------------------------------------------
    def insert(self, start: int, stop: int, iteration: int, value: Any) -> bool:
        """Apply the DSAG cache update.  Returns True iff the subgradient was
        accepted (False = discarded as stale-dominated)."""
        if not (1 <= start <= stop <= self.num_samples):
            raise ValueError(
                f"interval [{start},{stop}] outside 1..{self.num_samples}"
            )
        lo, hi = self._overlapping(start, stop)
        overlapping = self._entries[lo:hi]
        # staleness dominance: any overlapping entry at least as recent wins
        for e in overlapping:
            if e.iteration >= iteration:
                self.rejected_stale += 1
                return False
        # exact-match in-place fast path (degrades to the SAG update)
        if len(overlapping) == 1 and overlapping[0].start == start and overlapping[0].stop == stop:
            e = overlapping[0]
            self._sum += np.asarray(value, dtype=np.float64) - np.asarray(
                e.value, dtype=np.float64
            )
            e.value = value
            e.iteration = iteration
            return True
        # evict overlaps, insert new
        removed_width = 0
        for e in overlapping:
            self._sum -= np.asarray(e.value, dtype=np.float64)
            removed_width += e.width
        self.evictions += len(overlapping)
        del self._entries[lo:hi]
        del self._starts[lo:hi]
        pos = bisect.bisect_left(self._starts, start)
        self._starts.insert(pos, start)
        self._entries.insert(pos, CacheEntry(start, stop, iteration, value))
        self._sum += np.asarray(value, dtype=np.float64)
        self._covered += (stop - start + 1) - removed_width
        return True

    # -- invariant checks (used by property tests) -------------------------
    def check_invariants(self) -> None:
        assert self._starts == [e.start for e in self._entries]
        assert all(
            self._entries[k].stop < self._entries[k + 1].start
            for k in range(len(self._entries) - 1)
        ), "entries must be disjoint and sorted"
        width = sum(e.width for e in self._entries)
        assert width == self._covered, f"coverage mismatch {width} != {self._covered}"
        recomputed = np.zeros_like(self._sum)
        for e in self._entries:
            recomputed = recomputed + np.asarray(e.value, dtype=np.float64)
        np.testing.assert_allclose(recomputed, self._sum, rtol=1e-9, atol=1e-9)
