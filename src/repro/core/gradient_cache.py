"""DSAG gradient cache (paper §5).

The coordinator maintains a set 𝒴 of subgradients keyed by *sample intervals*
``[i, j]`` (1-based, inclusive, matching the paper's notation), each tagged
with the iteration index ``t`` of the iterate it was computed from.  On
receiving ``Y_{i:j}^{(t)}``:

  1. select overlapping cached entries 𝒴';
  2. if any entry of 𝒴' is at least as recent (t' >= t), discard the received
     subgradient (staleness dominance);
  3. otherwise evict 𝒴' and insert the new entry, maintaining the running sum
     ``H = Σ_{y∈𝒴} y`` incrementally:  H += Y - Σ_{y∈𝒴'} y.

Entries are stored in a sorted list keyed by interval start — the ordered-map
stand-in for the paper's tree structure; lookup/insert/delete are
O(log|𝒴| + overlap) via bisect.  The cache also tracks the *coverage*
ξ = (# samples covered)/n used to scale the gradient estimate (paper Eq. 6).

Exact-match fast path: if an entry with identical [i, j] exists, it is
updated in place (paper remark: the update then degrades to SAG's).

Example — staleness dominance and overlap eviction (paper §5):

>>> import numpy as np
>>> from repro.core.gradient_cache import GradientCache
>>> cache = GradientCache(10, np.zeros(2))
>>> cache.insert(1, 5, 0, np.ones(2))       # Y_{1:5}^{(0)} accepted
True
>>> cache.insert(3, 7, 0, np.ones(2))       # overlaps an equally recent entry
False
>>> cache.insert(3, 7, 1, 2 * np.ones(2))   # newer iterate: evicts [1, 5]
True
>>> cache.coverage                           # ξ: only [3, 7] remains
0.5
>>> cache.sum.tolist()
[2.0, 2.0]
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Any

import numpy as np


def scenario_ranks(ev_s: np.ndarray) -> np.ndarray:
    """Position of each event within its scenario's subsequence.

    ``ev_s`` is the per-event scenario index of a *time-ordered* event
    batch; the result assigns 0, 1, 2, ... to each scenario's events in
    order of appearance.  Events sharing a rank belong to distinct
    scenarios, so a rank's updates can be applied as one masked vectorized
    scatter without changing any scenario's sequential semantics.

    >>> scenario_ranks(np.array([0, 1, 0, 1, 1])).tolist()
    [0, 0, 1, 1, 2]
    """
    ev_s = np.asarray(ev_s)
    order = np.argsort(ev_s, kind="stable")
    sorted_s = ev_s[order]
    ranks = np.empty(ev_s.size, dtype=np.int64)
    ranks[order] = np.arange(ev_s.size) - np.searchsorted(
        sorted_s, sorted_s, side="left"
    )
    return ranks


@dataclasses.dataclass(frozen=True)
class SlotUniverse:
    """The pre-allocated interval universe of a fused §6 run.

    With Algorithm 1 restricted to the p-ladder
    (:func:`repro.lb.partitioner.build_p_ladder`), the set of intervals a
    repartition can ever produce is finite and known before the run:
    every (worker, ladder entry, cyclic index) triple.  The fused scan
    keeps its per-scenario cache state dense over these ``E`` slots, so a
    §6 repartition flips masks over static shapes instead of growing the
    slot table mid-scan — the memory trade-off is ``E ≈ N * sum(ladder)``
    value buffers up front (documented in docs/ARCHITECTURE.md).

    The scan-side consumer of this universe must keep its ``[S, E, ...]``
    value table *write-only* inside the per-event rank loop — a single
    stray read forces XLA to copy the whole table per trip.  That
    discipline is machine-checked by tracelint rule TL002
    (``repro.analysis.lint``; see "Checked invariants" in
    docs/ARCHITECTURE.md).

    ``slot_table[i, l, k-1]`` maps worker ``i``'s k-th subpartition at
    ladder entry ``l`` to its slot; ``overlap_idx[e]`` lists the other
    slots of the same worker whose intervals intersect slot ``e``'s,
    sorted by interval start and padded with -1 — the static form of the
    scalar cache's sorted eviction walk.
    """

    starts: np.ndarray  # [E] 1-based inclusive
    stops: np.ndarray  # [E]
    widths: np.ndarray  # [E]
    slot_table: np.ndarray  # [N, L, Pmax] int64, -1 where k > p
    overlap_idx: np.ndarray  # [E, Omax] int64, -1 padding
    owners: np.ndarray  # [E] worker index whose base range contains the slot

    @property
    def num_slots(self) -> int:
        return int(self.starts.size)


def build_slot_universe(
    base_start, base_stop, ladder: tuple[int, ...], *, with_overlaps: bool = True
) -> SlotUniverse:
    """Enumerate the p-ladder's reachable intervals (see :class:`SlotUniverse`).

    ``with_overlaps=False`` skips the per-worker pairwise overlap tables
    (quadratic in per-worker slot count, and the dominant build cost for
    large universes): the fused engine's *tiled* cache computes overlaps
    against its small active entry set at runtime instead, so it only
    needs ``starts``/``stops``/``widths`` and the ``slot_table``.
    ``overlap_idx`` is then a ``[E, 1]`` all ``-1`` placeholder.
    """
    from repro.lb.partitioner import p_start, p_stop

    base_start = np.asarray(base_start, dtype=np.int64)
    base_stop = np.asarray(base_stop, dtype=np.int64)
    N, L = base_start.size, len(ladder)
    n_local = base_stop - base_start + 1
    pmax = int(min(max(ladder), int(n_local.max())))
    slot_of: dict = {}
    starts: list[int] = []
    stops: list[int] = []
    owner: list[int] = []
    slot_table = np.full((N, L, pmax), -1, dtype=np.int64)
    for i in range(N):
        nl = int(n_local[i])
        for li, raw in enumerate(ladder):
            p = min(int(raw), nl)
            for k in range(1, p + 1):
                lo = int(base_start[i]) + p_start(nl, p, k) - 1
                hi = int(base_start[i]) + p_stop(nl, p, k) - 1
                slot = slot_of.get((lo, hi))
                if slot is None:
                    slot = len(starts)
                    slot_of[(lo, hi)] = slot
                    starts.append(lo)
                    stops.append(hi)
                    owner.append(i)
                slot_table[i, li, k - 1] = slot
    starts_a = np.asarray(starts, dtype=np.int64)
    stops_a = np.asarray(stops, dtype=np.int64)
    owner_a = np.asarray(owner, dtype=np.int64)
    E = starts_a.size
    if with_overlaps:
        per_slot: list[np.ndarray] = [np.empty(0, np.int64)] * E
        omax = 1
        for i in range(N):
            sl = np.flatnonzero(owner_a == i)
            a, b = starts_a[sl], stops_a[sl]
            inter = (a[:, None] <= b[None, :]) & (a[None, :] <= b[:, None])
            np.fill_diagonal(inter, False)
            for row, sid in enumerate(sl):
                ov = sl[inter[row]]
                ov = ov[np.argsort(starts_a[ov], kind="stable")]
                per_slot[int(sid)] = ov
                omax = max(omax, ov.size)
        overlap_idx = np.full((E, omax), -1, dtype=np.int64)
        for e, ov in enumerate(per_slot):
            overlap_idx[e, : ov.size] = ov
    else:
        overlap_idx = np.full((max(E, 1), 1), -1, dtype=np.int64)
    return SlotUniverse(
        starts=starts_a,
        stops=stops_a,
        widths=stops_a - starts_a + 1,
        slot_table=slot_table,
        overlap_idx=overlap_idx,
        owners=owner_a,
    )


def active_slot_capacity(universe: SlotUniverse) -> np.ndarray:
    """Per-worker hard cap on simultaneously *active* cache entries.

    A worker's active entries are pairwise-disjoint intervals drawn from
    its slot universe, so no run can ever hold more of them than the
    largest disjoint subset of that universe — the classic greedy
    interval-scheduling count (sort by stop, take every interval starting
    after the last taken stop).  The fused engine's tiled cache sizes its
    per-worker entry tables with this bound, which also guarantees a free
    entry always exists at insert time: after evictions the active set
    plus the incoming interval is again disjoint, hence within the cap.
    """
    slot_table = universe.slot_table
    N = slot_table.shape[0]
    caps = np.zeros(N, dtype=np.int64)
    for i in range(N):
        sl = np.unique(slot_table[i][slot_table[i] >= 0])
        if sl.size == 0:
            continue
        a, b = universe.starts[sl], universe.stops[sl]
        order = np.argsort(b, kind="stable")
        count = 0
        last = np.iinfo(np.int64).min
        for j in order:
            if a[j] > last:
                count += 1
                last = b[j]
        caps[i] = count
    return caps


@dataclasses.dataclass
class CacheEntry:
    start: int  # i (inclusive, 1-based)
    stop: int  # j (inclusive, 1-based)
    iteration: int  # t
    value: Any  # the subgradient (numpy/JAX array or pytree leaf container)

    def overlaps(self, start: int, stop: int) -> bool:
        return not (self.stop < start or stop < self.start)

    @property
    def width(self) -> int:
        return self.stop - self.start + 1


class GradientCache:
    """Interval-keyed subgradient cache with incremental sum maintenance."""

    def __init__(self, num_samples: int, zero_like: Any):
        if num_samples <= 0:
            raise ValueError("num_samples must be positive")
        self.num_samples = num_samples
        self._starts: list[int] = []  # sorted entry starts
        self._entries: list[CacheEntry] = []  # parallel to _starts
        self._covered: int = 0
        self._sum = np.array(zero_like, dtype=np.float64, copy=True)
        self.evictions: int = 0  # total entries evicted by overlap (telemetry)
        self.rejected_stale: int = 0

    # -- queries ---------------------------------------------------------
    @property
    def sum(self) -> np.ndarray:
        """H = Σ_{y∈𝒴} y (maintained incrementally)."""
        return self._sum

    @property
    def coverage(self) -> float:
        """ξ: fraction of the n samples covered by cached entries."""
        return self._covered / self.num_samples

    @property
    def num_entries(self) -> int:
        return len(self._entries)

    def entries(self) -> list[CacheEntry]:
        return list(self._entries)

    def _overlapping(self, start: int, stop: int) -> tuple[int, int]:
        """Return [lo, hi) slice of entries overlapping [start, stop].

        Entries are disjoint and sorted by start, so the overlap range is
        contiguous."""
        # first entry whose stop >= start:
        lo = bisect.bisect_left(self._starts, start)
        if lo > 0 and self._entries[lo - 1].stop >= start:
            lo -= 1
        hi = bisect.bisect_right(self._starts, stop)
        return lo, hi

    # -- the §5 update rule -----------------------------------------------
    def insert(self, start: int, stop: int, iteration: int, value: Any) -> bool:
        """Apply the DSAG cache update.  Returns True iff the subgradient was
        accepted (False = discarded as stale-dominated)."""
        if not (1 <= start <= stop <= self.num_samples):
            raise ValueError(
                f"interval [{start},{stop}] outside 1..{self.num_samples}"
            )
        lo, hi = self._overlapping(start, stop)
        overlapping = self._entries[lo:hi]
        # staleness dominance: any overlapping entry at least as recent wins
        for e in overlapping:
            if e.iteration >= iteration:
                self.rejected_stale += 1
                return False
        # exact-match in-place fast path (degrades to the SAG update)
        if len(overlapping) == 1 and overlapping[0].start == start and overlapping[0].stop == stop:
            e = overlapping[0]
            self._sum += np.asarray(value, dtype=np.float64) - np.asarray(
                e.value, dtype=np.float64
            )
            e.value = value
            e.iteration = iteration
            return True
        # evict overlaps, insert new
        removed_width = 0
        for e in overlapping:
            self._sum -= np.asarray(e.value, dtype=np.float64)
            removed_width += e.width
        self.evictions += len(overlapping)
        del self._entries[lo:hi]
        del self._starts[lo:hi]
        pos = bisect.bisect_left(self._starts, start)
        self._starts.insert(pos, start)
        self._entries.insert(pos, CacheEntry(start, stop, iteration, value))
        self._sum += np.asarray(value, dtype=np.float64)
        self._covered += (stop - start + 1) - removed_width
        return True

    # -- elastic-fleet death clear ------------------------------------------
    def clear_range(self, start: int, stop: int) -> int:
        """Drop every active entry overlapping ``[start, stop]`` (1-based).

        The churn semantics: when a worker dies, its cached subgradients are
        no longer refreshable and are removed from 𝒴 at the next assignment.
        Entries are subtracted from the running sum in *interval-start
        ascending* order — the canonical float order every engine must
        reproduce for bit-exactness — and the drop does NOT count as an
        overlap eviction (``evictions`` is §5 telemetry, not churn).
        Idempotent: clearing an already-empty range removes nothing.
        Returns the number of entries removed.
        """
        lo, hi = self._overlapping(start, stop)
        removed = self._entries[lo:hi]
        for e in removed:  # slice is already start-ascending
            self._sum -= np.asarray(e.value, dtype=np.float64)
            self._covered -= e.width
        del self._entries[lo:hi]
        del self._starts[lo:hi]
        return len(removed)

    # -- invariant checks (used by property tests) -------------------------
    def check_invariants(self) -> None:
        assert self._starts == [e.start for e in self._entries]
        assert all(
            self._entries[k].stop < self._entries[k + 1].start
            for k in range(len(self._entries) - 1)
        ), "entries must be disjoint and sorted"
        width = sum(e.width for e in self._entries)
        assert width == self._covered, f"coverage mismatch {width} != {self._covered}"
        recomputed = np.zeros_like(self._sum)
        for e in self._entries:
            recomputed = recomputed + np.asarray(e.value, dtype=np.float64)
        np.testing.assert_allclose(recomputed, self._sum, rtol=1e-9, atol=1e-9)


class BatchedGradientCache:
    """S independent §5 caches sharing one interval-slot table.

    How this differs from :class:`GradientCache`: the scalar cache keys a
    sorted entry list per run; here the *interval universe* (every [i, j]
    ever inserted, across all scenarios) is a single slot table, and the
    per-scenario state is dense arrays over those slots — iteration tags
    ``[E, S]``, float64 values ``[E, S, ...]``, running sums ``[S, ...]``
    and coverage ``[S]``.  Scenarios replaying the same fleet share the
    same partition arithmetic, so their intervals coincide and the fast
    path (an active exact-match slot, the SAG-style in-place update) is a
    dict lookup + one fused add — no per-entry Python objects, no bisect.

    Per-scenario semantics are exactly the scalar cache's §5 update rule
    (staleness dominance, overlap eviction in start order, incremental sum
    maintenance), applied event-by-event so the float accumulation order —
    and therefore every bit of ``sums`` — matches a scalar
    :class:`GradientCache` fed the same per-scenario insert sequence.
    """

    def __init__(self, num_scenarios: int, num_samples: int, zero_like: Any):
        if num_scenarios <= 0 or num_samples <= 0:
            raise ValueError("num_scenarios and num_samples must be positive")
        self.num_scenarios = num_scenarios
        self.num_samples = num_samples
        zero = np.array(zero_like, dtype=np.float64, copy=True)
        self._value_shape = zero.shape
        self._sums = np.zeros((num_scenarios,) + zero.shape, dtype=np.float64)
        self._covered = np.zeros(num_scenarios, dtype=np.int64)
        self.evictions = np.zeros(num_scenarios, dtype=np.int64)
        self.rejected_stale = np.zeros(num_scenarios, dtype=np.int64)
        self._slot_of: dict = {}  # (start, stop) -> slot index
        self._intervals: list[tuple[int, int]] = []
        # parallel numpy views of the interval universe (vectorized overlap
        # tests in insert_events); rows past len(_intervals) are unused
        cap = 8
        self._int_starts = np.zeros(cap, dtype=np.int64)
        self._int_stops = np.zeros(cap, dtype=np.int64)
        self._iters = np.full((cap, num_scenarios), -1, dtype=np.int64)
        self._values = np.zeros((cap,) + self._sums.shape, dtype=np.float64)

    # -- queries ---------------------------------------------------------
    @property
    def sums(self) -> np.ndarray:
        """[S, ...] running sums H_s (same bits as scalar caches)."""
        return self._sums

    @property
    def coverage(self) -> np.ndarray:
        """[S] coverage fractions ξ_s."""
        return self._covered / self.num_samples

    def _ensure_slot(self, start: int, stop: int) -> int:
        slot = self._slot_of.get((start, stop))
        if slot is not None:
            return slot
        slot = len(self._intervals)
        if slot >= self._iters.shape[0]:
            grow = self._iters.shape[0]
            self._iters = np.concatenate(
                [self._iters, np.full((grow, self.num_scenarios), -1, np.int64)]
            )
            self._values = np.concatenate(
                [self._values, np.zeros((grow,) + self._sums.shape)]
            )
            self._int_starts = np.concatenate(
                [self._int_starts, np.zeros(grow, np.int64)]
            )
            self._int_stops = np.concatenate([self._int_stops, np.zeros(grow, np.int64)])
        self._slot_of[(start, stop)] = slot
        self._intervals.append((start, stop))
        self._int_starts[slot] = start
        self._int_stops[slot] = stop
        return slot

    def insert(self, s: int, start: int, stop: int, iteration: int, value: Any) -> bool:
        """Apply the §5 update for scenario ``s``; True iff accepted."""
        if not (1 <= start <= stop <= self.num_samples):
            raise ValueError(f"interval [{start},{stop}] outside 1..{self.num_samples}")
        exact = self._slot_of.get((start, stop))
        if exact is not None and self._iters[exact, s] >= 0:
            # active entries are disjoint, so an active exact match is the
            # ONLY overlap — the scalar fast path (SAG in-place update)
            if self._iters[exact, s] >= iteration:
                self.rejected_stale[s] += 1
                return False
            v64 = np.asarray(value, dtype=np.float64)
            self._sums[s] += v64 - self._values[exact, s]
            self._values[exact, s] = v64
            self._iters[exact, s] = iteration
            return True
        # slow path: scan active slots for overlaps (in start order, like the
        # scalar sorted-entry walk)
        overlapping = [
            slot
            for slot, (a, b) in enumerate(self._intervals)
            if self._iters[slot, s] >= 0 and not (b < start or stop < a)
        ]
        overlapping.sort(key=lambda slot: self._intervals[slot][0])
        for slot in overlapping:
            if self._iters[slot, s] >= iteration:
                self.rejected_stale[s] += 1
                return False
        v64 = np.asarray(value, dtype=np.float64)
        removed_width = 0
        for slot in overlapping:
            self._sums[s] -= self._values[slot, s]
            a, b = self._intervals[slot]
            removed_width += b - a + 1
            self._iters[slot, s] = -1
        self.evictions[s] += len(overlapping)
        target = self._ensure_slot(start, stop)
        self._iters[target, s] = iteration
        self._values[target, s] = v64
        self._sums[s] += v64
        self._covered[s] += (stop - start + 1) - removed_width
        return True

    def insert_events(
        self,
        ev_s: np.ndarray,
        ev_start: np.ndarray,
        ev_stop: np.ndarray,
        ev_iter: np.ndarray,
        values: np.ndarray,
    ) -> np.ndarray:
        """Apply a *time-ordered* batch of §5 updates as masked scatters.

        ``values`` is ``[K, ...]``; events must arrive in event-time order
        (per-scenario subsequences are what the §5 semantics depend on —
        scenarios are independent).  Events are regrouped by within-scenario
        rank (:func:`scenario_ranks`): one rank holds at most one event per
        scenario, so its updates apply as a single vectorized masked scatter
        with per-event float expressions identical to :meth:`insert` — the
        result is bit-for-bit the same as K sequential inserts, without the
        per-event Python loop.  Overlapping-but-not-exact events (which
        occur only after a §6 repartition) fall back to the scalar slow path
        at their correct sequence position.

        Returns the ``[K]`` accepted mask.
        """
        ev_s = np.asarray(ev_s, dtype=np.int64)
        ev_start = np.asarray(ev_start, dtype=np.int64)
        ev_stop = np.asarray(ev_stop, dtype=np.int64)
        ev_iter = np.asarray(ev_iter, dtype=np.int64)
        K = ev_s.size
        accepted = np.zeros(K, dtype=bool)
        if K == 0:
            return accepted
        if np.any((ev_start < 1) | (ev_stop > self.num_samples) | (ev_start > ev_stop)):
            bad = np.flatnonzero(
                (ev_start < 1) | (ev_stop > self.num_samples) | (ev_start > ev_stop)
            )[0]
            raise ValueError(
                f"interval [{ev_start[bad]},{ev_stop[bad]}] outside "
                f"1..{self.num_samples}"
            )
        ranks = scenario_ranks(ev_s)
        n_active = len(self._intervals)
        for r in range(int(ranks.max()) + 1):
            idx = np.flatnonzero(ranks == r)
            # classify each event (<= S of them): exact-active fast path,
            # overlap-free simple insert, or scalar eviction fallback
            fast, simple = [], []
            for j in idx:
                s, a, b = int(ev_s[j]), int(ev_start[j]), int(ev_stop[j])
                slot = self._slot_of.get((a, b))
                if slot is not None and self._iters[slot, s] >= 0:
                    fast.append((j, slot))
                    continue
                n_active = len(self._intervals)
                overlap = (
                    (self._iters[:n_active, s] >= 0)
                    & (self._int_starts[:n_active] <= b)
                    & (a <= self._int_stops[:n_active])
                )
                if overlap.any():
                    accepted[j] = self.insert(s, a, b, int(ev_iter[j]), values[j])
                else:
                    simple.append((j, self._ensure_slot(a, b)))
            if fast:
                j_arr = np.array([j for j, _ in fast])
                slot_arr = np.array([sl for _, sl in fast])
                s_arr = ev_s[j_arr]
                dom = self._iters[slot_arr, s_arr] >= ev_iter[j_arr]
                np.add.at(self.rejected_stale, s_arr[dom], 1)
                acc = ~dom
                if acc.any():
                    ja, sa, sl = j_arr[acc], s_arr[acc], slot_arr[acc]
                    v64 = np.asarray(values[ja], dtype=np.float64)
                    # active entries are disjoint, so an active exact match
                    # is the only overlap — the SAG-style in-place update
                    self._sums[sa] += v64 - self._values[sl, sa]
                    self._values[sl, sa] = v64
                    self._iters[sl, sa] = ev_iter[ja]
                    accepted[ja] = True
            if simple:
                j_arr = np.array([j for j, _ in simple])
                slot_arr = np.array([sl for _, sl in simple])
                s_arr = ev_s[j_arr]
                v64 = np.asarray(values[j_arr], dtype=np.float64)
                self._sums[s_arr] += v64
                self._values[slot_arr, s_arr] = v64
                self._iters[slot_arr, s_arr] = ev_iter[j_arr]
                self._covered[s_arr] += ev_stop[j_arr] - ev_start[j_arr] + 1
                accepted[j_arr] = True
        return accepted

    # -- elastic-fleet death clear ------------------------------------------
    def clear_range(self, s: int, start: int, stop: int) -> int:
        """Scenario-``s`` counterpart of :meth:`GradientCache.clear_range`.

        Active slots overlapping ``[start, stop]`` are subtracted from
        ``sums[s]`` in interval-start ascending order (the canonical churn
        float order) and deactivated; ``evictions`` is untouched.  Returns
        the number of entries removed.
        """
        n_active = len(self._intervals)
        hit = np.flatnonzero(
            (self._iters[:n_active, s] >= 0)
            & (self._int_starts[:n_active] <= stop)
            & (start <= self._int_stops[:n_active])
        )
        hit = hit[np.argsort(self._int_starts[hit], kind="stable")]
        for slot in hit:
            self._sums[s] -= self._values[slot, s]
            self._covered[s] -= self._int_stops[slot] - self._int_starts[slot] + 1
            self._iters[slot, s] = -1
        return int(hit.size)

    # -- invariant checks (used by tests) ----------------------------------
    def check_invariants(self) -> None:
        for s in range(self.num_scenarios):
            active = [
                (a, b, slot)
                for slot, (a, b) in enumerate(self._intervals)
                if self._iters[slot, s] >= 0
            ]
            active.sort()
            assert all(
                active[k][1] < active[k + 1][0] for k in range(len(active) - 1)
            ), f"scenario {s}: active entries overlap"
            width = sum(b - a + 1 for a, b, _ in active)
            assert width == self._covered[s], f"scenario {s}: coverage mismatch"
            recomputed = np.zeros(self._value_shape)
            for _, _, slot in active:
                recomputed = recomputed + self._values[slot, s]
            np.testing.assert_allclose(recomputed, self._sums[s], rtol=1e-9, atol=1e-9)
