"""The paper's primary contribution: DSAG and its supporting machinery.

- :mod:`repro.core.gradient_cache` — the §5 interval-keyed subgradient cache.
- :mod:`repro.core.problems` — the paper's finite-sum problems (PCA, logreg).
- :mod:`repro.core.dsag_pjit` — Tier-1 distributed DSAG for pjit training
  at pod scale (masked delta all-reduce form).
"""

from repro.core.gradient_cache import CacheEntry, GradientCache
from repro.core.problems import (
    FiniteSumProblem,
    LogisticRegressionProblem,
    PCAProblem,
    make_genomics_like_matrix,
    make_higgs_like,
)

__all__ = [
    "CacheEntry",
    "GradientCache",
    "FiniteSumProblem",
    "LogisticRegressionProblem",
    "PCAProblem",
    "make_genomics_like_matrix",
    "make_higgs_like",
]
