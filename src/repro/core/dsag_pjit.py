"""DSAG as a compiled multi-pod training step (Tier 1).

Partitions ↔ groups of data-parallel replicas.  Per-group gradients are
exposed by ``vmap``-ing the loss gradient over a leading group dim whose
sharding maps onto the DP mesh axes.  The DSAG cache update is the SAG
incremental form

    H  <- H + Σ_i m_i (g_i - c_i)          (one masked delta all-reduce)
    c_i <- m_i ? g_i : c_i
    ξ   <- coverage(filled groups)

and the iterate update uses  Ĥ = H / (ξ P)  in place of the exact mean
gradient (paper Eq. 6).  Stale integration is step-granular: a group whose
result missed the deadline (mask 0) parks its gradient in a *pending* slot;
the Tier-2 coordinator later sets its *flush* bit and the pending gradient
(computed from an older iterate) replaces the cache entry — exactly the
paper's cache rule, with staleness dominance enforced by Tier-2 timestamps.

The mask/flush bits are step INPUTS: on a real deployment Tier 2 derives them
from per-group deadlines (w-of-P + the 2% margin, paper §5.1); in tests they
are scripted.  Memory knobs for 100B+ models: int8 per-row-scaled cache
(``optim/compression.py``) and pod-granularity groups.
"""

from __future__ import annotations

import dataclasses
import functools
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import TrainConfig
from repro.optim.compression import Quantized, dequantize, quantize
from repro.optim.optimizers import (
    Optimizer,
    apply_updates,
    clip_by_global_norm,
    make_optimizer,
)


# ---------------------------------------------------------------------------
# Group geometry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    num_groups: int
    axes: tuple[str, ...]  # mesh axes the group dim is sharded over ((),) = repl.

    @property
    def group_partition(self):
        if not self.axes:
            return None
        return self.axes if len(self.axes) > 1 else self.axes[0]


def make_group_spec(tc: TrainConfig, mesh: Mesh | None) -> GroupSpec:
    if mesh is None:  # single-device tests: any P, replicated
        return GroupSpec(num_groups=1 if not tc.dsag else 4, axes=())
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if not tc.dsag or tc.dsag_groups == "none":
        return GroupSpec(1, ())
    if tc.dsag_groups == "pod" and "pod" in sizes:
        return GroupSpec(sizes["pod"], ("pod",))
    if tc.dsag_groups == "zero":
        # group dim unsharded, cache/pending param dims ZeRO-sharded over all
        # axes via param_specs; groups are time-sliced (see DESIGN.md §6)
        return GroupSpec(tc.dsag_num_groups, ())
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    n = 1
    for a in dp_axes:
        n *= sizes[a]
    return GroupSpec(n, dp_axes)


# ---------------------------------------------------------------------------
# DSAG state
# ---------------------------------------------------------------------------


def _cache_like(param_abstract, gs: GroupSpec, dtype: str):
    """Abstract cache slot tree: leading group dim on every leaf."""

    def leaf(a):
        shape = (gs.num_groups,) + a.shape
        if dtype == "int8":
            block = a.shape[-1] if a.shape else 1  # per-row scales (DESIGN §6)
            nblocks = max((shape[-1] + block - 1) // block, 1)
            return Quantized(
                q=jnp.zeros(shape, jnp.int8),
                scale=jnp.zeros(shape[:-1] + (nblocks,), jnp.bfloat16),
                block=block,
            )
        # float32 slots: the live paper-problem path (launch/paper_jobs.py)
        # validates its trajectory against the fp64/fp32 simulator engines,
        # where bf16 cache rounding would swamp the comparison tolerance
        return jnp.zeros(shape, jnp.float32 if dtype == "float32" else jnp.bfloat16)

    return jax.tree.map(leaf, param_abstract)


def init_dsag_state(params_like, gs: GroupSpec, tc: TrainConfig):
    zeros_like = lambda t: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), t)
    return {
        "cache": _cache_like(params_like, gs, tc.dsag_cache_dtype),
        "pending": _cache_like(params_like, gs, tc.dsag_cache_dtype),
        "pending_valid": jnp.zeros((gs.num_groups,), jnp.bool_),
        "filled": jnp.zeros((gs.num_groups,), jnp.bool_),
        "h": zeros_like(params_like),
    }


def _store(x: jnp.ndarray, like) -> Any:
    """Encode a [P, ...] fp32 tensor into the cache representation."""
    if isinstance(like, Quantized):
        return quantize(x, block=like.block)
    return x.astype(like.dtype)


def _load(c) -> jnp.ndarray:
    if isinstance(c, Quantized):
        return dequantize(c, jnp.float32)
    return c.astype(jnp.float32)


def _is_slot(x) -> bool:
    return isinstance(x, (Quantized, jnp.ndarray)) or hasattr(x, "shape")


def _bmask(m, x):
    """Broadcast a [P] mask against [P, ...]."""
    return m.reshape((-1,) + (1,) * (x.ndim - 1))


# ---------------------------------------------------------------------------
# The DSAG update (pure function over pytrees)
# ---------------------------------------------------------------------------


def dsag_update(dsag, group_grads, mask, flush, evict=None):
    """Apply the DSAG cache rule.

    group_grads: tree of [P, ...] per-group gradients (fp32)
    mask, flush: [P] bool step inputs from Tier 2.
    evict:       [P] bool — failed groups whose cache entry must leave H
                 (the paper's §6.3 cache eviction; ξ shrinks, DSAG proceeds).
    Returns (new_dsag, h_hat, xi)."""
    p = mask.shape[0]
    if evict is None:
        evict = jnp.zeros_like(mask)
    mask = jnp.logical_and(mask, ~evict)
    mask_f = mask.astype(jnp.float32)
    # a flush is only meaningful if the slot was pending and not fresh now
    eff_flush = jnp.logical_and(flush, jnp.logical_and(~mask, dsag["pending_valid"]))
    flush_f = eff_flush.astype(jnp.float32)

    is_leaf = lambda x: isinstance(x, Quantized)

    def leaf_update(g, c, pend):
        c_f = _load(c)
        p_f = _load(pend)
        mf = _bmask(mask_f, g)
        ff = _bmask(flush_f, g)
        new_val = mf * g.astype(jnp.float32) + ff * p_f + (1.0 - mf - ff) * c_f
        new_val = new_val * (1.0 - _bmask(evict.astype(jnp.float32), g))
        # the delta entering H uses the *stored* (rounded/quantized) value so
        # the SAG invariant H == Σ_i cache_i holds exactly under compression
        stored = _store(new_val, c)
        new_val = _load(stored)
        delta_sum = ((new_val - c_f)).sum(axis=0)  # Σ_i applied deltas
        # pending: keep oldest in-flight unless fresh/flushed this step
        take_new = jnp.logical_or(
            jnp.logical_or(mask, eff_flush), ~dsag["pending_valid"]
        ).astype(jnp.float32)
        tf = _bmask(take_new, g)
        new_pend = tf * g.astype(jnp.float32) + (1.0 - tf) * p_f
        return stored, _store(new_pend, pend), delta_sum

    flat_g, tdef = jax.tree.flatten(group_grads)
    flat_c = tdef.flatten_up_to(dsag["cache"])
    flat_p = tdef.flatten_up_to(dsag["pending"])
    outs = [leaf_update(g, c, pe) for g, c, pe in zip(flat_g, flat_c, flat_p)]
    new_cache = tdef.unflatten([o[0] for o in outs])
    new_pending = tdef.unflatten([o[1] for o in outs])
    deltas = tdef.unflatten([o[2] for o in outs])

    new_h = jax.tree.map(lambda h, d: h + d.astype(jnp.float32), dsag["h"], deltas)
    arrived = jnp.logical_or(mask, eff_flush)
    new_filled = jnp.logical_and(
        jnp.logical_or(dsag["filled"], arrived), ~evict
    )
    new_pending_valid = jnp.where(
        arrived, True, jnp.logical_or(dsag["pending_valid"], ~mask)
    )
    # after a fresh arrival nothing is in flight; after flush the current
    # step's (masked-out) gradient is in flight again
    new_pending_valid = jnp.where(mask, False, new_pending_valid)
    # an evicted (failed) group's in-flight gradient died with it: a flush
    # after the group rejoins must not reinsert pre-failure state into H
    new_pending_valid = jnp.logical_and(new_pending_valid, ~evict)

    xi = jnp.clip(new_filled.astype(jnp.float32).mean(), 1e-6, 1.0)
    h_hat = jax.tree.map(lambda h: h / (xi * p), new_h)
    new_dsag = {
        "cache": new_cache,
        "pending": new_pending,
        "pending_valid": new_pending_valid,
        "filled": new_filled,
        "h": new_h,
    }
    return new_dsag, h_hat, xi


# ---------------------------------------------------------------------------
# Train step factory
# ---------------------------------------------------------------------------


def make_train_step(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    tc: TrainConfig,
    gs: GroupSpec,
    mesh: Mesh | None = None,
    param_specs: Any | None = None,
    project_fn: Callable[[Any], Any] | None = None,
):
    """Build ``step(state, batch, mask, flush) -> (state, metrics)``.

    ``loss_fn(params, batch)`` is the per-group mean loss; ``batch`` arrives
    with a leading group dim [P, ...] on every leaf.  ``project_fn``, when
    given, re-projects the updated parameters onto the feasible set after
    the optimizer step (the paper's PCA orthonormalization — projected
    subgradient descent, problems.py ``project``)."""
    opt = make_optimizer(tc)

    def constrain_grads(grads):
        """Per-group grads live on their group's devices, ZeRO-sharded over
        the remaining axes (reduce-scatter happens inside the backward)."""
        if mesh is None or param_specs is None:
            return grads
        gaxes = gs.group_partition

        def leaf(g, spec):
            from repro.models.sharding import strip_axis

            tail = spec
            for a in gs.axes:  # group axes cannot repeat in the param dims
                tail = strip_axis(tail, a)
            return jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, P(gaxes, *tuple(tail)))
            )

        return jax.tree.map(
            leaf, grads, param_specs, is_leaf=lambda x: hasattr(x, "shape")
        )

    def step(state, batch, mask, flush, evict=None):
        params = state["params"]
        if mesh is not None and param_specs is not None:
            from repro.models.sharding import degather

            params = degather(
                params, param_specs, mesh, quantized=tc.quantized_fsdp_allgather
            )

        def group_loss(p, b):
            return loss_fn(p, b)

        losses, grads = jax.vmap(
            jax.value_and_grad(group_loss), in_axes=(None, 0), out_axes=0
        )(params, batch)
        # keep grads in bf16 through the cross-group delta collective (halves
        # wire bytes); dsag_update / the mean accumulate in fp32 internally
        grads = constrain_grads(grads)

        if tc.dsag:
            new_dsag, h_hat, xi = dsag_update(
                state["dsag"], grads, mask, flush, evict
            )
        else:
            new_dsag = state["dsag"]
            xi = jnp.float32(1.0)
            h_hat = jax.tree.map(
                lambda g: g.astype(jnp.float32).mean(axis=0), grads
            )

        if tc.grad_clip > 0:
            h_hat, gnorm = clip_by_global_norm(h_hat, tc.grad_clip)
        else:
            from repro.optim.optimizers import global_norm

            gnorm = global_norm(h_hat)

        updates, new_opt = opt.update(h_hat, state["opt"], params)
        new_params = apply_updates(params, updates)
        if project_fn is not None:
            new_params = project_fn(new_params)
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "dsag": new_dsag,
            "step": state["step"] + 1,
        }
        metrics = {
            "loss": losses.mean(),
            "per_group_loss": losses,
            "grad_norm": gnorm,
            "xi": xi,
            "mask_count": mask.sum(),
        }
        return new_state, metrics

    return step


def init_train_state(params, tc: TrainConfig, gs: GroupSpec):
    opt = make_optimizer(tc)
    return {
        "params": params,
        "opt": opt.init(params),
        "dsag": init_dsag_state(params, gs, tc),
        "step": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Sharding specs for the full train state
# ---------------------------------------------------------------------------


def _spec_drop_last(spec: P) -> P:
    return P(*tuple(spec)[:-1]) if len(tuple(spec)) else P()


def opt_state_specs(tc: TrainConfig, param_specs) -> Any:
    if tc.optimizer == "adamw":
        return {
            "m": param_specs,
            "v": param_specs,
            "step": P(),
        }
    if tc.optimizer == "sgd":
        return {"mu": param_specs, "step": P()}
    if tc.optimizer == "adafactor":

        def leaf(spec):
            t = tuple(spec)
            if len(t) >= 2:
                return {"vr": P(*t[:-1]), "vc": P(*(t[:-2] + t[-1:]))}
            return {"v": spec}

        return {
            "stats": jax.tree.map(leaf, param_specs, is_leaf=lambda s: isinstance(s, P)),
            "step": P(),
        }
    raise ValueError(tc.optimizer)


def dsag_state_specs(tc: TrainConfig, gs: GroupSpec, param_specs) -> Any:
    from repro.models.sharding import strip_axis

    gaxes = gs.group_partition

    def slot(spec):
        for a in gs.axes:  # group axes cannot repeat in the param dims
            spec = strip_axis(spec, a)
        t = tuple(spec)
        if tc.dsag_cache_dtype == "int8":
            scale_spec = P(gaxes, *t[:-1], None) if t else P(gaxes, None)
            return Quantized(q=P(gaxes, *t), scale=scale_spec, block=0)
        return P(gaxes, *t)

    cache = jax.tree.map(slot, param_specs, is_leaf=lambda s: isinstance(s, P))
    return {
        "cache": cache,
        "pending": cache,
        "pending_valid": P(),
        "filled": P(),
        "h": param_specs,
    }


def train_state_specs(tc: TrainConfig, gs: GroupSpec, param_specs) -> Any:
    return {
        "params": param_specs,
        "opt": opt_state_specs(tc, param_specs),
        "dsag": dsag_state_specs(tc, gs, param_specs),
        "step": P(),
    }


def batch_group_specs(gs: GroupSpec, inner_spec_tail=(None,)) -> P:
    """Spec of a batch leaf [P, b/P, ...]: group dim over the group axes,
    inner batch dim over remaining dp axes (none left when groups = dp)."""
    return P(gs.group_partition, *inner_spec_tail)
