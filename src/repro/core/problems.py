"""Finite-sum problems from the paper's experiments (§2, §7).

* :class:`PCAProblem` — PCA cast as empirical-risk minimization (paper Eq. 9):
      R(V) = 1/2 ||V||_F^2,   f_i(V) = 1/2 ||x_i - x_i V V^T||^2,
  with G = Gram-Schmidt orthonormalization.  The block subgradient only needs
  the Gram product  A_b V = X_b^T (X_b V)  — the paper's Eq. (3) hot spot,
  served by ``kernels/gram_matvec`` on TPU and jnp on CPU:
      ∇_V Σ_{i∈b} f_i = -2 A_b V + A_b V (V^T V) + V (V^T A_b V).
* :class:`LogisticRegressionProblem` — L2-regularized logistic regression on
  HIGGS-like data:  f_i(V) = log(1 + exp(-b_i x_i^T V)) / n,
  R(V) = (λ/2)||V||^2, G = identity, λ = 1/n (paper §7).

Metrics follow the paper: explained-variance suboptimality for PCA and
classification-error/objective suboptimality for logreg, both against a
directly computed optimum.

Every float expression that feeds the convergence engines lives in exactly
one place: a per-problem set of JAX kernels (:class:`FusedKernels`) that the
scalar :class:`~repro.cluster.simulator.TrainingSimulator`, the batched host
engine (:mod:`repro.experiments.convergence`), and the fused
``jax.lax.scan`` engine (:mod:`repro.experiments.fused`) all share.  The
numpy-facing methods are thin wrappers; bit-exact equivalence of the three
paths rests on this delegation plus two structural properties: batch-size
invariance of the kernels (empirically pinned on CPU by
``tests/test_fused.py``) and the static :func:`width_bucket` ladder —
every interval width maps to one fixed gather shape, so a given (iterate,
interval) is evaluated at identical static shapes by every engine.  The
ladder is what carries bit-reproducibility: XLA's reduction lane grouping
*changes with the padded length*, so masking alone (zero rows contribute
0.0 mathematically, not positionally) would not keep the bits stable
across different pad widths.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.kernels import block_sub


class FiniteSumProblem:
    """Interface shared by the coordinator/cluster simulator.

    The ``*_blocks`` / ``*_batch`` methods are the batched counterparts used
    by the vectorized convergence engines
    (:mod:`repro.experiments.convergence`, :mod:`repro.experiments.fused`):
    they evaluate G tasks (one iterate + one sample interval each) in a
    single JAX dispatch.  Each row of the result must be *bit-identical* to
    the corresponding scalar call — the batched engines' equivalence
    guarantee against the scalar
    :class:`~repro.cluster.simulator.TrainingSimulator` rests on it, so the
    scalar methods delegate to the batched kernels at batch size 1.
    """

    num_samples: int

    def init(self, seed: int = 0) -> np.ndarray:
        raise NotImplementedError

    def fused_kernels(self) -> "FusedKernels":
        """The problem's traceable JAX kernels (shared by every engine)."""
        raise NotImplementedError

    def subgradient(self, V: np.ndarray, start: int, stop: int) -> np.ndarray:
        """Sum of ∇f_k(V) for k in [start, stop] (1-based inclusive)."""
        return self.subgradient_blocks(
            np.asarray(V)[None],
            np.array([start], dtype=np.int64),
            np.array([stop], dtype=np.int64),
        )[0]

    def subgradient_blocks(
        self, V_stack: np.ndarray, starts: np.ndarray, stops: np.ndarray
    ) -> np.ndarray:
        """[G, ...] block subgradients for G (iterate, interval) tasks.

        All intervals must have the same width; row g must equal
        ``subgradient(V_stack[g], starts[g], stops[g])`` bit-for-bit.
        """
        starts = np.asarray(starts, dtype=np.int64)
        stops = np.asarray(stops, dtype=np.int64)
        widths = stops - starts + 1
        if widths.size == 0:
            k = self.fused_kernels()
            return np.zeros((0,) + k.value_shape, dtype=k.value_dtype)
        m = int(widths[0])
        if not np.all(widths == m):
            raise ValueError("subgradient_blocks requires equal-width intervals")
        return self._call_sub_kernel(
            V_stack, starts, widths, width_bucket(m, self.num_samples)
        )

    def subgradient_blocks_masked(
        self, V_stack: np.ndarray, starts: np.ndarray, stops: np.ndarray
    ) -> np.ndarray:
        """Like :meth:`subgradient_blocks` but for *mixed-width* intervals.

        Rows are grouped by their :func:`width_bucket` (at most a couple of
        buckets in practice — the §6.3 partition arithmetic only produces
        floor/ceil widths plus the full range) and each bucket is one
        dispatch.  Because the bucket of a width is a pure function of the
        width, every caller — the scalar simulator at G = 1, this wrapper,
        and the fused scan — evaluates a given (iterate, interval) at the
        exact same static shapes, which is what makes the results
        bit-identical across engines (pinned by ``tests/test_fused.py``).
        """
        starts = np.asarray(starts, dtype=np.int64)
        stops = np.asarray(stops, dtype=np.int64)
        widths = stops - starts + 1
        if widths.size == 0:
            k = self.fused_kernels()
            return np.zeros((0,) + k.value_shape, dtype=k.value_dtype)
        buckets = np.array([width_bucket(int(m), self.num_samples) for m in widths])
        out: np.ndarray | None = None
        for b in np.unique(buckets):
            sel = buckets == b
            block = self._call_sub_kernel(
                np.asarray(V_stack)[sel], starts[sel], widths[sel], int(b)
            )
            if out is None:
                out = np.empty((widths.size,) + block.shape[1:], dtype=block.dtype)
            out[sel] = block
        return out

    def _call_sub_kernel(self, V_stack, starts, widths, pad_width: int):
        k = self.fused_kernels()
        with enable_x64():
            out = k.sub_blocks_jit(
                jnp.asarray(V_stack),
                jnp.asarray(starts),
                jnp.asarray(widths),
                pad_width,
            )
            return np.asarray(out)

    def regularizer_grad(self, V: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def project(self, V: np.ndarray) -> np.ndarray:
        """The G(·) operator of paper Eq. (2)."""
        return V

    def project_batch(self, V_stack: np.ndarray) -> np.ndarray:
        """Apply G(·) to a stack of iterates; identity by default."""
        return V_stack

    def suboptimality(self, V: np.ndarray) -> float:
        return float(self.suboptimality_batch(np.asarray(V)[None])[0])

    def suboptimality_batch(self, V_stack: np.ndarray) -> np.ndarray:
        """[S] suboptimality gaps in one JAX dispatch.

        Row s must equal ``suboptimality(V_stack[s])`` bit-for-bit: the
        kernel maps the single-iterate evaluation over the batch with
        ``lax.map`` (a batched ``dot_general`` would reassociate the
        reductions and break batch invariance on CPU).
        """
        k = self.fused_kernels()
        with enable_x64():
            return np.asarray(k.suboptimality_jit(jnp.asarray(V_stack)))

    #: ops per sample row (set by subclasses; the static cost constant must
    #: be readable without building the JAX kernels — e.g. logreg's kernels
    #: materialize the Newton optimum, which cost-only callers never need)
    cost_per_row: float

    def compute_cost(self, start: int, stop: int) -> float:
        """Computational load c of the block (paper §3: ops count)."""
        return float(self.cost_per_row * (stop - start + 1))

    def compute_cost_batch(self, starts: np.ndarray, stops: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`compute_cost` (same float expression per row)."""
        rows = np.asarray(stops, dtype=np.int64) - np.asarray(starts, np.int64) + 1
        return self.cost_per_row * rows


@dataclasses.dataclass
class FusedKernels:
    """One problem's traceable JAX kernels plus their jitted entry points.

    ``sub_blocks(V_stack, starts, widths, pad_width)`` evaluates G block
    subgradients at a static gather width (rows past each width masked to
    zero); ``suboptimality`` / ``project`` / ``regularizer_grad`` operate on
    ``[S, ...]`` iterate stacks.  ``value_dtype`` is the dtype
    ``sub_blocks`` returns (the fused engine sizes its in-flight value
    buffers with it).  The raw callables are traceable from inside an outer
    ``jax.jit`` / ``lax.scan`` (the fused engine); the ``*_jit`` fields are
    the standalone jitted versions the numpy wrappers use.  Instances hash
    by identity, so they can be passed as static arguments to jitted
    drivers.
    """

    num_samples: int
    value_shape: tuple[int, ...]
    value_dtype: np.dtype
    cost_per_row: float
    sub_blocks: Callable  # (Vb, starts, widths, pad_width) -> [G, ...]
    suboptimality: Callable  # [S, ...] -> [S]
    project: Callable  # [S, ...] -> [S, ...]
    regularizer_grad: Callable  # [S, ...] -> [S, ...]
    # Pallas twin of sub_blocks — (Vb, starts, widths, pad_width, interpret)
    # with both trailing args static; None when the problem has no Pallas
    # kernels (the engine's kernel-backend capability check reports it)
    sub_blocks_pallas: Callable | None = None

    def __post_init__(self):
        self.sub_blocks_jit = jax.jit(self.sub_blocks, static_argnums=3)
        self.suboptimality_jit = jax.jit(self.suboptimality)
        self.project_jit = jax.jit(self.project)

    def __hash__(self):  # identity hash: usable as a jit static argument
        return id(self)

    def __eq__(self, other):
        return self is other


def width_bucket(m: int, num_samples: int) -> int:
    """Static gather width used to evaluate an interval of width ``m``.

    The next power of two, except the full range keeps its exact width (no
    point doubling the gather for the gd/coded full-dataset blocks).  The
    kernels' reductions are *not* invariant to the padded length (XLA's
    lane grouping changes with the shape), so bit-reproducibility across
    engines comes from this ladder being a pure function of the width:
    every caller evaluates a given width at the same static shape.
    """
    if m == num_samples:
        return m
    return 1 << (m - 1).bit_length()


def _pad_pow2(Vb, starts, widths):
    """Pad a task batch to the next power-of-two size (repeat the last row).

    The batched subgradient kernels are batch-invariant (each row's result
    is independent of what else shares the batch), so padding does not
    change any real row's bits — but it bounds the number of distinct batch
    shapes XLA ever sees to O(log G_max) per gather width, instead of one
    recompilation for every fleet configuration the event dynamics happen
    to produce.  Shapes are static at trace time, so this is usable from
    inside the fused scan as well.
    """
    g = Vb.shape[0]
    bucket = 1 << (g - 1).bit_length()
    if bucket == g:
        return Vb, starts, widths, g
    pad = bucket - g
    return (
        jnp.concatenate([Vb, jnp.repeat(Vb[-1:], pad, axis=0)]),
        jnp.concatenate([starts, jnp.repeat(starts[-1:], pad)]),
        jnp.concatenate([widths, jnp.repeat(widths[-1:], pad)]),
        g,
    )


# ---------------------------------------------------------------------------
# PCA (power-method family) on a genomics-like sparse binary matrix
# ---------------------------------------------------------------------------


def make_genomics_like_matrix(
    n: int, d: int, *, density: float = 0.0536, seed: int = 0
) -> np.ndarray:
    """Synthetic stand-in for the 1000-Genomes binary matrix (§2): sparse
    binary with ~5.36% density and a planted low-rank structure so the top
    principal components are well separated (row-permuted, like the paper)."""
    rng = np.random.default_rng(seed)
    # planted structure: rows belong to "populations" of decreasing size with
    # distinct variant patterns, giving a well-separated top spectrum (the
    # real 1000-Genomes matrix likewise has dominant population components)
    k0 = 6
    # geometric population sizes and disjoint dense column blocks give a
    # well-separated eigenvalue ladder (ratio ~0.5 between consecutive
    # principal values), so power-method-family convergence is observable
    sizes = 0.5 ** np.arange(k0)
    sizes = sizes / sizes.sum()
    assign = np.clip(np.searchsorted(np.cumsum(sizes), rng.random(n)), 0, k0 - 1)
    cols = np.arange(d)
    block = np.minimum(cols * k0 // d, k0 - 1)  # column -> population block
    dense_mask = block[None, :] == assign[:, None]
    # calibrate hi/lo to hit the target overall density
    frac_dense = float(dense_mask.mean())
    hi = min(0.7 * density / max(frac_dense, 1e-6), 0.95)
    lo = max((density - hi * frac_dense) / max(1 - frac_dense, 1e-6), density * 0.05)
    probs = np.where(dense_mask, hi, lo)
    x = (rng.random((n, d)) < probs).astype(np.float32)
    perm = rng.permutation(n)
    return x[perm]


@dataclasses.dataclass
class PCAProblem(FiniteSumProblem):
    X: np.ndarray  # [n, d]
    k: int = 3

    def __post_init__(self):
        self.num_samples = int(self.X.shape[0])
        self.dim = int(self.X.shape[1])
        self.cost_per_row = 2.0 * self.dim * self.k
        with enable_x64():
            self._Xj = jnp.asarray(self.X)
            self._X64 = jnp.asarray(self.X, dtype=jnp.float64)
        # reference optimum: exact top-k eigendecomposition of X^T X
        gram = np.asarray(self.X, dtype=np.float64).T @ np.asarray(self.X, np.float64)
        evals = np.linalg.eigvalsh(gram)
        self._opt_explained = float(np.sum(np.sort(evals)[::-1][: self.k]))
        self._total_var = float(np.trace(gram))
        self._kernels: FusedKernels | None = None

    def init(self, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        v = rng.normal(size=(self.dim, self.k)).astype(np.float32)
        q, _ = np.linalg.qr(v)
        return q

    def fused_kernels(self) -> FusedKernels:
        if self._kernels is not None:
            return self._kernels
        Xj, X64 = self._Xj, self._X64
        n = self.num_samples
        opt, total = self._opt_explained, self._total_var

        def sub_blocks(Vb, starts, widths, pad_width: int):
            # -X_b^T (X_b V) with a leading batch axis.  On the Stiefel
            # manifold enforced by G (V^T V = I),
            #   f_i(V) = 1/2||x_i - x_i V V^T||^2 = 1/2||x_i||^2 - 1/2||x_i V||^2,
            # so the block subgradient is -X_b^T (X_b V) — exactly the worker
            # computation of paper Eq. (3).  With eta = 1 the GD update
            # V - (V - A V) = A V followed by Gram-Schmidt *is* the power
            # method, as stated in §7.  Rows past each interval's width are
            # zero-masked (they contribute 0.0 to both matmuls); bit
            # reproducibility across engines comes from every caller using
            # the same static width_bucket pad per width, NOT from pad-width
            # invariance — see width_bucket.
            Vb, starts, widths, g = _pad_pow2(Vb, starts, widths)
            idx = jnp.clip(starts[:, None] - 1 + jnp.arange(pad_width)[None, :], 0, n - 1)
            xg = Xj[idx]  # [G, pad, d]
            mask = (jnp.arange(pad_width)[None, :] < widths[:, None]).astype(Xj.dtype)
            xg = xg * mask[:, :, None]
            return (-(jnp.swapaxes(xg, 1, 2) @ (xg @ Vb)))[:g]

        def sub_blocks_pallas(Vb, starts, widths, pad_width: int, interpret: bool):
            # same _pad_pow2 batching as the XLA form, then one Pallas
            # program per task evaluating the identical expression (see
            # kernels/block_sub.py for the bit-exactness contract)
            Vb, starts, widths, g = _pad_pow2(Vb, starts, widths)
            return block_sub.pca_block_sub(
                Xj, Vb, starts, widths, pad_width, interpret=interpret
            )[:g]

        def explained_one(V):
            xv = X64 @ V.astype(jnp.float64)
            return jnp.sum(xv * xv)

        def suboptimality(V_stack):
            # (optimal explained variance - achieved) / total variance — the
            # paper's 'suboptimality gap' for PCA, nonnegative up to roundoff
            def one(V):
                return jnp.maximum((opt - explained_one(V)) / total, 1e-16)

            return jax.lax.map(one, V_stack)

        def project(V_stack):
            # Gram-Schmidt == thin-QR orthonormalization (sign-fixed); on CPU
            # jnp.linalg.qr loops LAPACK per matrix, so rows are
            # batch-invariant (pinned by tests)
            q, r = jnp.linalg.qr(V_stack)
            diag = jnp.diagonal(r, axis1=-2, axis2=-1)
            return q * jnp.sign(diag)[..., None, :]

        self._kernels = FusedKernels(
            num_samples=n,
            value_shape=(self.dim, self.k),
            value_dtype=np.result_type(self.X.dtype, np.float32),
            cost_per_row=self.cost_per_row,
            sub_blocks=sub_blocks,
            suboptimality=suboptimality,
            project=project,
            regularizer_grad=lambda V_stack: V_stack,  # ∇ 1/2||V||_F^2
            sub_blocks_pallas=sub_blocks_pallas,
        )
        self._explained_jit = jax.jit(lambda Vs: jax.lax.map(explained_one, Vs))
        return self._kernels

    def regularizer_grad(self, V: np.ndarray) -> np.ndarray:
        return V  # ∇ 1/2||V||_F^2

    def project(self, V: np.ndarray) -> np.ndarray:
        return self.project_batch(np.asarray(V)[None])[0]

    def project_batch(self, V_stack: np.ndarray) -> np.ndarray:
        # delegates to the shared QR kernel: the scalar simulator, the host
        # batched engine, and the fused scan all orthonormalize with the
        # exact same bits
        k = self.fused_kernels()
        with enable_x64():
            return np.asarray(k.project_jit(jnp.asarray(V_stack)))

    def explained_variance(self, V: np.ndarray) -> float:
        self.fused_kernels()
        with enable_x64():
            return float(self._explained_jit(jnp.asarray(V)[None])[0])

    # compute_cost doc: c = 2 ζ d k rows with ζ the density (paper §3); for
    # our dense representation ζ=1 gives ops of the dense Gram product —
    # encoded as FusedKernels.cost_per_row = 2 d k.


# ---------------------------------------------------------------------------
# Logistic regression on HIGGS-like data
# ---------------------------------------------------------------------------


def make_higgs_like(
    n: int, d: int = 28, *, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Synthetic binary-classification data shaped like HIGGS (28 features,
    labels ±1), feature-normalized with an intercept appended (paper §7)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w_true = rng.normal(size=(d,)).astype(np.float32)
    logits = x @ w_true + 0.5 * rng.normal(size=(n,)).astype(np.float32)
    y = np.where(rng.random(n) < 1.0 / (1.0 + np.exp(-logits)), 1.0, -1.0).astype(
        np.float32
    )
    # normalize to zero mean / unit variance, add intercept = 1
    x = (x - x.mean(axis=0)) / (x.std(axis=0) + 1e-8)
    x = np.concatenate([x, np.ones((n, 1), np.float32)], axis=1)
    return x, y


@dataclasses.dataclass
class LogisticRegressionProblem(FiniteSumProblem):
    X: np.ndarray  # [n, d] (already includes intercept column)
    y: np.ndarray  # [n] in {-1, +1}
    lam: float | None = None  # default 1/n, as in the paper

    def __post_init__(self):
        self.num_samples = int(self.X.shape[0])
        self.dim = int(self.X.shape[1])
        self.cost_per_row = 2.0 * self.dim
        if self.lam is None:
            self.lam = 1.0 / self.num_samples
        with enable_x64():
            self._Xj = jnp.asarray(self.X)
            self._yj = jnp.asarray(self.y)
            self._X64 = jnp.asarray(self.X, dtype=jnp.float64)
            self._y64 = jnp.asarray(self.y, dtype=jnp.float64)
        self._opt = None  # lazy: computed by Newton iterations on first use
        self._kernels: FusedKernels | None = None

    def init(self, seed: int = 0) -> np.ndarray:
        return np.zeros((self.dim,), dtype=np.float32)

    def fused_kernels(self) -> FusedKernels:
        if self._kernels is not None:
            return self._kernels
        Xj, yj = self._Xj, self._yj
        X64, y64 = self._X64, self._y64
        n, lam = self.num_samples, self.lam

        def sub_blocks(Vb, starts, widths, pad_width: int):
            # Uses explicit elementwise-multiply + axis reductions rather
            # than matmuls: XLA lowers a [m, d] @ [d] mat-vec and a
            # [G, m, d] batched product to different kernels with different
            # accumulation orders, so matmul results would depend on the
            # batch size.  The reduce-based form is batch-invariant (pinned
            # by tests); labels are zero-masked past each interval's width,
            # and every caller evaluates a given width at the same static
            # width_bucket pad — the reduction is NOT invariant to the pad
            # length itself (see width_bucket).
            Vb, starts, widths, g = _pad_pow2(Vb, starts, widths)
            idx = jnp.clip(starts[:, None] - 1 + jnp.arange(pad_width)[None, :], 0, n - 1)
            xg = Xj[idx]  # [G, pad, d]
            yg = yj[idx] * (jnp.arange(pad_width)[None, :] < widths[:, None]).astype(
                yj.dtype
            )
            z = yg * jnp.sum(xg * Vb[:, None, :], axis=2)
            s = jax.nn.sigmoid(-z)
            return (-jnp.sum(xg * (yg * s)[:, :, None], axis=1) / n)[:g]

        def sub_blocks_pallas(Vb, starts, widths, pad_width: int, interpret: bool):
            Vb, starts, widths, g = _pad_pow2(Vb, starts, widths)
            return block_sub.logreg_block_sub(
                Xj, yj, Vb, starts, widths, pad_width, interpret=interpret
            )[:g]

        def objective_one(V):
            V64 = V.astype(jnp.float64)
            z = y64 * (X64 @ V64)
            # log1p(exp(-z)) stable
            return jnp.mean(jnp.logaddexp(0.0, -z)) + 0.5 * lam * jnp.sum(V64 * V64)

        def objective(V_stack):
            return jax.lax.map(objective_one, V_stack)

        self._objective_jit = jax.jit(objective)
        # materialize the Newton optimum now: the suboptimality kernel must
        # close over a concrete float (it may first be traced from inside
        # the fused scan, where resolving the lazy property would nest a
        # jit call into the trace)
        opt_obj = self.optimum_objective

        def suboptimality(V_stack):
            return jnp.maximum(objective(V_stack) - opt_obj, 1e-16)

        self._kernels = FusedKernels(
            num_samples=n,
            value_shape=(self.dim,),
            value_dtype=np.result_type(self.X.dtype, np.float32),
            cost_per_row=self.cost_per_row,
            sub_blocks=sub_blocks,
            suboptimality=suboptimality,
            project=lambda V_stack: V_stack,  # G = identity
            regularizer_grad=lambda V_stack: lam * V_stack,
            sub_blocks_pallas=sub_blocks_pallas,
        )
        return self._kernels

    def objective(self, V: np.ndarray) -> float:
        return float(self.objective_batch(np.asarray(V)[None])[0])

    def objective_batch(self, V_stack: np.ndarray) -> np.ndarray:
        """[S] objectives through the shared JAX kernel (one dispatch)."""
        if not hasattr(self, "_objective_jit"):  # set mid-build by fused_kernels
            self.fused_kernels()
        with enable_x64():
            return np.asarray(self._objective_jit(jnp.asarray(V_stack)))

    def _solve_optimum(self) -> np.ndarray:
        """Newton's method — logreg is strongly convex with λ>0."""
        v = np.zeros(self.dim, dtype=np.float64)
        x = self.X.astype(np.float64)
        y = self.y.astype(np.float64)
        n = self.num_samples
        for _ in range(50):
            z = y * (x @ v)
            s = 1.0 / (1.0 + np.exp(z))  # σ(-z)
            grad = -(x.T @ (y * s)) / n + self.lam * v
            w = s * (1.0 - s)
            hess = (x.T * w) @ x / n + self.lam * np.eye(self.dim)
            step = np.linalg.solve(hess, grad)
            v = v - step
            if np.linalg.norm(step) < 1e-12:
                break
        return v

    @property
    def optimum_objective(self) -> float:
        if self._opt is None:
            self._opt = self._solve_optimum()
            self._opt_obj = self.objective(self._opt)
        return self._opt_obj

    def regularizer_grad(self, V: np.ndarray) -> np.ndarray:
        return self.lam * V
