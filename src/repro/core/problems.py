"""Finite-sum problems from the paper's experiments (§2, §7).

* :class:`PCAProblem` — PCA cast as empirical-risk minimization (paper Eq. 9):
      R(V) = 1/2 ||V||_F^2,   f_i(V) = 1/2 ||x_i - x_i V V^T||^2,
  with G = Gram-Schmidt orthonormalization.  The block subgradient only needs
  the Gram product  A_b V = X_b^T (X_b V)  — the paper's Eq. (3) hot spot,
  served by ``kernels/gram_matvec`` on TPU and jnp on CPU:
      ∇_V Σ_{i∈b} f_i = -2 A_b V + A_b V (V^T V) + V (V^T A_b V).
* :class:`LogisticRegressionProblem` — L2-regularized logistic regression on
  HIGGS-like data:  f_i(V) = log(1 + exp(-b_i x_i^T V)) / n,
  R(V) = (λ/2)||V||^2, G = identity, λ = 1/n (paper §7).

Metrics follow the paper: explained-variance suboptimality for PCA and
classification-error/objective suboptimality for logreg, both against a
directly computed optimum.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class FiniteSumProblem:
    """Interface shared by the coordinator/cluster simulator.

    The ``*_blocks`` / ``*_batch`` methods are the batched counterparts used
    by the vectorized convergence engine
    (:mod:`repro.experiments.convergence`): they evaluate G tasks (one
    iterate + one sample interval each) in a single JAX dispatch.  Each row
    of the result must be *bit-identical* to the corresponding scalar call —
    the batched engine's equivalence guarantee against the scalar
    :class:`~repro.cluster.simulator.TrainingSimulator` rests on it, so the
    implementations keep the exact operation order of the scalar path and
    only add a leading batch dimension to the matmuls.
    """

    num_samples: int

    def init(self, seed: int = 0) -> np.ndarray:
        raise NotImplementedError

    def subgradient(self, V: np.ndarray, start: int, stop: int) -> np.ndarray:
        """Sum of ∇f_k(V) for k in [start, stop] (1-based inclusive)."""
        raise NotImplementedError

    def subgradient_blocks(
        self, V_stack: np.ndarray, starts: np.ndarray, stops: np.ndarray
    ) -> np.ndarray:
        """[G, ...] block subgradients for G (iterate, interval) tasks.

        All intervals must have the same width; row g must equal
        ``subgradient(V_stack[g], starts[g], stops[g])`` bit-for-bit.
        """
        raise NotImplementedError

    def regularizer_grad(self, V: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def project(self, V: np.ndarray) -> np.ndarray:
        """The G(·) operator of paper Eq. (2)."""
        return V

    def project_batch(self, V_stack: np.ndarray) -> np.ndarray:
        """Apply G(·) to a stack of iterates; identity by default."""
        return V_stack

    def suboptimality(self, V: np.ndarray) -> float:
        raise NotImplementedError

    def compute_cost(self, start: int, stop: int) -> float:
        """Computational load c of the block (paper §3: ops count)."""
        raise NotImplementedError

    def compute_cost_batch(self, starts: np.ndarray, stops: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`compute_cost` (same float expression per row)."""
        raise NotImplementedError


def _bucket_pad(V_stack: np.ndarray, starts: np.ndarray, stops: np.ndarray):
    """Pad a task batch to the next power-of-two size (repeat the last row).

    The batched subgradient kernels are batch-invariant (each row's result
    is independent of what else shares the batch), so padding does not
    change any real row's bits — but it bounds the number of distinct batch
    shapes XLA ever sees to O(log G_max) per block width, instead of one
    recompilation for every fleet configuration the event dynamics happen
    to produce.
    """
    g = V_stack.shape[0]
    bucket = 1 << (g - 1).bit_length()
    if bucket == g:
        return V_stack, starts, stops, g
    pad = bucket - g
    return (
        np.concatenate([V_stack, np.repeat(V_stack[-1:], pad, axis=0)]),
        np.concatenate([starts, np.repeat(starts[-1:], pad)]),
        np.concatenate([stops, np.repeat(stops[-1:], pad)]),
        g,
    )


# ---------------------------------------------------------------------------
# PCA (power-method family) on a genomics-like sparse binary matrix
# ---------------------------------------------------------------------------


def make_genomics_like_matrix(
    n: int, d: int, *, density: float = 0.0536, seed: int = 0
) -> np.ndarray:
    """Synthetic stand-in for the 1000-Genomes binary matrix (§2): sparse
    binary with ~5.36% density and a planted low-rank structure so the top
    principal components are well separated (row-permuted, like the paper)."""
    rng = np.random.default_rng(seed)
    # planted structure: rows belong to "populations" of decreasing size with
    # distinct variant patterns, giving a well-separated top spectrum (the
    # real 1000-Genomes matrix likewise has dominant population components)
    k0 = 6
    # geometric population sizes and disjoint dense column blocks give a
    # well-separated eigenvalue ladder (ratio ~0.5 between consecutive
    # principal values), so power-method-family convergence is observable
    sizes = 0.5 ** np.arange(k0)
    sizes = sizes / sizes.sum()
    assign = np.clip(np.searchsorted(np.cumsum(sizes), rng.random(n)), 0, k0 - 1)
    cols = np.arange(d)
    block = np.minimum(cols * k0 // d, k0 - 1)  # column -> population block
    dense_mask = block[None, :] == assign[:, None]
    # calibrate hi/lo to hit the target overall density
    frac_dense = float(dense_mask.mean())
    hi = min(0.7 * density / max(frac_dense, 1e-6), 0.95)
    lo = max((density - hi * frac_dense) / max(1 - frac_dense, 1e-6), density * 0.05)
    probs = np.where(dense_mask, hi, lo)
    x = (rng.random((n, d)) < probs).astype(np.float32)
    perm = rng.permutation(n)
    return x[perm]


@dataclasses.dataclass
class PCAProblem(FiniteSumProblem):
    X: np.ndarray  # [n, d]
    k: int = 3

    def __post_init__(self):
        self.num_samples = int(self.X.shape[0])
        self.dim = int(self.X.shape[1])
        self._Xj = jnp.asarray(self.X)
        # reference optimum: exact top-k eigendecomposition of X^T X
        gram = np.asarray(self.X, dtype=np.float64).T @ np.asarray(self.X, np.float64)
        evals = np.linalg.eigvalsh(gram)
        self._opt_explained = float(np.sum(np.sort(evals)[::-1][: self.k]))
        self._total_var = float(np.trace(gram))

    def init(self, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        v = rng.normal(size=(self.dim, self.k)).astype(np.float32)
        q, _ = np.linalg.qr(v)
        return q

    def subgradient(self, V: np.ndarray, start: int, stop: int) -> np.ndarray:
        # On the Stiefel manifold enforced by G (V^T V = I),
        #   f_i(V) = 1/2||x_i - x_i V V^T||^2 = 1/2||x_i||^2 - 1/2||x_i V||^2,
        # so the block subgradient is -X_b^T (X_b V) — exactly the worker
        # computation of paper Eq. (3).  With eta = 1 the GD update
        # V - (V - A V) = A V followed by Gram-Schmidt *is* the power method,
        # as stated in §7.  Routed through the G = 1 batched kernel so the
        # scalar simulator and the batched convergence engine share one code
        # path (bit-exact equivalence depends on it).
        return self.subgradient_blocks(
            np.asarray(V)[None],
            np.array([start], dtype=np.int64),
            np.array([stop], dtype=np.int64),
        )[0]

    def subgradient_blocks(
        self, V_stack: np.ndarray, starts: np.ndarray, stops: np.ndarray
    ) -> np.ndarray:
        # -X_b^T (X_b V) with a leading batch axis.  The batched matmul is
        # batch-invariant on CPU (row g is bit-identical whatever else is in
        # the batch — pinned by tests), which is what lets the scalar path
        # reuse this kernel at G = 1.
        starts = np.asarray(starts, dtype=np.int64)
        stops = np.asarray(stops, dtype=np.int64)
        widths = stops - starts + 1
        if widths.size == 0:
            return np.zeros((0,) + np.shape(V_stack)[1:], dtype=np.float32)
        m = int(widths[0])
        if not np.all(widths == m):
            raise ValueError("subgradient_blocks requires equal-width intervals")
        V_stack, starts, stops, g = _bucket_pad(np.asarray(V_stack), starts, stops)
        idx = starts[:, None] - 1 + np.arange(m)[None, :]
        xg = self._Xj[jnp.asarray(idx)]  # [G, m, d]
        Vb = jnp.asarray(V_stack)  # [G, d, k]
        return np.asarray(-(jnp.swapaxes(xg, 1, 2) @ (xg @ Vb)))[:g]

    def regularizer_grad(self, V: np.ndarray) -> np.ndarray:
        return V  # ∇ 1/2||V||_F^2

    def project(self, V: np.ndarray) -> np.ndarray:
        # Gram-Schmidt == thin-QR orthonormalization (sign-fixed)
        q, r = np.linalg.qr(V)
        return q * np.sign(np.diag(r))[None, :]

    def project_batch(self, V_stack: np.ndarray) -> np.ndarray:
        # np.linalg.qr gufunc-loops LAPACK per matrix, so each row matches
        # the scalar `project` bit-for-bit
        q, r = np.linalg.qr(V_stack)
        diag = r[..., np.arange(self.k), np.arange(self.k)]
        return q * np.sign(diag)[..., None, :]

    def explained_variance(self, V: np.ndarray) -> float:
        xv = self.X.astype(np.float64) @ V.astype(np.float64)
        return float(np.sum(xv * xv))

    def suboptimality(self, V: np.ndarray) -> float:
        """(optimal explained variance - achieved) / total variance — the
        paper's 'suboptimality gap' for PCA, nonnegative up to roundoff."""
        gap = (self._opt_explained - self.explained_variance(V)) / self._total_var
        return float(max(gap, 1e-16))

    def compute_cost(self, start: int, stop: int) -> float:
        # c = 2 ζ d k rows  with ζ the density (paper §3); for our dense
        # representation ζ=1 gives ops of the dense Gram product.
        rows = stop - start + 1
        return 2.0 * self.dim * self.k * rows

    def compute_cost_batch(self, starts: np.ndarray, stops: np.ndarray) -> np.ndarray:
        rows = np.asarray(stops, dtype=np.int64) - np.asarray(starts, np.int64) + 1
        return 2.0 * self.dim * self.k * rows


# ---------------------------------------------------------------------------
# Logistic regression on HIGGS-like data
# ---------------------------------------------------------------------------


def make_higgs_like(
    n: int, d: int = 28, *, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic binary-classification data shaped like HIGGS (28 features,
    labels ±1), feature-normalized with an intercept appended (paper §7)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w_true = rng.normal(size=(d,)).astype(np.float32)
    logits = x @ w_true + 0.5 * rng.normal(size=(n,)).astype(np.float32)
    y = np.where(rng.random(n) < 1.0 / (1.0 + np.exp(-logits)), 1.0, -1.0).astype(
        np.float32
    )
    # normalize to zero mean / unit variance, add intercept = 1
    x = (x - x.mean(axis=0)) / (x.std(axis=0) + 1e-8)
    x = np.concatenate([x, np.ones((n, 1), np.float32)], axis=1)
    return x, y


@dataclasses.dataclass
class LogisticRegressionProblem(FiniteSumProblem):
    X: np.ndarray  # [n, d] (already includes intercept column)
    y: np.ndarray  # [n] in {-1, +1}
    lam: Optional[float] = None  # default 1/n, as in the paper

    def __post_init__(self):
        self.num_samples = int(self.X.shape[0])
        self.dim = int(self.X.shape[1])
        if self.lam is None:
            self.lam = 1.0 / self.num_samples
        self._Xj = jnp.asarray(self.X)
        self._yj = jnp.asarray(self.y)
        self._opt = None  # lazy: computed by Newton iterations on first use

    def init(self, seed: int = 0) -> np.ndarray:
        return np.zeros((self.dim,), dtype=np.float32)

    def objective(self, V: np.ndarray) -> float:
        z = self.y * (self.X @ V)
        # log1p(exp(-z)) stable
        loss = np.logaddexp(0.0, -z).mean()
        return float(loss + 0.5 * self.lam * np.dot(V, V))

    def _solve_optimum(self) -> np.ndarray:
        """Newton's method — logreg is strongly convex with λ>0."""
        v = np.zeros(self.dim, dtype=np.float64)
        x = self.X.astype(np.float64)
        y = self.y.astype(np.float64)
        n = self.num_samples
        for _ in range(50):
            z = y * (x @ v)
            s = 1.0 / (1.0 + np.exp(z))  # σ(-z)
            grad = -(x.T @ (y * s)) / n + self.lam * v
            w = s * (1.0 - s)
            hess = (x.T * w) @ x / n + self.lam * np.eye(self.dim)
            step = np.linalg.solve(hess, grad)
            v = v - step
            if np.linalg.norm(step) < 1e-12:
                break
        return v

    @property
    def optimum_objective(self) -> float:
        if self._opt is None:
            self._opt = self._solve_optimum()
            self._opt_obj = self.objective(self._opt)
        return self._opt_obj

    def suboptimality(self, V: np.ndarray) -> float:
        return float(max(self.objective(V) - self.optimum_objective, 1e-16))

    def subgradient(self, V: np.ndarray, start: int, stop: int) -> np.ndarray:
        # routed through the G = 1 batched kernel (see subgradient_blocks)
        return self.subgradient_blocks(
            np.asarray(V)[None],
            np.array([start], dtype=np.int64),
            np.array([stop], dtype=np.int64),
        )[0]

    def subgradient_blocks(
        self, V_stack: np.ndarray, starts: np.ndarray, stops: np.ndarray
    ) -> np.ndarray:
        # Uses explicit elementwise-multiply + axis reductions rather than
        # matmuls: XLA lowers a [m, d] @ [d] mat-vec and a [G, m, d] batched
        # product to different kernels with different accumulation orders, so
        # matmul results would depend on the batch size.  The reduce-based
        # form is batch-invariant (row g identical at any G — pinned by
        # tests), which is what lets the scalar path reuse this kernel.
        starts = np.asarray(starts, dtype=np.int64)
        stops = np.asarray(stops, dtype=np.int64)
        widths = stops - starts + 1
        if widths.size == 0:
            return np.zeros((0, self.dim), dtype=np.float32)
        m = int(widths[0])
        if not np.all(widths == m):
            raise ValueError("subgradient_blocks requires equal-width intervals")
        V_stack, starts, stops, g = _bucket_pad(np.asarray(V_stack), starts, stops)
        idx = jnp.asarray(starts[:, None] - 1 + np.arange(m)[None, :])
        xg = self._Xj[idx]  # [G, m, d]
        yg = self._yj[idx]  # [G, m]
        Vb = jnp.asarray(V_stack)  # [G, d]
        z = yg * jnp.sum(xg * Vb[:, None, :], axis=2)
        s = jax.nn.sigmoid(-z)
        grad = -jnp.sum(xg * (yg * s)[:, :, None], axis=1) / self.num_samples
        return np.asarray(grad)[:g]

    def regularizer_grad(self, V: np.ndarray) -> np.ndarray:
        return self.lam * V

    def compute_cost(self, start: int, stop: int) -> float:
        return 2.0 * self.dim * (stop - start + 1)

    def compute_cost_batch(self, starts: np.ndarray, stops: np.ndarray) -> np.ndarray:
        rows = np.asarray(stops, dtype=np.int64) - np.asarray(starts, np.int64) + 1
        return 2.0 * self.dim * rows
