"""Data pipeline: synthetic corpora + group-sharded batch iterators."""

from repro.data.pipeline import GroupBatchIterator, make_batch_iterator

__all__ = ["GroupBatchIterator", "make_batch_iterator"]
