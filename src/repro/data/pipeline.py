"""Host data pipeline.

Produces batches with the Tier-1 layout: every leaf carries a leading DSAG
group dim [P, B/P, ...].  The sample->group assignment uses the paper's
``p_start/p_stop`` arithmetic over a (synthetic) document stream, and the
load balancer can re-slice group boundaries between steps without moving
data between hosts (each host's loader re-slices its local shard).

The corpus is a deterministic synthetic token stream (hash-mixed) so loss
curves are reproducible without shipping a dataset; examples can swap in a
real corpus by replacing ``token_block``.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Iterator

import numpy as np

from repro.configs.base import ModelConfig
from repro.lb.partitioner import p_start, p_stop


def token_block(seed: int, step: int, shape, vocab: int) -> np.ndarray:
    """Deterministic pseudo-corpus: overlapping n-gram-ish structure so a
    model can actually reduce loss (tokens correlate with position hash)."""
    rng = np.random.default_rng((seed * 1_000_003 + step) & 0x7FFFFFFF)
    base = rng.integers(0, vocab, size=shape, dtype=np.int64)
    # inject learnable structure: every even position repeats the previous
    # token with high probability
    rep = rng.random(shape) < 0.7
    shifted = np.roll(base, 1, axis=-1)
    out = np.where(rep & (np.arange(shape[-1]) % 2 == 0), shifted, base)
    return out.astype(np.int32)


@dataclasses.dataclass
class GroupBatchIterator:
    cfg: ModelConfig
    num_groups: int
    global_batch: int
    seq_len: int
    seed: int = 0
    step: int = 0
    #: fraction of the global batch assigned to each group (load balancing);
    #: defaults to uniform.  Kept normalized; group sizes are realized by
    #: masking within the fixed [P, B/P] layout (SPMD keeps shapes static).
    group_weights: np.ndarray | None = None

    def __post_init__(self):
        if self.global_batch % self.num_groups:
            raise ValueError(
                f"global_batch {self.global_batch} % groups {self.num_groups} != 0"
            )

    def set_group_weights(self, w: np.ndarray) -> None:
        w = np.asarray(w, dtype=np.float64)
        self.group_weights = w / w.sum()

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        p, bg = self.num_groups, self.global_batch // self.num_groups
        cfg = self.cfg
        s = self.seq_len
        if cfg.family == "vlm":
            toks = token_block(self.seed, self.step, (p, bg, s - cfg.num_image_tokens), cfg.vocab_size)
            img = token_block(self.seed + 7, self.step, (p, bg, cfg.num_image_tokens), 997)
            img_embed = (img[..., None] % 17 / 17.0 - 0.5).astype(np.float32)
            img_embed = np.repeat(img_embed, cfg.d_model, axis=-1)
            batch = {"tokens": toks, "image_embed": img_embed}
        elif cfg.family == "enc_dec":
            toks = token_block(self.seed, self.step, (p, bg, s), cfg.vocab_size)
            au = token_block(self.seed + 13, self.step, (p, bg, cfg.encoder_seq), 997)
            audio = (au[..., None] % 23 / 23.0 - 0.5).astype(np.float32)
            audio = np.repeat(audio, cfg.d_model, axis=-1)
            batch = {"tokens": toks, "audio_embed": audio}
        else:
            batch = {
                "tokens": token_block(self.seed, self.step, (p, bg, s), cfg.vocab_size)
            }
        self.step += 1
        return batch


def make_batch_iterator(
    cfg: ModelConfig,
    num_groups: int,
    global_batch: int,
    seq_len: int,
    seed: int = 0,
) -> GroupBatchIterator:
    return GroupBatchIterator(
        cfg=cfg,
        num_groups=num_groups,
        global_batch=global_batch,
        seq_len=seq_len,
        seed=seed,
    )
